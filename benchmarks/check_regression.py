"""CI guard: fail when batched protocol throughput regresses vs baseline.

Compares a fresh benchmark JSON (benchmarks/run.py ... --out BENCH_ci.json)
against the committed baseline (BENCH_1.json): the best batched dets/sec
for the chosen (n, N) shape must stay within `--factor` of the baseline's.

    python benchmarks/check_regression.py BENCH_ci.json BENCH_1.json \
        --n 64 --servers 2 --factor 2.0
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def best_batched_dets_per_sec(rows: list[dict], n: int, servers: int) -> float:
    """Max dets/sec over the batched throughput rows for one (n, N) shape."""
    rates = [
        float(r["dets_per_sec"])
        for r in rows
        if r.get("suite") == "throughput"
        and r.get("mode") == "batched"
        and r.get("n") == n
        and r.get("num_servers") == servers
    ]
    if not rates:
        raise SystemExit(
            f"no batched throughput rows for n={n}, N={servers} — "
            "did the throughput suite run?"
        )
    return max(rates)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", type=Path, help="freshly measured BENCH json")
    ap.add_argument("baseline", type=Path, help="committed baseline json")
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument(
        "--factor",
        type=float,
        default=2.0,
        help="maximum tolerated slowdown vs baseline (default 2.0x)",
    )
    args = ap.parse_args(argv)

    fresh = json.loads(args.fresh.read_text())
    base = json.loads(args.baseline.read_text())
    got = best_batched_dets_per_sec(fresh["rows"], args.n, args.servers)
    want = best_batched_dets_per_sec(base["rows"], args.n, args.servers)
    floor = want / args.factor
    verdict = "OK" if got >= floor else "REGRESSION"
    print(
        f"throughput n={args.n} N={args.servers}: fresh {got:.1f} dets/sec "
        f"vs baseline {want:.1f} (floor {floor:.1f} at {args.factor}x) "
        f"-> {verdict}"
    )
    return 0 if got >= floor else 1


if __name__ == "__main__":
    sys.exit(main())
