"""CI guard: fail when batched protocol / gateway throughput regresses.

Compares a fresh benchmark JSON (benchmarks/run.py ... --out BENCH_ci.json)
against a committed baseline: the best dets/sec for the chosen (n, N)
shape must stay within `--factor` of the baseline's.

    # batched-protocol guard (rows from the `throughput` suite, BENCH_1)
    python benchmarks/check_regression.py BENCH_ci.json BENCH_1.json \
        --n 64 --servers 2 --factor 2.0
    # gateway guard (rows from the `gateway` suite, BENCH_2): additionally
    # requires the fresh gateway to beat the fresh per-request loop rate —
    # the serving layer's acceptance claim
    python benchmarks/check_regression.py BENCH_ci.json BENCH_2.json \
        --suite gateway --n 64 --servers 2 --factor 2.0
    # precision guard (rows from the `precision` suite, BENCH_3): the f32
    # protocol must sustain >= --f32-speedup x the fresh f64 rate at --n,
    # and EVERY precision row must report a 100% Q3 verified-rate, worst
    # |dlog| <= 1e-4 vs the f64 references, and exact signs
    python benchmarks/check_regression.py BENCH_ci.json BENCH_3.json \
        --suite precision --n 256 --servers 4
    # transports guard (rows from the `transports` suite, BENCH_4): the
    # inline (fused) path of the role-split API must stay within --factor
    # of the committed baseline — the role split may not tax the fast path
    python benchmarks/check_regression.py BENCH_ci.json BENCH_4.json \
        --suite transports --n 256 --servers 4 --factor 1.5
    # rateless guard (rows from the `rateless` suite, BENCH_5): under the
    # straggling fault plan the rateless scheduler must sustain >=
    # --straggle-speedup x the deadline-based rate measured in the SAME
    # fresh run, stay within --honest-factor of an honest classic fleet
    # (the streaming scheduler's per-strip dispatches cannot match the
    # fused relay at smoke scale, so this bounds the overhead rather
    # than demanding parity), keep every leg 100%% verified, and stay
    # within --factor of the committed baseline's rateless_straggle rate
    python benchmarks/check_regression.py BENCH_ci.json BENCH_5.json \
        --suite rateless --n 64 --servers 4 --factor 2.0
    # sockets guard (rows from the `sockets` suite, BENCH_6): the socket
    # transport (real worker daemons, wire frames over UDS) must stay
    # within --socket-factor of the fresh inline rate — the "message
    # transports within 2-3x of inline at n >= 1024" claim of DESIGN.md
    # §9; pipelined sessions must never lose to the blocking loop on the
    # same warm daemons (--overlap-floor); every leg must verify; and the
    # committed baseline floors the absolute socket rate at --factor when
    # the shapes match (smoke runs a smaller n, so the floor is skipped
    # there, same as the rateless guard)
    python benchmarks/check_regression.py BENCH_ci.json BENCH_6.json \
        --suite sockets --n 1024 --servers 4 --factor 2.0
    # gateway_overload guard (rows from the `gateway_overload` suite,
    # BENCH_7): under open-loop Poisson storms every admitted request
    # must verify and every shed request must be a TYPED rejection that
    # accounts exactly (served + rejected == offered); the heaviest
    # storm must actually shed; the admitted-rate must beat the fresh
    # per-request loop rate (batching pays even while shedding); the
    # cache leg must hit >= 90%% and answer orders of magnitude above
    # the loop rate; the breaker leg must open at least once and keep
    # the clean bucket's rate within --containment-floor of its
    # no-chaos baseline; and the committed baseline floors the absolute
    # admitted rate at --factor when the shapes match (smoke shrinks
    # the request count, so the floor is skipped there)
    python benchmarks/check_regression.py BENCH_ci.json BENCH_7.json \
        --suite gateway_overload --n 32 --servers 2 --factor 2.0
    # linalg guard (rows from the `linalg` suite, BENCH_8): a shared-LU
    # (slogdet, solve) pair must beat two standalone outsourcings by
    # >= --shared-speedup x (the committed baseline is held to the sharp
    # 1.5x claim), every row must report factorizations == 1 and fully
    # verified ops, the gradient-step leg must match the plaintext
    # reference to 1e-6, and the shared rate floors at --factor of the
    # committed baseline when the shapes match (smoke shrinks n, so the
    # floor is skipped there)
    python benchmarks/check_regression.py BENCH_ci.json BENCH_8.json \
        --suite linalg --n 256 --servers 2 --factor 2.0
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def best_dets_per_sec(
    rows: list[dict], n: int, servers: int, *, suite: str, modes: tuple,
    dtype: str | None = None,
) -> float:
    """Max dets/sec over a suite's rows for one (n, N) shape and mode set."""
    rates = [
        float(r["dets_per_sec"])
        for r in rows
        if r.get("suite") == suite
        and r.get("mode") in modes
        and r.get("n") == n
        and r.get("num_servers") == servers
        and (dtype is None or r.get("dtype") == dtype)
    ]
    if not rates:
        raise SystemExit(
            f"no {suite} rows with mode in {modes} for n={n}, N={servers} — "
            f"did the {suite} suite run?"
        )
    return max(rates)


def check_precision(
    fresh_rows: list[dict],
    base_rows: list[dict],
    n: int,
    servers: int,
    f32_speedup: float,
) -> tuple[bool, float, float]:
    """The precision suite's acceptance claims.

    The COMMITTED baseline must hold the sharp f32 ≥ 1.5× f64 claim at
    (n, N) — it is a deterministic artifact, immune to CI-runner noise.
    The FRESH run must show f32 ≥ --f32-speedup × f64 (the smoke leg runs
    with a margin, same as the gateway guard's factor), a 100% Q3
    verified-rate on EVERY measured precision row — f32 is a first-class
    verified dtype, not a fast-but-unverifiable mode — and the accuracy
    claim itself: every row's worst |Δ log|det|| vs the f64 references
    stays ≤ 1e-4 with exact signs (speed that costs digits is a
    regression, not a win).

    Returns:
        (ok, fresh_f32_rate, baseline_f32_rate) — the f32 rates are
        returned so the caller's --factor floor reuses the same row
        selection.
    """
    def ratio_of(rows, label, need):
        f32 = best_dets_per_sec(rows, n, servers, suite="precision",
                                modes=("batched",), dtype="float32")
        f64 = best_dets_per_sec(rows, n, servers, suite="precision",
                                modes=("batched",), dtype="float64")
        r = f32 / f64
        print(
            f"precision[{label}] n={n} N={servers}: f32 {f32:.1f} vs f64 "
            f"{f64:.1f} dets/sec = {r:.2f}x (need >= {need}x) "
            f"-> {'OK' if r >= need else 'FAIL'}"
        )
        return r >= need, f32

    base_ok, base_f32 = ratio_of(base_rows, "committed", 1.5)
    fresh_ok, fresh_f32 = ratio_of(fresh_rows, "fresh", f32_speedup)
    ok = base_ok and fresh_ok
    unverified = [
        r["name"] for r in fresh_rows
        if r.get("suite") == "precision" and "verified_rate" in r
        and float(r["verified_rate"]) < 1.0
    ]
    if unverified:
        print(f"precision verified-rate < 100% on: {unverified} -> FAIL")
    else:
        print("precision verified-rate 100% on every row -> OK")
    inaccurate = [
        r["name"] for r in fresh_rows
        if r.get("suite") == "precision"
        and (float(r.get("max_abs_dlog", 0.0)) > 1e-4
             or r.get("sign_ok") is False)
    ]
    if inaccurate:
        print(f"precision |dlog| > 1e-4 or wrong sign on: {inaccurate} -> FAIL")
    else:
        print("precision |dlog| <= 1e-4 with exact signs on every row -> OK")
    return ok and not unverified and not inaccurate, fresh_f32, base_f32


def check_linalg(
    fresh_rows: list[dict],
    base_rows: list[dict],
    n: int,
    servers: int,
    shared_speedup: float,
    factor: float,
) -> bool:
    """The linalg suite's acceptance claims (DESIGN.md §12, BENCH_8).

    The COMMITTED baseline must hold the sharp shared ≥ 1.5× independent
    claim at its own measured shape — one factorization serving a
    (slogdet, solve) pair must beat two standalone outsourcings, which is
    the subsystem's reason to exist. The FRESH run must show shared ≥
    --shared-speedup × independent (margin for runner noise), report
    factorizations == 1 on the shared row AND the gradient-step row (the
    whole custom-VJP backward pass rides the same LU), keep every op
    verified, keep the gradient within 1e-6 of the plaintext reference,
    and stay within --factor of the committed baseline's shared rate when
    the shapes match (smoke shrinks n, so the floor is skipped there).
    """
    def rows_of(rows, mode):
        return [r for r in rows if r.get("suite") == "linalg"
                and r.get("mode") == mode]

    def speedup_of(rows, label, need, at_n):
        ratios = [float(r["shared_speedup"]) for r in rows_of(rows, "ratio")
                  if at_n is None or r.get("n") == at_n]
        if not ratios:
            raise SystemExit(
                f"no linalg ratio rows ({label}) — did the suite run?"
            )
        r = max(ratios)
        print(
            f"linalg[{label}]: shared/independent {r:.2f}x "
            f"(need >= {need}x) -> {'OK' if r >= need else 'FAIL'}"
        )
        return r >= need

    ok = speedup_of(base_rows, "committed", 1.5, None)
    ok = speedup_of(fresh_rows, "fresh", shared_speedup, None) and ok

    not_amortized = [
        r["name"] for r in fresh_rows
        if r.get("suite") == "linalg" and "factorizations" in r
        and int(r["factorizations"]) != 1
    ]
    if not_amortized:
        print(f"linalg factorizations != 1 on: {not_amortized} -> FAIL")
    else:
        print("linalg one-factorization claim holds on every row -> OK")
    unverified = [
        r["name"] for r in fresh_rows
        if r.get("suite") == "linalg" and r.get("all_verified") is False
    ]
    if unverified:
        print(f"linalg unverified ops on: {unverified} -> FAIL")
    else:
        print("linalg every op verified on every row -> OK")
    bad_grad = [
        r["name"] for r in rows_of(fresh_rows, "gradstep")
        if float(r.get("grad_err", "1")) > 1e-6
        or r.get("value_matches") is False
    ]
    if bad_grad:
        print(f"linalg gradient off the 1e-6 bar on: {bad_grad} -> FAIL")
    else:
        print("linalg gradients within 1e-6 of the reference -> OK")
    ok = ok and not not_amortized and not unverified and not bad_grad

    try:
        got = best_rate(fresh_rows, n, servers, "shared")
        want = best_rate(base_rows, n, servers, "shared")
    except SystemExit:
        print(
            f"linalg[baseline] no shared rows at n={n} N={servers} in both "
            "runs (smoke shapes differ) — absolute floor skipped"
        )
        return ok
    good = got >= want / factor
    print(
        f"linalg[baseline] n={n} N={servers}: fresh {got:.2f} vs baseline "
        f"{want:.2f} ops/sec (floor {want / factor:.2f} at {factor}x) "
        f"-> {'OK' if good else 'REGRESSION'}"
    )
    return ok and good


def best_rate(rows: list[dict], n: int, servers: int, mode: str) -> float:
    """Max ops_per_sec over linalg rows for one (n, N) shape and mode."""
    rates = [
        float(r["ops_per_sec"]) for r in rows
        if r.get("suite") == "linalg" and r.get("mode") == mode
        and r.get("n") == n and r.get("num_servers") == servers
    ]
    if not rates:
        raise SystemExit(
            f"no linalg rows with mode={mode} for n={n}, N={servers}"
        )
    return max(rates)


def check_rateless(
    fresh_rows: list[dict],
    base_rows: list[dict],
    n: int,
    servers: int,
    straggle_speedup: float,
    factor: float,
    honest_factor: float,
) -> bool:
    """The rateless suite's acceptance claims (DESIGN.md §8).

    All on the FRESH run (the modes share one process, one fleet, one
    machine — the ratios are noise-immune even when absolute rates are
    not): rateless beats the deadline-based session by
    ``straggle_speedup``× under the straggling plan; an honest uniform
    fleet pays at most ``honest_factor``× for over-decomposition and
    per-strip streaming (at smoke scale the F×lanes individual edge
    dispatches can't amortize against the fused relay's N, so the guard
    bounds that overhead instead of demanding parity — the bound
    tightens as n grows and strip compute dominates dispatch); every
    leg reports all_verified — a fast-but-rejected run is a regression,
    not a win. The committed baseline then floors the absolute
    rateless_straggle rate at ``factor``× like every other guard — but
    only against baseline rows measured at the SAME batch size: the
    smoke leg shrinks the batch and the fault plan's delays, so its
    absolute rates are a different experiment from the committed full
    run, and cross-shape floors would be noise, not a guard.
    """
    def rate(rows, mode):
        return best_dets_per_sec(
            rows, n, servers, suite="rateless", modes=(mode,)
        )

    ok = True
    r_strag = rate(fresh_rows, "rateless_straggle")
    d_strag = rate(fresh_rows, "deadline_straggle")
    sp = r_strag / d_strag
    good = sp >= straggle_speedup
    print(
        f"rateless[straggle] n={n} N={servers}: rateless {r_strag:.1f} vs "
        f"deadline-based {d_strag:.1f} dets/sec = {sp:.2f}x (need >= "
        f"{straggle_speedup}x) -> {'OK' if good else 'FAIL'}"
    )
    ok = ok and good
    r_hon = rate(fresh_rows, "rateless_honest")
    c_hon = rate(fresh_rows, "classic_honest")
    good = r_hon >= c_hon / honest_factor
    print(
        f"rateless[honest] n={n} N={servers}: rateless {r_hon:.1f} vs "
        f"classic {c_hon:.1f} dets/sec (floor {c_hon / honest_factor:.1f} "
        f"at {honest_factor}x) -> {'OK' if good else 'FAIL'}"
    )
    ok = ok and good
    unverified = [
        r["name"] for r in fresh_rows
        if r.get("suite") == "rateless" and r.get("all_verified") is False
    ]
    if unverified:
        print(f"rateless unverified legs: {unverified} -> FAIL")
        ok = False
    else:
        print("rateless all legs 100% verified -> OK")
    fresh_batch = [
        r.get("batch") for r in fresh_rows
        if r.get("suite") == "rateless" and r.get("mode") == "rateless_straggle"
        and r.get("n") == n and r.get("num_servers") == servers
    ]
    base_match = [
        float(r["dets_per_sec"]) for r in base_rows
        if r.get("suite") == "rateless" and r.get("mode") == "rateless_straggle"
        and r.get("n") == n and r.get("num_servers") == servers
        and r.get("batch") in fresh_batch
    ]
    if not base_match:
        print(
            f"rateless[baseline] n={n} N={servers}: no baseline "
            f"rateless_straggle row at batch={fresh_batch} — smoke shapes "
            f"differ from the committed full run; skipping absolute floor"
        )
        return ok
    base_strag = max(base_match)
    good = r_strag >= base_strag / factor
    print(
        f"rateless[baseline] n={n} N={servers}: fresh {r_strag:.1f} vs "
        f"baseline {base_strag:.1f} dets/sec (floor "
        f"{base_strag / factor:.1f} at {factor}x) "
        f"-> {'OK' if good else 'REGRESSION'}"
    )
    return ok and good


def check_sockets(
    fresh_rows: list[dict],
    base_rows: list[dict],
    n: int,
    servers: int,
    socket_factor: float,
    overlap_floor: float,
    factor: float,
) -> bool:
    """The sockets suite's acceptance claims (DESIGN.md §9).

    Ratios are taken on the FRESH run (inline and socket share one
    process and one machine, so the ratio is noise-immune even when the
    absolute rates are not): the socket transport — real worker daemons,
    wire-codec frames on a UDS — stays within ``socket_factor`` of the
    fused inline rate at its best sustained mode, which is the PIPELINED
    loop: the async-overlap redesign (`run_pipelined(depth=2)`, PMOP of
    batch k+1 overlapping wire time of batch k) is exactly the mechanism
    that buys the within-3x claim, so the guard measures the transport
    as the API means it to be driven (the blocking single-session rate
    is reported alongside, not guarded); pipelined sessions sustain at
    least ``overlap_floor`` x the blocking sequential loop on the SAME
    warm daemons — the redesign's whole point is that overlap is free,
    so a pipelined loss is a regression; and every leg verifies. The
    COMMITTED baseline must hold the sharp within-3x claim at its own
    shape (it is a deterministic artifact, immune to runner noise), and
    floors the fresh absolute socket rate at ``factor`` x when the
    fresh shapes match the committed ones (the smoke leg shrinks n and
    the batch, so cross-shape floors would be noise, not a guard —
    skipped, same as the rateless guard).
    """
    SOCKET_MODES = ("socket", "socket_seq", "socket_pipelined")

    def rate(rows, *modes):
        return best_dets_per_sec(
            rows, n, servers, suite="sockets", modes=modes
        )

    ok = True
    s = rate(fresh_rows, *SOCKET_MODES)
    i = rate(fresh_rows, "inline")
    r = s / i
    good = r >= 1.0 / socket_factor
    print(
        f"sockets[fresh] n={n} N={servers}: socket {s:.1f} vs inline "
        f"{i:.1f} dets/sec = {r:.3f}x (floor {1.0 / socket_factor:.3f} at "
        f"{socket_factor}x) -> {'OK' if good else 'FAIL'}"
    )
    ok = ok and good
    pipe = rate(fresh_rows, "socket_pipelined")
    seq = rate(fresh_rows, "socket_seq")
    print(f"sockets[fresh] best socket mode rate {s:.1f} "
          f"(blocking {seq:.1f}, pipelined {pipe:.1f})")
    good = pipe >= seq * overlap_floor
    print(
        f"sockets[overlap] n={n} N={servers}: pipelined {pipe:.1f} vs "
        f"blocking {seq:.1f} dets/sec = {pipe / seq:.2f}x (floor "
        f"{overlap_floor}x) -> {'OK' if good else 'FAIL'}"
    )
    ok = ok and good
    unverified = [
        r2["name"] for r2 in fresh_rows
        if r2.get("suite") == "sockets" and r2.get("all_verified") is False
    ]
    if unverified:
        print(f"sockets unverified legs: {unverified} -> FAIL")
        ok = False
    else:
        print("sockets all legs 100% verified -> OK")
    # committed claim, at the baseline's own shapes: the within-3x claim
    # is asymptotic in n (wire is n², compute is n³), so the sharp floor
    # binds at the LARGEST committed n; smaller legs are reported so the
    # trajectory stays visible but a small-n ratio is not a failure
    base_pairs = sorted({
        (r2["n"], r2["num_servers"]) for r2 in base_rows
        if r2.get("suite") == "sockets" and r2.get("mode") in SOCKET_MODES
    })
    for bn, bN in base_pairs:
        bs = best_dets_per_sec(base_rows, bn, bN, suite="sockets",
                               modes=SOCKET_MODES)
        bi = best_dets_per_sec(base_rows, bn, bN, suite="sockets",
                               modes=("inline",))
        br = bs / bi
        binding = bn == base_pairs[-1][0]
        good = br >= 1.0 / 3.0
        print(
            f"sockets[committed] n={bn} N={bN}: socket {bs:.1f} vs inline "
            f"{bi:.1f} dets/sec = {br:.3f}x "
            + (f"(sharp floor 0.333) -> {'OK' if good else 'FAIL'}"
               if binding else "(informational leg)")
        )
        if binding:
            ok = ok and good
    fresh_batch = [
        r2.get("batch") for r2 in fresh_rows
        if r2.get("suite") == "sockets" and r2.get("mode") in SOCKET_MODES
        and r2.get("n") == n and r2.get("num_servers") == servers
    ]
    base_match = [
        float(r2["dets_per_sec"]) for r2 in base_rows
        if r2.get("suite") == "sockets" and r2.get("mode") in SOCKET_MODES
        and r2.get("n") == n and r2.get("num_servers") == servers
        and r2.get("batch") in fresh_batch
    ]
    if not base_match:
        print(
            f"sockets[baseline] n={n} N={servers}: no baseline socket row "
            f"at batch={fresh_batch} — smoke shapes differ from the "
            f"committed full run; skipping absolute floor"
        )
        return ok
    base_s = max(base_match)
    good = s >= base_s / factor
    print(
        f"sockets[baseline] n={n} N={servers}: fresh {s:.1f} vs baseline "
        f"{base_s:.1f} dets/sec (floor {base_s / factor:.1f} at {factor}x) "
        f"-> {'OK' if good else 'REGRESSION'}"
    )
    return ok and good


def check_gateway_overload(
    fresh_rows: list[dict],
    base_rows: list[dict],
    n: int,
    servers: int,
    containment_floor: float,
    factor: float,
) -> bool:
    """The overload & chaos suite's acceptance claims (DESIGN.md §10).

    All sharp claims are taken on the FRESH run (the loop baseline, the
    storms, the cache leg, and both breaker legs share one process and
    one machine, so the ratios are noise-immune):

      * every overload leg accounts exactly — served + typed rejections
        == offered requests (no lost or silently dropped submissions) —
        and every ADMITTED request verifies;
      * the heaviest storm sheds (an overload guard that never rejects
        guards nothing);
      * the best admitted rate beats the fresh per-request loop rate —
        micro-batching must keep paying even while the admission layer
        is shedding (the serving layer's §5 claim, restated under load);
      * the cache leg hits >= 90% on identical resubmissions and
        answers >= 10x the loop rate (an idempotency hit must cost a
        hash, not a sweep);
      * the breaker leg opens at least once under pinned chaos and the
        CLEAN bucket's rate stays >= ``containment_floor`` x its own
        no-chaos baseline — a poisoned bucket must not starve healthy
        traffic (§10.2's containment claim; the no-chaos leg must not
        trip the breaker at all, folded into its all_verified flag).

    The COMMITTED baseline floors the fresh absolute admitted rate at
    ``factor`` x when an overload row matches on (n, N, offered_mult,
    requests); the smoke run shrinks the request count, so the floor is
    skipped there with a visible message, same as the sockets guard.
    """
    ok = True
    sweeps = [r for r in fresh_rows
              if r.get("suite") == "gateway_overload"
              and r.get("mode") == "overload"
              and r.get("n") == n and r.get("num_servers") == servers]
    if not sweeps:
        print(f"gateway_overload: no fresh overload rows at n={n} "
              f"N={servers} -> FAIL")
        return False
    for r in sweeps:
        shed = (r["rejected_overload"] + r["rejected_admission"]
                + r["rejected_breaker"])
        acct = (
            bool(r.get("all_accounted"))
            and r["served"] + shed == r["requests"]
        )
        ver = bool(r.get("all_verified"))
        print(
            f"gateway_overload[x{r['offered_mult']:g}] served {r['served']} "
            f"+ shed {shed} of {r['requests']} (typed: "
            f"overload={r['rejected_overload']} "
            f"admission={r['rejected_admission']} "
            f"breaker={r['rejected_breaker']}), p99 {r['p99_ms']}ms -> "
            f"{'OK' if acct and ver else 'FAIL'}"
            + ("" if ver else " (unverified admitted result)")
        )
        ok = ok and acct and ver
    heaviest = max(sweeps, key=lambda r: r["offered_mult"])
    heaviest_shed = (heaviest["rejected_overload"]
                     + heaviest["rejected_admission"]
                     + heaviest["rejected_breaker"])
    good = heaviest_shed > 0
    print(f"gateway_overload[shedding] x{heaviest['offered_mult']:g} storm "
          f"shed {heaviest_shed} -> {'OK' if good else 'FAIL'}")
    ok = ok and good
    loop = best_dets_per_sec(fresh_rows, n, servers,
                             suite="gateway_overload", modes=("loop",))
    admitted = max(float(r["dets_per_sec"]) for r in sweeps)
    good = admitted > loop
    print(
        f"gateway_overload[beats-loop] admitted {admitted:.1f} vs "
        f"per-request {loop:.1f} dets/sec -> {'OK' if good else 'FAIL'}"
    )
    ok = ok and good
    caches = [r for r in fresh_rows
              if r.get("suite") == "gateway_overload"
              and r.get("mode") == "cache" and r.get("n") == n]
    for r in caches:
        good = (r["hit_rate"] >= 0.9 and r["speedup_vs_loop"] >= 10.0
                and bool(r.get("all_verified")))
        print(
            f"gateway_overload[cache] hit_rate {r['hit_rate']:.3f} "
            f"(floor 0.9), {r['speedup_vs_loop']:.0f}x loop rate "
            f"(floor 10x) -> {'OK' if good else 'FAIL'}"
        )
        ok = ok and good
    if not caches:
        print("gateway_overload: no fresh cache rows -> FAIL")
        ok = False
    breakers = [r for r in fresh_rows
                if r.get("suite") == "gateway_overload"
                and r.get("mode") == "breaker" and r.get("n") == n]
    for r in breakers:
        good = (r["breaker_opens"] >= 1
                and r["containment_ratio"] >= containment_floor
                and bool(r.get("all_verified")))
        print(
            f"gateway_overload[breaker] opens {r['breaker_opens']}, clean "
            f"bucket {r['clean_dets_per_sec']:.1f} vs no-chaos "
            f"{r['baseline_dets_per_sec']:.1f} dets/sec = "
            f"{r['containment_ratio']:.3f}x (floor {containment_floor}x) "
            f"-> {'OK' if good else 'FAIL'}"
        )
        ok = ok and good
    if not breakers:
        print("gateway_overload: no fresh breaker rows -> FAIL")
        ok = False
    # committed-baseline absolute floor, only at matching storm shapes
    fresh_shapes = {(r["offered_mult"], r["requests"]) for r in sweeps}
    base_match = [
        float(r["dets_per_sec"]) for r in base_rows
        if r.get("suite") == "gateway_overload"
        and r.get("mode") == "overload"
        and r.get("n") == n and r.get("num_servers") == servers
        and (r.get("offered_mult"), r.get("requests")) in fresh_shapes
    ]
    if not base_match:
        print(
            f"gateway_overload[baseline] n={n} N={servers}: no baseline "
            f"overload row at shapes={sorted(fresh_shapes)} — smoke "
            f"shapes differ from the committed full run; skipping "
            f"absolute floor"
        )
        return ok
    base_a = max(base_match)
    good = admitted >= base_a / factor
    print(
        f"gateway_overload[baseline] n={n} N={servers}: fresh "
        f"{admitted:.1f} vs baseline {base_a:.1f} dets/sec (floor "
        f"{base_a / factor:.1f} at {factor}x) "
        f"-> {'OK' if good else 'REGRESSION'}"
    )
    return ok and good


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", type=Path, help="freshly measured BENCH json")
    ap.add_argument("baseline", type=Path, help="committed baseline json")
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument(
        "--factor",
        type=float,
        default=2.0,
        help="maximum tolerated slowdown vs baseline (default 2.0x)",
    )
    ap.add_argument(
        "--suite",
        choices=("throughput", "gateway", "precision", "transports",
                 "rateless", "sockets", "gateway_overload", "linalg"),
        default="throughput",
        help="which suite's rows to guard (gateway also checks the "
        "gateway-beats-loop acceptance claim on the fresh run; precision "
        "checks the f32-speedup and 100%%-verified claims; transports "
        "guards the role-split inline fast path; rateless checks the "
        "straggle-speedup, honest-within-noise, and all-verified claims; "
        "sockets checks the socket-within-socket-factor-of-inline, "
        "pipelined-never-loses, and all-verified claims; "
        "gateway_overload checks the typed-shedding, exact-accounting, "
        "all-admitted-verified, cache-hit, and breaker-containment "
        "claims)",
    )
    ap.add_argument(
        "--f32-speedup",
        type=float,
        default=1.5,
        help="precision suite: minimum fresh f32/f64 dets/sec ratio",
    )
    ap.add_argument(
        "--shared-speedup",
        type=float,
        default=1.5,
        help="linalg suite: minimum fresh shared-LU / two-independent-"
        "outsourcings rate ratio for a (slogdet, solve) pair",
    )
    ap.add_argument(
        "--straggle-speedup",
        type=float,
        default=1.5,
        help="rateless suite: minimum fresh rateless/deadline-based "
        "dets/sec ratio under the straggling fault plan",
    )
    ap.add_argument(
        "--honest-factor",
        type=float,
        default=6.0,
        help="rateless suite: maximum tolerated honest-uniform-fleet "
        "slowdown of the streaming scheduler vs the fused classic "
        "session (per-strip dispatch overhead, see check_rateless)",
    )
    ap.add_argument(
        "--socket-factor",
        type=float,
        default=3.0,
        help="sockets suite: maximum tolerated fresh socket-vs-inline "
        "slowdown (the DESIGN.md §9 within-2-3x claim; the committed "
        "baseline is always held to the sharp 3x)",
    )
    ap.add_argument(
        "--containment-floor",
        type=float,
        default=0.5,
        help="gateway_overload suite: minimum clean-bucket dets/sec "
        "ratio (chaos run / no-chaos baseline) — the breaker must keep "
        "a poisoned bucket from starving healthy traffic (0.5 "
        "tolerates runner noise; fast-failed chaos usually makes the "
        "ratio exceed 1)",
    )
    ap.add_argument(
        "--overlap-floor",
        type=float,
        default=0.9,
        help="sockets suite: minimum fresh pipelined/blocking dets/sec "
        "ratio on the same warm daemons (0.9 tolerates runner noise; "
        "the overlap must never be a real loss)",
    )
    args = ap.parse_args(argv)

    fresh = json.loads(args.fresh.read_text())
    base = json.loads(args.baseline.read_text())
    if args.suite == "linalg":
        ok = check_linalg(fresh["rows"], base["rows"], args.n,
                          args.servers, args.shared_speedup, args.factor)
        return 0 if ok else 1
    if args.suite == "gateway_overload":
        ok = check_gateway_overload(fresh["rows"], base["rows"], args.n,
                                    args.servers, args.containment_floor,
                                    args.factor)
        return 0 if ok else 1
    if args.suite == "sockets":
        ok = check_sockets(fresh["rows"], base["rows"], args.n,
                           args.servers, args.socket_factor,
                           args.overlap_floor, args.factor)
        return 0 if ok else 1
    if args.suite == "rateless":
        ok = check_rateless(fresh["rows"], base["rows"], args.n,
                            args.servers, args.straggle_speedup, args.factor,
                            args.honest_factor)
        return 0 if ok else 1
    if args.suite == "precision":
        ok, got, want = check_precision(fresh["rows"], base["rows"], args.n,
                                        args.servers, args.f32_speedup)
        floor = want / args.factor
        print(
            f"precision f32 n={args.n} N={args.servers}: fresh {got:.1f} "
            f"vs baseline {want:.1f} dets/sec (floor {floor:.1f} at "
            f"{args.factor}x) -> {'OK' if got >= floor else 'REGRESSION'}"
        )
        return 0 if ok and got >= floor else 1
    modes = {
        "throughput": ("batched",),
        "gateway": ("gateway",),
        "transports": ("inline",),
    }[args.suite]
    got = best_dets_per_sec(
        fresh["rows"], args.n, args.servers, suite=args.suite, modes=modes
    )
    want = best_dets_per_sec(
        base["rows"], args.n, args.servers, suite=args.suite, modes=modes
    )
    floor = want / args.factor
    ok = got >= floor
    print(
        f"{args.suite} n={args.n} N={args.servers}: fresh {got:.1f} dets/sec "
        f"vs baseline {want:.1f} (floor {floor:.1f} at {args.factor}x) "
        f"-> {'OK' if ok else 'REGRESSION'}"
    )
    if args.suite == "gateway":
        loop = best_dets_per_sec(
            fresh["rows"], args.n, args.servers, suite="gateway",
            modes=("loop",),
        )
        beats = got > loop
        print(
            f"gateway-beats-loop n={args.n} N={args.servers}: gateway "
            f"{got:.1f} vs per-request {loop:.1f} dets/sec "
            f"-> {'OK' if beats else 'FAIL'}"
        )
        ok = ok and beats
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
