"""CI guard: fail when batched protocol / gateway throughput regresses.

Compares a fresh benchmark JSON (benchmarks/run.py ... --out BENCH_ci.json)
against a committed baseline: the best dets/sec for the chosen (n, N)
shape must stay within `--factor` of the baseline's.

    # batched-protocol guard (rows from the `throughput` suite, BENCH_1)
    python benchmarks/check_regression.py BENCH_ci.json BENCH_1.json \
        --n 64 --servers 2 --factor 2.0
    # gateway guard (rows from the `gateway` suite, BENCH_2): additionally
    # requires the fresh gateway to beat the fresh per-request loop rate —
    # the serving layer's acceptance claim
    python benchmarks/check_regression.py BENCH_ci.json BENCH_2.json \
        --suite gateway --n 64 --servers 2 --factor 2.0
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def best_dets_per_sec(
    rows: list[dict], n: int, servers: int, *, suite: str, modes: tuple
) -> float:
    """Max dets/sec over a suite's rows for one (n, N) shape and mode set."""
    rates = [
        float(r["dets_per_sec"])
        for r in rows
        if r.get("suite") == suite
        and r.get("mode") in modes
        and r.get("n") == n
        and r.get("num_servers") == servers
    ]
    if not rates:
        raise SystemExit(
            f"no {suite} rows with mode in {modes} for n={n}, N={servers} — "
            f"did the {suite} suite run?"
        )
    return max(rates)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", type=Path, help="freshly measured BENCH json")
    ap.add_argument("baseline", type=Path, help="committed baseline json")
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument(
        "--factor",
        type=float,
        default=2.0,
        help="maximum tolerated slowdown vs baseline (default 2.0x)",
    )
    ap.add_argument(
        "--suite",
        choices=("throughput", "gateway"),
        default="throughput",
        help="which suite's rows to guard (gateway also checks the "
        "gateway-beats-loop acceptance claim on the fresh run)",
    )
    args = ap.parse_args(argv)

    fresh = json.loads(args.fresh.read_text())
    base = json.loads(args.baseline.read_text())
    modes = ("batched",) if args.suite == "throughput" else ("gateway",)
    got = best_dets_per_sec(
        fresh["rows"], args.n, args.servers, suite=args.suite, modes=modes
    )
    want = best_dets_per_sec(
        base["rows"], args.n, args.servers, suite=args.suite, modes=modes
    )
    floor = want / args.factor
    ok = got >= floor
    print(
        f"{args.suite} n={args.n} N={args.servers}: fresh {got:.1f} dets/sec "
        f"vs baseline {want:.1f} (floor {floor:.1f} at {args.factor}x) "
        f"-> {'OK' if ok else 'REGRESSION'}"
    )
    if args.suite == "gateway":
        loop = best_dets_per_sec(
            fresh["rows"], args.n, args.servers, suite="gateway",
            modes=("loop",),
        )
        beats = got > loop
        print(
            f"gateway-beats-loop n={args.n} N={args.servers}: gateway "
            f"{got:.1f} vs per-request {loop:.1f} dets/sec "
            f"-> {'OK' if beats else 'FAIL'}"
        )
        ok = ok and beats
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
