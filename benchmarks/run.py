"""Benchmark harness — one function per paper table/figure, plus the
throughput suite that tracks the batch-first protocol.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's claim
being checked, e.g. a flop count, speedup, or ratio) AND collects every row
into a machine-readable JSON baseline (BENCH_1.json at the repo root) so
future PRs have a perf trajectory to beat.

  table1_overhead        — paper Table I: per-stage client cost (flops/biops)
                           measured (wall µs) + counted vs the paper's models
  table2_characteristics — paper Table II: executable protocol properties
  table3_matrix_support  — paper Table III/IV: odd/even sizes + minimal padding
  fig_scaling            — §IV.D: N-server parallel LU scaling (the 2-server
                           baseline of Gao & Yu = N=2 column)
  verification_cost      — §IV.E: Q1 vs Q2 vs Q3 cost and rejection power
  cipher_fusion          — §IV.C: fused CED kernel vs two-pass cipher traffic
  spdc_pipeline_comm     — §IV.D.3: one-way relay bytes vs paper-exact volume
  throughput             — batch-first protocol: dets/sec vs batch size for
                           the (B, n, n) stack API vs a Python loop of
                           single-matrix calls
  faults                 — fault-tolerant SPDC: localized-shard recovery
                           overhead vs the paper's only remedy (full
                           re-outsource), wire savings included
  gateway                — micro-batching edge gateway (DESIGN.md §5):
                           sustained dets/sec + p50/p99 latency vs offered
                           load, against the per-request call baseline;
                           rows land in BENCH_2.json (its own CI guard)
  precision              — f32 vs f64 protocol (DESIGN.md §6): dets/sec
                           and verified-rate at n ∈ {64, 256, 1024}, plus
                           the worst log-space det error vs f64 numpy
                           references; rows land in BENCH_3.json, guarded
                           by check_regression.py --suite precision
                           (f32 ≥ 1.5× f64 at n=256, 100% Q3 verification)
  transports             — role-split API (DESIGN.md §7): dets/sec of the
                           SAME batched sweep over inline (fused fast
                           path) vs threadpool vs multiprocess (spawned
                           workers, wire-codec bytes on an OS pipe) at
                           n=256; rows land in BENCH_4.json with a
                           check_regression.py --suite transports guard
                           that inline stays within noise of the
                           pre-role-split throughput
  rateless               — rateless straggler-adaptive dispatch (DESIGN.md
                           §8): dets/sec of the streaming scheduler vs the
                           deadline-based classic session, honest uniform
                           fleet AND a Pareto/exponential straggling one;
                           rows land in BENCH_5.json, guarded by
                           check_regression.py --suite rateless (rateless
                           ≥ 1.5× deadline-based under straggle, within
                           noise on an honest fleet)
  sockets                — socket transport + async overlap (DESIGN.md §9):
                           dets/sec of warmed batched sweeps over real
                           worker daemons (UDS, length-prefixed wire
                           frames) vs the fused inline path at n=1024,
                           plus the pipelined-session overlap win vs a
                           sequential blocking loop on the SAME warm
                           daemons; rows land in BENCH_6.json, guarded
                           by check_regression.py --suite sockets
                           (socket within 3x of inline, pipelining never
                           slower than blocking, every leg verified)
  gateway_overload       — production-hardened gateway (DESIGN.md §10):
                           open-loop Poisson overload at 2×/8×/16× the
                           per-request loop rate against a rate-limited,
                           bounded-queue gateway (admitted p50/p99, typed
                           rejection accounting, 100% of admitted
                           verified), an idempotency cache-hit leg, and a
                           breaker-containment leg (one bucket poisoned,
                           the clean bucket's rate vs its no-fault
                           baseline); rows land in BENCH_7.json, guarded
                           by check_regression.py --suite gateway_overload
  extension_inverse      — paper §VII.B future work: secure inversion

Usage: python benchmarks/run.py [suite ...] [--smoke] [--out PATH]
(default: all suites; --smoke shrinks shapes for CI; --out writes the
measured rows as JSON without touching the committed BENCH_1.json /
BENCH_2.json baselines)
"""
from __future__ import annotations

import time

import jax

jax.config.update("jax_enable_x64", True)

import json
import platform
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

import jax.numpy as jnp
import numpy as np

#: every emit() lands here; main() dumps it as BENCH_1.json
RESULTS: list[dict] = []

#: --smoke shrinks suite shapes for the CI benchmark job
SMOKE = False


def emit(name: str, us: float, **derived) -> None:
    """One benchmark row: CSV to stdout + structured record to RESULTS."""
    kv = ",".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us:.1f}{',' + kv if kv else ''}")
    RESULTS.append({"name": name, "us_per_call": round(us, 1), **derived})


def _t(fn, *args, reps=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6, out


def _wellcond(n, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    if batch is None:
        return rng.standard_normal((n, n)) + n * np.eye(n)
    return rng.standard_normal((batch, n, n)) + n * np.eye(n)


def table1_overhead(n: int = 1024):
    """Paper Table I: SeedGen 2n biops, KeyGen n, Cipher n², Authenticate
    0 + 2n(n+1) (Q3), Decipher 2n."""
    from repro.core import (
        cipher, cipher_flops, decipher, decipher_flops, keygen, lu_unblocked,
        seedgen,
    )
    from repro.core.verify import authenticate, verification_flops

    m = _wellcond(n)
    mj = jnp.asarray(m)

    us, seed = _t(lambda: seedgen(128, m), reps=3)
    emit(f"table1_seedgen_n{n}", us, claimed_biops=2 * n)

    us, key = _t(lambda: keygen(128, seed, n), reps=3)
    emit(f"table1_keygen_n{n}", us, claimed_biops=n)

    cfn = jax.jit(lambda x: cipher(x, key, seed)[0])
    us, x = _t(cfn, mj)
    emit(f"table1_cipher_n{n}", us, claimed_flops=cipher_flops(n))

    _, meta = cipher(mj, key, seed)
    l, u = jax.jit(lu_unblocked)(x)
    for method in ("q1", "q2", "q3"):
        us, _ = _t(
            lambda method=method: authenticate(l, u, x, num_servers=4,
                                               method=method), reps=3
        )
        emit(f"table1_auth_{method}_n{n}", us,
             claimed_flops=verification_flops(n, method))

    us, det = _t(lambda: decipher(seed, meta, l, u), reps=3)
    emit(f"table1_decipher_n{n}", us, claimed_flops=decipher_flops(n))


def table2_characteristics():
    """Paper Table II, as executable checks: privacy-preserving (cipher
    changes all entries), parallel outsourcing (N-server LU matches), and
    malicious-model detection (tamper rejected)."""
    from repro.core import outsource_determinant

    m = _wellcond(24, seed=1)
    t0 = time.perf_counter()
    res = outsource_determinant(m, 4)
    ok = res.verified and np.isclose(
        res.det.logabs, np.linalg.slogdet(m)[1], rtol=1e-8
    )
    bad = outsource_determinant(
        m, 4, tamper=lambda l, u: (l.at[7, 3].add(0.05), u)
    )
    us = (time.perf_counter() - t0) * 1e6
    emit("table2_protocol_roundtrip", us, correct=bool(ok))
    emit("table2_malicious_detected", 0.0, rejected=bool(not bad.verified))


def table3_matrix_support():
    """Paper Tables III/IV: odd sizes minimally padded, even unpadded."""
    from repro.core import outsource_determinant, padding_for_servers

    rows = [(7, 2), (8, 2), (9, 3), (12, 3), (11, 4)]
    for n, servers in rows:
        m = _wellcond(n, seed=n)
        t0 = time.perf_counter()
        res = outsource_determinant(m, servers)
        us = (time.perf_counter() - t0) * 1e6
        ok = res.verified and np.isclose(
            res.det.logabs, np.linalg.slogdet(m)[1], rtol=1e-8
        )
        emit(f"table3_n{n}_N{servers}", us, padding=res.padding,
             min=padding_for_servers(n, servers), ok=bool(ok))


def fig_scaling(n: int = 512):
    """N-server LU vs a sequential blocked LU at the SAME block granularity
    (isolates the parallelism benefit from the blocking benefit). The
    critical-path model is the paper's §IV.D scalability claim: the last
    server's work ≈ (2/3)(n/N)³·N + O(n²·n/N) → ~1/N² of total flops on its
    own row after the pipeline fills."""
    from repro.core.lu import lu_blocked, lu_nserver

    x = jnp.asarray(_wellcond(n, seed=2))
    for N in (2, 4, 8):
        seq = jax.jit(lambda a, N=N: lu_blocked(a, n // N))
        base_us, _ = _t(seq, x, reps=2, warmup=1)
        fn = jax.jit(lambda a, N=N: lu_nserver(a, N)[:2])
        us, _ = _t(fn, x, reps=2, warmup=1)
        emit(f"fig_scaling_{N}server_n{n}", us,
             seq_blocked_us=round(base_us, 1),
             speedup=round(base_us / us, 2))


def verification_cost(n: int = 2048):
    """Q1 (vector) vs Q2/Q3 (scalar): cost and single-element sensitivity."""
    from repro.core import lu_unblocked, q1, q2, q3

    x = jnp.asarray(_wellcond(n, seed=3))
    l, u = jax.jit(lu_unblocked)(x)
    r = jnp.asarray(np.random.default_rng(0).standard_normal(n))
    for name, fn in (
        ("q1", jax.jit(lambda l, u, x: jnp.max(jnp.abs(q1(l, u, x, r))))),
        ("q2", jax.jit(lambda l, u, x: jnp.abs(q2(l, u, x, r)))),
        ("q3", jax.jit(q3)),
    ):
        us, resid = _t(fn, l, u, x, reps=3)
        u_bad = u.at[n // 2, n // 2].multiply(1.001)
        detect = float(fn(l, u_bad, x)) > 10 * float(resid) + 1e-12
        emit(f"verify_{name}_n{n}", us, residual=f"{float(resid):.2e}",
             detects_tamper=bool(detect))


def cipher_fusion(n: int = 2048):
    """Fused CED (1 HBM pass) vs unfused scale-then-rotate (2 passes)."""
    from repro.core import keygen, seedgen
    from repro.core.prt import rot90_cw
    from repro.kernels import ops

    m = jnp.asarray(_wellcond(n, seed=4))
    seed = seedgen(128, np.asarray(m))
    key = keygen(128, seed, n)
    v = jnp.asarray(key.v)

    fused = jax.jit(lambda m: ops.ced(m, v, 1, block=128))
    unfused = jax.jit(lambda m: rot90_cw(m / v.reshape(-1, 1), 1))
    us_f, a = _t(fused, m, reps=3)
    us_u, b = _t(unfused, m, reps=3)
    ok = np.allclose(np.asarray(a), np.asarray(b))
    # wall time of the fused kernel is interpret-mode (Python) — the claim
    # being checked is correctness + the 1-vs-2 HBM-pass traffic model
    emit(f"cipher_fused_n{n}", us_f, passes=1, match=bool(ok),
         note="interpret-mode")
    emit(f"cipher_unfused_n{n}", us_u, passes=2, traffic_ratio=2.0)


def spdc_pipeline_comm(n: int = 4096):
    """One-way relay volume: fixed-shape shard_map hops vs paper-exact."""
    from repro.distrib.spdc_pipeline import pipeline_collective_bytes

    for N in (2, 4, 8, 16):
        info = pipeline_collective_bytes(n, N)
        emit(f"comm_n{n}_N{N}", 0.0,
             relay_MB=round(info["relay_bytes"] / 1e6, 1),
             paper_MB=round(info["paper_exact_bytes"] / 1e6, 1),
             overcount=round(info["overcount_factor"], 2))


def throughput(ns=(64, 256, 1024), Ns=(2, 4, 8), batches=(1, 8, 32)):
    """Batch-first protocol throughput: dets/sec of one (B, n, n) call vs a
    Python loop of single-matrix calls (the pre-batching client pattern).

    The loop baseline's throughput is 1 / t_single: a loop of B calls costs
    exactly B · t_single (no warm state is shared between calls beyond what
    a real client would have)."""
    from repro.core import outsource_determinant

    if SMOKE:
        ns, Ns, batches = (64,), (2,), (1, 8, 32)
    for n in ns:
        for N in Ns:
            single_m = _wellcond(n, seed=n + N)
            t_single_us, res = _t(
                lambda N=N: outsource_determinant(single_m, N), reps=2, warmup=1
            )
            loop_dets_per_sec = 1e6 / t_single_us
            emit(f"throughput_loop_n{n}_N{N}", t_single_us,
                 suite="throughput", n=n, num_servers=N, batch=1,
                 mode="loop", dets_per_sec=round(loop_dets_per_sec, 2),
                 verified=bool(res.verified))
            for B in batches:
                stack = jnp.asarray(_wellcond(n, seed=n + N, batch=B))
                t_us, resb = _t(
                    lambda s=stack, N=N: outsource_determinant(s, N),
                    reps=2, warmup=1,
                )
                dets_per_sec = B * 1e6 / t_us
                emit(f"throughput_batched_n{n}_N{N}_B{B}", t_us,
                     suite="throughput", n=n, num_servers=N, batch=B,
                     mode="batched", dets_per_sec=round(dets_per_sec, 2),
                     speedup_vs_loop=round(dets_per_sec / loop_dets_per_sec, 2),
                     all_verified=bool(np.asarray(resb.verified).all()))


def faults_suite(n: int = 64, N: int = 4):
    """Fault-tolerant SPDC: the cost of healing one misbehaving server.

    Three timed paths per fault kind: honest run, tampered run with the
    verification-driven recovery scheduler (localize → re-dispatch one
    shard → splice), and the paper's only remedy — detect + full
    re-outsource (≈ 2× the honest run). Derived columns: recovery overhead
    vs honest, savings vs re-outsource, and the wire-cost ratio of one
    shard re-dispatch vs resending the n² ciphertext."""
    from repro.core import ServerFault, outsource_determinant

    if SMOKE:
        n = min(n, 64)
    m = _wellcond(n, seed=5)
    t_honest, res = _t(lambda: outsource_determinant(m, N), reps=2, warmup=1)
    assert res.verified
    emit(f"faults_honest_n{n}_N{N}", t_honest, suite="faults", n=n,
         num_servers=N, mode="honest")

    for kind, fault in (
        ("tamper", ServerFault(server=1)),
        ("dropout", ServerFault(server=1, kind="dropout")),
    ):
        t_rec, res_rec = _t(
            lambda f=fault: outsource_determinant(
                m, N, faults=f, recover=True, standby=1
            ),
            reps=2, warmup=1,
        )
        assert bool(np.all(res_rec.verified)) and res_rec.report.recovery.ok
        t_full = 2.0 * t_honest  # detect (wasted run) + re-outsource
        shard_elems = res_rec.report.recovery.events[0].comm_elements
        emit(
            f"faults_recover_{kind}_n{n}_N{N}", t_rec, suite="faults", n=n,
            num_servers=N, mode=f"recover_{kind}",
            rounds=res_rec.report.recovery.rounds,
            overhead_vs_honest=round(t_rec / t_honest, 2),
            speedup_vs_reoutsource=round(t_full / t_rec, 2),
            shard_wire_elems=shard_elems,
            reoutsource_wire_elems=(n + res_rec.padding) ** 2,
        )

    # batched: one bad matrix inside a stack — recovery splices one shard
    # of one matrix; the re-outsource remedy redoes the WHOLE batch
    B = 8
    stack = _wellcond(n, seed=6, batch=B)
    t_b, res_b = _t(
        lambda: outsource_determinant(stack, N), reps=2, warmup=1
    )
    t_brec, res_brec = _t(
        lambda: outsource_determinant(
            stack, N,
            faults=ServerFault(server=2, matrices=(3,)),
            recover=True, standby=1,
        ),
        reps=2, warmup=1,
    )
    assert bool(np.all(res_brec.verified)) and res_brec.report.recovery.ok
    emit(
        f"faults_recover_batched_n{n}_N{N}_B{B}", t_brec, suite="faults",
        n=n, num_servers=N, batch=B, mode="recover_batched",
        overhead_vs_honest=round(t_brec / t_b, 2),
        speedup_vs_reoutsource=round(2.0 * t_b / t_brec, 2),
    )


def gateway_suite(n: int = 64, N: int = 2):
    """Micro-batching gateway vs the per-request client pattern.

    The acceptance claim of the serving layer (ISSUE 3 / ROADMAP): a
    gateway coalescing single-matrix requests into batched sweeps sustains
    MORE aggregate dets/sec at n=64, N=2 than clients calling
    `outsource_determinant` one matrix at a time. Three measurement modes:

      * loop      — the baseline: one warm single-matrix call, 1/t rate;
      * gateway   — saturating open-loop arrivals (every request queued at
                    once), flushed in max_batch sweeps; sustained rate and
                    per-request p50/p99 from submit to verdict;
      * paced     — open-loop arrivals at a multiple of the loop rate
                    (the queueing-latency view of the same service).

    All gateway runs are warmed first (the jit shape set a padded gateway
    can produce), so rows measure steady-state serving, not compilation.
    """
    import asyncio

    from repro.configs import SPDCConfig, SPDCGatewayConfig
    from repro.core import outsource_determinant
    from repro.launch.serve_spdc import run_workload
    from repro.serve import AsyncSPDCGateway, SPDCGateway

    requests = 32 if SMOKE else 64
    batch_grid = (8,) if SMOKE else (8, 32)
    paced_mults = (4.0,) if SMOKE else (2.0, 8.0)

    rng = np.random.default_rng(7)
    spdc = SPDCConfig(num_servers=N)

    # baseline: the pre-gateway client pattern (same as throughput's loop)
    single_m = _wellcond(n, seed=n + N)
    t_single_us, res = _t(
        lambda: outsource_determinant(single_m, N), reps=3, warmup=1
    )
    loop_rate = 1e6 / t_single_us
    emit(f"gateway_loop_n{n}_N{N}", t_single_us, suite="gateway", n=n,
         num_servers=N, mode="loop", dets_per_sec=round(loop_rate, 2),
         verified=bool(res.verified))

    def lat_ms(results, q):
        return round(float(np.percentile(
            [r.latency_s for r in results], q) * 1e3), 2)

    for max_batch in batch_grid:
        cfg = SPDCGatewayConfig(
            name=f"bench-gw-B{max_batch}", buckets=(n,),
            max_batch=max_batch, max_wait_us=2000.0, spdc=spdc,
        )
        gw = SPDCGateway(cfg)
        gw.warmup()
        mats = [_wellcond(n, seed=1000 + i) for i in range(requests)]
        t0 = time.perf_counter()
        for m in mats:
            gw.submit(m)  # auto-flushes each time the bucket fills
        gw.drain()
        wall = time.perf_counter() - t0
        served = [gw.take(rid) for rid in range(requests)]
        assert all(r is not None for r in served), gw.stats.as_dict()
        rate = requests / wall
        emit(f"gateway_batched_n{n}_N{N}_B{max_batch}", wall * 1e6 / requests,
             suite="gateway", n=n, num_servers=N, mode="gateway",
             max_batch=max_batch, requests=requests,
             dets_per_sec=round(rate, 2),
             speedup_vs_loop=round(rate / loop_rate, 2),
             p50_ms=lat_ms(served, 50), p99_ms=lat_ms(served, 99),
             all_verified=bool(all(r.verified for r in served)))

    # paced open-loop: offered load as a multiple of the loop-client rate
    cfg = SPDCGatewayConfig(
        name="bench-gw-paced", buckets=(n,), max_batch=8,
        max_wait_us=2000.0, spdc=spdc,
    )
    SPDCGateway(cfg).warmup()  # shapes shared via the process jit cache
    for mult in paced_mults:
        offered = mult * loop_rate
        mats = [_wellcond(n, seed=2000 + i) for i in range(requests)]
        arrival_s = np.cumsum(
            rng.exponential(1.0 / offered, requests)
        )

        async def drive():
            async with AsyncSPDCGateway(cfg) as agw:
                return await run_workload(agw, mats, arrival_s)

        results, rejected, wall = asyncio.run(drive())
        served = [r for r in results if r is not None]
        emit(f"gateway_paced_n{n}_N{N}_x{mult:g}", wall * 1e6 / max(len(served), 1),
             suite="gateway", n=n, num_servers=N, mode="paced",
             offered_mult=mult, offered_per_sec=round(offered, 2),
             requests=requests, rejected=sum(rejected.values()),
             dets_per_sec=round(len(served) / wall, 2),
             p50_ms=lat_ms(served, 50), p99_ms=lat_ms(served, 99),
             all_verified=bool(all(r.verified for r in served)))

    # mixed raw sizes coalesced in one bucket — the gateway's defining case
    cfg = SPDCGatewayConfig(
        name="bench-gw-mixed", buckets=(n,), max_batch=8,
        max_wait_us=2000.0, spdc=spdc,
    )
    gw = SPDCGateway(cfg)
    sizes = rng.integers(n // 2, n + 1, size=requests)
    mats = [np.asarray(_wellcond(int(s), seed=3000 + i))
            for i, s in enumerate(sizes)]
    t0 = time.perf_counter()
    rids = [gw.submit(m) for m in mats]
    gw.drain()
    wall = time.perf_counter() - t0
    served = [gw.take(r) for r in rids]
    emit(f"gateway_mixed_n{n // 2}-{n}_N{N}", wall * 1e6 / requests,
         suite="gateway", n=n, num_servers=N, mode="gateway_mixed",
         requests=requests, dets_per_sec=round(requests / wall, 2),
         all_verified=bool(all(r.verified for r in served)))


def precision_suite(ns=(64, 256, 1024), N: int = 4, B: int = 8):
    """float32 vs float64 protocol (DESIGN.md §6) — the edge/accelerator
    precision profile's acceptance numbers.

    Per (n, dtype): dets/sec of one warmed (B, n, n) batched sweep, the
    Q3 verified-rate over the batch, and the worst per-matrix |Δ log|det||
    against float64 numpy references. The CI guard asserts f32 ≥ 1.5× the
    f64 rate at n = 256 with a 100% verified-rate — the claim that makes
    float32 the default edge profile rather than a degraded mode.
    """
    from repro.core import outsource_determinant

    if SMOKE:
        ns = (64, 256)  # keep B=8: the n=256 f32/f64 ratio is the claim
    for n in ns:
        stack = _wellcond(n, seed=n, batch=B)
        refs = [np.linalg.slogdet(stack[i]) for i in range(B)]
        rates = {}
        for dtype in ("float64", "float32"):
            t_us, res = _t(
                lambda d=dtype: outsource_determinant(stack, N, dtype=d),
                reps=2, warmup=1,
            )
            rate = B * 1e6 / t_us
            rates[dtype] = rate
            ok = np.asarray(res.verified)
            dlog = max(
                abs(res.dets[i].logabs - refs[i][1]) for i in range(B)
            )
            sign_ok = all(res.dets[i].sign == refs[i][0] for i in range(B))
            emit(
                f"precision_{dtype}_n{n}_N{N}_B{B}", t_us,
                suite="precision", n=n, num_servers=N, batch=B,
                dtype=dtype, mode="batched",
                dets_per_sec=round(rate, 2),
                verified_rate=round(float(ok.mean()), 4),
                max_abs_dlog=float(f"{dlog:.2e}"),
                sign_ok=bool(sign_ok),
            )
        emit(
            f"precision_speedup_n{n}_N{N}_B{B}", 0.0,
            suite="precision", n=n, num_servers=N, batch=B, mode="ratio",
            f32_speedup=round(rates["float32"] / rates["float64"], 2),
        )


def transports_suite(n: int = 256, N: int = 4, B: int = 8):
    """Role-split transports (DESIGN.md §7): one warmed (B, n, n) batched
    sweep per transport. inline is the fused fast path the gateway serves
    on — its rate is the regression claim (`--suite transports` guard:
    within noise of the committed baseline, i.e. of the pre-role-split
    protocol). threadpool/multiprocess quantify what a REAL execution
    boundary costs: per-server message dispatch, the sequential relay,
    and (multiprocess) wire-codec bytes over an OS pipe — the honest
    price of the paper's actual deployment shape, reported so nobody
    mistakes the simulation's throughput for it."""
    from repro.api import close_all
    from repro.core import outsource_determinant

    if SMOKE:
        B = 4
    stack = _wellcond(n, seed=n, batch=B)
    rates = {}
    for name in ("inline", "threadpool", "multiprocess"):
        t_us, res = _t(
            lambda tr=name: outsource_determinant(stack, N, transport=tr),
            reps=2, warmup=1,
        )
        rate = B * 1e6 / t_us
        rates[name] = rate
        emit(
            f"transports_{name}_n{n}_N{N}_B{B}", t_us,
            suite="transports", n=n, num_servers=N, batch=B, mode=name,
            dets_per_sec=round(rate, 2),
            vs_inline=round(rate / rates["inline"], 3),
            all_verified=bool(np.asarray(res.verified).all()),
        )
    close_all()  # shut the spawned workers down before the next suite


def rateless_suite(n: int = 64, N: int = 4, B: int = 8):
    """Rateless dispatch (DESIGN.md §8) vs the deadline-based session.

    Four measured modes over the SAME threadpool fleet:
      classic_honest / rateless_honest    — uniform fleet; the rateless
        claim here is "within noise" (over-decomposition must not tax a
        healthy fleet)
      deadline_straggle / rateless_straggle — two wall-clock stragglers
        (Pareto heavy tail + exponential); the classic relay WAITS out
        every sleep, the rateless scheduler times the slow workers out
        once, benches them, and streams their strips to the fast ones.
        The guarded claim: rateless ≥ 1.5× the deadline-based rate.

    The straggle legs reuse ONE client across reps — fleet health is
    client-lived, so later sessions skip the stragglers outright. That is
    the mechanism being measured, not an artifact.
    """
    from repro.api import ThreadPoolTransport
    from repro.api.client import SPDCClient
    from repro.configs.spdc import RatelessConfig
    from repro.core import ServerFault

    reps, delays = (2, (0.4, 0.2)) if SMOKE else (3, (1.0, 0.5))
    if SMOKE:
        B = 4
    stack = _wellcond(n, seed=n, batch=B)
    plan = (
        ServerFault(server=1, kind="delay", delay_s=delays[0],
                    delay_dist="pareto", delay_alpha=2.5),
        ServerFault(server=3, kind="delay", delay_s=delays[1],
                    delay_dist="exponential"),
    )
    cfg = RatelessConfig(request_timeout_s=0.25, probation_cooldown_s=1e9)
    rates = {}
    with ThreadPoolTransport() as tp:
        def measure(mode, client, faults):
            t_us, res = _t(
                lambda: client.open_session(stack, N, faults=faults).run(tp),
                reps=reps, warmup=1,
            )
            rates[mode] = B * 1e6 / t_us
            emit(
                f"rateless_{mode}_n{n}_N{N}_B{B}", t_us,
                suite="rateless", n=n, num_servers=N, batch=B, mode=mode,
                dets_per_sec=round(rates[mode], 2),
                all_verified=bool(np.asarray(res.verified).all()),
            )

        measure("classic_honest", SPDCClient(), ())
        measure("rateless_honest", SPDCClient(rateless=cfg), ())
        measure("deadline_straggle",
                SPDCClient(straggler_deadline=8, recover=True, standby=1),
                plan)
        measure("rateless_straggle", SPDCClient(rateless=cfg, recover=True),
                plan)
    emit(
        f"rateless_speedup_n{n}_N{N}_B{B}", 0.0,
        suite="rateless", n=n, num_servers=N, batch=B, mode="ratio",
        straggle_speedup=round(
            rates["rateless_straggle"] / rates["deadline_straggle"], 2
        ),
        honest_ratio=round(
            rates["rateless_honest"] / rates["classic_honest"], 2
        ),
    )


def sockets_suite(N: int = 4):
    """Socket transport + async overlap (DESIGN.md §9).

    Two legs (n=1024 and n=2048; smoke: one n=256 leg), each on warm
    state — daemon-side jit caches populated by untimed warmup sweeps,
    because persistence across sessions is the point of the worker
    daemons. Three claims per leg:

      * socket vs inline — the SAME warmed (B, n, n) batched sweep over
        real worker daemons (UDS sockets, length-prefixed wire frames,
        per-server processes) vs the fused inline path. Wire + codec
        cost scales n² while strip compute scales n³, so the ratio
        improves with n; the guarded within-3x claim is taken at the
        largest measured n (the "at n >= 1024" asymptote), with the
        best SUSTAINED socket mode — the pipelined loop — as the
        transport's rate, since the async-overlap redesign is exactly
        the mechanism that hides wire time.
      * pipelined vs sequential — K independent batches through
        `run_pipelined(depth=2)` (batch k+1's PMOP overlaps batch k's
        wire time via `Session.start`) vs the blocking
        `open_session().run()` loop on the SAME client and daemons; the
        overlap must never make things slower.
      * every leg verified — a fast-but-rejected sweep is a regression.
    """
    from repro.api.client import SPDCClient
    from repro.api.transport import TransportConfig
    from repro.core import outsource_determinant

    legs = ((256, 2, 4),) if SMOKE else ((1024, 4, 6), (2048, 2, 4))
    for n, B, K in legs:
        stack = _wellcond(n, seed=n, batch=B)

        t_us, res = _t(
            lambda: outsource_determinant(stack, N, transport="inline"),
            reps=2, warmup=1,
        )
        inline_rate = B * 1e6 / t_us
        emit(f"sockets_inline_n{n}_N{N}_B{B}", t_us, suite="sockets", n=n,
             num_servers=N, batch=B, mode="inline",
             dets_per_sec=round(inline_rate, 2),
             all_verified=bool(np.asarray(res.verified).all()))

        # self-hosted local daemons (addresses=() spawns one warm UDS
        # worker per server id); the client OWNS the config-built
        # transport and tears the fleet down on __exit__
        cfg = TransportConfig("socket", timeout=600.0)
        rates = {}
        with SPDCClient(transport=cfg) as client:
            tr = client.transport
            # warmup=2: the first sweep compiles every daemon's strip
            # kernels, the second settles allocator/wire buffers —
            # timing rep 1 would charge the socket path for one-time
            # warm costs the daemons exist to amortize
            t_us, res = _t(
                lambda: client.open_session(stack, N).run(tr),
                reps=3, warmup=2,
            )
            rates["socket"] = B * 1e6 / t_us
            emit(f"sockets_socket_n{n}_N{N}_B{B}", t_us, suite="sockets",
                 n=n, num_servers=N, batch=B, mode="socket",
                 dets_per_sec=round(rates["socket"], 2),
                 vs_inline=round(rates["socket"] / inline_rate, 3),
                 all_verified=bool(np.asarray(res.verified).all()))

            mats = [_wellcond(n, seed=7000 + i, batch=B) for i in range(K)]
            t0 = time.perf_counter()
            seq = [client.open_session(m, N).run(tr) for m in mats]
            t_seq = time.perf_counter() - t0
            rates["seq"] = K * B / t_seq
            emit(f"sockets_seq_n{n}_N{N}_B{B}_K{K}", t_seq * 1e6 / K,
                 suite="sockets", n=n, num_servers=N, batch=B,
                 mode="socket_seq",
                 dets_per_sec=round(rates["seq"], 2),
                 all_verified=bool(
                     all(np.asarray(r.verified).all() for r in seq)
                 ))

            t0 = time.perf_counter()
            piped = client.run_pipelined(mats, N, depth=2, transport=tr)
            t_pipe = time.perf_counter() - t0
            rates["pipelined"] = K * B / t_pipe
            emit(f"sockets_pipelined_n{n}_N{N}_B{B}_K{K}",
                 t_pipe * 1e6 / K,
                 suite="sockets", n=n, num_servers=N, batch=B,
                 mode="socket_pipelined",
                 dets_per_sec=round(rates["pipelined"], 2),
                 overlap_speedup=round(t_seq / t_pipe, 2),
                 all_verified=bool(
                     all(np.asarray(r.verified).all() for r in piped)
                 ))
        emit(
            f"sockets_ratio_n{n}_N{N}_B{B}", 0.0,
            suite="sockets", n=n, num_servers=N, batch=B, mode="ratio",
            socket_vs_inline=round(
                max(rates.values()) / inline_rate, 3
            ),
            overlap_speedup=round(rates["pipelined"] / rates["seq"], 2),
        )


def gateway_overload_suite(n: int = 32, N: int = 2):
    """Production-hardened gateway under overload and chaos (DESIGN.md §10).

    Four measurement legs, all against the per-request loop-rate baseline
    measured in the SAME process:

      * loop      — one warm single-matrix call; its 1/t rate calibrates
                    the offered-load multiples AND the admission rate;
      * overload  — open-loop Poisson arrivals at 2×/8×/16× the loop rate
                    against a gateway with per-tenant admission (rate =
                    loop rate) and a bounded pending queue: admitted
                    requests' sustained dets/sec + p50/p99, every shed
                    request a TYPED rejection (overload/admission split
                    emitted), all admitted verified — the guard's sharp
                    claims;
      * cache     — the same matrix resubmitted after a verified first
                    answer: idempotency hit rate and the O(hash) answer
                    rate vs the loop baseline;
      * breaker   — chaos pinned to one bucket (its sweeps raise) while a
                    clean bucket serves the same workload as a no-fault
                    baseline run: containment_ratio = clean-bucket rate
                    with chaos / without. The breaker fast-fails the
                    poisoned bucket after failure_threshold flushes, so
                    the clean bucket's rate must stay within noise.
    """
    import asyncio

    from repro.configs import (
        AdmissionConfig,
        BreakerConfig,
        SPDCConfig,
        SPDCGatewayConfig,
    )
    from repro.core import outsource_determinant
    from repro.launch.serve_spdc import run_workload
    from repro.serve import AsyncSPDCGateway, SPDCGateway

    requests = 48 if SMOKE else 96
    mults = (8.0,) if SMOKE else (2.0, 8.0, 16.0)
    max_batch = 8
    rng = np.random.default_rng(11)
    spdc = SPDCConfig(num_servers=N)

    single_m = _wellcond(n, seed=n + N)
    t_single_us, res = _t(
        lambda: outsource_determinant(single_m, N), reps=3, warmup=1
    )
    loop_rate = 1e6 / t_single_us
    emit(f"gw_overload_loop_n{n}_N{N}", t_single_us, suite="gateway_overload",
         n=n, num_servers=N, mode="loop", dets_per_sec=round(loop_rate, 2),
         verified=bool(res.verified))

    def lat_ms(results, q):
        return round(float(np.percentile(
            [r.latency_s for r in results], q) * 1e3), 2)

    # -- overload legs: Poisson arrivals at mult × the loop rate ---------
    cfg = SPDCGatewayConfig(
        name="bench-gw-overload", buckets=(n,), max_batch=max_batch,
        max_wait_us=2000.0, max_pending=4 * max_batch, spdc=spdc,
        admission=AdmissionConfig(rate_per_sec=loop_rate,
                                  burst=float(max_batch)),
    )
    SPDCGateway(cfg).warmup()  # shapes shared via the process jit cache
    for mult in mults:
        offered = mult * loop_rate
        mats = [_wellcond(n, seed=4000 + i) for i in range(requests)]
        arrival_s = np.cumsum(rng.exponential(1.0 / offered, requests))

        async def drive():
            async with AsyncSPDCGateway(cfg) as agw:
                out = await run_workload(agw, mats, arrival_s)
                return out, agw.stats.as_dict()

        (results, rejected, wall), stats = asyncio.run(drive())
        served = [r for r in results if r is not None]
        shed = sum(rejected.values())
        emit(f"gw_overload_x{mult:g}_n{n}_N{N}",
             wall * 1e6 / max(len(served), 1),
             suite="gateway_overload", n=n, num_servers=N, mode="overload",
             offered_mult=mult, offered_per_sec=round(offered, 2),
             requests=requests, served=len(served),
             rejected_overload=rejected["overload"],
             rejected_admission=rejected["admission"],
             rejected_breaker=rejected["breaker"],
             all_accounted=bool(len(served) + shed == requests),
             dets_per_sec=round(len(served) / wall, 2),
             p50_ms=lat_ms(served, 50), p99_ms=lat_ms(served, 99),
             all_verified=bool(all(r.verified for r in served)))

    # -- cache leg: identical resubmissions answer in O(hash) ------------
    cache_cfg = SPDCGatewayConfig(
        name="bench-gw-cache", buckets=(n,), max_batch=max_batch,
        max_wait_us=2000.0, spdc=spdc,
    )
    gw = SPDCGateway(cache_cfg)
    m = _wellcond(n, seed=5000)
    first = gw.submit(m)
    gw.drain()
    assert gw.take(first).verified
    reps = requests
    t0 = time.perf_counter()
    rids = [gw.submit(m) for _ in range(reps)]
    wall = time.perf_counter() - t0
    hits = [gw.take(rid) for rid in rids]
    lookups = gw.stats.cache_hits + gw.stats.cache_misses
    hit_rate = gw.stats.cache_hits / lookups
    emit(f"gw_cache_hit_n{n}_N{N}", wall * 1e6 / reps,
         suite="gateway_overload", n=n, num_servers=N, mode="cache",
         requests=reps, hit_rate=round(hit_rate, 4),
         dets_per_sec=round(reps / wall, 2),
         speedup_vs_loop=round((reps / wall) / loop_rate, 2),
         all_verified=bool(all(r.verified for r in hits)))
    gw.close()

    # -- breaker leg: chaos on one bucket, containment on the other ------
    n_small = n // 2

    def run_clean_stream(poison: bool):
        def faults_for(key):
            if poison and key.pad_to == n_small:
                raise RuntimeError("injected chaos: poisoned bucket")
            # callback contract: an explicit None means "no fault plan"
            return None  # noqa: RET501

        bcfg = SPDCGatewayConfig(
            name="bench-gw-breaker", buckets=(n_small, n),
            max_batch=max_batch, max_wait_us=2000.0, spdc=spdc,
            breaker=BreakerConfig(failure_threshold=3),
        )
        bgw = SPDCGateway(bcfg, faults_for=faults_for)
        bgw.warmup()
        clean = [_wellcond(n, seed=6000 + i) for i in range(requests // 2)]
        noisy = [_wellcond(n_small, seed=7000 + i)
                 for i in range(requests // 2)]
        clean_rids, shed = [], 0
        t0 = time.perf_counter()
        for cm, nm in zip(clean, noisy, strict=True):
            # Both legs submit BOTH streams; only the chaos leg's noisy
            # bucket fails (and fast-fails once the breaker trips).
            try:
                bgw.submit(nm)
            except Exception:  # noqa: BLE001 — BreakerOpen after it trips
                shed += 1
            clean_rids.append(bgw.submit(cm))
        bgw.drain()
        wall = time.perf_counter() - t0
        served = [bgw.take(rid) for rid in clean_rids]
        assert all(r is not None for r in served)
        return served, wall, shed, bgw.stats.as_dict()

    base_served, base_wall, _, base_stats = run_clean_stream(poison=False)
    chaos_served, chaos_wall, shed, chaos_stats = run_clean_stream(poison=True)
    base_rate = len(base_served) / base_wall
    chaos_rate = len(chaos_served) / chaos_wall
    emit(f"gw_breaker_containment_n{n}_N{N}", chaos_wall * 1e6 / len(chaos_served),
         suite="gateway_overload", n=n, num_servers=N, mode="breaker",
         requests=requests // 2, poisoned_shed=shed,
         breaker_opens=chaos_stats["breaker_opens"],
         clean_dets_per_sec=round(chaos_rate, 2),
         baseline_dets_per_sec=round(base_rate, 2),
         containment_ratio=round(chaos_rate / base_rate, 3),
         dets_per_sec=round(chaos_rate, 2),
         all_verified=bool(all(r.verified for r in chaos_served)
                           and base_stats["breaker_opens"] == 0))


def linalg_suite(n: int = 256, N: int = 2):
    """Shared-LU op plan + differentiable ops (DESIGN.md §12).

    Three measured legs, one guarded claim each (`--suite linalg`,
    BENCH_8.json):

      * independent — slogdet THEN solve as two standalone outsourcings
        (fresh session each, the pre-§12 cost of wanting both);
      * shared      — the same (slogdet, solve) pair on ONE LinalgSession:
        one factorization + one O(n²) triangular-solve round. The guarded
        claim: shared ≥ 1.5× the independent rate (amortization is the
        subsystem's reason to exist);
      * gradstep    — a full jitted value_and_grad of the GP negative
        log-likelihood through secure_slogdet + secure_solve (forward +
        custom-VJP backward on one factorization per step, session cache
        cleared per rep so every step pays the real pipeline).
    """
    from repro.linalg import (
        LinalgSession, SecureLinalg, secure_slogdet, secure_solve,
    )

    if SMOKE:
        n = 64
    b = _wellcond(n, seed=n)[:, 0]
    m = _wellcond(n, seed=n + 1)

    def independent():
        s1 = LinalgSession(m, N)
        sign, logabs = s1.slogdet()
        s2 = LinalgSession(m, N)
        y = s2.solve(b)
        assert s1.factorizations + s2.factorizations == 2
        return sign, logabs, y

    def shared():
        s = LinalgSession(m, N)
        sign, logabs = s.slogdet()
        y = s.solve(b)
        assert s.factorizations == 1, "the op plan must share one LU"
        return s, sign, logabs, y

    t_ind, _ = _t(independent, reps=3, warmup=1)
    emit(f"linalg_independent_n{n}_N{N}", t_ind, suite="linalg", n=n,
         num_servers=N, mode="independent",
         ops_per_sec=round(2e6 / t_ind, 2))
    t_sh, (s, sign, logabs, y) = _t(shared, reps=3, warmup=1)
    ref = np.linalg.solve(m, b)
    emit(f"linalg_shared_n{n}_N{N}", t_sh, suite="linalg", n=n,
         num_servers=N, mode="shared", ops_per_sec=round(2e6 / t_sh, 2),
         factorizations=s.factorizations,
         all_verified=bool(all(o.verified for o in s.report.ops)),
         solve_err=float(np.linalg.norm(y - ref) / np.linalg.norm(ref)))
    emit(f"linalg_shared_speedup_n{n}_N{N}", 0.0, suite="linalg", n=n,
         num_servers=N, mode="ratio",
         shared_speedup=round(t_ind / t_sh, 2))

    # -- gradient-step throughput (the GP workload shape) ----------------
    import jax as _jax

    rng = np.random.default_rng(0)
    xs = jnp.asarray(np.sort(rng.uniform(-3.0, 3.0, n)))
    ys = jnp.asarray(np.sin(2.0 * np.asarray(xs))
                     + 0.1 * rng.standard_normal(n))
    ctx = SecureLinalg(N)

    def nll(theta):
        d2 = (xs[:, None] - xs[None, :]) ** 2
        cov = jnp.exp(2 * theta[1]) * jnp.exp(
            -0.5 * d2 / jnp.exp(2 * theta[0])
        ) + jnp.exp(2 * theta[2]) * jnp.eye(n)
        _, logdet = secure_slogdet(cov, linalg=ctx)
        alpha = secure_solve(cov, ys, linalg=ctx)
        return 0.5 * (logdet + ys @ alpha + n * jnp.log(2 * jnp.pi))

    vg = _jax.jit(_jax.value_and_grad(nll))
    rvg = _jax.jit(_jax.value_and_grad(
        lambda th: 0.5 * (jnp.linalg.slogdet(
            jnp.exp(2 * th[1]) * jnp.exp(
                -0.5 * (xs[:, None] - xs[None, :]) ** 2
                / jnp.exp(2 * th[0])
            ) + jnp.exp(2 * th[2]) * jnp.eye(n)
        )[1] + ys @ jnp.linalg.solve(
            jnp.exp(2 * th[1]) * jnp.exp(
                -0.5 * (xs[:, None] - xs[None, :]) ** 2
                / jnp.exp(2 * th[0])
            ) + jnp.exp(2 * th[2]) * jnp.eye(n), ys)
            + n * jnp.log(2 * jnp.pi))
    ))
    theta = jnp.asarray([np.log(0.8), 0.0, np.log(0.2)])

    def step():
        ctx.clear()  # every rep pays factorization + VJP rounds
        val, grad = vg(theta)
        _jax.block_until_ready(grad)
        return val, grad

    t_step, (val, grad) = _t(step, reps=3, warmup=1)
    rval, rgrad = rvg(theta)
    gerr = float(jnp.max(jnp.abs(grad - rgrad))
                 / (jnp.max(jnp.abs(rgrad)) + 1e-30))
    sessions = list(ctx._sessions.values())
    emit(f"linalg_gradstep_n{n}_N{N}", t_step, suite="linalg", n=n,
         num_servers=N, mode="gradstep",
         steps_per_sec=round(1e6 / t_step, 3),
         grad_err=f"{gerr:.2e}",
         factorizations=sum(s_.factorizations for s_ in sessions),
         value_matches=bool(np.isclose(float(val), float(rval),
                                       rtol=1e-9)),
         all_verified=bool(all(
             o.verified for s_ in sessions for o in s_.report.ops
         )))


def extension_inverse(n: int = 128):
    """Paper §VII.B future work, implemented: secure outsourced inversion."""
    from repro.core import outsource_inverse

    m = _wellcond(n, seed=9)
    t0 = time.perf_counter()
    res = outsource_inverse(m, 4)
    us = (time.perf_counter() - t0) * 1e6
    err = float(np.max(np.abs(np.asarray(res.inverse) @ m - np.eye(n))))
    emit(f"ext_inverse_n{n}_N4", us, verified=bool(res.verified),
         max_err=f"{err:.2e}")


SUITES = {
    "table1": table1_overhead,
    "table2": table2_characteristics,
    "table3": table3_matrix_support,
    "scaling": fig_scaling,
    "verify": verification_cost,
    "cipher": cipher_fusion,
    "comm": spdc_pipeline_comm,
    "throughput": throughput,
    "faults": faults_suite,
    "gateway": gateway_suite,
    "precision": precision_suite,
    "transports": transports_suite,
    "rateless": rateless_suite,
    "sockets": sockets_suite,
    "gateway_overload": gateway_overload_suite,
    "linalg": linalg_suite,
    "inverse": extension_inverse,
}


def main(argv: list[str] | None = None) -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("suites", nargs="*",
                    help=f"suites to run (default: all; pick from {list(SUITES)})")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink shapes for the CI benchmark smoke job")
    ap.add_argument("--out", type=str, default=None,
                    help="write measured rows as JSON to this path "
                         "(BENCH_1.json is never touched when set)")
    args = ap.parse_args(argv if argv is not None else sys.argv[1:])
    names = args.suites or list(SUITES)
    unknown = [s for s in names if s not in SUITES]
    if unknown:
        raise SystemExit(f"unknown suites {unknown}; pick from {list(SUITES)}")

    global SMOKE
    SMOKE = args.smoke
    print("name,us_per_call,derived")
    for s in names:
        SUITES[s]()
    record = {
        "bench_version": 1,
        "suites": names,
        "smoke": SMOKE,
        "env": {
            "jax": jax.__version__,
            "python": platform.python_version(),
            "platform": platform.platform(),
            "device_count": jax.device_count(),
            "backend": jax.default_backend(),
            "x64": bool(jax.config.jax_enable_x64),
        },
        "rows": RESULTS,
    }
    if args.out is not None:
        out = Path(args.out)
        out.write_text(json.dumps(record, indent=1) + "\n")
        print(f"# wrote {out} ({len(RESULTS)} rows)")
        return
    # the gateway, precision, and transports suites own their own
    # committed baselines (BENCH_2/3/4.json — each with its own CI
    # guard); everything else lives in BENCH_1.json
    own_baseline = {"gateway": "BENCH_2.json", "precision": "BENCH_3.json",
                    "transports": "BENCH_4.json", "rateless": "BENCH_5.json",
                    "sockets": "BENCH_6.json",
                    "gateway_overload": "BENCH_7.json",
                    "linalg": "BENCH_8.json"}
    for suite, fname in own_baseline.items():
        rows = [r for r in RESULTS if r.get("suite") == suite]
        if suite in names and not SMOKE:
            out_s = ROOT / fname
            record_s = dict(record, suites=[suite], rows=rows)
            out_s.write_text(json.dumps(record_s, indent=1) + "\n")
            print(f"# wrote {out_s} ({len(rows)} rows)")
    core_names = [s for s in names if s not in own_baseline]
    if set(core_names) != set(s for s in SUITES if s not in own_baseline) \
            or SMOKE:
        # subset/smoke runs must not clobber the committed full baseline
        print("# partial suite run — BENCH_1.json left untouched "
              "(run with no args to refresh the baseline)")
        return
    out = ROOT / "BENCH_1.json"
    record1 = dict(
        record, suites=core_names,
        rows=[r for r in RESULTS if r.get("suite") not in own_baseline],
    )
    out.write_text(json.dumps(record1, indent=1) + "\n")
    print(f"# wrote {out} ({len(record1['rows'])} rows)")


if __name__ == "__main__":
    main()
