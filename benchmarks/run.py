"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's claim
being checked, e.g. a flop count, speedup, or ratio).

  table1_overhead        — paper Table I: per-stage client cost (flops/biops)
                           measured (wall µs) + counted vs the paper's models
  table2_characteristics — paper Table II: executable protocol properties
  table3_matrix_support  — paper Table III/IV: odd/even sizes + minimal padding
  fig_scaling            — §IV.D: N-server parallel LU scaling (the 2-server
                           baseline of Gao & Yu = N=2 column)
  verification_cost      — §IV.E: Q1 vs Q2 vs Q3 cost and rejection power
  cipher_fusion          — §IV.C: fused CED kernel vs two-pass cipher traffic
  spdc_pipeline_comm     — §IV.D.3: one-way relay bytes vs paper-exact volume
"""
from __future__ import annotations

import time

import jax

jax.config.update("jax_enable_x64", True)

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp
import numpy as np


def _t(fn, *args, reps=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6, out


def _wellcond(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n)) + n * np.eye(n)


def table1_overhead(n: int = 1024):
    """Paper Table I: SeedGen 2n biops, KeyGen ns, Cipher n², Authenticate
    0 + 2n(n+1) (Q3), Decipher 2n."""
    from repro.core import (
        cipher, cipher_flops, decipher, decipher_flops, keygen, lu_unblocked,
        seedgen,
    )
    from repro.core.verify import authenticate, verification_flops

    m = _wellcond(n)
    mj = jnp.asarray(m)

    us, seed = _t(lambda: seedgen(128, m), reps=3)
    print(f"table1_seedgen_n{n},{us:.1f},claimed_biops={2*n}")

    us, key = _t(lambda: keygen(128, seed, n), reps=3)
    print(f"table1_keygen_n{n},{us:.1f},claimed_biops={n}s")

    cfn = jax.jit(lambda x: cipher(x, key, seed)[0])
    us, x = _t(cfn, mj)
    print(f"table1_cipher_n{n},{us:.1f},claimed_flops={cipher_flops(n)}")

    _, meta = cipher(mj, key, seed)
    l, u = jax.jit(lu_unblocked)(x)
    for method in ("q1", "q2", "q3"):
        us, _ = _t(
            lambda: authenticate(l, u, x, num_servers=4, method=method), reps=3
        )
        print(f"table1_auth_{method}_n{n},{us:.1f},"
              f"claimed_flops={verification_flops(n, method)}")

    us, det = _t(lambda: decipher(seed, meta, l, u), reps=3)
    print(f"table1_decipher_n{n},{us:.1f},claimed_flops={decipher_flops(n)}")


def table2_characteristics():
    """Paper Table II, as executable checks: privacy-preserving (cipher
    changes all entries), parallel outsourcing (N-server LU matches), and
    malicious-model detection (tamper rejected)."""
    from repro.core import outsource_determinant

    m = _wellcond(24, seed=1)
    t0 = time.perf_counter()
    res = outsource_determinant(m, 4)
    ok = res.verified and np.isclose(
        res.det.logabs, np.linalg.slogdet(m)[1], rtol=1e-8
    )
    bad = outsource_determinant(
        m, 4, tamper=lambda l, u: (l.at[7, 3].add(0.05), u)
    )
    us = (time.perf_counter() - t0) * 1e6
    print(f"table2_protocol_roundtrip,{us:.1f},correct={ok}")
    print(f"table2_malicious_detected,0.0,rejected={not bad.verified}")


def table3_matrix_support():
    """Paper Tables III/IV: odd sizes minimally padded, even unpadded."""
    from repro.core import outsource_determinant, padding_for_servers

    rows = [(7, 2), (8, 2), (9, 3), (12, 3), (11, 4)]
    for n, servers in rows:
        m = _wellcond(n, seed=n)
        t0 = time.perf_counter()
        res = outsource_determinant(m, servers)
        us = (time.perf_counter() - t0) * 1e6
        ok = res.verified and np.isclose(
            res.det.logabs, np.linalg.slogdet(m)[1], rtol=1e-8
        )
        print(f"table3_n{n}_N{servers},{us:.1f},"
              f"padding={res.padding},min={padding_for_servers(n, servers)},ok={ok}")


def fig_scaling(n: int = 512):
    """N-server LU vs a sequential blocked LU at the SAME block granularity
    (isolates the parallelism benefit from the blocking benefit). The
    critical-path model is the paper's §IV.D scalability claim: the last
    server's work ≈ (2/3)(n/N)³·N + O(n²·n/N) → ~1/N² of total flops on its
    own row after the pipeline fills."""
    from repro.core.lu import lu_blocked, lu_nserver

    x = jnp.asarray(_wellcond(n, seed=2))
    for N in (2, 4, 8):
        seq = jax.jit(lambda a, N=N: lu_blocked(a, n // N))
        base_us, _ = _t(seq, x, reps=2, warmup=1)
        fn = jax.jit(lambda a, N=N: lu_nserver(a, N)[:2])
        us, _ = _t(fn, x, reps=2, warmup=1)
        print(f"fig_scaling_{N}server_n{n},{us:.1f},"
              f"seq_blocked_us={base_us:.1f},speedup={base_us/us:.2f}")


def verification_cost(n: int = 2048):
    """Q1 (vector) vs Q2/Q3 (scalar): cost and single-element sensitivity."""
    from repro.core import lu_unblocked, q1, q2, q3

    x = jnp.asarray(_wellcond(n, seed=3))
    l, u = jax.jit(lu_unblocked)(x)
    r = jnp.asarray(np.random.default_rng(0).standard_normal(n))
    for name, fn in (
        ("q1", jax.jit(lambda l, u, x: jnp.max(jnp.abs(q1(l, u, x, r))))),
        ("q2", jax.jit(lambda l, u, x: jnp.abs(q2(l, u, x, r)))),
        ("q3", jax.jit(q3)),
    ):
        us, resid = _t(fn, l, u, x, reps=3)
        u_bad = u.at[n // 2, n // 2].multiply(1.001)
        detect = float(fn(l, u_bad, x)) > 10 * float(resid) + 1e-12
        print(f"verify_{name}_n{n},{us:.1f},residual={float(resid):.2e},"
              f"detects_0.1pct_tamper={detect}")


def cipher_fusion(n: int = 2048):
    """Fused CED (1 HBM pass) vs unfused scale-then-rotate (2 passes)."""
    from repro.core import keygen, seedgen
    from repro.core.prt import rot90_cw
    from repro.kernels import ops

    m = jnp.asarray(_wellcond(n, seed=4))
    seed = seedgen(128, np.asarray(m))
    key = keygen(128, seed, n)
    v = jnp.asarray(key.v)

    fused = jax.jit(lambda m: ops.ced(m, v, 1, block=128))
    unfused = jax.jit(lambda m: rot90_cw(m / v.reshape(-1, 1), 1))
    us_f, a = _t(fused, m, reps=3)
    us_u, b = _t(unfused, m, reps=3)
    ok = np.allclose(np.asarray(a), np.asarray(b))
    # wall time of the fused kernel is interpret-mode (Python) — the claim
    # being checked is correctness + the 1-vs-2 HBM-pass traffic model
    print(f"cipher_fused_n{n},{us_f:.1f},passes=1,match={ok},note=interpret-mode")
    print(f"cipher_unfused_n{n},{us_u:.1f},passes=2,traffic_ratio=2.0")


def spdc_pipeline_comm(n: int = 4096):
    """One-way relay volume: fixed-shape shard_map hops vs paper-exact."""
    from repro.distrib.spdc_pipeline import pipeline_collective_bytes

    for N in (2, 4, 8, 16):
        info = pipeline_collective_bytes(n, N)
        print(
            f"comm_n{n}_N{N},0.0,"
            f"relay_MB={info['relay_bytes']/1e6:.1f},"
            f"paper_MB={info['paper_exact_bytes']/1e6:.1f},"
            f"overcount={info['overcount_factor']:.2f}"
        )


def extension_inverse(n: int = 128):
    """Paper §VII.B future work, implemented: secure outsourced inversion."""
    from repro.core import outsource_inverse

    m = _wellcond(n, seed=9)
    t0 = time.perf_counter()
    res = outsource_inverse(m, 4)
    us = (time.perf_counter() - t0) * 1e6
    err = float(np.max(np.abs(np.asarray(res.inverse) @ m - np.eye(n))))
    print(f"ext_inverse_n{n}_N4,{us:.1f},verified={res.verified},max_err={err:.2e}")


def main() -> None:
    print("name,us_per_call,derived")
    table1_overhead()
    table2_characteristics()
    table3_matrix_support()
    fig_scaling()
    verification_cost()
    cipher_fusion()
    spdc_pipeline_comm()
    extension_inverse()


if __name__ == "__main__":
    main()
