"""Render the §Roofline table (markdown) from benchmarks/dryrun_results/."""
import json
import sys
from pathlib import Path

DIR = Path(__file__).resolve().parent / "dryrun_results"


def fmt_s(x):
    return f"{x*1e3:9.1f}" if x < 10 else f"{x:8.1f}s"


def main(mesh="single"):
    rows = []
    for f in sorted(DIR.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        rows.append(r)
    print(f"| arch | shape | compute (s) | memory (s) | collective (s) | "
          f"dominant | MODEL_FLOPS | useful | frac | state/dev GiB | peak GiB |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        st = r.get("state_analysis", {}).get("state_per_device_gib", float("nan"))
        peak = r.get("memory_stats", {}).get("peak_est_bytes", 0) / 2**30
        print(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {r['dominant']} "
            f"| {r['model_flops']:.2e} | {r['useful_ratio']:.3f} "
            f"| {r['roofline_fraction']:.4f} | {st:.2f} | {peak:.1f} |"
        )


if __name__ == "__main__":
    main(*(sys.argv[1:] or ["single"]))
