"""Untrusted-server fault models + tamper localization + verification power.

Covers the fault-injection surface (core.faults through core.lu.lu_nserver
and the shard_map pipeline), the blocked-Q1 per-server attribution
(core.verify.localize / Verdict), and MEASURED false-accept /
false-reject rates of Q2 and Q3 under the three tamper models — per server
and per matrix within a batch.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    ServerFault, apply_faults, authenticate, localize, lu_nserver,
    normalize_plan, per_server_residuals, resolve_delays,
)

N = 4
B_N = 16  # matrix size for most cases (b = 4 per server)


def _wellcond(n, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    if batch is None:
        return jnp.asarray(rng.standard_normal((n, n)) + n * np.eye(n))
    return jnp.asarray(
        rng.standard_normal((batch, n, n)) + n * np.eye(n)
    )


@pytest.fixture(scope="module")
def honest_lu():
    a = _wellcond(B_N, seed=1)
    l, u, _ = lu_nserver(a, N)
    return a, l, u


# ------------------------------------------------------------- fault plumbing
def test_fault_plan_normalization_and_validation():
    f = ServerFault(server=1)
    assert normalize_plan(None) == ()
    assert normalize_plan(f) == (f,)
    assert normalize_plan([f, f]) == (f, f)
    with pytest.raises(ValueError, match="unknown fault kind"):
        ServerFault(server=0, kind="gremlin")
    with pytest.raises(ValueError, match="unknown tamper mode"):
        ServerFault(server=0, mode="subtle")
    with pytest.raises(ValueError, match="in_band"):
        ServerFault(server=0, kind="dropout", in_band=True)
    with pytest.raises(TypeError):
        normalize_plan(["not a fault"])


def test_resolve_delays_deadline_policy():
    late = ServerFault(server=2, kind="delay", delay_rounds=5)
    tam = ServerFault(server=1)
    # no deadline: the client waits; the delay disappears from the plan
    assert resolve_delays((late, tam), None) == (tam,)
    # past deadline: treated as a dropout of the same server
    eff = resolve_delays((late, tam), 3)
    assert eff[0].kind == "dropout" and eff[0].server == 2
    assert eff[1] is tam
    # within deadline: tolerated
    assert resolve_delays((late,), 8) == ()


@pytest.mark.parametrize("mode", ["single", "sign_flip", "block"])
@pytest.mark.parametrize("target", ["l", "u"])
def test_report_faults_touch_only_owner_strip(honest_lu, mode, target):
    a, l, u = honest_lu
    b = B_N // N
    for s in range(N):
        f = ServerFault(server=s, mode=mode, target=target)
        lf, uf = apply_faults(l, u, (f,), num_servers=N)
        changed, same = (lf, uf) if target == "l" else (uf, lf)
        ref = l if target == "l" else u
        other = u if target == "l" else l
        assert not np.allclose(
            np.asarray(changed[s * b : (s + 1) * b]),
            np.asarray(ref[s * b : (s + 1) * b]),
        )
        # rows outside the faulty server's strip are untouched
        mask = np.ones(B_N, dtype=bool)
        mask[s * b : (s + 1) * b] = False
        np.testing.assert_array_equal(
            np.asarray(changed[mask]), np.asarray(ref[mask])
        )
        np.testing.assert_array_equal(np.asarray(same), np.asarray(other))


def test_dropout_zeroes_both_strips(honest_lu):
    a, l, u = honest_lu
    b = B_N // N
    lf, uf = apply_faults(
        l, u, (ServerFault(server=2, kind="dropout"),), num_servers=N
    )
    assert np.all(np.asarray(lf[2 * b : 3 * b]) == 0)
    assert np.all(np.asarray(uf[2 * b : 3 * b]) == 0)


def test_in_band_fault_poisons_downstream_only():
    a = _wellcond(B_N, seed=2)
    l, u, _ = lu_nserver(a, N)
    b = B_N // N
    li, ui, _ = lu_nserver(
        a, N, faults=(ServerFault(server=1, in_band=True, target="u"),)
    )
    # upstream of the faulty server: bitwise clean
    np.testing.assert_array_equal(np.asarray(li[:b]), np.asarray(l[:b]))
    np.testing.assert_array_equal(np.asarray(ui[:b]), np.asarray(u[:b]))
    # the faulty row and everything downstream is contaminated
    assert not np.allclose(np.asarray(ui[b : 2 * b]), np.asarray(u[b : 2 * b]))
    assert not np.allclose(np.asarray(li[2 * b :]), np.asarray(l[2 * b :]))


def test_batch_targeted_fault_hits_only_named_matrices():
    ab = _wellcond(B_N, seed=3, batch=4)
    lh, uh, _ = lu_nserver(ab, N)
    lf, uf, _ = lu_nserver(
        ab, N, faults=(ServerFault(server=2, kind="dropout", matrices=(1, 3)),)
    )
    b = B_N // N
    for i in (1, 3):
        assert np.all(np.asarray(uf[i, 2 * b : 3 * b]) == 0)
    for i in (0, 2):
        np.testing.assert_array_equal(np.asarray(uf[i]), np.asarray(uh[i]))


@pytest.mark.parametrize("program", ["baseline", "exact", "stream"])
def test_shardmap_injection_matches_simulation(program):
    from repro.distrib.spdc_pipeline import lu_nserver_shardmap

    a = _wellcond(B_N, seed=4)
    f = ServerFault(server=2, mode="sign_flip", target="u")
    lf, uf = lu_nserver_shardmap(a, N, program=program, faults=(f,))
    lr, ur, _ = lu_nserver(a, N, faults=(f,))
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lr), atol=1e-9)
    np.testing.assert_allclose(np.asarray(uf), np.asarray(ur), atol=1e-9)


def test_shardmap_rejects_in_band_and_unresolved_delay():
    from repro.distrib.spdc_pipeline import lu_nserver_shardmap

    a = _wellcond(B_N, seed=5)
    with pytest.raises(ValueError, match="in_band"):
        lu_nserver_shardmap(
            a, N, faults=(ServerFault(server=0, in_band=True),)
        )
    with pytest.raises(ValueError, match="delay"):
        lu_nserver_shardmap(
            a, N, faults=(ServerFault(server=0, kind="delay", delay_rounds=1),)
        )


# ------------------------------------------------------------- localization
@pytest.mark.parametrize("kind,mode,target", [
    ("tamper", "single", "u"),
    ("tamper", "single", "l"),
    ("tamper", "sign_flip", "u"),
    ("tamper", "block", "lu"),
    ("dropout", "single", "u"),
])
def test_localize_names_the_faulty_server(honest_lu, kind, mode, target):
    a, l, u = honest_lu
    for s in range(N):
        f = ServerFault(server=s, kind=kind, mode=mode, target=target)
        lf, uf = apply_faults(l, u, (f,), num_servers=N)
        sres, sok, culprit = localize(lf, uf, a, num_servers=N)
        assert culprit == s, (kind, mode, target, s, sres)
        # every strip ABOVE the culprit is verified-clean — the invariant
        # recovery relies on to recompute from upstream rows
        assert sok[:s].all()


def test_localize_clean_run_blames_nobody(honest_lu):
    a, l, u = honest_lu
    sres, sok, culprit = localize(l, u, a, num_servers=N)
    assert culprit == -1 and sok.all()


def test_q3_per_server_view_attributes_to_diagonal_owner(honest_lu):
    """Documented contrast: an off-diagonal U tamper in server 1's strip at
    a column owned by server 3 shows up in the q3 view at server 3 (the
    diagonal owner), while the q1 localization names server 1 (the row
    owner). This is exactly why localize() uses the q1 form."""
    a, l, u = honest_lu
    b = B_N // N
    # tamper server 1's U strip in the last block column (owner: server 3)
    col = 3 * b + 1
    uf = u.at[b, col].add(0.5)
    q3_view = per_server_residuals(l, uf, a, num_servers=N, method="q3")
    q1_view = per_server_residuals(l, uf, a, num_servers=N, method="q1")
    assert np.argmax(q3_view) == 3
    eps = 1e-9
    assert (q1_view > eps).nonzero()[0][0] == 1


def test_batched_localization_per_matrix(honest_lu):
    ab = _wellcond(B_N, seed=6, batch=5)
    l, u, _ = lu_nserver(ab, N)
    plan = (
        ServerFault(server=0, matrices=(1,)),
        ServerFault(server=3, kind="dropout", matrices=(4,)),
    )
    lf, uf = apply_faults(l, u, plan, num_servers=N)
    v = authenticate(lf, uf, ab, num_servers=N)
    assert list(v.culprit) == [-1, 0, -1, -1, 3]
    assert list(v.ok) == [True, False, True, True, False]


# ------------------------------------------------------- verdict structure
def test_verdict_fields_and_tuple_shim_removed(honest_lu):
    a, l, u = honest_lu
    v = authenticate(l, u, a, num_servers=N, method="q2", attribute=True)
    assert v.method == "q2" and v.num_servers == N
    assert v.eps > 0 and v.server_residual.shape == (N,)
    assert v.all_ok
    # the legacy (verified, residual) tuple emulation completed its
    # deprecation cycle: a Verdict is no longer iterable or indexable
    with pytest.raises(TypeError):
        ok, resid = v
    with pytest.raises(TypeError):
        v[0]


def test_verdict_attribute_flag_skips_localization(honest_lu):
    a, l, u = honest_lu
    v = authenticate(l, u, a, num_servers=N, attribute=False)
    assert v.server_residual is None and v.culprit == -1
    # default "auto": no attribution pass on accepting verdicts (its only
    # consumer is the recovery scheduler), full attribution on rejects
    v_auto = authenticate(l, u, a, num_servers=N)
    assert v_auto.ok and v_auto.server_residual is None


# ------------------------------------------- verification power (measured)
TAMPER_MODES = ["single", "sign_flip", "block"]


@pytest.mark.slow
@pytest.mark.parametrize("method", ["q2", "q3"])
def test_false_reject_rate_is_zero_on_honest_runs(method):
    """FR: honest factorizations must never be rejected (20 trials/server
    count — ε(N) absorbs the no-pivot drift)."""
    rejects = 0
    trials = 20
    for t in range(trials):
        a = _wellcond(B_N, seed=100 + t)
        l, u, _ = lu_nserver(a, N)
        v = authenticate(l, u, a, num_servers=N, method=method)
        rejects += not v.ok
    assert rejects == 0


@pytest.mark.slow
@pytest.mark.parametrize("method", ["q2", "q3"])
@pytest.mark.parametrize("mode", TAMPER_MODES)
def test_false_accept_rate_per_server(method, mode):
    """FA: tampered results must be rejected — measured over every server ×
    10 trials with fresh matrices and fresh tamper positions. (Slow tier:
    the per-matrix batch variant below keeps FA coverage in tier-1.)"""
    accepts = 0
    trials = 10
    for s in range(N):
        for t in range(trials):
            a = _wellcond(B_N, seed=200 + t)
            l, u, _ = lu_nserver(a, N)
            f = ServerFault(server=s, mode=mode, target="u", seed=t)
            lf, uf = apply_faults(l, u, (f,), num_servers=N)
            v = authenticate(lf, uf, a, num_servers=N, method=method)
            accepts += bool(np.all(v.ok))
    assert accepts == 0, f"{accepts}/{N * trials} tampered results accepted"


@pytest.mark.parametrize("method", ["q2", "q3"])
@pytest.mark.parametrize("mode", TAMPER_MODES)
def test_false_accept_rate_per_matrix_in_batch(method, mode):
    """Batched FA: one tampered matrix inside a stack must flip ONLY its
    own verdict — measured per matrix over 8 trials."""
    trials = 8
    B = 4
    for t in range(trials):
        ab = _wellcond(B_N, seed=300 + t, batch=B)
        l, u, _ = lu_nserver(ab, N)
        bad = t % B
        f = ServerFault(server=t % N, mode=mode, target="u",
                        matrices=(bad,), seed=t)
        lf, uf = apply_faults(l, u, (f,), num_servers=N)
        v = authenticate(lf, uf, ab, num_servers=N, method=method)
        want = np.ones(B, dtype=bool)
        want[bad] = False
        assert (v.ok == want).all(), (t, v.ok, want)


def test_dropout_never_accepted():
    for method in ("q1", "q2", "q3"):
        for s in range(N):
            a = _wellcond(B_N, seed=400 + s)
            l, u, _ = lu_nserver(a, N)
            lf, uf = apply_faults(
                l, u, (ServerFault(server=s, kind="dropout"),), num_servers=N
            )
            v = authenticate(lf, uf, a, num_servers=N, method=method)
            assert not v.ok
