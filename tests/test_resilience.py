"""Unit tier for the gateway resilience + observability primitives
(DESIGN.md §10): token bucket and admission controller typed rejections,
the per-bucket circuit breaker state machine exercised exhaustively on an
explicit clock, the bounded LRU result cache, the deterministic streaming
quantile sketch, and the schema-versioned metrics snapshot / text
renderings. Pure bookkeeping — no jax, no gateway, no wall time.
"""
import pytest

from repro.configs import AdmissionConfig, BreakerConfig
from repro.serve import (
    AdmissionController,
    AdmissionRejected,
    CircuitBreaker,
    FlushEvent,
    GatewayMetrics,
    MetricsSnapshot,
    QuantileSketch,
    RejectEvent,
    ResultCache,
    TokenBucket,
    VerdictEvent,
    render_healthz,
    render_prometheus,
)

# ------------------------------------------------------------ token bucket


def test_token_bucket_starts_full_and_refills():
    tb = TokenBucket(rate=2.0, burst=4.0, now=0.0)
    assert all(tb.try_take(0.0) for _ in range(4))  # burst drains
    assert not tb.try_take(0.0)
    assert not tb.try_take(0.4)  # 0.8 tokens banked, need 1
    assert tb.try_take(0.5)  # 1.0 banked at rate 2/s
    assert tb.try_take(10.0)  # long idle refills, capped at burst
    assert sum(tb.try_take(10.0) for _ in range(10)) == 3  # burst-1 left


def test_token_bucket_ignores_clock_regression():
    tb = TokenBucket(rate=1.0, burst=1.0, now=5.0)
    assert tb.try_take(5.0)
    # a now() earlier than the last refill must not mint (or burn) tokens
    assert not tb.try_take(4.0)
    assert tb.try_take(6.0)


def test_token_bucket_validates():
    with pytest.raises(ValueError):
        TokenBucket(rate=0.0, burst=1.0)
    with pytest.raises(ValueError):
        TokenBucket(rate=1.0, burst=0.0)


# ------------------------------------------------------- admission control


def test_admission_rate_limit_is_per_tenant_and_typed():
    adm = AdmissionController(AdmissionConfig(rate_per_sec=1.0, burst=2.0))
    adm.charge("a", 0.0)
    adm.charge("a", 0.0)
    with pytest.raises(AdmissionRejected) as ei:
        adm.charge("a", 0.0)
    assert ei.value.tenant == "a" and ei.value.reason == "rate"
    # tenant b has its own bucket — a's exhaustion never touches it
    adm.charge("b", 0.0)
    # and a refills with time
    adm.charge("a", 1.5)


def test_admission_quota_tracks_slots_and_unwinds():
    adm = AdmissionController(AdmissionConfig(max_pending_per_tenant=2))
    adm.acquire_slot("a")
    adm.acquire_slot("a")
    with pytest.raises(AdmissionRejected) as ei:
        adm.acquire_slot("a")
    assert ei.value.reason == "quota"
    adm.acquire_slot("b")  # other tenants unaffected
    adm.release_slot("a")
    adm.acquire_slot("a")  # freed slot is reusable
    assert adm.pending_of("a") == 2
    assert adm.total_pending == 3
    for _ in range(2):
        adm.release_slot("a")
    adm.release_slot("b")
    assert adm.total_pending == 0
    assert adm.pending_by_tenant() == {}


def test_admission_disabled_is_a_noop():
    adm = AdmissionController(None)
    assert not adm.enabled
    for _ in range(1000):
        adm.charge("t", 0.0)
        adm.acquire_slot("t")
    assert adm.pending_of("t") == 1000  # accounting still works


def test_admission_config_validates():
    with pytest.raises(ValueError):
        AdmissionConfig(rate_per_sec=0.0)
    with pytest.raises(ValueError):
        AdmissionConfig(rate_per_sec=1.0, burst=0.0)
    with pytest.raises(ValueError):
        AdmissionConfig(max_pending_per_tenant=0)


# ------------------------------------------- breaker state machine (§10.2)


def _breaker(**kw):
    kw.setdefault("failure_threshold", 3)
    kw.setdefault("cooldown_base_s", 1.0)
    kw.setdefault("probe_jitter", 0.0)  # exact probe times for assertions
    kw.setdefault("max_unverified_rate", 0.5)
    kw.setdefault("min_samples", 4)
    return CircuitBreaker(BreakerConfig(**kw), seed=7)


def test_breaker_opens_at_consecutive_failure_threshold():
    br = _breaker()
    assert br.record(0.0, failed=True) == "closed"
    assert br.record(1.0, failed=True) == "closed"
    assert br.allow(1.5) == "ok"  # still closed: admits normally
    assert br.record(2.0, failed=True) == "open"  # third consecutive trips
    assert br.allow(2.1) == "open"


def test_breaker_success_resets_consecutive_count():
    br = _breaker()
    br.record(0.0, failed=True)
    br.record(1.0, failed=True)
    br.record(2.0, failed=False)  # streak broken
    br.record(3.0, failed=True)
    br.record(4.0, failed=True)
    assert br.state == "closed"  # 2 < threshold again
    assert br.record(5.0, failed=True) == "open"


def test_breaker_half_open_admits_exactly_one_probe():
    br = _breaker()
    for t in (0.0, 1.0, 2.0):
        br.record(t, failed=True)
    assert br.state == "open"
    assert br.allow(2.5) == "open"  # cooldown (1s) not elapsed
    assert br.allow(3.0) == "probe"  # exactly at next_probe_at
    assert br.state == "half_open"
    # a second submission while the probe is in flight is NOT admitted
    assert br.allow(3.1) == "open"
    assert br.allow(100.0) == "open"


def test_breaker_probe_success_closes_and_failure_reopens_with_backoff():
    br = _breaker()
    for t in (0.0, 1.0, 2.0):
        br.record(t, failed=True)
    assert br.allow(3.0) == "probe"
    assert br.record(3.5, failed=True) == "open"  # probe failed: re-trip
    # backoff doubled: second open waits base·2^1 = 2s
    assert br.allow(4.5) == "open"
    assert br.allow(5.5) == "probe"
    assert br.record(5.6, failed=False) == "closed"  # probe verified
    assert br.allow(5.7) == "ok"
    # `opens` survives the close: the NEXT trip pays the longer cooldown
    for t in (6.0, 6.1, 6.2):
        br.record(t, failed=True)
    assert br.state == "open"
    assert br.allow(9.0) == "open"  # base·2^2 = 4s now
    assert br.allow(10.2) == "probe"


def test_breaker_revert_probe_restores_reprobeable_open():
    """Regression: a granted probe whose request is shed before enqueue
    (quota / capacity) must be revocable — revert_probe() returns to
    "open" with next_probe_at untouched, so the NEXT submission re-probes
    instead of the bucket fast-failing forever on a probe that no flush
    will ever record()."""
    br = _breaker()
    for t in (0.0, 1.0, 2.0):
        br.record(t, failed=True)
    assert br.allow(3.0) == "probe"
    br.revert_probe()  # the probe's request never made it into the queue
    assert br.state == "open" and not br.probe_pending
    assert br.retry_after(3.0) == 0.0  # still due, not pushed out
    assert br.allow(3.0) == "probe"  # grant is re-issued immediately
    assert br.record(3.5, failed=False) == "closed"
    br.revert_probe()  # no-op outside a pending probe
    assert br.state == "closed"


def test_breaker_cooldown_caps_at_max():
    br = _breaker(cooldown_base_s=1.0, cooldown_max_s=4.0)
    for round_ in range(6):  # trip, fail the probe, repeat
        if br.state == "closed":
            t = float(round_ * 100)
            for dt in (0.0, 0.1, 0.2):
                br.record(t + dt, failed=True)
        assert br.state == "open"
        assert br.next_probe_at - (br.next_probe_at - br._cooldown()) <= 4.0 + 1e-9
        assert br.allow(br.next_probe_at) == "probe"
        br.record(br.next_probe_at + 0.01, failed=True)


def test_breaker_unverified_rate_ewma_trips_after_min_samples():
    br = _breaker(failure_threshold=100)  # isolate the verification signal
    # sweeps complete but most results fail verification
    for i in range(3):
        assert br.record(float(i), failed=False, unverified_rate=1.0) == "closed"
    # 4th sample crosses min_samples with EWMA ~1.0 > 0.5
    assert br.record(3.0, failed=False, unverified_rate=1.0) == "open"


def test_breaker_healthy_stream_never_trips():
    br = _breaker()
    for i in range(200):
        assert br.record(float(i), failed=False, unverified_rate=0.0) == "closed"
    assert br.opens == 0


def test_breaker_jitter_is_deterministic_and_bounded():
    cfg = BreakerConfig(probe_jitter=0.2, cooldown_base_s=1.0)
    a1 = CircuitBreaker(cfg, seed=1)
    a2 = CircuitBreaker(cfg, seed=1)
    b = CircuitBreaker(cfg, seed=2)
    for br in (a1, a2, b):
        for t in (0.0, 0.1, 0.2):
            br.record(t, failed=True)
    assert a1.next_probe_at == a2.next_probe_at  # same seed: same schedule
    assert a1.next_probe_at != b.next_probe_at  # probes de-synchronized
    for br in (a1, b):
        cd = br.next_probe_at - 0.2
        assert 0.8 - 1e-9 <= cd <= 1.2 + 1e-9  # within ±jitter of base


def test_breaker_disabled_never_blocks():
    br = CircuitBreaker(BreakerConfig(enabled=False), seed=0)
    for t in range(50):
        br.record(float(t), failed=True)
        assert br.allow(float(t)) == "ok"


def test_breaker_config_validates():
    with pytest.raises(ValueError):
        BreakerConfig(failure_threshold=0)
    with pytest.raises(ValueError):
        BreakerConfig(max_unverified_rate=1.5)
    with pytest.raises(ValueError):
        BreakerConfig(on_open="explode")


# ------------------------------------------------------------ result cache


def test_result_cache_lru_bound_and_evictions():
    c = ResultCache(max_entries=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1  # touch: a becomes most-recent
    c.put("c", 3)  # evicts b (LRU), not a
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    assert len(c) == 2 and c.evictions == 1
    with pytest.raises(ValueError):
        ResultCache(max_entries=0)


# -------------------------------------------------------- quantile sketch


def test_sketch_exact_until_capacity():
    s = QuantileSketch(capacity=64)
    for v in range(50):
        s.observe(float(v))
    assert s.quantile(0.0) == 0.0 and s.quantile(1.0) == 49.0
    assert s.quantile(0.5) == pytest.approx(24.0, abs=1.0)
    assert s.mean == pytest.approx(24.5)


def test_sketch_bounded_memory_and_graceful_accuracy():
    s = QuantileSketch(capacity=64)
    n = 100_000
    for v in range(n):
        s.observe(float(v))
    assert len(s._items) <= 64  # memory bound holds under a long stream
    assert s.count == n
    assert s.min == 0.0 and s.max == float(n - 1)  # extremes exact
    # estimates stay within a few compressed-resolution steps
    assert s.quantile(0.5) == pytest.approx(n / 2, rel=0.15)
    assert s.quantile(0.99) == pytest.approx(0.99 * n, rel=0.15)


def test_sketch_deterministic():
    a, b = QuantileSketch(capacity=32), QuantileSketch(capacity=32)
    vals = [(i * 37) % 1000 for i in range(5000)]
    for v in vals:
        a.observe(v)
        b.observe(v)
    for q in (0.1, 0.5, 0.9, 0.99):
        assert a.quantile(q) == b.quantile(q)


def test_sketch_empty_and_validation():
    s = QuantileSketch()
    assert s.quantile(0.5) is None and s.mean is None
    assert s.summary()["count"] == 0
    with pytest.raises(ValueError):
        QuantileSketch(capacity=4)


# ------------------------------------------- metrics registry + snapshot


def _populated_metrics():
    m = GatewayMetrics()
    m.record_submit("a")
    m.record_submit("a")
    m.record_submit("b")
    m.record_flush(FlushEvent(
        bucket="n8.N2.float64.ewd-q3#0000", reason="full", batch=2,
        padded_batch=2, queue_waits_s=(0.001, 0.002), sweep_s=0.05,
    ))
    m.record_verdict(VerdictEvent(
        rid=0, bucket="n8.N2.float64.ewd-q3#0000", tenant="a",
        verified=True, latency_s=0.051, flush_reason="full",
    ))
    m.record_verdict(VerdictEvent(
        rid=1, bucket="n8.N2.float64.ewd-q3#0000", tenant="a",
        verified=False, latency_s=0.052, flush_reason="full",
    ))
    m.record_reject(RejectEvent(reason="rate", tenant="b"))
    return m


#: the SCHEMA_VERSION=1 compatibility contract: dashboards key on these.
#: Widening the snapshot requires adding the key HERE and bumping the
#: version — that is the point of the test.
_V1_TOP_KEYS = {
    "schema_version", "counters", "pending", "request_latency_s",
    "buckets", "tenants", "cache",
}
_V1_COUNTER_KEYS = {
    "submitted", "admitted", "served", "failed", "direct",
    "rejected_overload", "rejected_rate", "rejected_quota",
    "rejected_breaker", "cache_hits", "cache_misses", "coalesced",
    "breaker_opens", "breaker_probes", "breaker_closes",
}
_V1_BUCKET_KEYS = {
    "depth", "breaker", "flushes", "requests", "verified", "unverified",
    "failed", "recovered_flushes", "sweep_errors", "flush_size",
    "queue_wait_s", "sweep_s",
}
_V1_TENANT_KEYS = {
    "pending", "submitted", "served", "rejected_rate", "rejected_quota",
    "rejected_overload", "rejected_breaker",
}
_V1_CACHE_KEYS = {"entries", "hits", "misses", "coalesced", "hit_rate",
                  "evictions"}
_V1_SUMMARY_KEYS = {"count", "mean", "min", "max", "p50", "p90", "p99"}


def test_snapshot_schema_v1_is_stable():
    assert MetricsSnapshot.SCHEMA_VERSION == 1
    d = _populated_metrics().snapshot().as_dict()
    assert set(d) == _V1_TOP_KEYS
    assert d["schema_version"] == 1
    assert set(d["counters"]) == _V1_COUNTER_KEYS
    assert set(d["request_latency_s"]) == _V1_SUMMARY_KEYS
    for b in d["buckets"].values():
        assert set(b) == _V1_BUCKET_KEYS
        for series in ("flush_size", "queue_wait_s", "sweep_s"):
            assert set(b[series]) == _V1_SUMMARY_KEYS
    for t in d["tenants"].values():
        assert set(t) == _V1_TENANT_KEYS
    assert set(d["cache"]) == _V1_CACHE_KEYS
    import json

    json.dumps(d)  # the whole snapshot must be JSON-serializable


def test_snapshot_folds_live_gauges():
    m = _populated_metrics()
    snap = m.snapshot(gauges={
        "pending": 3,
        "buckets": {
            "n8.N2.float64.ewd-q3#0000": {"depth": 3, "breaker": "open"},
            "n16.N2.float64.ewd-q3#0000": {"breaker": "half_open"},
        },
        "tenant_pending": {"a": 3},
        "cache_entries": 5,
        "cache_evictions": 1,
    })
    assert snap.pending == 3
    b = snap.buckets["n8.N2.float64.ewd-q3#0000"]
    assert b["depth"] == 3 and b["breaker"] == "open"
    # a bucket with a live gauge but no recorded flushes still surfaces
    assert snap.buckets["n16.N2.float64.ewd-q3#0000"]["breaker"] == "half_open"
    assert sorted(snap.open_breakers) == [
        "n16.N2.float64.ewd-q3#0000", "n8.N2.float64.ewd-q3#0000"]
    assert snap.tenants["a"]["pending"] == 3
    assert snap.cache["entries"] == 5 and snap.cache["evictions"] == 1


def test_tenant_isolation_in_metrics():
    snap = _populated_metrics().snapshot()
    assert snap.tenants["a"]["submitted"] == 2
    assert snap.tenants["b"]["submitted"] == 1
    assert snap.tenants["b"]["rejected_rate"] == 1
    assert snap.tenants["a"]["rejected_rate"] == 0


def test_tenant_served_excludes_failures():
    """Per-tenant served mirrors the global served/failed split: a
    request that completed WITH an error is failed, not served."""
    m = GatewayMetrics()
    m.record_verdict(VerdictEvent(
        rid=0, bucket="b", tenant="a", verified=True, latency_s=0.01,
        flush_reason="full"))
    m.record_verdict(VerdictEvent(
        rid=1, bucket="b", tenant="a", verified=False, latency_s=0.01,
        flush_reason="full", error="sweep raised"))
    snap = m.snapshot()
    assert snap.tenants["a"]["served"] == 1
    assert snap.counters["served"] == 1 and snap.counters["failed"] == 1


def test_render_prometheus_grammar():
    snap = _populated_metrics().snapshot(gauges={
        "buckets": {"n8.N2.float64.ewd-q3#0000": {"breaker": "open"}},
    })
    text = render_prometheus(snap)
    assert "spdc_gateway_submitted_total 3" in text
    assert 'spdc_gateway_bucket_verified{bucket="n8.N2.float64.ewd-q3#0000"} 1' in text
    assert ('spdc_gateway_breaker_state{bucket="n8.N2.float64.ewd-q3#0000",'
            'state="open"} 1') in text
    assert ('spdc_gateway_breaker_state{bucket="n8.N2.float64.ewd-q3#0000",'
            'state="closed"} 0') in text
    # every line is `name value` or `name{labels} value`
    for line in text.strip().splitlines():
        if line.startswith("#"):
            continue
        name, _, value = line.rpartition(" ")
        assert name and (value == "NaN" or float(value) == float(value))


def test_render_healthz_verdicts():
    m = _populated_metrics()
    assert render_healthz(m.snapshot())["status"] == "ok"
    degraded = m.snapshot(gauges={"buckets": {"x": {"breaker": "open"}}})
    assert render_healthz(degraded)["status"] == "degraded"
    over = m.snapshot(gauges={"pending": 64})
    assert render_healthz(over, max_pending=64)["status"] == "overloaded"
    body = render_healthz(m.snapshot())
    assert body["rejected"] == 1  # the one rate reject
