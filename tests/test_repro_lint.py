"""Tests for the repro-lint static-analysis suite (DESIGN.md §11).

Fixture corpus: for each pass, a must-flag and a must-pass source, the
two historical bug classes reproduced verbatim as must-flag patterns
(the unlocked ``_dummies`` LRU read, the under-lock hook firing), the
suppression grammar, and annotation-deletion checks against the REAL
tree sources — deleting any guard annotation or whitelist entry must
turn the lint red.  Finally the integration gate: the live tree lints
clean, which is what CI enforces.
"""
import re
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.repro_lint import CODES, lint_paths, lint_sources  # noqa: E402
from tools.repro_lint.vocab import REQUIRED_GUARDS, UNSUPPRESSIBLE  # noqa: E402

SERVE = "src/repro/serve/fixture.py"
CORE = "src/repro/core/fixture.py"


def codes(findings):
    return [f.code for f in findings]


def lint_one(src, path=SERVE, passes=None):
    return lint_sources({path: src}, passes=passes)


# ---------------------------------------------------------------- suppression
def test_syntax_error_is_spdc000():
    assert codes(lint_one("def f(:\n")) == ["SPDC000"]


def test_suppression_without_justification_rejected():
    src = "import time\nwith lock:\n    pass\n_x = 1  # repro-lint: ignore[SPDC301]\n"
    fs = lint_one(src)
    assert "SPDC001" in codes(fs)


def test_suppression_unknown_code_rejected():
    fs = lint_one("_x = 1  # repro-lint: ignore[SPDC999] -- misremembered code\n")
    assert "SPDC002" in codes(fs)


def test_suppressing_the_unsuppressible_rejected():
    for code in sorted(UNSUPPRESSIBLE):
        fs = lint_one(f"_x = 1  # repro-lint: ignore[{code}] -- nice try\n")
        assert "SPDC002" in codes(fs), code


def test_stale_suppression_is_spdc003():
    fs = lint_one("_x = 1  # repro-lint: ignore[SPDC301] -- nothing here flags\n")
    assert codes(fs) == ["SPDC003"]


def test_justified_suppression_silences_finding():
    src = (
        "import time\n"
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    t = time.time()  # repro-lint: ignore[SPDC301] -- fixture\n"
        "    return x * t\n"
    )
    assert codes(lint_one(src, path=CORE, passes=["jit"])) == []
    # and the same source WITHOUT the suppression flags
    assert "SPDC301" in codes(lint_one(src.replace(
        "  # repro-lint: ignore[SPDC301] -- fixture", ""), path=CORE, passes=["jit"]))


def test_standalone_suppression_targets_next_statement():
    src = (
        "import time\n"
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    # repro-lint: ignore[SPDC301] -- fixture, comment-above form\n"
        "    t = time.time()\n"
        "    return x * t\n"
    )
    assert codes(lint_one(src, path=CORE, passes=["jit"])) == []


# --------------------------------------------------------- pass 1: taint
def test_taint_secret_to_log_flags():
    src = (
        "import logging\n"
        "log = logging.getLogger(__name__)\n"
        "def f(m):\n"
        "    log.info('got %s', m)\n"
    )
    assert "SPDC102" in codes(lint_one(src, path=CORE))


def test_taint_metadata_attrs_are_clean():
    src = (
        "import logging\n"
        "log = logging.getLogger(__name__)\n"
        "def f(m):\n"
        "    log.info('got %s x %s', m.shape, m.dtype)\n"
        "    if len(m) > 2:\n"
        "        raise ValueError(f'bad rank {m.ndim}')\n"
    )
    assert codes(lint_one(src, path=CORE)) == []


def test_taint_secret_in_exception_flags():
    src = "def f(seed):\n    raise ValueError(f'bad seed {seed}')\n"
    assert "SPDC103" in codes(lint_one(src, path=CORE))


def test_taint_boundary_ctor_flags():
    src = (
        "def f(m, x_row):\n"
        "    return ShardTask(x_row=m)\n"
    )
    assert "SPDC101" in codes(lint_one(src, path=CORE, passes=["taint"]))
    # the CIPHERED row crossing is the protocol working as designed
    clean = "def f(m, x_row):\n    return ShardTask(x_row=x_row)\n"
    assert codes(lint_one(clean, path=CORE, passes=["taint"])) == []


def test_taint_interprocedural_sink_through_helper():
    """A secret reaching a sink through one level of local helper."""
    src = (
        "def _send(transport, x):\n"
        "    transport.submit(x)\n"
        "def f(transport, m):\n"
        "    _send(transport, m)\n"
    )
    fs = lint_one(src, path=CORE)
    assert "SPDC101" in codes(fs)
    # only the CALL of the helper with the secret flags, not clean calls
    src_clean = src + "def g(transport):\n    _send(transport, 'hello')\n"
    assert codes(lint_one(src_clean, path=CORE)).count("SPDC101") == 1


def test_taint_sanitizer_launders():
    src = (
        "import hashlib\n"
        "def f(m):\n"
        "    d = hashlib.sha256(m).hexdigest()\n"
        "    raise ValueError(f'digest {d}')\n"
    )
    assert codes(lint_one(src, path=CORE)) == []


def test_taint_out_of_scope_paths_are_skipped():
    src = "def f(m):\n    print(m)\n"
    assert "SPDC102" in codes(lint_one(src, path=CORE, passes=["taint"]))
    assert codes(lint_one(src, path="src/repro/models/fixture.py", passes=["taint"])) == []


# --------------------------------------------------------- pass 2: locks
_LRU_BUG = """\
import threading
from collections import OrderedDict

class Gateway:
    def __init__(self):
        self._lock = threading.RLock()
        #: guarded-by: self._lock
        self._dummies = OrderedDict()
        self.on_flush = None

    def dummy(self, key):
        {body}
"""


def test_lock_unlocked_lru_read_flags():
    """The PR-8 bug class: OrderedDict.get on an LRU outside the lock is
    a MUTATION of recency order and must flag."""
    src = _LRU_BUG.format(body="return self._dummies.get(key)")
    assert "SPDC201" in codes(lint_one(src, passes=["locks"]))


def test_lock_locked_lru_read_passes():
    src = _LRU_BUG.format(
        body="with self._lock:\n            return self._dummies.get(key)"
    )
    assert codes(lint_one(src, passes=["locks"])) == []


def test_lock_unlocked_store_flags():
    src = _LRU_BUG.format(body="self._dummies[key] = 1")
    assert "SPDC201" in codes(lint_one(src, passes=["locks"]))


def test_lock_hook_under_lock_flags():
    """The other historical bug class: observer hooks fired while the
    gateway lock is held (re-entrancy / deadlock hazard)."""
    src = _LRU_BUG.format(
        body="with self._lock:\n            self.on_flush(key)"
    )
    assert "SPDC203" in codes(lint_one(src, passes=["locks"]))


def test_lock_hook_outside_lock_passes():
    src = _LRU_BUG.format(
        body="with self._lock:\n            pass\n        self.on_flush(key)"
    )
    assert codes(lint_one(src, passes=["locks"])) == []


def test_lock_blocking_call_under_lock_flags():
    src = "import time\n" + _LRU_BUG.format(
        body="with self._lock:\n            time.sleep(1)"
    )
    assert "SPDC202" in codes(lint_one(src, passes=["locks"]))


def test_lock_requires_lock_callsite_enforced():
    src = _LRU_BUG.format(body="self._unsafe(key)") + """\

    #: requires-lock: self._lock
    def _unsafe(self, key):
        self._dummies[key] = 1
"""
    fs = lint_one(src, passes=["locks"])
    assert "SPDC204" in codes(fs)
    # body itself is analyzed as lock-held: no SPDC201 from _unsafe
    assert "SPDC201" not in codes(fs)
    locked = src.replace(
        "self._unsafe(key)",
        "with self._lock:\n            self._unsafe(key)",
    )
    assert codes(lint_one(locked, passes=["locks"])) == []


# ------------------------------------------------- pass 2: real-tree guards
def _real(relpath):
    return (REPO / relpath).read_text(encoding="utf-8")


def test_real_gateway_lints_clean_under_lock_pass():
    path = "src/repro/serve/spdc_gateway.py"
    assert codes(lint_sources({path: _real(path)}, passes=["locks"])) == []


def test_deleting_any_guard_annotation_turns_red():
    """REQUIRED_GUARDS: strip a single '#: guarded-by:' annotation from
    the real gateway source -> SPDC206."""
    path = "src/repro/serve/spdc_gateway.py"
    src = _real(path)
    assert "#: guarded-by: self._lock" in src
    stripped = src.replace("#: guarded-by: self._lock", "#:", 1)
    fs = lint_sources({path: stripped}, passes=["locks"])
    assert "SPDC206" in codes(fs)


def test_required_guards_cover_all_declared_files():
    """Every REQUIRED_GUARDS row matches a real file + class (no rotted
    entries pointing at renamed code)."""
    for suffix, clsname, _attr in REQUIRED_GUARDS:
        matches = [p for p in (REPO / "src").rglob("*.py")
                   if p.as_posix().endswith(suffix)]
        assert matches, f"REQUIRED_GUARDS names missing file {suffix}"
        assert any(f"class {clsname}" in m.read_text() for m in matches), (
            suffix, clsname)


def test_reintroducing_unlocked_dummies_pattern_turns_red():
    """Re-introduce the exact PR-8 regression in the real gateway source
    (hoist the _dummies LRU read above the lock) -> non-zero findings."""
    path = "src/repro/serve/spdc_gateway.py"
    src = _real(path)
    target = (
        "        with self._lock:  # RLock: safe from flush (unlocked) and warmup\n"
        '            assert_owns_lock(self._lock, "_dummies LRU")\n'
        "            cached = self._dummies.get(ckey)\n"
    )
    assert target in src
    buggy = src.replace(target, (
        "        cached = self._dummies.get(ckey)\n"
        "        with self._lock:  # RLock: safe from flush (unlocked) and warmup\n"
    ), 1)
    fs = lint_sources({path: buggy}, passes=["locks"])
    assert "SPDC201" in codes(fs)


def test_deleting_whitelist_entry_turns_red():
    """SPDC105: the ShardTask dataclass and the client-side _TASK_FIELDS
    whitelist are cross-checked; dropping a name from either side flags."""
    client = "src/repro/api/client.py"
    messages = "src/repro/api/messages.py"
    sources = {client: _real(client), messages: _real(messages)}
    assert codes(lint_sources(dict(sources), passes=["taint"])) == []
    assert '"subseed", ' in sources[client]
    sources[client] = sources[client].replace('"subseed", ', "", 1)
    fs = lint_sources(sources, passes=["taint"])
    assert "SPDC105" in codes(fs)


# --------------------------------------------------------- pass 3: jit
def test_jit_wallclock_flags():
    src = (
        "import time\n"
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x * time.time()\n"
    )
    assert "SPDC301" in codes(lint_one(src, path=CORE, passes=["jit"]))


def test_jit_wallclock_outside_jit_passes():
    src = "import time\ndef f(x):\n    return x * time.time()\n"
    assert codes(lint_one(src, path=CORE, passes=["jit"])) == []


def test_jit_reaches_through_helpers():
    src = (
        "import time\n"
        "import jax\n"
        "def _helper(x):\n"
        "    return x * time.time()\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return _helper(x)\n"
    )
    assert "SPDC301" in codes(lint_one(src, path=CORE, passes=["jit"]))


def test_jit_host_rng_flags():
    src = (
        "import jax\n"
        "import numpy as np\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x + np.random.standard_normal()\n"
    )
    assert "SPDC302" in codes(lint_one(src, path=CORE, passes=["jit"]))


def test_jit_global_mutation_flags():
    src = (
        "import jax\n"
        "CACHE = {}\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    CACHE[0] = x\n"
        "    return x\n"
    )
    assert "SPDC303" in codes(lint_one(src, path=CORE, passes=["jit"]))


def test_jit_assignment_form_is_a_root():
    src = (
        "import time\n"
        "import jax\n"
        "def f(x):\n"
        "    return x * time.time()\n"
        "g = jax.jit(f)\n"
    )
    assert "SPDC301" in codes(lint_one(src, path=CORE, passes=["jit"]))


def test_jit_unhashable_static_arg_flags():
    src = (
        "import jax\n"
        "from functools import partial\n"
        "@partial(jax.jit, static_argnames=('shape',))\n"
        "def f(x, shape):\n"
        "    return x\n"
        "def g(x):\n"
        "    return f(x, shape=[1, 2])\n"
    )
    assert "SPDC304" in codes(lint_one(src, path=CORE, passes=["jit"]))


# --------------------------------------------------------- pass 4: exports
def test_dead_export_flags_and_references_silence():
    a = "src/repro/fixture_a.py"
    b = "src/repro/fixture_b.py"
    srcs = {
        a: "def zzq_used():\n    return 1\ndef zzq_orphan():\n    return 2\n",
        b: "from repro.fixture_a import zzq_used\nzzq_used()\n",
    }
    fs = lint_sources(srcs, passes=["exports"])
    assert codes(fs) == ["SPDC401"]
    assert "zzq_orphan" in fs[0].message
    # private names are never audited
    srcs[a] = srcs[a].replace("zzq_orphan", "_zzq_orphan")
    assert codes(lint_sources(srcs, passes=["exports"])) == []


def test_module_internal_reuse_counts_as_reference():
    a = "src/repro/fixture_a.py"
    src = "ZZQ_CONST = 3\ndef _consume():\n    return ZZQ_CONST\n"
    assert codes(lint_sources({a: src}, passes=["exports"])) == []


# ------------------------------------------------------------- docs + CLI
def test_design_doc_code_table_matches_vocab():
    """DESIGN.md §11's finding-code table and vocab.CODES must agree
    exactly — the doc is the contract reviewers read."""
    design = (REPO / "DESIGN.md").read_text(encoding="utf-8")
    doc_codes = set(re.findall(r"\|\s*(SPDC\d{3})\s*\|", design))
    assert doc_codes == set(CODES), (
        sorted(doc_codes ^ set(CODES)))


def test_cli_exit_codes(tmp_path):
    import subprocess

    bad = tmp_path / "src" / "repro" / "core" / "m.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("def _f(seed):\n    raise ValueError(f'{seed}')\n")
    r = subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", "--root", str(tmp_path), "src"],
        capture_output=True, text=True, cwd=REPO,
    )
    assert r.returncode == 1
    assert "SPDC103" in r.stdout
    bad.write_text("def _f(seed):\n    raise ValueError('bad seed')\n")
    r = subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", "--root", str(tmp_path), "src"],
        capture_output=True, text=True, cwd=REPO,
    )
    assert r.returncode == 0, r.stdout + r.stderr


# ------------------------------------------------------------- integration
def test_live_tree_lints_clean():
    """The CI gate: zero findings across src, benchmarks, examples."""
    fs = lint_paths(["src", "benchmarks", "examples"], root=REPO)
    assert fs == [], "\n".join(f.render() for f in fs)


# ------------------------------------------- regression: fixed transport races
def test_transports_lint_clean_under_lock_pass():
    for path in ("src/repro/api/transport.py", "src/repro/api/socket_transport.py"):
        assert codes(lint_sources({path: _real(path)}, passes=["locks"])) == [], path


def test_reintroducing_unlocked_sent_plan_turns_red():
    """Regression guard for the fixed race: _sent_plan (shared with a
    concurrent close()) written without _meta must flag."""
    path = "src/repro/api/transport.py"
    src = _real(path)
    target = (
        "        with self._meta:\n"
        "            self._sent_plan[worker_id] = plan\n"
    )
    assert target in src
    buggy = src.replace(
        target, "        self._sent_plan[worker_id] = plan\n", 1
    )
    assert "SPDC201" in codes(lint_sources({path: buggy}, passes=["locks"]))


def test_reintroducing_blocking_close_under_lock_turns_red():
    """Regression guard for the fixed close(): pipe goodbyes moved back
    under _meta (one wedged worker freezing the fleet) must flag."""
    path = "src/repro/api/transport.py"
    src = _real(path)
    target = "            self._locks.clear()\n"
    assert target in src
    buggy = src.replace(target, (
        "            self._locks.clear()\n"
        "            for conn in conns.values():\n"
        "                conn.send_bytes(b\"\")\n"
    ), 1)
    assert "SPDC202" in codes(lint_sources({path: buggy}, passes=["locks"]))
