"""Gateway tests: mixed-size coalescing correctness, flush-policy edge
cases (timeout on a partial bucket, backpressure rejection), and per-bucket
fault isolation (a tampered server's recovery cost never leaks into other
buckets). DESIGN.md §5.
"""
import numpy as np
import pytest

from repro.configs import SPDCConfig, SPDCGatewayConfig
from repro.core import (
    ServerFault,
    outsource_determinant,
    outsource_determinant_mixed,
)
from repro.serve import (
    GatewayOverloaded,
    NoBucketFits,
    SPDCGateway,
    bucket_size_for,
)
from repro.serve.spdc_gateway import allowed_batch_sizes


def _mat(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n)) + n * np.eye(n)


def _cfg(**kw):
    kw.setdefault("buckets", (8, 16))
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_wait_us", 1000.0)
    kw.setdefault("spdc", SPDCConfig(num_servers=2))
    return SPDCGatewayConfig(name="test-gw", **kw)


class VirtualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------- bucketing


def test_bucket_size_for_picks_smallest_legal():
    assert bucket_size_for(5, (8, 16), 2) == 8
    assert bucket_size_for(8, (8, 16), 2) == 8
    assert bucket_size_for(9, (8, 16), 2) == 16
    # 8 is not servable by N=8 (8/8 == 1 block); falls through to 16
    assert bucket_size_for(5, (8, 16), 8) == 16
    with pytest.raises(NoBucketFits):
        bucket_size_for(17, (8, 16), 2)


def test_gateway_rejects_unservable_bucket_config():
    """A server count no bucket divides must fail at construction, not
    silently route every request down the un-coalesced direct path."""
    with pytest.raises(ValueError, match="servable"):
        SPDCGateway(_cfg(spdc=SPDCConfig(num_servers=3)))


def test_allowed_batch_sizes_bounded():
    assert allowed_batch_sizes(32) == (1, 2, 4, 8, 16, 32)
    assert allowed_batch_sizes(6) == (1, 2, 4, 6)
    assert allowed_batch_sizes(1) == (1,)


# ------------------------------------------------- mixed-size protocol sweep


def test_mixed_sweep_matches_direct_calls():
    """The coalesced mixed-size sweep returns, per request, the same
    determinant the client would have gotten from its own direct
    outsource_determinant call (rtol 1e-10)."""
    ms = [_mat(n, seed=n) for n in (3, 7, 8, 5, 6, 2)]
    res = outsource_determinant_mixed(ms, 2, pad_to=8)
    assert res.verified.all()
    assert res.pad_to == 8 and res.padding == 0
    assert res.paddings == [5, 1, 0, 3, 2, 6]
    for m, det in zip(ms, res.dets):
        direct = outsource_determinant(m, 2)
        assert direct.verified
        assert det.sign == direct.det.sign
        assert np.isclose(det.logabs, direct.det.logabs, rtol=1e-10)


def test_mixed_sweep_rejects_bad_pad_to():
    with pytest.raises(ValueError):
        outsource_determinant_mixed([_mat(4)], 2, pad_to=7)  # 7 % 2 != 0
    with pytest.raises(ValueError):
        outsource_determinant_mixed([_mat(9)], 2, pad_to=8)  # too small
    with pytest.raises(ValueError):
        outsource_determinant_mixed([], 2)


def test_outsource_determinant_routes_lists():
    ms = [_mat(3, seed=1), _mat(6, seed=2)]
    res = outsource_determinant(ms, 2)
    assert res.batch == 2 and res.verified.all()
    for m, det in zip(ms, res.dets):
        ws, wl = np.linalg.slogdet(m)
        assert det.sign == ws and np.isclose(det.logabs, wl, rtol=1e-10)


def test_mixed_sweep_flags_single_tampered_matrix():
    ms = [_mat(n, seed=10 + n) for n in (4, 6, 5)]
    res = outsource_determinant_mixed(
        ms, 2, pad_to=8,
        faults=ServerFault(server=1, matrices=(1,)),
    )
    assert bool(res.verified[0]) and bool(res.verified[2])
    assert not bool(res.verified[1])


# --------------------------------------------------------- gateway semantics


def test_gateway_mixed_interleaved_matches_direct():
    """Interleaved mixed-size, mixed-bucket submissions: every result
    matches the client's own direct call at rtol 1e-10."""
    gw = SPDCGateway(_cfg(), clock=VirtualClock())
    sizes = (3, 12, 5, 16, 8, 9, 4, 14)
    mats = [_mat(n, seed=20 + n) for n in sizes]
    rids = [gw.submit(m) for m in mats]
    gw.drain()
    for m, rid in zip(mats, rids):
        r = gw.take(rid)
        assert r is not None and r.verified
        direct = outsource_determinant(m, 2)
        assert r.det.sign == direct.det.sign
        assert np.isclose(r.det.logabs, direct.det.logabs, rtol=1e-10)
    assert gw.stats.served == len(sizes)
    # sizes <= 8 share bucket 8; 9..16 share bucket 16
    assert gw.stats.flushes >= 2


def test_gateway_full_bucket_flushes_on_submit():
    clock = VirtualClock()
    gw = SPDCGateway(_cfg(max_batch=2), clock=clock)
    r0 = gw.submit(_mat(5, seed=1))
    assert gw.take(r0) is None and gw.pending == 1
    r1 = gw.submit(_mat(6, seed=2))  # bucket reaches max_batch
    res0, res1 = gw.take(r0), gw.take(r1)
    assert res0 is not None and res1 is not None
    assert res0.flush_reason == "full" and res0.batch == 2
    assert gw.pending == 0 and gw.stats.flushes_full == 1


def test_gateway_timeout_flushes_partial_bucket():
    clock = VirtualClock()
    gw = SPDCGateway(_cfg(max_wait_us=1000.0), clock=clock)
    rid = gw.submit(_mat(5, seed=3))
    # before the deadline nothing happens
    clock.t = 0.0009
    assert gw.poll() == [] and gw.take(rid) is None
    # after max_wait_us the partial bucket (1 of 4) flushes
    clock.t = 0.0011
    out = gw.poll()
    assert [r.rid for r in out] == [rid]
    res = gw.take(rid)
    assert res.flush_reason == "timeout" and res.batch == 1 and res.verified
    assert gw.stats.flushes_timeout == 1


def test_gateway_backpressure_rejects_at_submit():
    clock = VirtualClock()
    gw = SPDCGateway(
        _cfg(max_batch=100, max_wait_us=1e9, max_pending=3), clock=clock
    )
    mats = [_mat(5, seed=30 + i) for i in range(3)]
    rids = [gw.submit(m) for m in mats]
    with pytest.raises(GatewayOverloaded):
        gw.submit(_mat(5, seed=99))
    assert gw.stats.rejected == 1 and gw.stats.submitted == 3
    assert gw.pending == 3  # the rejected request was never enqueued
    gw.drain()
    for rid in rids:  # queued requests are unharmed
        assert gw.take(rid).verified


def test_gateway_oversize_runs_direct():
    gw = SPDCGateway(_cfg(), clock=VirtualClock())
    rid = gw.submit(_mat(20, seed=4))  # larger than every bucket
    res = gw.take(rid)
    assert res is not None and res.verified
    assert res.flush_reason == "direct" and res.batch == 1
    assert gw.stats.direct == 1 and gw.stats.flushes == 0
    ws, wl = np.linalg.slogdet(_mat(20, seed=4))
    assert res.det.sign == ws and np.isclose(res.det.logabs, wl, rtol=1e-10)


def test_gateway_security_config_overrides_open_buckets():
    """Requests with different security configs never share a sweep."""
    gw = SPDCGateway(_cfg(max_batch=2, max_wait_us=1e9), clock=VirtualClock())
    a = gw.submit(_mat(5, seed=5))
    b = gw.submit(_mat(5, seed=6), method="q2")  # different bucket
    c = gw.submit(_mat(5, seed=7), lambda1=64)  # security params count too
    assert gw.take(a) is None and gw.take(b) is None and gw.pending == 3
    gw.drain()
    ra, rb, rc = gw.take(a), gw.take(b), gw.take(c)
    assert ra.verified and rb.verified and rc.verified
    assert gw.stats.flushes == 3  # one sweep per security config


def test_bucket_key_carries_full_security_config():
    """Every SPDCConfig protocol field the sweep honors must ride in the
    BucketKey's kwargs — a gateway preset raising lambda1/lambda2 must not
    be silently served at the defaults."""
    from repro.serve import BucketKey

    key = BucketKey(pad_to=8, num_servers=2, lambda1=256, lambda2=192)
    kwargs = key.protocol_kwargs()
    assert kwargs["lambda1"] == 256 and kwargs["lambda2"] == 192
    spdc_fields = set(SPDCConfig().protocol_kwargs())
    assert spdc_fields <= set(kwargs) | {"pad_to"}


def test_gateway_burst_flushes_in_max_batch_chunks():
    """A burst beyond max_batch is served in max_batch-sized sweeps (bounded
    jit shapes), not one oversized sweep."""
    gw = SPDCGateway(_cfg(max_batch=2, max_wait_us=1e9), clock=VirtualClock(),
                     auto_flush=False)
    rids = [gw.submit(_mat(5, seed=40 + i)) for i in range(5)]
    gw.poll()  # flushes the full bucket twice (2 + 2), leaves 1 pending
    assert gw.stats.flushes == 2 and gw.pending == 1
    gw.drain()
    assert gw.pending == 0
    batches = sorted(gw.take(r).batch for r in rids)
    assert batches == [1, 2, 2, 2, 2]


def test_gateway_rejects_bad_submissions_loudly():
    gw = SPDCGateway(_cfg(), clock=VirtualClock())
    with pytest.raises(TypeError, match="unknown submit"):
        gw.submit(_mat(5), recovery=True)  # typo for recover=
    with pytest.raises(ValueError, match="square"):
        gw.submit(np.ones((3, 4)))
    with pytest.raises(ValueError, match="at least 2x2"):
        gw.submit(np.ones((1, 1)))
    with pytest.raises(ValueError, match="non-finite"):
        gw.submit(np.full((4, 4), np.nan))
    assert gw.pending == 0


def test_gateway_sweep_failure_fails_requests_not_service():
    """A sweep that raises delivers per-request error results; co-batched
    requests never vanish and later submissions still work."""
    gw = SPDCGateway(_cfg(max_batch=2), clock=VirtualClock(),
                     faults_for=lambda key: (_ for _ in ()).throw(
                         RuntimeError("injected sweep failure")))
    r0 = gw.submit(_mat(5, seed=1))
    r1 = gw.submit(_mat(6, seed=2))  # fills the bucket -> failing flush
    res0, res1 = gw.take(r0), gw.take(r1)
    assert res0 is not None and res1 is not None
    assert not res0.verified and "injected sweep failure" in res0.error
    assert res0.det is None and res1.det is None
    assert gw.stats.failed == 2 and gw.pending == 0
    # the gateway keeps serving once the failure source is gone
    gw._faults_for = None
    r2 = gw.submit(_mat(5, seed=3))
    r3 = gw.submit(_mat(6, seed=4))
    assert gw.take(r2).verified and gw.take(r3).verified


def test_mixed_list_rejects_use_kernel():
    with pytest.raises(ValueError, match="use_kernel"):
        outsource_determinant([_mat(4), _mat(6)], 2, use_kernel=True)


# ----------------------------------------------------------- fault isolation


def test_tampered_bucket_pays_recovery_alone():
    """A tampering server poisons one bucket's sweep; recovery heals that
    bucket and the co-batched clean bucket never pays for it."""
    cfg = _cfg(
        max_batch=3, max_wait_us=1e9,
        spdc=SPDCConfig(num_servers=2, recover=True, standby=1),
    )

    def faults_for(key):
        return ServerFault(server=1) if key.pad_to == 8 else None

    gw = SPDCGateway(cfg, clock=VirtualClock(), faults_for=faults_for)
    small = [_mat(n, seed=50 + n) for n in (4, 6, 7)]  # bucket 8 (tampered)
    big = [_mat(n, seed=60 + n) for n in (10, 14, 16)]  # bucket 16 (clean)
    rids_s = [gw.submit(m) for m in small]
    rids_b = [gw.submit(m) for m in big]
    rs = [gw.take(r) for r in rids_s]
    rb = [gw.take(r) for r in rids_b]

    # tampered bucket: healed in place, exact dets, recovery report attached
    for m, r in zip(small, rs):
        assert r.verified and r.recovery is not None and r.recovery.ok
        ws, wl = np.linalg.slogdet(m)
        assert r.det.sign == ws and np.isclose(r.det.logabs, wl, rtol=1e-10)
    # clean bucket: verified with NO recovery involvement
    for m, r in zip(big, rb):
        assert r.verified and r.recovery is None
        ws, wl = np.linalg.slogdet(m)
        assert r.det.sign == ws and np.isclose(r.det.logabs, wl, rtol=1e-10)
    assert gw.stats.recovered_flushes == 1
    assert gw.stats.flushes == 2


# ------------------------------------------------------------- async surface


def test_async_gateway_serves_concurrent_clients():
    import asyncio

    from repro.serve import AsyncSPDCGateway

    cfg = _cfg(max_batch=4, max_wait_us=3000.0)
    mats = [_mat(n, seed=70 + n) for n in (3, 12, 5, 16, 8, 9, 4, 14)]

    async def main():
        async with AsyncSPDCGateway(cfg) as gw:
            return await asyncio.gather(*(gw.submit(m) for m in mats))

    results = asyncio.run(main())
    assert len(results) == len(mats)
    for m, r in zip(mats, results):
        assert r.verified
        ws, wl = np.linalg.slogdet(m)
        assert r.det.sign == ws and np.isclose(r.det.logabs, wl, rtol=1e-10)


def test_async_gateway_backpressure_raises():
    import asyncio

    from repro.serve import AsyncSPDCGateway

    cfg = _cfg(max_batch=100, max_wait_us=1e9, max_pending=2)

    async def main():
        async with AsyncSPDCGateway(cfg) as gw:
            t1 = asyncio.ensure_future(gw.submit(_mat(5, seed=1)))
            t2 = asyncio.ensure_future(gw.submit(_mat(5, seed=2)))
            # submits enqueue on worker threads; wait until both landed
            # (neither can flush: the bucket never fills nor times out)
            while gw.pending < 2:
                await asyncio.sleep(0.001)
            with pytest.raises(GatewayOverloaded):
                await gw.submit(_mat(5, seed=3))
        # leaving the context drains the queue and resolves the waiters
        return await asyncio.gather(t1, t2)

    r1, r2 = asyncio.run(main())
    assert r1.verified and r2.verified


# ------------------------------------------------------------- lock assertions
def test_assert_owns_lock_semantics():
    """Debug-mode ownership probe: exact for RLock, one-sided for Lock."""
    import threading

    from repro.serve.locking import assert_owns_lock

    rl = threading.RLock()
    with pytest.raises(AssertionError, match="without holding"):
        assert_owns_lock(rl, "thing")
    with rl:
        assert_owns_lock(rl, "thing")  # no raise
    # plain Lock: a free lock is provably not ours
    pl = threading.Lock()
    with pytest.raises(AssertionError):
        assert_owns_lock(pl)
    with pl:
        assert_owns_lock(pl)  # held (by us) => accepted
    assert not pl.locked()  # probe must not leave the lock held


def test_gateway_deliver_requires_lock_at_runtime():
    """_deliver asserts gateway-lock ownership: calling it unlocked (the
    bug class repro-lint's SPDC204 catches lexically) trips at runtime."""
    from repro.serve.spdc_gateway import GatewayResult

    gw = SPDCGateway(_cfg(), clock=VirtualClock())
    gres = GatewayResult(
        rid=1, det=None, verified=False, residual=0.0, n=8, pad_to=8,
        batch=1, flush_reason="direct", submitted_at=0.0, completed_at=0.0,
        error="x",
    )
    with pytest.raises(AssertionError, match="gateway results"):
        gw._deliver(gres, "b8")
    with gw._lock:
        gw._deliver(gres, "b8")
    assert gw.take(1) is gres
