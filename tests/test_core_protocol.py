"""SPDC protocol: seed/key/cipher/augment/LU/verify/decipher, unit +
end-to-end + hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    augment, cipher, keygen, lu_blocked, lu_nserver, lu_unblocked,
    outsource_determinant, padding_for_servers, q1, q2, q3,
    q3_paper_literal, seedgen, slogdet_from_lu,
)


def _wellcond(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n)) + n * np.eye(n)


# ---------------------------------------------------------------------- seed
def test_seedgen_deterministic_and_sensitive():
    m = _wellcond(8)
    s1 = seedgen(128, m)
    s2 = seedgen(128, m)
    assert s1.psi == s2.psi and s1.digest == s2.digest
    s3 = seedgen(129, m)  # different λ → different seed
    assert s3.psi != s1.psi
    m2 = m.copy(); m2[0, 0] += 1.0  # different stats → different seed
    assert seedgen(128, m2).psi != s1.psi
    assert 2**-4 <= s1.psi <= 2**4


# ---------------------------------------------------------------------- key
@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 64))
def test_keygen_product_constraint(n):
    seed = seedgen(128, _wellcond(max(n, 2)))
    key = keygen(128, seed, n)
    assert key.v.shape == (n,)
    assert not np.any(key.v == 1.0)  # paper constraint v_i != 1
    np.testing.assert_allclose(np.prod(key.v), seed.psi, rtol=1e-9)


# -------------------------------------------------------------------- cipher
@pytest.mark.parametrize("mode", ["ewd", "ewm"])
def test_cipher_det_relation(mode):
    """det(X) = s · det(M) · Ψ^{∓1} — the relation Decipher inverts."""
    n = 8
    m = jnp.asarray(_wellcond(n))
    seed = seedgen(128, np.asarray(m))
    key = keygen(128, seed, n)
    x, meta = cipher(m, key, seed, mode=mode)
    from repro.core.prt import rotation_sign

    s = rotation_sign(n, meta.rotate_k)
    det_m = np.linalg.det(np.asarray(m))
    det_x = np.linalg.det(np.asarray(x))
    if mode == "ewd":
        np.testing.assert_allclose(det_x, s * det_m / seed.psi, rtol=1e-9)
    else:
        np.testing.assert_allclose(det_x, s * det_m * seed.psi, rtol=1e-9)


def test_cipher_kernel_path_matches_jnp():
    n = 16
    m = jnp.asarray(_wellcond(n))
    seed = seedgen(7, np.asarray(m))
    key = keygen(9, seed, n)
    x_ref, _ = cipher(m, key, seed, use_kernel=False)
    x_k, _ = cipher(m, key, seed, use_kernel=True)
    np.testing.assert_allclose(np.asarray(x_k), np.asarray(x_ref), rtol=1e-12)


def test_cipher_hides_entries():
    """Ciphertext should not reveal plaintext entries (basic sanity — each
    entry is scaled by a secret v_i and relocated)."""
    n = 12
    m = jnp.asarray(_wellcond(n))
    seed = seedgen(128, np.asarray(m))
    key = keygen(128, seed, n)
    x, _ = cipher(m, key, seed)
    assert not np.allclose(np.sort(np.asarray(x).ravel()),
                           np.sort(np.asarray(m).ravel()))


# ------------------------------------------------------------------- augment
@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 40), servers=st.integers(1, 8))
def test_padding_rule(n, servers):
    p = padding_for_servers(n, servers)
    assert (n + p) % servers == 0 and (n + p) // servers > 1
    # minimality
    for q in range(p):
        assert (n + q) % servers != 0 or (n + q) // servers <= 1


def test_paper_examples_of_augmentation():
    assert padding_for_servers(4, 3) == 2  # paper example 1: 4×4, N=3 → 6×6
    assert padding_for_servers(6, 2) == 0  # paper example 2: 6×6, N=2 → p=0


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 16), p=st.integers(0, 5))
def test_augment_preserves_det(n, p):
    import jax

    a = jnp.asarray(_wellcond(n, seed=n + p))
    b = augment(a, p, key=jax.random.key(0))
    np.testing.assert_allclose(
        np.linalg.det(np.asarray(b)), np.linalg.det(np.asarray(a)), rtol=1e-9
    )


# ------------------------------------------------------------------------ LU
@pytest.mark.parametrize("n", [4, 16, 33])
def test_lu_unblocked(n):
    a = jnp.asarray(_wellcond(n))
    l, u = lu_unblocked(a)
    np.testing.assert_allclose(np.asarray(l @ u), np.asarray(a), atol=1e-9)
    assert np.allclose(np.diag(np.asarray(l)), 1.0)
    assert np.allclose(np.asarray(l), np.tril(np.asarray(l)))
    assert np.allclose(np.asarray(u), np.triu(np.asarray(u)))


@pytest.mark.parametrize("n,block", [(16, 4), (32, 8), (64, 16)])
def test_lu_blocked_matches_unblocked(n, block):
    a = jnp.asarray(_wellcond(n))
    l1, u1 = lu_unblocked(a)
    l2, u2 = lu_blocked(a, block)
    np.testing.assert_allclose(np.asarray(l2), np.asarray(l1), atol=1e-9)
    np.testing.assert_allclose(np.asarray(u2), np.asarray(u1), atol=1e-9)


@pytest.mark.parametrize("n,servers", [(8, 2), (12, 3), (16, 4), (30, 5)])
def test_lu_nserver_matches_and_logs_comm(n, servers):
    a = jnp.asarray(_wellcond(n))
    l, u, log = lu_nserver(a, servers)
    np.testing.assert_allclose(np.asarray(l @ u), np.asarray(a), atol=1e-8)
    # one-way chain: exactly N-1 messages, each to the next server
    assert log.hops == servers - 1
    assert all(dst == src + 1 for src, dst, _ in log.messages)
    s, la = slogdet_from_lu(l, u)
    want_s, want_la = np.linalg.slogdet(np.asarray(a))
    assert float(s) == want_s
    np.testing.assert_allclose(float(la), want_la, rtol=1e-9)


# ------------------------------------------------------------------- verify
def test_q_formulas_zero_on_correct_lu():
    n = 16
    a = jnp.asarray(_wellcond(n))
    l, u = lu_unblocked(a)
    r = jnp.asarray(np.random.default_rng(0).standard_normal(n))
    assert float(jnp.max(jnp.abs(q1(l, u, a, r)))) < 1e-9
    assert abs(float(q2(l, u, a, r))) < 1e-8
    assert float(q3(l, u, a)) < 1e-10
    assert float(q3_paper_literal(l, u, a)) < 1e-10


def test_q_formulas_reject_tampering():
    n = 16
    a = jnp.asarray(_wellcond(n))
    l, u = lu_unblocked(a)
    u_bad = u.at[3, 3].multiply(1.01)
    r = jnp.asarray(np.random.default_rng(0).standard_normal(n))
    assert abs(float(q2(l, u_bad, a, r))) > 1e-4
    assert float(q3(l, u_bad, a)) > 1e-4


def test_q3_literal_cancellation_weakness():
    """The paper's literal Q3 (abs outside the sum) accepts a tampering
    whose per-row errors cancel — the per-element form rejects it.
    (DESIGN.md §1.1 erratum.)"""
    n = 8
    a = jnp.asarray(_wellcond(n))
    l, u = lu_unblocked(a)
    # equal-and-opposite diagonal perturbations
    u_bad = u.at[0, 0].add(0.5)
    u_bad = u_bad.at[1, 1].add(-0.5 * float(l[0, 0] / l[1, 1]))
    lit = float(q3_paper_literal(l, u_bad, a))
    strict = float(q3(l, u_bad, a))
    assert strict > 0.1          # real check catches it
    assert lit < strict / 100    # literal form nearly blind to it


def test_q3_growth_widening_is_not_attacker_inflatable():
    """Adaptive attack on the growth-widened ε: plant a pair of huge
    strictly-upper entries in U whose diagonal contributions cancel
    (L[i,j]·Δ + L[i,j']·δ = 0) — Q3's residual is untouched while
    max|U| (hence growth_estimate, hence ε) inflates by ~1e8 — then bias
    a diagonal entry by far more than the honest tolerance. Pre-fix,
    authenticate(method="q3") accepted the biased determinant; the
    q3_growth_cap clamp must reject it.
    """
    from repro.core.verify import (
        authenticate, epsilon, growth_estimate, q3_growth_cap,
    )

    n, servers = 32, 4
    a = jnp.asarray(_wellcond(n))
    l, u = lu_unblocked(a)
    assert authenticate(l, u, a, num_servers=servers, method="q3").ok

    # cancelling pair in column n-1: Δ·L[i,0] + δ·L[i,1] = 0
    i = n - 1
    scale = 1e8 * float(jnp.max(jnp.abs(a))) / float(jnp.abs(l[i, 1]))
    u_adv = u.at[0, i].add(float(l[i, 1]) * scale)
    u_adv = u_adv.at[1, i].add(-float(l[i, 0]) * scale)
    inflation = growth_estimate(u_adv, a) / growth_estimate(u, a)
    assert inflation > 1e6  # the planted entries dominate max|U|

    # diagonal bias: residual ≈ |U[k,k]|·τ sits far above the clamped ε
    # but far below the raw growth-widened ε the pre-fix code used
    base_eps = epsilon(servers, n, a, dtype=a.dtype)
    k = 3
    tau = 100.0 * base_eps * q3_growth_cap(n) / abs(float(u[k, k]))
    u_adv = u_adv.at[k, k].multiply(1.0 + tau)

    verdict = authenticate(l, u_adv, a, num_servers=servers, method="q3")
    assert verdict.residual < base_eps * growth_estimate(u_adv, a)
    assert not verdict.ok  # pre-fix: accepted (ok == residual <= raw ε)
    # the secret-probed Q1 form sees the planted entries outright
    rng = np.random.default_rng(7)
    assert not authenticate(
        l, u_adv, a, num_servers=servers, method="q1", rng=rng
    ).ok


# ------------------------------------------------------------ end-to-end
@pytest.mark.parametrize("mode", ["ewd", "ewm"])
@pytest.mark.parametrize("method", ["q1", "q2", "q3"])
def test_protocol_roundtrip(mode, method):
    m = _wellcond(12, seed=5)
    res = outsource_determinant(m, 3, mode=mode, method=method)
    want_s, want_la = np.linalg.slogdet(m)
    assert res.verified, f"residual {res.residual}"
    assert res.det.sign == want_s
    np.testing.assert_allclose(res.det.logabs, want_la, rtol=1e-9)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 24), servers=st.integers(2, 5),
       mode=st.sampled_from(["ewd", "ewm"]))
def test_protocol_property(n, servers, mode):
    m = _wellcond(n, seed=n * 7 + servers)
    res = outsource_determinant(m, servers, mode=mode)
    want_s, want_la = np.linalg.slogdet(m)
    assert res.verified
    assert res.det.sign == want_s
    np.testing.assert_allclose(res.det.logabs, want_la, rtol=1e-8)


def test_protocol_detects_malicious_server():
    m = _wellcond(12, seed=9)
    res = outsource_determinant(
        m, 3, tamper=lambda l, u: (l.at[5, 2].add(0.05), u)
    )
    assert not res.verified


def test_protocol_faithful_sign_differs_for_n_mod4_0():
    """Same run deciphered with the paper's literal sign vs the theorem's:
    they disagree exactly when n ≡ 0,1 (mod 4) and an odd rotation fired."""
    for seed in range(12):
        m = _wellcond(8, seed=seed)  # n = 8 ≡ 0 (mod 4)
        res = outsource_determinant(m, 2)
        if res.meta.rotate_k % 2 == 1:
            res_paper = outsource_determinant(m, 2, faithful_sign=True)
            assert res_paper.det.sign == -res.det.sign
            want_s, _ = np.linalg.slogdet(m)
            assert res.det.sign == want_s  # the corrected one is right
            return
    pytest.skip("no odd rotation drawn in 12 seeds")


def test_protocol_with_augmentation_and_odd_sizes():
    """Paper Table III: odd sizes supported via minimal padding."""
    for n, servers in [(7, 2), (9, 4), (11, 3)]:
        m = _wellcond(n, seed=n)
        res = outsource_determinant(m, servers)
        assert res.padding == padding_for_servers(n, servers)
        want_s, want_la = np.linalg.slogdet(m)
        assert res.verified and res.det.sign == want_s
        np.testing.assert_allclose(res.det.logabs, want_la, rtol=1e-8)


# ---------------------------------------------------------------- inversion
def test_secure_inverse_roundtrip():
    """Beyond-paper (paper §VII.B future work): secure outsourced INVERSION
    on the same CED+LU machinery; client recovery is O(n²)."""
    from repro.core import outsource_inverse

    rng = np.random.default_rng(5)
    for n, servers, mode in [(12, 3, "ewd"), (16, 4, "ewm"), (9, 2, "ewd")]:
        m = rng.standard_normal((n, n)) + n * np.eye(n)
        res = outsource_inverse(m, servers, mode=mode)
        assert res.verified, res.residual
        np.testing.assert_allclose(
            np.asarray(res.inverse) @ m, np.eye(n), atol=1e-8
        )


def test_secure_inverse_rejects_tampering():
    from repro.core import outsource_inverse

    rng = np.random.default_rng(6)
    m = rng.standard_normal((12, 12)) + 12 * np.eye(12)
    res = outsource_inverse(m, 3, tamper=lambda iv: iv.at[3, 4].add(0.01))
    assert not res.verified


@settings(max_examples=10, deadline=None)
@given(n=st.integers(4, 20), servers=st.integers(2, 4),
       mode=st.sampled_from(["ewd", "ewm"]))
def test_secure_inverse_property(n, servers, mode):
    from repro.core import outsource_inverse

    rng = np.random.default_rng(n * 13 + servers)
    m = rng.standard_normal((n, n)) + n * np.eye(n)
    res = outsource_inverse(m, servers, mode=mode)
    assert res.verified
    np.testing.assert_allclose(np.asarray(res.inverse) @ m, np.eye(n), atol=1e-7)
