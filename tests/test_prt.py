"""PRT (Panth Rotation Theorem) — the theorem itself, as tests + hypothesis
property checks, including the paper's §IV.F sign-recovery erratum."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.prt import (
    quantize_seed, rot90_cw, rotate_degree, rotation_sign,
    rotation_sign_paper, sign_preserved,
)


def _det(x):
    return np.linalg.det(np.asarray(x, dtype=np.float64))


@pytest.mark.parametrize("n", [2, 3, 4, 5, 6, 7, 8, 9])
@pytest.mark.parametrize("k", [1, 2, 3, 4])
def test_prt_sign_law(n, k):
    """det(rot90_cw^k(X)) == rotation_sign(n,k) * det(X) for all n mod 4."""
    rng = np.random.default_rng(n * 10 + k)
    x = jnp.asarray(rng.standard_normal((n, n)))
    got = _det(rot90_cw(x, k))
    want = rotation_sign(n, k) * _det(x)
    np.testing.assert_allclose(got, want, rtol=1e-9)


@pytest.mark.parametrize("n,k,preserved", [
    (4, 1, True), (5, 1, True),     # n ≡ 0,1 (mod 4): all rotations preserve
    (6, 1, False), (7, 1, False),   # n ≡ 2,3 (mod 4): 90° flips
    (6, 2, True), (7, 2, True),     # 180° always preserves
    (6, 3, False), (7, 3, False),   # 270° flips
    (6, 4, True),                   # 360° identity
])
def test_theorem_case_split(n, k, preserved):
    assert sign_preserved(n, k) is preserved


def test_rotation_matches_paper_example_layout():
    """The paper's explicit 4×4 R_90 layout (§II.A.1)."""
    x = jnp.arange(16, dtype=jnp.float64).reshape(4, 4) + 11  # X_ij = i*10+j style
    r = rot90_cw(x, 1)
    # paper: first row of R_90(X) is X_41, X_31, X_21, X_11 (first column reversed)
    np.testing.assert_array_equal(np.asarray(r)[0], np.asarray(x)[::-1, 0])
    # 360° is identity
    np.testing.assert_array_equal(np.asarray(rot90_cw(x, 4)), np.asarray(x))


def test_paper_sign_erratum():
    """Paper's Decipher factor (-1)^k is wrong for n ≡ 0,1 (mod 4), odd k
    (its own theorem says sign is preserved there). DESIGN.md §1.1."""
    for n in (4, 8, 5, 9):
        for k in (1, 3):
            assert rotation_sign(n, k) == 1
            assert rotation_sign_paper(k) == -1  # the paper's literal formula
    # agreement region: n ≡ 2,3 (mod 4)
    for n in (6, 7, 10, 11):
        for k in (1, 2, 3):
            assert rotation_sign(n, k) == rotation_sign_paper(k)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 12), k=st.integers(0, 7))
def test_prt_property(n, k):
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.standard_normal((n, n)))
    got = _det(rot90_cw(x, k))
    want = rotation_sign(n, k) * _det(x)
    np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-10)


@settings(max_examples=25, deadline=None)
@given(psi=st.floats(0.01, 1e6))
def test_rotate_degree_range(psi):
    assert rotate_degree(psi) in (1, 2, 3)
    for method in ("floor", "ceil", "round", "trunc"):
        assert isinstance(quantize_seed(psi, method), int)


def test_rotation_composition():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((6, 6)))
    np.testing.assert_array_equal(
        np.asarray(rot90_cw(rot90_cw(x, 1), 2)), np.asarray(rot90_cw(x, 3))
    )
