"""Test session config: 8 fake CPU devices for sharding tests (NOT 512 —
the production-mesh dry-run has its own entrypoint), x64 for the SPDC
protocol's float64 paths.

JAX_ENABLE_X64=0 runs the x64-disabled float32 leg (the CI job that
proves the protocol works on backends without f64): only the precision
test module is expected to pass there — the f64-calibrated suites assume
x64. Default (unset or 1) keeps x64 on.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

jax.config.update(
    "jax_enable_x64",
    os.environ.get("JAX_ENABLE_X64", "1").lower() not in ("0", "false"),
)

import sys
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# The test image may not ship `hypothesis`; fall back to the deterministic
# shim in tests/_hypothesis_stub.py so property tests still run.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import importlib.util

    _stub_path = Path(__file__).resolve().parent / "_hypothesis_stub.py"
    _spec = importlib.util.spec_from_file_location("hypothesis", _stub_path)
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod

# repro.linalg flips jax_cpu_enable_async_dispatch, which only takes
# effect if it runs before the first jax dispatch of the process — and
# pytest runs every module in one process. Import it here so the
# jit-callback tests (tests/test_linalg.py) can't deadlock just because
# an earlier test module initialized the CPU backend first.
import repro.linalg  # noqa: E402,F401
