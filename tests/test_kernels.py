"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref


def _rand(shape, dtype=jnp.float64, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ------------------------------------------------------------------- CED
@pytest.mark.parametrize("n,block", [(8, 4), (16, 8), (12, 4), (256, 128), (20, 1)])
@pytest.mark.parametrize("k", [1, 2, 3])
@pytest.mark.parametrize("mode", ["ewd", "ewm"])
def test_ced_kernel(n, block, k, mode):
    m = _rand((n, n), seed=n + k)
    v = jnp.asarray(np.random.default_rng(1).uniform(0.5, 2.0, n))
    got = ops.ced(m, v, k, mode=mode, block=block)
    want = ref.ced_ref(m, v, k, mode=mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_ced_dtypes(dtype):
    m = _rand((16, 16), dtype=dtype)
    v = jnp.asarray(np.random.default_rng(1).uniform(0.5, 2.0, 16), dtype=dtype)
    got = ops.ced(m, v, 2, block=8)
    want = ref.ced_ref(m, v, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# --------------------------------------------------------------- LU panel
@pytest.mark.parametrize("n", [4, 8, 32, 64, 128])
def test_lu_panel_kernel(n):
    a = _rand((n, n), seed=n) + n * jnp.eye(n)
    l, u = ops.lu_panel(a)
    want = ref.lu_panel_ref(a)
    got = jnp.tril(l, -1) + u
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-9)
    np.testing.assert_allclose(np.asarray(l @ u), np.asarray(a), atol=1e-9)


# ------------------------------------------------------------------- TRSM
@pytest.mark.parametrize("n,m", [(4, 8), (16, 16), (32, 128), (64, 32)])
def test_trsm_kernels(n, m):
    l = jnp.tril(_rand((n, n), seed=n), -1) + jnp.eye(n)
    b = _rand((n, m), seed=m)
    np.testing.assert_allclose(
        np.asarray(ops.trsm_lower(l, b)),
        np.asarray(ref.trsm_lower_ref(l, b)), atol=1e-9,
    )
    u = jnp.triu(_rand((n, n), seed=n + 1)) + n * jnp.eye(n)
    b2 = _rand((m, n), seed=m + 1)
    np.testing.assert_allclose(
        np.asarray(ops.trsm_upper_right(u, b2)),
        np.asarray(ref.trsm_upper_right_ref(u, b2)), atol=1e-9,
    )


# ------------------------------------------------------------------- Schur
@settings(max_examples=10, deadline=None)
@given(mi=st.sampled_from([32, 64]), ni=st.sampled_from([32, 96]),
       ki=st.sampled_from([16, 64]))
def test_schur_kernel_property(mi, ni, ki):
    c = _rand((mi, ni), seed=1)
    a = _rand((mi, ki), seed=2)
    b = _rand((ki, ni), seed=3)
    got = ops.schur_update(c, a, b, bm=32, bn=32, bk=16)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.schur_update_ref(c, a, b)), atol=1e-9
    )


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-4), (jnp.bfloat16, 0.15)])
def test_schur_low_precision(dtype, atol):
    c = _rand((64, 64), dtype=dtype)
    a = _rand((64, 64), dtype=dtype, seed=1)
    b = _rand((64, 64), dtype=dtype, seed=2)
    got = ops.schur_update(c, a, b, bm=32, bn=32, bk=32)
    want = ref.schur_update_ref(
        c.astype(jnp.float32), a.astype(jnp.float32), b.astype(jnp.float32)
    )
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want), atol=atol, rtol=0.05
    )


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_gqa(hq, hkv, causal):
    q = _rand((2, hq, 64, 16), dtype=jnp.float32, seed=1)
    k = _rand((2, hkv, 64, 16), dtype=jnp.float32, seed=2)
    v = _rand((2, hkv, 64, 16), dtype=jnp.float32, seed=3)
    got = ops.flash_attention(q, k, v, causal=causal, bq=16, bk=16)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


@pytest.mark.parametrize("window", [8, 24, 64])
def test_flash_attention_sliding(window):
    q = _rand((1, 2, 64, 16), dtype=jnp.float32, seed=1)
    k = _rand((1, 2, 64, 16), dtype=jnp.float32, seed=2)
    v = _rand((1, 2, 64, 16), dtype=jnp.float32, seed=3)
    got = ops.flash_attention(q, k, v, causal=True, window=window, bq=16, bk=16)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


def test_flash_attention_decode_right_aligned():
    """sq < sk: queries are the LAST sq positions (decode semantics)."""
    q = _rand((2, 4, 4, 16), dtype=jnp.float32, seed=1)
    k = _rand((2, 4, 64, 16), dtype=jnp.float32, seed=2)
    v = _rand((2, 4, 64, 16), dtype=jnp.float32, seed=3)
    got = ops.flash_attention(q, k, v, causal=True, bq=4, bk=16)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 3e-5), (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, atol):
    q = _rand((1, 2, 32, 8), dtype=dtype, seed=1)
    k = _rand((1, 2, 32, 8), dtype=dtype, seed=2)
    v = _rand((1, 2, 32, 8), dtype=dtype, seed=3)
    got = ops.flash_attention(q, k, v, causal=True, bq=8, bk=8)
    want = ref.flash_attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        causal=True,
    )
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want), atol=atol
    )


# -------------------------------------------- kernels inside blocked LU
def test_blocked_lu_with_kernels_end_to_end():
    from repro.core.lu import lu_blocked

    a = _rand((64, 64), seed=11) + 64 * jnp.eye(64)
    l, u = lu_blocked(a, 16, use_kernels=True)
    l2, u2 = lu_blocked(a, 16, use_kernels=False)
    np.testing.assert_allclose(np.asarray(l), np.asarray(l2), atol=1e-9)
    np.testing.assert_allclose(np.asarray(u), np.asarray(u2), atol=1e-9)
