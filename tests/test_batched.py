"""Batch-first SPDC: batched cipher/decipher round-trips, batched N-server
pipeline (simulated + shard_map), per-matrix tamper detection inside a
batch, and the blocked panel factorization vs the unblocked oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    cipher, cipher_batch, decipher_batch, keygen, keygen_batch,
    lu_diag_factor, lu_nserver, lu_panel_blocked, lu_unblocked,
    outsource_determinant, seedgen, seedgen_batch,
)


def _wellcond_stack(B, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((B, n, n)) + n * np.eye(n)


# ------------------------------------------------------------ blocked panel
@pytest.mark.parametrize("b", [64, 96, 100, 128, 256])
def test_blocked_panel_matches_unblocked_oracle(b):
    """Acceptance: bitwise-tolerant agreement vs the unblocked oracle at
    rtol=1e-10 in f64 (the pipeline's per-round diagonal uses this path)."""
    rng = np.random.default_rng(b)
    a = jnp.asarray(rng.standard_normal((b, b)) + b * np.eye(b))
    l1, u1 = lu_unblocked(a)
    l2, u2 = lu_panel_blocked(a, inner=32)
    np.testing.assert_allclose(np.asarray(l2), np.asarray(l1),
                               rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(np.asarray(u2), np.asarray(u1),
                               rtol=1e-10, atol=1e-12)


def test_diag_factor_dispatch():
    """b >= 64 takes the blocked panel; small tiles stay unblocked — and
    both agree with the oracle."""
    rng = np.random.default_rng(0)
    for b in (16, 64, 128):
        a = jnp.asarray(rng.standard_normal((b, b)) + b * np.eye(b))
        l, u = lu_diag_factor(a)
        l1, u1 = lu_unblocked(a)
        np.testing.assert_allclose(np.asarray(l), np.asarray(l1), rtol=1e-10,
                                   atol=1e-12)
        np.testing.assert_allclose(np.asarray(u), np.asarray(u1), rtol=1e-10,
                                   atol=1e-12)


def test_blocked_panel_batched_equals_per_matrix():
    a = jnp.asarray(_wellcond_stack(4, 96, seed=3))
    lb, ub = lu_panel_blocked(a, inner=32)
    for i in range(4):
        li, ui = lu_panel_blocked(a[i], inner=32)
        np.testing.assert_allclose(np.asarray(lb[i]), np.asarray(li), atol=1e-12)
        np.testing.assert_allclose(np.asarray(ub[i]), np.asarray(ui), atol=1e-12)


# ------------------------------------------------- batched cipher/decipher
@pytest.mark.parametrize("mode", ["ewd", "ewm"])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_batched_cipher_equals_per_matrix_loop(mode, use_kernel):
    B, n = 6, 16
    m = jnp.asarray(_wellcond_stack(B, n, seed=7))
    seeds = seedgen_batch(128, np.asarray(m))
    vs = keygen_batch(128, seeds, n)
    xb, metas = cipher_batch(m, vs, seeds, mode=mode, use_kernel=use_kernel)
    for i in range(B):
        key_i = keygen(128, seeds[i], n)
        np.testing.assert_allclose(vs[i], key_i.v)
        x_i, meta_i = cipher(m[i], key_i, seeds[i], mode=mode)
        assert metas[i] == meta_i
        np.testing.assert_allclose(np.asarray(xb[i]), np.asarray(x_i),
                                   rtol=1e-12)


def test_batched_seedgen_independent_per_matrix():
    m = _wellcond_stack(4, 8, seed=1)
    seeds = seedgen_batch(128, m)
    assert len({s.psi for s in seeds}) == 4  # distinct stats → distinct Ψ
    for i, s in enumerate(seeds):
        assert s.psi == seedgen(128, m[i]).psi


@pytest.mark.parametrize("mode", ["ewd", "ewm"])
def test_batched_decipher_roundtrip_equals_loop(mode):
    """Cipher→LU→Decipher over a stack == the same per matrix."""
    B, n, N = 5, 24, 4
    m = jnp.asarray(_wellcond_stack(B, n, seed=11))
    seeds = seedgen_batch(128, np.asarray(m))
    vs = keygen_batch(128, seeds, n)
    xb, metas = cipher_batch(m, vs, seeds, mode=mode)
    l, u, _ = lu_nserver(xb, N)
    dets = decipher_batch(seeds, metas, l, u)
    for i in range(B):
        want_s, want_la = np.linalg.slogdet(np.asarray(m[i]))
        assert dets[i].sign == want_s
        np.testing.assert_allclose(dets[i].logabs, want_la, rtol=1e-8)


# ------------------------------------------------------- batched pipeline
@pytest.mark.parametrize("program", ["baseline", "exact", "stream"])
def test_batched_shardmap_lu_reconstruction(program):
    """Batched pipeline L·U must reconstruct every matrix in the stack."""
    from repro.distrib.spdc_pipeline import lu_nserver_shardmap

    B, n, N = 4, 32, 4
    x = jnp.asarray(_wellcond_stack(B, n, seed=N))
    l, u = lu_nserver_shardmap(x, N, program=program)
    assert l.shape == (B, n, n) and u.shape == (B, n, n)
    np.testing.assert_allclose(np.asarray(l @ u), np.asarray(x), atol=1e-9)
    l2, u2, _ = lu_nserver(x, N)
    np.testing.assert_allclose(np.asarray(l), np.asarray(l2), atol=1e-9)
    np.testing.assert_allclose(np.asarray(u), np.asarray(u2), atol=1e-9)


# ----------------------------------------------------- batched end-to-end
@pytest.mark.parametrize("distributed", [False, True])
def test_batched_protocol_roundtrip(distributed):
    B, n, N = 5, 21, 3  # odd n → augmentation inside the batch
    m = _wellcond_stack(B, n, seed=2)
    res = outsource_determinant(m, N, distributed=distributed)
    assert res.batch == B
    assert res.verified.shape == (B,) and res.verified.all()
    for i in range(B):
        want_s, want_la = np.linalg.slogdet(m[i])
        assert res.dets[i].sign == want_s
        np.testing.assert_allclose(res.dets[i].logabs, want_la, rtol=1e-8)


def test_batched_protocol_equals_single_calls():
    B, n, N = 4, 16, 4
    m = _wellcond_stack(B, n, seed=5)
    res = outsource_determinant(m, N)
    for i in range(B):
        single = outsource_determinant(m[i], N)
        assert single.det.sign == res.dets[i].sign
        np.testing.assert_allclose(single.det.logabs, res.dets[i].logabs,
                                   rtol=1e-9)


@pytest.mark.parametrize("method", ["q2", "q3"])
def test_batched_verify_flags_single_tampered_matrix(method):
    """A malicious server corrupting ONE matrix of the stack must flip only
    that matrix's verdict (per-matrix Q2/Q3, never averaged)."""
    B, n, N = 6, 16, 4
    m = _wellcond_stack(B, n, seed=9)
    bad_idx = 3
    res = outsource_determinant(
        m, N, method=method,
        tamper=lambda l, u: (l, u.at[bad_idx, 5, 5].multiply(1.01)),
    )
    assert not res.verified[bad_idx]
    ok = np.ones(B, dtype=bool)
    ok[bad_idx] = False
    assert (res.verified == ok).all(), res.residual


def test_batched_q1_also_flags_tampered_matrix():
    B, n, N = 4, 16, 2
    m = _wellcond_stack(B, n, seed=13)
    res = outsource_determinant(
        m, N, method="q1",
        tamper=lambda l, u: (l.at[1, 9, 2].add(0.05), u),
    )
    assert not res.verified[1]
    assert res.verified[[0, 2, 3]].all()
