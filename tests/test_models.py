"""Per-arch smoke tests (reduced configs, one forward/train step on CPU,
shape + finiteness assertions — as the assignment requires) plus layer-level
unit tests for attention variants, MoE, and SSD.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS, runnable_cells, smoke_config
from repro.models.common import split_tree
from repro.models.lm import forward_hidden, init_lm, lm_loss

ARCHS = list(CONFIGS)

#: architectures whose smoke configs take tens of seconds per jitted
#: train step on CPU — their train/grad-accum legs run in the slow tier
#: (pytest -m slow); every arch keeps its forward-shape test in tier-1
_HEAVY_ARCHS = {
    "jamba-1.5-large-398b", "gemma3-1b", "llama4-scout-17b-a16e",
    "gemma-2b", "qwen2-vl-72b", "nemotron-4-340b",
}
TRAIN_ARCHS = [
    pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_ARCHS else a
    for a in ARCHS
]


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    lab = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), dtype=jnp.int32)
    if cfg.frontend is None:
        return {"tokens": lab, "labels": lab}
    emb = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), dtype=jnp.float32)
    return {"embeds": emb, "labels": lab}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    params, _ = split_tree(init_lm(cfg, jax.random.key(0)))
    batch = _batch(cfg)
    hidden, _ = jax.jit(lambda p, b: forward_hidden(p, b, cfg))(params, batch)
    assert hidden.shape == (2, 32, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden)))


@pytest.mark.parametrize("arch", TRAIN_ARCHS)
def test_smoke_train_step(arch):
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.steps import build_train_step

    cfg = smoke_config(arch)
    params, _ = split_tree(init_lm(cfg, jax.random.key(0)))
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(build_train_step(cfg, opt_cfg))
    p1, o1, m1 = step(params, opt, _batch(cfg, seed=1), jax.random.key(1))
    assert np.isfinite(float(m1["loss"]))
    # params changed
    d0 = jax.tree.leaves(params)[0]
    d1 = jax.tree.leaves(p1)[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))
    # loss decreases over a few steps on repeated batch (sanity learnable)
    batch = _batch(cfg, seed=2)
    p, o = params, opt
    losses = []
    for i in range(5):
        p, o, m = step(p, o, batch, jax.random.key(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("arch", TRAIN_ARCHS)
def test_grad_accum_equivalence(arch):
    """grad_accum=2 must match accum=1 on the same global batch (up to
    accumulation-dtype rounding)."""
    from dataclasses import replace

    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.steps import build_train_step

    cfg1 = smoke_config(arch)
    if cfg1.num_experts:
        # capacity dropping is batch-composition-dependent; disable drops so
        # microbatched routing matches full-batch routing exactly
        cfg1 = replace(cfg1, moe_capacity_factor=8.0)
    cfg2 = replace(cfg1, grad_accum=2)
    params, _ = split_tree(init_lm(cfg1, jax.random.key(0)))
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = init_opt_state(params, opt_cfg)
    batch = _batch(cfg1, b=4, s=16, seed=3)
    key = jax.random.key(0)
    p1, _, m1 = jax.jit(build_train_step(cfg1, opt_cfg))(params, opt, batch, key)
    p2, _, m2 = jax.jit(build_train_step(cfg2, opt_cfg))(params, opt, batch, key)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=2e-5)
    for a, b_ in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-4)


def test_local_attention_exactness():
    """Sliding/chunked local path == masked full attention, at several
    window/seq combinations (incl. non-dividing)."""
    from repro.models.attention import _blockwise, _local

    rng = np.random.default_rng(0)
    for s, w, kind in [(64, 16, "sliding"), (48, 16, "sliding"),
                       (64, 16, "chunked"), (40, 16, "chunked")]:
        q = jnp.asarray(rng.standard_normal((2, s, 4, 8)), dtype=jnp.float32)
        k = jnp.asarray(rng.standard_normal((2, s, 4, 8)), dtype=jnp.float32)
        v = jnp.asarray(rng.standard_normal((2, s, 4, 8)), dtype=jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(s)[None], (2, s))
        got = _local(q, k, v, pos, kind=kind, window=w, scale=0.35)
        want = _blockwise(
            q, k, v, pos, jnp.arange(s), causal=True,
            window=w if kind == "sliding" else None,
            chunk=w if kind == "chunked" else None, scale=0.35, block=10**9,
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, err_msg=f"{kind} s={s} w={w}")


def test_blockwise_attention_matches_reference():
    from repro.kernels.ref import flash_attention_ref
    from repro.models.attention import _blockwise

    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((2, 48, 4, 8)), dtype=jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 48, 4, 8)), dtype=jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 48, 4, 8)), dtype=jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(48)[None], (2, 48))
    got = _blockwise(q, k, v, pos, jnp.arange(48), causal=True, window=None,
                     chunk=None, scale=8**-0.5, block=16)
    want = flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


def test_moe_dispatch_vs_dense_high_capacity():
    """With capacity high enough to never drop, dispatch == dense."""
    from dataclasses import replace

    from repro.models.moe import apply_moe, init_moe
    from repro.models.common import Initializer

    cfg = replace(smoke_config("granite-moe-1b-a400m"),
                  moe_capacity_factor=8.0, moe_group=64)
    ini = Initializer(jax.random.key(0), dtype=jnp.float32)
    p, _ = split_tree(init_moe(ini, cfg))
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 32, cfg.d_model)),
                    dtype=jnp.float32)
    y_disp = apply_moe(p, x, replace(cfg, moe_impl="dispatch"))
    y_dense = apply_moe(p, x, replace(cfg, moe_impl="dense"))
    np.testing.assert_allclose(np.asarray(y_disp), np.asarray(y_dense), atol=1e-4)


def test_ssd_chunked_matches_sequential():
    """Chunked SSD == naive sequential recurrence."""
    from repro.models.ssm import _ssd_chunked

    rng = np.random.default_rng(2)
    b, s, h, p_, n = 2, 24, 3, 4, 8
    xd = jnp.asarray(rng.standard_normal((b, s, h, p_)), dtype=jnp.float32)
    la = jnp.asarray(-np.abs(rng.standard_normal((b, s, h))) * 0.1,
                     dtype=jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, n)), dtype=jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, n)), dtype=jnp.float32)
    got, state = _ssd_chunked(xd, la, B, C, chunk=8)
    # naive recurrence
    want = np.zeros((b, s, h, p_), dtype=np.float64)
    st = np.zeros((b, h, n, p_), dtype=np.float64)
    for t in range(s):
        al = np.exp(np.asarray(la[:, t], dtype=np.float64))  # (b,h)
        st = st * al[:, :, None, None] + np.einsum(
            "bn,bhp->bhnp", np.asarray(B[:, t], np.float64),
            np.asarray(xd[:, t], np.float64),
        )
        want[:, t] = np.einsum("bn,bhnp->bhp", np.asarray(C[:, t], np.float64), st)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state), st, atol=1e-4)


def test_mrope_sections_and_equivalence():
    """Text-only M-RoPE (equal position streams) == plain RoPE."""
    from repro.models.common import apply_mrope, apply_rope, mrope_sections

    assert mrope_sections(128) == (16, 24, 24)  # Qwen2-VL's exact split
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 8, 4, 32)), dtype=jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    pos3 = jnp.repeat(pos[..., None], 3, axis=-1)
    np.testing.assert_allclose(
        np.asarray(apply_mrope(x, pos3)), np.asarray(apply_rope(x, pos)),
        atol=1e-6,
    )


def test_runnable_cells_match_design():
    """The 33-of-40 cell grid from DESIGN.md §4."""
    total = sum(len(runnable_cells(CONFIGS[a])) for a in CONFIGS)
    assert total == 33
    assert runnable_cells(CONFIGS["hubert-xlarge"]) == ["train_4k", "prefill_32k"]
    assert "long_500k" in runnable_cells(CONFIGS["mamba2-370m"])
    assert "long_500k" in runnable_cells(CONFIGS["jamba-1.5-large-398b"])
    assert "long_500k" not in runnable_cells(CONFIGS["gemma-2b"])


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-370m",
                                  "gemma3-1b", "granite-moe-1b-a400m"])
def test_decode_matches_parallel_forward(arch):
    """Sequential decode over caches == parallel forward (dense MoE to
    exclude capacity-drop differences)."""
    from dataclasses import replace as rep

    from repro.serve.kvcache import init_caches
    from repro.serve.steps import build_decode_step, build_prefill_step

    cfg = rep(smoke_config(arch), moe_impl="dense")
    params, _ = split_tree(init_lm(cfg, jax.random.key(1)))
    B, S = 2, 24
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), dtype=jnp.int32)
    ref = jax.jit(build_prefill_step(cfg))(params, {"tokens": toks})
    decode = jax.jit(build_decode_step(cfg))
    caches = init_caches(cfg, B, S)
    for t in range(S):
        logits, caches = decode(params, caches, {"tokens": toks[:, t:t+1]},
                                jnp.full((B,), t, jnp.int32))
    mask = np.arange(logits.shape[-1]) < cfg.vocab_size
    err = np.max(np.abs(np.asarray(logits - ref))[:, mask])
    assert err < 2e-3, err


def test_moe_active_params_accounting():
    """moe_active_params counts only per-token ACTIVE expert weights: it
    scales with experts_per_token, not with the expert pool size."""
    from dataclasses import replace

    from repro.models.moe import moe_active_params

    cfg = smoke_config("granite-moe-1b-a400m")
    base = moe_active_params(cfg)
    assert base > 0
    # doubling the routed-expert count doubles the active matmul cost
    # (router cost unchanged), while growing the POOL only adds router rows
    doubled = moe_active_params(
        replace(cfg, experts_per_token=2 * cfg.experts_per_token)
    )
    assert doubled == base + 3 * cfg.d_model * cfg.d_ff * cfg.experts_per_token
    pool = moe_active_params(replace(cfg, num_experts=2 * cfg.num_experts))
    assert pool - base == cfg.d_model * cfg.num_experts


def test_cache_bytes_matches_materialized_caches():
    """cache_bytes (an eval_shape estimate — no allocation) must agree
    exactly with the bytes of actually materialized decode caches."""
    from repro.serve.kvcache import cache_bytes, init_caches

    cfg = smoke_config("tinyllama-1.1b")
    B, S = 2, 16
    est = cache_bytes(cfg, B, S)
    real = sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves(init_caches(cfg, B, S))
    )
    assert est == real > 0
