"""Verification-driven recovery: localize → re-dispatch one shard → splice.

Includes the acceptance end-to-end: with N=4 servers and ANY single server
tampering or dropping out, the recovery scheduler localizes the fault,
re-dispatches only that shard, and the final determinant passes Q2 AND Q3
and matches the honest-run value at rtol=1e-10 (f64) — for single matrices
and (B, n, n) batches.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    ServerFault, augment_for_servers, authenticate, lu_block_row, lu_nserver,
    outsource_determinant,
)
from repro.distrib.recovery import (
    RecoveryReport, ServerPool, dispatch_subseed, recover_lu,
    recovery_comm_elements, rederive_shard,
)

N = 4


def _wellcond(n, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    if batch is None:
        return rng.standard_normal((n, n)) + n * np.eye(n)
    return rng.standard_normal((batch, n, n)) + n * np.eye(n)


SINGLE_SERVER_FAULTS = [
    ServerFault(server=s, kind=kind, mode=mode, target=target)
    for s in range(N)
    for kind, mode, target in [
        ("tamper", "single", "u"),
        ("tamper", "sign_flip", "l"),
        ("tamper", "block", "lu"),
        ("dropout", "single", "u"),
    ]
]


# ------------------------------------------------------------- acceptance
@pytest.mark.parametrize(
    "fault", SINGLE_SERVER_FAULTS,
    ids=[f"s{f.server}-{f.kind}-{f.mode}-{f.target}"
         for f in SINGLE_SERVER_FAULTS],
)
def test_recovery_end_to_end_single_matrix(fault):
    """Acceptance: any single server tampering/dropping out → localized,
    ONE shard re-dispatched, Q2+Q3 pass, det == honest at rtol 1e-10."""
    n = 32
    m = _wellcond(n, seed=fault.server + 7)
    honest = outsource_determinant(m, N)
    res = outsource_determinant(m, N, faults=fault, recover=True, standby=1)

    assert res.verified
    rep = res.report.recovery
    assert isinstance(rep, RecoveryReport) and rep.ok
    # report-level fault: exactly one round, only the culprit's shard moved
    assert rep.rounds == 1
    assert rep.servers_replaced == (fault.server,)
    assert rep.standby_used == 1
    assert rep.events[0].replacement == N  # the provisioned standby

    # the HEALED factors pass BOTH Q2 and Q3 (not just the protocol's
    # configured method) — exercised on the raw recovery scheduler
    x_aug, _ = _reconstruct_ciphertext(res, m)
    lf, uf, _ = lu_nserver(x_aug, N, faults=(fault,))
    l2, u2, _, rep2 = recover_lu(lf, uf, x_aug, num_servers=N, standby=1)
    assert rep2.ok
    for method in ("q2", "q3"):
        v = authenticate(l2, u2, x_aug, num_servers=N, method=method)
        assert v.ok, (method, v.residual)

    assert res.report.verdict.ok and res.report.verdict.method == "q3"
    assert res.det.sign == honest.det.sign
    np.testing.assert_allclose(res.det.logabs, honest.det.logabs, rtol=1e-10)
    want_s, want_la = np.linalg.slogdet(m)
    assert res.det.sign == want_s
    np.testing.assert_allclose(res.det.logabs, want_la, rtol=1e-10)


def _reconstruct_ciphertext(res, m):
    """Replay the client's PMOP to rebuild x_aug for out-of-band checks."""
    from repro.core import augment, cipher, keygen

    key = keygen(128, res.seed, m.shape[-1])
    x, _ = cipher(jnp.asarray(m, dtype=jnp.float64), key, res.seed)
    aug_key = jax.random.key(
        int.from_bytes(res.seed.digest[8:16], "big") % (2**31)
    )
    return augment(x, res.padding, key=aug_key), key


@pytest.mark.parametrize("kind", ["tamper", "dropout"])
def test_recovery_end_to_end_batched(kind):
    """Acceptance (batch leg): per-matrix faults across different servers
    all heal in one pass; every det matches honest at rtol 1e-10."""
    B, n = 5, 32
    m = _wellcond(n, seed=11, batch=B)
    honest = outsource_determinant(m, N)
    plan = (
        ServerFault(server=1, kind=kind, matrices=(0,)),
        ServerFault(server=3, kind=kind, matrices=(2, 4)),
    )
    res = outsource_determinant(m, N, faults=plan, recover=True, standby=2)
    assert res.verified.all()
    assert res.report.recovery.ok
    assert res.report.recovery.servers_replaced == (1, 3)
    spliced = {e.server: e.matrices for e in res.report.recovery.events}
    assert spliced[1] == (0,) and spliced[3] == (2, 4)
    # the healed batch passes Q2 as well as the default Q3
    res_q2 = outsource_determinant(
        m, N, method="q2", faults=plan, recover=True, standby=2
    )
    assert res_q2.verified.all() and res_q2.report.recovery.ok
    for i in range(B):
        assert res.dets[i].sign == honest.dets[i].sign
        np.testing.assert_allclose(
            res.dets[i].logabs, honest.dets[i].logabs, rtol=1e-10
        )


def test_recovery_distributed_pipeline():
    """Faults injected on the shard_map pipeline heal the same way.

    The first re-dispatch must target the genuinely faulty server; the
    loop may then heal a downstream row whose splice-induced rounding
    grazes ε(N) (a replacement server cannot be bitwise-identical to the
    jitted pipeline), but it must converge within the round budget.
    """
    n = 32
    m = _wellcond(n, seed=13)
    honest = outsource_determinant(m, N)
    res = outsource_determinant(
        m, N, distributed=True,
        faults=ServerFault(server=2, kind="dropout"),
        recover=True, standby=1,
    )
    assert res.verified and res.report.recovery.ok
    assert res.report.recovery.events[0].server == 2
    assert res.report.recovery.rounds <= N
    np.testing.assert_allclose(res.det.logabs, honest.det.logabs, rtol=1e-10)


def test_recovery_in_band_cascade():
    """Relay poisoning: the tampered U row was consumed downstream, so the
    scheduler heals one block row per round — and still converges to the
    honest determinant."""
    n = 32
    m = _wellcond(n, seed=17)
    honest = outsource_determinant(m, N)
    fault = ServerFault(server=1, in_band=True, mode="block", magnitude=0.3)
    res = outsource_determinant(m, N, faults=fault, recover=True, standby=N)
    assert res.verified and res.report.recovery.ok
    assert res.report.recovery.rounds >= 2  # genuinely cascaded
    assert res.report.recovery.rounds <= N
    assert 1 in res.report.recovery.servers_replaced
    np.testing.assert_allclose(res.det.logabs, honest.det.logabs, rtol=1e-10)


def test_recovery_straggler_redispatch():
    """A server slower than the deadline is treated as dropped and its
    shard re-dispatched; within the deadline the client just waits."""
    n = 32
    m = _wellcond(n, seed=19)
    fault = ServerFault(server=2, kind="delay", delay_rounds=6)
    late = outsource_determinant(
        m, N, faults=fault, straggler_deadline=3, recover=True, standby=1
    )
    assert late.verified and late.report.recovery.servers_replaced == (2,)
    ontime = outsource_determinant(m, N, faults=fault, straggler_deadline=10)
    assert ontime.verified and ontime.report.recovery is None


def test_recovery_without_standby_uses_healthy_neighbor():
    n = 32
    m = _wellcond(n, seed=23)
    res = outsource_determinant(
        m, N, faults=ServerFault(server=1), recover=True, standby=0
    )
    assert res.verified
    assert res.report.recovery.standby_used == 0
    assert res.report.recovery.events[0].replacement == 2  # culprit's neighbor


def test_recovery_cost_is_one_shard_not_full_restart():
    """The wire cost of every recovery event is << one full re-outsource
    (n² ciphertext resend) — the 'one extra hop' property."""
    n = 64
    m = _wellcond(n, seed=29)
    res = outsource_determinant(
        m, N, faults=ServerFault(server=0), recover=True, standby=1
    )
    full_restart = n * n
    for e in res.report.recovery.events:
        assert e.comm_elements < full_restart
    assert recovery_comm_elements(n, N, 0) == 3 * (n // N) * n


# ------------------------------------------------------------- unit pieces
def test_lu_block_row_matches_honest_rows():
    n = 24
    a = jnp.asarray(_wellcond(n, seed=31))
    l, u, _ = lu_nserver(a, N)
    b = n // N
    for s in range(N):
        lr, ur = lu_block_row(a, u, s, N)
        np.testing.assert_allclose(
            np.asarray(lr), np.asarray(l[s * b : (s + 1) * b]), atol=1e-10
        )
        np.testing.assert_allclose(
            np.asarray(ur), np.asarray(u[s * b : (s + 1) * b]), atol=1e-10
        )


def test_lu_block_row_ignores_corrupted_own_and_downstream_rows():
    """The recompute must be a function of x and the rows ABOVE only."""
    n = 24
    a = jnp.asarray(_wellcond(n, seed=37))
    l, u, _ = lu_nserver(a, N)
    b = n // N
    u_bad = u.at[2 * b :, :].set(999.0)  # garbage at and below server 2
    lr, ur = lu_block_row(a, u_bad, 2, N)
    np.testing.assert_allclose(
        np.asarray(ur), np.asarray(u[2 * b : 3 * b]), atol=1e-10
    )


def test_recover_lu_direct_api():
    n = 24
    a = jnp.asarray(_wellcond(n, seed=41))
    l, u, _ = lu_nserver(
        a, N, faults=(ServerFault(server=3, kind="dropout"),)
    )
    l2, u2, verdict, report = recover_lu(
        l, u, a, num_servers=N, standby=1, digest=b"t"
    )
    assert verdict.ok and report.ok and report.servers_replaced == (3,)
    np.testing.assert_allclose(np.asarray(l2 @ u2), np.asarray(a), atol=1e-8)


def test_server_pool_standby_then_neighbor():
    pool = ServerPool(num_servers=4, standby=2)
    p1, pool = pool.replacement_for(1)
    assert p1 == 4
    p2, pool = pool.replacement_for(2)
    assert p2 == 5 and pool.spares_used == 2
    p3, pool = pool.replacement_for(3)  # spares exhausted → healthy neighbor
    assert p3 == 0
    assert pool.retired == (1, 2, 3)


def test_server_pool_standby_exhaustion_batched():
    """Batched sweep with MORE culprits than spares: the pool hands out
    both standbys, then falls back to healthy neighbors — every matrix
    still heals to the honest determinant, and every re-dispatch carries
    a fresh sub-seed."""
    B, n = 4, 32
    m = _wellcond(n, seed=61, batch=B)
    honest = outsource_determinant(m, N)
    plan = (
        ServerFault(server=0, kind="tamper", matrices=(0,)),
        ServerFault(server=1, kind="dropout", matrices=(1,)),
        ServerFault(server=2, kind="tamper", mode="sign_flip",
                    matrices=(2,)),
        ServerFault(server=3, kind="dropout", matrices=(3,)),
    )
    res = outsource_determinant(m, N, faults=plan, recover=True, standby=2)
    assert np.asarray(res.verified).all()
    rep = res.report.recovery
    assert rep.ok and rep.standby_used == 2  # spares genuinely exhausted
    assert rep.servers_replaced == (0, 1, 2, 3)
    repl = [e.replacement for e in rep.events]
    assert repl[:2] == [N, N + 1]  # the provisioned standbys, in order
    assert all(r < N for r in repl[2:])  # then healthy-neighbor fallback
    for e in rep.events:
        assert e.replacement != e.server
    subseeds = [e.subseed for e in rep.events]
    assert len(set(subseeds)) == len(subseeds)
    for i in range(B):
        assert res.dets[i].sign == honest.dets[i].sign
        np.testing.assert_allclose(
            res.dets[i].logabs, honest.dets[i].logabs, rtol=1e-10
        )


def test_standby_exhaustion_cascade_fresh_subseed_per_attempt():
    """An in-band cascade with ONE spare: after the spare is spent the
    remaining rounds ride neighbors, and the sub-seed is fresh on every
    event — re-dispatches of different rounds never share a channel key."""
    n = 32
    m = _wellcond(n, seed=67)
    honest = outsource_determinant(m, N)
    fault = ServerFault(server=1, in_band=True, mode="block", magnitude=0.3)
    res = outsource_determinant(m, N, faults=fault, recover=True, standby=1)
    assert res.verified and res.report.recovery.ok
    assert res.report.recovery.rounds >= 2  # genuinely cascaded past the spare
    assert res.report.recovery.standby_used == 1
    repl = [e.replacement for e in res.report.recovery.events]
    assert repl[0] == N and any(r < N for r in repl[1:])
    subseeds = [e.subseed for e in res.report.recovery.events]
    assert len(set(subseeds)) == len(subseeds)
    np.testing.assert_allclose(res.det.logabs, honest.det.logabs, rtol=1e-10)


def test_dispatch_subseed_is_fresh_per_attempt():
    d = b"\x01" * 32
    s1 = dispatch_subseed(d, 2, 1)
    s2 = dispatch_subseed(d, 2, 2)
    s3 = dispatch_subseed(d, 3, 1)
    assert len({s1, s2, s3}) == 3


def test_rederive_shard_matches_full_augmentation():
    rng = np.random.default_rng(43)
    x = jnp.asarray(rng.standard_normal((10, 10)))
    key = jax.random.key(5)
    x_aug, p = augment_for_servers(x, N, key=key)
    b = x_aug.shape[-1] // N
    for s in range(N):
        shard = rederive_shard(x, padding=p, server=s, num_servers=N,
                               aug_key=key)
        np.testing.assert_array_equal(
            np.asarray(shard), np.asarray(x_aug[s * b : (s + 1) * b])
        )


def test_hardened_config_profile_drives_recovery():
    """SPDC_EDGE_HARDENED's standby/recover/straggler fields map onto the
    protocol signature (protocol_kwargs keeps them from drifting)."""
    from repro.configs import SPDC_EDGE_HARDENED as cfg

    assert cfg.recover and cfg.standby == 2
    m = _wellcond(32, seed=53)
    res = outsource_determinant(
        m, N, faults=ServerFault(server=1), **cfg.protocol_kwargs()
    )
    assert res.verified and res.report.recovery.ok
    assert res.report.recovery.events[0].replacement == N  # healed on a standby


def test_server_pool_never_returns_culprit_when_avoidable():
    """Spares and fresh neighbors exhausted → a retired-but-healed server
    gets the shard, never the culprit itself (N=2 worst case)."""
    pool = ServerPool(num_servers=2, standby=0)
    p0, pool = pool.replacement_for(0)
    assert p0 == 1
    p1, pool = pool.replacement_for(1)
    assert p1 == 0  # retired-but-healed, NOT the culprit


def test_recover_lu_stops_once_verdict_accepts():
    """Matrices whose verdict already passes are never re-dispatched: a
    clean factorization with a pre-computed verdict exits in zero rounds."""
    n = 24
    a = jnp.asarray(_wellcond(n, seed=59))
    l, u, _ = lu_nserver(a, N)
    v0 = authenticate(l, u, a, num_servers=N)
    l2, u2, v, rep = recover_lu(
        l, u, a, num_servers=N, standby=1, verdict=v0
    )
    assert rep.ok and rep.rounds == 0 and rep.events == []
    assert l2 is l and u2 is u


def test_unrecoverable_without_recover_flag():
    """Default behavior unchanged: no recover → rejected verdict stands."""
    n = 24
    m = _wellcond(n, seed=47)
    res = outsource_determinant(m, N, faults=ServerFault(server=1))
    assert not res.verified
    assert res.report.recovery is None
    assert res.report.verdict.culprit == 1
