"""Overload & chaos tier for the hardened gateway (DESIGN.md §10).

Everything here is deterministic: arrivals come from seeded Poisson
processes mapped onto the injected virtual clock, breaker probe timing
uses zero (or seeded) jitter, and chaos is injected through the gateway's
``faults_for`` hook — so the sharp assertions (p99 bounds, exact rejection
counts, breaker transition times) reproduce bit-for-bit on every run.

Covered: open-loop overload at 8× the admitted rate (bounded p99 for
admitted requests, 100% of them verified, every shed request a typed
counted rejection, post-storm gauges back to zero), rejection storms never
leaving half-enqueued state (sync and async — no leaked futures), the
breaker opening on a poisoned bucket and recovering through a half-open
probe while co-resident buckets keep serving, idempotency-cache
correctness under identical + tampered + cross-tenant submissions,
single-flight coalescing, the (n, dtype) dummy-cache regression, and a
property test pitting random interleavings against the sequential
direct-call oracle.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import (
    AdmissionConfig,
    BreakerConfig,
    CacheConfig,
    SPDCConfig,
    SPDCGatewayConfig,
)
from repro.core import ServerFault, outsource_determinant
from repro.serve import (
    AdmissionRejected,
    BreakerOpen,
    GatewayOverloaded,
    SPDCGateway,
)
from repro.serve.spdc_gateway import _DUMMY_CACHE_MAX


@pytest.fixture(scope="module", autouse=True)
def _drop_compiled_sweeps():
    # This module compiles sweep shapes (small buckets, f32 variants,
    # direct-path programs) no other module reuses; the executables stay
    # alive in jax's global jit cache for the rest of the pytest process
    # otherwise, and the accumulated XLA state pushes later large
    # compilations (tests/test_system.py) into a jaxlib 0.4.x CPU
    # compiler segfault. Dropping them restores the pre-module cache
    # profile; downstream modules recompile what they actually use.
    yield
    jax.clear_caches()


def _mat(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n)) + n * np.eye(n)


def _cfg(**kw):
    kw.setdefault("buckets", (8, 16))
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_wait_us", 1000.0)
    kw.setdefault("spdc", SPDCConfig(num_servers=2))
    return SPDCGatewayConfig(name="test-gw", **kw)


def _nojitter(**kw):
    kw.setdefault("probe_jitter", 0.0)
    return BreakerConfig(**kw)


class VirtualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _quantile(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))]


# ------------------------------------------------ open-loop overload (8×)


def test_overload_8x_bounded_p99_and_zero_loss():
    """Open-loop Poisson arrivals at 8× the admitted rate: every admitted
    request completes verified with bounded (virtual) p99 latency, every
    shed request is a TYPED, counted rejection, and after the storm every
    gauge — queue, tenant slots, single-flight table — is back to zero."""
    admit_rate = 50.0  # tokens/s
    cfg = _cfg(
        buckets=(8,), max_batch=4, max_wait_us=5000.0, max_pending=16,
        admission=AdmissionConfig(rate_per_sec=admit_rate, burst=5.0),
        breaker=_nojitter(),
    )
    clock = VirtualClock()
    gw = SPDCGateway(cfg, clock=clock)
    rng = np.random.default_rng(42)
    n_arrivals = 300
    offered = 8 * admit_rate
    admitted, rejections = [], {"rate": 0, "overload": 0}
    for i in range(n_arrivals):
        clock.t += rng.exponential(1.0 / offered)
        gw.poll()
        try:
            admitted.append(gw.submit(_mat(4 + i % 5, seed=1000 + i)))
        except AdmissionRejected as e:
            assert e.reason in ("rate", "quota")
            rejections["rate"] += 1
        except GatewayOverloaded:
            rejections["overload"] += 1
    # drain the tail through the normal timeout path, not drain(): flush
    # reasons and latencies stay exactly what a live gateway would see
    for _ in range(100):
        if not gw.pending:
            break
        clock.t += 1e-3
        gw.poll()
    assert gw.pending == 0

    results = [gw.take(r) for r in admitted]
    assert all(r is not None for r in results)  # zero lost requests
    assert all(r.verified and r.error is None for r in results)
    lat = [r.latency_s for r in results]
    # sharp bound: worst admitted wait is the timeout budget (5ms) plus
    # the largest arrival gap until the next poll (the exponential tail
    # reaches ~13ms under this seed) — deterministic, so 20ms is tight
    assert _quantile(lat, 0.99) <= 0.020
    # the storm actually shed: ~7/8 of offered load rejected, all typed
    assert rejections["rate"] + rejections["overload"] == n_arrivals - len(admitted)
    assert rejections["rate"] > n_arrivals // 2
    assert gw.stats.rejected_admission == rejections["rate"]
    assert gw.stats.rejected == rejections["overload"]
    assert gw.stats.served == len(admitted)

    # post-storm: every gauge back to zero, nothing half-enqueued
    snap = gw.metrics_snapshot()
    assert snap.pending == 0
    assert all(b["depth"] == 0 for b in snap.buckets.values())
    assert snap.tenants["default"]["pending"] == 0
    assert gw._admission.total_pending == 0
    assert gw._inflight == {}
    assert snap.counters["admitted"] == len(admitted)
    assert snap.counters["served"] == len(admitted)
    assert snap.counters["rejected_rate"] == rejections["rate"]
    assert snap.counters["rejected_overload"] == rejections["overload"]
    assert gw.healthz()["status"] == "ok"


def test_overload_per_tenant_isolation():
    """A greedy tenant burning 10× its rate collects rejections; a polite
    tenant submitting under ITS rate is never shed — admission is per
    tenant, not per gateway."""
    cfg = _cfg(
        buckets=(8,), max_wait_us=1e9,
        admission=AdmissionConfig(rate_per_sec=20.0, burst=2.0),
    )
    clock = VirtualClock()
    gw = SPDCGateway(cfg, clock=clock, auto_flush=False)
    polite_rejects = greedy_rejects = 0
    seed = 0
    for step in range(200):  # 1 virtual second
        clock.t = step * 5e-3
        seed += 1
        try:  # greedy: every 5ms = 200/s against a 20/s budget
            gw.submit(_mat(4, seed=seed), tenant="greedy")
        except AdmissionRejected as e:
            assert e.tenant == "greedy"
            greedy_rejects += 1
        if step % 10 == 0:  # polite: 20/s exactly at budget
            seed += 1
            try:
                gw.submit(_mat(5, seed=seed), tenant="polite")
            except AdmissionRejected:
                polite_rejects += 1
        gw.poll()
    gw.drain()
    assert polite_rejects == 0
    assert greedy_rejects > 100
    snap = gw.metrics_snapshot()
    assert snap.tenants["polite"]["rejected_rate"] == 0
    assert snap.tenants["greedy"]["rejected_rate"] == greedy_rejects


def test_rejection_storm_leaves_no_half_enqueued_state():
    """Satellite: every rejection path (rate, quota, overload, breaker)
    unwinds completely — submitted/pending/slot counters return to their
    pre-storm values and later service is unaffected."""
    cfg = _cfg(
        buckets=(8,), max_batch=2, max_wait_us=1e9, max_pending=2,
        admission=AdmissionConfig(rate_per_sec=1000.0, burst=1000.0,
                                  max_pending_per_tenant=1),
        breaker=_nojitter(),
    )
    clock = VirtualClock()
    gw = SPDCGateway(cfg, clock=clock, auto_flush=False)
    r0 = gw.submit(_mat(4, seed=1), tenant="a")  # a's quota now full
    for i in range(20):  # quota storm
        with pytest.raises(AdmissionRejected) as ei:
            gw.submit(_mat(4, seed=100 + i), tenant="a")
        assert ei.value.reason == "quota"
    r1 = gw.submit(_mat(4, seed=2), tenant="b")  # gateway-wide cap now full
    for i in range(20):  # overload storm
        with pytest.raises(GatewayOverloaded):
            gw.submit(_mat(4, seed=200 + i), tenant="c")
    assert gw.pending == 2
    assert gw._admission.pending_by_tenant() == {"a": 1, "b": 1}
    assert gw.stats.submitted == 2  # storms never half-counted
    assert gw.stats.rejected_admission == 20 and gw.stats.rejected == 20
    gw.drain()
    for rid, tenant in ((r0, "a"), (r1, "b")):
        res = gw.take(rid)
        assert res.verified and res.tenant == tenant
    assert gw.pending == 0 and gw._admission.total_pending == 0
    # the tenants whose storms were shed are not poisoned for later work
    assert gw.take(gw.submit(_mat(4, seed=300), tenant="a")) is None
    gw.drain()
    assert gw.stats.served == 3


def test_async_rejection_storm_leaks_no_futures():
    """Typed rejections propagate out of async submit() BEFORE a waiter
    future exists — a storm of them cannot strand the event loop."""
    import asyncio

    from repro.serve import AsyncSPDCGateway

    cfg = _cfg(
        buckets=(8,), max_batch=4, max_wait_us=2000.0, max_pending=4,
        admission=AdmissionConfig(max_pending_per_tenant=2),
    )

    async def main():
        async with AsyncSPDCGateway(cfg) as gw:
            outcomes = await asyncio.gather(
                *(gw.submit(_mat(4, seed=400 + i), tenant=f"t{i % 2}")
                  for i in range(16)),
                return_exceptions=True,
            )
            assert gw._waiters == {}  # nothing left hanging
            assert gw.pending == 0
            return outcomes, gw.stats.as_dict()

    outcomes, stats = asyncio.run(main())
    served = [o for o in outcomes if not isinstance(o, BaseException)]
    shed = [o for o in outcomes if isinstance(o, BaseException)]
    assert len(served) + len(shed) == 16  # every submission accounted for
    assert all(isinstance(o, (AdmissionRejected, GatewayOverloaded))
               for o in shed)
    assert all(r.verified for r in served)
    assert stats["served"] == len(served)
    assert (stats["rejected"] + stats["rejected_admission"]) == len(shed)


# -------------------------------------------------------- circuit breaker


def test_breaker_opens_then_recovers_through_probe():
    """Chaos leg: a bucket whose sweeps start failing trips its breaker
    after exactly failure_threshold flushes; submissions then fast-fail
    with a retry hint; after the cooldown ONE probe is admitted, and its
    verified flush closes the breaker for good."""
    chaos = {"on": True}

    def faults_for(key):
        if chaos["on"]:
            raise RuntimeError("injected chaos: fleet unreachable")
        return None

    cfg = _cfg(
        buckets=(8,), max_batch=1, pad_batches=False,
        breaker=_nojitter(failure_threshold=3, cooldown_base_s=1.0),
    )
    clock = VirtualClock()
    gw = SPDCGateway(cfg, clock=clock, faults_for=faults_for)
    key = gw._key_for(4, {})
    for i in range(3):  # max_batch=1: each submit flushes (and fails)
        rid = gw.submit(_mat(4, seed=500 + i))
        assert "injected chaos" in gw.take(rid).error
    assert gw.breaker_state(key) == "open"
    assert gw.stats.breaker_opens == 1

    with pytest.raises(BreakerOpen) as ei:  # fast-fail while open
        gw.submit(_mat(4, seed=510))
    assert ei.value.retry_after_s == pytest.approx(1.0)
    assert gw.stats.rejected_breaker == 1
    assert gw.healthz()["status"] == "degraded"

    clock.t = 1.0  # cooldown elapsed; next submission is THE probe
    chaos["on"] = False  # fleet healed
    probe_rid = gw.submit(_mat(4, seed=511))
    assert gw.take(probe_rid).verified
    assert gw.breaker_state(key) == "closed"
    assert gw.stats.breaker_probes == 1 and gw.stats.breaker_closes == 1
    assert gw.healthz()["status"] == "ok"
    # full service restored
    rid = gw.submit(_mat(4, seed=512))
    assert gw.take(rid).verified


def test_breaker_failed_probe_reopens_with_backoff():
    def faults_for(key):
        raise RuntimeError("still down")

    cfg = _cfg(
        buckets=(8,), max_batch=1, pad_batches=False,
        breaker=_nojitter(failure_threshold=2, cooldown_base_s=1.0),
    )
    clock = VirtualClock()
    gw = SPDCGateway(cfg, clock=clock, faults_for=faults_for)
    for i in range(2):
        gw.submit(_mat(4, seed=520 + i))
    key = gw._key_for(4, {})
    assert gw.breaker_state(key) == "open"
    clock.t = 1.0
    gw.submit(_mat(4, seed=522))  # probe admitted... and fails
    assert gw.breaker_state(key) == "open"
    assert gw.stats.breaker_opens == 2
    with pytest.raises(BreakerOpen) as ei:
        gw.submit(_mat(4, seed=523))
    # backoff doubled: second open cools down for 2s
    assert ei.value.retry_after_s == pytest.approx(2.0)


def test_breaker_on_open_direct_degrades_instead_of_failing():
    """on_open="direct": an open bucket detours submissions to the
    un-coalesced path — clients get verified answers, just slower."""
    chaos = {"on": True}

    def faults_for(key):
        if chaos["on"]:
            raise RuntimeError("bucket chaos")
        return None

    cfg = _cfg(
        buckets=(8,), max_batch=1, pad_batches=False,
        breaker=_nojitter(failure_threshold=1, on_open="direct"),
    )
    clock = VirtualClock()
    gw = SPDCGateway(cfg, clock=clock, faults_for=faults_for)
    gw.submit(_mat(4, seed=530))  # trips instantly (threshold 1)
    chaos["on"] = False  # direct path is healthy; bucket still open
    m = _mat(4, seed=531)
    res = gw.take(gw.submit(m))
    assert res.verified and res.flush_reason == "direct"
    ws, wl = np.linalg.slogdet(m)
    assert res.det.sign == ws and np.isclose(res.det.logabs, wl, rtol=1e-10)
    assert gw.stats.degraded_direct == 1 and gw.stats.rejected_breaker == 0


@pytest.mark.parametrize("shed", ["quota", "overload"])
def test_breaker_probe_shed_before_enqueue_is_not_lost(shed):
    """Regression: a half-open probe grant whose request is then shed by
    tenant quota or gateway capacity must revert the breaker to "open"
    with the probe still due. Before the fix, probe_pending stayed set
    with no flush ever record()ing, so every later submission fast-failed
    with retry_after 0 — the bucket was permanently unavailable."""
    chaos = {"on": True}

    def faults_for(key):
        if chaos["on"] and key.pad_to == 8:
            raise RuntimeError("bucket chaos")
        return None

    kw = (dict(max_pending=1) if shed == "overload"
          else dict(admission=AdmissionConfig(max_pending_per_tenant=1)))
    cfg = _cfg(
        buckets=(8, 16), max_batch=2, pad_batches=False,
        max_wait_us=1000.0,
        breaker=_nojitter(failure_threshold=1, cooldown_base_s=1.0),
        **kw,
    )
    clock = VirtualClock()
    gw = SPDCGateway(cfg, clock=clock, faults_for=faults_for)
    key8 = gw._key_for(4, {})

    # trip bucket 8 via a timeout flush (threshold 1 → opens immediately)
    gw.submit(_mat(4, seed=540))
    clock.t = 0.01
    gw.poll()
    assert gw.breaker_state(key8) == "open"

    # a pending request in the CLEAN bucket pins the tenant slot /
    # gateway capacity, so the upcoming probe will be shed post-verdict
    blocker = gw.submit(_mat(12, seed=541))
    clock.t = 1.02  # cooldown (1s after the 0.01 failure) elapsed
    chaos["on"] = False  # fleet healed — the probe WOULD succeed
    expect = GatewayOverloaded if shed == "overload" else AdmissionRejected
    for _ in range(2):  # shed twice: each revoked grant must re-arm
        with pytest.raises(expect):
            gw.submit(_mat(4, seed=542))
        # the shed probe is revoked, not consumed: back to open, still due
        assert gw.breaker_state(key8) == "open"

    clock.t = 1.03
    gw.poll()  # the overdue clean-bucket blocker flushes, freeing capacity
    assert gw.take(blocker).verified
    probe_rid = gw.submit(_mat(4, seed=543))  # THE probe, finally enqueued
    assert gw.breaker_state(key8) == "half_open"
    clock.t = 1.05
    gw.poll()
    assert gw.take(probe_rid).verified
    assert gw.breaker_state(key8) == "closed"
    assert gw.stats.breaker_closes == 1
    assert gw.healthz()["status"] == "ok"


def test_padding_failure_fails_requests_instead_of_losing_them():
    """Regression: batch padding runs after the requests are popped from
    the queue — a filler failure must route them through _fail_requests
    (typed error results, slots released), not vanish them and hang
    their waiters."""
    cfg = _cfg(
        buckets=(8,), max_batch=4, pad_batches=True, max_wait_us=1000.0,
        admission=AdmissionConfig(max_pending_per_tenant=4),
        breaker=_nojitter(),
    )
    clock = VirtualClock()
    gw = SPDCGateway(cfg, clock=clock)

    def boom(n_bucket, dtype="float64"):
        raise RuntimeError("filler allocation failed")

    gw._dummy = boom
    # 3 requests pad to the next allowed shape (4) → one filler needed
    rids = [gw.submit(_mat(4, seed=910 + i)) for i in range(3)]
    clock.t = 0.01
    out = gw.poll()
    assert sorted(r.rid for r in out) == sorted(rids)
    for rid in rids:
        res = gw.take(rid)
        assert res.error is not None
        assert "filler allocation failed" in res.error
    assert gw.pending == 0
    assert gw._admission.total_pending == 0  # slots released on failure
    snap = gw.metrics_snapshot()
    assert snap.counters["failed"] == 3
    assert snap.tenants["default"]["served"] == 0


def test_breaker_containment_poisoned_bucket_does_not_starve_others():
    """Acceptance: chaos pinned to ONE bucket trips only that breaker;
    the co-resident bucket's full workload still serves verified, its
    breaker never leaves closed, and its flush count matches a no-fault
    run of the same workload exactly."""
    def run(poison: bool):
        def faults_for(key):
            if poison and key.pad_to == 8:
                raise RuntimeError("poisoned bucket")
            return None

        cfg = _cfg(
            buckets=(8, 16), max_batch=2, max_wait_us=1e9,
            breaker=_nojitter(failure_threshold=2),
        )
        clock = VirtualClock()
        gw = SPDCGateway(cfg, clock=clock, faults_for=faults_for)
        outcomes = {"clean_served": 0, "poisoned_failed": 0, "breaker": 0}
        for i in range(12):
            try:
                rid = gw.submit(_mat(4, seed=600 + i))  # bucket 8
                res = gw.take(rid)
                if res is not None and res.error is not None:
                    outcomes["poisoned_failed"] += 1
            except BreakerOpen:
                outcomes["breaker"] += 1
            rid = gw.submit(_mat(12, seed=700 + i))  # bucket 16
            res = gw.take(rid)
            if res is not None and res.verified:
                outcomes["clean_served"] += 1
        gw.drain()
        clean_key = gw._key_for(12, {})
        return outcomes, gw.breaker_state(clean_key), gw.stats.as_dict()

    chaos_out, chaos_clean_state, chaos_stats = run(poison=True)
    base_out, _, base_stats = run(poison=False)
    # poisoned bucket: first failures then breaker fast-fails the rest
    assert chaos_out["poisoned_failed"] >= 2
    assert chaos_out["breaker"] >= 8
    assert chaos_stats["breaker_opens"] >= 1
    # clean bucket: IDENTICAL service to the no-fault baseline
    assert chaos_out["clean_served"] == base_out["clean_served"]
    assert chaos_clean_state == "closed"
    assert base_stats["breaker_opens"] == 0


# --------------------------------------------------- cache + single-flight


def test_cache_hit_identical_miss_tampered_and_cross_tenant():
    """Identical resubmission answers from the cache with the SAME det;
    a one-bit tamper or a different tenant/security config misses and is
    honestly recomputed — the key covers the full (bytes, security tuple,
    tenant) identity."""
    cfg = _cfg(buckets=(8,), max_batch=1, pad_batches=False,
               cache=CacheConfig(max_entries=8))
    clock = VirtualClock()
    gw = SPDCGateway(cfg, clock=clock)
    m = _mat(4, seed=800)
    first = gw.take(gw.submit(m))
    assert first.verified and gw.stats.cache_misses == 1

    hit = gw.take(gw.submit(m.copy()))  # same bytes, new array object
    assert hit.cache_hit and hit.flush_reason == "cache"
    assert hit.det.sign == first.det.sign
    assert hit.det.logabs == first.det.logabs
    assert gw.stats.cache_hits == 1
    assert gw.stats.flushes == 1  # no second sweep ran

    tampered = m.copy()
    tampered[2, 3] += 1e-9  # sub-tolerance nudge still changes the bytes
    t_res = gw.take(gw.submit(tampered))
    assert not t_res.cache_hit and gw.stats.flushes == 2
    ws, wl = np.linalg.slogdet(tampered)
    assert t_res.det.sign == ws and np.isclose(t_res.det.logabs, wl,
                                               rtol=1e-10)

    other = gw.take(gw.submit(m.copy(), tenant="other"))  # tenant in key
    assert not other.cache_hit and gw.stats.flushes == 3
    lam = gw.take(gw.submit(m.copy(), lambda1=64))  # security tuple in key
    assert not lam.cache_hit and gw.stats.flushes == 4
    snap = gw.metrics_snapshot()
    assert snap.cache["hits"] == 1 and snap.cache["entries"] == 4


def test_cache_never_stores_unverified_results():
    """A tampered sweep's rejected verdict must not outlive its flush: the
    identical resubmission after the fleet heals is RECOMPUTED."""
    chaos = {"on": True}

    def faults_for(key):
        # server 0 owns the matrix's REAL rows (server 1's strip is the
        # identity padding for n=4 → n'=8, where a tamper is harmless)
        return ServerFault(server=0) if chaos["on"] else None

    cfg = _cfg(buckets=(8,), max_batch=1, pad_batches=False,
               breaker=_nojitter(max_unverified_rate=None))
    clock = VirtualClock()
    gw = SPDCGateway(cfg, clock=clock, faults_for=faults_for)
    m = _mat(4, seed=810)
    bad = gw.take(gw.submit(m))
    assert not bad.verified  # tampered, no recovery configured
    chaos["on"] = False
    good = gw.take(gw.submit(m.copy()))
    assert good.verified and not good.cache_hit
    assert gw.stats.flushes == 2 and gw.stats.cache_hits == 0
    ws, wl = np.linalg.slogdet(m)
    assert good.det.sign == ws and np.isclose(good.det.logabs, wl,
                                              rtol=1e-10)


def test_single_flight_coalesces_concurrent_identical_submissions():
    """Identical matrices in flight together ride ONE sweep slot: the
    followers' results clone the leader's verdict, and a later identical
    submission hits the cache."""
    cfg = _cfg(buckets=(8,), max_batch=4, max_wait_us=1e9)
    clock = VirtualClock()
    gw = SPDCGateway(cfg, clock=clock, auto_flush=False)
    m = _mat(5, seed=820)
    leader = gw.submit(m)
    f1 = gw.submit(m.copy())
    f2 = gw.submit(m.copy())
    assert gw.pending == 1  # followers hold no queue slot
    assert gw.stats.coalesced == 2
    gw.drain()
    rl, r1, r2 = gw.take(leader), gw.take(f1), gw.take(f2)
    assert rl.verified and rl.batch == 1
    for r in (r1, r2):
        assert r.verified and r.flush_reason == "coalesced"
        assert r.det.logabs == rl.det.logabs and r.det.sign == rl.det.sign
    assert gw.stats.flushes == 1 and gw.stats.served == 3
    assert gw._inflight == {}
    late = gw.take(gw.submit(m.copy()))
    assert late.cache_hit


def test_single_flight_followers_fail_with_their_leader():
    """A follower must never outlive a failed leader as a hung request."""
    def faults_for(key):
        raise RuntimeError("sweep down")

    cfg = _cfg(buckets=(8,), max_batch=4, max_wait_us=1e9)
    clock = VirtualClock()
    gw = SPDCGateway(cfg, clock=clock, faults_for=faults_for,
                     auto_flush=False)
    m = _mat(5, seed=830)
    leader, follower = gw.submit(m), gw.submit(m.copy())
    gw.drain()
    for rid in (leader, follower):
        res = gw.take(rid)
        assert res is not None and "sweep down" in res.error
    assert gw.pending == 0 and gw._inflight == {}
    assert gw._admission.total_pending == 0
    assert gw.stats.failed == 2


# ------------------------------------------------- dummy cache regression


def test_dummy_cache_keyed_by_dtype_and_bounded():
    """Regression: the padding/warmup dummy cache is keyed by
    (bucket size, dtype) — an f32 bucket must never pad with the f64
    dummy — and is LRU-bounded so a diverse size/dtype mix cannot grow it
    without limit."""
    gw = SPDCGateway(_cfg(), clock=VirtualClock())
    d64 = gw._dummy(8, "float64")
    d32 = gw._dummy(8, "float32")
    assert d64.dtype == np.float64 and d32.dtype == np.float32
    assert gw._dummy(8, "float64") is d64  # cached per key
    for n in range(2, 2 + 2 * _DUMMY_CACHE_MAX, 2):  # flood with sizes
        gw._dummy(n, "float64")
    assert len(gw._dummies) <= _DUMMY_CACHE_MAX


def test_f32_bucket_pads_with_f32_dummies():
    """End-to-end: a partial f32 flush pads its batch, and the whole sweep
    (dummies included) runs at the bucket's dtype."""
    cfg = _cfg(buckets=(8,), max_batch=4, max_wait_us=0.0)
    clock = VirtualClock()
    gw = SPDCGateway(cfg, clock=clock)
    # 3 requests round up to the warmed batch shape 4 → one dummy padder
    rids = [gw.submit(_mat(4, seed=840 + i), dtype="float32")
            for i in range(3)]
    clock.t = 1.0
    gw.poll()
    for rid in rids:
        res = gw.take(rid)
        assert res is not None and res.verified
    assert ("float32" in {k[1] for k in gw._dummies}
            and "float64" not in {k[1] for k in gw._dummies})


# ------------------------------------------------ property: oracle parity


@settings(max_examples=5, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    n_requests=st.integers(min_value=4, max_value=10),
    quota=st.integers(min_value=1, max_value=4),
)
def test_random_interleavings_match_sequential_oracle(seed, n_requests, quota):
    """Property (runs under real hypothesis or the deterministic stub):
    for random tenant/size interleavings under a random quota, every
    ADMITTED request's det equals the sequential direct-call oracle, and
    every shed request is a typed rejection — never a wrong answer."""
    rng = np.random.default_rng(seed)
    cfg = _cfg(
        buckets=(8, 16), max_batch=4, max_wait_us=1e9,
        admission=AdmissionConfig(max_pending_per_tenant=quota),
        cache=CacheConfig(enabled=False),  # oracle parity, not cache reuse
    )
    clock = VirtualClock()
    gw = SPDCGateway(cfg, clock=clock, auto_flush=False)
    mats = [_mat(int(rng.integers(2, 17)), seed=seed * 100 + i)
            for i in range(n_requests)]
    tenants = [f"t{int(rng.integers(0, 2))}" for _ in mats]
    admitted, shed = {}, 0
    for i, (m, tenant) in enumerate(zip(mats, tenants)):
        clock.t = float(i)
        try:
            admitted[i] = gw.submit(m, tenant=tenant)
        except (AdmissionRejected, GatewayOverloaded):
            shed += 1
        if rng.integers(0, 3) == 0:  # random flush interleaving
            gw.drain()
    gw.drain()
    assert len(admitted) + shed == n_requests
    for i, rid in admitted.items():
        res = gw.take(rid)
        assert res is not None and res.verified
        oracle = outsource_determinant(mats[i], 2)
        assert res.det.sign == oracle.det.sign
        assert np.isclose(res.det.logabs, oracle.det.logabs, rtol=1e-10)
    assert gw.pending == 0 and gw._admission.total_pending == 0
