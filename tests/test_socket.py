"""SocketTransport acceptance (DESIGN.md §9): warm worker daemons over
UDS, the full fault matrix (honest / tamper-localize-heal / death /
rateless streaming) bit-identical to the multiprocess transport, plus
wire-level adversaries — truncated frames, oversized length prefixes,
HELLO version mismatches, mid-session disconnects — all surfacing as
TYPED TransportErrors with the session healing where the protocol says
it must. This file is the CI `sockets` job."""
import multiprocessing
import os
import socket as socketlib
import struct
import threading
import time

import jax
import numpy as np
import pytest

from repro.api import (
    MultiprocessTransport,
    SPDCClient,
    TransportConfig,
    TransportError,
    TransportProtocolError,
    TransportWorkerDied,
    resolve_transport,
    wire,
)
from repro.api.socket_transport import (
    MAX_FRAME,
    SOCKET_PROTO,
    SocketTransport,
    WorkerDaemon,
    _daemon_main,
    _hello_frame,
    _parse_hello,
    parse_address,
    recv_frame,
    send_frame,
)
from repro.core import ServerFault, outsource_determinant

N = 4


def _wellcond(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n)) + n * np.eye(n)


# ----------------------------------------------------------- fixtures
def _spawn_daemon(address, workers=None):
    """A daemon in its own process, like deployment. In-process daemons
    would run EdgeServer jit compiles in ephemeral handler threads, and
    XLA compiles launched from short-lived threads can destabilize later
    main-thread compiles in the same process — daemon jax stays out."""
    proc = multiprocessing.get_context("spawn").Process(
        target=_daemon_main,
        args=(address, workers, bool(jax.config.jax_enable_x64)),
        daemon=True,
    )
    proc.start()
    return proc


def _wait_bound(address, timeout=120.0):
    """Block until the daemon's UDS path exists (it binds right after
    the child finishes importing jax)."""
    path = parse_address(address)[1]
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if os.path.exists(path):
            return
        time.sleep(0.05)
    raise RuntimeError(f"daemon never bound {address}")


def _probe_hello(address, worker_id=0):
    """One throwaway wire-level handshake: the daemon's lifetime
    counters as a NEW client would see them."""
    family, target = parse_address(address)
    s = socketlib.socket(
        socketlib.AF_UNIX if family == "unix" else socketlib.AF_INET,
        socketlib.SOCK_STREAM,
    )
    s.connect(target)
    with s:
        send_frame(s, _hello_frame(
            proto=SOCKET_PROTO, wire=wire.VERSION,
            role="client", worker_id=int(worker_id),
        ))
        return _parse_hello(recv_frame(s))


@pytest.fixture(scope="module")
def fleet(tmp_path_factory):
    """N=4 REAL warm daemon processes on Unix sockets, shared by the
    whole module — their lifetime HELLO counters are how tests observe
    warmth. Each serves any worker id, so recovery's replacement ids
    N, N+1, … wrap onto the same fleet (addresses[i % len])."""
    root = tmp_path_factory.mktemp("spdc-fleet")
    addrs = [f"unix://{root}/w{i}.sock" for i in range(N)]
    procs = [_spawn_daemon(a) for a in addrs]
    try:
        for a in addrs:
            _wait_bound(a)
    except BaseException:
        for p in procs:
            p.terminate()
        raise
    yield addrs
    for p in procs:
        p.terminate()
    for p in procs:
        p.join(timeout=10)


@pytest.fixture()
def sock_transport(fleet):
    t = SocketTransport(tuple(fleet), connect_timeout=10.0)
    yield t
    t.close()


# ------------------------------------------------- framing primitives
def test_parse_address():
    assert parse_address("unix:///tmp/x.sock") == ("unix", "/tmp/x.sock")
    assert parse_address("tcp://127.0.0.1:8471") == ("tcp", ("127.0.0.1", 8471))
    for bad in ("http://x", "unix://", "tcp://noport"):
        with pytest.raises(ValueError):
            parse_address(bad)


def test_frame_roundtrip_and_goodbye():
    a, b = socketlib.socketpair()
    with a, b:
        send_frame(a, b"payload-bytes")
        assert recv_frame(b) == b"payload-bytes"
        send_frame(a, b"")  # goodbye sentinel
        assert recv_frame(b) == b""
        a.close()
        assert recv_frame(b) is None  # clean EOF at a frame boundary


# --------------------------------------------------- wire adversaries
def test_adversary_truncated_frame_is_typed():
    """A peer that dies mid-frame produced a truncated frame — a
    protocol violation, never retried."""
    a, b = socketlib.socketpair()
    with b:
        a.sendall(struct.pack(">I", 100) + b"only-ten-b")
        a.close()
        with pytest.raises(TransportProtocolError, match="truncated"):
            recv_frame(b)


def test_adversary_oversized_length_prefix_never_allocated():
    """A malicious length prefix must not OOM the client: the reader
    refuses before allocating."""
    a, b = socketlib.socketpair()
    with a, b:
        a.sendall(struct.pack(">I", MAX_FRAME + 1))
        with pytest.raises(TransportProtocolError, match="oversized"):
            recv_frame(b)
    assert issubclass(TransportProtocolError, TransportError)


def _fake_daemon(reply_hello):
    """One-connection fake worker: accepts, reads the client HELLO,
    replies with `reply_hello` bytes, then serves nothing."""
    lsock = socketlib.socket(socketlib.AF_INET, socketlib.SOCK_STREAM)
    lsock.bind(("127.0.0.1", 0))
    lsock.listen(1)
    port = lsock.getsockname()[1]

    def serve():
        conn, _ = lsock.accept()
        with conn, lsock:
            recv_frame(conn)  # client HELLO
            send_frame(conn, reply_hello)
            recv_frame(conn)  # linger until the client hangs up

    threading.Thread(target=serve, daemon=True).start()
    return f"tcp://127.0.0.1:{port}"


def test_adversary_hello_version_mismatch_not_retried():
    """A daemon speaking the wrong socket-proto version is a protocol
    violation: typed, immediate, no reconnect storm."""
    addr = _fake_daemon(_hello_frame(
        proto=SOCKET_PROTO + 1, wire=wire.VERSION, role="worker",
        worker_id=0, served=None, caps=[], accept=True,
        connections=1, frames_served=0,
    ))
    with SocketTransport((addr,), connect_timeout=5.0) as t:
        task = SPDCClient().open_session(_wellcond(8), 2).tasks()[0]
        with pytest.raises(TransportProtocolError, match="version mismatch"):
            t.submit(task, 0)


def test_adversary_non_worker_role_rejected():
    addr = _fake_daemon(_hello_frame(
        proto=SOCKET_PROTO, wire=wire.VERSION, role="client",
        worker_id=0, accept=True,
    ))
    with SocketTransport((addr,), connect_timeout=5.0) as t:
        task = SPDCClient().open_session(_wellcond(8), 2).tasks()[0]
        with pytest.raises(TransportProtocolError, match="not a worker"):
            t.submit(task, 0)


def test_daemon_refuses_bad_client_hello(tmp_path):
    """Daemon side of the handshake: wrong version or an unserved worker
    id gets an explicit accept=False HELLO, not a silent EOF."""
    with WorkerDaemon(f"unix://{tmp_path}/w.sock", workers=(0, 1)) as d:
        def handshake(**fields):
            s = socketlib.socket(socketlib.AF_UNIX, socketlib.SOCK_STREAM)
            s.connect(parse_address(d.address)[1])
            with s:
                send_frame(s, _hello_frame(**fields))
                return _parse_hello(recv_frame(s))

        good = dict(proto=SOCKET_PROTO, wire=wire.VERSION, role="client")
        assert handshake(**good, worker_id=1)["accept"] is True
        assert handshake(**good, worker_id=7)["accept"] is False  # unserved
        assert handshake(**{**good, "proto": 99}, worker_id=0)["accept"] is False
        assert handshake(**{**good, "role": "worker"}, worker_id=0)["accept"] is False
        hello = handshake(**good, worker_id=0)
        assert hello["served"] == [0, 1] and hello["role"] == "worker"


def test_mid_session_disconnect_heals(tmp_path):
    """The daemon dies and is replaced between sweeps: the stale pooled
    connection surfaces a TYPED TransportWorkerDied, and a full session
    through the same transport heals by reconnecting — one drop costs
    one reconnect, not the session."""
    address = f"unix://{tmp_path}/w.sock"
    sockpath = parse_address(address)[1]
    p1 = _spawn_daemon(address)
    p2 = None
    m = _wellcond(16, seed=5)
    t = SocketTransport((address,), connect_timeout=10.0)
    try:
        _wait_bound(address)
        assert outsource_determinant(m, 2, transport=t).verified
        p1.terminate()  # takes its live connections down with it
        p1.join(timeout=10)
        if os.path.exists(sockpath):
            os.unlink(sockpath)  # SIGTERM skipped the daemon's unlink
        p2 = _spawn_daemon(address)
        _wait_bound(address)
        task = SPDCClient().open_session(m, 2).tasks()[0]
        with pytest.raises((TransportWorkerDied, TransportProtocolError)):
            with t._worker_lock(0):
                t._request(0, task.to_bytes())
        res = outsource_determinant(m, 2, transport=t)  # reconnects
        assert res.verified
        assert t.hello(0)["connections"] >= 1  # the NEW daemon's counter
    finally:
        t.close()
        for p in (p1, p2):
            if p is not None:
                p.terminate()
                p.join(timeout=10)


# ------------------------------------------- acceptance matrix (UDS, N=4)
def test_honest_end_to_end(sock_transport, fleet):
    """N=4 real daemons; every message crosses as length-prefixed wire
    frames; det matches numpy at rtol 1e-10."""
    m = _wellcond(16, seed=31)
    res = outsource_determinant(m, N, transport=sock_transport)
    assert len(sock_transport.workers) == N  # one connection per worker
    ws, wl = np.linalg.slogdet(m)
    assert res.verified and res.det.sign == ws
    np.testing.assert_allclose(res.det.logabs, wl, rtol=1e-10)
    hello = sock_transport.hello(0)
    assert hello["role"] == "worker" and hello["proto"] == SOCKET_PROTO
    # a fresh handshake reads each daemon's LIFETIME counter: all served
    assert all(_probe_hello(a)["frames_served"] >= 1 for a in fleet)


def test_socket_factors_bit_identical_to_multiprocess(fleet):
    """THE equivalence bar: the same session's ShardTasks produce
    bit-identical ShardResults over sockets and over process pipes —
    the transport moves bytes, it must not change a single one."""
    session = SPDCClient().open_session(_wellcond(16, seed=33), N)
    tasks = session.tasks()
    addrs = tuple(fleet)
    with SocketTransport(addrs, connect_timeout=5.0) as st, \
            MultiprocessTransport() as mt:
        rs = st.factor(tasks)
        rm = mt.factor(tasks)
    for a, b in zip(rs, rm):
        assert a.server == b.server and a.subseed == b.subseed
        np.testing.assert_array_equal(a.l_row, b.l_row)  # bit-exact
        np.testing.assert_array_equal(a.u_row, b.u_row)
    out = session.collect(rs)
    assert out.verified


@pytest.mark.parametrize("method", ["q2", "q3"])
def test_tamper_localize_heal(sock_transport, method):
    """Worker 1 tampers its strip in-band; the client localizes it over
    the socket boundary and heals via re-dispatched ShardTasks — the
    replacement id N wraps onto the same fleet (addresses[N % N])."""
    m = _wellcond(16, seed=37)
    honest = outsource_determinant(m, N)
    res = outsource_determinant(
        m, N, method=method,
        faults=ServerFault(server=1, mode="block", magnitude=0.3),
        recover=True, standby=1, transport=sock_transport,
    )
    assert res.verified and res.report.recovery.ok
    assert res.report.recovery.events[0].server == 1
    assert 1 in res.report.recovery.servers_replaced
    np.testing.assert_allclose(res.det.logabs, honest.det.logabs,
                               rtol=1e-10)


def test_rateless_streams_over_sockets(fleet):
    """Rateless dispatch over real daemons: a sleeping worker's request
    times out, its CONNECTION is dropped (the daemon survives), the
    strip re-streams to a live sibling, and the fleet report attributes
    the slowness."""
    from repro.configs import RatelessConfig

    m = _wellcond(16, seed=53)
    cfg = RatelessConfig(request_timeout_s=1.0, probation_cooldown_s=60.0)
    client = SPDCClient(rateless=cfg, recover=True)
    fault = ServerFault(server=1, kind="delay", delay_s=8.0)
    addrs = tuple(fleet)
    with SocketTransport(addrs, connect_timeout=5.0) as t:
        out = client.open_session(m, N, faults=fault).run(t)
    assert out.verified
    assert out.report.fleet.timeouts >= 1
    w1 = out.report.fleet.workers[1]
    assert w1["failures"] >= 1 and w1["completed"] == 0
    ws, wl = np.linalg.slogdet(m)
    np.testing.assert_allclose(out.det.logabs, wl, rtol=1e-8)


def test_daemons_stay_warm_across_clients(fleet):
    """The point of the transport: a NEW client (fresh SocketTransport,
    as after a client restart) lands on the SAME daemon — its lifetime
    counters keep growing and earlier clients' frames are visible."""
    m = _wellcond(12, seed=61)
    addrs = tuple(fleet)
    with SocketTransport(addrs, connect_timeout=5.0) as t1:
        assert outsource_determinant(m, N, transport=t1).verified
        first = t1.hello(0)["connections"]
    with SocketTransport(addrs, connect_timeout=5.0) as t2:
        assert outsource_determinant(m, N, transport=t2).verified
        hello = t2.hello(0)
    assert hello["connections"] > first  # same daemon, one more client
    assert hello["frames_served"] > 0  # warm: it served before we arrived


def test_session_start_overlaps_wire(sock_transport):
    """The async-overlap redesign end-to-end on real sockets: batch k+1's
    PMOP runs while batch k's ShardTasks ride the wire; both collect on
    the calling thread, in order, verified."""
    client = SPDCClient()
    m1, m2 = _wellcond(16, seed=71), _wellcond(16, seed=72)
    p1 = client.open_session(m1, N).start(sock_transport)
    # this PMOP overlaps p1's wire time — the pipeline's whole point
    p2 = client.open_session(m2, N).start(sock_transport)
    r2, r1 = p2.result(timeout=60), p1.result(timeout=60)
    assert p1.done() and p2.done()
    for m, r in ((m1, r1), (m2, r2)):
        ws, wl = np.linalg.slogdet(m)
        assert r.verified and r.det.sign == ws
        np.testing.assert_allclose(r.det.logabs, wl, rtol=1e-10)
    t = r1.report.timings
    assert t.pmop_s > 0 and t.dispatch_s > 0 and t.collect_s > 0


# ------------------------------------------ self-hosting and lifecycle
@pytest.mark.slow
def test_self_hosted_daemons_death_respawn_and_leak_free():
    """Bare `SocketTransport()` self-hosts one warm UDS daemon process
    per worker id; a killed daemon is respawned transparently; close()
    terminates every spawned process and removes the socket dir — the
    leak check."""
    m = _wellcond(16, seed=81)
    t = SocketTransport(connect_timeout=30.0)
    try:
        res = outsource_determinant(m, 2, transport=t)
        assert res.verified
        assert sorted(t._spawned) == [0, 1]
        victim = t._spawned[1][0]
        victim.terminate()
        victim.join(timeout=10)
        res2 = outsource_determinant(m, 2, transport=t)  # respawn heals
        assert res2.verified
        assert t._spawned[1][0].pid != victim.pid
    finally:
        procs = [p for p, _ in t._spawned.values()]
        tmpdir = t._tmpdir
        t.close()
    assert t.closed
    assert tmpdir is not None and not os.path.exists(tmpdir)
    for p in procs:
        assert not p.is_alive()
    with pytest.raises(TransportError, match="closed"):
        t.factor([])


def test_transport_config_socket_resolution(fleet):
    """The unified transport= surface reaches sockets: a TransportConfig
    with addresses builds a working transport, equal configs share ONE
    process-wide instance via resolve_transport, and build() is the
    fresh-owned escape hatch."""
    addrs = tuple(fleet)
    cfg = TransportConfig("socket", addresses=addrs, timeout=30.0)
    shared = resolve_transport(cfg)
    assert shared is resolve_transport(TransportConfig(
        "socket", addresses=addrs, timeout=30.0
    ))  # equal configs → one warm pool
    owned = cfg.build()
    assert owned is not shared
    try:
        m = _wellcond(12, seed=91)
        res = outsource_determinant(m, N, transport=cfg)
        assert res.verified
        ws, wl = np.linalg.slogdet(m)
        np.testing.assert_allclose(res.det.logabs, wl, rtol=1e-10)
    finally:
        owned.close()
    # client OWNS a config-built transport and closes it deterministically
    with SPDCClient(transport=cfg) as client:
        inner = client.transport
        assert isinstance(inner, SocketTransport) and inner is not shared
        assert client.open_session(m, N).run().verified
    assert inner.closed
    assert not shared.closed  # the registry instance is untouched
