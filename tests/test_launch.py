"""Launch-layer tests: hlo_cost trip-count correction, roofline parsing,
perf variants (pure-DP strategy, relay programs), and one real dry-run cell
via subprocess (512 fake devices need a fresh process)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ROOT = Path(__file__).resolve().parents[1]


def test_hlo_cost_counts_scan_trip_counts():
    """The raison d'être of launch/hlo_cost.py: XLA counts while bodies
    once; we must multiply by the trip count."""
    from repro.launch.hlo_cost import analyze_hlo

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jnp.zeros((64, 128), jnp.float32)
    w = jnp.zeros((128, 128), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    from repro.compat import cost_analysis_dict

    raw = cost_analysis_dict(compiled).get("flops", 0.0)
    ours = analyze_hlo(compiled.as_text()).flops
    dot_flops = 2 * 64 * 128 * 128
    assert raw < 2 * dot_flops  # XLA: body counted once
    assert ours > 9 * dot_flops  # ours: ~10x
    assert ours < 12 * dot_flops


def test_hlo_cost_collectives_in_loops():
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.hlo_cost import analyze_hlo

    from repro.compat import make_mesh

    mesh = make_mesh((2, 4), ("data", "model"), devices=jax.devices())
    xs = jax.ShapeDtypeStruct((16, 64), jnp.float32,
                              sharding=NamedSharding(mesh, P("data", None)))
    ws = jax.ShapeDtypeStruct((64, 64), jnp.float32,
                              sharding=NamedSharding(mesh, P(None, "model")))

    def g(x, w):
        def body(c, _):
            h = jnp.tanh(c @ w)
            h = jax.lax.with_sharding_constraint(
                h, NamedSharding(mesh, P("data", None)))
            return h, None
        out, _ = jax.lax.scan(body, x, None, length=5)
        return out

    c = analyze_hlo(jax.jit(g).lower(xs, ws).compile().as_text())
    assert c.coll_counts.get("all-gather", 0) == 5  # multiplied by trips


def test_roofline_analyze_terms():
    from repro.launch.hlo_cost import Cost
    from repro.launch.roofline import analyze

    hc = Cost(flops=197e12, hbm_bytes=819e9 / 2)
    hc.coll_wire = {"all-reduce": 100e9}
    hc.coll_counts = {"all-reduce": 1}
    hc.coll_bytes = {"all-reduce": 50e9}
    rl = analyze(arch="x", shape="y", mesh_name="single", chips=256,
                 cost={}, hlo_text="", memory_stats={},
                 active_params=1e9, tokens=1e6, training=True, hlo_cost=hc)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(0.5)
    assert rl.collective_s == pytest.approx(1.0)  # 100e9/(2*50e9)
    assert rl.dominant in ("compute", "collective")
    assert rl.model_flops == pytest.approx(6e15)


def test_relay_programs_equivalent():
    """baseline / exact / stream relay programs produce identical LU."""
    from repro.core.lu import lu_nserver
    from repro.distrib.spdc_pipeline import lu_nserver_shardmap

    rng = np.random.default_rng(11)
    n, N = 32, 8
    x = jnp.asarray(rng.standard_normal((n, n)) + n * np.eye(n))
    ref_l, ref_u, _ = lu_nserver(x, N)
    for program in ("baseline", "exact", "stream"):
        l, u = lu_nserver_shardmap(x, N, program=program)
        np.testing.assert_allclose(np.asarray(l), np.asarray(ref_l),
                                   atol=1e-9, err_msg=program)
        np.testing.assert_allclose(np.asarray(u), np.asarray(ref_u),
                                   atol=1e-9, err_msg=program)


def test_dp_over_model_rules():
    """The pure-DP strategy (§Perf B) folds every axis into batch/fsdp."""
    from dataclasses import replace

    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import rules_for
    from repro.launch.mesh import make_smoke_mesh

    mesh = make_smoke_mesh((2, 4), ("data", "model"))
    cfg = replace(get_config("mamba2-370m"), dp_over_model=True)
    rules = rules_for(cfg, SHAPES["train_4k"], mesh)
    assert rules.model_axis is None
    assert rules.batch_axes == ("data", "model")
    assert rules.fsdp_axes == ("data", "model")


def test_effective_grad_accum_clamp():
    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import effective_cfg, rules_for
    from repro.launch.mesh import make_smoke_mesh

    mesh = make_smoke_mesh((8, 1), ("data", "model"))
    cfg = get_config("nemotron-4-340b")  # grad_accum=32
    rules = rules_for(cfg, SHAPES["train_4k"], mesh)
    eff = effective_cfg(cfg, SHAPES["train_4k"], mesh, rules)
    # 256 batch / 8 data shards => accum can stay 32 (256/32=8 divisible by 8)
    assert (256 // eff.grad_accum) % 8 == 0


@pytest.mark.slow
def test_dryrun_one_cell_subprocess(tmp_path):
    """End-to-end dry-run of a small cell on the real 16x16 mesh (fresh
    process: 512 fake devices must be set before JAX init)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "gemma3-1b",
         "--shape", "decode_32k", "--mesh", "single", "--out", str(tmp_path)],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=400,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.load(open(tmp_path / "gemma3-1b__decode_32k__single.json"))
    assert rec["chips"] == 256
    assert rec["compute_s"] > 0 and rec["memory_s"] > 0
    assert rec["dominant"] in ("compute", "memory", "collective")
