"""outsource_inverse — the facade over the shared-LU op plan.

The §VII.B enhancement, post-refactor (DESIGN.md §12): one verified
session factorization, one wide public-RHS round, facade-level Freivalds
re-check with a SECRET probe lane. Includes the adaptive-attack
regression against the fixed-seed probe the facade replaced, and the
one-cycle deprecation shims for the pre-facade result fields.
"""
import jax
import numpy as np
import pytest

from repro.core import outsource_determinant, outsource_inverse
from repro.core.faults import ServerFault
from repro.linalg import LinalgSession

X64 = bool(jax.config.jax_enable_x64)
needs_x64 = pytest.mark.skipif(
    not X64, reason="compares against float64-calibrated tolerances"
)


def _wellcond(n, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    if batch is None:
        return rng.standard_normal((n, n)) + n * np.eye(n)
    return rng.standard_normal((batch, n, n)) + n * np.eye(n)


@pytest.mark.parametrize("dtype,tol", [
    ("float64", 1e-9),
    ("float32", 2e-3),
])
def test_honest_roundtrip(dtype, tol):
    if dtype == "float64" and not X64:
        pytest.skip("x64 disabled")
    m = _wellcond(10, seed=1)
    res = outsource_inverse(m, 2, dtype=dtype)
    assert res.verified
    np.testing.assert_allclose(
        np.asarray(res.inverse), np.linalg.inv(m), rtol=0, atol=tol
    )
    assert res.residual < tol
    # per-op diagnostics: factorization + the inverse round, all verified
    ops = [o.op for o in res.report.ops]
    assert "factor" in ops and "inv" in ops
    assert all(o.verified for o in res.report.ops)


@needs_x64
def test_tampered_server_localizes_and_heals():
    """Transport-level misbehavior is the heal-able kind: the session's
    per-chunk verification localizes the bad chunk and recovers, and the
    facade still verifies the final inverse."""
    m = _wellcond(12, seed=2)
    res = outsource_inverse(
        m, 2, faults=ServerFault(server=0, magnitude=50.0), recover=True,
    )
    assert res.verified
    np.testing.assert_allclose(
        np.asarray(res.inverse), np.linalg.inv(m), rtol=0, atol=1e-9
    )
    assert any(o.healed >= 1 for o in res.report.ops)


def test_final_tamper_is_caught():
    """`tamper=` corrupts the REPORTED inverse after recovery — only the
    facade's final Freivalds projection can catch it."""
    m = _wellcond(10, seed=3)
    res = outsource_inverse(
        m, 2, tamper=lambda iv: iv.at[3, 4].add(0.01)
    )
    assert not res.verified
    assert res.residual > 1e-6


def test_batched_path():
    ms = _wellcond(8, seed=4, batch=3)
    res = outsource_inverse(ms, 2)
    assert res.verified
    assert np.asarray(res.inverse).shape == (3, 8, 8)
    tol = 1e-9 if X64 else 2e-3
    for i in range(3):
        np.testing.assert_allclose(
            np.asarray(res.inverse[i]), np.linalg.inv(ms[i]),
            rtol=0, atol=tol,
        )
    # one factorization per matrix in the stack, concatenated reports
    assert sum(1 for o in res.report.ops if o.op == "factor") == 3


@needs_x64
def test_factors_bit_equal_to_fresh_outsourcing():
    """The protocol is deterministic in the matrix bytes: the facade's
    session factors are BIT-identical to a fresh determinant outsourcing
    under the same client knobs — which is what lets the differentiable
    ops re-enter under jit replay and land on the same session."""
    m = _wellcond(10, seed=5)
    s1 = LinalgSession(m, 2)
    s1._ensure_factors()
    s2 = LinalgSession(m, 2)
    s2._ensure_factors()
    for f1, f2 in zip(s1._factors, s2._factors):
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    assert s1.digest == s2.digest
    # and the det the facade's factors imply agrees exactly with the
    # standalone protocol entry point at the session's config
    det = outsource_determinant(
        m, 2, method="q2", recover=True, growth_safe=True,
        equilibrate=False,
    )
    sign, logabs = s1.slogdet()
    assert float(det.det.sign) == sign
    assert np.isclose(float(det.det.logabs), logabs, rtol=0, atol=1e-12)


@needs_x64
def test_adaptive_attack_on_fixed_probe_is_caught():
    """Regression for the fixed-seed Freivalds probe the facade replaced.

    The pre-facade check seeded its projection from a fixed slice of the
    session digest — wire-adjacent material an adaptive server could
    learn. Such a server tampers with E chosen ORTHOGONAL to that
    predictable probe r₀ (E·r₀ = 0): the old check's residual is
    untouched while the inverse is arbitrarily wrong. The secret-lane
    probe (fresh per attempt, never on the wire) must reject it.
    """
    m = _wellcond(10, seed=6)
    # the digest is deterministic in the matrix bytes — exactly what an
    # adaptive attacker could replay to learn a digest-sliced seed
    digest = LinalgSession(m, 2).digest
    r0 = np.random.default_rng(
        int.from_bytes(digest[:4], "big")
    ).standard_normal(10)
    # rank-1 tamper orthogonal to the predictable probe, O(1) magnitude
    z = np.arange(1.0, 11.0)
    w = np.random.default_rng(7).standard_normal(10)
    w -= (w @ r0) / (r0 @ r0) * r0
    attack = np.outer(z, w / np.linalg.norm(w))

    res = outsource_inverse(
        m, 2, tamper=lambda iv: iv + np.asarray(attack, dtype=iv.dtype)
    )
    # the OLD check would have accepted: the attack is invisible to r₀
    old_resid = float(
        np.linalg.norm(m @ ((np.asarray(res.inverse)) @ r0) - r0)
        / np.linalg.norm(r0)
    )
    assert old_resid < 1e-6, "attack must be orthogonal to the old probe"
    # the secret-lane probe catches it
    assert not res.verified
    assert res.residual > 1e-3


def test_deprecated_protocol_fields_warn_and_error_policy():
    """`result.seed` / `result.meta` still answer but warn; under the
    repo's error::DeprecationWarning filter the access RAISES, which is
    the one-cycle removal contract."""
    m = _wellcond(8, seed=8)
    res = outsource_inverse(m, 2)
    with pytest.warns(DeprecationWarning, match="session-internal"):
        seed = res.seed
    assert seed is not None
    with pytest.warns(DeprecationWarning, match="report.ops"):
        meta = res.meta
    assert meta is not None
    # the pytest.ini policy (error::DeprecationWarning:repro) turns the
    # bare access into an exception — shims cannot silently outlive
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        with pytest.raises(DeprecationWarning):
            _ = res.seed
