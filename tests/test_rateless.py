"""Rateless straggler-adaptive dispatch + fleet health (DESIGN.md §8).

Includes the acceptance end-to-end: N=4 edge workers, ONE Pareto-delayed
and ONE tampering, NO straggler_deadline configured — the session still
completes, the determinant matches the honest run at rtol 1e-10, the
healed factors pass Q2 AND Q3, the slow worker completed fewer strips
than the healthy ones, and the tamperer ends the session quarantined.

The chaos matrix at the bottom (slow/chaos-marked; always-on in CI's
chaos job) sweeps seeded tamper × dropout × delay-distribution plans
through the scheduler.
"""
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import SPDCClient, ThreadPoolTransport
from repro.configs import RATELESS_DEFAULT, RatelessConfig, SPDC_EDGE_RATELESS
from repro.core import ServerFault, authenticate, outsource_determinant
from repro.distrib.rateless import FleetHealth, run_rateless

N = 4


def _wellcond(n, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    if batch is None:
        return rng.standard_normal((n, n)) + n * np.eye(n)
    return rng.standard_normal((batch, n, n)) + n * np.eye(n)


def _logabs(res):
    if hasattr(res, "dets"):
        return np.asarray([d.logabs for d in res.dets])
    return np.asarray(res.det.logabs)


# ------------------------------------------------------------- acceptance
def test_rateless_acceptance_straggler_and_tamperer():
    """Acceptance: one Pareto-heavy-tail straggler + one tamperer, no
    deadline anywhere — verified det matches honest at rtol 1e-10, Q2 and
    Q3 both pass on the streamed factors, the slow worker did less, and
    the tamperer is quarantined."""
    B, n = 5, 32
    m = _wellcond(n, seed=7, batch=B)
    honest = outsource_determinant(m, N, rateless=True)
    assert np.asarray(honest.verified).all()

    cfg = RatelessConfig(
        request_timeout_s=0.35,
        probation_cooldown_s=60.0,  # no probes inside this short session
    )
    plan = (
        ServerFault(server=1, kind="delay", delay_s=0.25,
                    delay_dist="pareto", delay_alpha=2.5),
        ServerFault(server=2, kind="tamper", mode="block", magnitude=0.5),
    )
    client = SPDCClient(rateless=cfg, recover=True)
    assert client.straggler_deadline is None  # nothing to tune
    session = client.open_session(m, N, faults=plan)
    assert session.partitions == cfg.overdecompose * N

    with ThreadPoolTransport() as tp:
        l, u, rpt = run_rateless(
            session, tp, client.rateless, client.fleet, faults=session.plan
        )
        # the streamed factors pass BOTH Q2 and Q3 — per-strip probes
        # caught the tampered strips before any downstream strip consumed
        # them, so no localize→heal cascade is even needed
        for method in ("q2", "q3"):
            v = authenticate(
                jnp.asarray(l), jnp.asarray(u), session.x_aug,
                num_servers=session.partitions, method=method,
            )
            assert bool(np.all(v.ok)), (method, v.residual)
        session.fleet_report = rpt
        out = session.collect(
            (jnp.asarray(l, dtype=session.x_aug.dtype),
             jnp.asarray(u, dtype=session.x_aug.dtype)),
            transport=tp,
        )

    assert np.asarray(out.verified).all()
    np.testing.assert_allclose(_logabs(out), _logabs(honest), rtol=1e-10)

    workers = rpt.workers
    tamperer = workers[2]
    assert tamperer["quarantined"] and tamperer["tampers"] >= 1
    assert tamperer["completed"] == 0  # nothing it produced was accepted
    honest_completed = [workers[w]["completed"] for w in (0, 3)]
    # rateless redistribution: the straggler pulled fewer strips than the
    # healthy workers absorbed on its behalf
    assert workers[1]["completed"] < max(honest_completed)
    total = rpt.num_strips * rpt.lanes
    assert sum(w["completed"] for w in workers.values()) \
        + rpt.inline_strips == total


def test_rateless_honest_matches_numpy_single_and_batch():
    m = _wellcond(24, seed=11)
    res = outsource_determinant(m, N, rateless=True)
    ws, wl = np.linalg.slogdet(m)
    assert res.verified and res.det.sign == ws
    np.testing.assert_allclose(res.det.logabs, wl, rtol=1e-8)
    assert res.num_servers == N  # fleet size, not strip count
    assert res.report.fleet.num_strips == RATELESS_DEFAULT.overdecompose * N
    assert res.report.fleet.inline_strips == 0 and res.report.fleet.retries == 0

    stack = _wellcond(16, seed=13, batch=3)
    bres = outsource_determinant(stack, N, rateless=True,
                                 transport="threadpool")
    assert np.asarray(bres.verified).all()
    for i in range(3):
        ws, wl = np.linalg.slogdet(stack[i])
        assert bres.dets[i].sign == ws
        np.testing.assert_allclose(bres.dets[i].logabs, wl, rtol=1e-8)
    assert bres.report.fleet.lanes == 3  # one lane per batch slice


def test_rateless_ignores_round_deadline():
    """A rateless session has no rounds deadline: a delay_rounds fault far
    past any classic deadline is NOT converted to a dropout (while the
    classic path drops it and rejects without recovery)."""
    m = _wellcond(16, seed=17)
    fault = ServerFault(server=0, kind="delay", delay_rounds=99)
    classic = outsource_determinant(m, N, faults=fault, straggler_deadline=1)
    assert not classic.verified
    res = outsource_determinant(
        m, N, faults=fault, straggler_deadline=1, rateless=True
    )
    assert res.verified and res.report.recovery is None


def test_rateless_config_resolution_and_validation():
    assert SPDCClient().fleet is None
    c = SPDCClient(rateless=True)
    assert c.rateless == RATELESS_DEFAULT
    assert isinstance(c.fleet, FleetHealth)
    with pytest.raises(ValueError, match="rateless"):
        SPDCClient(rateless="yes")
    with pytest.raises(ValueError, match="overdecompose"):
        RatelessConfig(overdecompose=0)
    with pytest.raises(ValueError, match="ewma_alpha"):
        RatelessConfig(ewma_alpha=1.5)
    cfg = SPDC_EDGE_RATELESS
    assert cfg.rateless and cfg.protocol_kwargs()["rateless"] is True


def test_fleet_health_outlives_sessions():
    """What one session learned rides into the next: the client's
    FleetHealth keeps its observations across open_session calls."""
    client = SPDCClient(
        rateless=RatelessConfig(probation_cooldown_s=60.0), recover=True
    )
    m = _wellcond(16, seed=19)
    fault = ServerFault(server=1, kind="tamper", mode="sign_flip")
    with ThreadPoolTransport() as tp:
        out1 = client.open_session(m, N, faults=fault).run(tp)
        assert out1.verified
        assert client.fleet.worker(1).quarantined
        out2 = client.open_session(m, N).run(tp)
        assert out2.verified
    # second session never dispatched to the quarantined worker
    assert out2.report.fleet.workers[1]["completed"] == 0


# ------------------------------------------------- fleet-health unit pieces
def test_fleet_ewma_and_assignable_ordering():
    fh = FleetHealth(RatelessConfig(ewma_alpha=0.5))
    fh.observe_success(0, 1.0)
    fh.observe_success(0, 0.5)
    assert fh.worker(0).ewma_latency_s == pytest.approx(0.75)
    fh.observe_success(1, 0.1)
    # unknown worker 2 ranks FIRST (optimism), then fastest EWMA
    assert fh.assignable((0, 1, 2), set(), now=0.0) == [2, 1, 0]
    # busy workers drop out of the assignable view
    assert fh.assignable((0, 1, 2), {2}, now=0.0) == [1, 0]


def test_fleet_backoff_is_exponential_capped_and_deterministic():
    cfg = RatelessConfig(backoff_base_s=0.1, backoff_max_s=0.4,
                         backoff_jitter=0.25, quarantine_after=99)
    fh = FleetHealth(cfg)
    pauses = []
    for _ in range(4):
        fh.observe_failure(3, now=0.0)
        pauses.append(fh.worker(3).next_ok_at)
    for pause, nominal in zip(pauses, (0.1, 0.2, 0.4, 0.4)):
        assert nominal * 0.75 <= pause <= nominal * 1.25
    # deterministic: a fresh tracker replays the identical jitter
    fh2 = FleetHealth(cfg)
    for k in range(4):
        fh2.observe_failure(3, now=0.0)
        assert fh2.worker(3).next_ok_at == pauses[k]
    # a worker inside its backoff window is not assignable, then is again
    assert fh.assignable((3,), set(), now=0.0) == []
    assert fh.assignable((3,), set(), now=1.0) == [3]


def test_fleet_quarantine_paths_and_probation():
    cfg = RatelessConfig(quarantine_after=2, probation_cooldown_s=10.0)
    fh = FleetHealth(cfg)
    # path 1: consecutive failures
    fh.observe_failure(0, now=0.0)
    assert not fh.worker(0).quarantined
    fh.observe_failure(0, now=1.0)
    assert fh.worker(0).quarantined
    # path 2: ONE tamper is enough
    fh.observe_tamper(1, now=1.0)
    assert fh.worker(1).quarantined and fh.worker(1).tampers == 1
    assert fh.live((0, 1, 2)) == [2]
    # probation respects the cooldown and the busy set
    assert fh.probation_due((0, 1, 2), set(), now=5.0) == []
    assert fh.probation_due((0, 1, 2), set(), now=12.0) == [0, 1]
    assert fh.probation_due((0, 1, 2), {0}, now=12.0) == [1]
    # a passed probe re-admits and resets the failure streak
    fh.readmit(0, now=12.0, latency_s=0.2)
    w = fh.worker(0)
    assert not w.quarantined and w.consecutive_failures == 0
    assert w.probes_passed == 1 and w.quarantine_count == 1
    # success resets the streak without touching quarantine bookkeeping
    fh.observe_failure(2, now=0.0)
    fh.observe_success(2, 0.1)
    assert fh.worker(2).consecutive_failures == 0


def test_fleet_next_wakeup_bounds_the_stall_sleep():
    cfg = RatelessConfig(backoff_base_s=0.2, backoff_jitter=0.0,
                         probation_cooldown_s=1.0, quarantine_after=99)
    fh = FleetHealth(cfg)
    assert fh.next_wakeup((0, 1), now=0.0) is None  # nothing benched
    fh.observe_failure(0, now=0.0)  # backoff expires at 0.2
    fh.observe_tamper(1, now=0.0)  # probation due at 1.0
    assert fh.next_wakeup((0, 1), now=0.0) == pytest.approx(0.2)
    assert fh.next_wakeup((0, 1), now=0.5) == pytest.approx(0.5)
    assert fh.next_wakeup((0, 1), now=2.0) == 0.0


# --------------------------------------------------- degradation + probation
def test_degradation_ladder_completes_inline_when_fleet_is_dark():
    """Every worker quarantined before the session starts → the client
    computes every strip itself; the answer is still verified."""
    client = SPDCClient(rateless=RatelessConfig(probation_cooldown_s=60.0))
    for wid in range(N):
        client.fleet.observe_tamper(wid, now=time.monotonic())
    m = _wellcond(16, seed=23)
    with ThreadPoolTransport() as tp:
        out = client.open_session(m, N).run(tp)
    assert out.verified
    assert out.report.fleet.inline_strips == out.report.fleet.num_strips
    assert out.report.fleet.dispatches == 0
    ws, wl = np.linalg.slogdet(m)
    assert out.det.sign == ws
    np.testing.assert_allclose(out.det.logabs, wl, rtol=1e-8)


def test_degradation_ladder_when_every_worker_tampers():
    """All N workers tamper: per-strip probes burn through max_attempts,
    the whole fleet lands in quarantine, and the ladder's last rung
    (inline completion) still produces a verified determinant."""
    cfg = RatelessConfig(max_attempts=2, probation_cooldown_s=60.0)
    plan = tuple(
        ServerFault(server=s, kind="tamper", mode="block", magnitude=0.5)
        for s in range(N)
    )
    client = SPDCClient(rateless=cfg, recover=True)
    m = _wellcond(16, seed=29)
    with ThreadPoolTransport() as tp:
        out = client.open_session(m, N, faults=plan).run(tp)
    assert out.verified
    assert out.report.fleet.inline_strips > 0
    assert out.report.fleet.tampered_strips >= 1
    assert all(w["quarantined"] for w in out.report.fleet.workers.values())


def test_probation_probe_readmits_transient_offender():
    """A worker benched by stale health state earns its way back through
    the probation probe (a re-issue of an already-verified strip) and is
    then assigned real work again."""
    cfg = RatelessConfig(probation_cooldown_s=0.0)
    client = SPDCClient(rateless=cfg)
    # bench worker 3 with PRE-SESSION state (transient flake, now healthy)
    client.fleet.observe_tamper(3, now=time.monotonic() - 1.0)
    m = _wellcond(24, seed=31, batch=4)
    with ThreadPoolTransport() as tp:
        out = client.open_session(m, N).run(tp)
    assert np.asarray(out.verified).all()
    assert out.report.fleet.probes >= 1
    w3 = out.report.fleet.workers[3]
    assert not w3["quarantined"] and w3["probes_passed"] >= 1


def test_probation_probe_keeps_persistent_tamperer_benched():
    """The probe rides the wire as attempt 0, so a persistently tampering
    worker corrupts the probe too and stays quarantined.

    cooldown 0 makes the probe deterministic: the worker is probation-due
    in the same scheduler iteration that re-streams its tampered strip,
    so the probe cannot race the session finishing (a nonzero cooldown
    flakes when the remaining strips complete inside the window)."""
    cfg = RatelessConfig(probation_cooldown_s=0.0)
    plan = ServerFault(server=1, kind="tamper", mode="single", target="u",
                       magnitude=100.0)
    client = SPDCClient(rateless=cfg, recover=True)
    m = _wellcond(24, seed=37, batch=4)
    with ThreadPoolTransport() as tp:
        out = client.open_session(m, N, faults=plan).run(tp)
    assert np.asarray(out.verified).all()
    w1 = out.report.fleet.workers[1]
    assert w1["quarantined"] and w1["probes_passed"] == 0
    assert w1["tampers"] >= 2  # the original strike plus failed probe(s)


def test_rateless_recovery_reroutes_to_live_worker():
    """collect()-level healing on a rateless session re-streams the strip
    to a healthy worker chosen by fleet health (tamper=... corrupts the
    factors AFTER the scheduler, so only recovery can heal them)."""
    m = _wellcond(16, seed=41)
    client = SPDCClient(rateless=True, recover=True)
    client.fleet.observe_tamper(0, now=time.monotonic())

    def corrupt(l, u):
        u = np.asarray(u).copy()
        u[3, 3] += 50.0
        return jnp.asarray(np.asarray(l)), jnp.asarray(u)

    with ThreadPoolTransport() as tp:
        session = client.open_session(m, N, tamper=corrupt)
        out = session.run(tp)
    assert out.verified and out.report.recovery is not None and out.report.recovery.ok
    ws, wl = np.linalg.slogdet(m)
    np.testing.assert_allclose(out.det.logabs, wl, rtol=1e-8)


# ----------------------------------------------------------- gateway thread
def test_gateway_coalesces_rateless_sweeps():
    from repro.configs import SPDCGatewayConfig
    from repro.serve import SPDCGateway
    from repro.serve.queue import BucketKey

    cfg = SPDCGatewayConfig(
        name="gw-rateless-test", buckets=(32, 64), max_batch=4,
        pad_batches=False, spdc=SPDC_EDGE_RATELESS,
    )
    gw = SPDCGateway(cfg)
    mats = [_wellcond(k, seed=200 + k) for k in (20, 30, 32, 25)]
    rids = [gw.submit(m) for m in mats]
    gw.drain()
    for rid, m in zip(rids, mats):
        r = gw.take(rid)
        assert r is not None and r.verified
        ws, wl = np.linalg.slogdet(m)
        assert r.det.sign == ws
        np.testing.assert_allclose(r.det.logabs, wl, rtol=1e-8)
    # rateless is part of the coalescing identity AND the grid rule: a
    # per-request override must not share the default-config bucket
    key = gw._key_for(30, {})
    assert key.rateless and key.pad_to == 32
    assert key != gw._key_for(30, {"rateless": False})
    # buckets must divide into F strips, not merely N
    with pytest.raises(ValueError, match="rateless"):
        SPDCGateway(SPDCGatewayConfig(buckets=(12,), spdc=SPDC_EDGE_RATELESS))
    assert "rateless" in BucketKey(pad_to=64, num_servers=4).protocol_kwargs()


# ------------------------------------------------------------- chaos matrix
def _chaos_plans():
    delay = dict(kind="delay", delay_s=0.15, delay_dist="exponential")
    pareto = dict(kind="delay", delay_s=0.15, delay_dist="pareto",
                  delay_alpha=2.0)
    return {
        "tamper-pair": (
            ServerFault(server=0, kind="tamper", mode="block", magnitude=0.4),
            ServerFault(server=2, kind="tamper", mode="sign_flip"),
        ),
        "dropout-delay": (
            ServerFault(server=1, kind="dropout"),
            ServerFault(server=3, **delay),
        ),
        "pareto-tamper": (
            ServerFault(server=0, **pareto),
            ServerFault(server=1, kind="tamper", mode="single", target="l"),
        ),
        "exp-exp-dropout": (
            ServerFault(server=0, **delay),
            ServerFault(server=1, **delay),
            ServerFault(server=2, kind="dropout"),
        ),
    }


@pytest.mark.slow
@pytest.mark.chaos
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("plan_name", sorted(_chaos_plans()))
def test_chaos_matrix_rateless_survives_fault_plans(plan_name, seed):
    """Seeded chaos: every tamper × dropout × delay-distribution plan must
    end in a verified determinant matching the honest rateless run."""
    plan = _chaos_plans()[plan_name]
    B, n = 3, 24
    m = _wellcond(n, seed=100 + seed, batch=B)
    honest = outsource_determinant(m, N, rateless=True)
    cfg = RatelessConfig(request_timeout_s=0.3, probation_cooldown_s=0.2)
    client = SPDCClient(rateless=cfg, recover=True)
    with ThreadPoolTransport() as tp:
        out = client.open_session(m, N, faults=plan).run(tp)
    assert np.asarray(out.verified).all(), (plan_name, seed)
    np.testing.assert_allclose(_logabs(out), _logabs(honest), rtol=1e-10)
