"""repro.linalg — the shared-LU op plan and the differentiable ops.

Tier for DESIGN.md §12: `LinalgSession` (slogdet/solve/inv on ONE
verified outsourced factorization), the `secure_*` custom-VJP ops, the
TriSolve wire layer, the trust-boundary invariants (blinding, secret
probe lanes), and tamper/heal through the recovery machinery.

Runs on both CI legs: with JAX_ENABLE_X64=0 everything executes in f32
(tolerances widen with the dtype); tests comparing against the protocol's
f64-calibrated gradients carry `needs_x64`.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.messages import TriSolveResult, TriSolveTask
from repro.api.transport import InlineTransport, ThreadPoolTransport
from repro.core.faults import ServerFault
from repro.linalg import (
    LinalgSession,
    LinalgVerificationError,
    SecureLinalg,
    blind_rhs,
    outsource_solve,
    secure_inv,
    secure_slogdet,
    secure_solve,
)

X64 = bool(jax.config.jax_enable_x64)
needs_x64 = pytest.mark.skipif(
    not X64, reason="gradient bar calibrated against float64 protocol runs"
)

#: op-plan acceptance vs numpy references, by compute dtype
TOL = 1e-9 if X64 else 2e-3
N_SERVERS = 2


def _wellcond(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n)) + n * np.eye(n)


def _spd(n, seed=0, cond=50.0):
    """RBF-like SPD matrix — the GP workload's shape (near-worst no-pivot
    input when growth_safe is off)."""
    rng = np.random.default_rng(seed)
    x = np.sort(rng.uniform(-3, 3, n))
    k = np.exp(-0.5 * (x[:, None] - x[None, :]) ** 2)
    return k + (np.trace(k) / (n * cond)) * np.eye(n)


# ------------------------------------------------------------ the op plan


def test_session_one_factorization_many_ops():
    """The whole point: slogdet + solve + adjoint solve + inv on ONE
    factorization, each op verified (Q2-accepted factors, Q3-checked,
    per-round residual checks)."""
    m = _wellcond(12, seed=3)
    b = np.arange(12, dtype=float)
    s = LinalgSession(m, N_SERVERS)
    sign, logabs = s.slogdet()
    y = s.solve(b)
    yt = s.solve(b, transpose=True)
    inv = s.inv()
    assert s.factorizations == 1
    ws, wl = np.linalg.slogdet(m)
    assert sign == ws and np.isclose(logabs, wl, rtol=TOL)
    np.testing.assert_allclose(y, np.linalg.solve(m, b), rtol=0, atol=TOL)
    np.testing.assert_allclose(yt, np.linalg.solve(m.T, b), rtol=0,
                               atol=TOL)
    np.testing.assert_allclose(inv, np.linalg.inv(m), rtol=0, atol=TOL)
    rep = s.report
    ops = [o.op for o in rep.ops]
    assert ops == ["factor", "slogdet", "solve", "solve_t", "inv"]
    assert all(o.verified for o in rep.ops)
    # inv is cached: asking again (either orientation) adds no round
    s.inv(transpose=True)
    assert len(s.report.ops) == len(rep.ops)
    assert s.factorizations == 1


@pytest.mark.parametrize("mode", ["ewd", "ewm"])
@pytest.mark.parametrize("growth_safe", [True, False])
def test_solve_inv_match_numpy_across_cipher_variants(mode, growth_safe):
    """(mode, growth_safe) × seeds: the case table of B⁻¹ recoveries must
    hold for every rotation degree the seeds land on."""
    seen_k = set()
    for seed in range(6):
        m = _wellcond(9, seed=seed)
        b = np.linspace(-1, 1, 9)
        s = LinalgSession(m, N_SERVERS, mode=mode, growth_safe=growth_safe)
        np.testing.assert_allclose(
            s.solve(b), np.linalg.solve(m, b), rtol=0, atol=TOL
        )
        np.testing.assert_allclose(
            s.inv(), np.linalg.inv(m), rtol=0, atol=TOL
        )
        seen_k.add(s._meta.rotate_k % 4)
    assert len(seen_k) >= 2, "seeds never varied the rotation degree"


def test_solve_matrix_rhs_and_transpose():
    m = _wellcond(10, seed=7)
    b = np.random.default_rng(7).standard_normal((10, 3))
    s = LinalgSession(m, N_SERVERS)
    np.testing.assert_allclose(
        s.solve(b), np.linalg.solve(m, b), rtol=0, atol=TOL
    )
    np.testing.assert_allclose(
        s.solve(b, transpose=True), np.linalg.solve(m.T, b), rtol=0,
        atol=TOL,
    )
    assert s.factorizations == 1


def test_growth_safe_default_survives_spd_kernels():
    """rot90 of an SPD kernel matrix is a catastrophic no-pivot input
    (growth ~1e18 at n=64); the session's growth_safe default must keep
    the GP workload's matrices solvable."""
    m = _spd(24, seed=0, cond=500.0)
    s = LinalgSession(m, N_SERVERS)  # growth_safe unspecified -> ON
    inv = s.inv()
    err = np.linalg.norm(inv @ m - np.eye(24)) / np.linalg.norm(inv)
    assert err < (1e-8 if X64 else 1e-2)


def test_session_rejects_nonsquare_and_bad_rhs():
    with pytest.raises(ValueError, match="square"):
        LinalgSession(np.ones((3, 4)), N_SERVERS)
    s = LinalgSession(_wellcond(6), N_SERVERS)
    with pytest.raises(ValueError, match="does not match"):
        s.solve(np.ones(7))


def test_outsource_solve_facade():
    """The gateway's audited one-shot path: factor+verify+solve inside."""
    m = _wellcond(8, seed=11)
    b = np.ones(8)
    y, s = outsource_solve(m, b, N_SERVERS)
    np.testing.assert_allclose(y, np.linalg.solve(m, b), rtol=0, atol=TOL)
    assert s.factorizations == 1
    yt, _ = outsource_solve(m, b, N_SERVERS, transpose=True)
    np.testing.assert_allclose(yt, np.linalg.solve(m.T, b), rtol=0,
                               atol=TOL)


# ------------------------------------------------- trust boundary invariants


class _RecordingTransport(InlineTransport):
    """Delegate that captures every TriSolveTask the session ships."""

    def __init__(self):
        super().__init__()
        self.shipped = []

    def solve_shards(self, tasks, faults=(), timeout=None):
        self.shipped.extend(tasks)
        return super().solve_shards(tasks, faults=faults, timeout=timeout)


def test_secret_rhs_never_crosses_in_the_clear():
    """Masked rounds ship rhs + X'·C, never the plaintext right-hand side
    (nor its v-scaled sibling); inverse rounds ship only permutation
    columns."""
    m = _wellcond(10, seed=5)
    b = np.random.default_rng(5).standard_normal(10)
    t = _RecordingTransport()
    s = LinalgSession(m, N_SERVERS, transport=t)
    s.solve(b)
    s.inv()
    n = 10
    # the masked solve round ships one single-column chunk; the inverse
    # round fans the n identity columns out wide (the round's transpose
    # flag varies with the cipher's rotation plan, its width does not)
    narrow = [np.asarray(tk.rhs) for tk in t.shipped
              if np.asarray(tk.rhs).shape[1] <= 2]
    assert narrow, "no masked solve-round tasks captured"
    masked = np.concatenate(narrow, axis=1)
    # the pad C has ~‖b‖ scale: the wire chunk must differ from both b
    # and b/v (EWD pre-scaling) everywhere, not just somewhere
    v = s._v
    for cand in (b, b / v):
        assert not np.any(
            np.isclose(masked[:n, 0], cand, rtol=1e-3, atol=1e-9)
        ), "plaintext RHS entries visible on the wire"
    # wide (inverse) round: strictly public entries, a 0/1 permutation
    wide = [np.asarray(tk.rhs) for tk in t.shipped
            if np.asarray(tk.rhs).shape[1] >= n // 2]
    assert wide and all(
        set(np.unique(w.round(12))) <= {0.0, 1.0} for w in wide
    ), "inverse rounds must ship only permutation columns"


def test_blind_rhs_roundtrip_and_freshness():
    rng = np.random.default_rng(0)
    x_aug = rng.standard_normal((12, 12))
    rhs = rng.standard_normal((12, 2))
    digest = b"\x07" * 32
    shipped, c = blind_rhs(rhs, x_aug, digest, 0, 0)
    np.testing.assert_allclose(shipped - x_aug @ c, rhs, atol=1e-12)
    # transpose rounds pad through X'ᵀ
    shipped_t, c_t = blind_rhs(rhs, x_aug, digest, 1, 1)
    np.testing.assert_allclose(shipped_t - x_aug.T @ c_t, rhs, atol=1e-12)
    # fresh pad per round index — no two-time pad
    s2, c2 = blind_rhs(rhs, x_aug, digest, 1, 0)
    assert not np.allclose(c, c2)


def test_probe_lanes_are_domain_separated():
    from repro.linalg.session import _lane_rng

    d = b"\x01" * 32
    a = _lane_rng(d, b"trisolve-probe", 0, 0, 0).standard_normal(8)
    b = _lane_rng(d, b"trisolve-mask", 0, 0, 0).standard_normal(8)
    c = _lane_rng(d, b"trisolve-probe", 0, 0, 1).standard_normal(8)
    again = _lane_rng(d, b"trisolve-probe", 0, 0, 0).standard_normal(8)
    assert not np.allclose(a, b) and not np.allclose(a, c)
    np.testing.assert_array_equal(a, again)


# ------------------------------------------------------------- tamper / heal


def _corrupting(cls):
    """Transport subclass that tampers the first solve chunk of every
    initial dispatch (attempt 0) — the factorization stays honest, so
    the heal under test is the TRISOLVE one."""
    class Corrupting(cls):
        def solve_shards(self, tasks, faults=(), timeout=None):
            out = super().solve_shards(tasks, faults=faults,
                                       timeout=timeout)
            if tasks and tasks[0].attempt == 0:
                from dataclasses import replace
                out[0] = replace(out[0], y=np.asarray(out[0].y) * 3.0)
            return out

    return Corrupting


@pytest.mark.parametrize("transport_cls", [InlineTransport,
                                           ThreadPoolTransport])
def test_trisolve_tamper_localizes_and_heals(transport_cls):
    """A tampered solve chunk fails the per-chunk residual check; the
    round localizes it and recover_solve re-issues to a replacement."""
    m = _wellcond(12, seed=9)
    b = np.random.default_rng(9).standard_normal(12)
    with _corrupting(transport_cls)() as t:
        s = LinalgSession(m, N_SERVERS, transport=t)
        y = s.solve(b)
    np.testing.assert_allclose(y, np.linalg.solve(m, b), rtol=0, atol=TOL)
    rep = s.report
    solve_ops = [o for o in rep.ops if o.op.startswith("solve")]
    assert solve_ops and solve_ops[0].healed >= 1
    assert all(o.verified for o in rep.ops)


@needs_x64
def test_fault_plan_tamper_heals_factorization_and_round():
    """The `faults=` plan corrupts the named server's LU strip AND its
    solve chunks; both layers localize and heal. (f64 only: the f32 Q2
    eps is scale²-widened far past a single-entry tamper, so the f32 leg
    fail-stops at the session's Q3 instead of healing — tested above via
    transport-level corruption.)"""
    m = _wellcond(12, seed=9)
    b = np.random.default_rng(9).standard_normal(12)
    s = LinalgSession(
        m, N_SERVERS, faults=ServerFault(server=0, magnitude=50.0),
    )
    y = s.solve(b)
    np.testing.assert_allclose(y, np.linalg.solve(m, b), rtol=0, atol=TOL)
    assert all(o.verified for o in s.report.ops)
    assert any(o.healed >= 1 for o in s.report.ops)


def test_trisolve_dropout_heals():
    m = _wellcond(10, seed=4)
    s = LinalgSession(
        m, N_SERVERS,
        faults=ServerFault(server=1, kind="dropout"),
    )
    inv = s.inv()
    np.testing.assert_allclose(inv, np.linalg.inv(m), rtol=0, atol=TOL)
    assert any(o.healed >= 1 for o in s.report.ops)


def test_trisolve_tamper_recover_false_raises():
    """Corrupt ONLY the solve round (the factorization stays honest, so
    the failure is the trisolve check, not Authenticate)."""
    class _Tamper(InlineTransport):
        def solve_shards(self, tasks, faults=(), timeout=None):
            out = super().solve_shards(tasks, faults=faults,
                                       timeout=timeout)
            from dataclasses import replace
            out[0] = replace(out[0], y=np.asarray(out[0].y) * 3.0)
            return out

    m = _wellcond(10, seed=2)
    with _Tamper() as t:
        s = LinalgSession(m, N_SERVERS, transport=t, recover=False)
        with pytest.raises(LinalgVerificationError, match="recover=False"):
            s.solve(np.ones(10))


# ----------------------------------------------------------------- wire layer


def test_trisolve_wire_roundtrip():
    rng = np.random.default_rng(1)
    task = TriSolveTask(
        server=1, num_servers=3,
        l=np.tril(rng.standard_normal((6, 6))),
        u=np.triu(rng.standard_normal((6, 6))),
        rhs=rng.standard_normal((6, 2)),
        subseed=b"\xaa" * 16, transpose=1, col0=2, attempt=1,
        session_id="sess-1",
    )
    back = TriSolveTask.from_bytes(task.to_bytes())
    assert (back.server, back.num_servers, back.subseed, back.transpose,
            back.col0, back.attempt, back.session_id) == \
        (1, 3, b"\xaa" * 16, 1, 2, 1, "sess-1")
    np.testing.assert_array_equal(back.l, task.l)
    np.testing.assert_array_equal(back.u, task.u)
    np.testing.assert_array_equal(back.rhs, task.rhs)
    assert back.n == 6 and back.cols == 2

    res = TriSolveResult(server=1, y=rng.standard_normal((6, 2)),
                         subseed=b"\xbb" * 16, transpose=1, col0=2,
                         attempt=1, session_id="sess-1")
    rback = TriSolveResult.from_bytes(res.to_bytes())
    np.testing.assert_array_equal(rback.y, res.y)
    assert rback.subseed == b"\xbb" * 16 and rback.col0 == 2


def test_stale_echo_rejected():
    """A replayed chunk from another dispatch fails the echo binding
    before any math — and heals."""
    class _Replay(InlineTransport):
        def solve_shards(self, tasks, faults=(), timeout=None):
            out = super().solve_shards(tasks, faults=faults,
                                       timeout=timeout)
            if tasks and tasks[0].attempt == 0:
                from dataclasses import replace
                out[0] = replace(out[0], subseed=b"\x00" * 16)
            return out

    m = _wellcond(10, seed=6)
    with _Replay() as t:
        s = LinalgSession(m, N_SERVERS, transport=t)
        y = s.solve(np.ones(10))
    np.testing.assert_allclose(y, np.linalg.solve(m, np.ones(10)),
                               rtol=0, atol=TOL)
    assert any(o.healed >= 1 for o in s.report.ops)


# ------------------------------------------------------- differentiable ops


def test_secure_ops_forward_match():
    m = _wellcond(10, seed=8)
    b = np.random.default_rng(8).standard_normal(10)
    ctx = SecureLinalg(N_SERVERS)
    sign, logabs = secure_slogdet(m, linalg=ctx)
    y = secure_solve(m, b, linalg=ctx)
    inv = secure_inv(m, linalg=ctx)
    ws, wl = np.linalg.slogdet(m)
    assert float(sign) == ws and np.isclose(float(logabs), wl, rtol=TOL)
    np.testing.assert_allclose(np.asarray(y), np.linalg.solve(m, b),
                               rtol=0, atol=TOL)
    np.testing.assert_allclose(np.asarray(inv), np.linalg.inv(m),
                               rtol=0, atol=TOL)
    # all three ops (and their rounds) on one session, one factorization
    assert len(ctx._sessions) == 1
    assert sum(s.factorizations for s in ctx._sessions.values()) == 1


def test_secure_ops_validate_shapes():
    ctx = SecureLinalg(N_SERVERS)
    with pytest.raises(ValueError, match="square"):
        secure_slogdet(jnp.ones((2, 3)), linalg=ctx)
    with pytest.raises(ValueError, match="square"):
        secure_inv(jnp.ones((2, 3)), linalg=ctx)
    with pytest.raises(ValueError, match="rhs shape"):
        secure_solve(jnp.eye(3), jnp.ones(4), linalg=ctx)


@needs_x64
def test_gp_loglik_grad_matches_reference():
    """The acceptance bar: jax.grad of a jitted GP log-likelihood through
    secure_slogdet + secure_solve matches the plaintext reference to
    1e-6, with Q2+Q3-verified ops and exactly one factorization."""
    n = 24
    rng = np.random.default_rng(0)
    x = jnp.asarray(np.sort(rng.uniform(-3, 3, n)))
    yv = jnp.asarray(np.sin(2 * np.asarray(x))
                     + 0.1 * rng.standard_normal(n))
    ctx = SecureLinalg(N_SERVERS)

    def cov(theta):
        d2 = (x[:, None] - x[None, :]) ** 2
        k = jnp.exp(2 * theta[1]) * jnp.exp(
            -0.5 * d2 / jnp.exp(2 * theta[0]))
        return k + jnp.exp(2 * theta[2]) * jnp.eye(n)

    def nll_secure(theta):
        c = cov(theta)
        _, logdet = secure_slogdet(c, linalg=ctx)
        alpha = secure_solve(c, yv, linalg=ctx)
        return 0.5 * (logdet + yv @ alpha)

    def nll_ref(theta):
        c = cov(theta)
        _, logdet = jnp.linalg.slogdet(c)
        return 0.5 * (logdet + yv @ jnp.linalg.solve(c, yv))

    theta = jnp.asarray([np.log(0.8), 0.0, np.log(0.2)])
    val, grad = jax.jit(jax.value_and_grad(nll_secure))(theta)
    rval, rgrad = jax.jit(jax.value_and_grad(nll_ref))(theta)
    assert np.isclose(float(val), float(rval), rtol=1e-9)
    gerr = float(jnp.max(jnp.abs(grad - rgrad))
                 / (jnp.max(jnp.abs(rgrad)) + 1e-30))
    assert gerr < 1e-6, gerr
    sessions = list(ctx._sessions.values())
    assert len(sessions) == 1 and sessions[0].factorizations == 1
    assert all(o.verified for o in sessions[0].report.ops)


def test_grad_works_without_x64_leg():
    """The f32 leg still differentiates end-to-end (looser bar)."""
    m = _wellcond(8, seed=10)
    ctx = SecureLinalg(N_SERVERS)

    def f(a):
        _, logdet = secure_slogdet(a, linalg=ctx)
        return logdet

    g = jax.grad(f)(jnp.asarray(m))
    ref = np.linalg.inv(m).T
    np.testing.assert_allclose(np.asarray(g), ref, rtol=0,
                               atol=1e-8 if X64 else 1e-2)
    assert sum(s.factorizations for s in ctx._sessions.values()) == 1


def test_solve_vjp_adjoint_round():
    """b̄ = M⁻ᵀz̄ comes back through the same session; ā = −b̄zᵀ."""
    m = _wellcond(8, seed=12)
    b = np.random.default_rng(12).standard_normal(8)
    ctx = SecureLinalg(N_SERVERS)

    def f(a, rhs):
        z = secure_solve(a, rhs, linalg=ctx)
        return jnp.sum(z ** 2)

    ga, gb = jax.grad(f, argnums=(0, 1))(jnp.asarray(m), jnp.asarray(b))
    z = np.linalg.solve(m, b)
    gbar = np.linalg.solve(m.T, 2 * z)
    np.testing.assert_allclose(np.asarray(gb), gbar, rtol=0,
                               atol=1e-8 if X64 else 1e-2)
    np.testing.assert_allclose(np.asarray(ga), -np.outer(gbar, z),
                               rtol=0, atol=1e-8 if X64 else 1e-2)
    assert sum(s.factorizations for s in ctx._sessions.values()) == 1


def test_session_cache_eviction():
    ctx = SecureLinalg(N_SERVERS, max_sessions=2)
    for seed in range(3):
        ctx.session_for(_wellcond(6, seed=seed))
    assert len(ctx._sessions) == 2
    ctx.clear()
    assert not ctx._sessions
