"""Distributed SPDC pipeline (shard_map) + sharding rules + SDC checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import freivalds_residual, outsource_determinant, sdc_flag
from repro.core.lu import lu_nserver
from repro.distrib.sharding import make_rules, use_rules
from repro.distrib.spdc_pipeline import (
    lu_nserver_shardmap, pipeline_collective_bytes,
)


def _wellcond(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, n)) + n * np.eye(n))


@pytest.mark.parametrize("program", ["baseline", "exact", "stream"])
@pytest.mark.parametrize("n,servers", [(16, 4), (24, 8), (32, 2), (40, 5)])
def test_shardmap_matches_reference(n, servers, program):
    x = _wellcond(n, seed=servers)
    l, u = lu_nserver_shardmap(x, servers, program=program)
    l2, u2, _ = lu_nserver(x, servers)
    np.testing.assert_allclose(np.asarray(l), np.asarray(l2), atol=1e-9)
    np.testing.assert_allclose(np.asarray(u), np.asarray(u2), atol=1e-9)


def test_shardmap_exact_relay_shim_removed():
    """The exact_relay deprecation cycle is finished: the parameter is
    gone, so passing it is a TypeError — not a silent bool reinterpret."""
    x = _wellcond(16, seed=1)
    with pytest.raises(TypeError, match="exact_relay"):
        lu_nserver_shardmap(x, 4, exact_relay=True)
    ref_l, ref_u = lu_nserver_shardmap(x, 4, program="exact")
    np.testing.assert_allclose(np.asarray(ref_l @ ref_u), np.asarray(x),
                               atol=1e-9)


def test_shardmap_rejects_unknown_program():
    with pytest.raises(ValueError, match="unknown program"):
        lu_nserver_shardmap(_wellcond(16), 4, program="telepathy")


def test_shardmap_hlo_is_one_way():
    """The distributed pipeline must contain collective-permutes (the
    one-way relay) and no all-gather/all-reduce (no broadcast pattern)."""
    n, servers = 16, 4
    from functools import partial

    from repro.distrib.spdc_pipeline import _server_program
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh, shard_map

    mesh = make_mesh((servers,), ("servers",), devices=jax.devices()[:servers])
    fn = shard_map(
        partial(_server_program, n=n, b=n // servers, num_servers=servers,
                axis="servers"),
        mesh=mesh, in_specs=P("servers", None),
        out_specs=(P("servers", None), P("servers", None)),
    )
    txt = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float64)
    ).compile().as_text()
    assert "collective-permute" in txt
    assert "all-gather" not in txt
    assert "all-reduce" not in txt


def test_distributed_protocol_end_to_end():
    m = np.asarray(_wellcond(24, seed=3))
    res = outsource_determinant(m, 4, distributed=True)
    want_s, want_la = np.linalg.slogdet(m)
    assert res.verified and res.det.sign == want_s
    np.testing.assert_allclose(res.det.logabs, want_la, rtol=1e-9)


def test_comm_model_overcount_bounded():
    info = pipeline_collective_bytes(1024, 8)
    assert info["paper_exact_bytes"] < info["relay_bytes"]
    # relay = N·n² vs paper ≈ n²·N/3 asymptotically → factor ≤ ~3 for large
    # N, 4 at N=2 (the relay's fixed n×n hop vs one half-filled message)
    assert info["overcount_factor"] <= 4.0


# ----------------------------------------------------------- sharding rules
def test_rules_head_fallback():
    from repro.compat import make_mesh

    mesh = make_mesh((2, 4), ("data", "model"), devices=jax.devices())
    r1 = make_rules(mesh, num_heads=8, num_kv_heads=4)
    assert r1.shard_heads and r1.shard_kv
    r2 = make_rules(mesh, num_heads=6, num_kv_heads=1)  # 6 % 4 != 0
    assert not r2.shard_heads and not r2.shard_kv
    assert r2.resolve("batch", "qseq", "heads", None) == jax.sharding.PartitionSpec(
        ("data",), "model", None, None
    )


def test_constrain_noop_without_rules():
    from repro.distrib.sharding import constrain

    x = jnp.ones((4, 4))
    assert constrain(x, "batch", None) is x


def test_sharded_train_step_runs():
    """Integration: tiny model, real mesh, sharded params, one train step."""
    from repro.configs import smoke_config
    from repro.models.common import split_tree
    from repro.models.lm import init_lm
    from repro.train.data import SyntheticLM
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.steps import build_train_step
    from jax.sharding import NamedSharding

    cfg = smoke_config("tinyllama-1.1b")
    from repro.compat import make_mesh

    mesh = make_mesh((2, 4), ("data", "model"), devices=jax.devices())
    rules = make_rules(mesh, num_heads=cfg.num_heads,
                       num_kv_heads=cfg.num_kv_heads)
    with use_rules(rules):
        px = init_lm(cfg, jax.random.key(0))
        params, specs = split_tree(px)
        params = jax.tree.map(
            lambda v, s: jax.device_put(
                v, NamedSharding(mesh, rules.resolve(*s))
            ),
            params, specs,
        )
        opt_cfg = AdamWConfig(lr=1e-3)
        opt = init_opt_state(params, opt_cfg)
        step = jax.jit(build_train_step(cfg, opt_cfg))
        batch = SyntheticLM(cfg).batch(0, 8, 32)
        p2, o2, metrics = step(params, opt, batch, jax.random.key(1))
        assert np.isfinite(float(metrics["loss"]))
        # params actually sharded
        emb = p2["embed"]
        assert len(emb.sharding.device_set) == 8


# ------------------------------------------------------------------ SDC
def test_freivalds_accepts_correct_and_rejects_corrupt():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((64, 32)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal((32, 48)), dtype=jnp.float32)
    c = a @ b
    key = jax.random.key(0)
    r_ok = freivalds_residual(a, b, c, key)
    assert not bool(sdc_flag(r_ok))
    c_bad = c.at[5, 7].add(1.0)  # one corrupted element
    r_bad = freivalds_residual(a, b, c_bad, key)
    assert bool(sdc_flag(r_bad))


def test_sdc_in_train_step():
    from repro.configs import smoke_config
    from repro.models.common import split_tree
    from repro.models.lm import init_lm
    from repro.train.data import SyntheticLM
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.steps import build_train_step

    cfg = smoke_config("tinyllama-1.1b")
    params, _ = split_tree(init_lm(cfg, jax.random.key(0)))
    opt_cfg = AdamWConfig()
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(build_train_step(cfg, opt_cfg, sdc_check=True))
    batch = SyntheticLM(cfg).batch(0, 4, 128)
    _, _, metrics = step(params, opt, batch, jax.random.key(1))
    assert float(metrics["sdc_residual"]) < 1e-3
