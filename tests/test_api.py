"""Role-split SPDC API (DESIGN.md §7): wire-format round-trips, the
no-plaintext trust boundary, transport equivalence (inline vs threadpool
vs multiprocess), and the multiprocess acceptance end-to-end — N=4 real
worker processes, a tampering server localized and healed via
re-dispatched ShardTasks, det matching the honest run at rtol 1e-10."""
import inspect

import numpy as np
import pytest

from repro.api import (
    BoundaryViolation,
    EdgeServer,
    FaultPlanFrame,
    InlineTransport,
    MultiprocessTransport,
    ShardResult,
    ShardTask,
    SPDCClient,
    ThreadPoolTransport,
    TransportError,
    TransportTimeout,
    WireError,
    decode_message,
    resolve_transport,
)
from repro.api import wire
from repro.core import (
    Determinant,
    ServerFault,
    Verdict,
    authenticate,
    lu_nserver,
    outsource_determinant,
)

N = 4


def _wellcond(n, seed=0, batch=None, dtype=np.float64):
    rng = np.random.default_rng(seed)
    if batch is None:
        return (rng.standard_normal((n, n)) + n * np.eye(n)).astype(dtype)
    return (rng.standard_normal((batch, n, n))
            + n * np.eye(n)).astype(dtype)


# ------------------------------------------------------------- wire format
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("batch", [None, 3])
def test_wire_roundtrip_shard_task(dtype, batch):
    x_row = _wellcond(8, seed=1, dtype=dtype)[:2] if batch is None else \
        _wellcond(8, seed=1, batch=batch, dtype=dtype)[:, :2]
    up = None if batch is None else x_row[..., :1, :].astype(dtype)
    t = ShardTask(server=1, num_servers=4, x_row=x_row,
                  subseed=b"\x07" * 32, style="nserver", attempt=2,
                  u_upstream=up, session_id="abc123")
    t2 = ShardTask.from_bytes(t.to_bytes())
    assert (t2.server, t2.num_servers, t2.style, t2.attempt) == (1, 4, "nserver", 2)
    assert t2.subseed == t.subseed and t2.session_id == "abc123"
    assert t2.x_row.dtype == dtype
    np.testing.assert_array_equal(t2.x_row, x_row)  # bit-exact
    if up is None:
        assert t2.u_upstream is None
    else:
        np.testing.assert_array_equal(t2.u_upstream, up)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("batch", [None, 2])
def test_wire_roundtrip_shard_result(dtype, batch):
    strip = _wellcond(8, seed=2, batch=batch, dtype=dtype)
    strip = strip[..., :2, :]
    r = ShardResult(server=3, l_row=strip, u_row=2 * strip,
                    subseed=b"\x01" * 32, attempt=1, session_id="ff")
    r2 = ShardResult.from_bytes(r.to_bytes())
    assert r2.server == 3 and r2.attempt == 1 and r2.subseed == r.subseed
    assert r2.l_row.dtype == dtype and r2.u_row.dtype == dtype
    np.testing.assert_array_equal(r2.l_row, strip)
    np.testing.assert_array_equal(r2.u_row, 2 * strip)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("batch", [None, 3])
def test_wire_roundtrip_verdict(dtype, batch):
    import jax.numpy as jnp

    a = jnp.asarray(_wellcond(8, seed=3, batch=batch, dtype=dtype))
    l, u, _ = lu_nserver(a, 2)
    u_bad = u.at[..., 3, 3].multiply(1.5)  # force a reject → attribution
    v = authenticate(l, u_bad, a, num_servers=2)
    v2 = Verdict.from_bytes(v.to_bytes())
    assert v2.method == v.method and v2.num_servers == v.num_servers
    for f in ("ok", "residual", "eps", "culprit"):
        got, want = getattr(v2, f), getattr(v, f)
        if isinstance(want, np.ndarray):
            np.testing.assert_array_equal(got, want)
        else:
            assert got == want and type(got) is type(want)
    np.testing.assert_array_equal(v2.server_residual, v.server_residual)
    np.testing.assert_array_equal(v2.server_ok, v.server_ok)
    # accepting verdict: localization fields stay None through the wire
    v_ok = authenticate(l, u, a, num_servers=2)
    v_ok2 = Verdict.from_bytes(v_ok.to_bytes())
    assert v_ok2.server_residual is None and v_ok2.server_ok is None
    assert bool(np.all(v_ok2.ok))


def test_wire_roundtrip_determinant():
    for det in (
        Determinant(sign=-1.0, logabs=1234.56789012345678, dtype="float64"),
        Determinant(sign=1.0, logabs=-0.25, dtype="float32"),
        Determinant(sign=0.0, logabs=float("-inf"), dtype="float64"),
    ):
        d2 = Determinant.from_bytes(det.to_bytes())
        assert d2.sign == det.sign and d2.dtype == det.dtype
        assert d2.logabs == det.logabs  # bit-exact, ±inf included
    assert Determinant.from_bytes(
        Determinant(1.0, float("-inf")).to_bytes()
    ).is_zero()


def test_wire_roundtrip_fault_plan_frame():
    plan = (
        ServerFault(server=1, mode="block", magnitude=0.3),
        ServerFault(server=2, kind="dropout", matrices=(0, 2)),
    )
    f2 = FaultPlanFrame.from_bytes(FaultPlanFrame(plan).to_bytes())
    assert f2.plan == plan


def test_decode_message_dispatches_every_kind():
    t = ShardTask(server=0, num_servers=2,
                  x_row=_wellcond(4)[:2], subseed=b"\x02" * 32)
    r = ShardResult(server=0, l_row=_wellcond(4)[:2],
                    u_row=_wellcond(4)[:2])
    d = Determinant(sign=1.0, logabs=3.5)
    for msg, cls in [(t, ShardTask), (r, ShardResult), (d, Determinant),
                     (FaultPlanFrame(()), FaultPlanFrame)]:
        assert isinstance(decode_message(msg.to_bytes()), cls)


def test_wire_rejects_malformed_frames():
    good = Determinant(sign=1.0, logabs=1.0).to_bytes()
    with pytest.raises(WireError, match="magic"):
        wire.decode(b"JUNK" + good[4:])
    with pytest.raises(WireError):
        wire.decode(good[:10])  # truncated header
    t = ShardTask(server=0, num_servers=2, x_row=_wellcond(4)[:2],
                  subseed=b"\x03" * 32)
    with pytest.raises(WireError):  # truncated array body
        wire.decode(t.to_bytes()[:-16])
    with pytest.raises(WireError, match="expected ShardResult"):
        ShardResult.from_bytes(good)
    with pytest.raises(WireError, match="unknown message kind"):
        decode_message(wire.encode("Nonsense", {}, {}))


def test_wire_rejects_malicious_array_specs():
    """Header fields are attacker-controlled: a negative offset must raise
    WireError, never silently reinterpret header bytes as strip data."""
    import json
    import struct

    def tampered(mutate):
        frame = ShardResult(server=0, l_row=_wellcond(4)[:2],
                            u_row=_wellcond(4)[:2]).to_bytes()
        hlen = struct.unpack_from(">BI", frame, 4)[1]
        header = json.loads(frame[9 : 9 + hlen].decode())
        body = frame[wire._pad(9 + hlen):]
        mutate(header)
        hjson = json.dumps(header, separators=(",", ":")).encode()
        head = wire.MAGIC + struct.pack(">BI", wire.VERSION, len(hjson)) \
            + hjson
        return head.ljust(wire._pad(len(head)), b"\x00") + body

    def set_field(name, value):
        def mutate(header):
            header["arrays"][0][name] = value
        return mutate

    for bad in (set_field("offset", -64), set_field("nbytes", -8),
                set_field("shape", [-2, 4]), set_field("dtype", "O"),
                set_field("offset", "no"), set_field("shape", [3, 5])):
        with pytest.raises(WireError):
            wire.decode(tampered(bad))


# ----------------------------------------------------------- trust boundary
def test_shard_tasks_carry_no_plaintext_or_key_material():
    """The ISSUE's negative test: for every ShardTask of a session, the
    payload contains no verbatim plaintext entry, no blinding-vector
    entry, no Ψ — and does not correlate with the same-position plaintext
    block (the cipher rotated + scaled it away)."""
    from repro.core import keygen

    n = 24
    m = _wellcond(n, seed=11)
    client = SPDCClient()
    session = client.open_session(m, N)
    tasks = session.tasks(check_boundary=True)  # library-side screen
    seed = session.seeds[0]
    key = keygen(client.lambda2, seed, n)
    secrets = np.concatenate([[seed.psi], key.v])

    def informative(a):
        a = np.asarray(a).ravel()
        return a[(a != 0.0) & (np.abs(a) != 1.0)]

    assert len(tasks) == N
    assert {t.server for t in tasks} == set(range(N))
    for t in tasks:
        payload = informative(t.x_row)
        assert np.intersect1d(payload, informative(m)).size == 0
        assert np.intersect1d(payload, secrets).size == 0
        assert t.u_upstream is None  # relay is the transport's job
        assert len(t.subseed) == 32 and t.subseed != seed.digest
        # same-position correlation: the task's strip vs the plaintext's
        # strip at the same rows (padded to n') — rotation + row scaling
        # must have destroyed the alignment
        b = session.block
        rows = slice(t.server * b, min((t.server + 1) * b, n))
        plain = m[rows, :]
        if plain.size:
            crypt = np.asarray(t.x_row)[: plain.shape[0], : n]
            c = np.corrcoef(plain.ravel(), crypt.ravel())[0, 1]
            assert abs(c) < 0.5, f"server {t.server} strip correlates: {c}"


def test_boundary_violation_on_plaintext_payload():
    """If a (buggy) session were about to ship plaintext, tasks() must
    refuse — simulate by splicing the raw matrix into the ciphertext."""
    import jax.numpy as jnp

    n = 16
    m = _wellcond(n, seed=13)
    session = SPDCClient().open_session(m, N)
    session.x_aug = session.x_aug.at[:n, :n].set(jnp.asarray(m))
    with pytest.raises(BoundaryViolation, match="plaintext"):
        session.tasks(check_boundary=True)


# ------------------------------------------------- transport equivalence
@pytest.mark.parametrize("equilibrate", [False, True])
def test_inline_batched_matches_pre_split_fused_sweep(equilibrate):
    """Acceptance: the role split moved equilibrate+augment out of the
    old fused (equilibrate→augment→LU) jit program into the Session's
    PMOP. Both stages are exact in floating point, so the inline path
    must reproduce the pre-split fused program at rtol 1e-10 (observed:
    bit-identical)."""
    from functools import partial

    import jax
    import jax.numpy as jnp

    from repro.core.cipher import cipher_batch
    from repro.core.cipher import equilibrate as ced_equilibrate
    from repro.core.augment import augment, padding_for_servers
    from repro.core.decipher import decipher_batch
    from repro.core.keygen import keygen_batch
    from repro.core.seed import seedgen_batch

    B, n = 4, 24
    stack = _wellcond(n, seed=43, batch=B)

    # --- the pre-role-split fused server stage, verbatim ---
    @partial(jax.jit, static_argnames=("num_servers", "padding", "eq"))
    def fused(x, aug_key, *, num_servers, padding, eq):
        if eq:
            x, log2_scale = ced_equilibrate(x)
        else:
            log2_scale = jnp.zeros(x.shape[0], dtype=jnp.int32)
        x_aug = augment(x, padding, key=aug_key)
        l, u, _ = lu_nserver(x_aug, num_servers)
        return l, u, log2_scale

    seeds = seedgen_batch(128, stack)
    v = keygen_batch(128, seeds, n)
    x, metas = cipher_batch(jnp.asarray(stack), v, seeds)
    aug_key = jax.random.key(
        int.from_bytes(seeds[0].digest[8:16], "big") % (2**31)
    )
    l, u, log2_scale = fused(
        x, aug_key, num_servers=N,
        padding=padding_for_servers(n, N), eq=equilibrate,
    )
    want = decipher_batch(seeds, metas, l, u,
                          log2_scale=np.asarray(log2_scale))

    got = outsource_determinant(stack, N, equilibrate=equilibrate)
    assert np.asarray(got.verified).all()
    for i in range(B):
        assert got.dets[i].sign == want[i].sign
        np.testing.assert_allclose(got.dets[i].logabs, want[i].logabs,
                                   rtol=1e-10)

def test_threadpool_matches_inline_every_input_kind():
    m = _wellcond(20, seed=17)
    stack = _wellcond(16, seed=19, batch=3)
    mixed = [m, m[:9, :9], m[:14, :14]]
    with ThreadPoolTransport() as tp:
        for inp in (m, stack, mixed):
            a = outsource_determinant(inp, N)
            b = outsource_determinant(inp, N, transport=tp)
            if hasattr(a, "dets"):
                assert np.asarray(b.verified).all()
                for da, db in zip(a.dets, b.dets):
                    assert da.sign == db.sign
                    np.testing.assert_allclose(db.logabs, da.logabs,
                                               rtol=1e-12)
            else:
                assert b.verified
                assert a.det.sign == b.det.sign
                np.testing.assert_allclose(b.det.logabs, a.det.logabs,
                                           rtol=1e-12)


def test_session_roles_drive_manually():
    """The role API without the facade: client opens a session, an
    EdgeServer farm executes the relay task by task, the session collects
    ShardResults — same determinant as the one-call facade."""
    n = 20
    m = _wellcond(n, seed=23)
    client = SPDCClient(method="q2")
    session = client.open_session(m, N)
    edges = [EdgeServer(i) for i in range(N)]
    results, u_rows = [], []
    for task in session.tasks():
        if task.server > 0:
            task = task.with_upstream(np.concatenate(u_rows, axis=-2))
        res = edges[task.server].run(task)
        # round-trip every message through the wire, as a real remote
        # worker would see it
        res = ShardResult.from_bytes(res.to_bytes())
        results.append(res)
        u_rows.append(np.asarray(res.u_row))
    out = session.collect(results)
    ref = outsource_determinant(m, N, method="q2")
    assert out.verified
    assert out.det.sign == ref.det.sign
    np.testing.assert_allclose(out.det.logabs, ref.det.logabs, rtol=1e-12)


def test_threadpool_recovery_emits_fresh_shard_tasks():
    """Recovery over a message transport: the session re-issues ShardTasks
    with fresh sub-seeds; the healed det matches honest at rtol 1e-10."""
    from repro.distrib.recovery import dispatch_subseed

    m = _wellcond(16, seed=29)
    honest = outsource_determinant(m, N)
    res = outsource_determinant(
        m, N, method="q2", faults=ServerFault(server=1, mode="block"),
        recover=True, standby=1, transport="threadpool",
    )
    assert res.verified and res.report.recovery.ok
    assert 1 in res.report.recovery.servers_replaced
    # in-band poisoning: the relay forwarded the tampered row, so healing
    # cascades one row per round (DESIGN.md §4.3)
    assert 2 <= res.report.recovery.rounds <= N
    np.testing.assert_allclose(res.det.logabs, honest.det.logabs,
                               rtol=1e-10)
    # every event's sub-seed is the documented derivation — fresh per
    # (server, attempt), never the raw digest
    seen = set()
    for e in res.report.recovery.events:
        assert e.subseed not in seen
        seen.add(e.subseed)


def test_resolve_transport_rules():
    assert resolve_transport(None).name == "inline"
    assert resolve_transport(None, distributed=True).name == "shardmap"
    assert resolve_transport("threadpool").name == "threadpool"
    inst = InlineTransport()
    assert resolve_transport(inst) is inst
    with pytest.raises(ValueError, match="unknown transport"):
        resolve_transport("carrier-pigeon")
    with pytest.raises(ValueError, match="conflicts"):
        resolve_transport("threadpool", distributed=True)
    with pytest.raises(ValueError, match="conflicts"):
        resolve_transport(inst, distributed=True)


def test_transport_config_rules():
    """Satellite: the declarative third leg of resolve_transport —
    frozen/hashable, validated at construction, shared when resolved,
    fresh when built."""
    from repro.api import TransportConfig

    cfg = TransportConfig("threadpool", max_workers=2)
    assert hash(cfg) == hash(TransportConfig("threadpool", max_workers=2))
    shared = resolve_transport(cfg)
    assert shared is resolve_transport(TransportConfig("threadpool",
                                                       max_workers=2))
    owned = cfg.build()
    try:
        assert owned is not shared and owned.name == "threadpool"
    finally:
        owned.close()
    # a closed shared instance is rebuilt on the next resolve
    shared.close()
    rebuilt = resolve_transport(cfg)
    assert rebuilt is not shared and not rebuilt.closed
    # field applicability is validated up front, not at build time
    with pytest.raises(ValueError, match="unknown transport"):
        TransportConfig("carrier-pigeon")
    with pytest.raises(ValueError, match="addresses"):
        TransportConfig("inline", addresses=("tcp://h:1",))
    with pytest.raises(ValueError, match="max_workers"):
        TransportConfig("socket", max_workers=3)
    with pytest.raises(ValueError, match="program"):
        TransportConfig("threadpool", program="baseline")
    with pytest.raises(ValueError, match="timeout"):
        TransportConfig("inline", timeout=5.0)
    # list addresses are coerced so the config stays hashable
    assert TransportConfig(
        "socket", addresses=["unix:///a"]
    ).addresses == ("unix:///a",)


def test_transport_lifecycle_uniform():
    """Satellite: every transport is a context manager; close() is
    idempotent, flips `closed`, and a closed transport refuses
    dispatch with a typed error."""
    from repro.api.transport import _FACTORIES

    for name in ("inline", "shardmap", "threadpool", "multiprocess",
                 "socket"):
        assert name in _FACTORIES
    for make in (InlineTransport, ThreadPoolTransport):
        with make() as t:
            assert not t.closed
        assert t.closed
        t.close()  # idempotent
        with pytest.raises(TransportError, match="closed"):
            t.factor([])
        with pytest.raises(TransportError, match="closed"):
            t.driver_submit(lambda: None)


def test_client_owns_config_transport_not_instances():
    """Satellite: SPDCClient builds-and-OWNS a TransportConfig transport
    (context manager closes it); a passed instance stays caller-owned."""
    from repro.api import TransportConfig

    with SPDCClient(transport=TransportConfig("threadpool")) as client:
        inner = client.transport
        assert isinstance(inner, ThreadPoolTransport)
        assert client.open_session(_wellcond(12, seed=63), 2).run().verified
    assert inner.closed
    mine = ThreadPoolTransport()
    try:
        with SPDCClient(transport=mine) as client:
            assert client.transport is mine
        assert not mine.closed  # caller-owned: the client must not close it
    finally:
        mine.close()


# ------------------------------------------------- report consolidation
def test_report_consolidation_and_deprecated_shims():
    """Satellite: verdict/recovery/fleet/timings live on ONE typed
    `result.report`; the old top-level attributes still answer but warn
    (pytest.ini escalates those warnings to errors inside repro/tests,
    so no internal caller can quietly keep using them)."""
    res = outsource_determinant(_wellcond(12, seed=65), 2)
    rep = res.report
    assert bool(np.all(rep.verdict.ok)) and rep.recovery is None
    assert rep.fleet is None
    t = rep.timings
    assert t.pmop_s > 0 and t.collect_s > 0
    assert t.total_s == pytest.approx(t.pmop_s + t.dispatch_s + t.collect_s)
    for name in ("verdict", "recovery", "fleet"):
        with pytest.warns(DeprecationWarning, match=f"report.{name}"):
            assert getattr(res, name) is getattr(rep, name)


def test_run_pipelined_overlaps_and_preserves_order():
    """Tentpole: the async-overlap pipeline — up to `depth` sessions in
    flight, batch k+1's PMOP hidden under batch k's wire time, results
    in input order."""
    mats = [_wellcond(12 + 2 * i, seed=70 + i) for i in range(5)]
    client = SPDCClient()
    with ThreadPoolTransport() as tp:
        outs = client.run_pipelined(mats, 2, depth=3, transport=tp)
    assert len(outs) == len(mats)
    for m, r in zip(mats, outs):
        ws, wl = np.linalg.slogdet(m)
        assert r.verified and r.det.sign == ws
        np.testing.assert_allclose(r.det.logabs, wl, rtol=1e-10)
        assert r.report.timings.dispatch_s > 0
    with pytest.raises(ValueError, match="depth"):
        client.run_pipelined(mats, 2, depth=0)


def test_session_start_matches_run_on_inline():
    """start() on a fused transport completes synchronously and collects
    to the same result as run() — same verdict, same det."""
    m = _wellcond(16, seed=67)
    client = SPDCClient()
    pending = client.open_session(m, 2).start()
    assert pending.done()
    a = pending.result()
    b = client.open_session(m, 2).run()
    assert a.verified and b.verified
    assert a.det.sign == b.det.sign
    np.testing.assert_allclose(a.det.logabs, b.det.logabs, rtol=1e-12)


def test_edge_server_requires_relay_rows():
    t = ShardTask(server=1, num_servers=2, x_row=_wellcond(8)[:4],
                  subseed=b"\x04" * 32)
    with pytest.raises(ValueError, match="upstream"):
        EdgeServer().run(t)


# ----------------------------------------------------- config reflection
def test_spdc_config_protocol_kwargs_match_signature():
    """Satellite: protocol_kwargs() must emit only (and exercise all of)
    the real outsource_determinant keywords it models — the reflection
    guard that stops the config from drifting again."""
    from repro.configs import SPDCConfig

    params = set(
        inspect.signature(outsource_determinant).parameters
    )
    kwargs = SPDCConfig().protocol_kwargs()
    assert set(kwargs) <= params, set(kwargs) - params
    # the config must model every protocol kwarg except the per-call ones
    per_call = {"m", "num_servers", "use_kernel", "distributed",
                "faithful_sign", "tamper", "faults"}
    assert set(kwargs) == params - per_call


def test_bucket_key_protocol_kwargs_match_mixed_signature():
    from repro.core.protocol import outsource_determinant_mixed
    from repro.serve import BucketKey

    params = set(
        inspect.signature(outsource_determinant_mixed).parameters
    )
    kwargs = BucketKey(pad_to=64, num_servers=4).protocol_kwargs()
    assert set(kwargs) <= params, set(kwargs) - params


# --------------------------------------------------- gateway over transports
def test_gateway_threadpool_transport():
    from repro.configs import SPDCConfig, SPDCGatewayConfig
    from repro.serve import SPDCGateway

    cfg = SPDCGatewayConfig(
        name="gw-tp-test", buckets=(16,), max_batch=4, pad_batches=False,
        spdc=SPDCConfig(num_servers=2, transport="threadpool"),
    )
    gw = SPDCGateway(cfg)
    mats = [_wellcond(k, seed=100 + k) for k in (8, 12, 16, 10)]
    rids = [gw.submit(m) for m in mats]
    gw.drain()
    for rid, m in zip(rids, mats):
        r = gw.take(rid)
        assert r is not None and r.verified
        ws, wl = np.linalg.slogdet(m)
        assert r.det.sign == ws
        np.testing.assert_allclose(r.det.logabs, wl, rtol=1e-10)


# -------------------------------------------- multiprocess acceptance (CI)
@pytest.fixture(scope="module")
def mp_transport():
    t = MultiprocessTransport()
    yield t
    t.close()


def test_multiprocess_honest_end_to_end(mp_transport):
    """N=4 real worker processes; every message crosses the boundary as
    wire-codec bytes over an OS pipe; det matches numpy at rtol 1e-10."""
    n = 16
    m = _wellcond(n, seed=31)
    res = outsource_determinant(m, N, transport=mp_transport)
    assert len(mp_transport.workers) == N  # genuinely 4 processes
    ws, wl = np.linalg.slogdet(m)
    assert res.verified and res.det.sign == ws
    np.testing.assert_allclose(res.det.logabs, wl, rtol=1e-10)


@pytest.mark.parametrize("method", ["q2", "q3"])
def test_multiprocess_acceptance_tamper_recovery(mp_transport, method):
    """THE acceptance criterion: 4 worker processes, worker 1 tampers its
    strip (in-band — downstream workers consume the poisoned relay), the
    client localizes it and heals via re-dispatched ShardTasks; the final
    verdict passes under Q2 and Q3 and the det matches the honest run at
    rtol 1e-10."""
    n = 16
    m = _wellcond(n, seed=37)
    honest = outsource_determinant(m, N)
    res = outsource_determinant(
        m, N, method=method,
        faults=ServerFault(server=1, mode="block", magnitude=0.3),
        recover=True, standby=1, transport=mp_transport,
    )
    assert res.verified and res.report.recovery.ok
    assert res.report.recovery.events[0].server == 1  # localized the culprit
    assert 1 in res.report.recovery.servers_replaced
    assert res.det.sign == honest.det.sign
    np.testing.assert_allclose(res.det.logabs, honest.det.logabs,
                               rtol=1e-10)
    ws, wl = np.linalg.slogdet(m)
    assert res.det.sign == ws
    np.testing.assert_allclose(res.det.logabs, wl, rtol=1e-10)


def test_multiprocess_batched_sweep(mp_transport):
    stack = _wellcond(16, seed=41, batch=2)
    res = outsource_determinant(stack, N, transport=mp_transport)
    assert np.asarray(res.verified).all()
    for i in range(2):
        ws, wl = np.linalg.slogdet(stack[i])
        assert res.dets[i].sign == ws
        np.testing.assert_allclose(res.dets[i].logabs, wl, rtol=1e-10)


def test_multiprocess_timeout_is_typed_and_worker_respawns(mp_transport):
    """A worker sleeping past the per-request deadline surfaces a TYPED
    TransportTimeout (a TransportError — callers catching the base class
    keep working), the stuck process is killed, and the next dispatch to
    that worker id transparently respawns it."""
    import time

    m = _wellcond(16, seed=43)
    session = SPDCClient().open_session(m, N)
    task = session.tasks()[0]
    slow = ServerFault(server=0, kind="delay", delay_s=30.0)
    pid_before = mp_transport._conn(0) and mp_transport._procs[0].pid
    t0 = time.monotonic()
    # start() is the nonblocking half of the redesigned dispatch surface:
    # it hands back a Future immediately; result() surfaces the typed error
    fut = mp_transport.start(task, 0, faults=(slow,), timeout=0.5)
    with pytest.raises(TransportTimeout, match="request deadline"):
        mp_transport.result(fut, timeout=60)
    assert time.monotonic() - t0 < 20.0  # did NOT wait out the sleep
    assert issubclass(TransportTimeout, TransportError)
    assert 0 not in mp_transport.workers  # killed and discarded
    res = mp_transport.submit(task, 0)  # blocking facade over start/result
    assert res.server == 0  # respawned on demand and served
    assert mp_transport._procs[0].pid != pid_before


def test_multiprocess_worker_killed_mid_session_heals(mp_transport):
    """Regression: SIGKILL a live worker, then run a full session through
    the same transport — the dead worker is detected (TransportWorkerDied
    under the hood), respawned, and the protocol completes verified."""
    import os
    import signal
    import time

    m = _wellcond(16, seed=47)
    res = outsource_determinant(m, N, transport=mp_transport)
    assert res.verified  # all four workers warm and live
    victim = mp_transport._procs[1]
    os.kill(victim.pid, signal.SIGKILL)
    victim.join(timeout=10)
    time.sleep(0.1)
    res2 = outsource_determinant(m, N, transport=mp_transport)
    assert res2.verified
    assert mp_transport._procs[1].pid != victim.pid  # genuinely respawned
    ws, wl = np.linalg.slogdet(m)
    assert res2.det.sign == ws
    np.testing.assert_allclose(res2.det.logabs, wl, rtol=1e-10)


def test_multiprocess_rateless_streams_through_worker_processes():
    """Rateless dispatch over REAL worker processes: per-request timeouts
    cut a sleeping worker loose mid-session, the strip re-streams to a
    live sibling, and the fleet report attributes the slowness."""
    from repro.configs import RatelessConfig

    m = _wellcond(16, seed=53)
    cfg = RatelessConfig(request_timeout_s=1.0, probation_cooldown_s=60.0)
    client = SPDCClient(rateless=cfg, recover=True)
    fault = ServerFault(server=1, kind="delay", delay_s=8.0)
    with MultiprocessTransport() as t:
        out = client.open_session(m, N, faults=fault).run(t)
    assert out.verified
    assert out.report.fleet.timeouts >= 1
    w1 = out.report.fleet.workers[1]
    assert w1["failures"] >= 1 and w1["completed"] == 0
    ws, wl = np.linalg.slogdet(m)
    np.testing.assert_allclose(out.det.logabs, wl, rtol=1e-8)
