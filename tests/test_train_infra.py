"""Training infrastructure: optimizer math, checkpoint atomicity/integrity/
elasticity, deterministic data, fault-tolerant loop behavior."""
import os
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.common import split_tree
from repro.models.lm import init_lm
from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticLM
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import (
    AdamWConfig, adamw_update, global_norm, init_opt_state, schedule,
)
from repro.train.steps import build_train_step


# ------------------------------------------------------------------ optimizer
def test_adamw_matches_reference_impl():
    """Our AdamW == a straightforward numpy AdamW on a toy problem."""
    cfg = AdamWConfig(lr=0.1, beta1=0.9, beta2=0.999, eps=1e-8,
                      weight_decay=0.0, clip_norm=1e9, warmup_steps=0,
                      total_steps=10**9)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    st = init_opt_state(p, cfg)
    p1, st1, _ = adamw_update(p, g, st, cfg)
    # reference
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    # schedule at step 1: cosine progress ~0 => lr ≈ cfg.lr
    lr = float(schedule(cfg, jnp.asarray(1.0)))
    want = np.asarray(p["w"]) - lr * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"]), want, rtol=1e-5)


def test_grad_clipping():
    cfg = AdamWConfig(clip_norm=0.5)
    g = {"w": jnp.asarray([30.0, 40.0])}  # norm 50
    assert np.isclose(float(global_norm(g)), 50.0)
    p = {"w": jnp.zeros(2)}
    st = init_opt_state(p, cfg)
    _, _, metrics = adamw_update(p, g, st, cfg)
    assert np.isclose(float(metrics["grad_norm"]), 50.0)


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110)
    assert float(schedule(cfg, jnp.asarray(5.0))) == pytest.approx(0.5)
    assert float(schedule(cfg, jnp.asarray(10.0))) == pytest.approx(1.0)
    late = float(schedule(cfg, jnp.asarray(110.0)))
    assert late == pytest.approx(0.1, rel=1e-3)  # cosine floor = 0.1 lr


def test_bf16_state_dtype():
    cfg = AdamWConfig(state_dtype=jnp.bfloat16)
    p = {"w": jnp.ones(4, jnp.bfloat16)}
    st = init_opt_state(p, cfg)
    assert st["mu"]["w"].dtype == jnp.bfloat16
    p2, st2, _ = adamw_update(p, {"w": jnp.ones(4, jnp.bfloat16)}, st, cfg)
    assert p2["w"].dtype == jnp.bfloat16
    assert st2["nu"]["w"].dtype == jnp.bfloat16


# ----------------------------------------------------------------- checkpoint
def _tiny_state():
    return {
        "params": {"a": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones(4)},
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep_last=2)
        st = _tiny_state()
        for step in (10, 20, 30):
            mgr.save(step, st, blocking=True)
        assert mgr.all_steps() == [20, 30]  # oldest pruned
        restored, at = mgr.restore(st)
        assert at == 30
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["a"]), np.asarray(st["params"]["a"])
        )


def test_checkpoint_integrity_detection():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        st = _tiny_state()
        mgr.save(5, st, blocking=True)
        # corrupt a leaf on disk
        leaf = next(Path(d).glob("step_*/leaf_000000.npy"))
        arr = np.load(leaf)
        arr.flat[0] += 1
        np.save(leaf, arr)
        with pytest.raises(IOError, match="corruption"):
            mgr.restore(st)


def test_checkpoint_atomicity_no_partial_dirs():
    """A tmp dir left by a 'crashed' writer is never listed as a checkpoint."""
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, _tiny_state(), blocking=True)
        fake = Path(d) / "step_000000099.tmp-1234"
        fake.mkdir()
        assert mgr.all_steps() == [1]


def test_elastic_restore_onto_different_mesh():
    """Save unsharded, restore onto a 4-device sharded layout (and back)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        st = {"w": jnp.arange(16.0).reshape(4, 4)}
        mgr.save(1, st, blocking=True)
        from repro.compat import make_mesh

        mesh = make_mesh(
            (4,), ("data",),
            devices=jax.devices()[:4],
        )
        sh = {"w": NamedSharding(mesh, P("data", None))}
        placed, _ = mgr.restore_sharded(st, sh)
        assert len(placed["w"].sharding.device_set) == 4
        np.testing.assert_array_equal(np.asarray(placed["w"]), np.asarray(st["w"]))


# ----------------------------------------------------------------------- data
def test_data_determinism_and_sharding():
    cfg = smoke_config("tinyllama-1.1b")
    d1 = SyntheticLM(cfg, seed=1)
    d2 = SyntheticLM(cfg, seed=1)
    b1 = d1.batch(5, 8, 16)
    b2 = d2.batch(5, 8, 16)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(
        np.asarray(d1.batch(6, 8, 16)["tokens"]), np.asarray(b1["tokens"])
    )
    # shard slices tile the global batch
    shards = [d1.shard_batch(5, 8, 16, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(s) for s in shards]), np.asarray(b1["tokens"])
    )
    # markov structure: every transition comes from the table
    toks = np.asarray(b1["tokens"])
    nexts = np.asarray(d1.nexts)
    for row in toks:
        for t in range(len(row) - 1):
            assert row[t + 1] in nexts[row[t]]


# ----------------------------------------------------------------------- loop
def _loop_fixture(tmp, total=30, **kw):
    cfg = smoke_config("tinyllama-1.1b")
    params, _ = split_tree(init_lm(cfg, jax.random.key(0)))
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = jax.tree.map(lambda x: x, init_opt_state(params, opt_cfg))
    step = jax.jit(build_train_step(cfg, opt_cfg))
    data = SyntheticLM(cfg, seed=0)
    mgr = CheckpointManager(tmp, keep_last=3)
    lc = LoopConfig(total_steps=total, checkpoint_every=10, **kw)
    return step, params, opt, (lambda s: data.batch(s, 4, 32)), mgr, lc


def test_loop_resumes_after_crash():
    with tempfile.TemporaryDirectory() as d:
        step, p, o, data_fn, mgr, lc = _loop_fixture(d)

        calls = {"n": 0}

        def bomb(s):
            if s == 15 and calls["n"] == 0:
                calls["n"] = 1
                raise RuntimeError("node failure")

        _, _, rep = run_training(step, p, o, data_fn, mgr, lc,
                                 fault_injector=bomb)
        assert rep.restarts == 1
        # replayed steps 10..15 after resume => more steps run than total
        assert rep.steps_run > lc.total_steps - 1
        assert mgr.latest_step() == lc.total_steps


def test_loop_straggler_detection():
    import time

    with tempfile.TemporaryDirectory() as d:
        step, p, o, data_fn, mgr, lc = _loop_fixture(
            d, total=12, straggler_factor=5.0
        )

        def slow_data(s):
            if s == 9:
                time.sleep(1.0)  # slow data fetch — inside the timed region
            return data_fn(s)

        _, _, rep = run_training(step, p, o, slow_data, mgr, lc)
        assert any(s == 9 for s, _, _ in rep.straggler_events)


@pytest.mark.slow
def test_loop_fresh_vs_resumed_equivalence():
    """Crash/resume must land on the same params as an uninterrupted run
    (determinism of data + replay from checkpoint)."""
    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        step, p, o, data_fn, mgr1, lc = _loop_fixture(d1, total=20)
        pa, _, _ = run_training(step, p, o, data_fn, mgr1, lc)

        step2, p2, o2, data_fn2, mgr2, lc2 = _loop_fixture(d2, total=20)

        fired = {"n": 0}

        def bomb(s):
            if s == 13 and fired["n"] == 0:
                fired["n"] = 1
                raise RuntimeError("boom")

        pb, _, _ = run_training(step2, p2, o2, data_fn2, mgr2, lc2,
                                fault_injector=bomb)
        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=1e-6)


# ----------------------------------------------------------------------- eval
def test_eval_step_deterministic_finite_loss():
    """build_eval_step returns a pure loss: finite scalar, bit-identical
    across calls, and jit-compatible."""
    from repro.train.steps import build_eval_step

    cfg = smoke_config("tinyllama-1.1b")
    params, _ = split_tree(init_lm(cfg, jax.random.key(0)))
    batch = SyntheticLM(cfg, seed=0).batch(0, 4, 32)
    ev = jax.jit(build_eval_step(cfg, ce_chunk=16))
    l1 = float(ev(params, batch))
    l2 = float(ev(params, batch))
    assert np.isfinite(l1)
    assert l1 == l2
    # an untrained model should sit near uniform cross-entropy
    assert 0.0 < l1 < 2.0 * np.log(cfg.vocab_size)
