"""Precision-robust SPDC: float32 as a first-class verified compute dtype.

The f32 protocol leg (DESIGN.md §6): growth-safe cipher relayout,
power-of-two equilibration, compensated log-det accumulation, growth-aware
ε(N) — plus the regression tests for the three numeric-comparison bugfixes
(bucket_size_for fallback, Determinant.allclose, Determinant.value).

This module is the x64-disabled CI leg: every test here passes with
JAX_ENABLE_X64=0 (tests comparing f32 against a live f64 protocol run are
skipped there; the f64 *references* come from numpy, which the x64 switch
does not touch).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Determinant, ServerFault, cipher, equilibrate, keygen,
    outsource_determinant, seedgen, slogdet_pair_from_lu,
)
from repro.core.verify import growth_estimate

X64 = bool(jax.config.jax_enable_x64)
needs_x64 = pytest.mark.skipif(
    not X64, reason="compares against a live float64 protocol run"
)

N = 4
#: acceptance bar: f32 relative det error vs f64 references (log space)
F32_DLOG = 1e-4


def _wellcond(n, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    if batch is None:
        return rng.standard_normal((n, n)) + n * np.eye(n)
    return rng.standard_normal((batch, n, n)) + n * np.eye(n)


# ------------------------------------------------------- growth control
def test_growth_safe_cipher_det_relation():
    """The flip-composed cipher still satisfies Decipher's det algebra:
    det(X) = s · det(M) / Ψ with s = growth_safe_sign — for every forced
    rotation degree (seeds drawn until all of k ∈ {1,2,3} are seen)."""
    from repro.core.prt import growth_safe_sign

    seen = set()
    for t in range(24):
        n = 8
        m = _wellcond(n, seed=t)
        seed = seedgen(128, m)
        key = keygen(128, seed, n)
        x, meta = cipher(jnp.asarray(m), key, seed, growth_safe=True)
        seen.add(meta.rotate_k)
        s = growth_safe_sign(n, meta.rotate_k)
        np.testing.assert_allclose(
            np.linalg.det(np.asarray(x)),
            s * np.linalg.det(m) / seed.psi,
            rtol=1e-5,
        )
        assert meta.flipped == (meta.rotate_k % 2 == 1)
    assert seen == {1, 2, 3}


def test_growth_safe_kernel_matches_jnp():
    n = 16
    m = jnp.asarray(_wellcond(n, seed=3))
    seed = seedgen(11, np.asarray(m))
    key = keygen(13, seed, n)
    x_ref, meta = cipher(m, key, seed, growth_safe=True)
    x_k, meta_k = cipher(m, key, seed, growth_safe=True, use_kernel=True)
    np.testing.assert_allclose(np.asarray(x_k), np.asarray(x_ref), rtol=1e-6)
    assert meta == meta_k


def test_growth_safe_tames_element_growth():
    """The headline hazard: an odd rotation of a diagonally dominant
    matrix is anti-diagonally dominant, and the no-pivot LU's growth
    factor explodes (~n). The flip-composed relayout pins it at ~1."""
    from repro.core.lu import lu_nserver
    from repro.core.prt import rotate_degree

    n = 64
    hit = False
    for t in range(12):
        m = _wellcond(n, seed=100 + t)
        seed = seedgen(128, m)
        if rotate_degree(seed.psi) % 2 == 0:
            continue  # only odd rotations exhibit the hazard
        hit = True
        key = keygen(128, seed, n)
        x_unsafe, _ = cipher(jnp.asarray(m), key, seed)
        x_safe, _ = cipher(jnp.asarray(m), key, seed, growth_safe=True)
        xe_unsafe, _ = equilibrate(x_unsafe)
        xe_safe, _ = equilibrate(x_safe)
        lu_g = lu_nserver(xe_unsafe, N)[1]
        lu_s = lu_nserver(xe_safe, N)[1]
        g_unsafe = growth_estimate(lu_g, xe_unsafe)
        g_safe = growth_estimate(lu_s, xe_safe)
        assert g_safe < 4.0, g_safe
        assert g_unsafe > 4 * g_safe, (g_unsafe, g_safe)
    assert hit, "no odd rotation drawn in 12 seeds"


def test_equilibrate_exact_and_det_tracked():
    """Power-of-two scales are lossless: every entry of x_eq is x's entry
    times an exact power of two, and the integer exponent correction
    recovers log|det| exactly (up to the f64 slogdet's own rounding)."""
    x = jnp.asarray(_wellcond(24, seed=7))
    x_eq, log2_scale = equilibrate(x)
    assert np.max(np.abs(np.asarray(x_eq))) <= np.sqrt(2.0) + 1e-9
    assert jnp.issubdtype(log2_scale.dtype, jnp.integer)  # exact, not f32
    s0, l0 = np.linalg.slogdet(np.asarray(x, dtype=np.float64))
    s1, l1 = np.linalg.slogdet(np.asarray(x_eq, dtype=np.float64))
    assert s0 == s1
    # with x64 off the matrices themselves are f32, so the two f64
    # slogdets see slightly different roundings of the same values
    np.testing.assert_allclose(
        l0, l1 - float(log2_scale) * np.log(2.0),
        rtol=1e-12 if X64 else 1e-6,
    )
    # zero matrix: no scaling, no correction, no nan
    z_eq, z_scale = equilibrate(jnp.zeros((5, 5)))
    assert int(z_scale) == 0 and not np.isnan(np.asarray(z_eq)).any()


def test_compensated_slogdet_pair():
    """The (hi, lo) pair recombined in f64 holds the log sum where a naive
    f32 accumulation drifts: alternating ±10 logs over n = 4096 sum to a
    known value; the pair lands within 2e-4 of it."""
    n = 4096
    logs = np.where(np.arange(n) % 2 == 0, 10.0, -10.0)
    logs[-1] = 0.125  # make the exact total nonzero
    d = np.exp(logs).astype(np.float32)
    l = jnp.eye(n, dtype=jnp.float32)
    u = jnp.diag(jnp.asarray(d))
    sign, hi, lo = slogdet_pair_from_lu(l, u)
    got = float(hi) + float(lo)
    want = float(np.sum(np.log(np.abs(d.astype(np.float64)))))
    assert abs(got - want) <= 2e-4, (got, want)
    assert float(sign) == 1.0


# ------------------------------------------------- f32 protocol end-to-end
@pytest.mark.parametrize("n,servers", [(12, 3), (64, 4), (256, 4)])
def test_f32_roundtrip_matches_f64_reference(n, servers):
    m = _wellcond(n, seed=n)
    want_s, want_la = np.linalg.slogdet(m)
    res = outsource_determinant(m, servers, dtype="float32")
    assert res.verified, res.residual
    assert res.det.sign == want_s
    assert abs(res.det.logabs - want_la) <= F32_DLOG
    assert res.det.dtype == "float32"


def test_f32_batched_roundtrip():
    B, n = 4, 64
    stack = _wellcond(n, seed=1, batch=B)
    res = outsource_determinant(jnp.asarray(stack), N, dtype="float32")
    assert bool(np.all(res.verified))
    for i in range(B):
        ws, wl = np.linalg.slogdet(stack[i])
        assert res.dets[i].sign == ws
        assert abs(res.dets[i].logabs - wl) <= F32_DLOG


def test_f32_mixed_sizes_one_sweep():
    mats = [_wellcond(n, seed=n) for n in (24, 33, 48)]
    res = outsource_determinant(mats, N, dtype="float32")
    assert bool(np.all(res.verified))
    for i, m in enumerate(mats):
        ws, wl = np.linalg.slogdet(m)
        assert res.dets[i].sign == ws
        assert abs(res.dets[i].logabs - wl) <= F32_DLOG


@needs_x64
def test_f32_agrees_with_f64_protocol_run():
    """Property-style agreement: the same matrices through both compute
    dtypes produce Determinants that allclose() at the f32 default
    tolerance — single and batched."""
    for n in (12, 40):
        m = _wellcond(n, seed=n * 3)
        d64 = outsource_determinant(m, N, dtype="float64").det
        d32 = outsource_determinant(m, N, dtype="float32").det
        assert d32.allclose(d64)  # dtype-aware default rtol (1e-4)
        assert not d32.allclose(
            Determinant(d64.sign, d64.logabs + 0.01, d64.dtype)
        )
    stack = _wellcond(32, seed=5, batch=3)
    r64 = outsource_determinant(jnp.asarray(stack), N, dtype="float64")
    r32 = outsource_determinant(jnp.asarray(stack), N, dtype="float32")
    for a, b in zip(r32.dets, r64.dets):
        assert a.allclose(b)


def test_f32_growth_controls_are_defaults_and_overridable():
    m = _wellcond(16, seed=9)
    # f32 auto-enables both; forcing them off still runs (just less robust)
    res = outsource_determinant(m, N, dtype="float32",
                                growth_safe=False, equilibrate=False)
    assert res.det.dtype == "float32"
    # f64 + explicit growth controls works and stays accurate
    if X64:
        want_s, want_la = np.linalg.slogdet(m)
        res = outsource_determinant(m, N, dtype="float64",
                                    growth_safe=True, equilibrate=True)
        assert res.verified and res.det.sign == want_s
        np.testing.assert_allclose(res.det.logabs, want_la, rtol=1e-9)
    # faithful_sign conflicts with the growth-safe relayout
    with pytest.raises(ValueError, match="faithful_sign"):
        outsource_determinant(m, N, dtype="float32", faithful_sign=True)


@pytest.mark.slow
def test_f32_batched_n1024_roundtrip():
    """The acceptance shape the bench guard also pins (BENCH_3.json):
    B×n=1024 f32 stacks stay Q3-verified within the 1e-4 log budget —
    the compensated log accumulation is what keeps the digit."""
    B, n = 2, 1024
    stack = _wellcond(n, seed=10, batch=B)
    res = outsource_determinant(jnp.asarray(stack), N, dtype="float32")
    assert bool(np.all(res.verified))
    for i in range(B):
        ws, wl = np.linalg.slogdet(stack[i])
        assert res.dets[i].sign == ws
        assert abs(res.dets[i].logabs - wl) <= F32_DLOG


def test_f32_distributed_pipeline():
    """The shard_map relay programs are dtype-generic: an f32 stack runs
    the real device pipeline (one mesh device per server) verified."""
    if len(jax.devices()) < N:
        pytest.skip(f"needs {N} devices")
    B, n = 2, 32
    stack = _wellcond(n, seed=11, batch=B)
    res = outsource_determinant(
        jnp.asarray(stack), N, dtype="float32", distributed=True
    )
    assert bool(np.all(res.verified))
    for i in range(B):
        ws, wl = np.linalg.slogdet(stack[i])
        assert res.dets[i].sign == ws
        assert abs(res.dets[i].logabs - wl) <= F32_DLOG


# --------------------------------------------------- f32 verification power
def test_f32_false_reject_rate_is_zero():
    """Honest f32 runs must never be rejected: the growth-aware ε(N)
    absorbs the f32 no-pivot drift (20 trials, mixed rotations)."""
    for t in range(20):
        m = _wellcond(32, seed=500 + t)
        res = outsource_determinant(m, N, dtype="float32")
        assert res.verified, (t, res.residual, res.report.verdict.eps)


@pytest.mark.parametrize("kind,kw", [
    ("dropout", dict(kind="dropout")),
    ("block", dict(mode="block", magnitude=0.5)),
    ("sign_flip_diag", dict(mode="single", magnitude=1.0)),
])
def test_f32_tampered_results_rejected(kind, kw):
    """FA at f32 thresholds: structurally significant tampers (dropout, a
    wholesale strip rescale, a unit-magnitude element hit) are rejected
    for every server. (Detection resolution necessarily scales with the
    compute dtype's noise floor — DESIGN.md §6.3 — so the f32 FA claim is
    pinned at magnitudes above it, unlike the f64 tests' 0.05.)"""
    m = _wellcond(32, seed=77)
    for s in range(N):
        res = outsource_determinant(
            m, N, dtype="float32", faults=ServerFault(server=s, **kw)
        )
        assert not bool(np.all(res.verified)), (kind, s, res.residual)


def test_f32_accepted_results_are_det_accurate():
    """The safety property behind the f32 FA floor: ANY accepted verdict —
    honest or carrying a sub-threshold tamper — yields a determinant
    within the f32 acceptance tolerance of the true one (a tamper small
    enough to pass ε(N) is a backward-stable perturbation)."""
    m = _wellcond(32, seed=88)
    want_s, want_la = np.linalg.slogdet(m)
    accepted = 0
    for s in range(N):
        for t in range(4):
            res = outsource_determinant(
                m, N, dtype="float32",
                faults=ServerFault(server=s, magnitude=1e-4, seed=t),
            )
            if bool(np.all(res.verified)):
                accepted += 1
                assert res.det.sign == want_s
                assert abs(res.det.logabs - want_la) <= 1e-3
    assert accepted > 0  # 1e-4 tampers sit below the f32 noise floor


# ------------------------------------------------------------ f32 recovery
@pytest.mark.parametrize("fault_kw", [
    dict(kind="dropout"),
    dict(mode="block", magnitude=0.5),
])
def test_f32_recovery_under_every_single_server_fault(fault_kw):
    n = 64
    m = _wellcond(n, seed=4)
    want_s, want_la = np.linalg.slogdet(m)
    for s in range(N):
        res = outsource_determinant(
            m, N, dtype="float32",
            faults=ServerFault(server=s, **fault_kw),
            recover=True, standby=1,
        )
        assert bool(np.all(res.verified)) and res.report.recovery.ok, (s, fault_kw)
        assert res.det.sign == want_s
        assert abs(res.det.logabs - want_la) <= F32_DLOG


def test_f32_batched_recovery_splices_one_matrix():
    B, n = 4, 32
    stack = _wellcond(n, seed=6, batch=B)
    res = outsource_determinant(
        jnp.asarray(stack), N, dtype="float32",
        faults=ServerFault(server=2, kind="dropout", matrices=(1,)),
        recover=True, standby=1,
    )
    assert bool(np.all(res.verified)) and res.report.recovery.ok
    for i in range(B):
        ws, wl = np.linalg.slogdet(stack[i])
        assert res.dets[i].sign == ws
        assert abs(res.dets[i].logabs - wl) <= F32_DLOG


# ------------------------------------------------------------- f32 gateway
def test_f32_gateway_bucket_serves_verified():
    from repro.configs import SPDCConfig, SPDCGatewayConfig
    from repro.serve import SPDCGateway

    cfg = SPDCGatewayConfig(
        name="t-f32", buckets=(64,), max_batch=4,
        spdc=SPDCConfig(num_servers=N, dtype="float32"),
    )
    gw = SPDCGateway(cfg)
    mats = [_wellcond(48 + 3 * i, seed=40 + i) for i in range(4)]
    rids = [gw.submit(m) for m in mats]
    for m, rid in zip(mats, rids):
        r = gw.take(rid)
        ws, wl = np.linalg.slogdet(m)
        assert r is not None and r.verified and r.flush_reason == "full"
        assert r.det.dtype == "float32" and r.det.sign == ws
        assert abs(r.det.logabs - wl) <= F32_DLOG
        assert r.batch == 4  # ONE coalesced f32 sweep served all four


@needs_x64
def test_gateway_dtype_override_opens_separate_bucket():
    """f32 and f64 clients must never share a sweep: the dtype rides in
    the BucketKey, so a mixed submission flushes as two sweeps."""
    from repro.configs import SPDCConfig, SPDCGatewayConfig
    from repro.serve import SPDCGateway

    cfg = SPDCGatewayConfig(
        name="t-mixdt", buckets=(32,), max_batch=8,
        spdc=SPDCConfig(num_servers=N),
    )
    gw = SPDCGateway(cfg)
    m = _wellcond(24, seed=3)
    r64 = gw.submit(m)
    r32 = gw.submit(m, dtype="float32")
    gw.drain()
    a, b = gw.take(r64), gw.take(r32)
    assert a.det.dtype == "float64" and b.det.dtype == "float32"
    assert a.batch == 1 and b.batch == 1  # separate buckets, separate sweeps
    assert a.verified and b.verified
    ws, wl = np.linalg.slogdet(m)
    assert abs(a.det.logabs - wl) <= 1e-8
    assert abs(b.det.logabs - wl) <= F32_DLOG
    assert gw.stats.flushes == 2


# --------------------------------------- bugfix regressions (pre-PR fails)
def test_bucket_size_for_synthesizes_when_divisibility_fails():
    """Pre-fix: every bucket failing n' % N == 0 raised NoBucketFits even
    though a valid padded size exists (default power-of-two buckets with
    num_servers=3). Synthesized sizes land on the coarse N·SYNTH_GRID
    grid, not the per-request minimum — see the bounded-compile-set test
    below."""
    from repro.serve.queue import NoBucketFits, bucket_size_for

    assert bucket_size_for(50, (64, 128, 256, 512, 1024), 3) == 96
    assert bucket_size_for(2, (64,), 3) == 48  # servable: 48/3 = 16 > 1
    # a servable configured bucket still wins over synthesis
    assert bucket_size_for(50, (64, 128), 4) == 64
    # genuine oversize still raises → the gateway's direct escape hatch
    with pytest.raises(NoBucketFits):
        bucket_size_for(2000, (64, 128, 256, 512, 1024), 4)
    # synthesis honors the operator's size cap: grid round-up of n=50 is
    # 96 > max(buckets)=64, so the request directs instead of running a
    # sweep larger than any configured bucket
    with pytest.raises(NoBucketFits):
        bucket_size_for(50, (64,), 3)


def test_synthesized_buckets_stay_bounded():
    """Pre-fix (of the fallback itself): each distinct request size
    synthesized its own bucket, so a diverse or adversarial size
    distribution grew the gateway's jit-compile set without bound. The
    grid caps the synthesized sizes at ~max(buckets)/(N·SYNTH_GRID)."""
    from repro.serve.queue import NoBucketFits, SYNTH_GRID, bucket_size_for

    buckets, servers = (64, 128, 256, 512, 1024), 3
    sizes, direct = set(), 0
    for n in range(2, 1025):
        try:
            sizes.add(bucket_size_for(n, buckets, servers))
        except NoBucketFits:
            direct += 1  # grid round-up would exceed max(buckets)
    assert all(s % servers == 0 and s // servers > 1 for s in sizes)
    assert max(sizes) <= max(buckets)  # operator size cap holds
    assert len(sizes) <= 1024 // (servers * SYNTH_GRID) + 1
    # only the thin band above the last grid line under the cap directs
    assert direct < servers * SYNTH_GRID


def test_gateway_submit_override_rides_synthesized_bucket():
    """A num_servers override none of the preset buckets divides must
    still coalesce (pre-fix it silently fell to the direct path)."""
    from repro.configs import SPDCConfig, SPDCGatewayConfig
    from repro.serve import SPDCGateway

    cfg = SPDCGatewayConfig(
        name="t-n3", buckets=(64,), max_batch=2,
        spdc=SPDCConfig(num_servers=4, dtype="float32"),
    )
    gw = SPDCGateway(cfg)
    rids = [gw.submit(_wellcond(20, seed=i), num_servers=3)
            for i in range(2)]
    results = [gw.take(r) for r in rids]
    assert all(r is not None and r.verified for r in results)
    assert results[0].batch == 2  # coalesced, not direct
    assert results[0].pad_to == 48  # synthesized: next N·SYNTH_GRID ≥ 20
    assert gw.stats.direct == 0


def test_gateway_rejects_unservable_preset_bucket():
    """Construction-time validation names the offending bucket."""
    from repro.configs import SPDCConfig, SPDCGatewayConfig
    from repro.serve import SPDCGateway

    with pytest.raises(ValueError, match="129"):
        SPDCGateway(SPDCGatewayConfig(
            name="t-bad", buckets=(64, 129), spdc=SPDCConfig(num_servers=4)
        ))


def test_determinant_allclose_is_relative_det_error():
    """Pre-fix: rtol applied to logabs itself — |Δlog| = 0.5 (a 65%
    relative det error!) passed at rtol=1e-3 once logabs ≈ 1000."""
    a = Determinant(sign=1.0, logabs=1000.0)
    b = Determinant(sign=1.0, logabs=1000.5)
    assert not a.allclose(b, rtol=1e-3)  # pre-fix: True
    # the same |Δlog| near |det| ≈ 1 was and stays a reject
    assert not Determinant(1.0, 0.0).allclose(Determinant(1.0, 0.5),
                                              rtol=1e-3)
    # genuinely close dets pass at any magnitude
    assert a.allclose(Determinant(1.0, 1000.0 + 1e-9), rtol=1e-8)
    # dtype-aware default: an f32-produced det gets the f32 tolerance
    c = Determinant(sign=1.0, logabs=100.0, dtype="float32")
    assert c.allclose(Determinant(1.0, 100.00005, "float32"))
    assert not c.allclose(Determinant(1.0, 100.001, "float32"))


def test_determinant_allclose_zero_and_sign_cases():
    """Pre-fix: sign != sign rejected legitimate det ≈ 0 comparisons."""
    zp = Determinant(sign=1.0, logabs=float("-inf"))
    zn = Determinant(sign=-1.0, logabs=float("-inf"))
    z0 = Determinant(sign=0.0, logabs=float("-inf"))
    assert zp.allclose(zn)  # ±0 are the same determinant (pre-fix: False)
    assert zp.allclose(z0) and z0.allclose(zn)
    one = Determinant(sign=1.0, logabs=0.0)
    assert not zp.allclose(one) and not one.allclose(zn)
    # opposite-sign nonzeros still mismatch
    assert not one.allclose(Determinant(-1.0, 0.0))
    # explicit numeric-zero band: dets below zero_logabs compare as zero
    tiny_p = Determinant(1.0, -700.0)
    tiny_n = Determinant(-1.0, -700.5)
    assert not tiny_p.allclose(tiny_n)
    assert tiny_p.allclose(tiny_n, zero_logabs=-600.0)


def test_determinant_value_raises_instead_of_inf():
    """Pre-fix: .value silently overflowed to inf for log|det| > ~709 —
    any n ≳ 200 ciphered matrix."""
    ok = Determinant(sign=-1.0, logabs=10.0)
    np.testing.assert_allclose(ok.value, -np.exp(10.0))
    big = Determinant(sign=1.0, logabs=800.0)
    with pytest.raises(OverflowError, match="logabs"):
        _ = big.value


# ----------------------------------------------------------- x64-off leg
def test_float64_request_resolves_under_x64_off():
    """With jax.enable_x64 OFF a float64 request must run (as float32)
    instead of warning-per-array or crashing — the gateway default config
    stays usable on every backend."""
    from repro.core import resolve_dtype

    resolved = np.dtype(resolve_dtype("float64"))
    assert resolved == (np.float64 if X64 else np.float32)
    m = _wellcond(16, seed=2)
    res = outsource_determinant(m, N)  # default dtype="float64"
    assert res.verified
    assert res.det.dtype == str(np.dtype(resolved))
