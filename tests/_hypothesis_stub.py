"""Minimal stand-in for the slice of the `hypothesis` API this suite uses.

The test image does not ship `hypothesis`; conftest.py installs this module
under the name ``hypothesis`` only when the real package is absent, so the
property tests keep running (as deterministic seeded sweeps) instead of
erroring at collection. If real hypothesis is ever installed it wins.

Supported surface: ``given(**strategies)``, ``settings(max_examples=,
deadline=)``, ``strategies.integers/floats/sampled_from``.
"""
from __future__ import annotations

import random
import zlib


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(options) -> _Strategy:
        opts = list(options)
        return _Strategy(lambda rng: opts[rng.randrange(len(opts))])


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**strategy_kwargs):
    def deco(fn):
        # NOT functools.wraps: copying __wrapped__ would make pytest
        # introspect the inner signature and treat strategy params as
        # missing fixtures. The wrapper must look parameterless.
        def runner(*args, **kwargs):
            # @settings may decorate either the raw fn (inner) or this
            # wrapper (outer) — check the wrapper first, then the fn.
            max_ex = getattr(
                runner, "_stub_max_examples",
                getattr(fn, "_stub_max_examples", 20),
            )
            # Deterministic per-test seed so failures reproduce exactly.
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(max_ex):
                drawn = {k: s.example(rng) for k, s in strategy_kwargs.items()}
                fn(*args, **kwargs, **drawn)

        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner

    return deco
