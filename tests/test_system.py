"""End-to-end behaviour tests for the paper's system: the complete SPDC
six-algorithm tuple against ground truth, determinant + inversion, across
server counts and matrix parities — the topmost acceptance test."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import outsource_determinant, outsource_inverse


def _matrix(n, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n)) + n * np.eye(n)


@pytest.mark.parametrize("n", [7, 12, 16, 25])
@pytest.mark.parametrize("servers", [2, 3, 4])
def test_spdc_system_end_to_end(n, servers):
    """SeedGen -> KeyGen -> Cipher(CED) -> Parallelize(N-server LU) ->
    Authenticate(Q3) -> Decipher, exact vs numpy, odd and even sizes."""
    m = _matrix(n, seed=n * 10 + servers)
    res = outsource_determinant(m, servers)
    want_sign, want_log = np.linalg.slogdet(m)
    assert res.verified, f"residual {res.residual}"
    assert res.det.sign == want_sign
    np.testing.assert_allclose(res.det.logabs, want_log, rtol=1e-8)


def test_spdc_system_rejects_every_single_block_tamper():
    """Any single tampered LU block is caught by the client (malicious
    threat model, paper Table II)."""
    n, servers = 12, 3
    m = _matrix(n, seed=0)
    for i in range(0, n, 4):
        res = outsource_determinant(
            m, servers,
            tamper=lambda l, u, i=i: (l.at[min(i + 1, n - 1), i].add(0.05), u),
        )
        assert not res.verified, f"tamper at block row {i} went undetected"


def test_spdc_system_inverse_extension():
    m = _matrix(10, seed=3)
    res = outsource_inverse(m, 2)
    assert res.verified
    np.testing.assert_allclose(np.asarray(res.inverse) @ m, np.eye(10),
                               atol=1e-8)


@pytest.mark.slow
def test_lm_framework_end_to_end_smoke():
    """The LM side: one train step + one decode step of one arch through
    the public API (deep coverage lives in the dedicated test files)."""
    import jax

    from repro.configs import smoke_config
    from repro.models.common import split_tree
    from repro.models.lm import init_lm
    from repro.serve.kvcache import init_caches
    from repro.serve.steps import build_decode_step
    from repro.train.data import SyntheticLM
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.steps import build_train_step

    cfg = smoke_config("gemma3-1b")
    params, _ = split_tree(init_lm(cfg, jax.random.key(0)))
    opt_cfg = AdamWConfig()
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(build_train_step(cfg, opt_cfg))
    params, opt, metrics = step(params, opt, SyntheticLM(cfg).batch(0, 2, 64),
                                jax.random.key(1))
    assert np.isfinite(float(metrics["loss"]))
    decode = jax.jit(build_decode_step(cfg))
    caches = init_caches(cfg, 2, 32)
    logits, caches = decode(
        params, caches, {"tokens": jnp.zeros((2, 1), jnp.int32)},
        jnp.zeros((2,), jnp.int32),
    )
    assert bool(jnp.all(jnp.isfinite(logits[:, : cfg.vocab_size])))
