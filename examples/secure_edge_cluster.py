"""Secure edge cluster: the distributed SPDC pipeline on a simulated
N-device cluster (shard_map + one-way ppermute relay), including the
paper's odd-size augmentation and a comparison of EWD vs EWM recovery.

    PYTHONPATH=src python examples/secure_edge_cluster.py [--servers 8]
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

jax.config.update("jax_enable_x64", True)

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import outsource_determinant
from repro.distrib.spdc_pipeline import pipeline_collective_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--servers", type=int, default=8)
    ap.add_argument("--n", type=int, default=237)  # deliberately awkward size
    args = ap.parse_args()
    assert args.servers <= len(jax.devices()), (
        f"need {args.servers} devices, have {len(jax.devices())}"
    )

    rng = np.random.default_rng(1)
    m = rng.standard_normal((args.n, args.n)) + args.n * np.eye(args.n)
    want_sign, want_log = np.linalg.slogdet(m)

    print(f"cluster: {args.servers} edge servers (1 JAX device each)")
    print(f"matrix:  {args.n}x{args.n} (odd/awkward on purpose)")

    for mode in ("ewd", "ewm"):
        res = outsource_determinant(
            m, args.servers, mode=mode, distributed=True, method="q2"
        )
        status = "OK" if (
            res.verified and res.det.sign == want_sign
            and np.isclose(res.det.logabs, want_log, rtol=1e-9)
        ) else "MISMATCH"
        print(f"  CED={mode}: padded +{res.padding} -> "
              f"{(args.n + res.padding)}, verified={res.verified}, "
              f"logdet={res.det.logabs:.6f} ({status})")

    info = pipeline_collective_bytes(args.n + 3, args.servers)
    print(f"one-way relay traffic: {info['relay_bytes']/1e6:.1f} MB "
          f"(paper-exact {info['paper_exact_bytes']/1e6:.1f} MB, "
          f"fixed-shape overcount {info['overcount_factor']:.2f}x)")
    print("note: no all-gather/all-reduce appears in the pipeline HLO — "
          "neighbor permutes only (tests/test_distributed.py asserts this).")


if __name__ == "__main__":
    main()
