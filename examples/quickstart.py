"""Quickstart: securely outsource one determinant through the full SPDC
protocol — SeedGen → KeyGen → Cipher(CED) → Parallelize(N-server LU) →
Authenticate(Q3) → Decipher.

    PYTHONPATH=src python examples/quickstart.py [--n 256] [--servers 4]
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import outsource_determinant


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--servers", type=int, default=4)
    ap.add_argument("--mode", choices=["ewd", "ewm"], default="ewd")
    ap.add_argument("--method", choices=["q1", "q2", "q3"], default="q3")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    # a client matrix (well-conditioned, as an outsourcing client can ensure)
    m = rng.standard_normal((args.n, args.n)) + args.n * np.eye(args.n)

    print(f"Outsourcing det of a {args.n}x{args.n} matrix to "
          f"{args.servers} untrusted edge servers (CED: {args.mode} + PRT, "
          f"verify: {args.method})")
    res = outsource_determinant(
        m, args.servers, mode=args.mode, method=args.method
    )
    want_sign, want_log = np.linalg.slogdet(m)

    print(f"  seed Ψ            = {res.seed.psi:.6f}")
    print(f"  rotation          = {res.meta.rotate_k * 90}°")
    print(f"  padding           = {res.padding}")
    print(f"  verified          = {res.verified} (residual {res.residual:.2e})")
    print(f"  det (sign,logabs) = ({res.det.sign:+.0f}, {res.det.logabs:.10f})")
    print(f"  numpy slogdet     = ({want_sign:+.0f}, {want_log:.10f})")
    assert res.verified
    assert res.det.sign == want_sign
    assert np.isclose(res.det.logabs, want_log, rtol=1e-9)
    print("OK: determinant recovered exactly; servers saw only the ciphertext.")

    # a malicious server corrupts its block — the client catches it
    bad = outsource_determinant(
        m, args.servers, tamper=lambda l, u: (l.at[5, 2].add(0.05), u)
    )
    print(f"  tampered result rejected = {not bad.verified} "
          f"(residual {bad.residual:.2e})")
    assert not bad.verified


if __name__ == "__main__":
    main()
