"""Quickstart: securely outsource one determinant through the full SPDC
protocol — SeedGen → KeyGen → Cipher(CED) → Parallelize(N-server LU) →
Authenticate(Q3) → Decipher — then a batched stack through the same API.

    PYTHONPATH=src python examples/quickstart.py [--n 256] [--servers 4]
                                                 [--batch 8]
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import outsource_determinant


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--servers", type=int, default=4)
    ap.add_argument("--mode", choices=["ewd", "ewm"], default="ewd")
    ap.add_argument("--method", choices=["q1", "q2", "q3"], default="q3")
    ap.add_argument("--batch", type=int, default=8,
                    help="size of the batched demo stack (0 to skip)")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    # a client matrix (well-conditioned, as an outsourcing client can ensure)
    m = rng.standard_normal((args.n, args.n)) + args.n * np.eye(args.n)

    print(f"Outsourcing det of a {args.n}x{args.n} matrix to "
          f"{args.servers} untrusted edge servers (CED: {args.mode} + PRT, "
          f"verify: {args.method})")
    res = outsource_determinant(
        m, args.servers, mode=args.mode, method=args.method
    )
    want_sign, want_log = np.linalg.slogdet(m)

    print(f"  seed Ψ            = {res.seed.psi:.6f}")
    print(f"  rotation          = {res.meta.rotate_k * 90}°")
    print(f"  padding           = {res.padding}")
    print(f"  verified          = {res.verified} (residual {res.residual:.2e})")
    print(f"  det (sign,logabs) = ({res.det.sign:+.0f}, {res.det.logabs:.10f})")
    print(f"  numpy slogdet     = ({want_sign:+.0f}, {want_log:.10f})")
    assert res.verified
    assert res.det.sign == want_sign
    assert np.isclose(res.det.logabs, want_log, rtol=1e-9)
    print("OK: determinant recovered exactly; servers saw only the ciphertext.")

    # a malicious server corrupts its block — the client catches it
    bad = outsource_determinant(
        m, args.servers, tamper=lambda l, u: (l.at[5, 2].add(0.05), u)
    )
    print(f"  tampered result rejected = {not bad.verified} "
          f"(residual {bad.residual:.2e})")
    assert not bad.verified

    # fault tolerance (DESIGN.md §4): name the tampering server via the
    # per-server residuals, re-dispatch ONLY its shard to a standby, and
    # recover the exact determinant — no full re-outsource
    from repro.core import ServerFault

    culprit_server = min(1, args.servers - 1)
    healed = outsource_determinant(
        m, args.servers, mode=args.mode, method=args.method,
        faults=ServerFault(server=culprit_server, kind="tamper"),
        recover=True, standby=1,
    )
    rep = healed.report.recovery
    print(f"  tampered server {culprit_server}: localized culprit="
          f"{rep.events[0].server}, shard re-dispatched to standby "
          f"server {rep.events[0].replacement} "
          f"({rep.rounds} round(s), {rep.events[0].comm_elements} elements "
          f"on the wire vs {(args.n + healed.padding)**2} for re-outsource)")
    assert healed.verified and rep.ok
    assert healed.det.sign == want_sign
    assert np.isclose(healed.det.logabs, want_log, rtol=1e-9)
    print("  recovered determinant matches — one extra hop, not a restart.")

    # a straggler past the client's deadline is re-dispatched the same way
    slow = outsource_determinant(
        m, args.servers,
        faults=ServerFault(server=args.servers - 1, kind="delay",
                           delay_rounds=9),
        straggler_deadline=4, recover=True, standby=1,
    )
    assert slow.verified and slow.report.recovery.ok
    print(f"  straggler (9 rounds late, deadline 4): shard re-dispatched, "
          f"verified={slow.verified}")

    # role-split transports (DESIGN.md §7): the same protocol with the
    # client and the untrusted workers as separate objects — here on a
    # thread pool; transport="multiprocess" spawns real worker processes
    # (see examples/role_split.py for the full role API)
    role = outsource_determinant(m, args.servers, transport="threadpool")
    assert role.verified and role.det.sign == want_sign
    assert np.isclose(role.det.logabs, want_log, rtol=1e-9)
    print("  role-split threadpool transport: verified, same determinant")

    if args.batch:
        # batch-first: a (B, n, n) stack goes through the identical protocol
        # in ONE call — per-matrix seeds/keys/rotations/verdicts, one sweep
        # of the N-server schedule (DESIGN.md §3)
        import time

        stack = rng.standard_normal((args.batch, args.n, args.n)) \
            + args.n * np.eye(args.n)
        t0 = time.perf_counter()
        batch_res = outsource_determinant(
            stack, args.servers, mode=args.mode, method=args.method
        )
        dt = time.perf_counter() - t0
        assert batch_res.verified.all()
        for i in range(args.batch):
            ws, wl = np.linalg.slogdet(stack[i])
            assert batch_res.dets[i].sign == ws
            assert np.isclose(batch_res.dets[i].logabs, wl, rtol=1e-8)
        print(f"  batched: {args.batch} matrices outsourced+verified in one "
              f"call ({dt:.3f}s, {args.batch / dt:.1f} dets/sec, "
              f"all verified)")

        # mixed sizes? a list coalesces into ONE padded sweep (the gateway
        # path — see examples/edge_gateway.py and repro.launch.serve_spdc)
        mixed = [rng.standard_normal((k, k)) + k * np.eye(k)
                 for k in (args.n // 2, args.n // 3, args.n)]
        mres = outsource_determinant(mixed, args.servers)
        assert mres.verified.all()
        print(f"  mixed sizes {[m.shape[0] for m in mixed]} coalesced at "
              f"n'={mres.pad_to}: all verified")


if __name__ == "__main__":
    main()
