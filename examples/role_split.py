"""Role-split SPDC (DESIGN.md §7): drive the client and the untrusted
edge servers as separate objects, watch the wire messages, and heal a
tampering worker over a real process boundary.

    PYTHONPATH=src python examples/role_split.py [--n 64] [--servers 4]
                                                 [--multiprocess]
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.api import (
    EdgeServer, MultiprocessTransport, ShardResult, SPDCClient,
    ThreadPoolTransport,
)
from repro.core import ServerFault


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--servers", type=int, default=4)
    ap.add_argument("--multiprocess", action="store_true",
                    help="spawn real worker processes (slower to start; "
                         "every message crosses an OS pipe as bytes)")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    m = rng.standard_normal((args.n, args.n)) + args.n * np.eye(args.n)
    want_s, want_la = np.linalg.slogdet(m)

    # --- the client role: all secrets live in the session -------------------
    client = SPDCClient(method="q2")
    session = client.open_session(m, args.servers)
    tasks = session.tasks()
    frame = tasks[1].to_bytes()
    print(f"client: session {session.session_id} → {len(tasks)} ShardTasks")
    print(f"  task[1] on the wire: {len(frame)} bytes "
          f"(encrypted {tasks[1].x_row.shape} block row + 32-byte subseed; "
          "no plaintext, no key material)")

    # --- the server role: stateless workers, relay threaded by hand --------
    results, u_rows = [], []
    for task in tasks:
        if task.server > 0:  # the one-way S_{i-1} → S_i relay content
            task = task.with_upstream(np.concatenate(u_rows, axis=-2))
        res = EdgeServer(task.server).run(task)
        res = ShardResult.from_bytes(res.to_bytes())  # bytes, like a real wire
        results.append(res)
        u_rows.append(np.asarray(res.u_row))
    out = session.collect(results)
    assert out.verified and out.det.sign == want_s
    assert np.isclose(out.det.logabs, want_la, rtol=1e-9)
    print("  manual relay: verified, determinant recovered exactly")

    # --- same flow through a pluggable transport, with a tampering worker --
    transport_cls = MultiprocessTransport if args.multiprocess \
        else ThreadPoolTransport
    with transport_cls() as tp:
        honest = SPDCClient(method="q2").open_session(m, args.servers).run(tp)
        assert honest.verified
        hardened = SPDCClient(method="q2", recover=True, standby=1)
        bad = hardened.open_session(
            m, args.servers,
            faults=ServerFault(server=1, mode="block", magnitude=0.3),
        ).run(tp)
        rep = bad.report.recovery
        assert bad.verified and rep.ok
        assert np.isclose(bad.det.logabs, honest.det.logabs, rtol=1e-10)
        print(f"  {tp.name} transport: worker 1 tampered in-band → localized, "
              f"healed in {rep.rounds} round(s) via re-dispatched ShardTasks "
              f"(servers {rep.servers_replaced}), det matches honest")


if __name__ == "__main__":
    main()
