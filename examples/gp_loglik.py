"""GP marginal log-likelihood through the secure-linalg family.

The intended workload shape for `repro.linalg` (DESIGN.md §12): a
Gaussian-process hyperparameter step needs log|Σ| AND solves against Σ
inside one jitted, grad-ed objective —

    -2·logp(y) = log|Σ(θ)| + yᵀ Σ(θ)⁻¹ y + n·log(2π)

Both terms route through `secure_slogdet` / `secure_solve`: ONE verified
outsourced factorization of Σ per objective evaluation serves the value
and the whole custom-VJP backward pass (∂log|Σ|/∂Σ = Σ⁻ᵀ and the solve
adjoint are triangular-solve rounds through the SAME factors), so the
untrusted fleet does the O(n³) work and the client keeps O(n²) — without
the kernel matrix, the targets, or any gradient crossing the trust
boundary in the clear.

    PYTHONPATH=src python examples/gp_loglik.py [--n 128] [--servers 2]
        [--transport inline] [--gateway]

--gateway additionally serves the same (slogdet, solve) pair through the
SPDC gateway's op-keyed buckets (serve/) to show the service path agrees
with the in-process one.
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp
import numpy as np

# before any jax dispatch: repro.linalg flips jax_cpu_enable_async_dispatch
# at import, which only takes effect while the CPU backend doesn't exist yet
from repro.linalg import SecureLinalg  # noqa: E402


def rbf_cov(x, log_ell, log_sf, log_noise):
    """RBF kernel matrix Σ(θ) on 1-d inputs — differentiable in θ."""
    d2 = (x[:, None] - x[None, :]) ** 2
    k = jnp.exp(2.0 * log_sf) * jnp.exp(-0.5 * d2 / jnp.exp(2.0 * log_ell))
    return k + jnp.exp(2.0 * log_noise) * jnp.eye(x.shape[0])


def make_objectives(x, y, linalg_ctx):
    """(secure, reference) negative log-marginal-likelihood closures."""
    from repro.linalg import secure_slogdet, secure_solve

    n = x.shape[0]

    def nll_secure(theta):
        cov = rbf_cov(x, *theta)
        _, logdet = secure_slogdet(cov, linalg=linalg_ctx)
        alpha = secure_solve(cov, y, linalg=linalg_ctx)
        return 0.5 * (logdet + y @ alpha + n * jnp.log(2.0 * jnp.pi))

    def nll_reference(theta):
        cov = rbf_cov(x, *theta)
        _, logdet = jnp.linalg.slogdet(cov)
        alpha = jnp.linalg.solve(cov, y)
        return 0.5 * (logdet + y @ alpha + n * jnp.log(2.0 * jnp.pi))

    return nll_secure, nll_reference


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=128, help="training points")
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument("--transport",
                    choices=["inline", "threadpool", "multiprocess",
                             "socket"],
                    default="inline")
    ap.add_argument("--steps", type=int, default=3,
                    help="gradient-descent steps to take")
    ap.add_argument("--gateway", action="store_true",
                    help="also serve the (slogdet, solve) pair through "
                         "the SPDC gateway's op-keyed buckets")
    args = ap.parse_args()

    from repro.api.transport import resolve_transport

    rng = np.random.default_rng(0)
    x = jnp.asarray(np.sort(rng.uniform(-3.0, 3.0, args.n)))
    y_clean = np.sin(2.0 * np.asarray(x)) + 0.5 * np.asarray(x)
    y = jnp.asarray(y_clean + 0.1 * rng.standard_normal(args.n))

    transport = resolve_transport(args.transport)
    ctx = SecureLinalg(args.servers, transport=transport)
    nll_secure, nll_ref = make_objectives(x, y, ctx)

    theta = jnp.asarray([np.log(0.8), np.log(1.0), np.log(0.2)])
    value_and_grad = jax.jit(jax.value_and_grad(nll_secure))
    ref_vg = jax.jit(jax.value_and_grad(nll_ref))

    print(f"GP log-likelihood, n={args.n}, N={args.servers} "
          f"({args.transport} transport)")
    for step in range(args.steps):
        ctx.clear()  # new θ ⇒ new Σ ⇒ new session next evaluation
        val, grad = value_and_grad(theta)
        ref_val, ref_grad = ref_vg(theta)
        gerr = float(jnp.max(jnp.abs(grad - ref_grad))
                     / (jnp.max(jnp.abs(ref_grad)) + 1e-30))
        sessions = list(ctx._sessions.values())
        facts = sum(s.factorizations for s in sessions)
        print(f"  step {step}: nll={float(val):.6f} "
              f"(ref {float(ref_val):.6f}) |grad err|={gerr:.2e} "
              f"factorizations={facts} (sessions={len(sessions)})")
        assert np.isclose(float(val), float(ref_val), rtol=1e-9), \
            "secure nll diverged from the jax.scipy reference"
        assert gerr < 1e-6, f"gradient error {gerr:.2e} exceeds 1e-6"
        assert facts == len(sessions) == 1, \
            "a gradient step must share ONE factorization"
        # normalized step: raw NLL gradients overshoot in log-space
        theta = theta - 0.1 * grad / (jnp.linalg.norm(grad) + 1.0)
    print("OK: value and gradient match the plaintext reference; each "
          "step used one shared verified LU.")

    if args.gateway:
        from repro.configs.spdc import SPDC_GATEWAY_DEFAULT
        from repro.serve.spdc_gateway import SPDCGateway

        cov = np.asarray(rbf_cov(x, *theta))
        # kernel matrices need the growth-safe relayout (the reason it is
        # the LinalgSession default): no-pivot LU growth on a near-SPD Σ
        # overflows the verifier otherwise. It is a bucket dimension, so
        # the override rides the submit call.
        with SPDCGateway(SPDC_GATEWAY_DEFAULT) as gw:
            r_sl = gw.submit(cov, op="slogdet", growth_safe=True)
            r_sv = gw.submit(cov, op="solve", rhs=np.asarray(y),
                             growth_safe=True)
            gw.drain()
            sl, sv = gw.take(r_sl), gw.take(r_sv)
        ws, wl = np.linalg.slogdet(cov)
        alpha = np.linalg.solve(cov, np.asarray(y))
        assert sl.verified and sl.sign == ws and \
            np.isclose(sl.logabs, wl, rtol=1e-9)
        serr = float(np.linalg.norm(np.asarray(sv.solution) - alpha)
                     / np.linalg.norm(alpha))
        assert sv.verified and serr < 1e-8, serr
        print(f"OK: gateway op-keyed buckets agree "
              f"(slogdet bucket + solve bucket, solve err {serr:.2e}).")


if __name__ == "__main__":
    main()
