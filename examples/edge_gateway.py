"""Edge-gateway demo: many IoT clients, one micro-batching SPDC service.

A swarm of clients each submits ONE matrix (mixed sizes, one tampering
edge server in the mix); the gateway buckets them by padded size, coalesces
each bucket into a single batched protocol sweep, heals the tampered
bucket in place, and answers every client with a verified determinant.

    PYTHONPATH=src python examples/edge_gateway.py [--clients 24]
                                                   [--servers 2]
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs import SPDCConfig, SPDCGatewayConfig
from repro.core import ServerFault
from repro.serve import SPDCGateway


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--servers", type=int, default=2)
    args = ap.parse_args()

    cfg = SPDCGatewayConfig(
        name="demo-gateway",
        buckets=(16, 32, 64),
        max_batch=8,
        max_wait_us=2000.0,
        spdc=SPDCConfig(
            num_servers=args.servers, recover=True, standby=1,
        ),
    )

    # one edge server misbehaves, but only in the n'=32 bucket's sweeps
    def faults_for(key):
        if key.pad_to == 32:
            return ServerFault(server=args.servers - 1, kind="tamper")
        return None

    gw = SPDCGateway(cfg, faults_for=faults_for)
    rng = np.random.default_rng(0)
    sizes = rng.integers(4, 65, size=args.clients)
    mats = [rng.standard_normal((n, n)) + n * np.eye(n) for n in sizes]

    print(f"{args.clients} clients (sizes {sizes.min()}..{sizes.max()}) → "
          f"gateway → {args.servers} untrusted edge servers "
          f"(server {args.servers - 1} tampers with the n'=32 bucket)")
    rids = [gw.submit(m) for m in mats]
    gw.drain()

    healed = 0
    for m, rid in zip(mats, rids, strict=True):
        res = gw.take(rid)
        assert res is not None and res.verified, f"request {rid} failed"
        ws, wl = np.linalg.slogdet(m)
        assert res.det.sign == ws and np.isclose(res.det.logabs, wl,
                                                 rtol=1e-10)
        if res.recovery is not None:
            healed += 1
    s = gw.stats
    print(f"  served {s.served} requests in {s.flushes} coalesced sweeps "
          f"(full={s.flushes_full} timeout={s.flushes_timeout} "
          f"drain={s.flushes_drain})")
    print(f"  {s.recovered_flushes} sweep(s) healed a tampered server; "
          f"{healed} requests rode through recovery")
    print("  every determinant exact at rtol 1e-10; "
          "tampered buckets healed without touching clean ones. OK")


if __name__ == "__main__":
    main()
