"""End-to-end training driver: train the ~100M-param repro-100m model for a
few hundred steps on synthetic Markov data, with checkpointing, resume, and
optional Freivalds SDC verification.

    PYTHONPATH=src python examples/train_lm.py               # full run (~100M, 300 steps)
    PYTHONPATH=src python examples/train_lm.py --quick       # CI-sized (~15s)

This is a thin veneer over the production launcher
(`python -m repro.launch.train`) — same code path the cluster would run.
"""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def main():
    quick = "--quick" in sys.argv
    extra = [a for a in sys.argv[1:] if a != "--quick"]
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "repro-100m",
        "--steps", "30" if quick else "300",
        "--batch", "4" if quick else "16",
        "--seq", "128" if quick else "512",
        "--ckpt", "/tmp/repro_100m_ckpt",
        "--sdc",
    ] + extra
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
    import os

    env.update({k: v for k, v in os.environ.items() if k not in env})
    print("+", " ".join(cmd))
    sys.exit(subprocess.call(cmd, env=env, cwd=ROOT))


if __name__ == "__main__":
    main()
