"""Serving example: batched greedy generation with per-layer-kind caches
(ring-buffered sliding windows for gemma3, SSM state for mamba2).

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-1b
    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-370m
"""
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def main():
    args = sys.argv[1:] or ["--arch", "tinyllama-1.1b"]
    cmd = [sys.executable, "-m", "repro.launch.serve", "--smoke",
           "--batch", "4", "--prompt-len", "12", "--gen", "20"] + args
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    print("+", " ".join(cmd))
    sys.exit(subprocess.call(cmd, env=env, cwd=ROOT))


if __name__ == "__main__":
    main()
