"""Rateless fleet demo: a straggling server and a tampering server, no
deadline anywhere — the scheduler streams over-decomposed strips to
whoever is free, the straggler just does less, and the tamperer is
caught by a per-strip secret probe and quarantined (DESIGN.md §8).

    PYTHONPATH=src python examples/rateless_fleet.py [--n 64] [--batch 6]
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.api import SPDCClient, ThreadPoolTransport
from repro.configs import RatelessConfig
from repro.core.faults import ServerFault

N = 4


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--batch", type=int, default=6)
    args = ap.parse_args()

    rng = np.random.default_rng(7)
    stack = (rng.standard_normal((args.batch, args.n, args.n))
             + args.n * np.eye(args.n))
    want_sign, want_log = np.linalg.slogdet(stack)

    # server 1 straggles (heavy Pareto tail — the case deadlines handle
    # worst); server 2 tampers with every block row it computes
    plan = (
        ServerFault(server=1, kind="delay", delay_s=0.3,
                    delay_dist="pareto", delay_alpha=2.5),
        ServerFault(server=2, kind="tamper", mode="block", magnitude=0.5),
    )
    cfg = RatelessConfig(request_timeout_s=0.5)
    client = SPDCClient(rateless=cfg)

    print(f"Outsourcing {args.batch} determinants ({args.n}x{args.n}) to "
          f"{N} edge servers: server 1 straggling, server 2 tampering,")
    print(f"no straggler deadline — F = {cfg.overdecompose}*{N} rateless "
          f"strips per matrix, streamed to whoever is free")
    with ThreadPoolTransport() as tp:
        # honest pass on a throwaway client: pays the per-strip-shape jit
        # compiles once so the faulted run's timeouts measure the FLEET,
        # not cold-start compilation
        honest_res = SPDCClient(rateless=cfg).open_session(stack, N).run(tp)
        honest_done = [w["completed"]
                       for w in honest_res.report.fleet.workers.values()]
        print(f"warmup (honest fleet): strips per server = "
              f"{sorted(honest_done, reverse=True)}")
        res = client.open_session(stack, N, faults=plan).run(tp)

    fleet = res.report.fleet
    print(f"\n  verified          = {np.asarray(res.verified).tolist()}")
    print(f"  strips x lanes    = {fleet.num_strips} x {fleet.lanes} "
          f"({fleet.dispatches} dispatches, {fleet.retries} retries, "
          f"{fleet.timeouts} timeouts)")
    for wid in sorted(fleet.workers):
        w = fleet.workers[wid]
        role = {1: "  <- straggler", 2: "  <- tamperer"}.get(wid, "")
        ewma = w["ewma_latency_s"]
        ewma_ms = f"{ewma * 1e3:7.1f} ms" if ewma is not None else "      --- "
        print(f"  server {wid}: completed {w['completed']:3d}  "
              f"ewma {ewma_ms}  tampers {w['tampers']}  "
              f"quarantined={w['quarantined']}{role}")

    assert bool(np.all(res.verified))
    got_sign = np.asarray([d.sign for d in res.dets])
    got_log = np.asarray([d.logabs for d in res.dets])
    assert np.array_equal(got_sign, want_sign)
    assert np.allclose(got_log, want_log, rtol=1e-9)
    honest = [fleet.workers[w]["completed"] for w in fleet.workers
              if w not in (1, 2)]
    assert fleet.workers[2]["quarantined"], "tamperer must end benched"
    assert fleet.workers[2]["completed"] == 0, "no tampered strip accepted"
    assert fleet.workers[1]["completed"] < max(honest), \
        "the straggler should complete fewer strips than a healthy server"
    print("\nOK: determinants recovered exactly; the straggler was never "
          "evicted (it just did less),")
    print("and the tamperer contributed nothing — benched by its first "
          "rejected probe.")


if __name__ == "__main__":
    main()
