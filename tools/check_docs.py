"""Docs link-and-anchor checker (CI docs job).

Validates the repo's documentation graph so README/DESIGN can be load-bearing:

  1. every relative markdown link in README.md / DESIGN.md resolves to an
     existing file;
  2. every intra-document anchor link (`[...](#heading)` or
     `[...](FILE.md#heading)`) matches a real heading's GitHub slug;
  3. every `DESIGN.md §N[.M]` reference — in the markdown docs AND in
     src/tests/benchmarks/examples source — names a section heading that
     actually exists in DESIGN.md (section numbers are the repo's stable
     cross-reference currency, so a dangling one is a doc bug);
  4. README.md contains the required top-level sections (quickstart,
     install/test, architecture).

    python tools/check_docs.py        # exit 0 clean / 1 with findings
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

DOCS = ("README.md", "DESIGN.md")
REQUIRED_README_HEADINGS = (
    "quickstart",
    "install and test",
    "architecture",
)

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$", re.M)
SECTION_REF_RE = re.compile(r"DESIGN\.md[ §§]*§?\s*(\d+(?:\.\d+)?)")
SECTION_HEAD_RE = re.compile(r"^#{2,6}\s+§(\d+(?:\.\d+)?)\b", re.M)


def github_slug(heading: str) -> str:
    """GitHub's heading → anchor slug (close enough for ASCII docs)."""
    slug = heading.strip().lower()
    slug = re.sub(r"[^\w\- ]", "", slug, flags=re.UNICODE)
    return slug.replace(" ", "-")


def headings_of(path: Path) -> list[str]:
    return [m.group(2).strip() for m in HEADING_RE.finditer(path.read_text())]


def check_links(problems: list[str]) -> None:
    for doc in DOCS:
        path = ROOT / doc
        if not path.exists():
            problems.append(f"{doc}: missing")
            continue
        text = path.read_text()
        slugs = {github_slug(h) for h in headings_of(path)}
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if target.startswith("#"):
                if target[1:] not in slugs:
                    problems.append(f"{doc}: dangling anchor {target}")
                continue
            file_part, _, anchor = target.partition("#")
            dest = (path.parent / file_part).resolve()
            if not dest.exists():
                problems.append(f"{doc}: broken link {target}")
                continue
            if anchor and dest.suffix == ".md":
                dest_slugs = {github_slug(h) for h in headings_of(dest)}
                if anchor not in dest_slugs:
                    problems.append(
                        f"{doc}: dangling anchor #{anchor} in {file_part}"
                    )


def design_sections() -> set[str]:
    text = (ROOT / "DESIGN.md").read_text()
    return {m.group(1) for m in SECTION_HEAD_RE.finditer(text)}


def check_section_refs(problems: list[str]) -> None:
    sections = design_sections()
    if not sections:
        problems.append("DESIGN.md: no §-numbered sections found")
        return
    scan = [ROOT / d for d in DOCS]
    for sub in ("src", "tests", "benchmarks", "examples"):
        scan.extend((ROOT / sub).rglob("*.py"))
    for path in scan:
        text = path.read_text()
        for m in SECTION_REF_RE.finditer(text):
            ref = m.group(1)
            # §N.M references resolve if §N.M or its parent §N exists
            if ref in sections or ref.split(".")[0] in sections:
                continue
            problems.append(
                f"{path.relative_to(ROOT)}: reference to DESIGN.md §{ref} "
                "but no such section"
            )


def check_required_readme(problems: list[str]) -> None:
    heads = [h.lower() for h in headings_of(ROOT / "README.md")]
    for want in REQUIRED_README_HEADINGS:
        if not any(want in h for h in heads):
            problems.append(f"README.md: missing required section '{want}'")


def main() -> int:
    problems: list[str] = []
    check_links(problems)
    check_section_refs(problems)
    check_required_readme(problems)
    if problems:
        print(f"check_docs: {len(problems)} problem(s)")
        for p in problems:
            print(f"  - {p}")
        return 1
    print("check_docs: README.md + DESIGN.md links, anchors, and §-references OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
