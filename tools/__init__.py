"""Repo tooling namespace (``python -m tools.<tool>``).

Everything in here is stdlib-only on purpose: the CI lint job installs
no project dependencies (not even jax), so a tool that imports
``repro.*`` at module scope would break the cheapest gate we have.
"""
