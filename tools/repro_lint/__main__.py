"""CLI driver: ``python -m tools.repro_lint [paths...]``.

Exit status: 0 when the tree is clean, 1 when there are findings
(including malformed/stale suppressions), 2 on usage errors.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .engine import lint_paths
from .vocab import CODES

PASS_NAMES = ("taint", "locks", "jit", "exports")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.repro_lint",
        description="SPDC static analysis: taint, locks, jit hygiene, exports.",
    )
    ap.add_argument(
        "paths", nargs="*", default=["src", "benchmarks", "examples"],
        help="files or directories to lint (default: src benchmarks examples)",
    )
    ap.add_argument(
        "--pass", dest="passes", action="append", choices=PASS_NAMES,
        help="run only the named pass (repeatable; default: all)",
    )
    ap.add_argument(
        "--root", default=None,
        help="repo root (default: auto-detect from this file's location)",
    )
    ap.add_argument(
        "--codes", action="store_true", help="print the finding-code table",
    )
    ns = ap.parse_args(argv)

    if ns.codes:
        for code in sorted(CODES):
            print(f"{code}  {CODES[code]}")
        return 0

    root = Path(ns.root) if ns.root else Path(__file__).resolve().parents[2]
    findings = lint_paths(ns.paths or ["src"], root=root, passes=ns.passes)
    for f in findings:
        print(f.render())
    n = len(findings)
    print(f"repro-lint: {n} finding{'s' if n != 1 else ''}", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
