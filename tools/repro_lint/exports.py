"""Pass 4 — dead/undeclared-export audit (SPDC401).

A module-level public symbol (no leading underscore) defined under
src/repro must be *referenced* — by bare identifier, anywhere in the
reference index — or it is dead API surface. The index always covers
src/tests/benchmarks/examples/tools relative to the repo root, no
matter which subset of paths the CLI was pointed at, so
``python -m tools.repro_lint src`` still knows that tests/ uses a
symbol. References are word-boundary identifier hits in any other file, or
*repeat* hits inside the defining file itself — a module-internal
helper/constant with a public name is used, not dead; the definition
line alone does not witness itself.

Deliberate dead surface goes in vocab.EXPORT_EXEMPT with a written
justification, the same standard as an inline suppression.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path

from . import vocab
from .engine import Context, Finding, SourceFile

_IDENT_RE = re.compile(r"[A-Za-z_][A-Za-z0-9_]*")


def _public_defs(tree: ast.Module) -> list[tuple[str, int]]:
    out: list[tuple[str, int]] = []
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            if not node.name.startswith("_"):
                out.append((node.name, node.lineno))
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and not t.id.startswith("_"):
                    out.append((t.id, node.lineno))
        elif (
            isinstance(node, ast.AnnAssign)
            and isinstance(node.target, ast.Name)
            and not node.target.id.startswith("_")
            and node.value is not None
        ):
            out.append((node.target.id, node.lineno))
    return out


def _token_index(ctx: Context, scanned: list[SourceFile]) -> dict[str, set[str]]:
    """path -> set of identifier tokens, over the reference roots."""
    index: dict[str, set[str]] = {
        sf.path: set(_IDENT_RE.findall(sf.text)) for sf in scanned
    }
    if ctx.root is None:
        return index
    for root_name in vocab.REFERENCE_ROOTS:
        root = Path(ctx.root) / root_name
        if not root.is_dir():
            continue
        for p in sorted(root.rglob("*.py")):
            if "__pycache__" in p.parts:
                continue
            rel = p.relative_to(ctx.root).as_posix()
            if rel in index:
                continue
            try:
                index[rel] = set(_IDENT_RE.findall(p.read_text(encoding="utf-8")))
            except OSError:
                continue
    return index


def run(files: list[SourceFile], ctx: Context) -> list[Finding]:
    targets = [
        sf for sf in files
        if sf.tree is not None
        and "src/repro/" in sf.path
        and not sf.path.endswith(vocab.EXPORT_EXEMPT_MODULES or ("\0",))
    ]
    if not targets:
        return []
    index = _token_index(ctx, files)
    findings: list[Finding] = []
    for sf in targets:
        assert sf.tree is not None
        for name, lineno in _public_defs(sf.tree):
            if name in ("__all__",) or name in vocab.EXPORT_EXEMPT:
                continue
            if any(
                name in toks
                for path, toks in index.items()
                if path != sf.path
            ):
                continue
            # used inside its own module (beyond the definition line)?
            own_hits = len(
                re.findall(rf"\b{re.escape(name)}\b", sf.text)
            )
            if own_hits > 1:
                continue
            findings.append(Finding(
                sf.path, lineno, "SPDC401",
                f"public symbol {name!r} is referenced nowhere in "
                f"src/tests/benchmarks/examples/tools",
            ))
    return findings
