"""Pass 2 — lock discipline (SPDC201..206).

Annotation grammar (DESIGN.md §11.2)::

    self._results = {}          #: guarded-by: self._lock
    #: guarded-by: self._lock
    self._dummies = OrderedDict()

    #: requires-lock: self._lock
    def _deliver(self, ...): ...

An attribute annotated ``guarded-by`` may only be *mutated* — assigned,
aug-assigned, deleted, subscript-stored, or have ANY method called on it
— inside a lexical ``with <lock>:`` over the named lock. The
any-method-call rule is deliberately strict: the PR-8 bug this pass
exists for was ``OrderedDict.get`` + ``move_to_end`` (a read API that
mutates LRU order) outside the gateway lock, and no static pass can
know which methods of an arbitrary object mutate. Plain attribute
*loads* (``self._queue.pending``) are not flagged — benign-race reads
of scalars are an accepted idiom here and are annotated in source.

``guarded-by: external(<who>)`` documents a container that has no lock
of its own and is serialized by its single owner (MicroBatchQueue under
the gateway lock). It satisfies the REQUIRED_GUARDS coverage check but
is not lexically enforced in the annotated class — enforcement happens
in the owner, whose *reference* to the container is itself guarded.

``requires-lock`` on a method makes every call site of
``self.<method>()`` require the named lock to be lexically held
(SPDC204); the method body is analyzed as if the lock were held.

Also flagged while any lock is held: blocking calls (sweep dispatch,
socket/pipe I/O, futures, sleeps — SPDC202) and user hook invocation
(on_flush/on_verdict/on_reject — SPDC203). Nested function bodies are
analyzed with an empty lock set: a closure outlives the ``with`` block
it was defined in.

``__init__``/``__post_init__`` are exempt from mutation checks —
construction happens-before publication.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field

from . import vocab
from .engine import Context, Finding, SourceFile

GUARD_RE = re.compile(r"#:\s*guarded-by:\s*(.+?)\s*$")
REQUIRES_RE = re.compile(r"#:\s*requires-lock:\s*(.+?)\s*$")

_EXEMPT_METHODS = frozenset({"__init__", "__post_init__", "__new__"})


def _lockish(name: str) -> bool:
    last = name.rsplit(".", 1)[-1]
    return any(h in last for h in vocab.LOCK_NAME_HINTS) or last == "lock"


def _comment_above_or_trailing(
    lines: list[str], lineno: int, rx: re.Pattern
) -> str | None:
    """Match rx on the statement's own line or the line directly above."""
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines):
            m = rx.search(lines[ln - 1])
            if m:
                return m.group(1).strip()
    return None


def _base_self_attr(node: ast.expr) -> str | None:
    """'X' when the expression drills into self.X (through any number of
    Attribute/Subscript layers), else None."""
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        if (
            isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
        ):
            return node.attr
        node = node.value
    return None


def _dotted(node: ast.expr) -> str | None:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclass
class ClassGuards:
    name: str
    lineno: int
    guards: dict[str, str] = field(default_factory=dict)      # attr -> lock
    requires: dict[str, str] = field(default_factory=dict)    # method -> lock

    def enforced(self, attr: str) -> str | None:
        lock = self.guards.get(attr)
        if lock is None or lock.startswith("external"):
            return None
        return lock


def _collect_class(cls: ast.ClassDef, lines: list[str]) -> ClassGuards:
    cg = ClassGuards(name=cls.name, lineno=cls.lineno)
    for node in cls.body:
        # dataclass-style field annotations in the class body
        if isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
            expr = _comment_above_or_trailing(lines, node.lineno, GUARD_RE)
            if expr:
                cg.guards[node.target.id] = expr
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        look_from = min(
            [node.lineno] + [d.lineno for d in node.decorator_list]
        )
        expr = _comment_above_or_trailing(lines, look_from, REQUIRES_RE)
        if expr:
            cg.requires[node.name] = expr
        if node.name in _EXEMPT_METHODS:
            for stmt in ast.walk(node):
                if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                    targets = (
                        stmt.targets
                        if isinstance(stmt, ast.Assign)
                        else [stmt.target]
                    )
                    for t in targets:
                        if (
                            isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"
                        ):
                            g = _comment_above_or_trailing(
                                lines, stmt.lineno, GUARD_RE
                            )
                            if g:
                                cg.guards[t.attr] = g
    return cg


class _MethodWalker:
    def __init__(
        self,
        path: str,
        cg: ClassGuards,
        method: ast.FunctionDef | ast.AsyncFunctionDef,
    ):
        self.path = path
        self.cg = cg
        self.findings: list[Finding] = []
        held: set[str] = set()
        req = cg.requires.get(method.name)
        if req:
            held.add(req)
        self.exempt = method.name in _EXEMPT_METHODS
        self._block(method.body, held)

    def _f(self, code: str, node: ast.AST, msg: str) -> None:
        self.findings.append(Finding(self.path, node.lineno, code, msg))

    def _block(self, stmts: list[ast.stmt], held: set[str]) -> None:
        for s in stmts:
            self._stmt(s, held)

    def _stmt(self, s: ast.stmt, held: set[str]) -> None:
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # closures escape the lexical lock scope: empty lock set
            self._block(s.body, set())
            return
        if isinstance(s, ast.ClassDef):
            return
        if isinstance(s, (ast.With, ast.AsyncWith)):
            inner = set(held)
            for item in s.items:
                d = _dotted(item.context_expr)
                if d and _lockish(d):
                    inner.add(d)
                self._expr(item.context_expr, held)
            self._block(s.body, inner)
            return
        if isinstance(s, (ast.Assign,)):
            for t in s.targets:
                self._store_target(t, held, s)
            self._expr(s.value, held)
            return
        if isinstance(s, ast.AnnAssign):
            self._store_target(s.target, held, s)
            if s.value is not None:
                self._expr(s.value, held)
            return
        if isinstance(s, ast.AugAssign):
            self._store_target(s.target, held, s)
            self._expr(s.value, held)
            return
        if isinstance(s, ast.Delete):
            for t in s.targets:
                self._store_target(t, held, s)
            return
        if isinstance(s, (ast.For, ast.AsyncFor)):
            self._expr(s.iter, held)
            self._block(s.body, held)
            self._block(s.orelse, held)
            return
        if isinstance(s, ast.While):
            self._expr(s.test, held)
            self._block(s.body, held)
            self._block(s.orelse, held)
            return
        if isinstance(s, ast.If):
            self._expr(s.test, held)
            self._block(s.body, held)
            self._block(s.orelse, held)
            return
        if isinstance(s, ast.Try):
            self._block(s.body, held)
            for h in s.handlers:
                self._block(h.body, held)
            self._block(s.orelse, held)
            self._block(s.finalbody, held)
            return
        # everything else: just scan contained expressions
        for child in ast.iter_child_nodes(s):
            if isinstance(child, ast.expr):
                self._expr(child, held)

    def _store_target(self, t: ast.expr, held: set[str], s: ast.stmt) -> None:
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                self._store_target(e, held, s)
            return
        attr = _base_self_attr(t)
        if attr is None:
            return
        self._check_guard(attr, held, s, "mutated")

    def _check_guard(
        self, attr: str, held: set[str], node: ast.AST, verb: str
    ) -> None:
        if self.exempt:
            return
        lock = self.cg.enforced(attr)
        if lock is not None and lock not in held:
            self._f(
                "SPDC201", node,
                f"{self.cg.name}.{attr} is guarded by {lock} but {verb} "
                f"outside it",
            )

    def _expr(self, e: ast.expr, held: set[str]) -> None:
        if isinstance(e, ast.Lambda):
            # lambda bodies run later, outside the lexical lock scope
            self._expr(e.body, set())
            return
        if isinstance(e, ast.Call):
            self._call(e, held)
        for child in ast.iter_child_nodes(e):
            if isinstance(child, ast.expr):
                self._expr(child, held)

    def _call(self, node: ast.Call, held: set[str]) -> None:
        func = node.func
        d = _dotted(func)
        # strict rule: ANY method call through a guarded attribute
        if isinstance(func, ast.Attribute):
            base = _base_self_attr(func.value)
            if base is not None:
                self._check_guard(
                    base, held, node,
                    f"touched via .{func.attr}()",
                )
            # hooks under lock
            if func.attr in vocab.HOOK_ATTRS and held:
                self._f(
                    "SPDC203", node,
                    f"user hook .{func.attr}() fired while holding "
                    f"{', '.join(sorted(held))}",
                )
            # blocking method names under lock
            if func.attr in vocab.BLOCKING_METHODS and held:
                recv = _dotted(func.value) or "<expr>"
                # ".join" is overloaded: str.join / os.path.join are not
                # thread joins — skip literal receivers and *path modules
                str_join = func.attr == "join" and (
                    isinstance(func.value, ast.Constant)
                    or recv.endswith("path")
                    or recv == "<expr>"
                )
                if not _lockish(recv) and not str_join:
                    self._f(
                        "SPDC202", node,
                        f"blocking call {recv}.{func.attr}() while holding "
                        f"{', '.join(sorted(held))}",
                    )
            # requires-lock methods called on self
            if (
                isinstance(func.value, ast.Name)
                and func.value.id == "self"
                and func.attr in self.cg.requires
            ):
                req = self.cg.requires[func.attr]
                if req not in held:
                    self._f(
                        "SPDC204", node,
                        f"{self.cg.name}.{func.attr}() requires {req} "
                        f"but it is not held at this call site",
                    )
        if (
            held
            and d is not None
            and (d in vocab.BLOCKING_CALLEES
                 or any(d.endswith("." + b) for b in vocab.BLOCKING_CALLEES))
        ):
            self._f(
                "SPDC202", node,
                f"blocking call {d}() while holding "
                f"{', '.join(sorted(held))}",
            )


def _required_guard_findings(
    files: list[SourceFile], collected: dict[str, dict[str, ClassGuards]]
) -> list[Finding]:
    out: list[Finding] = []
    for suffix, clsname, attr in vocab.REQUIRED_GUARDS:
        for sf in files:
            if not sf.path.endswith(suffix):
                continue
            cg = collected.get(sf.path, {}).get(clsname)
            if cg is None:
                out.append(Finding(
                    sf.path, 1, "SPDC206",
                    f"class {clsname} (REQUIRED_GUARDS) not found",
                ))
            elif attr not in cg.guards:
                out.append(Finding(
                    sf.path, cg.lineno, "SPDC206",
                    f"{clsname}.{attr} must carry a '#: guarded-by:' "
                    f"annotation (REQUIRED_GUARDS)",
                ))
    return out


def run(files: list[SourceFile], ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    collected: dict[str, dict[str, ClassGuards]] = {}
    for sf in files:
        if sf.tree is None:
            continue
        per_class: dict[str, ClassGuards] = {}
        for node in sf.tree.body:
            if not isinstance(node, ast.ClassDef):
                continue
            cg = _collect_class(node, sf.lines)
            per_class[cg.name] = cg
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    findings.extend(
                        _MethodWalker(sf.path, cg, sub).findings
                    )
        collected[sf.path] = per_class
    findings.extend(_required_guard_findings(files, collected))
    return findings
