"""repro-lint: AST static analysis for the SPDC tree (DESIGN.md §11).

Four passes over the source, each with stable SPDCxxx finding codes:

1. ``taint``   — secret-taint / trust-boundary dataflow (SPDC10x)
2. ``locks``   — lock discipline for annotated attributes (SPDC20x)
3. ``jit``     — jit/tracer hygiene (SPDC30x)
4. ``exports`` — dead public API surface (SPDC401)

Run as ``python -m tools.repro_lint src benchmarks examples``.
Stdlib-only: safe for the dependency-free CI lint job.
"""

from .engine import Finding, lint_paths, lint_sources  # noqa: F401
from .vocab import CODES  # noqa: F401

__all__ = ["Finding", "lint_paths", "lint_sources", "CODES"]
