"""Pass 1 — secret-taint / trust-boundary dataflow (SPDC101..105).

Intra-procedural forward taint with conservative per-parameter call
summaries (DESIGN.md §11.2). Taint is a set of labels: the reserved
label ``*secret*`` marks values derived from the declared vocabulary
(vocab.SECRET_PARAMS / SECRET_ATTRS / SECRET_CALLS); parameter-name
labels track which formal a value came from, which is what makes the
summaries precise. A finding is emitted when a ``*secret*``-labelled
value reaches a boundary, logging, exception, or metrics sink without
passing through a sanctioned chokepoint (vocab.SANITIZERS).

Call summaries: every module-level function/method is pre-analyzed once
with each parameter carrying its own label. That yields, per function:
``sink_params`` — formals that can reach a sink inside (with the sink's
code) — and ``ret_params`` — formals whose taint flows to the return
value. At a local call site, only arguments bound to a sink formal
report, and only arguments bound to a return formal taint the result.
This stays linear in program size and catches one level of
secret-through-helper indirection; helper→helper chains are analyzed
from each function's own entry instead (every function whose formals
are secret-*named* re-enters the analysis with real secret labels).

Scope: src/repro/{api,core,serve,distrib} only. benchmarks/ and
examples/ are the data owner's own scripts — plaintext is *supposed* to
live there. Within serve/, ``key``/``keys`` name BucketKeys (public
batching identity), not cipher keys, so the key-ish names only taint
under core/ and api/ (vocab.SECRET_KEY_PARAMS).
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field

from . import vocab
from .engine import Context, Finding, SourceFile

SECRET = "*secret*"
EMPTY: frozenset[str] = frozenset()

#: builtins whose result is cardinality/identity metadata, never payload
CLEAN_FUNCS = frozenset({"len", "isinstance", "type", "callable", "bool",
                         "range", "id"})


def _dotted(node: ast.expr) -> str | None:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _last(d: str | None) -> str | None:
    return d.rsplit(".", 1)[-1] if d else None


@dataclass
class Summary:
    name: str = ""
    params: list[str] = field(default_factory=list)
    sink_params: dict[str, str] = field(default_factory=dict)  # param -> code
    ret_params: set[str] = field(default_factory=set)


def _secret_params_for(path: str) -> frozenset[str]:
    base = vocab.SECRET_PARAMS
    if any(p in path for p in vocab.SECRET_KEY_SCOPES):
        return base | vocab.SECRET_KEY_PARAMS
    return base


class _FunctionTaint:
    """Single forward pass over one function body, label-set taint."""

    def __init__(
        self,
        fn: ast.FunctionDef | ast.AsyncFunctionDef,
        path: str,
        summaries: dict[str, Summary],
        *,
        summary_mode: bool,
    ):
        self.fn = fn
        self.path = path
        self.summaries = summaries
        self.summary_mode = summary_mode
        self.findings: list[Finding] = []
        self.sink_labels: dict[str, str] = {}  # label -> first sink code
        self.ret_labels: set[str] = set()
        self.env: dict[str, frozenset[str]] = {}
        self.params = _param_names(fn)
        secret_names = _secret_params_for(path)
        for p in self.params:
            if summary_mode:
                self.env[p] = frozenset({p})
            else:
                self.env[p] = (
                    frozenset({SECRET}) if p in secret_names else EMPTY
                )

    # ------------------------------------------------------------- expr

    def taint(self, node: ast.expr | None) -> frozenset[str]:
        if node is None or isinstance(node, ast.Constant):
            return EMPTY
        if isinstance(node, ast.Name):
            return self.env.get(node.id, EMPTY)
        if isinstance(node, ast.Attribute):
            if node.attr in vocab.METADATA_ATTRS:
                self.taint(node.value)  # still walk for nested calls
                return EMPTY
            base = self.taint(node.value)
            if node.attr in vocab.SECRET_ATTRS and not self.summary_mode:
                return base | {SECRET}
            return base
        if isinstance(node, ast.Call):
            return self._call(node)
        if isinstance(node, ast.Compare):
            # comparison results are booleans (shape checks, thresholds)
            self.taint(node.left)
            for c in node.comparators:
                self.taint(c)
            return EMPTY
        if isinstance(node, ast.BinOp):
            return self.taint(node.left) | self.taint(node.right)
        if isinstance(node, ast.BoolOp):
            out = EMPTY
            for v in node.values:
                out = out | self.taint(v)
            return out
        if isinstance(node, ast.UnaryOp):
            return self.taint(node.operand)
        if isinstance(node, ast.IfExp):
            self.taint(node.test)
            return self.taint(node.body) | self.taint(node.orelse)
        if isinstance(node, ast.Subscript):
            self.taint(node.slice)
            return self.taint(node.value)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            out = EMPTY
            for e in node.elts:
                out = out | self.taint(e)
            return out
        if isinstance(node, ast.Dict):
            out = EMPTY
            for k in node.keys:
                if k is not None:
                    out = out | self.taint(k)
            for v in node.values:
                out = out | self.taint(v)
            return out
        if isinstance(node, ast.JoinedStr):
            out = EMPTY
            for v in node.values:
                if isinstance(v, ast.FormattedValue):
                    out = out | self.taint(v.value)
            return out
        if isinstance(node, ast.FormattedValue):
            return self.taint(node.value)
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            return self._comp(node, [node.elt])
        if isinstance(node, ast.DictComp):
            return self._comp(node, [node.key, node.value])
        if isinstance(node, ast.Starred):
            return self.taint(node.value)
        if isinstance(node, ast.Await):
            return self.taint(node.value)
        if isinstance(node, ast.NamedExpr):
            t = self.taint(node.value)
            if isinstance(node.target, ast.Name):
                self.env[node.target.id] = t
            return t
        return EMPTY

    def _comp(self, comp: ast.expr, elts: list[ast.expr]) -> frozenset[str]:
        saved = dict(self.env)
        for gen in comp.generators:  # type: ignore[attr-defined]
            self._bind_iter(gen.target, gen.iter)
            for cond in gen.ifs:
                self.taint(cond)
        out = EMPTY
        for e in elts:
            out = out | self.taint(e)
        self.env = saved
        return out

    def _bind_iter(self, target: ast.expr, iter_node: ast.expr) -> None:
        """Bind a loop/comprehension target, element-wise through the
        common zip()/enumerate() shapes so one secret operand does not
        smear its co-iterated metadata (seeds vs metas)."""
        if isinstance(iter_node, ast.Call):
            d = _dotted(iter_node.func)
            if (
                d == "zip"
                and isinstance(target, (ast.Tuple, ast.List))
                and len(target.elts) == len(iter_node.args)
            ):
                for e, a in zip(target.elts, iter_node.args, strict=False):
                    self._bind(e, self.taint(a))
                return
            if (
                d == "enumerate"
                and isinstance(target, (ast.Tuple, ast.List))
                and len(target.elts) == 2
                and iter_node.args
            ):
                self._bind(target.elts[0], EMPTY)
                self._bind_iter(target.elts[1], iter_node.args[0])
                return
        self._bind(target, self.taint(iter_node))

    def _bind(self, target: ast.expr, t: frozenset[str]) -> None:
        if isinstance(target, ast.Name):
            self.env[target.id] = t
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, t)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, t)
        # stores through self.X are tracked statically via SECRET_ATTRS

    # ------------------------------------------------------------- call

    def _call(self, node: ast.Call) -> frozenset[str]:
        arg_t = [self.taint(a) for a in node.args]
        kw_t = {k.arg: self.taint(k.value) for k in node.keywords}
        all_labels = EMPTY
        for t in arg_t:
            all_labels = all_labels | t
        for t in kw_t.values():
            all_labels = all_labels | t
        d = _dotted(node.func)
        last = _last(d)

        self._check_sinks(node, d, last, all_labels)

        # sanctioned chokepoints launder; metadata builtins are clean
        if last in vocab.SANITIZERS or last in CLEAN_FUNCS:
            return EMPTY
        if d and d.startswith(vocab.SANITIZER_PREFIXES):
            return EMPTY
        if d in vocab.SECRET_CALLS or last in vocab.SECRET_CALLS:
            return EMPTY if self.summary_mode else frozenset({SECRET})

        # receiver taint rides along: m.copy() of a secret is secret
        recv_t = (
            self.taint(node.func.value)
            if isinstance(node.func, ast.Attribute)
            else EMPTY
        )

        summ = self.summaries.get(last or "")
        if summ is not None:
            return recv_t | self._apply_summary(summ, node, arg_t, kw_t)

        # unknown callee: conservative propagation
        return all_labels | recv_t

    def _apply_summary(
        self,
        summ: Summary,
        node: ast.Call,
        arg_t: list[frozenset[str]],
        kw_t: dict[str | None, frozenset[str]],
    ) -> frozenset[str]:
        """Bind call arguments to the callee's formals; report args that
        hit an in-callee sink, propagate args bound to return formals."""
        bound: list[tuple[str | None, frozenset[str]]] = []
        for i, t in enumerate(arg_t):
            p = summ.params[i] if i < len(summ.params) else None
            bound.append((p, t))
        for name, t in kw_t.items():
            bound.append((name if name in summ.params else None, t))
        out = EMPTY
        for p, t in bound:
            if not t:
                continue
            code = summ.sink_params.get(p or "")
            if code is not None:
                if SECRET in t:
                    self._report(
                        code, node,
                        f"secret argument for {p!r} reaches a "
                        f"{_sink_noun(code)} inside {summ.name}()",
                    )
                elif self.summary_mode:
                    # transitive: my formal feeds a sink one level down
                    for lbl in t:
                        self.sink_labels.setdefault(lbl, code)
            if p is None or p in summ.ret_params:
                out = out | t
        return out

    def _check_sinks(
        self,
        node: ast.Call,
        d: str | None,
        last: str | None,
        labels: frozenset[str],
    ) -> None:
        if not labels:
            return
        code_msg: list[tuple[str, str]] = []
        if last in vocab.BOUNDARY_CTORS:
            code_msg.append((
                "SPDC101",
                f"secret value passed to boundary constructor {last}()",
            ))
        if d in vocab.WIRE_CALLEES or (last == "encode" and d and "wire" in d):
            code_msg.append(("SPDC101", "secret value passed to a wire encoder"))
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr in vocab.TRANSPORT_METHODS
        ):
            recv = _dotted(node.func.value) or ""
            if "transport" in recv.lower():
                code_msg.append((
                    "SPDC101",
                    f"secret value passed to transport .{node.func.attr}()",
                ))
        if d in vocab.LOG_CALLEES or (
            d and d.startswith(vocab.LOG_CALLEE_PREFIXES)
        ):
            code_msg.append(("SPDC102", f"secret value logged via {d}()"))
        if last in vocab.METRIC_CTORS:
            code_msg.append((
                "SPDC104", f"secret value in metrics event {last}()",
            ))
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr in vocab.METRIC_METHODS
        ):
            code_msg.append((
                "SPDC104",
                f"secret value passed to metrics .{node.func.attr}()",
            ))
        for code, msg in code_msg:
            self._sink(code, node, msg, labels)

    def _sink(
        self, code: str, node: ast.AST, msg: str, labels: frozenset[str]
    ) -> None:
        if self.summary_mode:
            for lbl in labels:
                self.sink_labels.setdefault(lbl, code)
        elif SECRET in labels:
            self._report(code, node, msg)

    def _report(self, code: str, node: ast.AST, msg: str) -> None:
        if not self.summary_mode:
            self.findings.append(Finding(self.path, node.lineno, code, msg))

    # ------------------------------------------------------------- stmt

    def run(self) -> "Summary":
        self._block(self.fn.body)
        return Summary(
            name=self.fn.name,
            params=self.params,
            sink_params={
                p: c for p, c in self.sink_labels.items() if p in self.params
            },
            ret_params=self.ret_labels & set(self.params),
        )

    def _block(self, stmts: list[ast.stmt]) -> None:
        for s in stmts:
            self._stmt(s)

    def _stmt(self, s: ast.stmt) -> None:
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return  # nested defs run later, outside this flow
        if isinstance(s, ast.Assign):
            t = self.taint(s.value)
            for tgt in s.targets:
                self._bind(tgt, t)
        elif isinstance(s, ast.AnnAssign):
            if s.value is not None:
                self._bind(s.target, self.taint(s.value))
        elif isinstance(s, ast.AugAssign):
            t = self.taint(s.value)
            if isinstance(s.target, ast.Name):
                self.env[s.target.id] = (
                    self.env.get(s.target.id, EMPTY) | t
                )
        elif isinstance(s, ast.Expr):
            self.taint(s.value)
        elif isinstance(s, ast.Return):
            self.ret_labels |= self.taint(s.value)
        elif isinstance(s, ast.Raise):
            self._raise(s)
        elif isinstance(s, ast.Assert):
            self.taint(s.test)
            if s.msg is not None:
                self._sink(
                    "SPDC103", s, "secret value in assert message",
                    self.taint(s.msg),
                )
        elif isinstance(s, (ast.For, ast.AsyncFor)):
            self._bind_iter(s.target, s.iter)
            self._block(s.body)
            self._block(s.orelse)
        elif isinstance(s, ast.While):
            self.taint(s.test)
            self._block(s.body)
            self._block(s.orelse)
        elif isinstance(s, ast.If):
            self.taint(s.test)
            self._block(s.body)
            self._block(s.orelse)
        elif isinstance(s, (ast.With, ast.AsyncWith)):
            for item in s.items:
                self.taint(item.context_expr)
                if item.optional_vars is not None:
                    self._bind(item.optional_vars, EMPTY)
            self._block(s.body)
        elif isinstance(s, ast.Try):
            self._block(s.body)
            for h in s.handlers:
                if h.name:
                    self.env[h.name] = EMPTY
                self._block(h.body)
            self._block(s.orelse)
            self._block(s.finalbody)

    def _raise(self, s: ast.Raise) -> None:
        exc = s.exc
        if exc is None:
            return
        if isinstance(exc, ast.Call):
            labels = EMPTY
            for a in exc.args:
                labels = labels | self.taint(a)
            for k in exc.keywords:
                labels = labels | self.taint(k.value)
            self._sink(
                "SPDC103", s,
                "secret value interpolated into exception message", labels,
            )
        else:
            self._sink(
                "SPDC103", s, "secret value raised as exception",
                self.taint(exc),
            )


def _param_names(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> list[str]:
    args = fn.args
    params = list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs)
    names = [a.arg for a in params]
    if names and names[0] in ("self", "cls"):
        names = names[1:]
    if args.vararg:
        names.append(args.vararg.arg)
    if args.kwarg:
        names.append(args.kwarg.arg)
    return names


def _sink_noun(code: str) -> str:
    return {
        "SPDC101": "trust-boundary sink",
        "SPDC102": "logging sink",
        "SPDC103": "exception message",
        "SPDC104": "metrics label",
    }.get(code, "sink")


def _functions(tree: ast.Module):
    """Yield (func_node, qualname) for module functions and methods."""
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node, node.name
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield sub, f"{node.name}.{sub.name}"


def _whitelist_check(ctx: Context) -> list[Finding]:
    """SPDC105: each wire-task dataclass and the client-side mint
    whitelist that guards it must agree exactly — a field added to a
    wire message without a whitelist decision (or a stale whitelist
    name) is a boundary change nobody signed off on. One check per row
    of vocab.TASK_WHITELISTS."""
    out: list[Finding] = []
    for wl_path, wl_name, dc_path, dc_name in vocab.TASK_WHITELISTS:
        out.extend(_whitelist_check_one(ctx, wl_path, wl_name,
                                        dc_path, dc_name))
    return out


def _whitelist_check_one(
    ctx: Context, wl_path: str, wl_name: str, dc_path: str, dc_name: str
) -> list[Finding]:
    wl_file = ctx.by_suffix(wl_path)
    dc_file = ctx.by_suffix(dc_path)
    if wl_file is None or dc_file is None:
        return []
    if wl_file.tree is None or dc_file.tree is None:
        return []

    whitelist: set[str] | None = None
    wl_line = 1
    for node in ast.walk(wl_file.tree):
        if isinstance(node, ast.Assign):
            names = [t.id for t in node.targets if isinstance(t, ast.Name)]
            if wl_name in names:
                try:
                    val = ast.literal_eval(
                        node.value.args[0]
                        if isinstance(node.value, ast.Call)
                        else node.value
                    )
                    whitelist = set(val)
                    wl_line = node.lineno
                except Exception:
                    pass

    fields: set[str] = set()
    dc_line = 1
    for node in dc_file.tree.body:
        if isinstance(node, ast.ClassDef) and node.name == dc_name:
            dc_line = node.lineno
            for sub in node.body:
                if isinstance(sub, ast.AnnAssign) and isinstance(
                    sub.target, ast.Name
                ):
                    fields.add(sub.target.id)

    out: list[Finding] = []
    if whitelist is None:
        out.append(Finding(
            wl_file.path, wl_line, "SPDC105",
            f"{wl_name} whitelist not found in "
            f"{wl_file.path} (moved or deleted?)",
        ))
        return out
    if not fields:
        return out
    for f in sorted(fields - whitelist):
        out.append(Finding(
            dc_file.path, dc_line, "SPDC105",
            f"{dc_name} field {f!r} is not in the "
            f"{wl_name} whitelist",
        ))
    for f in sorted(whitelist - fields):
        out.append(Finding(
            wl_file.path, wl_line, "SPDC105",
            f"whitelist entry {f!r} matches no {dc_name} field",
        ))
    return out


def run(files: list[SourceFile], ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for sf in files:
        if sf.tree is None:
            continue
        if not any(p in sf.path for p in vocab.TAINT_SCOPE_PREFIXES):
            continue
        # phase 1: per-parameter summaries (definition order; a helper
        # defined before its callee sees no summary for it — one level
        # of indirection is the documented contract)
        summaries: dict[str, Summary] = {}
        for fn, qual in _functions(sf.tree):
            ft = _FunctionTaint(fn, sf.path, summaries, summary_mode=True)
            summ = ft.run()
            summ.name = qual
            summaries[fn.name] = summ
        # phase 2: real analysis with the secret vocabulary
        for fn, _qual in _functions(sf.tree):
            ft = _FunctionTaint(fn, sf.path, summaries, summary_mode=False)
            ft.run()
            findings.extend(ft.findings)
    findings.extend(_whitelist_check(ctx))
    return findings
