"""repro-lint engine: findings, suppressions, file discovery, pass driver.

Stdlib-only (ast/re/pathlib) — this runs in the CI lint job, which
installs no project dependencies. Passes live in sibling modules and
register through PASSES; each is a function
``(files: list[SourceFile], ctx: Context) -> list[Finding]``.

Suppression syntax (DESIGN.md §11.4)::

    x = risky()  # repro-lint: ignore[SPDC102] -- startup banner, no payload

The justification after ``--`` is mandatory; an ignore without one is
itself a finding (SPDC001) and cannot be suppressed. A suppression may
sit trailing on the offending line or on its own line directly above.
Stale suppressions (matching no finding) are findings too (SPDC003), so
the ignore inventory can only shrink when the underlying issue is gone.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path

from . import vocab

SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*ignore\[([A-Za-z0-9_,\s]+)\](.*)$"
)
JUSTIFY_RE = re.compile(r"^\s*--\s*\S")


@dataclass(frozen=True, order=True)
class Finding:
    path: str
    line: int
    code: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


@dataclass
class Suppression:
    line: int          # physical line of the comment
    target: int        # line whose findings it silences
    codes: frozenset[str]
    used: bool = False


@dataclass
class SourceFile:
    """One parsed file plus its suppression table.

    ``path`` is the repo-relative posix label; passes match on suffixes
    of it, so fixture tests can use the same labels as the real tree.
    """

    path: str
    text: str
    tree: ast.Module | None = None
    lines: list[str] = field(default_factory=list)
    suppressions: list[Suppression] = field(default_factory=list)
    parse_error: Finding | None = None
    _eager: list[Finding] = field(default_factory=list)

    @classmethod
    def parse(cls, path: str, text: str) -> "SourceFile":
        sf = cls(path=path, text=text, lines=text.splitlines())
        try:
            sf.tree = ast.parse(text)
        except SyntaxError as e:
            sf.parse_error = Finding(
                path, e.lineno or 1, "SPDC000", f"syntax error: {e.msg}"
            )
        sf._collect_suppressions()
        return sf

    def _collect_suppressions(self) -> None:
        for i, raw in enumerate(self.lines, start=1):
            m = SUPPRESS_RE.search(raw)
            if not m:
                continue
            codes = frozenset(
                c.strip() for c in m.group(1).split(",") if c.strip()
            )
            before = raw[: m.start()]
            if before.strip():
                target = i
            else:
                target = self._next_code_line(i)
            self.suppressions.append(
                Suppression(line=i, target=target, codes=codes)
            )
            # malformed suppressions are findings in their own right;
            # recorded eagerly so they surface even in pass subsets
            if not JUSTIFY_RE.match(m.group(2)):
                self._eager.append(Finding(
                    self.path, i, "SPDC001",
                    "suppression lacks ' -- <justification>'",
                ))
            for c in codes:
                if c not in vocab.CODES:
                    self._eager.append(Finding(
                        self.path, i, "SPDC002",
                        f"unknown finding code {c!r} in suppression",
                    ))
                elif c in vocab.UNSUPPRESSIBLE:
                    self._eager.append(Finding(
                        self.path, i, "SPDC002",
                        f"{c} cannot be suppressed",
                    ))

    def _next_code_line(self, after: int) -> int:
        for j in range(after, len(self.lines)):
            s = self.lines[j].strip()
            if s and not s.startswith("#"):
                return j + 1
        return after

    def eager_findings(self) -> list[Finding]:
        out = list(self._eager)
        if self.parse_error is not None:
            out.append(self.parse_error)
        return out

    def suppressed(self, finding: Finding) -> bool:
        if finding.code in vocab.UNSUPPRESSIBLE:
            return False
        hit = False
        for s in self.suppressions:
            if s.target == finding.line and finding.code in s.codes:
                s.used = True
                hit = True
        return hit

    def stale_suppressions(self) -> list[Finding]:
        return [
            Finding(
                self.path, s.line, "SPDC003",
                f"suppression for {','.join(sorted(s.codes))} matched no finding",
            )
            for s in self.suppressions
            if not s.used and not (s.codes & vocab.UNSUPPRESSIBLE)
        ]


@dataclass
class Context:
    """Shared pass context: all scanned files + optional real repo root
    (None when linting in-memory fixture sources)."""

    files: list["SourceFile"]
    root: Path | None = None

    def by_suffix(self, suffix: str) -> "SourceFile | None":
        for f in self.files:
            if f.path.endswith(suffix):
                return f
        return None


def _discover(root: Path, targets: list[str]) -> list[Path]:
    out: list[Path] = []
    for t in targets:
        p = (root / t) if not Path(t).is_absolute() else Path(t)
        if p.is_file() and p.suffix == ".py":
            out.append(p)
        elif p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
    # de-dup, keep deterministic order, skip caches
    seen, uniq = set(), []
    for p in out:
        if "__pycache__" in p.parts or p in seen:
            continue
        seen.add(p)
        uniq.append(p)
    return uniq


def _run_passes(ctx: Context, passes: list | None) -> list[Finding]:
    from . import exports, jit_hygiene, locks, taint

    registry = {
        "taint": taint.run,
        "locks": locks.run,
        "jit": jit_hygiene.run,
        "exports": exports.run,
    }
    names = passes if passes is not None else list(registry)
    findings: list[Finding] = []
    for f in ctx.files:
        findings.extend(f.eager_findings())
    for name in names:
        findings.extend(registry[name](ctx.files, ctx))
    # apply suppressions, then report stale ones
    by_path = {f.path: f for f in ctx.files}
    kept = []
    for fi in findings:
        sf = by_path.get(fi.path)
        if sf is not None and sf.suppressed(fi):
            continue
        kept.append(fi)
    for sf in ctx.files:
        kept.extend(sf.stale_suppressions())
    return sorted(set(kept))


def lint_sources(
    sources: dict[str, str],
    passes: list[str] | None = None,
    root: Path | None = None,
) -> list[Finding]:
    """Lint in-memory sources; keys are repo-relative path labels."""
    files = [SourceFile.parse(p, s) for p, s in sources.items()]
    return _run_passes(Context(files=files, root=root), passes)


def lint_paths(
    targets: list[str],
    root: Path | str | None = None,
    passes: list[str] | None = None,
) -> list[Finding]:
    rootp = Path(root) if root is not None else Path.cwd()
    files = []
    for p in _discover(rootp, targets):
        rel = p.relative_to(rootp).as_posix() if p.is_relative_to(rootp) else str(p)
        files.append(SourceFile.parse(rel, p.read_text(encoding="utf-8")))
    return _run_passes(Context(files=files, root=rootp), passes)
