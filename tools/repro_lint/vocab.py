"""Declared vocabularies for the repro-lint passes (DESIGN.md §11.1).

This module is the single place where the *names* of the protocol's
secrets, sanctioned chokepoints, boundary sinks, and lock-coverage
requirements live. The passes are generic dataflow/scope machinery; all
protocol knowledge is data in this file, so a reviewer can audit the
security argument by reading one table instead of four visitors.

Everything here is checked against the live tree by
tests/test_repro_lint.py — deleting an entry that the tree relies on
(e.g. a REQUIRED_GUARDS row, or a name from the ShardTask whitelist in
api/client.py) makes ``python -m tools.repro_lint`` exit non-zero.
"""

from __future__ import annotations

# --------------------------------------------------------------------------
# Finding codes. SPDC0xx engine/suppression, 1xx taint, 2xx locks,
# 3xx jit hygiene, 4xx exports. The table is the one rendered in
# DESIGN.md §11.3; keep the two in sync (tools/check_docs.py does not
# diff them, tests/test_repro_lint.py does).
# --------------------------------------------------------------------------

CODES: dict[str, str] = {
    "SPDC000": "file does not parse (syntax error)",
    "SPDC001": "suppression without ' -- <justification>' (not suppressible)",
    "SPDC002": "suppression names an unknown finding code",
    "SPDC003": "suppression matched no finding on its line (stale)",
    "SPDC101": "secret value reaches a trust-boundary sink (task/wire/transport)",
    "SPDC102": "secret value reaches a logging/print sink",
    "SPDC103": "secret value interpolated into an exception message",
    "SPDC104": "secret value used as a metrics/event label or field",
    "SPDC105": "ShardTask fields and the client _TASK_FIELDS whitelist disagree",
    "SPDC201": "guarded attribute mutated outside its lock",
    "SPDC202": "blocking operation while holding a lock",
    "SPDC203": "user hook fired while holding a lock",
    "SPDC204": "requires-lock method called without the lock held",
    "SPDC206": "required guarded-by annotation is missing",
    "SPDC301": "wall-clock read inside jit-traced code",
    "SPDC302": "host RNG inside jit-traced code",
    "SPDC303": "mutable global state touched inside jit-traced code",
    "SPDC304": "unhashable value passed for a static jit argument",
    "SPDC401": "public symbol in src/repro referenced nowhere",
}

#: Codes that may never be suppressed — a suppression *about*
#: suppressions would be circular, and a syntax error hides everything.
UNSUPPRESSIBLE: frozenset[str] = frozenset({"SPDC000", "SPDC001", "SPDC002", "SPDC003"})

# --------------------------------------------------------------------------
# Pass 1 — secret-taint / trust-boundary (SPDC10x).
#
# Scope: the protocol implementation only. benchmarks/ and examples/ are
# client-side driver scripts that legitimately hold plaintext (they ARE
# the data owner in the paper's model), so taint there is meaningless;
# they still get passes 2-4.
# --------------------------------------------------------------------------

TAINT_SCOPE_PREFIXES: tuple[str, ...] = (
    "src/repro/api/",
    "src/repro/core/",
    "src/repro/serve/",
    "src/repro/distrib/",
    "src/repro/linalg/",
)

#: Parameter names that introduce taint at function entry. These are the
#: paper's objects: the plaintext matrix (m/matrix/x...), PMOP seeds and
#: derived keys, the blinding vector v, rotation degrees psi.
SECRET_PARAMS: frozenset[str] = frozenset({
    "m", "ms", "mi", "matrix", "matrices", "m_host", "m_hosts",
    "seed", "seeds", "aug_key",
    "psi", "digest", "plaintext", "plaintexts", "secret", "secrets",
})

#: key-ish parameter names are secret only under these path fragments:
#: in core/ and api/ a ``key`` is cipher key material; in serve/ the
#: same name is a BucketKey — the gateway's *public* batching identity.
SECRET_KEY_PARAMS: frozenset[str] = frozenset({"key", "keys", "key_vs", "v"})
SECRET_KEY_SCOPES: tuple[str, ...] = ("src/repro/core/", "src/repro/api/")

#: Attribute loads that introduce taint regardless of the object:
#: ``anything.psi`` is a rotation secret, ``req.matrix`` is plaintext.
SECRET_ATTRS: frozenset[str] = frozenset({
    "psi", "digest", "_m_host", "_m_hosts", "seeds", "v", "matrix",
    "aug_key", "_keys",
})

#: Calls whose *result* is secret (dotted suffix match on the unparsed
#: callee): the seed/key mint points and raw key material.
SECRET_CALLS: frozenset[str] = frozenset({
    "seedgen", "seedgen_batch", "keygen", "keygen_batch",
    "jax.random.key", "random.key",
})

#: Sanctioned chokepoints: a call THROUGH one of these launders taint —
#: its result is clean even with secret arguments. This is exactly the
#: paper's boundary argument: cipher/augment outputs are what servers
#: may see; dispatch_subseed and hashlib are one-way derivations;
#: outsource_determinant* are the audited client facades that perform
#: the whole PMOP→dispatch→RRVP round themselves.
SANITIZERS: frozenset[str] = frozenset({
    "cipher", "cipher_batch", "_cipher_host",
    "augment", "_augment_host", "_equilibrate_augment", "_equilibrate_augment_jit",
    "equilibrate",
    "dispatch_subseed",
    "outsource_determinant", "outsource_determinant_mixed",
    # linalg family: LinalgSession is the audited shared-LU client facade
    # (same standing as outsource_determinant — everything it ships is
    # ciphered/augmented internally); blind_rhs is the one-time-pad
    # chokepoint every secret RHS must pass before a trisolve round;
    # trisolve_subseed / _lane_rng are hashlib one-way derivations like
    # dispatch_subseed.
    "LinalgSession", "outsource_solve",
    "blind_rhs",
    "trisolve_subseed", "_lane_rng",
})

#: Dotted-callee prefixes that sanitize (hashlib.sha256(...).digest()).
SANITIZER_PREFIXES: tuple[str, ...] = ("hashlib.",)

#: Attribute loads that are metadata, never payload: taking .shape of a
#: secret array yields a public value (the paper pads/sizes openly).
METADATA_ATTRS: frozenset[str] = frozenset({
    "shape", "ndim", "dtype", "size", "nbytes", "itemsize",
    # gateway accounting identity on requests/results: timestamps, ids,
    # tenant names, the (public, padded) matrix size, and the requested
    # op kind ("det"/"slogdet"/"solve") — never payload
    "enqueued_at", "tenant", "rid", "n", "op",
})

#: Logging-style callees (dotted suffix match) -> SPDC102.
LOG_CALLEES: frozenset[str] = frozenset({
    "print", "warnings.warn", "sys.stdout.write", "sys.stderr.write",
})
LOG_CALLEE_PREFIXES: tuple[str, ...] = ("logging.", "logger.", "log.")

#: Boundary sinks -> SPDC101. Constructor names whose arguments cross to
#: the edge servers, and wire encoders.
BOUNDARY_CTORS: frozenset[str] = frozenset({"ShardTask", "TriSolveTask"})
WIRE_CALLEES: frozenset[str] = frozenset({"wire.encode", "encode_message"})
#: Transport submission methods (suffix match, receiver must *mention*
#: transport to avoid flagging every ThreadPoolExecutor.submit).
TRANSPORT_METHODS: frozenset[str] = frozenset({
    "start", "submit", "factor", "repair", "sweep", "driver_submit",
})

#: Metrics/event sinks -> SPDC104.
METRIC_CTORS: frozenset[str] = frozenset({
    "FlushEvent", "VerdictEvent", "RejectEvent",
})
METRIC_METHODS: frozenset[str] = frozenset({
    "record_submit", "record_verdict", "record_flush", "record_reject",
})

#: Cross-file whitelist checks (SPDC105): each row pairs a dataclass
#: that crosses the boundary with the runtime whitelist that guards its
#: construction — (whitelist file, whitelist name, dataclass file,
#: dataclass name). Every wire task kind gets a row; adding a field to
#: either side without the other is a boundary change nobody signed off.
TASK_WHITELISTS: tuple[tuple[str, str, str, str], ...] = (
    ("src/repro/api/client.py", "_TASK_FIELDS",
     "src/repro/api/messages.py", "ShardTask"),
    ("src/repro/api/client.py", "_SOLVE_TASK_FIELDS",
     "src/repro/api/messages.py", "TriSolveTask"),
)

# --------------------------------------------------------------------------
# Pass 2 — lock discipline (SPDC20x).
# --------------------------------------------------------------------------

#: (path suffix, class name, attribute) triples that MUST carry a
#: ``#: guarded-by:`` annotation. This list is what makes annotation
#: deletion loud: removing the comment from the source trips SPDC206
#: here rather than silently disabling the check.
REQUIRED_GUARDS: tuple[tuple[str, str, str], ...] = (
    # gateway shared state (all under the gateway RLock)
    ("serve/spdc_gateway.py", "SPDCGateway", "_queue"),
    ("serve/spdc_gateway.py", "SPDCGateway", "_results"),
    ("serve/spdc_gateway.py", "SPDCGateway", "_next_rid"),
    ("serve/spdc_gateway.py", "SPDCGateway", "_owned_transports"),
    ("serve/spdc_gateway.py", "SPDCGateway", "stats"),
    ("serve/spdc_gateway.py", "SPDCGateway", "metrics"),
    ("serve/spdc_gateway.py", "SPDCGateway", "_admission"),
    ("serve/spdc_gateway.py", "SPDCGateway", "_breakers"),
    ("serve/spdc_gateway.py", "SPDCGateway", "_cache"),
    ("serve/spdc_gateway.py", "SPDCGateway", "_inflight"),
    ("serve/spdc_gateway.py", "SPDCGateway", "_dummies"),
    # micro-batch queue: externally locked (the gateway's lock), the
    # annotation documents the contract and keeps the attr in this table
    ("serve/queue.py", "MicroBatchQueue", "_buckets"),
    ("serve/queue.py", "MicroBatchQueue", "_pending"),
    # socket transport metadata + worker daemon state
    ("api/socket_transport.py", "SocketTransport", "_socks"),
    ("api/socket_transport.py", "SocketTransport", "_hellos"),
    ("api/socket_transport.py", "SocketTransport", "_sent_plan"),
    ("api/socket_transport.py", "SocketTransport", "_spawned"),
    ("api/socket_transport.py", "WorkerDaemon", "_edges"),
    ("api/socket_transport.py", "WorkerDaemon", "_open"),
    ("api/socket_transport.py", "WorkerDaemon", "connections"),
    ("api/socket_transport.py", "WorkerDaemon", "frames_served"),
    # multiprocess transport metadata
    ("api/transport.py", "MultiprocessTransport", "_conns"),
    ("api/transport.py", "MultiprocessTransport", "_procs"),
    ("api/transport.py", "MultiprocessTransport", "_sent_plan"),
    ("api/transport.py", "ThreadPoolTransport", "_edges"),
)

#: Callees (dotted suffix) that block: jitted sweep dispatch, socket and
#: pipe I/O, futures, sleeps. Flagged under any held lock (SPDC202).
BLOCKING_CALLEES: frozenset[str] = frozenset({
    "time.sleep",
    "outsource_determinant", "outsource_determinant_mixed",
    "send_frame", "recv_frame", "serve_frame",
})
#: Method names that block regardless of receiver. ``.start`` is NOT
#: here: Process.start is a fast fork, and flagging it would outlaw the
#: legitimate spawn-under-metadata-lock pattern in the transports.
BLOCKING_METHODS: frozenset[str] = frozenset({
    "sleep", "result", "sendall", "recv", "recv_bytes", "send_bytes",
    "accept", "connect", "join", "wait",
})

#: User-hook attributes: firing one of these while holding a lock is the
#: PR-8 deadlock class (hook re-enters the gateway) -> SPDC203.
HOOK_ATTRS: frozenset[str] = frozenset({"on_flush", "on_verdict", "on_reject"})

#: Lock-ish attribute names recognised in ``with self.<name>:`` even
#: without an annotation mentioning them.
LOCK_NAME_HINTS: tuple[str, ...] = ("_lock", "_meta", "_worker_lock")

# --------------------------------------------------------------------------
# Pass 3 — jit/tracer hygiene (SPDC30x).
# --------------------------------------------------------------------------

WALLCLOCK_CALLEES: frozenset[str] = frozenset({
    "time.time", "time.monotonic", "time.perf_counter", "time.process_time",
    "time.time_ns", "time.monotonic_ns", "time.perf_counter_ns",
    "datetime.now", "datetime.utcnow", "datetime.datetime.now",
    "datetime.datetime.utcnow",
})

#: Host RNG callee prefixes (dotted). jax.random is NOT here — it is
#: functional and trace-safe; np/stdlib RNG inside a traced body bakes
#: one sample into the compiled executable.
HOST_RNG_PREFIXES: tuple[str, ...] = (
    "np.random.", "numpy.random.", "random.", "secrets.", "os.urandom",
)
#: Generator-method heuristic: ``rng.normal(...)`` where the receiver is
#: literally named like a host RNG handle.
HOST_RNG_RECEIVERS: frozenset[str] = frozenset({"rng", "np_rng", "host_rng"})
HOST_RNG_METHODS: frozenset[str] = frozenset({
    "standard_normal", "normal", "uniform", "integers", "random",
    "permutation", "choice", "shuffle",
})

#: Container-mutating method names for the module-global check.
MUTATOR_METHODS: frozenset[str] = frozenset({
    "append", "extend", "insert", "add", "update", "setdefault",
    "pop", "popitem", "clear", "remove", "discard",
})

# --------------------------------------------------------------------------
# Pass 4 — export audit (SPDC401).
# --------------------------------------------------------------------------

#: Reference index roots: identifiers are harvested from every .py file
#: under these (relative to repo root) regardless of which paths the CLI
#: was pointed at, so `python -m tools.repro_lint src` still knows that
#: tests/ uses a symbol.
REFERENCE_ROOTS: tuple[str, ...] = (
    "src", "tests", "benchmarks", "examples", "tools",
)

#: name -> justification. Symbols that are deliberately public yet
#: unreferenced (registry-filled, forward-compat API surface).
EXPORT_EXEMPT: dict[str, str] = {}

#: Module path suffixes excluded from the export audit entirely.
EXPORT_EXEMPT_MODULES: tuple[str, ...] = ()
