"""Pass 3 — jit/tracer hygiene (SPDC301..304).

Roots: module-level functions decorated ``@jax.jit`` / ``@jit`` /
``@partial(jax.jit, ...)``, plus functions wrapped by a module-level
``name = jax.jit(fn)`` assignment. From the roots, an intra-module call
graph (bare-name calls to module functions) gives the set of
traced-reachable bodies.

Inside a traced body, the following are one-time trace effects — they
bake a single host value into the compiled executable and silently
diverge on every later call (the classic "why is my timestamp frozen"
bug):

* wall-clock reads (time.*, datetime.now)            -> SPDC301
* host RNG (np.random/random/secrets/os.urandom;
  jax.random is functional and fine)                 -> SPDC302
* mutable module-global state (global stmt, stores
  or mutating method calls on module-level names)    -> SPDC303

SPDC304 checks that decorator-declared static args receive hashable
literals at intra-module call sites (a list/dict/set literal passed for
a static arg is a guaranteed TypeError at trace time — but only on the
first cache miss, which tests may never hit).
"""

from __future__ import annotations

import ast

from . import vocab
from .engine import Context, Finding, SourceFile


def _dotted(node: ast.expr) -> str | None:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jit_expr(node: ast.expr) -> bool:
    d = _dotted(node)
    return d in ("jit", "jax.jit")


def _jit_decoration(fn: ast.FunctionDef | ast.AsyncFunctionDef):
    """(is_jit, static_names, static_nums) from the decorator list."""
    static_names: set[str] = set()
    static_nums: set[int] = set()
    is_jit = False
    for dec in fn.decorator_list:
        call = dec if isinstance(dec, ast.Call) else None
        target = call.func if call else dec
        if _is_jit_expr(target):
            is_jit = True
        elif call is not None and _dotted(call.func) in (
            "partial", "functools.partial"
        ):
            if not (call.args and _is_jit_expr(call.args[0])):
                continue
            is_jit = True
        else:
            continue
        if call is None:
            continue
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                try:
                    v = ast.literal_eval(kw.value)
                    static_names.update(
                        [v] if isinstance(v, str) else list(v)
                    )
                except Exception:
                    pass
            elif kw.arg == "static_argnums":
                try:
                    v = ast.literal_eval(kw.value)
                    static_nums.update(
                        [v] if isinstance(v, int) else list(v)
                    )
                except Exception:
                    pass
    return is_jit, static_names, static_nums


class _ModulePass:
    def __init__(self, sf: SourceFile):
        self.sf = sf
        self.findings: list[Finding] = []
        tree = sf.tree
        assert tree is not None
        self.functions: dict[str, ast.FunctionDef | ast.AsyncFunctionDef] = {}
        self.module_names: set[str] = set()
        self.roots: dict[str, tuple[set[str], set[int]]] = {}

        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions[node.name] = node
                is_jit, names, nums = _jit_decoration(node)
                if is_jit:
                    self.roots[node.name] = (names, nums)
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        self.module_names.add(t.id)
                # name = jax.jit(fn, static_argnames=...)
                v = node.value
                if (
                    isinstance(v, ast.Call)
                    and _is_jit_expr(v.func)
                    and v.args
                    and isinstance(v.args[0], ast.Name)
                ):
                    names: set[str] = set()
                    nums: set[int] = set()
                    for kw in v.keywords:
                        try:
                            lv = ast.literal_eval(kw.value)
                        except Exception:
                            continue
                        if kw.arg == "static_argnames":
                            names.update([lv] if isinstance(lv, str) else list(lv))
                        elif kw.arg == "static_argnums":
                            nums.update([lv] if isinstance(lv, int) else list(lv))
                    self.roots[v.args[0].id] = (names, nums)
            elif isinstance(node, ast.AnnAssign) and isinstance(
                node.target, ast.Name
            ):
                self.module_names.add(node.target.id)

        self.reachable = self._reachability()

    def _callees(self, fn: ast.AST) -> set[str]:
        out: set[str] = set()
        for node in ast.walk(fn):
            if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                    and node.func.id in self.functions):
                out.add(node.func.id)
        return out

    def _reachability(self) -> set[str]:
        seen: set[str] = set()
        work = [r for r in self.roots if r in self.functions]
        while work:
            name = work.pop()
            if name in seen:
                continue
            seen.add(name)
            for callee in self._callees(self.functions[name]):
                if callee not in seen:
                    work.append(callee)
        return seen

    def run(self) -> None:
        for name in sorted(self.reachable):
            self._check_body(self.functions[name], name)
        self._check_static_call_sites()

    def _f(self, code: str, node: ast.AST, msg: str) -> None:
        self.findings.append(Finding(self.sf.path, node.lineno, code, msg))

    def _check_body(self, fn: ast.AST, name: str) -> None:
        locals_: set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                targets = (
                    node.targets
                    if isinstance(node, ast.Assign)
                    else [node.target]
                )
                for t in targets:
                    if isinstance(t, ast.Name):
                        locals_.add(t.id)
        for node in ast.walk(fn):
            if isinstance(node, ast.Global):
                self._f(
                    "SPDC303", node,
                    f"'global' statement in jit-traced {name}()",
                )
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (
                    node.targets
                    if isinstance(node, ast.Assign)
                    else [node.target]
                )
                for t in targets:
                    base = t
                    while isinstance(base, (ast.Subscript, ast.Attribute)):
                        base = base.value
                    if (
                        isinstance(base, ast.Name)
                        and base is not t  # only stores THROUGH the name
                        and base.id in self.module_names
                        and base.id not in locals_
                    ):
                        self._f(
                            "SPDC303", node,
                            f"store into module-level {base.id!r} inside "
                            f"jit-traced {name}()",
                        )
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            if d in vocab.WALLCLOCK_CALLEES or d == "time.sleep":
                self._f(
                    "SPDC301", node,
                    f"wall-clock read {d}() traces to a constant inside "
                    f"jit-traced {name}()",
                )
            elif d and d.startswith(vocab.HOST_RNG_PREFIXES):
                self._f(
                    "SPDC302", node,
                    f"host RNG {d}() inside jit-traced {name}() — one "
                    f"sample is baked in at trace time",
                )
            elif (
                isinstance(node.func, ast.Attribute)
                and node.func.attr in vocab.HOST_RNG_METHODS
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id in vocab.HOST_RNG_RECEIVERS
            ):
                self._f(
                    "SPDC302", node,
                    f"host RNG {node.func.value.id}.{node.func.attr}() "
                    f"inside jit-traced {name}()",
                )
            elif (
                isinstance(node.func, ast.Attribute)
                and node.func.attr in vocab.MUTATOR_METHODS
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id in self.module_names
                and node.func.value.id not in locals_
            ):
                self._f(
                    "SPDC303", node,
                    f"mutation of module-level {node.func.value.id!r} via "
                    f".{node.func.attr}() inside jit-traced {name}()",
                )

    def _check_static_call_sites(self) -> None:
        assert self.sf.tree is not None
        for node in ast.walk(self.sf.tree):
            if not isinstance(node, ast.Call):
                continue
            if not isinstance(node.func, ast.Name):
                continue
            root = self.roots.get(node.func.id)
            if root is None:
                continue
            static_names, static_nums = root
            for kw in node.keywords:
                if kw.arg in static_names and isinstance(
                    kw.value, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                               ast.DictComp, ast.SetComp)
                ):
                    self._f(
                        "SPDC304", node,
                        f"unhashable literal for static argument "
                        f"{kw.arg!r} of {node.func.id}()",
                    )
            for i, a in enumerate(node.args):
                if i in static_nums and isinstance(
                    a, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                        ast.DictComp, ast.SetComp)
                ):
                    self._f(
                        "SPDC304", node,
                        f"unhashable literal for static argument #{i} "
                        f"of {node.func.id}()",
                    )


def run(files: list[SourceFile], ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for sf in files:
        if sf.tree is None:
            continue
        mp = _ModulePass(sf)
        mp.run()
        findings.extend(mp.findings)
    return findings
