import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count at first init.

import argparse
import json
import sys
import time
import traceback
from dataclasses import replace
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import SHAPES, CONFIGS, cell_status, get_config
from repro.distrib.sharding import ShardingRules, make_rules, use_rules
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze
from repro.models.common import split_tree
from repro.models.lm import init_lm
from repro.serve.kvcache import cache_logical_specs, init_caches
from repro.serve.steps import build_decode_step, build_prefill_step
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.steps import build_train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "dryrun_results"


def _sds(tree, rules: ShardingRules, spec_tree):
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    def attach(x, spec):
        return jax.ShapeDtypeStruct(
            x.shape, x.dtype, sharding=NamedSharding(rules.mesh, rules.resolve(*spec))
        )
    return jax.tree.map(attach, tree, spec_tree)


def _batch_specs(cfg, shape, rules):
    b, s = shape.global_batch, shape.seq_len
    seq = 1 if shape.kind == "decode" else s
    batch_sh = NamedSharding(rules.mesh, rules.resolve("batch", None))
    out = {}
    if cfg.frontend is None:
        out["tokens"] = jax.ShapeDtypeStruct((b, seq), jnp.int32, sharding=batch_sh)
    else:
        out["embeds"] = jax.ShapeDtypeStruct(
            (b, seq, cfg.d_model), jnp.float32,
            sharding=NamedSharding(rules.mesh, rules.resolve("batch", None, None)),
        )
    if shape.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, seq), jnp.int32, sharding=batch_sh)
    return out


def rules_for(cfg, shape, mesh) -> ShardingRules:
    rules = make_rules(mesh, num_heads=cfg.num_heads or None,
                       num_kv_heads=cfg.num_kv_heads or None,
                       use_fsdp=cfg.use_fsdp)
    if cfg.dp_over_model:
        # pure-DP strategy: batch (and FSDP) over every mesh axis, no TP
        all_axes = tuple(a for a in ("pod", "data", "model")
                         if a in mesh.axis_names)
        rules = replace(rules, batch_axes=all_axes, model_axis=None,
                        fsdp_axes=all_axes if cfg.use_fsdp else (),
                        shard_heads=False, shard_kv=False)
    dsize = 1
    for a in rules.batch_axes:
        dsize *= mesh.shape[a]
    if dsize and shape.global_batch % dsize != 0:
        rules = replace(rules, batch_axes=())
    return rules


def effective_cfg(cfg, shape, mesh, rules) -> object:
    """Clamp grad_accum so each microbatch still shards evenly over the
    data axes (global_batch / accum must be a multiple of the data size)."""
    if shape.kind != "train" or cfg.grad_accum == 1:
        return cfg
    dsize = 1
    for a in rules.batch_axes:
        dsize *= mesh.shape[a]
    accum = cfg.grad_accum
    while accum > 1 and (shape.global_batch % accum or
                         (shape.global_batch // accum) % max(dsize, 1)):
        accum //= 2
    return replace(cfg, grad_accum=max(accum, 1))


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str):
    """Lower + compile one (arch × shape) cell; returns (compiled, rules, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rules = rules_for(cfg, shape, mesh)
    cfg = effective_cfg(cfg, shape, mesh, rules)

    with use_rules(rules):
        px = jax.eval_shape(lambda: init_lm(cfg, jax.random.key(0)))
        params_sds, specs = split_tree(px)
        params_sds = _sds(params_sds, rules, specs)
        batch_sds = _batch_specs(cfg, shape, rules)

        def shardings_of(tree):
            return jax.tree.map(lambda x: x.sharding, tree)
        if shape.kind == "train":
            opt_cfg = AdamWConfig(state_dtype=cfg.opt_dtype)
            opt_sds = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params_sds)
            opt_specs = {"mu": specs, "nu": specs, "step": ()}
            opt_sds = _sds(opt_sds, rules, opt_specs)
            step_fn = build_train_step(cfg, opt_cfg)
            # out_shardings pinned to the input layouts: stops GSPMD from
            # re-sharding (= all-gathering) optimizer math or gradients
            lowered = jax.jit(
                step_fn, donate_argnums=(0, 1),
                out_shardings=(shardings_of(params_sds), shardings_of(opt_sds),
                               None),
            ).lower(params_sds, opt_sds, batch_sds, jax.random.key(0))
        elif shape.kind == "prefill":
            step_fn = build_prefill_step(cfg)
            lowered = jax.jit(step_fn).lower(params_sds, batch_sds)
        else:  # decode
            caches_sds = jax.eval_shape(
                lambda: init_caches(cfg, shape.global_batch, shape.seq_len)
            )
            cache_specs = cache_logical_specs(cfg, caches_sds)
            caches_sds = _sds(caches_sds, rules, cache_specs)
            pos_sds = jax.ShapeDtypeStruct(
                (shape.global_batch,), jnp.int32,
                sharding=NamedSharding(rules.mesh, rules.resolve("batch")),
            )
            step_fn = build_decode_step(cfg)
            lowered = jax.jit(
                step_fn, donate_argnums=(1,),
                out_shardings=(None, shardings_of(caches_sds)),
            ).lower(params_sds, caches_sds, batch_sds, pos_sds)
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0
    meta = {"compile_s": compile_s, "cfg": cfg, "shape": shape,
            "params_sds": params_sds,
            "opt_sds": locals().get("opt_sds"),
            "caches_sds": locals().get("caches_sds")}
    return compiled, rules, meta


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: Path) -> dict:
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.devices.size
    compiled, rules, meta = lower_cell(arch, shape_name, mesh, mesh_name)
    cfg, shape = meta["cfg"], meta["shape"]

    mem = compiled.memory_analysis()
    memory_stats = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "peak_est_bytes": int(
            mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes
        ),
    }
    from repro.compat import cost_analysis_dict
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    hc = analyze_hlo(hlo)  # trip-count-corrected (see hlo_cost.py docstring)

    training = shape.kind == "train"
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    rl = analyze(
        arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
        cost={k: cost.get(k, 0.0) for k in ("flops", "bytes accessed")},
        hlo_text=hlo, memory_stats=memory_stats,
        active_params=cfg.active_param_count(), tokens=tokens,
        training=training, hlo_cost=hc,
    )
    rec = rl.to_dict()
    rec["xla_cost_analysis_raw"] = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "note": "XLA visits while bodies once; see hlo_cost.py",
    }
    rec["compile_s"] = meta["compile_s"]
    rec["sharding"] = {
        "shard_heads": rules.shard_heads, "shard_kv": rules.shard_kv,
        "batch_axes": list(rules.batch_axes),
    }
    # analytic state accounting (exact; the memory_analysis temp numbers
    # additionally carry XLA:CPU f32-promotion artifacts — see EXPERIMENTS.md)
    def _tree_bytes(t):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))

    state = {"params_total_bytes": _tree_bytes(meta["params_sds"])}
    if meta.get("opt_sds") is not None:
        state["opt_total_bytes"] = _tree_bytes(meta["opt_sds"])
    if meta.get("caches_sds") is not None:
        state["caches_total_bytes"] = _tree_bytes(meta["caches_sds"])
    state["state_per_device_gib"] = sum(
        v for k, v in state.items() if k.endswith("_bytes")
    ) / chips / 2**30
    rec["state_analysis"] = state
    out_dir.mkdir(parents=True, exist_ok=True)
    with open(out_dir / f"{arch}__{shape_name}__{mesh_name}.json", "w") as f:
        json.dump(rec, f, indent=1)
    print(
        f"[dryrun] {arch} × {shape_name} × {mesh_name}: OK "
        f"compile={meta['compile_s']:.1f}s "
        f"compute={rl.compute_s*1e3:.2f}ms memory={rl.memory_s*1e3:.2f}ms "
        f"collective={rl.collective_s*1e3:.2f}ms dominant={rl.dominant} "
        f"frac={rl.roofline_fraction:.3f} peak_mem={memory_stats['peak_est_bytes']/2**30:.2f}GiB"
    )
    print(f"  memory_analysis: {mem}")
    return rec


def run_spdc_cell(mesh_name: str, out_dir: Path, n: int = 8192) -> dict:
    """The paper's own workload on the production mesh: 16-server one-way
    pipelined LU over the model axis (f32 lowering; f64 validated in tests)."""
    from repro.distrib.spdc_pipeline import lu_nserver_shardmap
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    x_sds = jax.ShapeDtypeStruct(
        (n, n), jnp.float32,
        sharding=NamedSharding(mesh, jax.sharding.PartitionSpec("model", None)),
    )
    from functools import partial
    from repro.distrib.spdc_pipeline import _server_program
    from jax.sharding import PartitionSpec as P
    N = mesh.shape["model"]
    from repro.compat import shard_map

    fn = shard_map(
        partial(_server_program, n=n, b=n // N, num_servers=N, axis="model"),
        mesh=mesh, in_specs=P("model", None),
        out_specs=(P("model", None), P("model", None)),
    )
    t0 = time.time()
    lowered = jax.jit(fn).lower(x_sds)
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    from repro.compat import cost_analysis_dict
    cost = cost_analysis_dict(compiled)
    hc = analyze_hlo(compiled.as_text())
    rl = analyze(
        arch="spdc-lu", shape=f"n{n}", mesh_name=mesh_name,
        chips=mesh.devices.size,
        cost={k: cost.get(k, 0.0) for k in ("flops", "bytes accessed")},
        hlo_text=compiled.as_text(),
        memory_stats={"temp_bytes": int(mem.temp_size_in_bytes)},
        active_params=0.0, tokens=1.0, training=False, hlo_cost=hc,
    )
    rec = rl.to_dict()
    rec["compile_s"] = compile_s
    rec["lu_flops"] = 2 * n**3 / 3
    out_dir.mkdir(parents=True, exist_ok=True)
    with open(out_dir / f"spdc-lu__n{n}__{mesh_name}.json", "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[dryrun] spdc-lu n={n} × {mesh_name}: OK compile={compile_s:.1f}s "
          f"collective-permutes={rl.collectives['counts'].get('collective-permute', 0)}")
    return rec


def all_cells() -> list[tuple[str, str]]:
    cells = []
    for arch in CONFIGS:
        for shape_name in SHAPES:
            ok, _ = cell_status(CONFIGS[arch], shape_name)
            if ok:
                cells.append((arch, shape_name))
    return cells


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--spdc", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()
    out_dir = Path(args.out)
    if args.list:
        for a, s in all_cells():
            print(f"{a} {s}")
        return 0
    try:
        if args.spdc:
            run_spdc_cell(args.mesh, out_dir)
        else:
            run_cell(args.arch, args.shape, args.mesh, out_dir)
        return 0
    except Exception:
        traceback.print_exc()
        print(f"[dryrun] {args.arch} × {args.shape} × {args.mesh}: FAILED")
        return 1


if __name__ == "__main__":
    sys.exit(main())
