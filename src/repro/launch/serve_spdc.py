"""SPDC gateway launcher: drive the async micro-batching determinant
service with a synthetic open-loop client workload.

    PYTHONPATH=src python -m repro.launch.serve_spdc --smoke
    PYTHONPATH=src python -m repro.launch.serve_spdc \
        --servers 4 --requests 256 --rate 200 --sizes 24,48,96 \
        --max-batch 32 --max-wait-us 2000

Open-loop means arrivals are paced by the offered rate, not by service
completions (`--rate 0` = saturating: all requests arrive at once), so
queueing delay shows up in the reported p50/p99 latency exactly as it
would for independent IoT clients. Each request draws its size from
--sizes; the gateway buckets mixed sizes, coalesces each bucket into one
batched protocol sweep, and answers with a per-request verdict.

--check verifies every returned determinant against numpy slogdet at
rtol 1e-10 (always on with --smoke, which is the CI docs-job entry).
"""
from __future__ import annotations

import argparse
import asyncio
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np


def parse_sizes(spec: str) -> tuple[int, ...]:
    sizes = tuple(int(s) for s in spec.split(",") if s)
    if not sizes or any(s < 2 for s in sizes):
        raise argparse.ArgumentTypeError(f"bad --sizes {spec!r}")
    return sizes


def percentile_ms(lat_s: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(lat_s), q) * 1e3)


async def run_workload(gw, mats, arrival_s):
    """Submit each matrix at its open-loop arrival time; gather results."""
    t0 = time.perf_counter()
    results = [None] * len(mats)
    rejected = 0

    async def one(i):
        nonlocal rejected
        delay = arrival_s[i] - (time.perf_counter() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        from repro.serve import GatewayOverloaded

        try:
            results[i] = await gw.submit(mats[i])
        except GatewayOverloaded:
            rejected += 1

    await asyncio.gather(*(one(i) for i in range(len(mats))))
    wall = time.perf_counter() - t0
    return results, rejected, wall


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="SPDC micro-batching gateway + synthetic client swarm"
    )
    ap.add_argument("--servers", type=int, default=2,
                    help="edge servers per sweep (N)")
    ap.add_argument("--requests", type=int, default=128,
                    help="total client requests to offer")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="offered load, requests/sec (0 = saturating)")
    ap.add_argument("--sizes", type=parse_sizes, default=(24, 48, 96),
                    help="comma-separated raw matrix sizes clients draw from")
    ap.add_argument("--buckets", type=parse_sizes, default=None,
                    help="bucket sizes (default: preset buckets)")
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-wait-us", type=float, default=2000.0)
    ap.add_argument("--max-pending", type=int, default=4096)
    ap.add_argument("--method", choices=["q1", "q2", "q3"], default="q3")
    ap.add_argument("--mode", choices=["ewd", "ewm"], default="ewd")
    ap.add_argument("--transport",
                    choices=["inline", "threadpool", "multiprocess",
                             "socket"],
                    default="inline",
                    help="execution boundary for bucket sweeps (DESIGN.md "
                         "§7/§9): inline = fused fast path; threadpool = "
                         "in-process edge workers; multiprocess = spawned "
                         "worker processes, wire-codec messages; socket = "
                         "warm worker daemons over TCP/UDS (self-hosted "
                         "local UDS fleet when no addresses are given)")
    ap.add_argument("--recover", action="store_true",
                    help="heal rejected verdicts in place (DESIGN.md §4)")
    ap.add_argument("--standby", type=int, default=0)
    ap.add_argument("--no-warmup", dest="warmup", action="store_false",
                    help="skip pre-compiling bucket sweeps")
    ap.add_argument("--check", action="store_true",
                    help="verify every det against numpy slogdet")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + full checking (CI entry)")
    args = ap.parse_args(argv)

    from repro.configs import SPDCConfig, SPDCGatewayConfig
    from repro.serve import AsyncSPDCGateway

    if args.smoke:
        args.requests = min(args.requests, 24)
        args.sizes = (6, 10, 16)
        args.buckets = args.buckets or (16, 32)
        args.max_batch = min(args.max_batch, 8)
        args.check = True

    spdc = SPDCConfig(
        num_servers=args.servers, mode=args.mode, method=args.method,
        recover=args.recover, standby=args.standby,
        transport=args.transport,
    )
    cfg = SPDCGatewayConfig(
        name="spdc-gateway-cli",
        buckets=args.buckets or SPDCGatewayConfig.buckets,
        max_batch=args.max_batch,
        max_wait_us=args.max_wait_us,
        max_pending=args.max_pending,
        spdc=spdc,
    )

    rng = np.random.default_rng(args.seed)
    sizes = rng.choice(args.sizes, size=args.requests)
    mats = [rng.standard_normal((n, n)) + n * np.eye(n) for n in sizes]
    if args.rate > 0:
        arrival_s = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    else:
        arrival_s = np.zeros(args.requests)

    async def drive():
        async with AsyncSPDCGateway(cfg) as gw:
            if args.warmup:
                t0 = time.perf_counter()
                # only the batch shapes this workload can produce
                compiled = await gw.warmup()
                print(f"[warmup] {compiled} bucket programs compiled in "
                      f"{time.perf_counter() - t0:.1f}s")
            results, rejected, wall = await run_workload(gw, mats, arrival_s)
            return results, rejected, wall, gw.stats.as_dict()

    results, rejected, wall, stats = asyncio.run(drive())
    served = [r for r in results if r is not None]
    if not served:
        print("no requests served")
        return 1
    lats = [r.latency_s for r in served]
    rate_txt = f"{args.rate:.0f} req/s" if args.rate else "saturating"
    print(f"[serve_spdc] N={args.servers} offered={rate_txt} "
          f"requests={args.requests} sizes={tuple(args.sizes)}")
    print(f"  served={len(served)} rejected={rejected} wall={wall:.2f}s "
          f"sustained={len(served) / wall:.1f} dets/sec")
    print(f"  latency p50={percentile_ms(lats, 50):.1f}ms "
          f"p99={percentile_ms(lats, 99):.1f}ms "
          f"max={max(lats) * 1e3:.1f}ms")
    print(f"  flushes={stats['flushes']} (full={stats['flushes_full']} "
          f"timeout={stats['flushes_timeout']} drain={stats['flushes_drain']}) "
          f"recovered={stats['recovered_flushes']} direct={stats['direct']}")

    failed = [r for r in served if not r.verified]
    if failed:
        print(f"  VERIFICATION FAILED for {len(failed)} requests")
        return 1
    if args.check:
        for r, m in zip(results, mats):
            if r is None:
                continue
            ws, wl = np.linalg.slogdet(m)
            assert r.det.sign == ws and np.isclose(
                r.det.logabs, wl, rtol=1e-10
            ), f"det mismatch for request {r.rid} (n={r.n})"
        print(f"  check: all {len(served)} dets match numpy slogdet "
              "at rtol 1e-10")
    return 0


if __name__ == "__main__":
    sys.exit(main())
