"""SPDC gateway launcher: drive the async micro-batching determinant
service with a synthetic open-loop client workload.

    PYTHONPATH=src python -m repro.launch.serve_spdc --smoke
    PYTHONPATH=src python -m repro.launch.serve_spdc \
        --servers 4 --requests 256 --rate 200 --sizes 24,48,96 \
        --max-batch 32 --max-wait-us 2000 \
        --tenants 4 --tenant-rate 100 --health-port 9100

Open-loop means arrivals are paced by the offered rate, not by service
completions (`--rate 0` = saturating: all requests arrive at once), so
queueing delay shows up in the reported p50/p99 latency exactly as it
would for independent IoT clients. Each request draws its size from
--sizes; the gateway buckets mixed sizes, coalesces each bucket into one
batched protocol sweep, and answers with a per-request verdict.

Production-hardening surface (DESIGN.md §10): --tenants spreads the swarm
over synthetic tenants, --tenant-rate/--tenant-burst/--tenant-max-pending
turn on per-tenant admission control, --no-breaker/--no-cache disable the
per-bucket circuit breakers and the idempotency result cache, and
--health-port serves GET /healthz and GET /metrics (Prometheus text) from
the live gateway on 127.0.0.1 for the run's duration (port 0 picks a free
port). --smoke self-fetches both endpoints once to prove the surface.

--check verifies every returned determinant against numpy slogdet at
rtol 1e-10 (always on with --smoke, which is the CI docs-job entry).
"""
from __future__ import annotations

import argparse
import asyncio
import sys
import threading
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np


def parse_sizes(spec: str) -> tuple[int, ...]:
    sizes = tuple(int(s) for s in spec.split(",") if s)
    if not sizes or any(s < 2 for s in sizes):
        raise argparse.ArgumentTypeError(f"bad --sizes {spec!r}")
    return sizes


def percentile_ms(lat_s: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(lat_s), q) * 1e3)


def parse_ops(spec: str) -> tuple[str, ...]:
    ops = tuple(s for s in spec.split(",") if s)
    bad = set(ops) - {"det", "slogdet", "solve"}
    if not ops or bad:
        raise argparse.ArgumentTypeError(f"bad --ops {spec!r}")
    return ops


async def run_workload(gw, mats, arrival_s, tenants=None, ops=None,
                       rhss=None):
    """Submit each matrix at its open-loop arrival time; gather results.

    Returns (results, rejected_by_kind, wall_s). Shed requests leave None
    in their results slot and count under their typed rejection kind.
    `ops`/`rhss` carry each request's secure-linalg op and (for solve)
    its right-hand side; None means all-determinant.
    """
    t0 = time.perf_counter()
    results = [None] * len(mats)
    rejected = {"overload": 0, "admission": 0, "breaker": 0}

    async def one(i):
        delay = arrival_s[i] - (time.perf_counter() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        from repro.serve import (
            AdmissionRejected,
            BreakerOpen,
            GatewayOverloaded,
        )

        kwargs = {"tenant": tenants[i]} if tenants is not None else {}
        if ops is not None:
            kwargs["op"] = ops[i]
            if ops[i] == "solve":
                kwargs["rhs"] = rhss[i]
        try:
            results[i] = await gw.submit(mats[i], **kwargs)
        except GatewayOverloaded:
            rejected["overload"] += 1
        except AdmissionRejected:
            rejected["admission"] += 1
        except BreakerOpen:
            rejected["breaker"] += 1

    await asyncio.gather(*(one(i) for i in range(len(mats))))
    wall = time.perf_counter() - t0
    return results, rejected, wall


def start_health_server(gw, port: int):
    """Serve GET /healthz and GET /metrics from the live gateway.

    Returns the ThreadingHTTPServer (bound to 127.0.0.1; ``port`` 0 picks
    a free one — read it back from ``server_address[1]``). The caller
    shuts it down.
    """
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path == "/healthz":
                verdict = gw.healthz()
                body = "".join(f"{k}: {v}\n" for k, v in verdict.items())
                code = 503 if verdict["status"] == "overloaded" else 200
            elif self.path == "/metrics":
                body, code = gw.render_metrics(), 200
            else:
                body, code = "not found\n", 404
            data = body.encode()
            self.send_response(code)
            self.send_header("Content-Type", "text/plain; version=0.0.4")
            self.send_header("Content-Length", str(len(data)))
            self.end_headers()
            self.wfile.write(data)

        def log_message(self, *args):  # keep the workload output clean
            pass

    srv = ThreadingHTTPServer(("127.0.0.1", port), Handler)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    return srv


def _self_check_health(port: int) -> None:
    """Fetch both endpoints once (the --smoke proof that the surface
    actually serves, not merely that the thread started)."""
    from urllib.request import urlopen

    with urlopen(f"http://127.0.0.1:{port}/healthz", timeout=10) as r:
        health = r.read().decode()
        assert health.startswith("status: "), health
    with urlopen(f"http://127.0.0.1:{port}/metrics", timeout=10) as r:
        metrics = r.read().decode()
        assert "spdc_gateway_served_total" in metrics, metrics[:200]
    print(f"  health: GET /healthz -> {health.splitlines()[0]!r}, "
          f"GET /metrics -> {len(metrics.splitlines())} series lines")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="SPDC micro-batching gateway + synthetic client swarm"
    )
    ap.add_argument("--servers", type=int, default=2,
                    help="edge servers per sweep (N)")
    ap.add_argument("--requests", type=int, default=128,
                    help="total client requests to offer")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="offered load, requests/sec (0 = saturating)")
    ap.add_argument("--sizes", type=parse_sizes, default=(24, 48, 96),
                    help="comma-separated raw matrix sizes clients draw from")
    ap.add_argument("--ops", type=parse_ops, default=("det",),
                    help="secure-linalg ops clients draw from (comma-"
                         "separated subset of det,slogdet,solve — "
                         "DESIGN.md §12); solve requests carry a random "
                         "right-hand side")
    ap.add_argument("--buckets", type=parse_sizes, default=None,
                    help="bucket sizes (default: preset buckets)")
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-wait-us", type=float, default=2000.0)
    ap.add_argument("--max-pending", type=int, default=4096)
    ap.add_argument("--method", choices=["q1", "q2", "q3"], default="q3")
    ap.add_argument("--mode", choices=["ewd", "ewm"], default="ewd")
    ap.add_argument("--transport",
                    choices=["inline", "threadpool", "multiprocess",
                             "socket"],
                    default="inline",
                    help="execution boundary for bucket sweeps (DESIGN.md "
                         "§7/§9): inline = fused fast path; threadpool = "
                         "in-process edge workers; multiprocess = spawned "
                         "worker processes, wire-codec messages; socket = "
                         "warm worker daemons over TCP/UDS (self-hosted "
                         "local UDS fleet when no addresses are given)")
    ap.add_argument("--recover", action="store_true",
                    help="heal rejected verdicts in place (DESIGN.md §4)")
    ap.add_argument("--standby", type=int, default=0)
    ap.add_argument("--tenants", type=int, default=1,
                    help="spread the client swarm over this many tenants")
    ap.add_argument("--tenant-rate", type=float, default=None,
                    help="per-tenant admission rate, tokens/sec "
                         "(DESIGN.md §10.1; unset = no rate limit)")
    ap.add_argument("--tenant-burst", type=float, default=None,
                    help="per-tenant token-bucket burst (default: rate)")
    ap.add_argument("--tenant-max-pending", type=int, default=None,
                    help="per-tenant pending-request quota")
    ap.add_argument("--no-breaker", action="store_true",
                    help="disable per-bucket circuit breakers")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the idempotency result cache")
    ap.add_argument("--health-port", type=int, default=None,
                    help="serve GET /healthz + /metrics on 127.0.0.1:PORT "
                         "for the run (0 = pick a free port)")
    ap.add_argument("--no-warmup", dest="warmup", action="store_false",
                    help="skip pre-compiling bucket sweeps")
    ap.add_argument("--check", action="store_true",
                    help="verify every det against numpy slogdet")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + full checking (CI entry)")
    args = ap.parse_args(argv)

    from repro.configs import (
        ADMISSION_OFF,
        BREAKER_DEFAULT,
        BREAKER_OFF,
        CACHE_DEFAULT,
        CACHE_OFF,
        AdmissionConfig,
        SPDCConfig,
        SPDCGatewayConfig,
    )
    from repro.serve import AsyncSPDCGateway

    if args.smoke:
        args.requests = min(args.requests, 24)
        args.sizes = (6, 10, 16)
        args.buckets = args.buckets or (16, 32)
        args.max_batch = min(args.max_batch, 8)
        args.check = True
        if args.ops == ("det",):
            # the CI smoke proves the whole secure-linalg family
            args.ops = ("det", "slogdet", "solve")
        if args.health_port is None:
            args.health_port = 0  # prove the health surface in CI

    if (args.tenant_rate is not None or args.tenant_burst is not None
            or args.tenant_max_pending is not None):
        admission = AdmissionConfig(
            rate_per_sec=args.tenant_rate,
            burst=args.tenant_burst,
            max_pending_per_tenant=args.tenant_max_pending,
        )
    else:
        admission = ADMISSION_OFF

    spdc = SPDCConfig(
        num_servers=args.servers, mode=args.mode, method=args.method,
        recover=args.recover, standby=args.standby,
        transport=args.transport,
    )
    cfg = SPDCGatewayConfig(
        name="spdc-gateway-cli",
        buckets=args.buckets or SPDCGatewayConfig.buckets,
        max_batch=args.max_batch,
        max_wait_us=args.max_wait_us,
        max_pending=args.max_pending,
        spdc=spdc,
        admission=admission,
        breaker=BREAKER_OFF if args.no_breaker else BREAKER_DEFAULT,
        cache=CACHE_OFF if args.no_cache else CACHE_DEFAULT,
    )

    rng = np.random.default_rng(args.seed)
    sizes = rng.choice(args.sizes, size=args.requests)
    mats = [rng.standard_normal((n, n)) + n * np.eye(n) for n in sizes]
    ops = (
        [str(o) for o in rng.choice(args.ops, size=args.requests)]
        if tuple(args.ops) != ("det",) else None
    )
    rhss = (
        [rng.standard_normal(int(n)) if ops[i] == "solve" else None
         for i, n in enumerate(sizes)]
        if ops is not None else None
    )
    tenants = (
        [f"tenant{i % args.tenants}" for i in range(args.requests)]
        if args.tenants > 1 else None
    )
    if args.rate > 0:
        arrival_s = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    else:
        arrival_s = np.zeros(args.requests)

    async def drive():
        async with AsyncSPDCGateway(cfg) as gw:
            health_srv = None
            if args.health_port is not None:
                health_srv = start_health_server(gw, args.health_port)
                port = health_srv.server_address[1]
                print(f"[health] serving /healthz + /metrics on "
                      f"127.0.0.1:{port}")
            if args.warmup:
                t0 = time.perf_counter()
                # only the batch shapes this workload can produce
                compiled = await gw.warmup()
                print(f"[warmup] {compiled} bucket programs compiled in "
                      f"{time.perf_counter() - t0:.1f}s")
            results, rejected, wall = await run_workload(
                gw, mats, arrival_s, tenants, ops, rhss
            )
            health_checked = False
            if health_srv is not None:
                await asyncio.to_thread(
                    _self_check_health, health_srv.server_address[1]
                )
                health_checked = True
                health_srv.shutdown()
            return (results, rejected, wall, gw.stats.as_dict(),
                    gw.healthz(), health_checked)

    results, rejected, wall, stats, health, health_checked = (
        asyncio.run(drive())
    )
    served = [r for r in results if r is not None]
    n_rejected = sum(rejected.values())
    if not served:
        print("no requests served")
        return 1
    lats = [r.latency_s for r in served]
    rate_txt = f"{args.rate:.0f} req/s" if args.rate else "saturating"
    print(f"[serve_spdc] N={args.servers} offered={rate_txt} "
          f"requests={args.requests} sizes={tuple(args.sizes)}"
          + (f" ops={tuple(args.ops)}" if ops is not None else "")
          + (f" tenants={args.tenants}" if args.tenants > 1 else ""))
    if ops is not None:
        mix = {o: sum(1 for r in served if r.op == o) for o in args.ops}
        print("  op mix served: "
              + " ".join(f"{o}={c}" for o, c in mix.items()))
    print(f"  served={len(served)} rejected={n_rejected} "
          f"(overload={rejected['overload']} "
          f"admission={rejected['admission']} "
          f"breaker={rejected['breaker']}) wall={wall:.2f}s "
          f"sustained={len(served) / wall:.1f} dets/sec")
    print(f"  latency p50={percentile_ms(lats, 50):.1f}ms "
          f"p99={percentile_ms(lats, 99):.1f}ms "
          f"max={max(lats) * 1e3:.1f}ms")
    print(f"  flushes={stats['flushes']} (full={stats['flushes_full']} "
          f"timeout={stats['flushes_timeout']} drain={stats['flushes_drain']}) "
          f"recovered={stats['recovered_flushes']} direct={stats['direct']}")
    print(f"  cache hits={stats['cache_hits']} "
          f"coalesced={stats['coalesced']} "
          f"breaker opens={stats['breaker_opens']} "
          f"health={health['status']}")

    failed = [r for r in served if not r.verified]
    if failed:
        print(f"  VERIFICATION FAILED for {len(failed)} requests")
        return 1
    if args.smoke and args.health_port is not None and not health_checked:
        print("  health surface was not exercised")
        return 1
    if args.check:
        for i, (r, m) in enumerate(zip(results, mats, strict=True)):
            if r is None:
                continue
            if r.op == "solve":
                want = np.linalg.solve(m, rhss[i])
                err = (np.linalg.norm(np.asarray(r.solution) - want)
                       / np.linalg.norm(want))
                assert err < 1e-8, \
                    f"solve mismatch for request {r.rid} (n={r.n}): {err:.2e}"
                continue
            ws, wl = np.linalg.slogdet(m)
            if r.op == "slogdet":
                got_s, got_l = r.sign, r.logabs
            else:
                got_s, got_l = r.det.sign, r.det.logabs
            assert got_s == ws and np.isclose(got_l, wl, rtol=1e-10), \
                f"{r.op} mismatch for request {r.rid} (n={r.n})"
        print(f"  check: all {len(served)} answers match numpy at "
              "op-appropriate tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
