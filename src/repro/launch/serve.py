"""LM-serving launcher (seed model-zoo stack): batched greedy generation
against the decode cache.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
        --batch 4 --prompt-len 16 --gen 32

NOTE: this serves the seed's *language models*, not the paper's workload.
The SPDC determinant service — the async micro-batching gateway over
untrusted edge servers — is `python -m repro.launch.serve_spdc --help`
(repro.serve.spdc_gateway, DESIGN.md §5).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models.common import split_tree
from repro.models.lm import init_lm
from repro.serve.steps import greedy_generate
from repro.train.data import SyntheticLM


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.causal:
        print(f"{cfg.name} is encoder-only: no decode step (DESIGN.md §4)")
        return 0
    params, _ = split_tree(init_lm(cfg, jax.random.key(args.seed)))
    data = SyntheticLM(cfg, seed=args.seed)
    prompt = data.batch(0, args.batch, args.prompt_len)
    if cfg.frontend is not None:
        print(f"{cfg.name}: frontend stub serves text decode after a stub "
              "prefill; using token path via labels")
        prompt_toks = prompt["labels"]
    else:
        prompt_toks = prompt["tokens"]

    t0 = time.time()
    out = greedy_generate(cfg, params, prompt_toks, steps=args.gen)
    dt = time.time() - t0
    print(f"[serve] {cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"generated={args.gen} in {dt:.1f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("[serve] sample token ids:", np.asarray(out[0, :24]).tolist())
    assert out.shape == (args.batch, args.prompt_len + args.gen)
    assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < cfg.vocab_size))
    return 0


if __name__ == "__main__":
    sys.exit(main())
