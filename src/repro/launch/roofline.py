"""Roofline analysis from compiled artifacts (no hardware required).

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_flops_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = Σ per-class effective bytes / (ICI_LINKS_USED · LINK_BW)

HLO flops/bytes come from compiled.cost_analysis() (the partitioned
per-device module). Collective bytes are parsed from the post-SPMD HLO text:
we take each collective op's result-shape bytes and apply a wire-traffic
multiplier (ring all-reduce moves ≈ 2× the buffer; all-gather's result
already counts the gathered size; reduce-scatter moves ≈ its input ≈
result × group). collective-permute is 1× (neighbor hop).

MODEL_FLOPS = 6·N·tokens for training (2 fwd + 4 bwd), 2·N·tokens for
inference, N = active params. The "useful-compute ratio" MODEL_FLOPS /
(HLO_flops·chips) exposes remat/redundancy waste; the roofline fraction
ideal_compute_time / max(term) is the score §Perf reports.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field

import numpy as np

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e)
HBM_BW = 819e9  # B/s per chip
LINK_BW = 50e9  # B/s per ICI link
ICI_LINKS_USED = 2  # effective links for ring collectives on a 2D torus

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"(\w[\w\d.\-]*)\s*=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)

_WIRE_MULT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,  # applied to result bytes × group ≈ input bytes
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)
    result_bytes: dict = field(default_factory=dict)
    wire_bytes: dict = field(default_factory=dict)

    @property
    def total_wire_bytes(self) -> float:
        return float(sum(self.wire_bytes.values()))


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum collective op bytes from post-SPMD HLO. Ignores -done ops (the
    -start carries the shape) and duplicate tuple elements conservatively."""
    stats = CollectiveStats()
    for m in _COLL_RE.finditer(hlo_text):
        _, dtype, dims, op = m.groups()
        if dtype not in _DTYPE_BYTES:
            continue
        size = 1
        if dims:
            for d in dims.split(","):
                size *= int(d)
        nbytes = size * _DTYPE_BYTES[dtype]
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.result_bytes[op] = stats.result_bytes.get(op, 0) + nbytes
        stats.wire_bytes[op] = (
            stats.wire_bytes.get(op, 0) + nbytes * _WIRE_MULT[op]
        )
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    ideal_s: float
    roofline_fraction: float
    collectives: dict
    memory_stats: dict

    def to_dict(self):
        return asdict(self)


def analyze(
    *, arch: str, shape: str, mesh_name: str, chips: int,
    cost: dict, hlo_text: str, memory_stats: dict,
    active_params: float, tokens: float, training: bool,
    hlo_cost=None,
) -> Roofline:
    """hlo_cost: a launch.hlo_cost.Cost with trip-count-corrected numbers
    (preferred); `cost` keeps XLA's raw cost_analysis for cross-reference."""
    if hlo_cost is not None:
        flops = float(hlo_cost.flops)
        nbytes = float(hlo_cost.hbm_bytes)
        coll_wire = float(hlo_cost.total_coll_wire)
        coll_detail = {
            "counts": hlo_cost.coll_counts,
            "result_bytes": hlo_cost.coll_bytes,
            "wire_bytes": hlo_cost.coll_wire,
        }
    else:
        flops = float(cost.get("flops", 0.0))
        nbytes = float(cost.get("bytes accessed", 0.0))
        c = parse_collectives(hlo_text)
        coll_wire = c.total_wire_bytes
        coll_detail = {"counts": c.counts, "result_bytes": c.result_bytes,
                       "wire_bytes": c.wire_bytes}
    compute_s = flops / PEAK_FLOPS
    memory_s = nbytes / HBM_BW
    collective_s = coll_wire / (ICI_LINKS_USED * LINK_BW)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mult = 6.0 if training else 2.0
    model_flops = mult * active_params * tokens
    hlo_total = flops * chips
    useful = model_flops / hlo_total if hlo_total else 0.0
    ideal_s = model_flops / (chips * PEAK_FLOPS)
    bound_s = max(terms.values())
    fraction = ideal_s / bound_s if bound_s > 0 else 0.0
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=nbytes,
        collective_wire_bytes=coll_wire,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops, useful_ratio=useful,
        ideal_s=ideal_s, roofline_fraction=fraction,
        collectives=coll_detail,
        memory_stats=memory_stats,
    )
