"""Trip-count-aware cost analysis of post-SPMD compiled HLO.

Why this exists: XLA's HloCostAnalysis (what compiled.cost_analysis()
returns) visits every while-loop body exactly ONCE — under scan-over-layers
(the only way 96-layer × 512-device programs compile tractably) that
undercounts flops/bytes/collectives by the trip count (≈ layers ×
grad-accum × CE-chunks). Verified empirically in EXPERIMENTS.md §Dry-run.

This module re-derives the three roofline inputs directly from
compiled.as_text():

  flops       — 2·|result|·K per dot (K = contracted extent read from the
                lhs operand's shape via the per-computation symbol table),
                plus 1 flop/element for elementwise/reduce/fusion results
  hbm_bytes   — Σ result bytes of compute ops (writes) + Σ operand bytes of
                materialization boundaries (fusion/dot/collective/gather/
                scatter/slice ops = reads). Producer-write + consumer-read
                double-count is intentional: that IS the HBM traffic.
  collectives — result bytes × wire multiplier per class (ring all-reduce
                2×, others 1×)

each multiplied by the product of enclosing while trip counts (parsed from
the loop-condition region's `constant(N)` bound — all loops in this
codebase are counted lax.scan/fori loops). `conditional` branches
contribute their max-cost branch.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from functools import lru_cache

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
# result type matched lazily up to the first `opcode(` word — tuple types
# contain parens/braces that defeat a direct grammar
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

STRUCTURAL = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
# Ops that materialize HBM values on a TPU-class compiler. Standalone
# elementwise/convert/broadcast/select/compare ops are treated as fused
# into their consumers (XLA:TPU does this; XLA:CPU leaves more of them
# unfused, which would otherwise inflate the memory term 3-5x).
MATERIALIZING = {
    "fusion", "dot", "convolution", "gather", "scatter",
    "dynamic-update-slice", "dynamic-slice", "copy", "concatenate",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "slice", "pad", "transpose",
    "reduce", "select-and-scatter", "sort", "rng-bit-generator",
    "custom-call",
}
# in-place update ops: traffic = 2 x slice bytes, never the full buffer
INPLACE_SLICE = {"dynamic-update-slice", "dynamic-slice"}
COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
}
WIRE_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
             "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    rest: str  # args + attributes


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    symtab: dict = field(default_factory=dict)  # name -> result_type


def parse_module(text: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and "=" not in line.split("(")[0]:
            cur = Computation(m.group(2))
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if im:
            name, rtype, opcode, rest = im.groups()
            cur.instrs.append(Instr(name, rtype, opcode, rest))
            cur.symtab[name] = rtype
    return comps, entry or "main"


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_counts: dict = field(default_factory=dict)
    coll_bytes: dict = field(default_factory=dict)
    coll_wire: dict = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for d_self, d_o in ((self.coll_counts, other.coll_counts),
                            (self.coll_bytes, other.coll_bytes),
                            (self.coll_wire, other.coll_wire)):
            for k, v in d_o.items():
                d_self[k] = d_self.get(k, 0) + v * mult

    @property
    def total_coll_wire(self) -> float:
        return float(sum(self.coll_wire.values()))


def _trip_count(cond: Computation) -> int:
    consts = []
    for ins in cond.instrs:
        consts += [int(v) for v in _CONST_RE.findall(
            f"%{ins.name} = {ins.result_type} {ins.opcode}({ins.rest}"
        )]
    return max(consts) if consts else 1


def _called(rest: str, key: str) -> list[str]:
    out = []
    m = re.search(key + r"=\{?([^,}\s]+(?:,\s*[^,}\s]+)*)\}?", rest)
    if m:
        for tok in m.group(1).split(","):
            tok = tok.strip().lstrip("%")
            if tok:
                out.append(tok)
    return out


def _slice_traffic(ins: Instr, comp: Computation) -> float:
    """dynamic-slice: result bytes; dynamic-update-slice: update bytes
    (operand 1). The backing buffer is updated in place — only the slice
    moves."""
    base = ins.opcode.replace("-start", "")
    if base == "dynamic-slice":
        return float(_shape_bytes(ins.result_type))
    ops_ = _OPERAND_RE.findall(ins.rest.split(")")[0])
    if len(ops_) >= 2:
        return float(_shape_bytes(comp.symtab.get(ops_[1], "")))
    return float(_shape_bytes(ins.result_type))


def _fusion_traffic(ins: Instr, comp: Computation, comps: dict) -> float:
    """Fusion traffic = result + operand bytes, unless the fusion root is an
    in-place slice update (then 2 x slice bytes — the whole point of
    donated scan carries)."""
    callees = _called(ins.rest, "calls")
    if callees and callees[0] in comps:
        fused = comps[callees[0]]
        if fused.instrs:
            root = fused.instrs[-1]
            rbase = root.opcode.replace("-start", "")
            if rbase in INPLACE_SLICE:
                return 2.0 * _slice_traffic(root, fused)
    total = float(_shape_bytes(ins.result_type))
    for operand in _OPERAND_RE.findall(ins.rest.split(")")[0]):
        total += _shape_bytes(comp.symtab.get(operand, ""))
    return total


def analyze_hlo(text: str) -> Cost:
    comps, entry = parse_module(text)

    import functools

    @functools.lru_cache(maxsize=None)
    def cost_of(cname: str) -> Cost:
        comp = comps.get(cname)
        c = Cost()
        if comp is None:
            return c
        for ins in comp.instrs:
            op = ins.opcode
            base = op.replace("-start", "")
            if op == "while":
                bodies = _called(ins.rest, "body")
                conds = _called(ins.rest, "condition")
                trips = _trip_count(comps[conds[0]]) if conds and conds[0] in comps else 1
                if bodies and bodies[0] in comps:
                    c.add(cost_of(bodies[0]), trips)
                if conds and conds[0] in comps:
                    c.add(cost_of(conds[0]), trips)
                continue
            if op == "conditional":
                branches = _called(ins.rest, "branch_computations") or (
                    _called(ins.rest, "true_computation")
                    + _called(ins.rest, "false_computation")
                )
                subs = [cost_of(b) for b in branches if b in comps]
                if subs:
                    best = max(subs, key=lambda s: (s.flops, s.hbm_bytes))
                    c.add(best)
                continue
            callees = _called(ins.rest, "calls") + _called(ins.rest, "to_apply")
            for callee in callees:
                if callee in comps:
                    c.add(cost_of(callee))
            if op in STRUCTURAL:
                continue
            rbytes = _shape_bytes(ins.result_type)
            # flops
            if op == "dot":
                k = 1
                cd = _LHS_CDIMS_RE.search(ins.rest)
                ops_ = _OPERAND_RE.findall(ins.rest.split(")")[0])
                if cd and ops_:
                    lhs_t = comp.symtab.get(ops_[0], "")
                    sm = _SHAPE_RE.search(lhs_t)
                    if sm and sm.group(2):
                        dims = [int(d) for d in sm.group(2).split(",")]
                        for ci in cd.group(1).split(","):
                            if ci != "" and int(ci) < len(dims):
                                k *= dims[int(ci)]
                c.flops += 2.0 * _shape_elems(ins.result_type) * k
            elif op not in ("fusion",):
                c.flops += float(_shape_elems(ins.result_type))
            # hbm traffic — only at materialization boundaries
            if base in INPLACE_SLICE:
                c.hbm_bytes += 2.0 * _slice_traffic(ins, comp)
            elif base == "fusion":
                c.hbm_bytes += _fusion_traffic(ins, comp, comps)
            elif base in MATERIALIZING:
                c.hbm_bytes += rbytes
                arglist = ins.rest.split(")")[0]
                for operand in _OPERAND_RE.findall(arglist):
                    c.hbm_bytes += _shape_bytes(comp.symtab.get(operand, ""))
            # collectives
            if base in COLLECTIVES:
                c.coll_counts[base] = c.coll_counts.get(base, 0) + 1
                c.coll_bytes[base] = c.coll_bytes.get(base, 0) + rbytes
                c.coll_wire[base] = (
                    c.coll_wire.get(base, 0) + rbytes * WIRE_MULT[base]
                )
        return c

    return cost_of(entry)
