"""Production meshes.

Single pod: 16×16 = 256 chips (v5e pod), axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — the pod axis is
pure data parallelism so only gradient all-reduces cross the (slower) DCN
boundary; growing the fleet means growing `pod`.

Defined as functions (never module-level constants) so importing this file
touches no JAX device state — the dry-run must set XLA_FLAGS before the
first device query.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devs)} — the "
            "dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import"
        )
    return make_mesh(shape, axes, devices=devs[:need])


def make_smoke_mesh(shape=(2, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh over however many (fake) devices the test process has."""
    need = 1
    for s in shape:
        need *= s
    return make_mesh(shape, axes, devices=jax.devices()[:need])
