"""SPDC edge-worker daemon launcher: one warm worker process a fleet of
clients can reach over TCP or a Unix-domain socket (DESIGN.md §9).

    # serve ANY worker id on an ephemeral TCP port (printed on start)
    PYTHONPATH=src python -m repro.launch.serve_worker --bind tcp://127.0.0.1:0

    # one daemon per worker identity, the paper's fleet shape
    PYTHONPATH=src python -m repro.launch.serve_worker \
        --bind unix:///tmp/spdc-w0.sock --workers 0

    # client side
    from repro.api import SPDCClient, TransportConfig
    client = SPDCClient(transport=TransportConfig(
        "socket", addresses=("tcp://127.0.0.1:45123",)))

The daemon holds this process's EdgeServers — and therefore its jit
caches — warm across every connection, session, and client restart: the
first sweep of a given shape pays the trace, every later one (from any
client) reuses it. Worker ids map onto daemons client-side as
``addresses[i % len(addresses)]``, so one daemon serving "any id" can
stand in for a whole fleet, and recovery's replacement ids wrap onto
the same endpoints.

--smoke starts a UDS daemon, runs one small verified determinant through
it over a real SocketTransport, and exits — the runnable quickstart CI
executes.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile


def parse_workers(spec: str | None):
    if spec is None or spec == "":
        return None
    try:
        return tuple(int(s) for s in spec.split(",") if s != "")
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--workers wants comma-separated ints, got {spec!r}"
        ) from None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="warm SPDC edge-worker daemon (TCP or Unix socket)"
    )
    ap.add_argument("--bind", default="tcp://127.0.0.1:0",
                    help="tcp://host:port (port 0 = ephemeral, printed) "
                         "or unix:///path.sock")
    ap.add_argument("--workers", type=parse_workers, default=None,
                    help="comma-separated worker ids this daemon serves "
                         "(default: any id)")
    ap.add_argument("--no-x64", dest="x64", action="store_false",
                    help="serve the float32 protocol shape "
                         "(jax_enable_x64 off)")
    ap.add_argument("--smoke", action="store_true",
                    help="self-test: UDS daemon + one verified "
                         "determinant over SocketTransport, then exit")
    args = ap.parse_args(argv)

    import jax

    jax.config.update("jax_enable_x64", bool(args.x64))

    from repro.api.socket_transport import WorkerDaemon

    if args.smoke:
        return smoke()

    daemon = WorkerDaemon(args.bind, workers=args.workers)
    addr = daemon.start()
    served = "any" if args.workers is None else ",".join(
        str(w) for w in args.workers
    )
    print(f"[serve_worker] listening on {addr} workers={served} "
          f"x64={'on' if args.x64 else 'off'}", flush=True)
    try:
        daemon.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        daemon.close()
    return 0


def smoke() -> int:
    """Daemon + client in one process: the quickstart, executably."""
    import numpy as np

    from repro.api import SPDCClient, TransportConfig
    from repro.api.socket_transport import WorkerDaemon

    path = os.path.join(tempfile.mkdtemp(prefix="spdc-smoke-"), "w.sock")
    with WorkerDaemon(f"unix://{path}") as daemon:
        cfg = TransportConfig("socket", addresses=(daemon.address,))
        rng = np.random.default_rng(7)
        x = rng.standard_normal((48, 48)) + 48 * np.eye(48)
        with SPDCClient(transport=cfg) as client:
            sess = client.open_session(x, num_servers=2)
            res = sess.run(client.transport)
            hello = client.transport.hello(0)
        ws, wl = np.linalg.slogdet(x)
        ok = (res.verified and res.det.sign == ws
              and np.isclose(res.det.logabs, wl, rtol=1e-10))
        print(f"[serve_worker --smoke] addr={daemon.address} "
              f"verified={res.verified} "
              f"det matches slogdet={ok} "
              f"daemon connections={hello['connections'] if hello else '?'}")
        return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
