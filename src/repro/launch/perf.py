import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# §Perf hillclimb driver: lower one cell with config overrides, report the
# three roofline terms + deltas vs the recorded baseline. (Same first-lines
# rule as dryrun.py.)
#
#   PYTHONPATH=src python -m repro.launch.perf --arch nemotron-4-340b \
#       --shape train_4k --set attn_probs_bf16=true --set grad_accum=8 \
#       --tag nemotron_bf16probs
#
#   PYTHONPATH=src python -m repro.launch.perf --spdc --exact-relay --tag spdc_exact

import argparse
import json
import sys
import time
from dataclasses import replace
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "perf_results"
BASE = Path(__file__).resolve().parents[3] / "benchmarks" / "dryrun_results"


def _coerce(v: str):
    if v.lower() in ("true", "false"):
        return v.lower() == "true"
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def run_variant(arch, shape_name, mesh_name, overrides, tag):
    import repro.launch.dryrun as dr
    from repro.configs import get_config
    from repro.launch.hlo_cost import analyze_hlo
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import analyze

    # monkeypatch the config the dryrun cell will resolve
    base_cfg = get_config(arch)
    cfg = replace(base_cfg, **overrides)
    orig = dr.get_config
    dr.get_config = lambda name: cfg if name == arch else orig(name)
    try:
        rec = dr.run_cell(arch, shape_name, mesh_name, RESULTS / tag)
    finally:
        dr.get_config = orig

    base_file = BASE / f"{arch}__{shape_name}__{mesh_name}.json"
    if base_file.exists():
        base = json.loads(base_file.read_text())
        print(f"[perf:{tag}] vs baseline:")
        for k in ("compute_s", "memory_s", "collective_s", "roofline_fraction"):
            b, v = base[k], rec[k]
            delta = (v - b) / b * 100 if b else float("nan")
            print(f"   {k:20s} {b:12.4f} -> {v:12.4f}  ({delta:+.1f}%)")
    return rec


def run_spdc_variant(mesh_name, relay, n, tag):
    from functools import partial

    from repro.distrib.spdc_pipeline import _PROGRAMS
    from repro.launch.hlo_cost import analyze_hlo
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import analyze

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    N = mesh.shape["model"]
    prog = _PROGRAMS[relay if isinstance(relay, str) else
                     ("exact" if relay else "baseline")]
    from repro.compat import shard_map

    fn = shard_map(
        partial(prog, n=n, b=n // N, num_servers=N, axis="model"),
        mesh=mesh, in_specs=P("model", None),
        out_specs=(P("model", None), P("model", None)),
    )
    x_sds = jax.ShapeDtypeStruct(
        (n, n), jnp.float32, sharding=NamedSharding(mesh, P("model", None))
    )
    t0 = time.time()
    compiled = jax.jit(fn).lower(x_sds).compile()
    hc = analyze_hlo(compiled.as_text())
    rl = analyze(
        arch="spdc-lu", shape=f"n{n}-{relay}",
        mesh_name=mesh_name, chips=mesh.devices.size, cost={},
        hlo_text="", memory_stats={}, active_params=0.0, tokens=1.0,
        training=False, hlo_cost=hc,
    )
    rec = rl.to_dict()
    rec["compile_s"] = time.time() - t0
    RESULTS.mkdir(parents=True, exist_ok=True)
    with open(RESULTS / f"{tag}.json", "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[perf:{tag}] compute={rl.compute_s*1e3:.2f}ms "
          f"memory={rl.memory_s*1e3:.2f}ms "
          f"collective={rl.collective_s*1e3:.2f}ms "
          f"permutes={hc.coll_counts.get('collective-permute', 0)} "
          f"coll_wire={hc.total_coll_wire/1e9:.3f}GB")
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--spdc", action="store_true")
    ap.add_argument("--exact-relay", action="store_true")
    ap.add_argument("--relay", choices=["baseline", "exact", "stream"])
    ap.add_argument("--n", type=int, default=8192)
    args = ap.parse_args()
    if args.spdc:
        relay = args.relay or ("exact" if args.exact_relay else "baseline")
        run_spdc_variant(args.mesh, relay, args.n, args.tag)
    else:
        overrides = {}
        for kv in args.set:
            k, v = kv.split("=", 1)
            overrides[k] = _coerce(v)
        run_variant(args.arch, args.shape, args.mesh, overrides, args.tag)
    return 0


if __name__ == "__main__":
    sys.exit(main())
