"""Training launcher: end-to-end driver wiring configs → mesh/sharding →
sharded params → fault-tolerant loop with checkpointing.

    PYTHONPATH=src python -m repro.launch.train --arch repro-100m \
        --steps 300 --batch 16 --seq 512 --ckpt /tmp/ckpt

Any registry arch (or its -smoke reduction via --smoke) runs; --mesh smoke
shards over this process's fake devices the same way the production mesh
would (same rules code path).
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs import ModelConfig, get_config, smoke_config
from repro.distrib.sharding import make_rules, use_rules
from repro.launch.mesh import make_smoke_mesh
from repro.models.common import split_tree
from repro.models.lm import init_lm
from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticLM
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.steps import build_train_step

# the example ~100M-param config (llama-style), trained by examples/train_lm.py
REPRO_100M = ModelConfig(
    name="repro-100m", family="dense",
    num_layers=10, d_model=640, num_heads=10, num_kv_heads=5, head_dim=64,
    d_ff=1792, vocab_size=32000,
    pattern=(("attn_full", "mlp"),), mlp_type="swiglu",
    activation_dtype="float32", params_dtype="float32",
)


def resolve_config(name: str, smoke: bool) -> ModelConfig:
    if name == "repro-100m":
        cfg = REPRO_100M
    else:
        cfg = smoke_config(name) if smoke else get_config(name)
    return cfg


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="repro-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["none", "smoke"], default="none")
    ap.add_argument("--sdc", action="store_true",
                    help="enable Freivalds SDC verification per step")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = resolve_config(args.arch, args.smoke)
    mesh = None
    if args.mesh == "smoke":
        n = len(jax.devices())
        d = 2 if n >= 4 else 1
        mesh = make_smoke_mesh((d, n // d), ("data", "model"))
    rules = make_rules(mesh, num_heads=cfg.num_heads or None,
                       num_kv_heads=cfg.num_kv_heads or None)

    with use_rules(rules):
        params_px = init_lm(cfg, jax.random.key(args.seed))
        params, specs = split_tree(params_px)
        if mesh is not None:
            params = jax.tree.map(
                lambda v, s: jax.device_put(
                    v, NamedSharding(mesh, rules.resolve(*s))),
                params, specs,
            )
        n_params = sum(x.size for x in jax.tree.leaves(params))
        opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                              total_steps=args.steps,
                              state_dtype=cfg.opt_dtype)
        opt = init_opt_state(params, opt_cfg)
        step_fn = jax.jit(build_train_step(cfg, opt_cfg, sdc_check=args.sdc))
        data = SyntheticLM(cfg, seed=args.seed)
        mgr = CheckpointManager(args.ckpt, keep_last=3)
        print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
              f"batch={args.batch} seq={args.seq} steps={args.steps} "
              f"mesh={'none' if mesh is None else dict(mesh.shape)} "
              f"sdc={args.sdc} resume_from={mgr.latest_step()}")

        t0 = time.time()
        params, opt, report = run_training(
            step_fn, params, opt,
            lambda s: data.batch(s, args.batch, args.seq),
            mgr,
            LoopConfig(total_steps=args.steps,
                       checkpoint_every=args.ckpt_every, log_every=10),
            key=jax.random.key(args.seed + 1),
        )
        dt = time.time() - t0
        first = np.mean(report.losses[:5])
        last = np.mean(report.losses[-5:])
        tput = args.batch * args.seq * report.steps_run / dt
        print(f"[train] done: {report.steps_run} steps in {dt:.1f}s "
              f"({tput:.0f} tok/s) loss {first:.4f} -> {last:.4f} "
              f"restarts={report.restarts} sdc_rejects={report.sdc_rejects} "
              f"stragglers={len(report.straggler_events)}")
        assert last < first, "training did not improve loss"
    return 0


if __name__ == "__main__":
    sys.exit(main())
