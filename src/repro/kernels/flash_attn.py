"""Flash attention kernel — blockwise online-softmax, the memory hot spot of
every attention arch at 32k–500k context.

Materializing S = QKᵀ at 32k is 4 GiB/head (f32); blockwise online softmax
(Rabe & Staats / FlashAttention) keeps the working set at
(bq×d + 2·bk×d + bq×bk) ≈ 300 KiB in VMEM. Grid (batch, q_head, q_blk,
kv_blk), kv innermost so the accumulator + running (m, ℓ) stats stay
resident in VMEM scratch across the contraction. GQA is handled in the
K/V index maps (kv head = q head // group), so K/V tiles are never
replicated in HBM. Causal and sliding-window masks are applied per-tile
with right-aligned query positions (decode: sq < sk works unchanged).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: int | None, sk_total: int, bq: int, bk: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, ...]  # (bq, d)
    k = k_ref[0, 0, ...]  # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)

    # right-aligned absolute positions
    sq_total = pl.num_programs(2) * bq
    qpos = qi * bq + lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (sk_total - sq_total)
    kpos = ki * bk + lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # (bq, bk)
    correction = jnp.exp(m_prev - m_new)  # (bq, 1)
    l_new = correction * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * correction + jnp.dot(
        p.astype(v_ref.dtype), v_ref[0, 0, ...], preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        # fully-masked rows (can happen with windows) -> zero output
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, ...] = (acc_ref[...] / safe).astype(o_ref.dtype)


@partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret", "scale"),
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D) with Hq % Hkv == 0."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    bq = min(bq, sq)
    while sq % bq != 0:
        bq //= 2
    bk = min(bk, sk)
    while sk % bk != 0:
        bk //= 2
    if scale is None:
        scale = 1.0 / (d**0.5)

    # fold batch into a leading grid axis; heads are their own axis so the
    # GQA index map can divide by the group size
    grid = (b, hq, sq // bq, sk // bk)
    kernel = partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, sk_total=sk, bq=bq, bk=bk,
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, h, qi, ki: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, h, qi, ki: (bi, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, h, qi, ki: (bi, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bi, h, qi, ki: (bi, h, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
