"""Schur-complement GEMM kernel: C ← C − A·B, the O(n³) hot spot of
blocked LU (≥ ~90% of Parallelize flops for nb ≥ 4).

Classic three-loop Pallas matmul: grid (i, j, k) with the (i, j) output
tile revisited across the contraction index k (k innermost ⇒ the out tile
stays resident in VMEM; Mosaic keeps the accumulator on-chip between grid
steps). MXU-aligned 128× tiles; accumulation in the output dtype's widened
form (f32 for bf16 inputs) via preferred_element_type.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _schur_kernel(c_ref, a_ref, b_ref, o_ref, *, acc_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = c_ref[...]

    o_ref[...] -= jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=acc_dtype
    ).astype(o_ref.dtype)


def _fit_block(n: int, want: int) -> int:
    b = min(want, n)
    while n % b != 0:
        b //= 2
    return max(b, 1)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def schur_update(
    c: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """C − A @ B with (M,K)@(K,N) tiling."""
    m, kdim = a.shape
    _, n = b.shape
    bm = _fit_block(m, bm)
    bn = _fit_block(n, bn)
    bk = _fit_block(kdim, bk)
    acc_dtype = jnp.float32 if c.dtype in (jnp.bfloat16, jnp.float16) else c.dtype
    return pl.pallas_call(
        partial(_schur_kernel, acc_dtype=acc_dtype),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        grid=(m // bm, n // bn, kdim // bk),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        interpret=interpret,
    )(c, a, b)
