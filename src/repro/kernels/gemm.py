"""Schur-complement GEMM kernel: C ← C − A·B, the O(n³) hot spot of
blocked LU (≥ ~90% of Parallelize flops for nb ≥ 4).

Classic three-loop Pallas matmul: grid (i, j, k) with the (i, j) output
tile revisited across the contraction index k (k innermost ⇒ the out tile
stays resident in VMEM; Mosaic keeps the accumulator on-chip between grid
steps). MXU-aligned 128× tiles; accumulation in the output dtype's widened
form (f32 for bf16 inputs) via preferred_element_type.

Batch (DESIGN.md §3): (B, m, k)·(B, k, n) stacks prepend a batch grid axis
— grid (B, i, j, k), one independent accumulator walk per matrix. The
contraction index stays innermost so the VMEM-residency argument is
unchanged.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _schur_kernel(c_ref, a_ref, b_ref, o_ref, *, acc_dtype):
    # contraction index is the innermost grid axis: 2 for (i,j,k) grids,
    # 3 for batched (b,i,j,k) grids — equal to the block rank
    k = pl.program_id(c_ref.ndim)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = c_ref[...]

    o_ref[...] -= jnp.matmul(
        a_ref[...], b_ref[...], preferred_element_type=acc_dtype
    ).astype(o_ref.dtype)


def _fit_block(n: int, want: int) -> int:
    b = min(want, n)
    while n % b != 0:
        b //= 2
    return max(b, 1)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "acc_dtype"))
def schur_update(
    c: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
    acc_dtype=None,
) -> jnp.ndarray:
    """C − A @ B with (M,K)@(K,N) tiling; batched over a leading stack dim.

    acc_dtype: accumulation dtype override. Default (None) widens bf16/f16
    inputs to f32 and keeps f32/f64 inputs at their own dtype; passing
    jnp.float64 on f32 inputs selects the "mixed" variant (DESIGN.md §6.4)
    — each tile's contraction accumulates wide, the output stores narrow.
    f64 accumulation needs a backend with f64 support (CPU/GPU, or
    interpret mode); TPU Mosaic callers should stay ≤ f32.
    """
    m, kdim = a.shape[-2:]
    n = b.shape[-1]
    bm = _fit_block(m, bm)
    bn = _fit_block(n, bn)
    bk = _fit_block(kdim, bk)
    if acc_dtype is None:
        acc_dtype = (jnp.float32 if c.dtype in (jnp.bfloat16, jnp.float16)
                     else c.dtype)
    batched = c.ndim == 3
    if batched:
        B = c.shape[0]
        grid = (B, m // bm, n // bn, kdim // bk)
        in_specs = [
            pl.BlockSpec((1, bm, bn), lambda p, i, j, k: (p, i, j)),
            pl.BlockSpec((1, bm, bk), lambda p, i, j, k: (p, i, k)),
            pl.BlockSpec((1, bk, bn), lambda p, i, j, k: (p, k, j)),
        ]
        out_specs = pl.BlockSpec((1, bm, bn), lambda p, i, j, k: (p, i, j))
        out_shape = jax.ShapeDtypeStruct((B, m, n), c.dtype)
    else:
        grid = (m // bm, n // bn, kdim // bk)
        in_specs = [
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ]
        out_specs = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
        out_shape = jax.ShapeDtypeStruct((m, n), c.dtype)
    return pl.pallas_call(
        partial(_schur_kernel, acc_dtype=acc_dtype),
        out_shape=out_shape,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        interpret=interpret,
    )(c, a, b)
