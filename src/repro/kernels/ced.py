"""Fused CED cipher kernel — blind + rotate in one HBM pass.

The paper's Cipher (§IV.C) runs EWO and PRT "simultaneously". On TPU that
means: read each input tile HBM→VMEM once, scale rows by the blinding
vector in VMEM (VPU elementwise), and write the tile to its *rotated*
destination — the rotation is carried by the output BlockSpec index map, so
it costs zero extra bandwidth (vs. a naive scale-pass + rotate-pass at 2×
traffic). Arithmetic intensity is 1 flop / 8 bytes (f64) — purely
memory-bound, so halving traffic halves cipher latency.

Tiles are square (b×b, b a multiple of the 128-lane for the TPU target);
the in-tile quarter-turn is a (sublane,lane) transpose + flip, supported by
the Mosaic relayout path on TPU and exact in interpret mode.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ced_kernel(m_ref, v_ref, o_ref, *, k: int, mode: str):
    tile = m_ref[...]
    vcol = v_ref[...]  # (b, 1) slice of the blinding vector for these rows
    scaled = tile / vcol if mode == "ewd" else tile * vcol
    o_ref[...] = jnp.rot90(scaled, k=-(k % 4), axes=(0, 1))


def _out_index_map(k: int, nb: int):
    k = k % 4
    if k == 1:  # block (i,j) -> (j, nb-1-i)
        return lambda i, j: (j, nb - 1 - i)
    if k == 2:  # -> (nb-1-i, nb-1-j)
        return lambda i, j: (nb - 1 - i, nb - 1 - j)
    if k == 3:  # -> (nb-1-j, i)
        return lambda i, j: (nb - 1 - j, i)
    return lambda i, j: (i, j)


@partial(jax.jit, static_argnames=("k", "mode", "block", "interpret"))
def ced(
    m: jnp.ndarray,
    v: jnp.ndarray,
    k: int,
    *,
    mode: str = "ewd",
    block: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused Cipher: rot90_cw^k(EWO(m, v)). n must be divisible by block
    (callers pad via core.augment first when needed)."""
    n = m.shape[0]
    if n % block != 0:
        block = 1
        while block * 2 <= n and n % (block * 2) == 0:
            block *= 2
    nb = n // block
    return pl.pallas_call(
        partial(_ced_kernel, k=k, mode=mode),
        out_shape=jax.ShapeDtypeStruct((n, n), m.dtype),
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, j)),
            pl.BlockSpec((block, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, block), _out_index_map(k, nb)),
        interpret=interpret,
    )(m, v.reshape(-1, 1).astype(m.dtype))
