"""Fused CED cipher kernel — blind + rotate in one HBM pass.

The paper's Cipher (§IV.C) runs EWO and PRT "simultaneously". On TPU that
means: read each input tile HBM→VMEM once, scale rows by the blinding
vector in VMEM (VPU elementwise), and write the tile to its *rotated*
destination — the rotation is carried by the output BlockSpec index map, so
it costs zero extra bandwidth (vs. a naive scale-pass + rotate-pass at 2×
traffic). Arithmetic intensity is 1 flop / 8 bytes (f64) — purely
memory-bound, so halving traffic halves cipher latency.

Tiles are square (b×b, b a multiple of the 128-lane for the TPU target);
the in-tile quarter-turn is a (sublane,lane) transpose + flip, supported by
the Mosaic relayout path on TPU and exact in interpret mode.

Batch (DESIGN.md §3): a (B, n, n) stack adds a leading batch grid axis —
grid (B, nb, nb), each program ciphers one tile of one matrix; the
rotation index map acts on the tile coordinates only, the batch coordinate
passes through. All matrices in one call share the rotation degree k (the
index map is static in k); core.cipher.cipher_batch groups a mixed-k batch
into ≤ 3 launches.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ced_kernel(m_ref, v_ref, o_ref, *, k: int, mode: str,
                growth_safe: bool):
    tile = m_ref[...]
    vcol = v_ref[...]  # (b, 1) slice of the blinding vector for these rows
    scaled = tile / vcol if mode == "ewd" else tile * vcol
    axes = (tile.ndim - 2, tile.ndim - 1)
    if growth_safe and k % 2 == 1:
        # odd rotation ∘ exchange flip = transpose, in-tile and in the
        # index map alike (core.cipher growth-safe relayout)
        o_ref[...] = jnp.swapaxes(scaled, *axes)
    else:
        o_ref[...] = jnp.rot90(scaled, k=-(k % 4), axes=axes)


def _out_index_map(k: int, nb: int, *, batched: bool, growth_safe: bool):
    k = k % 4
    if growth_safe and k % 2 == 1:  # transpose: block (i,j) -> (j,i)
        def rot(i, j):
            return (j, i)
    elif k == 1:  # block (i,j) -> (j, nb-1-i)
        def rot(i, j):
            return (j, nb - 1 - i)
    elif k == 2:  # -> (nb-1-i, nb-1-j)
        def rot(i, j):
            return (nb - 1 - i, nb - 1 - j)
    elif k == 3:  # -> (nb-1-j, i)
        def rot(i, j):
            return (nb - 1 - j, i)
    else:
        def rot(i, j):
            return (i, j)
    if batched:
        return lambda b, i, j: (b, *rot(i, j))
    return rot


@partial(jax.jit,
         static_argnames=("k", "mode", "block", "interpret", "growth_safe"))
def ced(
    m: jnp.ndarray,
    v: jnp.ndarray,
    k: int,
    *,
    mode: str = "ewd",
    block: int = 128,
    interpret: bool = True,
    growth_safe: bool = False,
) -> jnp.ndarray:
    """Fused Cipher: rot90_cw^k(EWO(m, v)) for (n, n) or (B, n, n).

    n must be divisible by block (callers pad via core.augment first when
    needed); otherwise the largest power-of-two divisor is used.
    growth_safe composes odd rotations with the exchange flip (the
    composite is a transpose — still a single fused HBM pass, the index
    map just changes; core.cipher semantics, DESIGN.md §6.1).
    """
    n = m.shape[-1]
    if n % block != 0:
        block = 1
        while block * 2 <= n and n % (block * 2) == 0:
            block *= 2
    nb = n // block
    batched = m.ndim == 3
    if batched:
        B = m.shape[0]
        grid = (B, nb, nb)
        in_specs = [
            pl.BlockSpec((1, block, block), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, block, 1), lambda b, i, j: (b, i, 0)),
        ]
        out_shape = jax.ShapeDtypeStruct((B, n, n), m.dtype)
        vv = v.reshape(B, n, 1).astype(m.dtype)
    else:
        grid = (nb, nb)
        in_specs = [
            pl.BlockSpec((block, block), lambda i, j: (i, j)),
            pl.BlockSpec((block, 1), lambda i, j: (i, 0)),
        ]
        out_shape = jax.ShapeDtypeStruct((n, n), m.dtype)
        vv = v.reshape(n, 1).astype(m.dtype)
    return pl.pallas_call(
        partial(_ced_kernel, k=k, mode=mode, growth_safe=growth_safe),
        out_shape=out_shape,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            in_specs[0].block_shape,
            _out_index_map(k, nb, batched=batched, growth_safe=growth_safe),
        ),
        interpret=interpret,
    )(m, vv)
