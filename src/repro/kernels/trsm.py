"""Block triangular-solve kernels.

trsm_lower:        X = L^{-1} B   (L unit-lower b×b; B b×m, tiled over cols)
trsm_upper_right:  Z = B U^{-1}   (U upper b×b;      B m×b, tiled over rows)

The triangular factor stays resident in VMEM across the grid; each grid
step solves one column (row) tile of B by masked forward (backward)
elimination — the same gather-free masking idiom as lu_panel. Elimination
steps are rank-1 updates (VPU) over a tile; the O(b²·m) work is dominated
by the rank-1 broadcasts, which vectorize over the m-tile lane dimension.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _trsm_lower_kernel(l_ref, b_ref, o_ref, *, acc_dtype=None):
    l = l_ref[...]
    x = b_ref[...]
    if acc_dtype is not None:  # mixed variant: solve wide, store narrow
        l, x = l.astype(acc_dtype), x.astype(acc_dtype)
    squeeze = l.ndim == 3  # batched launch: (1, n, n) / (1, n, cb) blocks
    if squeeze:
        l, x = l[0], x[0]
    b = l.shape[0]
    rows = lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols = lax.broadcasted_iota(jnp.int32, (b, b), 1)

    def body(k, x):
        # row_k of the current solution; eliminate it from rows > k
        xrows = lax.broadcasted_iota(jnp.int32, x.shape, 0)
        row_k = jnp.sum(jnp.where(xrows == k, x, 0.0), axis=0)  # (m,)
        lcol = jnp.sum(jnp.where(cols == k, l, 0.0), axis=1)  # (b,)
        lcol = jnp.where(jnp.arange(b) > k, lcol, 0.0)
        return x - lcol[:, None] * row_k[None, :]

    out = lax.fori_loop(0, b, body, x).astype(o_ref.dtype)
    o_ref[...] = out[None] if squeeze else out


def _trsm_upper_right_kernel(u_ref, b_ref, o_ref, *, acc_dtype=None):
    u = u_ref[...]
    x = b_ref[...]
    if acc_dtype is not None:  # mixed variant: solve wide, store narrow
        u, x = u.astype(acc_dtype), x.astype(acc_dtype)
    squeeze = u.ndim == 3  # batched launch: (1, n, n) / (1, rb, n) blocks
    if squeeze:
        u, x = u[0], x[0]
    b = u.shape[0]
    rows = lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols = lax.broadcasted_iota(jnp.int32, (b, b), 1)

    def body(k, x):
        # scale column k by 1/U_kk, then eliminate from columns > k
        ukk = jnp.sum(jnp.where((rows == k) & (cols == k), u, 0.0))
        col_k = jnp.sum(jnp.where(lax.broadcasted_iota(jnp.int32, x.shape, 1) == k, x, 0.0), axis=1) / ukk
        urow = jnp.sum(jnp.where(rows == k, u, 0.0), axis=0)  # (b,)
        urow = jnp.where(jnp.arange(b) > k, urow, 0.0)
        x = x - col_k[:, None] * urow[None, :]
        # write the scaled column back into position k
        iscol = lax.broadcasted_iota(jnp.int32, x.shape, 1) == k
        return jnp.where(iscol, col_k[:, None], x)

    out = lax.fori_loop(0, b, body, x).astype(o_ref.dtype)
    o_ref[...] = out[None] if squeeze else out


@partial(jax.jit, static_argnames=("col_block", "interpret", "acc_dtype"))
def trsm_lower(
    l: jnp.ndarray, b: jnp.ndarray, *, col_block: int = 256,
    interpret: bool = True, acc_dtype=None,
) -> jnp.ndarray:
    """Solve L X = B for X; grid over column tiles of B. A (B, n, n) /
    (B, n, m) stack adds a leading batch grid axis (DESIGN.md §3).
    acc_dtype selects the mixed variant: the elimination runs in the wider
    dtype in VMEM, the output tile stores at b.dtype (DESIGN.md §6.4)."""
    n, m = b.shape[-2:]
    cb = min(col_block, m)
    while m % cb != 0:
        cb //= 2
    kern = partial(_trsm_lower_kernel, acc_dtype=acc_dtype)
    if b.ndim == 3:
        batch = b.shape[0]
        return pl.pallas_call(
            kern,
            out_shape=jax.ShapeDtypeStruct((batch, n, m), b.dtype),
            grid=(batch, m // cb),
            in_specs=[
                pl.BlockSpec((1, n, n), lambda i, j: (i, 0, 0)),
                pl.BlockSpec((1, n, cb), lambda i, j: (i, 0, j)),
            ],
            out_specs=pl.BlockSpec((1, n, cb), lambda i, j: (i, 0, j)),
            interpret=interpret,
        )(l, b)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((n, m), b.dtype),
        grid=(m // cb,),
        in_specs=[
            pl.BlockSpec((n, n), lambda j: (0, 0)),
            pl.BlockSpec((n, cb), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n, cb), lambda j: (0, j)),
        interpret=interpret,
    )(l, b)


@partial(jax.jit, static_argnames=("row_block", "interpret", "acc_dtype"))
def trsm_upper_right(
    u: jnp.ndarray, b: jnp.ndarray, *, row_block: int = 256,
    interpret: bool = True, acc_dtype=None,
) -> jnp.ndarray:
    """Solve Z U = B for Z; grid over row tiles of B. A (B, n, n) /
    (B, m, n) stack adds a leading batch grid axis (DESIGN.md §3).
    acc_dtype: mixed variant, as trsm_lower."""
    m, n = b.shape[-2:]
    rb = min(row_block, m)
    while m % rb != 0:
        rb //= 2
    kern = partial(_trsm_upper_right_kernel, acc_dtype=acc_dtype)
    if b.ndim == 3:
        batch = b.shape[0]
        return pl.pallas_call(
            kern,
            out_shape=jax.ShapeDtypeStruct((batch, m, n), b.dtype),
            grid=(batch, m // rb),
            in_specs=[
                pl.BlockSpec((1, n, n), lambda i, j: (i, 0, 0)),
                pl.BlockSpec((1, rb, n), lambda i, j: (i, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, rb, n), lambda i, j: (i, j, 0)),
            interpret=interpret,
        )(u, b)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((m, n), b.dtype),
        grid=(m // rb,),
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((rb, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rb, n), lambda i: (i, 0)),
        interpret=interpret,
    )(u, b)
