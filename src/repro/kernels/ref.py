"""Pure-jnp oracles for every Pallas kernel. These define correctness."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ced_ref(m: jnp.ndarray, v: jnp.ndarray, k: int, mode: str = "ewd") -> jnp.ndarray:
    """Fused CED cipher oracle: row-blind by v then rotate k cw quarter-turns."""
    v = v.reshape(-1, 1).astype(m.dtype)
    scaled = m / v if mode == "ewd" else m * v
    return jnp.rot90(scaled, k=-(k % 4), axes=(0, 1))


def lu_panel_ref(a: jnp.ndarray) -> jnp.ndarray:
    """Compact LU (strict-lower L multipliers + upper U in one matrix)."""
    from repro.core.lu import lu_unblocked

    l, u = lu_unblocked(a)
    return jnp.tril(l, -1) + u


def trsm_lower_ref(l: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """X = L^{-1} B with L unit lower triangular."""
    return jax.scipy.linalg.solve_triangular(l, b, lower=True, unit_diagonal=True)


def trsm_upper_right_ref(u: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Z = B U^{-1} with U upper triangular (non-unit diagonal)."""
    return jax.scipy.linalg.solve_triangular(u.T, b.T, lower=True).T


def schur_update_ref(c: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C − A @ B (the Schur-complement GEMM)."""
    return c - a @ b


def flash_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    """Standard softmax attention oracle with GQA head-grouping.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); Hq % Hkv == 0.
    window: sliding-window width (keys within [i-window+1, i]).
    """
    bq, hq, sq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    if scale is None:
        scale = 1.0 / (d**0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * scale
    sk = k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # right-aligned (decode-friendly)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), vv)
