"""Panel LU kernel — unblocked no-pivot factorization of one b×b tile in VMEM.

This is the sequential bottleneck of blocked LU: everything else (TRSM,
Schur GEMM) is MXU-bound, but the panel is a b-step dependent elimination.
Keeping the whole panel resident in VMEM (b ≤ 256 ⇒ ≤ 512 KiB f64) and
expressing each elimination step as masked row/column reductions keeps the
inner loop on the VPU without dynamic gathers (TPU-unfriendly).

Output is the compact form (strict-lower multipliers + U), matching
ref.lu_panel_ref; callers split with tril/triu.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _lu_panel_kernel(x_ref, o_ref, *, acc_dtype=None):
    a = x_ref[...]
    if acc_dtype is not None:  # mixed variant: eliminate wide, store narrow
        a = a.astype(acc_dtype)
    squeeze = a.ndim == 3  # batched launch: one (1, b, b) tile per program
    if squeeze:
        a = a[0]
    b = a.shape[0]
    # 2D iota (TPU requires >= 2D); rows[i,j] = i, cols[i,j] = j
    rows = lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols = lax.broadcasted_iota(jnp.int32, (b, b), 1)

    def body(k, a):
        # pivot = a[k, k]; urow = a[k, :] masked to cols > k;
        # lcol = a[:, k] / pivot masked to rows > k — all as masked sums,
        # no dynamic slicing.
        pivot = jnp.sum(jnp.where((rows == k) & (cols == k), a, 0.0))
        urow = jnp.sum(jnp.where(rows == k, a, 0.0), axis=0)  # (b,)
        acol = jnp.sum(jnp.where(cols == k, a, 0.0), axis=1)  # (b,)
        lcol = jnp.where(jnp.arange(b) > k, acol / pivot, 0.0)
        urow_right = jnp.where(jnp.arange(b) > k, urow, 0.0)
        a = a - lcol[:, None] * urow_right[None, :]
        # store multipliers into column k (rows > k)
        return jnp.where((cols == k) & (rows > k), lcol[:, None], a)

    out = lax.fori_loop(0, b, body, a).astype(o_ref.dtype)
    o_ref[...] = out[None] if squeeze else out


@partial(jax.jit, static_argnames=("interpret", "acc_dtype"))
def lu_panel_compact(x: jnp.ndarray, *, interpret: bool = True,
                     acc_dtype=None) -> jnp.ndarray:
    """Compact LU of one panel, or of a (B, b, b) stack via a batch grid
    axis (one panel per program instance — DESIGN.md §3). acc_dtype
    selects the mixed variant: the b-step elimination runs in the wider
    dtype in VMEM and the compact form stores at x.dtype (DESIGN.md §6.4;
    f64 accumulation needs a f64-capable backend or interpret mode)."""
    b = x.shape[-1]
    kern = partial(_lu_panel_kernel, acc_dtype=acc_dtype)
    if x.ndim == 3:
        B = x.shape[0]
        return pl.pallas_call(
            kern,
            out_shape=jax.ShapeDtypeStruct((B, b, b), x.dtype),
            grid=(B,),
            in_specs=[pl.BlockSpec((1, b, b), lambda i: (i, 0, 0))],
            out_specs=pl.BlockSpec((1, b, b), lambda i: (i, 0, 0)),
            interpret=interpret,
        )(x)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((b, b), x.dtype),
        in_specs=[pl.BlockSpec((b, b), lambda: (0, 0))],
        out_specs=pl.BlockSpec((b, b), lambda: (0, 0)),
        interpret=interpret,
    )(x)
