"""Jit'd public wrappers for every Pallas kernel (the API the rest of the
framework calls). Each has an `interpret` flag: True executes the kernel
body on CPU (this container), False targets the TPU Mosaic pipeline.
"""
from __future__ import annotations

import jax.numpy as jnp

from .ced import ced as _ced
from .flash_attn import flash_attention as _flash
from .gemm import schur_update as _schur
from .lu_panel import lu_panel_compact as _lu_panel_compact
from .trsm import trsm_lower as _trsm_lower
from .trsm import trsm_upper_right as _trsm_upper_right


def ced(m, v, k, *, mode="ewd", block=128, interpret=True,
        growth_safe=False):
    """Fused CED cipher: rot90_cw^k(EWO(m, v)); growth_safe composes odd
    rotations with the exchange flip (DESIGN.md §6.1)."""
    return _ced(m, v, k, mode=mode, block=block, interpret=interpret,
                growth_safe=growth_safe)


def lu_panel(x, *, interpret=True, acc_dtype=None):
    """Panel LU -> (L unit-lower, U upper); batched over a leading dim.
    acc_dtype selects the mixed (wide-accumulate) variant."""
    compact = _lu_panel_compact(x, interpret=interpret, acc_dtype=acc_dtype)
    n = x.shape[-1]
    l = jnp.tril(compact, -1) + jnp.eye(n, dtype=x.dtype)
    u = jnp.triu(compact)
    return l, u


def trsm_lower(l, b, *, interpret=True, acc_dtype=None):
    """X = L^{-1} B (L unit lower)."""
    return _trsm_lower(l, b, interpret=interpret, acc_dtype=acc_dtype)


def trsm_upper_right(u, b, *, interpret=True, acc_dtype=None):
    """Z = B U^{-1} (U upper)."""
    return _trsm_upper_right(u, b, interpret=interpret, acc_dtype=acc_dtype)


def schur_update(c, a, b, *, interpret=True, acc_dtype=None, **tiles):
    """C - A @ B; acc_dtype overrides the accumulation dtype."""
    return _schur(c, a, b, interpret=interpret, acc_dtype=acc_dtype, **tiles)


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    bq=128, bk=128, interpret=True):
    """Blockwise online-softmax attention (GQA-aware)."""
    return _flash(
        q, k, v, causal=causal, window=window, scale=scale,
        bq=bq, bk=bk, interpret=interpret,
    )
