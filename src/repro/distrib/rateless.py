"""Rateless straggler-adaptive dispatch with fleet health (DESIGN.md §8).

The classic session binds strip i to server i and the only straggler
remedy is a deadline: wait d rounds, then drop the server wholesale.
This module replaces the deadline with the rateless shape of Bitar et
al.'s adaptive coded computation: the client over-decomposes the
bordered ciphertext into F = overdecompose × N strips and STREAMS them
to whichever workers are free — completion is "every strip verified",
never "every server answered by round d". A slow server is not a fault
to adjudicate; it simply pulls fewer strips.

Three mechanisms, one loop:

  * Per-strip verification gates the wavefront. Strip s of a lane is
    accepted only after a secret Q1-style probe (max |X_s·r − L_s·(U·r)|
    against the growth-widened ε(N), core.verify conventions) — so a
    tampered strip is caught BEFORE any downstream strip consumes its U
    rows, and re-dispatch costs one strip, not a localize→heal cascade.
    The final `Session.collect()` authenticate (Q2/Q3) remains the
    accept/reject authority; the strip probe is the scheduler's gate.
  * FleetHealth turns observations into assignment. EWMA completion
    latency ranks free workers (unknown workers are assumed fast —
    optimism costs one strip to correct); failures back a worker off
    exponentially with deterministic jitter; repeated failures or a
    single detected tamper quarantine it. Quarantined workers re-admit
    only by passing a probation probe: a re-issue of an already-verified
    strip, dispatched as attempt 0 so a persistent tamperer fails it.
  * The degradation ladder keeps the session answering. A strip that
    exhausts `max_attempts`, or a fleet below `min_live`, falls back to
    the client computing the strip inline (EdgeServer arithmetic, no
    transport) — slower, never wrong, never stuck.

Lanes: a batched session is split into contiguous batch slices
("lanes"), each an independent sequential strip chain — the wavefront
dependency (strip s needs U rows 0..s−1) means a single matrix can only
pipeline one strip at a time, but L lanes keep L workers busy at once.

Security is unchanged by F > N: a ShardTask still carries only a
ciphered block row and a derived sub-seed; cutting the same ciphertext
into thinner strips hands each worker STRICTLY LESS of it, and the PRT
argument never used "one strip per server" (DESIGN.md §8).
"""

from __future__ import annotations

import hashlib
import struct
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FutureTimeout
from concurrent.futures import wait as futures_wait
from dataclasses import dataclass, field

import numpy as np

from repro.api.messages import ShardResult, ShardTask
from repro.api.server import EdgeServer
from repro.api.transport import TransportError, TransportTimeout
from repro.configs.spdc import RATELESS_DEFAULT, RatelessConfig
from repro.core.verify import epsilon
from repro.distrib.recovery import dispatch_subseed

__all__ = ["FleetHealth", "WorkerHealth", "RatelessReport", "run_rateless"]


@dataclass
class WorkerHealth:
    """Everything the client has observed about one physical worker."""

    worker_id: int
    ewma_latency_s: float | None = None  # None = never completed (optimism)
    completed: int = 0  # strips ACCEPTED from this worker
    discarded: int = 0  # late results thrown away (zombie futures)
    failures: int = 0  # transport errors + timeouts, lifetime
    consecutive_failures: int = 0
    tampers: int = 0  # probe-failed strips attributed here
    probes_passed: int = 0
    quarantined: bool = False
    quarantined_at: float = 0.0  # monotonic; probation cooldown anchor
    quarantine_count: int = 0
    next_ok_at: float = 0.0  # backoff gate (monotonic)

    def as_dict(self) -> dict:
        return {
            "worker_id": self.worker_id,
            "ewma_latency_s": self.ewma_latency_s,
            "completed": self.completed,
            "discarded": self.discarded,
            "failures": self.failures,
            "tampers": self.tampers,
            "probes_passed": self.probes_passed,
            "quarantined": self.quarantined,
            "quarantine_count": self.quarantine_count,
        }


class FleetHealth:
    """Per-worker health the rateless scheduler assigns work by.

    Lives on the SPDCClient (not the Session) so what one session learned
    about the fleet — who is slow, who tampers — carries into the next.
    All mutation happens on the scheduler's thread; the tracker is plain
    bookkeeping, no locks, no clocks of its own (callers pass `now` from
    time.monotonic() so tests can drive it virtually).
    """

    def __init__(self, cfg: RatelessConfig | None = None):
        self.cfg = cfg or RATELESS_DEFAULT
        self.workers: dict[int, WorkerHealth] = {}

    def worker(self, wid: int) -> WorkerHealth:
        return self.workers.setdefault(wid, WorkerHealth(worker_id=wid))

    # -- observations --------------------------------------------------------

    def observe_success(self, wid: int, latency_s: float) -> None:
        w = self.worker(wid)
        w.consecutive_failures = 0
        a = self.cfg.ewma_alpha
        w.ewma_latency_s = (
            latency_s if w.ewma_latency_s is None
            else a * latency_s + (1.0 - a) * w.ewma_latency_s
        )

    def observe_failure(self, wid: int, now: float, *,
                        kind: str = "error") -> None:
        """A timeout or transport error: back the worker off exponentially
        (deterministic jitter — reproducible runs, no thundering herd),
        quarantine it after `quarantine_after` consecutive failures."""
        w = self.worker(wid)
        w.failures += 1
        w.consecutive_failures += 1
        k = w.consecutive_failures
        pause = min(self.cfg.backoff_base_s * 2.0 ** (k - 1),
                    self.cfg.backoff_max_s)
        h = hashlib.sha256(struct.pack(">qqq", wid, w.failures, 0)).digest()
        frac = (int.from_bytes(h[:4], "big") / 2**32) * 2.0 - 1.0
        w.next_ok_at = now + pause * (1.0 + self.cfg.backoff_jitter * frac)
        if k >= self.cfg.quarantine_after:
            self._quarantine(w, now)

    def observe_tamper(self, wid: int, now: float) -> None:
        """A strip that failed its secret probe: one strike is enough —
        an arithmetic slip and a forgery are indistinguishable to the
        client, and the probation probe is how the worker earns its way
        back either way."""
        w = self.worker(wid)
        w.tampers += 1
        self._quarantine(w, now)

    def observe_discard(self, wid: int, latency_s: float | None = None) -> None:
        """A zombie future resolved after its strip was re-streamed: the
        result is discarded but the latency sample is still real."""
        w = self.worker(wid)
        w.discarded += 1
        if latency_s is not None:
            self.observe_success(wid, latency_s)
            w.consecutive_failures = 0

    def _quarantine(self, w: WorkerHealth, now: float) -> None:
        if not w.quarantined:
            w.quarantine_count += 1
        w.quarantined = True
        w.quarantined_at = now

    def readmit(self, wid: int, now: float, latency_s: float) -> None:
        w = self.worker(wid)
        w.quarantined = False
        w.consecutive_failures = 0
        w.probes_passed += 1
        w.next_ok_at = now
        self.observe_success(wid, latency_s)

    # -- scheduling views ----------------------------------------------------

    def live(self, fleet: tuple[int, ...]) -> list[int]:
        return [wid for wid in fleet if not self.worker(wid).quarantined]

    def predicted_latency(self, wid: int) -> float:
        w = self.worker(wid)
        return 0.0 if w.ewma_latency_s is None else w.ewma_latency_s

    def assignable(self, fleet, busy, now: float) -> list[int]:
        """Live, idle, out-of-backoff workers — fastest predicted first,
        ties to the one that has completed least (spread the unknowns)."""
        ids = [
            wid for wid in self.live(fleet)
            if wid not in busy and self.worker(wid).next_ok_at <= now
        ]
        ids.sort(key=lambda w: (self.predicted_latency(w),
                                self.worker(w).completed, w))
        return ids

    def probation_due(self, fleet, busy, now: float) -> list[int]:
        return [
            wid for wid in fleet
            if self.worker(wid).quarantined and wid not in busy
            and now - self.worker(wid).quarantined_at
            >= self.cfg.probation_cooldown_s
        ]

    def next_wakeup(self, fleet, now: float) -> float | None:
        """Seconds until some benched worker becomes usable again (backoff
        expiry or probation due) — the scheduler's stall-sleep bound."""
        horizon = []
        for wid in fleet:
            w = self.worker(wid)
            if w.quarantined:
                horizon.append(
                    w.quarantined_at + self.cfg.probation_cooldown_s
                )
            elif w.next_ok_at > now:
                horizon.append(w.next_ok_at)
        if not horizon:
            return None
        return max(0.0, min(horizon) - now)

    def report(self) -> dict:
        return {
            "workers": {
                wid: w.as_dict() for wid, w in sorted(self.workers.items())
            },
        }


@dataclass
class RatelessReport:
    """What one rateless session did — attached to the SPDCResult."""

    num_strips: int
    lanes: int
    dispatches: int = 0
    retries: int = 0
    timeouts: int = 0
    tampered_strips: int = 0
    inline_strips: int = 0  # degradation-ladder completions
    probes: int = 0
    workers: dict = field(default_factory=dict)  # FleetHealth.report()

    def as_dict(self) -> dict:
        d = dict(self.__dict__)
        d["workers"] = dict(self.workers)
        return d


@dataclass
class _Lane:
    """One independent strip chain: a contiguous batch slice (or the
    whole matrix) advancing strip by strip as probes accept."""

    index: int
    sel: slice | None  # batch rows this lane owns (None = unbatched)
    x: np.ndarray  # (…, n', n') ciphertext view
    next_strip: int = 0
    attempts: int = 0  # dispatches of the CURRENT strip
    in_flight: bool = False
    l_rows: list = field(default_factory=list)
    u_rows: list = field(default_factory=list)
    # running concat of u_rows — u_known() is on the mint hot path, and
    # re-concatenating s blocks per dispatch is O(F^2) copies per lane
    u_cat: np.ndarray | None = None

    def u_known(self) -> np.ndarray:
        if self.u_cat is None:
            b, n = 0, self.x.shape[-1]
            return np.zeros((*self.x.shape[:-2], b, n), dtype=self.x.dtype)
        return self.u_cat


@dataclass
class _Dispatch:
    lane: _Lane | None  # None = probation probe
    strip: int
    worker: int
    attempt: int
    t0: float
    probe: bool = False
    stale: bool = False  # timed out client-side; result will be discarded


def _probe_vector(digest: bytes, lane: int, strip: int, attempt: int,
                  n: int, dtype) -> np.ndarray:
    """Fresh SECRET probe per (lane, strip, attempt) — a worker that
    solved one probe's null space gains nothing against the next."""
    h = hashlib.sha256(
        digest + b"rateless-probe"
        + struct.pack(">qqq", lane, strip, attempt)
    ).digest()
    rng = np.random.default_rng(int.from_bytes(h[:8], "big"))
    return rng.standard_normal(n).astype(dtype)


def _verify_strip(x_row, l_row, u_known, r, eps_base) -> tuple[bool, float]:
    """Secret-probed acceptance of ONE strip (core.verify conventions):
    max |X_s·r − L_s·(U_{0..s}·r)| over the strip's rows, against the
    growth-widened ε(N). Columns of L_s beyond the known U rows must be
    structurally zero (an honest strip's are), so junk planted there
    cannot ride an accepted strip into the final factors."""
    rows = u_known.shape[-2]
    lhs = np.einsum("...ij,j->...i", x_row, r)
    rhs = np.einsum("...ij,...j->...i", l_row[..., :rows],
                    np.einsum("...ij,j->...i", u_known, r))
    res = float(np.max(np.abs(lhs - rhs)))
    tail = l_row[..., rows:]
    if tail.size:
        res = max(res, float(np.max(np.abs(tail))) * float(np.max(np.abs(r))))
    # growth_estimate's clamp(max|U|/max|X|, >= 1), in plain numpy — this
    # runs once per accepted strip on the scheduler's hot path, where a
    # jitted reduction's dispatch overhead would dominate the math
    gx = float(np.max(np.abs(x_row)))
    gu = float(np.max(np.abs(u_known))) if u_known.size else gx
    growth = max(1.0, gu / max(gx, np.finfo(np.asarray(x_row).dtype).tiny))
    return res <= eps_base * growth, res


def run_rateless(
    session,
    transport,
    cfg: RatelessConfig,
    fleet: FleetHealth,
    *,
    faults=(),
) -> tuple[np.ndarray, np.ndarray, RatelessReport]:
    """Drive one session's factorization through the rateless loop.

    Returns (l, u, report) with l/u host arrays shaped like the fused
    sweep's output; `Session.collect()` authenticates them exactly as it
    would any transport's. Raises nothing for fleet trouble — the
    degradation ladder absorbs it — only for programming errors.
    """
    F = session.partitions
    b = session.strip_block
    x_host = np.asarray(session.x_aug)
    n = x_host.shape[-1]
    batched = x_host.ndim == 3
    fleet_ids = tuple(range(session.num_servers))

    if batched:
        B = x_host.shape[0]
        n_lanes = min(B, cfg.lanes or max(1, len(fleet_ids)))
        bounds = np.linspace(0, B, n_lanes + 1).astype(int)
        lanes = [
            _Lane(index=i, sel=slice(int(lo), int(hi)),
                  x=x_host[int(lo):int(hi)])
            for i, (lo, hi) in enumerate(zip(bounds[:-1], bounds[1:], strict=True))
            if hi > lo
        ]
    else:
        lanes = [_Lane(index=0, sel=None, x=x_host)]

    eps_base = float(
        np.max(np.asarray(
            epsilon(F, n, session.x_aug, dtype=x_host.dtype)
        ))
    )
    report = RatelessReport(num_strips=F, lanes=len(lanes))
    pending: dict[Future, _Dispatch] = {}
    busy: set[int] = set()
    probe_seq = 0
    # the probe pool: an (x_row, u_above, verified row count) re-issue a
    # quarantined worker must reproduce to re-admit — filled by the first
    # verified strip of lane 0
    probe_strip: tuple[int, _Lane] | None = None

    boundary_checked = False

    def mint(lane: _Lane, strip: int, attempt: int) -> ShardTask:
        nonlocal boundary_checked
        s0 = strip * b
        # lane-disambiguated sub-seed token: lanes re-use strip indices,
        # the dispatch channel key must still be unique per (lane, strip)
        token = lane.index * F + strip
        task = ShardTask(
            server=strip,
            num_servers=F,
            x_row=np.ascontiguousarray(lane.x[..., s0:s0 + b, :]),
            subseed=dispatch_subseed(session.digest, token, attempt),
            style="nserver",
            attempt=attempt,
            u_upstream=lane.u_known() if strip > 0 else None,
            session_id=session.session_id,
        )
        # every mint composes the task from the same fields of the same
        # session, so one representative boundary check per session
        # covers them all — the per-strip payloads differ only in which
        # ciphertext rows they slice
        if not boundary_checked:
            session._assert_boundary([task], False)
            boundary_checked = True
        return task

    def accept(lane: _Lane, result: ShardResult) -> None:
        u = np.asarray(result.u_row)
        lane.l_rows.append(np.asarray(result.l_row))
        lane.u_rows.append(u)
        lane.u_cat = (
            u if lane.u_cat is None
            else np.concatenate([lane.u_cat, u], axis=-2)
        )
        lane.next_strip += 1
        lane.attempts = 0
        lane.in_flight = False

    def verify(lane: _Lane, strip: int, attempt: int,
               result: ShardResult) -> bool:
        s0 = strip * b
        r = _probe_vector(session.digest, lane.index, strip, attempt, n,
                          x_host.dtype)
        u_new = np.asarray(result.u_row)
        u_known = (
            u_new if lane.u_cat is None
            else np.concatenate([lane.u_cat, u_new], axis=-2)
        )
        ok, _ = _verify_strip(
            lane.x[..., s0:s0 + b, :], np.asarray(result.l_row),
            u_known, r, eps_base,
        )
        return ok

    def run_inline(lane: _Lane) -> None:
        """Degradation ladder, last rung: the client computes the strip
        itself — EdgeServer arithmetic, no transport, no faults."""
        task = mint(lane, lane.next_strip, lane.attempts)
        lane.attempts += 1
        accept(lane, EdgeServer(None).run(task))
        report.inline_strips += 1

    def dispatch(lane: _Lane, wid: int, now: float) -> None:
        task = mint(lane, lane.next_strip, lane.attempts)
        if lane.attempts > 0:
            report.retries += 1
        rec = _Dispatch(lane=lane, strip=lane.next_strip, worker=wid,
                        attempt=lane.attempts, t0=now)
        lane.attempts += 1
        lane.in_flight = True
        busy.add(wid)
        report.dispatches += 1
        fut = transport.start(task, wid, faults=faults,
                              timeout=cfg.request_timeout_s)
        pending[fut] = rec

    def dispatch_probe(wid: int, now: float) -> None:
        nonlocal probe_seq
        strip, lane = probe_strip
        s0 = strip * b
        probe_seq += 1
        task = ShardTask(
            server=strip,
            num_servers=F,
            x_row=np.ascontiguousarray(lane.x[..., s0:s0 + b, :]),
            # attempt stays 0 on the WIRE so a persistently tampering
            # worker misbehaves on the probe too; the sub-seed token keys
            # the channel uniquely per probe regardless
            subseed=dispatch_subseed(session.digest, -2, 1000 + probe_seq),
            style="nserver",
            attempt=0,
            u_upstream=(
                np.concatenate(lane.u_rows[:strip], axis=-2)
                if strip > 0 else None
            ),
            session_id=session.session_id,
        )
        # rec.attempt carries the probe sequence (not the wire attempt)
        # so verify_probe re-derives THIS probe's vector even when
        # several probes are in flight
        rec = _Dispatch(lane=None, strip=strip, worker=wid,
                        attempt=1000 + probe_seq, t0=now, probe=True)
        busy.add(wid)
        report.probes += 1
        fut = transport.start(task, wid, faults=faults,
                              timeout=cfg.request_timeout_s)
        pending[fut] = rec

    def verify_probe(rec: _Dispatch, result: ShardResult) -> bool:
        strip, lane = probe_strip
        s0 = strip * b
        r = _probe_vector(session.digest, -2, strip, rec.attempt, n,
                          x_host.dtype)
        u_known = np.concatenate(
            [*lane.u_rows[:strip], np.asarray(result.u_row)], axis=-2
        )
        ok, _ = _verify_strip(
            lane.x[..., s0:s0 + b, :], np.asarray(result.l_row),
            u_known, r, eps_base,
        )
        return ok

    def settle(fut: Future, now: float) -> None:
        rec = pending.pop(fut)
        busy.discard(rec.worker)
        err = fut.exception()
        if rec.stale:
            # zombie: its strip was re-streamed when the client-side
            # deadline passed; the worker is merely free again now
            if err is None:
                fleet.observe_discard(rec.worker, now - rec.t0)
            return
        if err is not None:
            if isinstance(err, (TransportError, FutureTimeout)):
                if isinstance(err, TransportTimeout):
                    report.timeouts += 1
                fleet.observe_failure(rec.worker, now)
                if rec.probe:
                    # a failed probe restarts the cooldown — no point
                    # re-probing a worker that just timed out
                    fleet.worker(rec.worker).quarantined_at = now
                elif rec.lane is not None:
                    rec.lane.in_flight = False
                return
            raise err
        result = fut.result()
        if rec.probe:
            if verify_probe(rec, result):
                fleet.readmit(rec.worker, now, now - rec.t0)
            else:
                fleet.observe_tamper(rec.worker, now)
            return
        lane = rec.lane
        lane.in_flight = False
        if rec.strip != lane.next_strip:
            # a duplicate answer for an already-accepted strip
            fleet.observe_discard(rec.worker, now - rec.t0)
            return
        if verify(lane, rec.strip, rec.attempt, result):
            accept(lane, result)
            fleet.observe_success(rec.worker, now - rec.t0)
            fleet.worker(rec.worker).completed += 1
        else:
            report.tampered_strips += 1
            fleet.observe_tamper(rec.worker, now)

    while True:
        now = time.monotonic()
        if all(lane.next_strip >= F for lane in lanes):
            # every strip verified — do NOT wait out stale zombies or
            # in-flight probes; their pool threads resolve in the
            # background and the unobserved results are simply dropped
            break
        open_lanes = [
            lane for lane in lanes
            if lane.next_strip < F and not lane.in_flight
        ]

        if probe_strip is None:
            for lane in lanes:
                if lane.next_strip > 0:
                    probe_strip = (0, lane)
                    break

        # degradation ladder, rungs 1–2: exhausted strips and a
        # too-small fleet complete inline — the session answers anyway
        live = fleet.live(fleet_ids)
        for lane in list(open_lanes):
            if lane.attempts >= cfg.max_attempts or len(live) < cfg.min_live:
                run_inline(lane)
                open_lanes.remove(lane)

        for wid in fleet.assignable(fleet_ids, busy, now):
            if not open_lanes:
                break
            # most-behind lane first: the stragglers' backlog gets the
            # fastest predicted worker
            open_lanes.sort(key=lambda lane: lane.next_strip)
            dispatch(open_lanes.pop(0), wid, now)

        if probe_strip is not None:
            for wid in fleet.probation_due(fleet_ids, busy, now):
                dispatch_probe(wid, now)

        if not pending:
            if not any(lane.next_strip < F for lane in lanes):
                break
            # nothing in flight, nothing assignable: either a bench is
            # about to expire (sleep until it does) or the fleet is gone
            # (finish inline)
            pause = fleet.next_wakeup(fleet_ids, time.monotonic())
            if pause is None or not fleet.live(fleet_ids):
                for lane in lanes:
                    while lane.next_strip < F:
                        run_inline(lane)
                break
            time.sleep(min(pause + 1e-3, 0.25))
            continue

        # client-side request deadline: transports that cannot preempt a
        # worker (threads) still converge on the one straggler policy —
        # the strip is re-streamed, the late future becomes a zombie
        if cfg.request_timeout_s is not None:
            for rec in pending.values():
                if rec.stale or now - rec.t0 <= cfg.request_timeout_s:
                    continue
                rec.stale = True
                report.timeouts += 1
                fleet.observe_failure(rec.worker, now)
                if rec.probe:
                    fleet.worker(rec.worker).quarantined_at = now
                elif rec.lane is not None:
                    rec.lane.in_flight = False

        done, _ = futures_wait(
            list(pending), timeout=0.05, return_when="FIRST_COMPLETED"
        )
        now = time.monotonic()
        for fut in done:
            settle(fut, now)

    # assemble: strips back into (…, n', n') factors, lanes back into
    # batch order (contiguous slices — concatenation restores it)
    def stack(rows_attr):
        per_lane = [
            np.concatenate(getattr(lane, rows_attr), axis=-2)
            for lane in lanes
        ]
        if not batched:
            return per_lane[0]
        return np.concatenate(per_lane, axis=0)

    report.workers = fleet.report()["workers"]
    return stack("l_rows"), stack("u_rows"), report
