"""Distributed runtime: SPDC shard_map pipeline + fault recovery + LM
sharding rules."""
