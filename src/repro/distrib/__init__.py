"""Distributed runtime: SPDC shard_map pipeline + LM sharding rules."""
