"""Sharding rules: logical axis names → mesh PartitionSpecs.

Models are written against *logical* axis names; a ShardingRules object
resolves them to the active mesh's physical axes. With no active rules
(smoke tests, single device) every constraint is a no-op and params are
unsharded.

Logical names:
  "batch"   → the data-parallel axes (("pod","data") multi-pod, ("data",)
              single-pod, () on one device)
  "model"   → the tensor-parallel axis
  "seq"     → sequence sharding of the residual stream (mapped to "model";
              Ulysses-style — attention reshards seq→heads via all-to-all,
              inserted by the SPMD partitioner)
  None      → replicated

Fallback policy (DESIGN.md §5): head-sharded attention when
num_heads % model_size == 0, else sequence-parallel attention (Q sharded on
seq, KV gathered — exact for the MQA/GQA archs that hit this: gemma-2b
8 heads, gemma3 4, llama4 40 on a 16-way model axis).
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ShardingRules:
    mesh: jax.sharding.Mesh | None = None
    batch_axes: tuple[str, ...] = ()
    model_axis: str | None = None
    fsdp_axes: tuple[str, ...] = ()  # param-only second axis (ZeRO-3 style)
    # resolved per-config at step-build time:
    shard_heads: bool = True  # False => sequence-parallel attention
    shard_kv: bool = False    # kv heads sharded (only when divisible)
    shard_seq: bool = True    # residual-stream sequence sharding

    @property
    def model_size(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    def resolve(self, *names) -> P:
        """Map logical names to a PartitionSpec under these rules."""
        out = []
        for nm in names:
            if nm == "batch":
                out.append(self.batch_axes if self.batch_axes else None)
            elif nm == "model":
                out.append(self.model_axis)
            elif nm == "seq":
                out.append(self.model_axis if self.shard_seq else None)
            elif nm == "heads":
                out.append(self.model_axis if self.shard_heads else None)
            elif nm == "kv_heads":
                out.append(self.model_axis if self.shard_kv else None)
            elif nm == "fsdp":
                out.append(self.fsdp_axes if self.fsdp_axes else None)
            elif nm == "qseq":
                # sequence-parallel attention fallback axis
                out.append(None if self.shard_heads else self.model_axis)
            elif nm is None:
                out.append(None)
            else:
                raise ValueError(f"unknown logical axis {nm!r}")
        return P(*out)

    def sharding(self, *names) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.resolve(*names))


_rules: contextvars.ContextVar[ShardingRules | None] = contextvars.ContextVar(
    "sharding_rules", default=None
)


def current_rules() -> ShardingRules | None:
    return _rules.get()


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
    tok = _rules.set(rules)
    try:
        yield rules
    finally:
        _rules.reset(tok)


def make_rules(
    mesh: jax.sharding.Mesh | None,
    *,
    num_heads: int | None = None,
    num_kv_heads: int | None = None,
    shard_seq: bool = True,
    use_fsdp: bool = True,
) -> ShardingRules:
    """Build rules from a mesh with axes ⊆ {pod, data, model, servers}."""
    if mesh is None:
        return ShardingRules()
    names = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in names)
    model = "model" if "model" in names else None
    msize = mesh.shape[model] if model else 1
    shard_heads = True
    if num_heads is not None and model and num_heads % msize != 0:
        shard_heads = False
    shard_kv = bool(
        shard_heads and num_kv_heads and model and num_kv_heads % msize == 0
    )
    # FSDP/ZeRO over all data-parallel axes, pod included: at ≥340B params,
    # sharding state across pods (ZeRO over DCN — gather weights once per
    # step, standard practice) is the difference between fitting 512×16 GB
    # and not. Weight gathers inside a pod ride the ICI.
    fsdp = tuple(a for a in ("pod", "data") if a in names) if use_fsdp else ()
    return ShardingRules(
        mesh=mesh, batch_axes=batch, model_axis=model, fsdp_axes=fsdp,
        shard_heads=shard_heads, shard_kv=shard_kv, shard_seq=shard_seq,
    )


def constrain(x, *names):
    """with_sharding_constraint against the active rules (no-op if none)."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, rules.resolve(*names))
    )
