"""Verification-driven recovery — re-dispatch ONE shard, not the protocol.

The paper's client has exactly one remedy when Authenticate rejects: throw
the whole result away and re-outsource (re-cipher, re-send, re-factor —
O(n²) wire + O(n³) compute, again). Algorithm 3's block-row ownership
admits something far cheaper: the blocked-Q1 localization
(core.verify.localize) names the faulty server, every strip ABOVE it is
verified-clean, and the faulty server's strip is a pure function of

    (its shard of the ciphertext) × (the verified U rows above it)

— so the client re-derives that one shard (core.augment.augment_block_row:
replay the padding draw, slice the block row), re-keys the dispatch channel
with a fresh sub-seed, hands the shard + upstream U rows to a standby (or
any healthy) server, and splices the recomputed strips into the wavefront
result. Cost: one recompute of ~1/N of the factorization plus O(n·b) wire
— vs a full restart.

The loop is *verification-driven*: recompute → re-verify → repeat. A
report-only fault converges in one round; an in-band relay poisoning
(the tampered U row was consumed downstream) heals one block row per
round, cascading at most N−s rounds — each round's first-failing block is
provably computable from the verified rows above it, so progress is
monotone. `max_rounds` defaults to num_servers (the worst cascade).

N+r standby (ServerPool): the client provisions r spare servers up front;
a failed server is retired and its shard re-dispatched to a spare, so
recovery costs one extra hop instead of a renegotiation. With the pool
exhausted, re-dispatch falls back to the failed server's healthy neighbor
(the client has no reason to trust the culprit twice).
"""

from __future__ import annotations

import hashlib
import struct
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

import jax

from repro.core.augment import augment_block_row
from repro.core.lu import lu_block_row
from repro.core.verify import Verdict, authenticate

#: jitted recompute for (B, n, n) stacks, where host-side dispatch would
#: dominate; single matrices stay un-jitted so the recompute's operation
#: order matches the (un-jitted) lu_nserver run bit-for-bit.
_block_row_batched = jax.jit(
    lu_block_row, static_argnums=(2, 3), static_argnames=("style",)
)


def dispatch_subseed(digest: bytes, server: int, attempt: int) -> bytes:
    """Fresh per-dispatch sub-seed: H(Ψ-digest ‖ server ‖ attempt).

    Re-keys the client→replacement channel so a replayed or stale shard
    from the original (possibly malicious) server cannot impersonate the
    re-dispatch. Derived, never stored — the client only keeps Ψ's digest.
    """
    h = hashlib.sha256()
    h.update(digest)
    h.update(struct.pack(">qq", int(server), int(attempt)))
    return h.digest()


def trisolve_subseed(
    digest: bytes, rnd: int, chunk: int, attempt: int
) -> bytes:
    """Dispatch-channel key for one triangular-solve chunk (DESIGN.md
    §12): H(Ψ-digest ‖ "trisolve" ‖ round ‖ chunk ‖ attempt).

    A lane DISJOINT from `dispatch_subseed` (the literal tag separates
    the domains), so a server holding LU-round sub-seeds learns nothing
    about the solve rounds' probe or masking keys, and a replayed chunk
    cannot impersonate a re-issue (attempt is part of the derivation).
    """
    h = hashlib.sha256()
    h.update(digest)
    h.update(b"trisolve")
    h.update(struct.pack(">qqq", int(rnd), int(chunk), int(attempt)))
    return h.digest()


def recover_solve(
    results: list,
    bad: list[int],
    *,
    make_task,
    verify_chunk,
    transport,
    num_servers: int,
    standby: int = 0,
    max_rounds: int | None = None,
    pool: "ServerPool | None" = None,
) -> tuple[list, "RecoveryReport"]:
    """Heal rejected triangular-solve chunks by re-dispatching them.

    The solve analogue of `recover_lu`, column-wise instead of row-wise:
    chunks are independent (no relay, no cascade), so each round simply
    re-issues every failed chunk to a pool replacement with attempt+1 —
    a fresh `trisolve_subseed` keys the re-dispatch — and re-verifies it
    with the round's check. Convergence needs one honest replacement per
    chunk; `max_rounds` (default num_servers) bounds a fleet that keeps
    lying.

    results: the round's TriSolveResult list, indexed by chunk (None for
        timeouts). Healed in place on a copy, returned.
    bad: chunk indices whose verification failed (or that are None).
    make_task(chunk, attempt, replacement) -> TriSolveTask: mints the
        re-issue — the LinalgSession closure holds the factors/RHS and
        the digest, so this module never touches secret material.
    verify_chunk(chunk, result) -> float | None: residual if the healed
        chunk now verifies, None if it still fails.
    """
    pool = pool or ServerPool(num_servers, standby)
    max_rounds = num_servers if max_rounds is None else max_rounds
    report = RecoveryReport(ok=False, rounds=0)
    results = list(results)
    pending = sorted(set(bad))
    attempts: dict[int, int] = {}
    for rnd in range(max_rounds):
        if not pending:
            break
        report.rounds = rnd + 1
        still_bad = []
        for c in pending:
            attempts[c] = attempts.get(c, 0) + 1
            phys, pool = pool.replacement_for(c % num_servers)
            task = make_task(c, attempts[c], phys)
            res = transport.repair(task, replacement=phys)
            residual = verify_chunk(c, res)
            if residual is None:
                still_bad.append(c)
                continue
            results[c] = res
            report.events.append(
                RecoveryEvent(
                    round=rnd,
                    server=c,
                    replacement=phys,
                    residual=float(residual),
                    comm_elements=2 * task.rhs.size + 2 * task.l.size,
                    subseed=task.subseed.hex(),
                )
            )
        pending = still_bad
    report.ok = not pending
    report.standby_used = pool.spares_used
    return results, report


def recovery_comm_elements(n: int, num_servers: int, server: int) -> int:
    """Wire cost (elements) of re-dispatching server `server`'s shard:
    its (b, n) ciphertext block row + the verified upstream U rows
    (their structural support only) + the (2·b·n) L/U strips coming back."""
    b = n // num_servers
    upstream = sum(b * (n - k * b) for k in range(server))
    return b * n + upstream + 2 * b * n


@dataclass(frozen=True)
class ServerPool:
    """N workers + r standbys (frozen bookkeeping; replace() returns the
    next pool state so recovery rounds stay functional)."""

    num_servers: int
    standby: int = 0
    spares_used: int = 0
    retired: tuple[int, ...] = ()

    def replacement_for(self, server: int) -> tuple[int, "ServerPool"]:
        """Physical id that re-runs `server`'s shard, and the next pool.

        Standbys are numbered num_servers..num_servers+standby−1; once
        exhausted, the shard goes to the culprit's next healthy neighbor.
        """
        retired = (*self.retired, server)
        if self.spares_used < self.standby:
            phys = self.num_servers + self.spares_used
            pool = ServerPool(
                self.num_servers,
                self.standby,
                self.spares_used + 1,
                retired,
            )
            return phys, pool
        # no spares: prefer a never-retired neighbor; failing that, a
        # retired-but-healed one — anyone but the culprit itself
        candidates = [
            (server + 1 + i) % self.num_servers
            for i in range(max(self.num_servers - 1, 1))
        ]
        fresh = [c for c in candidates if c not in retired]
        phys = fresh[0] if fresh else candidates[0]
        return phys, ServerPool(
            self.num_servers,
            self.standby,
            self.spares_used,
            retired,
        )


@dataclass(frozen=True)
class RecoveryEvent:
    """One re-dispatch: which logical server failed, who re-ran its shard."""

    round: int
    server: int
    replacement: int
    residual: float
    comm_elements: int
    subseed: str  # hex digest of the fresh dispatch channel key
    matrices: tuple[int, ...] | None = None  # batch indices spliced


@dataclass
class RecoveryReport:
    """Outcome of the verification-driven re-dispatch loop."""

    ok: bool
    rounds: int
    events: list[RecoveryEvent] = field(default_factory=list)
    standby_used: int = 0

    @property
    def servers_replaced(self) -> tuple[int, ...]:
        return tuple(sorted({e.server for e in self.events}))


def recover_lu(
    l: jnp.ndarray,
    u: jnp.ndarray,
    x: jnp.ndarray,
    *,
    num_servers: int,
    method: str = "q3",
    standby: int = 0,
    max_rounds: int | None = None,
    digest: bytes = b"",
    pool: ServerPool | None = None,
    style: str = "nserver",
    verdict: Verdict | None = None,
    dispatch=None,
) -> tuple[jnp.ndarray, jnp.ndarray, Verdict, RecoveryReport]:
    """Heal a rejected factorization by re-dispatching localized shards.

    x is the (verified-held) ciphertext the client dispatched — (n, n) or a
    (B, n, n) stack. Each round: authenticate → take each matrix's FIRST
    failing block row (rows above are clean) → recompute that strip from x
    and the verified upstream U rows (lu_block_row — the same arithmetic a
    replacement server runs) → splice it into l/u for exactly the matrices
    that blamed that server. Converges in ≤ num_servers rounds for any
    single-server fault, including in-band relay poisoning (one healed row
    per round). `style` must name the Parallelize implementation that
    produced the surviving rows ("nserver" simulation / "pipeline"
    shard_map) so the recompute replays its exact operation order — see
    core.lu.lu_block_row. When the replacement's arithmetic still cannot
    be bitwise-identical to the original (a jitted pipeline vs a host-side
    recompute, or a genuinely different machine), splice-induced rounding
    can push a downstream row's residual over ε(N); the loop simply heals
    that row on the next round — an extra hop, never a wrong answer.

    dispatch: optional hook actually EXECUTING one re-dispatch —
    ``dispatch(x, u, server, attempt, replacement) -> (l_row, u_row)``.
    The role-split Session passes one that mints a fresh ShardTask
    (sub-seed H(Ψ ‖ server ‖ attempt), verified upstream U rows attached)
    and runs it on the replacement worker through its Transport
    (repro.api.client), so recovery stays client-driven under every
    execution boundary. Default: recompute locally via lu_block_row —
    identical arithmetic, no transport.

    Returns (l, u, final verdict, report).
    """
    n = x.shape[-1]
    batched = x.ndim == 3
    pool = pool or ServerPool(num_servers, standby)
    max_rounds = num_servers if max_rounds is None else max_rounds
    report = RecoveryReport(ok=False, rounds=0)
    attempts: dict[int, int] = {}

    def _probe_rng(rnd: int) -> np.random.Generator:
        # fresh SECRET probe per verification round — a server that solved
        # one probe's null space gains nothing against the next
        h = hashlib.sha256(digest + struct.pack(">q", rnd)).digest()
        return np.random.default_rng(int.from_bytes(h[:8], "big"))

    if verdict is None:
        verdict = authenticate(
            l, u, x, num_servers=num_servers, method=method,
            rng=_probe_rng(-1),
        )

    for rnd in range(max_rounds):
        # the global verdict is the accept/reject authority; localization
        # only guides healing — matrices whose verdict already passes are
        # never re-dispatched (a block residual may graze the raw ε(N)
        # while the configured method accepts)
        failing = ~np.atleast_1d(np.asarray(verdict.ok))
        culprit = np.where(
            failing, np.atleast_1d(np.asarray(verdict.culprit)), -1
        )
        to_heal = sorted({int(c) for c in culprit if c >= 0})
        if not to_heal:
            # recovered, or the failure is global and unattributable —
            # either way there is nothing localizable left to re-dispatch
            break
        report.rounds = rnd + 1
        for s in to_heal:
            attempts[s] = attempts.get(s, 0) + 1
            phys, pool = pool.replacement_for(s)
            if dispatch is not None:
                l_row, u_row = dispatch(x, u, s, attempts[s], phys)
            else:
                row_fn = _block_row_batched if batched else lu_block_row
                l_row, u_row = row_fn(x, u, s, num_servers, style=style)
            b = n // num_servers
            sl = slice(s * b, (s + 1) * b)
            if batched:
                idx = np.nonzero(culprit == s)[0]
                l = l.at[idx, sl, :].set(l_row[idx])
                u = u.at[idx, sl, :].set(u_row[idx])
                sres = float(np.max(verdict.server_residual[idx, s]))
                hit: tuple[int, ...] | None = tuple(int(i) for i in idx)
            else:
                l = l.at[..., sl, :].set(l_row)
                u = u.at[..., sl, :].set(u_row)
                sres = float(verdict.server_residual[s])
                hit = None
            report.events.append(
                RecoveryEvent(
                    round=rnd,
                    server=s,
                    replacement=phys,
                    residual=sres,
                    comm_elements=recovery_comm_elements(n, num_servers, s),
                    subseed=dispatch_subseed(digest, s, attempts[s]).hex(),
                    matrices=hit,
                )
            )
        verdict = authenticate(
            l, u, x, num_servers=num_servers, method=method,
            rng=_probe_rng(rnd),
        )

    report.ok = bool(np.all(verdict.ok))
    report.standby_used = pool.spares_used
    return l, u, verdict, report


def rederive_shard(
    x: jnp.ndarray,
    *,
    padding: int,
    server: int,
    num_servers: int,
    aug_key=None,
) -> jnp.ndarray:
    """Re-derive one server's shard of the augmented ciphertext from the
    (unaugmented) ciphertext x — replaying the deterministic padding draw
    instead of caching X_aug (core.augment.augment_block_row). Returns the
    (…, b, n_aug) block row the replacement server receives."""
    n_aug = x.shape[-1] + padding
    if n_aug % num_servers != 0:
        raise ValueError(f"n+p={n_aug} not partitioned by N={num_servers}")
    b = n_aug // num_servers
    return augment_block_row(x, padding, server * b, b, key=aug_key)
