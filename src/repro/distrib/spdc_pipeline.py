"""Distributed N-server SPDC LU — paper Algorithm 3 as a shard_map pipeline.

Mapping (DESIGN.md §2): edge server i ⇒ mesh device i on a 1-D "servers"
axis. Server i owns block row i of the ciphered matrix (in_specs
P("servers", None)). The paper's one-way communication pattern — S_i sends
its accumulated U rows only to S_{i+1} — becomes a single forward
`lax.ppermute` per round: neighbor-only ICI traffic, no broadcast, no
all-gather, exactly the paper's §IV.D.3 schedule.

Program structure (SPMD, N rounds):

  round t:  device with axis_index == t runs its Alg.-3 row computation
            (L_{t,0..t-1} via TRSM against upstream U; blocked-panel LU of
            the Schur-updated diagonal block; its U row), writes the U row
            into the relay buffer; then every device forwards the relay
            buffer one hop down the ring.

Batch semantics (DESIGN.md §3): every program accepts a device-local block
of shape (b, n) — one matrix — or (B, b, n) — a stack. The batch dimension
stays device-local (in_specs P(None, "servers", None)); the "servers" axis
and the relay schedule are unchanged, so a single N-round wavefront sweep
factors all B matrices: the N-1 relay hops are paid once per batch instead
of once per matrix.

The relay buffer is the fixed-shape (n, n) U matrix (rows ≥ t still zero).
The paper's variable-size messages (rows 0..t only) would be a ragged
send; fixed-shape relay overcounts bytes by ≤ 2× — accounted for in
benchmarks (CommLog tracks the paper-exact volume).

The per-device active computation is gated behind `lax.cond` on the traced
axis index, so passive devices do no FLOPs while the wavefront is
elsewhere — faithful to the paper's staggered activation (§IV.D.3).
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, pcast, shard_map


def _factor_diag(a: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-round diagonal factorization: the blocked panel for b >= 64 (no
    full-tile Doolittle on the critical path), plain Doolittle below."""
    from repro.core.lu import lu_diag_factor

    return lu_diag_factor(a)


def _batched_view(x_blk: jnp.ndarray, b: int, n: int) -> tuple[jnp.ndarray, bool]:
    """Normalize a device-local block to (B, b, n); remember if it was 2-D."""
    if x_blk.ndim == 3:
        return x_blk, True
    return x_blk.reshape(1, b, n), False


def _trsm_right_upper_b(u: jnp.ndarray, acc: jnp.ndarray) -> jnp.ndarray:
    """L_ik = acc @ U_kk^{-1}, batched over the leading dim."""
    from repro.core.lu import _trsm_right_upper

    return _trsm_right_upper(u, acc)


def _inject_faults(l_row, u_row, my_id, faults, *, n, batched):
    """Device-output fault injection (core.faults surface, distributed leg).

    The mesh device playing the faulty server corrupts the (B, b, n) strips
    it reports — tamper modes and dropouts are first-class on the real
    pipeline, not just the single-process simulation. Faults are static
    (part of the compile cache key); the injection is a `where` on the
    traced axis index, so honest devices' outputs pass through untouched.
    In-band relay poisoning is NOT modeled here (see core.lu.lu_nserver).
    """
    import numpy as np

    from repro.core.faults import corrupt_strip

    for f in faults:
        targets = ("l", "u") if f.kind == "dropout" else tuple(f.target)

        def masked(orig, factor, f=f):
            bad = corrupt_strip(orig, f, n=n, factor=factor)
            if f.matrices is not None and batched:
                idx = np.asarray(f.matrices, dtype=np.int32)
                bad = orig.at[idx].set(bad[idx])
            return jnp.where(my_id == f.server, bad, orig)

        if "l" in targets:
            l_row = masked(l_row, "l")
        if "u" in targets:
            u_row = masked(u_row, "u")
    return l_row, u_row


def _server_program(x_blk: jnp.ndarray, *, n: int, b: int, num_servers: int,
                    axis: str, faults=()) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Runs on every device inside shard_map. x_blk: (b, n) or (B, b, n)."""
    my_id = lax.axis_index(axis)
    x_row, batched = _batched_view(x_blk, b, n)
    B = x_row.shape[0]
    zero = jnp.zeros((), jnp.int32)

    def active(args):
        u_buf, l_row, u_row = args  # (B,n,n), (B,b,n), (B,b,n)

        # --- L_{i,k} for k < i (sequential in k; TRSM vs upstream U_kk) ---
        def lblk(k, l_row):
            kb = (k * b).astype(jnp.int32)
            # slice the U column panel FIRST: O(b·n·b) per step instead of
            # recomputing the full (b,n) product (§Perf C2 — 16x fewer flops
            # in the L-row loop)
            u_col = lax.dynamic_slice(u_buf, (zero, zero, kb), (B, n, b))
            acc = lax.dynamic_slice(x_row, (zero, zero, kb), (B, b, b)) - l_row @ u_col
            ukk = lax.dynamic_slice(u_buf, (zero, kb, kb), (B, b, b))
            lik = _trsm_right_upper_b(ukk, acc)
            return lax.dynamic_update_slice(l_row, lik, (zero, zero, kb))

        l_row = lax.fori_loop(0, my_id, lblk, l_row)

        # --- Schur update of the whole row, blocked-panel LU of the diag ---
        s = x_row - l_row @ u_buf
        ib = (my_id * b).astype(jnp.int32)
        sii = lax.dynamic_slice(s, (zero, zero, ib), (B, b, b))
        lii, uii = _factor_diag(sii)
        l_row = lax.dynamic_update_slice(l_row, lii, (zero, zero, ib))

        # --- U_{i,j} for j >= i, vectorized over the full row ---
        r = jax.scipy.linalg.solve_triangular(lii, s, lower=True, unit_diagonal=True)
        cols = lax.broadcasted_iota(jnp.int32, (B, b, n), 2)
        u_row = jnp.where(cols >= ib, r, jnp.zeros_like(r))
        u_buf = lax.dynamic_update_slice(u_buf, u_row, (zero, ib, zero))
        return u_buf, l_row, u_row

    def passive(args):
        return args

    fwd = [(i, (i + 1) % num_servers) for i in range(num_servers)]

    def round_fn(t, state):
        u_buf, l_row, u_row = state
        u_buf, l_row, u_row = lax.cond(
            my_id == t, active, passive, (u_buf, l_row, u_row)
        )
        # one-way relay S_t -> S_{t+1} (ring hop; only the t -> t+1 edge
        # carries fresh data, matching the paper's single send per phase)
        u_buf = lax.ppermute(u_buf, axis, fwd)
        return u_buf, l_row, u_row

    u_buf0 = jnp.zeros((B, n, n), dtype=x_row.dtype)
    l_row0 = jnp.zeros((B, b, n), dtype=x_row.dtype)
    u_row0 = jnp.zeros((B, b, n), dtype=x_row.dtype)
    # carries become device-varying inside the loop; mark them so upfront
    u_buf0, l_row0, u_row0 = pcast(
        (u_buf0, l_row0, u_row0), (axis,), to="varying"
    )
    _, l_row, u_row = lax.fori_loop(
        0, num_servers, round_fn, (u_buf0, l_row0, u_row0)
    )
    if faults:
        l_row, u_row = _inject_faults(l_row, u_row, my_id, faults, n=n,
                                      batched=batched)
    if not batched:
        return l_row[0], u_row[0]
    return l_row, u_row


def _server_program_exact(x_blk: jnp.ndarray, *, n: int, b: int,
                          num_servers: int, axis: str, faults=()):
    """Exact-relay variant (§Perf optimization, beyond-paper): rounds are
    unrolled (num_servers is static) so hop t ppermutes ONLY the U rows
    0..t computed so far — (t+1)·b×n elements instead of the fixed n×n
    relay. Total wire volume drops from N·n² to n²(N+1)/2 (≈2× less), and
    matches the paper's §IV.D.3 message contents exactly.
    """
    my_id = lax.axis_index(axis)
    x_row, batched = _batched_view(x_blk, b, n)
    B = x_row.shape[0]
    fwd = [(i, (i + 1) % num_servers) for i in range(num_servers)]
    zero = jnp.zeros((), jnp.int32)

    def active_fn(args):
        u_buf, l_row, u_row = args

        def lblk(k, l_row):
            kb = (k * b).astype(jnp.int32)
            u_col = lax.dynamic_slice(u_buf, (zero, zero, kb), (B, n, b))
            acc = lax.dynamic_slice(x_row, (zero, zero, kb), (B, b, b)) - l_row @ u_col
            ukk = lax.dynamic_slice(u_buf, (zero, kb, kb), (B, b, b))
            lik = _trsm_right_upper_b(ukk, acc)
            return lax.dynamic_update_slice(l_row, lik, (zero, zero, kb))

        l_row = lax.fori_loop(0, my_id, lblk, l_row)
        s = x_row - l_row @ u_buf
        ib = (my_id * b).astype(jnp.int32)
        sii = lax.dynamic_slice(s, (zero, zero, ib), (B, b, b))
        lii, _ = _factor_diag(sii)
        l_row = lax.dynamic_update_slice(l_row, lii, (zero, zero, ib))
        r = jax.scipy.linalg.solve_triangular(lii, s, lower=True,
                                              unit_diagonal=True)
        cols = lax.broadcasted_iota(jnp.int32, (B, b, n), 2)
        u_row = jnp.where(cols >= ib, r, jnp.zeros_like(r))
        u_buf = lax.dynamic_update_slice(u_buf, u_row, (zero, ib, zero))
        return u_buf, l_row, u_row

    u_buf = jnp.zeros((B, n, n), dtype=x_row.dtype)
    l_row = jnp.zeros((B, b, n), dtype=x_row.dtype)
    u_row = jnp.zeros((B, b, n), dtype=x_row.dtype)
    u_buf, l_row, u_row = pcast(
        (u_buf, l_row, u_row), (axis,), to="varying"
    )
    for t in range(num_servers):
        u_buf, l_row, u_row = lax.cond(
            my_id == t, active_fn, lambda a: a, (u_buf, l_row, u_row)
        )
        if t + 1 < num_servers:
            # relay exactly rows 0..t (static slice — rounds are unrolled)
            chunk = lax.ppermute(u_buf[:, : (t + 1) * b], axis, fwd)
            u_buf = u_buf.at[:, : (t + 1) * b].set(chunk)
    if faults:
        l_row, u_row = _inject_faults(l_row, u_row, my_id, faults, n=n,
                                      batched=batched)
    if not batched:
        return l_row[0], u_row[0]
    return l_row, u_row


def _server_program_stream(x_blk: jnp.ndarray, *, n: int, b: int,
                           num_servers: int, axis: str, faults=()):
    """Streaming variant (§Perf C3): no (n,n) relay buffer at all. Each
    round's live state is exactly the received U rows ((t·b, n), a static
    shape per unrolled round); the active server computes against that row
    set and appends its own row before the hop. Wire volume equals the
    exact relay; local HBM traffic drops by the (n,n) buffer copies.
    """
    my_id = lax.axis_index(axis)
    x_row, batched = _batched_view(x_blk, b, n)
    B = x_row.shape[0]
    fwd = [(i, (i + 1) % num_servers) for i in range(num_servers)]
    zero = jnp.zeros((), jnp.int32)

    l_row = jnp.zeros((B, b, n), dtype=x_row.dtype)
    u_row = jnp.zeros((B, b, n), dtype=x_row.dtype)
    l_row, u_row = pcast((l_row, u_row), (axis,), to="varying")
    # _stream_rows[t] = rows received before round t ((B, t·b, n), static)
    _stream_rows = [
        pcast(jnp.zeros((B, t * b, n), dtype=x_row.dtype), (axis,),
              to="varying")
        for t in range(num_servers)
    ]

    for t in range(num_servers):
        def active_fn(args, t=t):
            l_row, u_row = args
            tb = t * b
            u_recv = _stream_rows[t]  # (B, tb, n) received rows, static shape

            def lblk(k, l_row):
                kb = (k * b).astype(jnp.int32)
                u_col = lax.dynamic_slice(u_recv, (zero, zero, kb), (B, tb, b))
                acc = lax.dynamic_slice(x_row, (zero, zero, kb), (B, b, b)) \
                    - l_row[:, :, :tb] @ u_col
                ukk = lax.dynamic_slice(u_recv, (zero, kb, kb), (B, b, b))
                lik = _trsm_right_upper_b(ukk, acc)
                return lax.dynamic_update_slice(l_row, lik, (zero, zero, kb))

            if t:
                l_row = lax.fori_loop(0, t, lblk, l_row)
                s = x_row - l_row[:, :, :tb] @ u_recv
            else:
                s = x_row
            ib = jnp.asarray(t * b, jnp.int32)
            sii = lax.dynamic_slice(s, (zero, zero, ib), (B, b, b))
            lii, _ = _factor_diag(sii)
            l_row = lax.dynamic_update_slice(l_row, lii, (zero, zero, ib))
            r = jax.scipy.linalg.solve_triangular(lii, s, lower=True,
                                                  unit_diagonal=True)
            cols = lax.broadcasted_iota(jnp.int32, (B, b, n), 2)
            u_row = jnp.where(cols >= ib, r, jnp.zeros_like(r))
            return l_row, u_row

        l_row, u_row = lax.cond(
            my_id == t, active_fn, lambda a: a, (l_row, u_row)
        )
        if t + 1 < num_servers:
            # append the active server's row to the stream and hop. Passive
            # devices forward the rows they were relayed (garbage until a
            # device is about to activate, at which point it has received
            # the genuine rows 0..t from its true upstream chain).
            send = jnp.concatenate(
                [_stream_rows[t],
                 jnp.where(my_id == t, u_row, jnp.zeros_like(u_row))],
                axis=1,
            )
            _stream_rows[t + 1] = lax.ppermute(send, axis, fwd)
    if faults:
        l_row, u_row = _inject_faults(l_row, u_row, my_id, faults, n=n,
                                      batched=batched)
    if not batched:
        return l_row[0], u_row[0]
    return l_row, u_row


_PROGRAMS = {
    "baseline": _server_program,
    "exact": _server_program_exact,
    "stream": _server_program_stream,
}


@lru_cache(maxsize=None)
def _compiled_pipeline(program: str, n: int, batch: int | None,
                       num_servers: int, axis: str, faults=()):
    """Build + jit one pipeline program on the default device mesh.

    Cached so repeated protocol calls (the high-throughput serving path)
    reuse the compiled executable instead of re-tracing a fresh shard_map.
    """
    devs = tuple(jax.devices()[:num_servers])
    mesh = make_mesh((num_servers,), (axis,), devices=devs)
    b = n // num_servers
    spec = P(None, axis, None) if batch is not None else P(axis, None)
    fn = shard_map(
        partial(_PROGRAMS[program], n=n, b=b, num_servers=num_servers,
                axis=axis, faults=faults),
        mesh=mesh,
        in_specs=spec,
        out_specs=(spec, spec),
    )
    return jax.jit(fn)


def lu_nserver_shardmap(
    x: jnp.ndarray, num_servers: int, *, mesh=None, axis: str = "servers",
    program: str = "baseline", faults=(),
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Distributed Alg. 3. x: (n, n) or (B, n, n) with n % num_servers == 0.

    program: one of "baseline" (fixed-shape relay), "exact" (paper-exact
    ragged relay), "stream" (no relay buffer; received rows only). The
    batch dimension, if present, stays device-local — one wavefront sweep
    factors the whole stack (DESIGN.md §3).

    faults: a FaultPlan (core.faults) injected at the device-output level:
    the mesh device playing each faulty server corrupts (or zeroes) the
    strips it reports. Delay faults must be resolved by the caller
    (core.faults.resolve_delays); in-band relay poisoning is only modeled
    by the single-process simulation and is rejected here.

    mesh: optional existing mesh containing `axis`; default builds a 1-D
    mesh over the first num_servers devices of this process.

    (The deprecated `exact_relay=` bool shim completed its cycle and was
    removed — passing it now raises TypeError.)
    """
    if program not in _PROGRAMS:
        raise ValueError(
            f"unknown program {program!r}; expected one of {sorted(_PROGRAMS)}"
        )
    from repro.core.faults import normalize_plan

    faults = normalize_plan(faults)
    if any(f.in_band for f in faults):
        raise ValueError(
            "in_band faults are not modeled by the shard_map pipeline; use "
            "core.lu.lu_nserver for relay-poisoning simulation"
        )
    if any(f.kind == "delay" for f in faults):
        raise ValueError(
            "resolve delay faults first (core.faults.resolve_delays)"
        )
    n = x.shape[-1]
    if x.ndim not in (2, 3):
        raise ValueError(f"x must be (n, n) or (B, n, n), got shape {x.shape}")
    if n % num_servers != 0 or n // num_servers <= 1:
        raise ValueError(f"n={n} not partitionable over N={num_servers}; augment first")
    batch = x.shape[0] if x.ndim == 3 else None

    if mesh is None:
        if len(jax.devices()) < num_servers:
            raise ValueError(
                f"need {num_servers} devices, have {len(jax.devices())} "
                "(set --xla_force_host_platform_device_count)"
            )
        fn = _compiled_pipeline(program, n, batch, num_servers, axis, faults)
    else:
        b = n // num_servers
        spec = P(None, axis, None) if batch is not None else P(axis, None)
        fn = jax.jit(shard_map(
            partial(_PROGRAMS[program], n=n, b=b, num_servers=num_servers,
                    axis=axis, faults=faults),
            mesh=mesh,
            in_specs=spec,
            out_specs=(spec, spec),
        ))
    l, u = fn(x)
    return l, u


def pipeline_collective_bytes(n: int, num_servers: int, itemsize: int = 8) -> dict:
    """Communication model: fixed-shape relay vs the paper's exact volume."""
    relay = num_servers * n * n * itemsize  # one (n,n) hop per round
    paper = sum(
        sum((num_servers - k) for k in range(i + 1)) * (n // num_servers) ** 2
        for i in range(num_servers - 1)
    ) * itemsize
    return {"relay_bytes": relay, "paper_exact_bytes": paper,
            "overcount_factor": relay / max(paper, 1)}
