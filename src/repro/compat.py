"""JAX version-compatibility shims.

The codebase is written against the current jax API surface
(``jax.shard_map``, ``jax.make_mesh(..., axis_types=...)``,
``jax.lax.pcast``, ``jax.tree.flatten_with_path``). The pinned container
toolchain ships an older jaxlib (0.4.x) where those live elsewhere or do
not exist yet; every internal call site goes through this module instead
of touching the moved APIs directly.
"""
from __future__ import annotations

import jax

_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """jax.make_mesh with Auto axis_types when the API supports them."""
    if _AXIS_TYPE is not None:
        try:
            return jax.make_mesh(
                tuple(axis_shapes), tuple(axis_names),
                axis_types=(_AXIS_TYPE.Auto,) * len(tuple(axis_names)),
                devices=devices,
            )
        except TypeError:
            pass
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), devices=devices)


if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
else:  # jax < 0.6: experimental namespace, and check_rep lacks rules for
    # several primitives used in the pipeline (cond-of-collectives), so it
    # is disabled — correctness is covered by the oracle tests.
    from jax.experimental.shard_map import shard_map as _shard_map_experimental

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _shard_map_experimental(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )


if hasattr(jax.lax, "pcast"):
    pcast = jax.lax.pcast
else:
    def pcast(x, axes, *, to=None):  # noqa: ARG001 - signature parity
        """No-op: pre-varying-types shard_map tracks no replication state."""
        return x


def tree_flatten_with_path(tree):
    tree_mod = getattr(jax, "tree", None)
    if tree_mod is not None and hasattr(tree_mod, "flatten_with_path"):
        return tree_mod.flatten_with_path(tree)
    return jax.tree_util.tree_flatten_with_path(tree)


def tree_map_with_path(f, tree, *rest):
    tree_mod = getattr(jax, "tree", None)
    if tree_mod is not None and hasattr(tree_mod, "map_with_path"):
        return tree_mod.map_with_path(f, tree, *rest)
    return jax.tree_util.tree_map_with_path(f, tree, *rest)


def cost_analysis_dict(compiled) -> dict:
    """compiled.cost_analysis() as a flat dict — older jaxlib returns a
    one-element list of dicts (one per partition), newer returns the dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)
