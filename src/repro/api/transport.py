"""Transports — how ShardTasks reach edge servers and results come back.

A Transport is the pluggable boundary between the SPDC client role and
the N untrusted workers. All transports execute the SAME protocol
messages; they differ in what the wire physically is:

  * ``InlineTransport``      — client and servers share one process and
    the "wire" is elided: the fused, jitted single-sweep fast path of the
    pre-split protocol (bit-identical to it, and the gateway's
    throughput path). ShardTasks still exist (`Session.tasks()`), the
    fused path just never materializes them.
  * ``ShardMapTransport``    — the distrib.spdc_pipeline shard_map
    program: one JAX mesh device per server, the relay a real
    `lax.ppermute`. Fused like inline (the sweep is one SPMD program).
  * ``ThreadPoolTransport``  — one EdgeServer object per worker slot,
    tasks executed on a thread pool, the relay threaded between them as
    in-memory messages. The cheapest transport with a real
    scheduler-visible boundary.
  * ``MultiprocessTransport``— spawned worker PROCESSES; every message
    crosses the boundary as `to_bytes()` frames over an OS pipe and is
    decoded with `from_bytes()` on the far side.
  * ``SocketTransport``      — persistent worker DAEMONS reached over
    TCP or Unix-domain sockets (socket_transport.py): length-prefixed
    wire-codec frames, a versioned HELLO handshake, and warm worker
    processes whose jit caches survive across sessions and client
    restarts (launch/serve_worker.py). The closest shape to the paper's
    real deployment.

Dispatch surface (the async-overlap redesign, DESIGN.md §9):

  * ``start(task, worker_id) -> Future``  — the canonical NONBLOCKING
    primitive: ship one ShardTask to one worker, return immediately.
    The rateless scheduler streams strips with it, and `Session.start`
    rides it so the client's PMOP for batch k+1 overlaps the wire time
    of batch k.
  * ``result(future, timeout)``           — resolve a started dispatch,
    mapping a client-side wait expiry to the typed `TransportTimeout`.
  * ``submit(task, worker_id)``           — the BLOCKING facade:
    ``result(start(...))``. Kept for callers that want one strip now.
  * ``factor(tasks)`` / ``factor_async(tasks)`` — one session's whole
    relay sweep, blocking / as a Future (the unit `Session.start`
    pipelines).

One-way model: for the sequential (message) transports the relay is run
by the transport — task i executes only after i−1's result, and its
``u_upstream`` is exactly the U rows servers 0..i−1 reported, i.e. the
content of the paper's single S_{i-1} → S_i send. No server ever
receives anything from downstream, and the client never ships plaintext
or key material (messages.ShardTask).

Lifecycle: every transport is a context manager with an idempotent
``close()`` and a ``closed`` flag; dispatching on a closed transport
raises TransportError. Long-lived role objects (SPDCClient, the
gateway) BUILD and OWN their transports from a `TransportConfig` and
close them deterministically; the one-shot facades
(`outsource_determinant(transport=...)`) resolve strings and configs to
process-wide SHARED instances so repeated calls — and every gateway
flush — reuse one warm pool instead of respawning workers per call;
`close_all()` runs at interpreter exit.

Fault simulation: ``factor(tasks, faults=plan)`` plays core.faults
misbehavior on the matching workers (a FaultPlanFrame control message on
the message transports). Faults bind to initial dispatches; repairs run
honestly on replacement workers (api.server docstring).
"""
from __future__ import annotations

import atexit
import threading
from concurrent.futures import Future
from concurrent.futures import TimeoutError as _FutureTimeout
from dataclasses import dataclass
from functools import partial

import jax
import numpy as np

from repro.core.lu import lu_nserver

from .messages import FaultPlanFrame, ShardResult, ShardTask
from .server import EdgeServer

__all__ = [
    "Transport",
    "TransportConfig",
    "TransportError",
    "TransportTimeout",
    "TransportWorkerDied",
    "TransportProtocolError",
    "InlineTransport",
    "ShardMapTransport",
    "ThreadPoolTransport",
    "MultiprocessTransport",
    "resolve_transport",
    "close_all",
]


class TransportError(RuntimeError):
    """A worker died, timed out, replied with a malformed frame, or the
    transport was used after close()."""


class TransportTimeout(TransportError):
    """A per-request wall-clock deadline expired before the worker
    replied. On the process-backed transports the worker (multiprocess)
    or its connection (socket) is killed — a reply arriving after the
    deadline would desynchronize the lock-step channel — and respawned /
    reconnected lazily on the next dispatch; the caller treats the
    request as a dropout — zero strips, localize, re-dispatch — exactly
    the rounds-deadline straggler policy (core.faults.resolve_delays)."""


class TransportWorkerDied(TransportError):
    """The worker process/thread/connection went away mid-request
    (crash, kill, broken pipe, dropped socket). Unlike a timeout the
    worker did not merely straggle — transports respawn or reconnect it
    and retry the request once before surfacing the error; the
    fleet-health layer counts it as a failure either way."""


class TransportProtocolError(TransportError):
    """The far side violated the framing or handshake protocol: a
    truncated or oversized frame, a non-wire-codec reply, or a HELLO
    carrying an incompatible protocol/wire version. Unlike a death this
    is not retried — a peer speaking the wrong protocol will speak it
    again — the connection is dropped and the error surfaces typed."""


@partial(jax.jit, static_argnames=("num_servers", "faults"))
def _lu_sweep(x_aug, *, num_servers, faults=()):
    """Jitted fused sweep for (B, n', n') stacks — ONE device program per
    (shape, N, fault-plan), the throughput lever the inline transport
    exists to keep (DESIGN.md §3)."""
    l, u, _ = lu_nserver(x_aug, num_servers, faults=faults)
    return l, u


def serve_frame(edge: EdgeServer, state: dict, data: bytes) -> bytes:
    """One worker-side request → reply step, shared by every byte-framed
    worker loop (the multiprocess pipe worker and the socket daemon).

    Strict request-reply: EVERY frame gets exactly one reply — ShardTask
    → ShardResult bytes, FaultPlanFrame → b"ACK", anything that fails
    (including a frame that does not decode) → an ERR frame. One reply
    per request keeps the channel in lock-step, so a failure can never
    desynchronize later requests' replies. `state` holds the channel's
    fault plan (simulation control; per-pipe on multiprocess, per-
    connection on sockets).
    """
    from .wire import decode_message

    try:  # noqa: SIM105 — report every failure, don't die silently
        msg = decode_message(data)
        if isinstance(msg, FaultPlanFrame):
            state["plan"] = msg.plan
            return b"ACK"
        return edge.run(msg, faults=state.get("plan", ())).to_bytes()
    except Exception as e:  # noqa: BLE001
        return b"ERR:" + repr(e).encode()


class Transport:
    """Base transport: the message-executing interface.

    fused: True when `sweep()` runs the whole factorization as one fused
        program and `Session` should skip task materialization.
    style: the core.lu.lu_block_row operation order this transport's
        factors follow — what repair recomputes must replay.
    """

    name = "abstract"
    fused = False
    style = "nserver"

    _closed = False
    _driver_pool = None
    _driver_lock = threading.Lock()

    @property
    def closed(self) -> bool:
        """True once close() ran; a closed transport refuses dispatch."""
        return self._closed

    def _ensure_open(self) -> None:
        if self._closed:
            raise TransportError(
                f"transport {self.name!r} is closed; build or resolve a "
                "fresh one"
            )

    # -- whole-sweep surface -------------------------------------------------

    def factor(self, tasks, faults=()) -> list[ShardResult]:
        """Run one session's initial ShardTasks (the full sweep)."""
        raise NotImplementedError

    def driver_submit(self, fn, *args) -> Future:
        """Run `fn(*args)` on this transport's driver threads — the
        mechanism behind `factor_async` and `Session.start`. 4 drivers
        bound the pipeline depth, not the worker parallelism."""
        self._ensure_open()
        with Transport._driver_lock:
            if self._driver_pool is None:
                from concurrent.futures import ThreadPoolExecutor

                # instance attribute (class default is None)
                self._driver_pool = ThreadPoolExecutor(
                    max_workers=4, thread_name_prefix=f"spdc-{self.name}-drv"
                )
        return self._driver_pool.submit(fn, *args)

    def factor_async(self, tasks, faults=()) -> Future:
        """`factor` as a Future: the whole relay sweep runs on a driver
        thread so the caller — `Session.start` — can overlap the client
        PMOP for the NEXT session with this one's wire time. The relay
        inside stays strictly sequential (the one-way chain is a data
        dependency); only the session boundary is asynchronous."""
        return self.driver_submit(self.factor, tasks, faults)

    def repair(self, task: ShardTask, *, replacement: int) -> ShardResult:
        """Run one verification-driven re-dispatch on `replacement`."""
        raise NotImplementedError

    # -- per-task surface (the async-overlap redesign) -----------------------

    def start(self, task: ShardTask, worker_id: int, *, faults=(),
              timeout: float | None = None) -> Future:
        """Nonblocking single-task dispatch → `concurrent.futures.Future`
        resolving to a ShardResult (or raising a TransportError). The
        canonical async primitive: the rateless scheduler streams tasks
        to whichever workers are free with it, and `submit` is its
        blocking facade. `timeout` bounds the request where the transport
        can enforce one (multiprocess kills the worker, socket drops the
        connection); where it cannot (a thread has no preemption), the
        caller enforces its own wait and the late future becomes a
        zombie — discarded on arrival, the worker busy until it really
        returns. Fused transports don't have per-task workers; they
        raise."""
        raise NotImplementedError(
            f"transport {self.name!r} has no per-task dispatch surface "
            "(fused transports run the sweep as one program)"
        )

    def result(self, future: Future, timeout: float | None = None
               ) -> ShardResult:
        """Resolve a `start`ed dispatch. `timeout` is a CLIENT-side wait
        bound: expiry raises the typed TransportTimeout but does not kill
        the worker (pass timeout= to `start` for an enforced deadline);
        the future keeps running and may be resolved again later."""
        try:
            return future.result(timeout)
        except _FutureTimeout as e:
            raise TransportTimeout(
                f"dispatch did not resolve within the {timeout}s "
                "client-side wait (the worker-side request may still be "
                "running; start(timeout=) enforces a worker deadline)"
            ) from e

    def submit(self, task: ShardTask, worker_id: int, *, faults=(),
               timeout: float | None = None) -> ShardResult:
        """Blocking single-task facade: `result(start(...))`."""
        return self.result(
            self.start(task, worker_id, faults=faults, timeout=timeout)
        )

    def solve_shards(self, tasks, faults=(), timeout: float | None = None):
        """One triangular-solve round (DESIGN.md §12): dispatch each
        TriSolveTask to its column-chunk's worker and gather the
        TriSolveResults in task order.

        Chunks are independent (column-partitioned RHS — no relay, no
        data dependency), so transports with a per-task surface run them
        concurrently via `start`; fused transports without one (shardmap)
        fall back to an inline EdgeServer, same as their `repair` path. A
        straggler past `timeout` yields None in its slot — the caller
        treats it as a dropout: the residual check localizes the missing
        chunk and recovery re-dispatches it.
        """
        self._ensure_open()
        futures = []
        for t in tasks:
            try:
                futures.append(
                    self.start(t, t.server, faults=faults, timeout=timeout)
                )
            except NotImplementedError:
                fut: Future = Future()
                try:
                    fut.set_result(EdgeServer(t.server).run(t, faults))
                except Exception as e:  # noqa: BLE001 — future carries it
                    fut.set_exception(e)
                futures.append(fut)
        out = []
        for fut in futures:
            try:
                out.append(self.result(fut, timeout))
            except TransportTimeout:
                out.append(None)
        return out

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        """Release workers/pools; idempotent. Subclasses extend this and
        MUST call super().close() so `closed` flips and the driver pool
        shuts down. Shared instances are closed at interpreter exit."""
        self._closed = True
        pool, self._driver_pool = self._driver_pool, None
        if pool is not None:
            pool.shutdown(wait=False)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class InlineTransport(Transport):
    """Degenerate (single-process) transport: today's jitted fast path.

    `sweep()` IS the pre-split protocol's server stage — eager lu_nserver
    for one matrix (bit-matching the recovery recompute), one jitted
    program for a stack — so results are bit-identical to the monolithic
    `outsource_determinant` this API replaced. The message methods exist
    for uniformity (tests drive them); the Session prefers `sweep()`.
    """

    name = "inline"
    fused = True

    def sweep(self, x_aug, num_servers: int, faults=()):
        self._ensure_open()
        if x_aug.ndim == 2:
            l, u, _ = lu_nserver(x_aug, num_servers, faults=faults)
            return l, u
        return _lu_sweep(x_aug, num_servers=num_servers, faults=faults)

    def factor(self, tasks, faults=()):
        self._ensure_open()
        return _run_relay(tasks, lambda t, wid: EdgeServer(wid).run(t, faults))

    def repair(self, task, *, replacement):
        self._ensure_open()
        return EdgeServer(replacement).run(task)

    def start(self, task, worker_id, *, faults=(), timeout=None):
        """Synchronous start: compute now, return a completed Future.
        Lets the rateless scheduler run against the inline boundary
        (tests, and the degradation ladder's last rung)."""
        self._ensure_open()
        fut: Future = Future()
        try:
            fut.set_result(EdgeServer(worker_id).run(task, faults))
        except Exception as e:  # noqa: BLE001 — future carries it
            fut.set_exception(e)
        return fut


class ShardMapTransport(Transport):
    """distrib.spdc_pipeline as a transport: one mesh device per server,
    the relay a real lax.ppermute (DESIGN.md §2). Fused — the sweep is a
    single SPMD program; repairs recompute host-side in the pipeline's
    operation order ("pipeline" style), exactly as recovery always has.
    """

    name = "shardmap"
    fused = True
    style = "pipeline"

    def __init__(self, program: str = "baseline"):
        self.program = program

    def sweep(self, x_aug, num_servers: int, faults=()):
        self._ensure_open()
        from repro.distrib.spdc_pipeline import lu_nserver_shardmap

        return lu_nserver_shardmap(
            x_aug, num_servers, program=self.program, faults=faults
        )

    def repair(self, task, *, replacement):
        self._ensure_open()
        return EdgeServer(replacement).run(task)


def _run_relay(tasks, execute) -> list[ShardResult]:
    """The one-way relay schedule over single-shot workers: execute task i
    with u_upstream = the U rows servers 0..i−1 reported. `execute(task,
    worker_id)` runs one task on one worker.

    A per-request TransportTimeout is absorbed here as a DROPOUT: the
    straggler's strips are substituted with zeros — byte-for-byte what a
    `kind="dropout"` fault reports — so verification localizes it and
    recovery re-dispatches, identically to the pipeline-rounds deadline
    path (core.faults.resolve_delays). One straggler policy, two clocks.
    """
    tasks = sorted(tasks, key=lambda t: t.server)
    if [t.server for t in tasks] != list(range(len(tasks))):
        raise ValueError(
            f"factor() needs exactly one task per server 0..N-1, got "
            f"{[t.server for t in tasks]}"
        )
    results: list[ShardResult] = []
    u_rows: list[np.ndarray] = []
    for t in tasks:
        if t.server > 0:
            t = t.with_upstream(np.concatenate(u_rows, axis=-2))
        try:
            r = execute(t, t.server)
        except TransportTimeout:
            zero = np.zeros_like(np.asarray(t.x_row))
            r = ShardResult(
                server=t.server, l_row=zero, u_row=zero,
                subseed=t.subseed, attempt=t.attempt,
                session_id=t.session_id,
            )
        results.append(r)
        u_rows.append(np.asarray(r.u_row))
    return results


class ThreadPoolTransport(Transport):
    """EdgeServers on a thread pool: in-memory messages, real scheduler
    boundary, zero serialization cost. The relay is sequential per sweep
    (the one-way chain is a data dependency); concurrency comes from
    independent sessions sharing the pool — and from jitted strip
    programs releasing the GIL while they run."""

    name = "threadpool"

    def __init__(self, max_workers: int | None = None):
        from concurrent.futures import ThreadPoolExecutor

        self._pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="spdc-edge"
        )
        self._edges: dict[int, EdgeServer] = {}  #: guarded-by: self._lock
        self._lock = threading.Lock()

    def _edge(self, worker_id: int) -> EdgeServer:
        with self._lock:
            if worker_id not in self._edges:
                self._edges[worker_id] = EdgeServer(worker_id)
            return self._edges[worker_id]

    def factor(self, tasks, faults=()):
        self._ensure_open()

        def execute(t, wid):
            return self._pool.submit(self._edge(wid).run, t, faults).result()

        return _run_relay(tasks, execute)

    def repair(self, task, *, replacement):
        self._ensure_open()
        return self._pool.submit(self._edge(replacement).run, task).result()

    def start(self, task, worker_id, *, faults=(), timeout=None):
        """Future[ShardResult] on the shared pool. Threads cannot be
        preempted, so `timeout` is advisory here — the rateless scheduler
        enforces its own wait and zombifies a late future (the worker
        slot stays busy until the thread actually returns)."""
        self._ensure_open()
        return self._pool.submit(self._edge(worker_id).run, task, faults)

    def close(self):
        self._pool.shutdown(wait=True)
        super().close()


def _edge_worker_main(conn, worker_id: int, enable_x64: bool) -> None:
    """Entry point of one spawned edge-server process.

    One `serve_frame` reply per received frame keeps the pipe in strict
    lock-step; an empty frame is the shutdown sentinel. Everything in and
    out is the wire codec — no pickle of task data crosses the boundary.
    """
    import jax as _jax

    _jax.config.update("jax_enable_x64", bool(enable_x64))
    from repro.api.server import EdgeServer as _Edge
    from repro.api.transport import serve_frame as _serve

    edge = _Edge(worker_id)
    state: dict = {}
    while True:
        try:
            data = conn.recv_bytes()
        except (EOFError, OSError):
            return
        if not data:
            return
        conn.send_bytes(_serve(edge, state, data))


class MultiprocessTransport(Transport):
    """Spawned worker processes; ShardTask/ShardResult cross as bytes.

    Workers spawn lazily per worker id (first dispatch pays the process +
    jax import + jit cost; a shared instance amortizes it across every
    later sweep) and inherit the parent's x64 setting.

    Request discipline: each pipe is strict lock-step request-reply, so
    each WORKER has its own lock (requests to different workers run
    concurrently — the property the rateless scheduler needs) and every
    request takes a PER-REQUEST wall-clock deadline (`timeout` is only
    the default). A deadline miss kills the worker — its eventual reply
    would desynchronize the pipe — and raises TransportTimeout; a worker
    found dead mid-request (crash, external kill) is respawned and the
    request retried once before TransportWorkerDied surfaces, so a
    session heals across a worker death instead of failing.
    """

    name = "multiprocess"

    def __init__(self, *, timeout: float = 600.0):
        import multiprocessing as mp

        self._ctx = mp.get_context("spawn")
        self._conns: dict[int, object] = {}  #: guarded-by: self._meta
        self._procs: dict[int, object] = {}  #: guarded-by: self._meta
        self._sent_plan: dict[int, tuple] = {}  #: guarded-by: self._meta
        self._locks: dict[int, threading.Lock] = {}
        self._meta = threading.RLock()  # guards the dicts, not the pipes
        self._io = None  # lazy executor behind start()
        self.timeout = float(timeout)

    @property
    def workers(self) -> tuple[int, ...]:
        with self._meta:
            return tuple(sorted(self._procs))

    def _worker_lock(self, worker_id: int) -> threading.Lock:
        with self._meta:
            return self._locks.setdefault(worker_id, threading.Lock())

    def _conn(self, worker_id: int):
        with self._meta:
            conn = self._conns.get(worker_id)
            if conn is not None and self._procs[worker_id].is_alive():
                return conn
            parent, child = self._ctx.Pipe()
            proc = self._ctx.Process(
                target=_edge_worker_main,
                args=(child, worker_id,
                      bool(jax.config.jax_enable_x64)),
                daemon=True,
                name=f"spdc-edge-{worker_id}",
            )
            proc.start()
            child.close()
            self._conns[worker_id] = parent
            self._procs[worker_id] = proc
            self._sent_plan[worker_id] = ()
            return parent

    def _discard(self, worker_id: int) -> None:
        """Forget a worker whose pipe can no longer be trusted (dead, or
        timed out with a reply still owed). The next dispatch respawns
        it lazily with a fresh, in-sync pipe."""
        with self._meta:
            conn = self._conns.pop(worker_id, None)
            proc = self._procs.pop(worker_id, None)
            self._sent_plan.pop(worker_id, None)
        if conn is not None:
            try:
                conn.close()
            except (OSError, ValueError):
                pass
        if proc is not None and proc.is_alive():
            proc.terminate()
            proc.join(timeout=5)

    def _request(self, worker_id: int, frame: bytes,
                 timeout: float | None = None) -> bytes:
        """One lock-step request-reply round trip (raw reply bytes).
        Caller holds the worker's lock. Raises TransportTimeout (worker
        killed) past the deadline, TransportWorkerDied on a dead pipe."""
        deadline = self.timeout if timeout is None else float(timeout)
        conn = self._conn(worker_id)
        try:
            conn.send_bytes(frame)
            if not conn.poll(deadline):
                self._discard(worker_id)
                raise TransportTimeout(
                    f"edge worker {worker_id} exceeded its {deadline}s "
                    "request deadline (killed; respawns on next dispatch)"
                )
            data = conn.recv_bytes()
        except (EOFError, OSError, BrokenPipeError) as e:
            self._discard(worker_id)
            raise TransportWorkerDied(
                f"edge worker {worker_id} died mid-request: {e!r}"
            ) from e
        if data[:4] == b"ERR:":
            raise TransportError(
                f"edge worker {worker_id} failed: {data[4:].decode()}"
            )
        return data

    def _configure_faults(self, worker_id: int, faults,
                          timeout: float | None = None) -> None:
        plan = tuple(faults)
        # _sent_plan is _meta-guarded: close() clears it from another
        # thread. The caller's per-worker lock serializes the
        # check-then-send pair for THIS worker; the pipe round-trip
        # stays outside _meta (never block the fleet on one worker).
        with self._meta:
            if self._sent_plan.get(worker_id) == plan:
                return
        ack = self._request(worker_id, FaultPlanFrame(plan).to_bytes(),
                            timeout)
        if ack != b"ACK":
            raise TransportError(
                f"edge worker {worker_id} mis-acknowledged a fault-plan "
                f"frame: {ack[:32]!r}"
            )
        with self._meta:
            self._sent_plan[worker_id] = plan

    def _run_on(self, task, worker_id: int, faults=(),
                timeout: float | None = None):
        from .wire import decode_message

        def once():
            self._configure_faults(worker_id, faults, timeout)
            # decode by wire kind, not a pinned class: the same pipe
            # carries ShardResult and TriSolveResult replies
            return decode_message(
                self._request(worker_id, task.to_bytes(), timeout)
            )

        with self._worker_lock(worker_id):
            try:
                return once()
            except TransportWorkerDied:
                # the pipe state was discarded, so the retry spawns a
                # fresh worker (and re-sends the fault plan) — one crash
                # costs one respawn, not the session
                return once()

    def factor(self, tasks, faults=()):
        self._ensure_open()
        return _run_relay(tasks, lambda t, wid: self._run_on(t, wid, faults))

    def repair(self, task, *, replacement):
        self._ensure_open()
        return self._run_on(task, replacement)

    def start(self, task, worker_id, *, faults=(), timeout=None):
        """Future[ShardResult]: the blocking request-reply runs on an IO
        thread; the per-worker lock serializes a worker's pipe while
        different workers' requests proceed concurrently. `timeout` is
        REAL here — a deadline miss kills the straggling process."""
        self._ensure_open()
        with self._meta:
            if self._io is None:
                from concurrent.futures import ThreadPoolExecutor

                self._io = ThreadPoolExecutor(
                    max_workers=32, thread_name_prefix="spdc-mp-io"
                )
            io = self._io
        return io.submit(self._run_on, task, worker_id, faults, timeout)

    def close(self):
        # swap state out under _meta, then do the goodbye sends and the
        # (up to 5 s per worker) joins unlocked: a wedged worker must
        # not hold the metadata lock against every other thread
        with self._meta:
            io, self._io = self._io, None
            conns, self._conns = dict(self._conns), {}
            procs, self._procs = dict(self._procs), {}
            self._sent_plan.clear()
            self._locks.clear()
        for conn in conns.values():
            try:
                conn.send_bytes(b"")
                conn.close()
            except (OSError, ValueError):
                pass
        for proc in procs.values():
            proc.join(timeout=5)
            if proc.is_alive():
                proc.terminate()
        if io is not None:
            io.shutdown(wait=False)
        super().close()


def _socket_factory(**kwargs):
    from .socket_transport import SocketTransport

    return SocketTransport(**kwargs)


_FACTORIES = {
    "inline": InlineTransport,
    "shardmap": ShardMapTransport,
    "threadpool": ThreadPoolTransport,
    "multiprocess": MultiprocessTransport,
    "socket": _socket_factory,
}


@dataclass(frozen=True)
class TransportConfig:
    """Declarative transport spec — the third leg of `resolve_transport`.

    Everything that accepts `transport=` (`outsource_determinant{,_mixed}`,
    `SPDCClient`, `SPDCGatewayConfig.spdc`, gateway `submit()` overrides,
    the `serve_spdc`/`serve_worker` CLIs) takes a string name, a live
    `Transport` instance, or one of these — resolved by the ONE
    `resolve_transport()`. Frozen and hashable, so it can ride a gateway
    `BucketKey` and serve as the shared-instance registry key.

    name: "inline" | "shardmap" | "threadpool" | "multiprocess" | "socket".
    addresses: socket only — the worker fleet's endpoints
        ("tcp://host:port" / "unix:///path.sock"), worker_id i connecting
        to addresses[i % len]. Empty = spawn local warm UDS daemons on
        demand.
    timeout: default per-request deadline (multiprocess / socket).
    max_workers: thread pool width (threadpool only).
    program: relay program (shardmap only).

    `build()` returns a FRESH instance the caller owns (and must close —
    SPDCClient and the gateway do this deterministically);
    `resolve_transport(config)` instead returns a process-wide shared
    instance keyed by the config, for one-shot facade calls.
    """

    name: str
    addresses: tuple[str, ...] = ()
    timeout: float | None = None
    max_workers: int | None = None
    program: str | None = None

    def __post_init__(self):
        if self.name not in _FACTORIES:
            raise ValueError(
                f"unknown transport {self.name!r}; expected one of "
                f"{sorted(_FACTORIES)}"
            )
        # tolerate list input without breaking hashability
        object.__setattr__(self, "addresses", tuple(self.addresses))
        if self.addresses and self.name != "socket":
            raise ValueError("addresses= applies to the socket transport")
        if self.max_workers is not None and self.name != "threadpool":
            raise ValueError("max_workers= applies to threadpool")
        if self.program is not None and self.name != "shardmap":
            raise ValueError("program= applies to shardmap")
        if self.timeout is not None and self.name not in (
            "multiprocess", "socket",
        ):
            raise ValueError(
                "timeout= applies to the message transports "
                "(multiprocess, socket)"
            )

    def build(self) -> Transport:
        """Instantiate a FRESH transport the caller owns."""
        kwargs: dict = {}
        if self.name == "socket":
            if self.addresses:
                kwargs["addresses"] = self.addresses
            if self.timeout is not None:
                kwargs["timeout"] = self.timeout
        elif self.name == "multiprocess" and self.timeout is not None:
            kwargs["timeout"] = self.timeout
        elif self.name == "threadpool" and self.max_workers is not None:
            kwargs["max_workers"] = self.max_workers
        elif self.name == "shardmap" and self.program is not None:
            kwargs["program"] = self.program
        return _FACTORIES[self.name](**kwargs)


_SHARED: dict[object, Transport] = {}
_SHARED_LOCK = threading.Lock()


def resolve_transport(spec=None, *, distributed: bool = False) -> Transport:
    """THE transport resolver — every `transport=` kwarg in the package
    funnels here. Accepts:

      * None          → inline (or shardmap when the legacy
        `distributed=True` flag is set);
      * a name string from {"inline", "shardmap", "threadpool",
        "multiprocess", "socket"} → the process-wide shared instance;
      * a `TransportConfig` → a process-wide shared instance keyed by the
        config (equal configs share one warm pool; `config.build()` is
        the fresh-instance escape hatch role objects use);
      * a `Transport` instance → returned as-is (caller-owned).

    Shared instances that were individually closed are rebuilt on the
    next resolve; `close_all()` (atexit) closes the whole registry.
    """
    if isinstance(spec, Transport):
        if distributed and spec.name != "shardmap":
            raise ValueError(
                "distributed=True conflicts with an explicit non-shardmap "
                f"transport ({spec.name!r}); drop one of the two"
            )
        return spec
    if spec is None:
        spec = "shardmap" if distributed else "inline"
    elif distributed and getattr(spec, "name", spec) != "shardmap":
        raise ValueError(
            f"distributed=True conflicts with transport={spec!r}; "
            "pass transport='shardmap' (or drop distributed)"
        )
    if isinstance(spec, TransportConfig):
        with _SHARED_LOCK:
            inst = _SHARED.get(spec)
            if inst is None or inst.closed:
                _SHARED[spec] = inst = spec.build()
            return inst
    if spec not in _FACTORIES:
        raise ValueError(
            f"unknown transport {spec!r}; expected one of "
            f"{sorted(_FACTORIES)}, a TransportConfig, or a Transport "
            "instance"
        )
    with _SHARED_LOCK:
        inst = _SHARED.get(spec)
        if inst is None or inst.closed:
            _SHARED[spec] = inst = _FACTORIES[spec]()
        return inst


def close_all() -> None:
    """Close every shared transport (atexit; tests may call it)."""
    with _SHARED_LOCK:
        for t in _SHARED.values():
            t.close()
        _SHARED.clear()


atexit.register(close_all)
