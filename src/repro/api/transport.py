"""Transports — how ShardTasks reach edge servers and results come back.

A Transport is the pluggable boundary between the SPDC client role and
the N untrusted workers. All transports execute the SAME protocol
messages; they differ in what the wire physically is:

  * ``InlineTransport``      — client and servers share one process and
    the "wire" is elided: the fused, jitted single-sweep fast path of the
    pre-split protocol (bit-identical to it, and the gateway's
    throughput path). ShardTasks still exist (`Session.tasks()`), the
    fused path just never materializes them.
  * ``ShardMapTransport``    — the distrib.spdc_pipeline shard_map
    program: one JAX mesh device per server, the relay a real
    `lax.ppermute`. Fused like inline (the sweep is one SPMD program).
  * ``ThreadPoolTransport``  — one EdgeServer object per worker slot,
    tasks executed on a thread pool, the relay threaded between them as
    in-memory messages. The cheapest transport with a real
    scheduler-visible boundary.
  * ``MultiprocessTransport``— spawned worker PROCESSES; every message
    crosses the boundary as `to_bytes()` frames over an OS pipe and is
    decoded with `from_bytes()` on the far side. This is the transport
    the wire format exists for: nothing but bytes connects client and
    server, so whatever the codec does not carry, the server provably
    does not have.

One-way model: for the sequential (message) transports the relay is run
by the transport — task i executes only after i−1's result, and its
``u_upstream`` is exactly the U rows servers 0..i−1 reported, i.e. the
content of the paper's single S_{i-1} → S_i send. No server ever
receives anything from downstream, and the client never ships plaintext
or key material (messages.ShardTask).

Fault simulation: ``factor(tasks, faults=plan)`` plays core.faults
misbehavior on the matching workers (a FaultPlanFrame control message on
the multiprocess transport). Faults bind to initial dispatches; repairs
run honestly on replacement workers (api.server docstring).

Process-wide shared instances (`resolve_transport("threadpool")`, …) are
cached so repeated protocol calls — and every gateway flush — reuse one
warm pool instead of respawning workers per call; `close_all()` runs at
interpreter exit.
"""
from __future__ import annotations

import atexit
import threading
from functools import partial

import jax
import numpy as np

from repro.core.lu import lu_nserver

from .messages import FaultPlanFrame, ShardResult, ShardTask
from .server import EdgeServer

__all__ = [
    "Transport",
    "TransportError",
    "TransportTimeout",
    "TransportWorkerDied",
    "InlineTransport",
    "ShardMapTransport",
    "ThreadPoolTransport",
    "MultiprocessTransport",
    "resolve_transport",
    "close_all",
]


class TransportError(RuntimeError):
    """A worker died, timed out, or replied with a malformed frame."""


class TransportTimeout(TransportError):
    """A per-request wall-clock deadline expired before the worker
    replied. On the multiprocess transport the worker is killed (a reply
    arriving after the deadline would desynchronize the lock-step pipe)
    and respawned lazily on the next dispatch; the caller treats the
    request as a dropout — zero strips, localize, re-dispatch — exactly
    the rounds-deadline straggler policy (core.faults.resolve_delays)."""


class TransportWorkerDied(TransportError):
    """The worker process/thread went away mid-request (crash, kill,
    broken pipe). Unlike a timeout the worker did not merely straggle —
    transports respawn it and retry the request once before surfacing
    the error; the fleet-health layer counts it as a failure either way."""


@partial(jax.jit, static_argnames=("num_servers", "faults"))
def _lu_sweep(x_aug, *, num_servers, faults=()):
    """Jitted fused sweep for (B, n', n') stacks — ONE device program per
    (shape, N, fault-plan), the throughput lever the inline transport
    exists to keep (DESIGN.md §3)."""
    l, u, _ = lu_nserver(x_aug, num_servers, faults=faults)
    return l, u


class Transport:
    """Base transport: the message-executing interface.

    fused: True when `sweep()` runs the whole factorization as one fused
        program and `Session` should skip task materialization.
    style: the core.lu.lu_block_row operation order this transport's
        factors follow — what repair recomputes must replay.
    """

    name = "abstract"
    fused = False
    style = "nserver"

    def factor(self, tasks, faults=()) -> list[ShardResult]:
        """Run one session's initial ShardTasks (the full sweep)."""
        raise NotImplementedError

    def repair(self, task: ShardTask, *, replacement: int) -> ShardResult:
        """Run one verification-driven re-dispatch on `replacement`."""
        raise NotImplementedError

    def submit(self, task: ShardTask, worker_id: int, *, faults=(),
               timeout: float | None = None):
        """Async single-task dispatch → `concurrent.futures.Future`
        resolving to a ShardResult (or raising a TransportError). The
        rateless scheduler's surface: it streams tasks to whichever
        workers are free instead of walking the fixed relay order.
        `timeout` bounds the request where the transport can enforce one
        (multiprocess kills the worker); where it cannot (a thread has no
        preemption), the caller enforces its own wait and the late future
        becomes a zombie — discarded on arrival, the worker busy until it
        really returns. Fused transports don't have per-task workers;
        they raise."""
        raise NotImplementedError(
            f"transport {self.name!r} has no per-task submit surface "
            "(fused transports run the sweep as one program)"
        )

    def close(self) -> None:  # noqa: B027 — optional hook
        """Release workers/pools; shared instances are closed at exit."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class InlineTransport(Transport):
    """Degenerate (single-process) transport: today's jitted fast path.

    `sweep()` IS the pre-split protocol's server stage — eager lu_nserver
    for one matrix (bit-matching the recovery recompute), one jitted
    program for a stack — so results are bit-identical to the monolithic
    `outsource_determinant` this API replaced. The message methods exist
    for uniformity (tests drive them); the Session prefers `sweep()`.
    """

    name = "inline"
    fused = True

    def sweep(self, x_aug, num_servers: int, faults=()):
        if x_aug.ndim == 2:
            l, u, _ = lu_nserver(x_aug, num_servers, faults=faults)
            return l, u
        return _lu_sweep(x_aug, num_servers=num_servers, faults=faults)

    def factor(self, tasks, faults=()):
        return _run_relay(tasks, lambda t, wid: EdgeServer(wid).run(t, faults))

    def repair(self, task, *, replacement):
        return EdgeServer(replacement).run(task)

    def submit(self, task, worker_id, *, faults=(), timeout=None):
        """Synchronous submit: compute now, return a completed Future.
        Lets the rateless scheduler run against the inline boundary
        (tests, and the degradation ladder's last rung)."""
        from concurrent.futures import Future

        fut: Future = Future()
        try:
            fut.set_result(EdgeServer(worker_id).run(task, faults))
        except Exception as e:  # noqa: BLE001 — future carries it
            fut.set_exception(e)
        return fut


class ShardMapTransport(Transport):
    """distrib.spdc_pipeline as a transport: one mesh device per server,
    the relay a real lax.ppermute (DESIGN.md §2). Fused — the sweep is a
    single SPMD program; repairs recompute host-side in the pipeline's
    operation order ("pipeline" style), exactly as recovery always has.
    """

    name = "shardmap"
    fused = True
    style = "pipeline"

    def __init__(self, program: str = "baseline"):
        self.program = program

    def sweep(self, x_aug, num_servers: int, faults=()):
        from repro.distrib.spdc_pipeline import lu_nserver_shardmap

        return lu_nserver_shardmap(
            x_aug, num_servers, program=self.program, faults=faults
        )

    def repair(self, task, *, replacement):
        return EdgeServer(replacement).run(task)


def _run_relay(tasks, execute) -> list[ShardResult]:
    """The one-way relay schedule over single-shot workers: execute task i
    with u_upstream = the U rows servers 0..i−1 reported. `execute(task,
    worker_id)` runs one task on one worker.

    A per-request TransportTimeout is absorbed here as a DROPOUT: the
    straggler's strips are substituted with zeros — byte-for-byte what a
    `kind="dropout"` fault reports — so verification localizes it and
    recovery re-dispatches, identically to the pipeline-rounds deadline
    path (core.faults.resolve_delays). One straggler policy, two clocks.
    """
    tasks = sorted(tasks, key=lambda t: t.server)
    if [t.server for t in tasks] != list(range(len(tasks))):
        raise ValueError(
            f"factor() needs exactly one task per server 0..N-1, got "
            f"{[t.server for t in tasks]}"
        )
    results: list[ShardResult] = []
    u_rows: list[np.ndarray] = []
    for t in tasks:
        if t.server > 0:
            t = t.with_upstream(np.concatenate(u_rows, axis=-2))
        try:
            r = execute(t, t.server)
        except TransportTimeout:
            zero = np.zeros_like(np.asarray(t.x_row))
            r = ShardResult(
                server=t.server, l_row=zero, u_row=zero,
                subseed=t.subseed, attempt=t.attempt,
                session_id=t.session_id,
            )
        results.append(r)
        u_rows.append(np.asarray(r.u_row))
    return results


class ThreadPoolTransport(Transport):
    """EdgeServers on a thread pool: in-memory messages, real scheduler
    boundary, zero serialization cost. The relay is sequential per sweep
    (the one-way chain is a data dependency); concurrency comes from
    independent sessions sharing the pool — and from jitted strip
    programs releasing the GIL while they run."""

    name = "threadpool"

    def __init__(self, max_workers: int | None = None):
        from concurrent.futures import ThreadPoolExecutor

        self._pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="spdc-edge"
        )
        self._edges: dict[int, EdgeServer] = {}
        self._lock = threading.Lock()

    def _edge(self, worker_id: int) -> EdgeServer:
        with self._lock:
            if worker_id not in self._edges:
                self._edges[worker_id] = EdgeServer(worker_id)
            return self._edges[worker_id]

    def factor(self, tasks, faults=()):
        def execute(t, wid):
            return self._pool.submit(self._edge(wid).run, t, faults).result()

        return _run_relay(tasks, execute)

    def repair(self, task, *, replacement):
        return self._pool.submit(self._edge(replacement).run, task).result()

    def submit(self, task, worker_id, *, faults=(), timeout=None):
        """Future[ShardResult] on the shared pool. Threads cannot be
        preempted, so `timeout` is advisory here — the rateless scheduler
        enforces its own wait and zombifies a late future (the worker
        slot stays busy until the thread actually returns)."""
        return self._pool.submit(self._edge(worker_id).run, task, faults)

    def close(self):
        self._pool.shutdown(wait=True)


def _edge_worker_main(conn, worker_id: int, enable_x64: bool) -> None:
    """Entry point of one spawned edge-server process.

    Strict request-reply: EVERY frame gets exactly one reply — ShardTask
    → ShardResult bytes, FaultPlanFrame → b"ACK", anything that fails
    (including a frame that does not decode) → an ERR frame. One reply
    per request keeps the pipe in lock-step, so a failure can never
    desynchronize later requests' replies; an empty frame is the
    shutdown sentinel. Everything in and out is the wire codec — no
    pickle of task data crosses the boundary.
    """
    import jax as _jax

    _jax.config.update("jax_enable_x64", bool(enable_x64))
    from repro.api.messages import FaultPlanFrame as _FPF
    from repro.api.server import EdgeServer as _Edge
    from repro.api.wire import decode_message as _decode

    edge = _Edge(worker_id)
    plan = ()
    while True:
        try:
            data = conn.recv_bytes()
        except (EOFError, OSError):
            return
        if not data:
            return
        try:  # noqa: SIM105 — report every failure, don't die silently
            msg = _decode(data)
            if isinstance(msg, _FPF):
                plan = msg.plan
                reply = b"ACK"
            else:
                reply = edge.run(msg, faults=plan).to_bytes()
        except Exception as e:  # noqa: BLE001
            reply = b"ERR:" + repr(e).encode()
        conn.send_bytes(reply)


class MultiprocessTransport(Transport):
    """Spawned worker processes; ShardTask/ShardResult cross as bytes.

    Workers spawn lazily per worker id (first dispatch pays the process +
    jax import + jit cost; a shared instance amortizes it across every
    later sweep) and inherit the parent's x64 setting.

    Request discipline: each pipe is strict lock-step request-reply, so
    each WORKER has its own lock (requests to different workers run
    concurrently — the property the rateless scheduler needs) and every
    request takes a PER-REQUEST wall-clock deadline (`timeout` is only
    the default). A deadline miss kills the worker — its eventual reply
    would desynchronize the pipe — and raises TransportTimeout; a worker
    found dead mid-request (crash, external kill) is respawned and the
    request retried once before TransportWorkerDied surfaces, so a
    session heals across a worker death instead of failing.
    """

    name = "multiprocess"

    def __init__(self, *, timeout: float = 600.0):
        import multiprocessing as mp

        self._ctx = mp.get_context("spawn")
        self._conns: dict[int, object] = {}
        self._procs: dict[int, object] = {}
        self._sent_plan: dict[int, tuple] = {}
        self._locks: dict[int, threading.Lock] = {}
        self._meta = threading.RLock()  # guards the dicts, not the pipes
        self._io = None  # lazy executor behind submit()
        self.timeout = float(timeout)

    @property
    def workers(self) -> tuple[int, ...]:
        with self._meta:
            return tuple(sorted(self._procs))

    def _worker_lock(self, worker_id: int) -> threading.Lock:
        with self._meta:
            return self._locks.setdefault(worker_id, threading.Lock())

    def _conn(self, worker_id: int):
        with self._meta:
            conn = self._conns.get(worker_id)
            if conn is not None and self._procs[worker_id].is_alive():
                return conn
            parent, child = self._ctx.Pipe()
            proc = self._ctx.Process(
                target=_edge_worker_main,
                args=(child, worker_id,
                      bool(jax.config.jax_enable_x64)),
                daemon=True,
                name=f"spdc-edge-{worker_id}",
            )
            proc.start()
            child.close()
            self._conns[worker_id] = parent
            self._procs[worker_id] = proc
            self._sent_plan[worker_id] = ()
            return parent

    def _discard(self, worker_id: int) -> None:
        """Forget a worker whose pipe can no longer be trusted (dead, or
        timed out with a reply still owed). The next dispatch respawns
        it lazily with a fresh, in-sync pipe."""
        with self._meta:
            conn = self._conns.pop(worker_id, None)
            proc = self._procs.pop(worker_id, None)
            self._sent_plan.pop(worker_id, None)
        if conn is not None:
            try:
                conn.close()
            except (OSError, ValueError):
                pass
        if proc is not None and proc.is_alive():
            proc.terminate()
            proc.join(timeout=5)

    def _request(self, worker_id: int, frame: bytes,
                 timeout: float | None = None) -> bytes:
        """One lock-step request-reply round trip (raw reply bytes).
        Caller holds the worker's lock. Raises TransportTimeout (worker
        killed) past the deadline, TransportWorkerDied on a dead pipe."""
        deadline = self.timeout if timeout is None else float(timeout)
        conn = self._conn(worker_id)
        try:
            conn.send_bytes(frame)
            if not conn.poll(deadline):
                self._discard(worker_id)
                raise TransportTimeout(
                    f"edge worker {worker_id} exceeded its {deadline}s "
                    "request deadline (killed; respawns on next dispatch)"
                )
            data = conn.recv_bytes()
        except (EOFError, OSError, BrokenPipeError) as e:
            self._discard(worker_id)
            raise TransportWorkerDied(
                f"edge worker {worker_id} died mid-request: {e!r}"
            ) from e
        if data[:4] == b"ERR:":
            raise TransportError(
                f"edge worker {worker_id} failed: {data[4:].decode()}"
            )
        return data

    def _configure_faults(self, worker_id: int, faults,
                          timeout: float | None = None) -> None:
        plan = tuple(faults)
        if self._sent_plan.get(worker_id) == plan:
            return
        ack = self._request(worker_id, FaultPlanFrame(plan).to_bytes(),
                            timeout)
        if ack != b"ACK":
            raise TransportError(
                f"edge worker {worker_id} mis-acknowledged a fault-plan "
                f"frame: {ack[:32]!r}"
            )
        self._sent_plan[worker_id] = plan

    def _run_on(self, task: ShardTask, worker_id: int, faults=(),
                timeout: float | None = None):
        def once():
            self._configure_faults(worker_id, faults, timeout)
            return ShardResult.from_bytes(
                self._request(worker_id, task.to_bytes(), timeout)
            )

        with self._worker_lock(worker_id):
            try:
                return once()
            except TransportWorkerDied:
                # the pipe state was discarded, so the retry spawns a
                # fresh worker (and re-sends the fault plan) — one crash
                # costs one respawn, not the session
                return once()

    def factor(self, tasks, faults=()):
        return _run_relay(tasks, lambda t, wid: self._run_on(t, wid, faults))

    def repair(self, task, *, replacement):
        return self._run_on(task, replacement)

    def submit(self, task, worker_id, *, faults=(), timeout=None):
        """Future[ShardResult]: the blocking request-reply runs on an IO
        thread; the per-worker lock serializes a worker's pipe while
        different workers' requests proceed concurrently. `timeout` is
        REAL here — a deadline miss kills the straggling process."""
        with self._meta:
            if self._io is None:
                from concurrent.futures import ThreadPoolExecutor

                self._io = ThreadPoolExecutor(
                    max_workers=32, thread_name_prefix="spdc-mp-io"
                )
            io = self._io
        return io.submit(self._run_on, task, worker_id, faults, timeout)

    def close(self):
        with self._meta:
            io, self._io = self._io, None
            for conn in self._conns.values():
                try:
                    conn.send_bytes(b"")
                    conn.close()
                except (OSError, ValueError):
                    pass
            for proc in self._procs.values():
                proc.join(timeout=5)
                if proc.is_alive():
                    proc.terminate()
            self._conns.clear()
            self._procs.clear()
            self._sent_plan.clear()
            self._locks.clear()
        if io is not None:
            io.shutdown(wait=False)


_SHARED: dict[str, Transport] = {}
_SHARED_LOCK = threading.Lock()

_FACTORIES = {
    "inline": InlineTransport,
    "shardmap": ShardMapTransport,
    "threadpool": ThreadPoolTransport,
    "multiprocess": MultiprocessTransport,
}


def resolve_transport(spec=None, *, distributed: bool = False) -> Transport:
    """Resolve a transport spec: None (→ inline, or shardmap when the
    legacy `distributed=True` flag is set), a name from
    {"inline", "shardmap", "threadpool", "multiprocess"} (→ the shared
    process-wide instance), or a Transport object (returned as-is)."""
    if isinstance(spec, Transport):
        if distributed and spec.name != "shardmap":
            raise ValueError(
                "distributed=True conflicts with an explicit non-shardmap "
                f"transport ({spec.name!r}); drop one of the two"
            )
        return spec
    if spec is None:
        spec = "shardmap" if distributed else "inline"
    elif distributed and spec != "shardmap":
        raise ValueError(
            f"distributed=True conflicts with transport={spec!r}; "
            "pass transport='shardmap' (or drop distributed)"
        )
    if spec not in _FACTORIES:
        raise ValueError(
            f"unknown transport {spec!r}; expected one of "
            f"{sorted(_FACTORIES)} or a Transport instance"
        )
    with _SHARED_LOCK:
        if spec not in _SHARED:
            _SHARED[spec] = _FACTORIES[spec]()
        return _SHARED[spec]


def close_all() -> None:
    """Close every shared transport (atexit; tests may call it)."""
    with _SHARED_LOCK:
        for t in _SHARED.values():
            t.close()
        _SHARED.clear()


atexit.register(close_all)
