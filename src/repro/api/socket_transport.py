"""SocketTransport — the SPDC trust boundary over real sockets.

This is the networked realization of the role-split API (DESIGN.md §9):
edge workers are PERSISTENT DAEMONS (`repro.launch.serve_worker`, or the
in-library `WorkerDaemon`) reached over TCP or Unix-domain sockets, and
the client holds a connection pool to them. Where MultiprocessTransport
pays a process spawn + jax import + jit trace per client process, a
socket daemon pays them ONCE: its jit caches stay warm across sessions,
across client restarts, and across every client that connects — the
deployment shape the paper's edge-server fleet actually has.

Framing (one frame = one protocol message):

    ┌───────────────┬───────────────────────────────┐
    │ length  u32 BE│ payload — a wire.py codec frame│
    └───────────────┴───────────────────────────────┘

  * a ZERO length is the goodbye sentinel (polite close);
  * a length above ``MAX_FRAME`` (1 GiB) is an oversized prefix —
    the reader refuses to allocate and drops the connection with
    ``TransportProtocolError`` (a malicious peer cannot OOM the client
    by lying about length);
  * a peer that closes mid-frame produced a truncated frame — also
    ``TransportProtocolError``. Protocol violations are never retried:
    a peer speaking the wrong protocol will speak it again.

Handshake: the first frame each way is a HELLO (wire-codec kind
``"Hello"``) carrying the socket-protocol version ``SOCKET_PROTO``, the
wire-codec version, the speaker's role, the worker id the client wants,
the id set the daemon serves, and capability strings. Either side that
sees an incompatible version or role drops the connection; the daemon
additionally answers ``accept=False`` before closing so the client gets
a typed error instead of a silent EOF. The daemon's HELLO also reports
its lifetime ``connections``/``frames_served`` counters — how tests (and
operators) observe that a warm daemon, not a fresh spawn, served them.

Request discipline mirrors the multiprocess pipe: strict lock-step
request-reply per connection (ShardTask → ShardResult frame,
FaultPlanFrame → b"ACK", failures → b"ERR:..."), one connection per
worker id on the client, a per-worker lock so different workers'
requests overlap while one worker's connection stays in lock-step. A
request deadline kills the CONNECTION (the daemon and its warm caches
survive; the late reply dies with the socket) and raises
TransportTimeout; a dead connection raises TransportWorkerDied and the
request is retried once over a fresh connection before the error
surfaces. Reconnects ride the SAME FleetHealth machinery the rateless
scheduler uses (distrib.rateless): every failed connect is an
``observe_failure`` — exponential backoff with deterministic jitter —
and the pool won't hammer a dead endpoint any harder than the scheduler
would dispatch to it.

Addressing: ``addresses`` lists the fleet's endpoints
(``"tcp://host:port"`` or ``"unix:///path.sock"``); worker i connects to
``addresses[i % len(addresses)]``, so verification-driven replacement
ids N, N+1, … (recovery standbys) wrap onto the same physical fleet.
With NO addresses the transport self-hosts: it spawns one local warm
UDS daemon per worker id on demand (and respawns it if it dies), which
is what makes the bare string ``"socket"`` meaningful everywhere a
``transport=`` kwarg is accepted.
"""
from __future__ import annotations

import os
import shutil
import socket
import struct
import tempfile
import threading
import time

import jax

from . import wire
from .messages import FaultPlanFrame, ShardResult
from .server import EdgeServer
from .transport import (
    Transport,
    TransportError,
    TransportProtocolError,
    TransportTimeout,
    TransportWorkerDied,
    _run_relay,
    serve_frame,
)

__all__ = [
    "SocketTransport",
    "WorkerDaemon",
    "SOCKET_PROTO",
    "MAX_FRAME",
    "parse_address",
    "send_frame",
    "recv_frame",
]

#: socket-protocol version spoken in HELLO; bumped when the framing or
#: handshake changes incompatibly (independent of wire.VERSION, which
#: versions the payload codec).
SOCKET_PROTO = 1

#: refuse to allocate a frame larger than this — an attacker-controlled
#: length prefix must not be able to OOM the reader.
MAX_FRAME = 1 << 30

#: capabilities advertised by this implementation's daemons.
CAPS = ("faultplan", "rateless")

_HELLO_KIND = "Hello"


# -- framing primitives ------------------------------------------------------


def parse_address(addr: str) -> tuple[str, object]:
    """``"unix:///path.sock"`` → ("unix", path); ``"tcp://host:port"`` →
    ("tcp", (host, port))."""
    if addr.startswith("unix://"):
        path = addr[len("unix://"):]
        if not path:
            raise ValueError(f"empty unix socket path in {addr!r}")
        return "unix", path
    if addr.startswith("tcp://"):
        host, sep, port = addr[len("tcp://"):].rpartition(":")
        if not sep or not host:
            raise ValueError(f"tcp address needs host:port, got {addr!r}")
        return "tcp", (host, int(port))
    raise ValueError(
        f"unsupported address {addr!r}; use tcp://host:port or "
        "unix:///path.sock"
    )


def send_frame(sock: socket.socket, payload: bytes) -> None:
    """One length-prefixed frame; ``b""`` sends the goodbye sentinel."""
    sock.sendall(struct.pack(">I", len(payload)) + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes | None:
    """Exactly n bytes, or None on EOF at a frame boundary (no bytes
    read). EOF MID-read is a truncated frame → TransportProtocolError."""
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if not buf:
                return None
            raise TransportProtocolError(
                f"truncated frame: peer closed after {len(buf)}/{n} bytes"
            )
        buf += chunk
    return bytes(buf)


def recv_frame(sock: socket.socket, max_frame: int = MAX_FRAME
               ) -> bytes | None:
    """One frame's payload; ``b""`` for the goodbye sentinel, None for a
    clean EOF (peer closed between frames). Raises
    TransportProtocolError on a truncated frame or an oversized length
    prefix — the reader never allocates more than `max_frame`."""
    head = _recv_exact(sock, 4)
    if head is None:
        return None
    (length,) = struct.unpack(">I", head)
    if length == 0:
        return b""
    if length > max_frame:
        raise TransportProtocolError(
            f"oversized length prefix: peer claims a {length}-byte frame "
            f"(cap {max_frame}); refusing to allocate"
        )
    body = _recv_exact(sock, length)
    if body is None:
        raise TransportProtocolError(
            f"truncated frame: peer closed before its {length}-byte payload"
        )
    return body


# -- HELLO handshake ---------------------------------------------------------


def _hello_frame(**fields) -> bytes:
    return wire.encode(_HELLO_KIND, fields, {})


def _parse_hello(data: bytes) -> dict:
    try:
        kind, scalars, _ = wire.decode(data)
    except wire.WireError as e:
        raise TransportProtocolError(f"bad HELLO frame: {e}") from e
    if kind != _HELLO_KIND:
        raise TransportProtocolError(
            f"handshake violation: expected a HELLO frame, got {kind!r}"
        )
    return scalars


def _check_server_hello(hello: dict, worker_id: int, addr: str) -> None:
    proto, wirev = hello.get("proto"), hello.get("wire")
    if proto != SOCKET_PROTO or wirev != wire.VERSION:
        raise TransportProtocolError(
            f"version mismatch at {addr}: daemon speaks socket-proto "
            f"{proto}/wire {wirev}, client speaks {SOCKET_PROTO}/"
            f"{wire.VERSION}"
        )
    if hello.get("role") != "worker":
        raise TransportProtocolError(
            f"peer at {addr} is not a worker daemon "
            f"(role={hello.get('role')!r})"
        )
    if not hello.get("accept", False):
        raise TransportProtocolError(
            f"daemon at {addr} refused worker id {worker_id} "
            f"(serves {hello.get('served')})"
        )


# -- worker daemon -----------------------------------------------------------


class WorkerDaemon:
    """One warm edge-worker daemon: a listener + a thread per client
    connection, all sharing this process's EdgeServers (and therefore
    its jit caches — the warmth the transport exists for).

    `workers=None` serves ANY requested worker id (one daemon = whole
    fleet, connections for different ids run concurrently on their own
    threads); a tuple restricts the served set and the HELLO advertises
    it. Per-CONNECTION fault-plan state keeps one client's simulated
    fault plan from leaking into another client's session.
    """

    def __init__(self, bind: str, workers=None):
        self.bind = bind
        self.workers = None if workers is None else tuple(workers)
        self.address: str | None = None  # actual (ephemeral ports resolved)
        self._family, self._target = parse_address(bind)
        self._edges: dict[int, EdgeServer] = {}  #: guarded-by: self._lock
        self._lock = threading.Lock()
        self._listener: socket.socket | None = None
        self._accept_thread: threading.Thread | None = None
        #: live connections
        #: guarded-by: self._lock
        self._open: set[socket.socket] = set()
        self._stop = threading.Event()
        #: lifetime accepted connections
        self.connections = 0  #: guarded-by: self._lock
        #: lifetime request frames answered
        self.frames_served = 0  #: guarded-by: self._lock

    def start(self) -> str:
        """Bind + listen + spawn the accept loop; returns the actual
        address (ephemeral tcp ports resolved)."""
        if self._family == "unix":
            if os.path.exists(self._target):
                os.unlink(self._target)
            sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            sock.bind(self._target)
            self.address = f"unix://{self._target}"
        else:
            host, port = self._target
            sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            sock.bind((host, port))
            self.address = f"tcp://{host}:{sock.getsockname()[1]}"
        sock.listen(32)
        self._listener = sock
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="spdc-sockd-accept", daemon=True
        )
        self._accept_thread.start()
        return self.address

    def serve_forever(self) -> None:
        if self._listener is None:
            self.start()
        self._stop.wait()

    def _edge(self, worker_id: int) -> EdgeServer:
        with self._lock:
            if worker_id not in self._edges:
                self._edges[worker_id] = EdgeServer(worker_id)
            return self._edges[worker_id]

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return  # listener closed
            threading.Thread(
                target=self._handle, args=(conn,),
                name="spdc-sockd-conn", daemon=True,
            ).start()

    def _handle(self, sock: socket.socket) -> None:
        with self._lock:
            self._open.add(sock)
        try:
            self._serve_connection(sock)
        finally:
            with self._lock:
                self._open.discard(sock)

    def _serve_connection(self, sock: socket.socket) -> None:
        with sock:
            try:
                data = recv_frame(sock)
            except (TransportProtocolError, OSError):
                return  # garbage before HELLO: drop silently
            if not data:
                return
            try:
                hello = _parse_hello(data)
            except TransportProtocolError:
                return
            wid = hello.get("worker_id")
            ok = (
                hello.get("proto") == SOCKET_PROTO
                and hello.get("wire") == wire.VERSION
                and hello.get("role") == "client"
                and isinstance(wid, int)
                and (self.workers is None or wid in self.workers)
            )
            with self._lock:
                self.connections += 1
                conns, frames = self.connections, self.frames_served
            try:
                send_frame(sock, _hello_frame(
                    proto=SOCKET_PROTO,
                    wire=wire.VERSION,
                    role="worker",
                    worker_id=wid if isinstance(wid, int) else -1,
                    served=None if self.workers is None
                    else list(self.workers),
                    caps=list(CAPS),
                    accept=ok,
                    connections=conns,
                    frames_served=frames,
                ))
            except OSError:
                return
            if not ok:
                return
            edge = self._edge(wid)
            state: dict = {}  # per-connection fault plan
            while not self._stop.is_set():
                try:
                    data = recv_frame(sock)
                except (TransportProtocolError, OSError):
                    return
                if not data:
                    return  # goodbye or clean EOF
                reply = serve_frame(edge, state, data)
                with self._lock:
                    self.frames_served += 1
                try:
                    send_frame(sock, reply)
                except OSError:
                    return

    def close(self) -> None:
        self._stop.set()
        if self._listener is not None:
            # shutdown() first: a thread blocked in accept() is NOT woken
            # by close() alone on Linux — shutting the listening socket
            # down makes the pending accept raise, so the loop exits
            # instead of leaking a blocked thread per daemon
            try:
                self._listener.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                self._listener.close()
            except OSError:
                pass
        # genuinely disconnect live clients: shutdown() wakes handler
        # threads blocked in recv (closing the fd alone would not)
        with self._lock:
            conns = list(self._open)
        for s in conns:
            try:
                s.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)
            self._accept_thread = None
        if self._family == "unix" and os.path.exists(self._target):
            try:
                os.unlink(self._target)
            except OSError:
                pass

    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc):
        self.close()


def _daemon_main(bind: str, workers, enable_x64: bool) -> None:
    """Entry point of an auto-spawned local daemon process."""
    import jax as _jax

    _jax.config.update("jax_enable_x64", bool(enable_x64))
    from repro.api.socket_transport import WorkerDaemon as _Daemon

    _Daemon(bind, workers).serve_forever()


# -- client transport --------------------------------------------------------


class SocketTransport(Transport):
    """Connection pool to a fleet of warm worker daemons (module doc).

    addresses: daemon endpoints; worker i → addresses[i % len]. Empty →
        self-host local UDS daemons per worker id on demand.
    timeout: default per-request deadline; a miss drops the CONNECTION
        (the daemon survives) and raises TransportTimeout.
    connect_timeout: total budget for one connect-with-backoff cycle,
        handshake included.
    """

    name = "socket"

    def __init__(self, addresses=(), *, timeout: float = 600.0,
                 connect_timeout: float = 10.0):
        # lazy import: distrib.rateless imports repro.api.transport, so a
        # module-level import here would cycle through the package
        from repro.distrib.rateless import FleetHealth

        self.addresses = tuple(addresses)
        self.timeout = float(timeout)
        self.connect_timeout = float(connect_timeout)
        self.health = FleetHealth()  # reconnect/backoff bookkeeping
        self._socks: dict[int, socket.socket] = {}  #: guarded-by: self._meta
        self._hellos: dict[int, dict] = {}  #: guarded-by: self._meta
        self._sent_plan: dict[int, tuple | None] = {}  #: guarded-by: self._meta
        self._locks: dict[int, threading.Lock] = {}
        self._meta = threading.RLock()
        self._io = None  # lazy executor behind start()
        #: wid -> (proc, uds path)
        #: guarded-by: self._meta
        self._spawned: dict[int, tuple] = {}
        self._tmpdir: str | None = None
        self._ctx = None

    @property
    def workers(self) -> tuple[int, ...]:
        with self._meta:
            return tuple(sorted(self._socks))

    def hello(self, worker_id: int) -> dict | None:
        """The daemon's HELLO for this worker's current connection —
        `connections`/`frames_served` counters expose daemon warmth."""
        with self._meta:
            return self._hellos.get(worker_id)

    # -- addressing / self-hosting ------------------------------------------

    def _address_for(self, worker_id: int) -> str:
        if self.addresses:
            return self.addresses[worker_id % len(self.addresses)]
        return self._spawn_local(worker_id)

    def _spawn_local(self, worker_id: int) -> str:
        with self._meta:
            spawned = self._spawned.get(worker_id)
            if spawned is not None and spawned[0].is_alive():
                return f"unix://{spawned[1]}"
            if self._tmpdir is None:
                self._tmpdir = tempfile.mkdtemp(prefix="spdc-sock-")
            if self._ctx is None:
                import multiprocessing as mp

                self._ctx = mp.get_context("spawn")
            path = os.path.join(self._tmpdir, f"w{worker_id}.sock")
            if os.path.exists(path):
                os.unlink(path)  # stale socket from a dead daemon
            proc = self._ctx.Process(
                target=_daemon_main,
                args=(f"unix://{path}", (worker_id,),
                      bool(jax.config.jax_enable_x64)),
                daemon=True,
                name=f"spdc-sockd-{worker_id}",
            )
            proc.start()
            self._spawned[worker_id] = (proc, path)
            return f"unix://{path}"

    # -- connection pool ------------------------------------------------------

    def _worker_lock(self, worker_id: int) -> threading.Lock:
        with self._meta:
            return self._locks.setdefault(worker_id, threading.Lock())

    def _connect(self, worker_id: int) -> tuple[socket.socket, dict]:
        """Connect + HELLO, with FleetHealth exponential backoff between
        attempts — the pool won't hammer a dead endpoint. Protocol
        violations abort immediately (no retry); connect errors retry
        until `connect_timeout` is spent, then TransportWorkerDied."""
        deadline = time.monotonic() + self.connect_timeout
        last: Exception | None = None
        while True:
            now = time.monotonic()
            gate = self.health.worker(worker_id).next_ok_at
            if gate > now:
                time.sleep(max(0.0, min(gate - now, deadline - now)))
            addr = self._address_for(worker_id)
            family, target = parse_address(addr)
            sock = socket.socket(
                socket.AF_UNIX if family == "unix" else socket.AF_INET,
                socket.SOCK_STREAM,
            )
            try:
                sock.settimeout(max(0.1, deadline - time.monotonic()))
                sock.connect(target)
                send_frame(sock, _hello_frame(
                    proto=SOCKET_PROTO, wire=wire.VERSION,
                    role="client", worker_id=int(worker_id),
                ))
                reply = recv_frame(sock)
                if not reply:
                    raise TransportWorkerDied(
                        f"daemon at {addr} closed during the handshake"
                    )
                hello = _parse_hello(reply)
                _check_server_hello(hello, worker_id, addr)
            except TransportProtocolError:
                sock.close()
                raise
            except (OSError, TransportWorkerDied) as e:
                sock.close()
                last = e
                self.health.observe_failure(
                    worker_id, time.monotonic(), kind="connect"
                )
                if time.monotonic() >= deadline:
                    raise TransportWorkerDied(
                        f"could not connect to worker {worker_id} at "
                        f"{addr} within {self.connect_timeout}s: {last!r}"
                    ) from last
                continue
            self.health.worker(worker_id).consecutive_failures = 0
            return sock, hello

    def _sock(self, worker_id: int) -> socket.socket:
        with self._meta:
            sock = self._socks.get(worker_id)
        if sock is not None:
            return sock
        sock, hello = self._connect(worker_id)
        with self._meta:
            self._socks[worker_id] = sock
            self._hellos[worker_id] = hello
            self._sent_plan[worker_id] = None  # fresh connection: resend
        return sock

    def _discard(self, worker_id: int) -> None:
        """Drop a connection that can no longer be trusted (timed out
        with a reply still owed, died, or spoke garbage). The daemon —
        and its warm caches — survive; the next dispatch reconnects."""
        with self._meta:
            sock = self._socks.pop(worker_id, None)
            self._sent_plan.pop(worker_id, None)
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass

    # -- request path ---------------------------------------------------------

    def _request(self, worker_id: int, frame: bytes,
                 timeout: float | None = None) -> bytes:
        """One lock-step request-reply round trip (raw reply payload).
        Caller holds the worker's lock."""
        deadline = self.timeout if timeout is None else float(timeout)
        sock = self._sock(worker_id)
        try:
            sock.settimeout(deadline)
            send_frame(sock, frame)
            reply = recv_frame(sock)
        except TransportProtocolError:
            self._discard(worker_id)
            raise
        except TimeoutError as e:  # socket.timeout
            self._discard(worker_id)
            raise TransportTimeout(
                f"worker {worker_id} exceeded its {deadline}s request "
                "deadline (connection dropped; the warm daemon survives "
                "and the next dispatch reconnects)"
            ) from e
        except OSError as e:
            self._discard(worker_id)
            raise TransportWorkerDied(
                f"connection to worker {worker_id} died mid-request: {e!r}"
            ) from e
        if reply is None:
            self._discard(worker_id)
            raise TransportWorkerDied(
                f"worker {worker_id} closed the connection mid-request"
            )
        if reply == b"":
            self._discard(worker_id)
            raise TransportProtocolError(
                f"worker {worker_id} sent a goodbye frame in place of a "
                "reply"
            )
        if reply[:4] == b"ERR:":
            raise TransportError(
                f"worker {worker_id} failed: {reply[4:].decode()}"
            )
        return reply

    def _configure_faults(self, worker_id: int, faults,
                          timeout: float | None = None) -> None:
        plan = tuple(faults)
        # _sent_plan is _meta-guarded: close() clears it from another
        # thread, and dict reads concurrent with that clear are racy.
        # The caller's per-worker lock serializes the check-then-send
        # pair for THIS worker; the socket round-trip stays outside
        # _meta (never block the fleet on one worker's I/O).
        with self._meta:
            if self._sent_plan.get(worker_id) == plan:
                return
        ack = self._request(
            worker_id, FaultPlanFrame(plan).to_bytes(), timeout
        )
        if ack != b"ACK":
            self._discard(worker_id)
            raise TransportProtocolError(
                f"worker {worker_id} mis-acknowledged a fault-plan frame: "
                f"{ack[:32]!r}"
            )
        with self._meta:
            self._sent_plan[worker_id] = plan

    def _run_on(self, task, worker_id: int, faults=(),
                timeout: float | None = None):
        from .wire import decode_message

        def once():
            self._configure_faults(worker_id, faults, timeout)
            # decode by wire kind, not a pinned class: the same daemon
            # connection carries ShardResult and TriSolveResult replies
            return decode_message(
                self._request(worker_id, task.to_bytes(), timeout)
            )

        with self._worker_lock(worker_id):
            try:
                return once()
            except TransportWorkerDied:
                # the connection was discarded; the retry reconnects
                # (respawning a dead self-hosted daemon) and re-sends the
                # fault plan — one drop costs one reconnect, not the
                # session. Protocol violations deliberately not retried.
                return once()

    # -- Transport surface ----------------------------------------------------

    def factor(self, tasks, faults=()):
        self._ensure_open()
        return _run_relay(tasks, lambda t, wid: self._run_on(t, wid, faults))

    def repair(self, task, *, replacement):
        self._ensure_open()
        return self._run_on(task, replacement)

    def start(self, task, worker_id, *, faults=(), timeout=None):
        """Future[ShardResult]: the blocking request-reply runs on an IO
        thread; per-worker locks keep one connection in lock-step while
        different workers' requests fly concurrently. `timeout` is REAL —
        a deadline miss drops the straggler's connection."""
        self._ensure_open()
        with self._meta:
            if self._io is None:
                from concurrent.futures import ThreadPoolExecutor

                self._io = ThreadPoolExecutor(
                    max_workers=32, thread_name_prefix="spdc-sock-io"
                )
            io = self._io
        return io.submit(self._run_on, task, worker_id, faults, timeout)

    def close(self):
        # swap state out under _meta, then do the goodbye/teardown I/O
        # unlocked: a slow or dead daemon must not wedge every other
        # thread that needs the metadata lock while close() waits on it
        with self._meta:
            io, self._io = self._io, None
            socks, self._socks = dict(self._socks), {}
            self._hellos.clear()
            self._sent_plan.clear()
            self._locks.clear()
            spawned, self._spawned = dict(self._spawned), {}
            tmpdir, self._tmpdir = self._tmpdir, None
        for sock in socks.values():
            try:
                send_frame(sock, b"")  # goodbye
            except OSError:
                pass
            try:
                sock.close()
            except OSError:
                pass
        for proc, _path in spawned.values():
            if proc.is_alive():
                proc.terminate()
            proc.join(timeout=5)
        if tmpdir is not None:
            shutil.rmtree(tmpdir, ignore_errors=True)
        if io is not None:
            io.shutdown(wait=False)
        super().close()
