"""Role-split SPDC API — client, edge servers, wire, transports.

The paper's protocol is defined by a trust boundary; this package makes
the boundary the shape of the code (DESIGN.md §7):

  * `SPDCClient` / `Session` (client.py) — the trusted role: KeyGen /
    Cipher / Authenticate / Decipher, plus client-driven recovery.
  * `EdgeServer` (server.py)            — the untrusted role: a stateless
    `run(ShardTask) → ShardResult` worker.
  * `ShardTask` / `ShardResult` (messages.py) and the codec (wire.py) —
    the ONLY things that cross the boundary, serializable to versioned
    pickle-free byte frames.
  * transports (transport.py)           — inline (fused fast path),
    shardmap (mesh pipeline), threadpool, multiprocess (real process
    boundary, bytes on the wire).

`core.protocol.outsource_determinant` remains the one-call facade over
exactly these objects.
"""
from .client import BoundaryViolation, Session, SPDCClient
from .messages import FaultPlanFrame, ShardResult, ShardTask
from .server import EdgeServer
from .transport import (
    InlineTransport,
    MultiprocessTransport,
    ShardMapTransport,
    ThreadPoolTransport,
    Transport,
    TransportError,
    TransportTimeout,
    TransportWorkerDied,
    close_all,
    resolve_transport,
)
from .wire import WireError, decode_message

__all__ = [
    "SPDCClient", "Session", "BoundaryViolation",
    "EdgeServer",
    "ShardTask", "ShardResult", "FaultPlanFrame",
    "Transport", "TransportError", "TransportTimeout", "TransportWorkerDied",
    "InlineTransport", "ShardMapTransport",
    "ThreadPoolTransport", "MultiprocessTransport", "resolve_transport",
    "close_all",
    "WireError", "decode_message",
]
