"""Role-split SPDC API — client, edge servers, wire, transports.

The paper's protocol is defined by a trust boundary; this package makes
the boundary the shape of the code (DESIGN.md §7):

  * `SPDCClient` / `Session` (client.py) — the trusted role: KeyGen /
    Cipher / Authenticate / Decipher, plus client-driven recovery and
    the async-overlap pipeline (`Session.start` → `PendingResult`,
    `SPDCClient.run_pipelined`).
  * `EdgeServer` (server.py)            — the untrusted role: a stateless
    `run(ShardTask) → ShardResult` worker.
  * `ShardTask` / `ShardResult` (messages.py) and the codec (wire.py) —
    the ONLY things that cross the boundary, serializable to versioned
    pickle-free byte frames.
  * transports (transport.py, socket_transport.py) — inline (fused fast
    path), shardmap (mesh pipeline), threadpool, multiprocess (real
    process boundary, bytes on the wire), socket (warm worker daemons
    over TCP/UDS — DESIGN.md §9). Select any of them by name, by
    `TransportConfig`, or by instance through `resolve_transport`; all
    share the `start`/`result`/`submit` dispatch surface and a uniform
    `close()`/context-manager lifecycle.

`core.protocol.outsource_determinant` remains the one-call facade over
exactly these objects.
"""
from .client import (
    BoundaryViolation,
    PendingResult,
    Session,
    SPDCClient,
)
from .messages import (
    FaultPlanFrame,
    ShardResult,
    ShardTask,
    TriSolveResult,
    TriSolveTask,
)
from .server import EdgeServer
from .transport import (
    InlineTransport,
    MultiprocessTransport,
    ShardMapTransport,
    ThreadPoolTransport,
    Transport,
    TransportConfig,
    TransportError,
    TransportProtocolError,
    TransportTimeout,
    TransportWorkerDied,
    close_all,
    resolve_transport,
)
from .wire import WireError, decode_message

__all__ = [
    "SPDCClient", "Session", "PendingResult", "BoundaryViolation",
    "EdgeServer",
    "ShardTask", "ShardResult", "TriSolveTask", "TriSolveResult",
    "FaultPlanFrame",
    "Transport", "TransportConfig", "TransportError", "TransportTimeout",
    "TransportWorkerDied", "TransportProtocolError",
    "InlineTransport", "ShardMapTransport",
    "ThreadPoolTransport", "MultiprocessTransport", "SocketTransport",
    "WorkerDaemon", "resolve_transport",
    "close_all",
    "WireError", "decode_message",
]


def __getattr__(name):
    # SocketTransport/WorkerDaemon import lazily: socket_transport pulls
    # in distrib.rateless (FleetHealth), which itself imports this
    # package's transport module — a top-level import here would cycle.
    if name in ("SocketTransport", "WorkerDaemon"):
        from . import socket_transport

        return getattr(socket_transport, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
