"""EdgeServer — the untrusted worker role of the SPDC protocol.

A stateless executor of ShardTasks: given its encrypted block row and the
U rows relayed from upstream, it computes the (L strip, U strip) of paper
Algorithm 3's block row `task.server` and reports them back. It holds NO
session state between tasks, sees ONLY ciphertext (the trust boundary —
DESIGN.md §7), and its arithmetic is exactly `core.lu.lu_block_row` in
the task's declared operation order, so an honest EdgeServer's strips are
bit-identical to the strips the fused single-process sweep produces for
the same inputs.

Misbehavior is first-class but OPT-IN: `run(task, faults=plan)` applies
the core.faults model to the strips this server reports — tampering its
own block row before the relay hop forwards it, which is precisely the
paper's in-band threat (downstream servers consume the poisoned rows).
Faults bind to the initial assignment (attempt 0): verification-driven
re-dispatches go to replacement servers the pool chose specifically for
not being the culprit, so repair tasks always execute honestly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from jax.scipy.linalg import solve_triangular

from repro.core.faults import corrupt_strip, normalize_plan, sample_delay
from repro.core.lu import lu_block_row

from .messages import ShardResult, ShardTask, TriSolveResult, TriSolveTask

__all__ = ["EdgeServer"]

#: jitted strip recompute for (B, n, n) stacks — host dispatch would
#: dominate otherwise; single matrices stay eager so the arithmetic
#: bit-matches the eager lu_nserver simulation (core.lu.lu_block_row).
_block_row_batched = jax.jit(
    lu_block_row, static_argnums=(2, 3), static_argnames=("style",)
)


def _embed_rows(zeros, strip, row0, rows):
    """Place a (…, rows, n) strip into a zero (…, n, n) frame (eager —
    values only; lu_block_row never reads outside the strip)."""
    return zeros.at[..., row0 : row0 + rows, :].set(strip)


class EdgeServer:
    """One untrusted edge worker (see module docstring).

    worker_id identifies the PHYSICAL worker (process/thread slot) — it
    is labelling for logs and fault routing, not protocol state.
    """

    def __init__(self, worker_id: int | None = None):
        self.worker_id = worker_id

    def run(self, task, faults=()):
        """Execute one protocol task → its result message.

        ShardTask → ShardResult (one LU block row); TriSolveTask →
        TriSolveResult (one triangular-solve column chunk, DESIGN.md
        §12). The dispatch is by message type, so every transport whose
        worker loop decodes frames with `wire.decode_message` serves the
        linalg rounds with zero transport-side changes.

        For ShardTasks, the strips are embedded into zero-filled
        (…, n', n') frames because `lu_block_row` is written against
        full-matrix coordinates; it only ever READS block row
        `task.server` of x and the rows above `task.server` of u, so the
        zeros are never consumed and the embedding changes no arithmetic.
        """
        if isinstance(task, TriSolveTask):
            return self._run_trisolve(task, faults)
        if task.style not in ("nserver", "pipeline"):
            raise ValueError(f"unknown task style {task.style!r}")
        n, b, s0 = task.n, task.block, task.server * task.block
        if b * task.num_servers != n:
            raise ValueError(
                f"task block {b}×{task.num_servers} servers does not tile "
                f"n'={n}"
            )
        x_row = jnp.asarray(task.x_row)
        lead = x_row.shape[:-2]
        zeros = jnp.zeros((*lead, n, n), dtype=x_row.dtype)
        x = _embed_rows(zeros, x_row, s0, b)
        if task.u_upstream is not None and task.u_upstream.shape[-2]:
            u_up = jnp.asarray(task.u_upstream, dtype=x_row.dtype)
            u = _embed_rows(zeros, u_up, 0, int(u_up.shape[-2]))
        else:
            if task.server != 0:
                raise ValueError(
                    f"server {task.server} needs upstream U rows; the "
                    "transport must thread the one-way relay"
                )
            u = zeros
        self._straggle(task, faults)
        row_fn = _block_row_batched if x.ndim == 3 else lu_block_row
        l_row, u_row = row_fn(x, u, task.server, task.num_servers,
                              style=task.style)
        l_row, u_row = self._misbehave(task, l_row, u_row, faults)
        return ShardResult(
            server=task.server,
            l_row=np.asarray(l_row),
            u_row=np.asarray(u_row),
            subseed=task.subseed,
            attempt=task.attempt,
            session_id=task.session_id,
        )

    def _run_trisolve(self, task: TriSolveTask, faults=()) -> TriSolveResult:
        """One triangular-solve column chunk through the session's
        verified factors: X' y = rhs via L a = rhs, U y = a — or the
        adjoint X'ᵀ y = rhs via Uᵀ a = rhs, Lᵀ y = a when
        task.transpose. The server only ever touches material it already
        produced (l/u) or blinded/public RHS columns."""
        l = jnp.asarray(task.l)
        u = jnp.asarray(task.u)
        rhs = jnp.asarray(task.rhs, dtype=l.dtype)
        if l.ndim != 2 or l.shape != u.shape or rhs.shape[0] != l.shape[-1]:
            raise ValueError(
                f"trisolve shapes disagree: l {l.shape}, u {u.shape}, "
                f"rhs {rhs.shape}"
            )
        self._straggle(task, faults)
        if task.transpose:
            a = solve_triangular(u, rhs, lower=False, trans=1)
            y = solve_triangular(l, a, lower=True, trans=1)
        else:
            a = solve_triangular(l, rhs, lower=True)
            y = solve_triangular(u, a, lower=False)
        y = self._misbehave_solve(task, y, faults)
        return TriSolveResult(
            server=task.server,
            y=np.asarray(y),
            subseed=task.subseed,
            transpose=task.transpose,
            col0=task.col0,
            attempt=task.attempt,
            session_id=task.session_id,
        )

    def _misbehave_solve(self, task, y, faults):
        """Trisolve leg of the fault model: a tamper fault naming this
        worker corrupts the reported solution chunk (any target — the
        chunk is the only thing this round reports); a dropout zeroes
        it. Initial dispatch only, like `_misbehave` — re-issues go to
        replacements chosen for not being the culprit.

        Positions are picked directly inside the (n', c) chunk rather
        than through `corrupt_strip`'s LU-strip geometry: a solve chunk
        has no triangle structure, and the strip mapping can land outside
        a narrow chunk (where jax's out-of-bounds scatter silently drops
        the update — a tamper that never happened)."""
        plan = [
            f for f in normalize_plan(faults)
            if f.server == self._bound(task) and task.attempt == 0
            and f.kind != "delay"
        ]
        for f in plan:
            if f.kind == "dropout":
                y = jnp.zeros_like(y)
                continue
            if f.mode == "block":
                y = y * (1.0 + f.magnitude)
                continue
            h = (f.seed * 1315423911 + f.server * 2654435761) & 0x7FFFFFFF
            r = h % y.shape[0]
            c = (h >> 8) % y.shape[1]
            if f.mode == "sign_flip":
                y = y.at[r, c].multiply(-1.0)
            else:
                y = y.at[r, c].set(y[r, c] * (1.0 + f.magnitude)
                                   + f.magnitude)
        return y

    def _bound(self, task) -> int:
        """The id faults bind to: the PHYSICAL worker when known, else the
        task's block row. Identical on the classic paths (transports run
        task i on worker i); under rateless dispatch ``task.server`` is a
        strip index while the fault plan names workers, so the physical
        id is the one that matters."""
        return self.worker_id if self.worker_id is not None else task.server

    def _straggle(self, task, faults) -> None:
        """Play this worker's wall-clock delay faults (core.faults
        ``delay_s``) as a real sleep — unlike tampering, slowness is a
        property of the MACHINE, so it fires on every attempt, repairs
        and probation probes included (a retry on the same slow worker is
        slow again; a retry elsewhere escapes it)."""
        bound = self._bound(task)
        wait = sum(
            sample_delay(f, token=task.subseed)
            for f in normalize_plan(faults)
            if f.kind == "delay" and f.server == bound and f.delay_s > 0.0
        )
        if wait > 0.0:
            import time

            time.sleep(wait)

    def _misbehave(self, task, l_row, u_row, faults):
        """Apply the simulated fault model to this server's reported strips.

        Only faults naming this worker (`_bound`) fire, and only on the
        initial dispatch (module docstring). Because message transports
        forward the reported U row down the relay, every tamper here is
        effectively in-band — the cascading-poison threat model.
        """
        plan = [
            f for f in normalize_plan(faults)
            if f.server == self._bound(task) and task.attempt == 0
            and f.kind != "delay"
        ]
        if not plan:
            return l_row, u_row
        batched = l_row.ndim == 3
        for f in plan:
            targets = ("l", "u") if f.kind == "dropout" else tuple(f.target)

            def hit(orig, factor, f=f):
                bad = corrupt_strip(orig, f, n=task.n, factor=factor)
                if f.matrices is not None and batched:
                    idx = np.asarray(f.matrices, dtype=np.int32)
                    bad = orig.at[idx].set(bad[idx])
                return bad

            if "l" in targets:
                l_row = hit(l_row, "l")
            if "u" in targets:
                u_row = hit(u_row, "u")
        return l_row, u_row
