"""Protocol messages of the role-split SPDC API (DESIGN.md §7).

Exactly four object kinds exist at the client ↔ edge-server boundary, and
only the first two ever cross it:

  * ``ShardTask``   — client → server. One server's unit of work: its
    ENCRYPTED block row of the augmented ciphertext, the dispatch-channel
    sub-seed keying this (re-)issue, and — for repair tasks or transports
    that materialize the relay — the upstream U rows it would have
    received over the one-way chain. Nothing else: no plaintext entries,
    no blinding vector, no Ψ, no probe material (the boundary the paper's
    security analysis assumes; enforced by `Session.tasks()` and the
    negative tests in tests/test_api.py).
  * ``ShardResult`` — server → client. The (L strip, U strip) the server
    claims, echoing the task's sub-seed so the client can match a result
    to the dispatch that requested it (a stale strip from a retired
    server cannot impersonate a re-dispatch).
  * ``Verdict`` / ``Determinant`` (core.verify / core.decipher) — stay on
    the client side of the boundary but serialize with the same codec so
    gateways and archives can move them between processes.

``FaultPlanFrame`` is NOT a protocol message: it is the simulation
control frame transports use to tell a worker which misbehavior to play
(core.faults semantics) — a real deployment has real faults instead.

All wire frames use repro.api.wire (versioned, pickle-free — see that
module's docstring for why).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from repro.core.faults import FaultPlan, ServerFault, normalize_plan

from . import wire

__all__ = [
    "ShardTask", "ShardResult", "TriSolveTask", "TriSolveResult",
    "FaultPlanFrame",
]


def _np_or_none(a):
    return None if a is None else np.asarray(a)


@wire.register("ShardTask")
@dataclass(frozen=True, eq=False)
class ShardTask:
    """One server's unit of work — the only client → server message.

    x_row: the server's (…, b, n') block row of the augmented CIPHERTEXT
        (post-EWO, post-PRT, post-border). A leading batch dim means the
        whole stack's strip ships in one task (DESIGN.md §3).
    u_upstream: the (…, s0, n') U rows of the servers above — what the
        one-way relay S_{i-1} → S_i delivers. None on initial dispatch
        when the transport itself threads the relay; always present on
        repair tasks (the replacement is stateless and the culprit's
        relay cannot be trusted).
    subseed: H(Ψ-digest ‖ server ‖ attempt) — the dispatch-channel key.
        Derived from the client secret but reveals nothing about it
        (SHA-256 preimage); it is the re-keying that stops a replayed
        strip from the original server impersonating a re-dispatch.
    style: operation order the result must match ("nserver" | "pipeline",
        core.lu.lu_block_row) so a recomputed strip splices bit-cleanly.
    attempt: 0 = initial dispatch; > 0 = verification-driven re-issue.
    session_id: opaque routing tag (hex), NOT secret material.
    """

    server: int
    num_servers: int
    x_row: np.ndarray
    subseed: bytes
    style: str = "nserver"
    attempt: int = 0
    u_upstream: np.ndarray | None = None
    session_id: str = ""

    @property
    def n(self) -> int:
        """Padded sweep size n' (the full matrix the strips tile)."""
        return int(self.x_row.shape[-1])

    @property
    def block(self) -> int:
        return int(self.x_row.shape[-2])

    def with_upstream(self, u_upstream) -> "ShardTask":
        return replace(self, u_upstream=_np_or_none(u_upstream))

    def to_bytes(self) -> bytes:
        return wire.encode(
            "ShardTask",
            {
                "server": self.server,
                "num_servers": self.num_servers,
                "subseed": self.subseed,
                "style": self.style,
                "attempt": self.attempt,
                "session_id": self.session_id,
            },
            {"x_row": self.x_row, "u_upstream": self.u_upstream},
        )

    @classmethod
    def _from_wire(cls, scalars, arrays):
        return cls(
            server=int(scalars["server"]),
            num_servers=int(scalars["num_servers"]),
            x_row=arrays["x_row"],
            subseed=scalars["subseed"],
            style=scalars["style"],
            attempt=int(scalars["attempt"]),
            u_upstream=arrays["u_upstream"],
            session_id=scalars["session_id"],
        )

    @classmethod
    def from_bytes(cls, data: bytes) -> "ShardTask":
        kind, scalars, arrays = wire.decode(data)
        if kind != "ShardTask":
            raise wire.WireError(f"expected ShardTask frame, got {kind!r}")
        return cls._from_wire(scalars, arrays)


@wire.register("ShardResult")
@dataclass(frozen=True, eq=False)
class ShardResult:
    """One server's reported strips — the only server → client message.

    l_row / u_row: the (…, b, n') L and U strips of the server's block
    row. The client trusts NOTHING here until Authenticate accepts it.
    subseed/attempt echo the ShardTask so the client can bind the result
    to a specific dispatch.
    """

    server: int
    l_row: np.ndarray
    u_row: np.ndarray
    subseed: bytes = b""
    attempt: int = 0
    session_id: str = ""

    def to_bytes(self) -> bytes:
        return wire.encode(
            "ShardResult",
            {
                "server": self.server,
                "subseed": self.subseed,
                "attempt": self.attempt,
                "session_id": self.session_id,
            },
            {"l_row": self.l_row, "u_row": self.u_row},
        )

    @classmethod
    def _from_wire(cls, scalars, arrays):
        return cls(
            server=int(scalars["server"]),
            l_row=arrays["l_row"],
            u_row=arrays["u_row"],
            subseed=scalars["subseed"],
            attempt=int(scalars["attempt"]),
            session_id=scalars["session_id"],
        )

    @classmethod
    def from_bytes(cls, data: bytes) -> "ShardResult":
        kind, scalars, arrays = wire.decode(data)
        if kind != "ShardResult":
            raise wire.WireError(f"expected ShardResult frame, got {kind!r}")
        return cls._from_wire(scalars, arrays)


@wire.register("TriSolveTask")
@dataclass(frozen=True, eq=False)
class TriSolveTask:
    """One triangular-solve shard — client → server (DESIGN.md §12).

    Ships the session's ALREADY-VERIFIED factors of the augmented
    ciphertext plus one blinded right-hand-side column chunk; the server
    answers X' y = rhs (or X'ᵀ y = rhs) through two triangular solves.
    Everything here is already on the server side of the trust boundary:
    l/u are what the fleet itself reported during factorization, and rhs
    is either a public permutation block (inverse rounds) or passed
    through the `blind_rhs` one-time-pad chokepoint (solve rounds) — no
    new plaintext crosses with the op plan's extra rounds.

    col0: first column index of this chunk in the round's full RHS (the
        client reassembles chunks by columns, not by rows).
    transpose: 0 solves through X' = L·U, 1 through X'ᵀ (the adjoint
        round the VJPs use).
    subseed: the trisolve dispatch-channel key
        (distrib.recovery.trisolve_subseed) — a lane disjoint from the
        LU dispatch keys, re-derived per attempt so a replayed chunk
        cannot impersonate a re-issue.
    """

    server: int
    num_servers: int
    l: np.ndarray
    u: np.ndarray
    rhs: np.ndarray
    subseed: bytes
    transpose: int = 0
    col0: int = 0
    attempt: int = 0
    session_id: str = ""

    @property
    def n(self) -> int:
        """Padded solve size n' (the factors are (n', n'))."""
        return int(self.l.shape[-1])

    @property
    def cols(self) -> int:
        return int(self.rhs.shape[-1])

    def to_bytes(self) -> bytes:
        return wire.encode(
            "TriSolveTask",
            {
                "server": self.server,
                "num_servers": self.num_servers,
                "subseed": self.subseed,
                "transpose": self.transpose,
                "col0": self.col0,
                "attempt": self.attempt,
                "session_id": self.session_id,
            },
            {"l": self.l, "u": self.u, "rhs": self.rhs},
        )

    @classmethod
    def _from_wire(cls, scalars, arrays):
        return cls(
            server=int(scalars["server"]),
            num_servers=int(scalars["num_servers"]),
            l=arrays["l"],
            u=arrays["u"],
            rhs=arrays["rhs"],
            subseed=scalars["subseed"],
            transpose=int(scalars["transpose"]),
            col0=int(scalars["col0"]),
            attempt=int(scalars["attempt"]),
            session_id=scalars["session_id"],
        )

    @classmethod
    def from_bytes(cls, data: bytes) -> "TriSolveTask":
        kind, scalars, arrays = wire.decode(data)
        if kind != "TriSolveTask":
            raise wire.WireError(f"expected TriSolveTask frame, got {kind!r}")
        return cls._from_wire(scalars, arrays)


@wire.register("TriSolveResult")
@dataclass(frozen=True, eq=False)
class TriSolveResult:
    """One solved column chunk — server → client.

    y: the (n', c) solution chunk the server claims. Untrusted until the
    client's residual check accepts it (linalg.session; a failed chunk is
    re-dispatched through distrib.recovery.recover_solve). subseed /
    attempt / col0 echo the task so the client binds the chunk to its
    dispatch.
    """

    server: int
    y: np.ndarray
    subseed: bytes = b""
    transpose: int = 0
    col0: int = 0
    attempt: int = 0
    session_id: str = ""

    def to_bytes(self) -> bytes:
        return wire.encode(
            "TriSolveResult",
            {
                "server": self.server,
                "subseed": self.subseed,
                "transpose": self.transpose,
                "col0": self.col0,
                "attempt": self.attempt,
                "session_id": self.session_id,
            },
            {"y": self.y},
        )

    @classmethod
    def _from_wire(cls, scalars, arrays):
        return cls(
            server=int(scalars["server"]),
            y=arrays["y"],
            subseed=scalars["subseed"],
            transpose=int(scalars["transpose"]),
            col0=int(scalars["col0"]),
            attempt=int(scalars["attempt"]),
            session_id=scalars["session_id"],
        )

    @classmethod
    def from_bytes(cls, data: bytes) -> "TriSolveResult":
        kind, scalars, arrays = wire.decode(data)
        if kind != "TriSolveResult":
            raise wire.WireError(
                f"expected TriSolveResult frame, got {kind!r}"
            )
        return cls._from_wire(scalars, arrays)


@wire.register("FaultPlanFrame")
@dataclass(frozen=True)
class FaultPlanFrame:
    """Simulation control frame: configure a worker's misbehavior.

    Carries a core.faults FaultPlan as plain data (no pickle — a worker
    decodes field dicts and rebuilds frozen ServerFaults). Sent by
    transports before a sweep whose session requested fault injection;
    real deployments never send one.
    """

    plan: FaultPlan = ()

    def to_bytes(self) -> bytes:
        faults = []
        for f in self.plan:
            d = {
                "server": f.server, "kind": f.kind, "mode": f.mode,
                "target": f.target, "magnitude": f.magnitude,
                "delay_rounds": f.delay_rounds,
                "delay_s": f.delay_s, "delay_dist": f.delay_dist,
                "delay_alpha": f.delay_alpha,
                "matrices": None if f.matrices is None else list(f.matrices),
                "in_band": f.in_band, "seed": f.seed,
            }
            faults.append(d)
        return wire.encode("FaultPlanFrame", {"faults": faults}, {})

    @classmethod
    def _from_wire(cls, scalars, arrays):
        plan = []
        for d in scalars["faults"]:
            mats = d.pop("matrices")
            plan.append(
                ServerFault(matrices=None if mats is None else tuple(mats),
                            **d)
            )
        return cls(plan=normalize_plan(plan))

    @classmethod
    def from_bytes(cls, data: bytes) -> "FaultPlanFrame":
        kind, scalars, arrays = wire.decode(data)
        if kind != "FaultPlanFrame":
            raise wire.WireError(f"expected FaultPlanFrame, got {kind!r}")
        return cls._from_wire(scalars, arrays)


# Verdict and Determinant live in core (they predate the role split) but
# speak the same codec; register them so decode_message dispatches all
# four protocol-adjacent kinds.
def _register_core_kinds() -> None:
    from repro.core.decipher import Determinant
    from repro.core.verify import Verdict

    wire.MESSAGE_KINDS.setdefault("Verdict", Verdict)
    wire.MESSAGE_KINDS.setdefault("Determinant", Determinant)


_register_core_kinds()
