"""SPDCClient / Session — the trusted-client role of the SPDC protocol.

The paper's trust boundary (§III–IV) splits the six-algorithm tuple in
two: SeedGen, KeyGen, Cipher, Authenticate, and Decipher run on the
constrained CLIENT; only the Parallelize stage (the N-server LU) runs on
untrusted edge hardware. This module is everything on the client side of
that line, as an object API:

    client  = SPDCClient(method="q3", dtype="float64", recover=True)
    session = client.open_session(m, num_servers=4)      # PMOP runs here
    result  = session.run(transport)                     # SPCP + RRVP

`open_session` performs the full PMOP (seed → key → cipher → equilibrate
→ det-preserving border) and captures every secret the protocol needs —
seeds, blinding keys, rotation metadata, the augmented ciphertext the
probes verify against. What leaves the session is only what
`Session.tasks()` emits: per-server ShardTasks holding encrypted block
rows and dispatch sub-seeds (messages.ShardTask; the boundary is checked
at task-build time and adversarially in tests/test_api.py).

`Session.collect()` is the RRVP tail: Authenticate over the assembled
factors with a secret-keyed probe, then — when the client opted into
recovery — the verification-driven re-dispatch loop, expressed as the
session emitting NEW ShardTasks for blamed servers (fresh sub-seed per
attempt, verified upstream rows attached) through the same transport.
The one-way model survives recovery: servers still never talk backwards,
the client re-issues work instead.

The module-level `outsource_determinant` facades in core.protocol are
thin wrappers over exactly this flow and remain the stable entry point;
this API is for callers that need the roles separated — multi-process
serving, real remote workers, or security tests that must see the wire.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.augment import augment, padding_for_servers
from repro.core.cipher import CipherMeta, cipher, cipher_batch
from repro.core.cipher import equilibrate as ced_equilibrate
from repro.core.decipher import decipher, decipher_batch
from repro.core.faults import normalize_plan, resolve_delays
from repro.core.keygen import keygen, keygen_batch
from repro.core.lu import nserver_comm_model
from repro.core.prt import rotate_degree
from repro.core.seed import Seed, seedgen, seedgen_batch
from repro.core.verify import authenticate

from .messages import ShardResult, ShardTask
from .transport import Transport, TransportConfig, resolve_transport

__all__ = ["SPDCClient", "Session", "PendingResult", "BoundaryViolation"]


class BoundaryViolation(AssertionError):
    """A ShardTask was about to carry plaintext or key material."""


#: everything a ShardTask is allowed to hold — a new field on the message
#: is a deliberate API change, not something a refactor may smuggle in
_TASK_FIELDS = frozenset(
    {"server", "num_servers", "x_row", "subseed", "style", "attempt",
     "u_upstream", "session_id"}
)

#: everything a TriSolveTask (linalg.session's triangular-solve rounds,
#: DESIGN.md §12) is allowed to hold — same contract as _TASK_FIELDS:
#: repro-lint's SPDC105 cross-checks this set against the dataclass
_SOLVE_TASK_FIELDS = frozenset(
    {"server", "num_servers", "l", "u", "rhs", "subseed", "transpose",
     "col0", "attempt", "session_id"}
)

#: auto boundary check: full entry-level plaintext-disjointness screening
#: up to this many payload elements per sweep (beyond it the structural
#: checks still run; tests force the full check at every size)
_FULL_CHECK_ELEMS = 1 << 20


@partial(jax.jit, static_argnames=("padding", "equilibrate"))
def _equilibrate_augment_jit(x, aug_key, *, padding, equilibrate):
    if equilibrate:
        x, log2_scale = ced_equilibrate(x)
    else:
        log2_scale = jnp.zeros(x.shape[:-2], dtype=jnp.int32)
    return augment(x, padding, key=aug_key), log2_scale


def _equilibrate_augment(x, aug_key, *, padding, equilibrate):
    """PMOP tail for device ciphertexts: optional two-sided power-of-two
    equilibration, then the det-preserving [[X,0],[R,I]] border. Both
    transforms are exact in floating point, so running them here (vs
    fused into the old monolithic sweep) is value-identical. When both
    stages are no-ops (p = 0, no equilibration — every n divisible by N)
    the jit is skipped entirely: an identity program would still cost a
    dispatch plus a full ciphertext copy per sweep on the gateway's hot
    path."""
    if padding == 0 and not equilibrate:
        # host zeros, not device zeros: converting a device array back to
        # numpy at session-build time would SYNC the CPU stream and
        # serialize the still-in-flight cipher program behind it
        return x, np.zeros(x.shape[:-2], dtype=np.int32)
    return _equilibrate_augment_jit(x, aug_key, padding=padding,
                                    equilibrate=equilibrate)


@dataclass
class SPDCClient:
    """The trusted client role: holds the security configuration and
    mints Sessions. One client may run many concurrent sessions; all
    per-matrix secrets live on the Session, not here.

    Parameters mirror `core.protocol.outsource_determinant` (that facade
    constructs one of these); see its docstring for the full reference.
    """

    lambda1: int = 128
    lambda2: int = 128
    mode: str = "ewd"
    method: str = "q3"
    use_kernel: bool = False
    faithful_sign: bool = False
    recover: bool = False
    standby: int = 0
    straggler_deadline: int | None = None
    dtype: Any = "float64"
    growth_safe: bool | None = None
    equilibrate: bool | None = None
    #: rateless straggler-adaptive dispatch (DESIGN.md §8): True uses the
    #: default RatelessConfig, or pass one. Sessions over-decompose into
    #: F = overdecompose·N strips streamed to whichever workers are free;
    #: straggler_deadline is ignored (there is no deadline to tune).
    rateless: Any = False
    #: default execution boundary for this client's sessions: a name, a
    #: TransportConfig, or a Transport instance (resolve_transport). A
    #: config is BUILT here and OWNED — `close()` (or the client's
    #: context manager) tears it down deterministically; names resolve to
    #: the process-shared instance and instances stay caller-owned.
    transport: Any = None

    def __post_init__(self):
        from repro.configs.spdc import RATELESS_DEFAULT, RatelessConfig
        from repro.core.protocol import (
            _resolve_growth_controls, resolve_dtype,
        )

        self._owns_transport = False
        if isinstance(self.transport, TransportConfig):
            self.transport = self.transport.build()
            self._owns_transport = True
        elif self.transport is not None and not isinstance(
            self.transport, Transport
        ):
            # a name string — shared instance, not owned
            self.transport = resolve_transport(self.transport)
        self.dtype = resolve_dtype(self.dtype)
        self.growth_safe, self.equilibrate = _resolve_growth_controls(
            self.dtype, self.growth_safe, self.equilibrate,
            self.faithful_sign,
        )
        if self.rateless is True:
            self.rateless = RATELESS_DEFAULT
        elif not self.rateless:
            self.rateless = None
        elif not isinstance(self.rateless, RatelessConfig):
            raise ValueError(
                "rateless must be a bool or a configs.spdc.RatelessConfig, "
                f"got {self.rateless!r}"
            )
        # fleet health OUTLIVES sessions: what one session learned about
        # the workers (speed, tamper history) steers the next
        if self.rateless is not None:
            from repro.distrib.rateless import FleetHealth

            self.fleet = FleetHealth(self.rateless)
        else:
            self.fleet = None

    def _partitions(self, num_servers: int) -> int:
        """Strips per matrix: F = overdecompose·N rateless, N classic."""
        if self.rateless is None:
            return num_servers
        return num_servers * self.rateless.overdecompose

    # -- transport lifecycle -------------------------------------------------

    def close(self) -> None:
        """Close the transport this client OWNS (built from a
        TransportConfig). Shared (name-resolved) and caller-provided
        instances are left alone — their owner closes them. Idempotent."""
        if self._owns_transport and self.transport is not None:
            self.transport.close()

    def __enter__(self) -> "SPDCClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- async-overlap pipeline (DESIGN.md §9) --------------------------------

    def run_pipelined(self, inputs, num_servers: int, *, depth: int = 2,
                      transport=None, faults=None, tamper=None) -> list:
        """Run many independent protocol inputs with PMOP/wire overlap.

        The sequential loop `[open_session(m).run() for m in inputs]`
        leaves the wire idle during every PMOP and the client idle during
        every wire round trip. This pipeline keeps up to `depth` sessions
        in flight: batch k's ShardTasks ride the transport (a
        `Session.start` Future) WHILE batch k+1's cipher/border runs on
        the client — on message transports the client-side prepare cost
        disappears into wire time. Results come back in input order, each
        collected (authenticate → decipher) on this thread as its dispatch
        resolves; `inputs` elements are anything `open_session` accepts.

        depth=1 degrades to the sequential loop; depth beyond the
        transport's driver width (4) adds nothing.
        """
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        results: list = []
        pending: list[PendingResult] = []
        for m in inputs:
            if len(pending) >= depth:
                results.append(pending.pop(0).result())
            session = self.open_session(m, num_servers, faults=faults,
                                        tamper=tamper)
            pending.append(session.start(transport))
        while pending:
            results.append(pending.pop(0).result())
        return results

    # -- PMOP: everything before any server is involved ---------------------

    def open_session(
        self,
        m,
        num_servers: int,
        *,
        faults=None,
        tamper=None,
        pad_to: int | None = None,
    ) -> "Session":
        """Run the client-side PMOP and return the dispatchable Session.

        m: one (n, n) matrix, a (B, n, n) stack, or a list/tuple of
        mixed-size square matrices (coalesced at a shared padded size —
        `pad_to` applies only there). faults/tamper configure SIMULATED
        misbehavior: faults ride to the Parallelize stage (in-sweep for
        fused transports, worker-side for message transports); tamper is
        a client-side hook on the assembled factors.
        """
        t0 = time.perf_counter()
        plan = resolve_delays(
            normalize_plan(faults),
            # rateless has no rounds deadline — slow servers just do less
            None if self.rateless is not None else self.straggler_deadline,
        )
        if isinstance(m, (list, tuple)):
            sess = self._open_mixed(m, num_servers, plan, tamper, pad_to)
        else:
            if pad_to is not None:
                raise ValueError("pad_to applies to mixed-size lists only")
            m = jnp.asarray(m, dtype=self.dtype)
            if m.ndim == 3:
                sess = self._open_batch(m, num_servers, plan, tamper)
            else:
                if m.ndim != 2 or m.shape[0] != m.shape[1]:
                    raise ValueError(
                        f"expected a square matrix, got {m.shape}"
                    )
                sess = self._open_single(m, num_servers, plan, tamper)
        sess._pmop_s = time.perf_counter() - t0
        return sess

    def _open_single(self, m, num_servers, plan, tamper) -> "Session":
        n = int(m.shape[0])
        m_host = np.asarray(m)
        seed = seedgen(self.lambda1, m_host)
        key = keygen(self.lambda2, seed, n)
        x, meta = cipher(m, key, seed, mode=self.mode,
                         growth_safe=self.growth_safe,
                         use_kernel=self.use_kernel)
        if self.equilibrate:
            x, log2_scale = ced_equilibrate(x)
            log2_scale = float(log2_scale)
        else:
            log2_scale = 0.0
        aug_key = jax.random.key(
            int.from_bytes(seed.digest[8:16], "big") % (2**31)
        )
        parts = self._partitions(num_servers)
        padding = self._padding_for(n, parts)
        x_aug = augment(x, padding, key=aug_key)
        return Session(
            client=self, kind="single", num_servers=num_servers,
            x_aug=x_aug, seeds=[seed], metas=[meta],
            log2_scale=log2_scale, n=n, padding=padding,
            digest=seed.digest, plan=plan, tamper=tamper,
            num_strips=parts if parts != num_servers else None,
            _m_host=m_host,
        )

    def _padding_for(self, n: int, parts: int) -> int:
        """Identity-border padding to the partition grid; the rateless
        grid (F strips) additionally keeps strips ≥ 2 rows — the same
        n'/N > 1 floor the paper puts on the classic schedule."""
        padding = padding_for_servers(n, parts)
        if (n + padding) // parts < 2:
            padding = 2 * parts - n
        return padding

    def _open_batch(self, m, num_servers, plan, tamper) -> "Session":
        from repro.core.protocol import _batch_digest

        n = int(m.shape[-1])
        m_host = np.asarray(m)
        seeds = seedgen_batch(self.lambda1, m_host)
        v = keygen_batch(self.lambda2, seeds, n)
        x, metas = cipher_batch(m, v, seeds, mode=self.mode,
                                growth_safe=self.growth_safe,
                                use_kernel=self.use_kernel)
        aug_key = jax.random.key(
            int.from_bytes(seeds[0].digest[8:16], "big") % (2**31)
        )
        parts = self._partitions(num_servers)
        padding = self._padding_for(n, parts)
        x_aug, log2_scale = _equilibrate_augment(
            x, aug_key, padding=padding, equilibrate=self.equilibrate
        )
        # log2_scale may still be a device array here; collect() converts
        # it at Decipher time (the old fused path's sync point) — forcing
        # it now would stall the session behind the cipher program
        return Session(
            client=self, kind="batch", num_servers=num_servers,
            x_aug=x_aug, seeds=seeds, metas=metas,
            log2_scale=log2_scale, n=n, padding=padding,
            digest=_batch_digest(seeds), plan=plan, tamper=tamper,
            num_strips=parts if parts != num_servers else None,
            _m_host=m_host,
        )

    def _open_mixed(self, ms, num_servers, plan, tamper, pad_to) -> "Session":
        # host-native from the start: raw-size client matrices must never
        # individually touch the device (DESIGN.md §5.1)
        from repro.core.protocol import (
            _augment_host, _batch_digest, _cipher_host, _equilibrate_host,
            common_padded_size,
        )

        np_dtype = np.dtype(self.dtype.name)
        ms = [np.asarray(mi, dtype=np_dtype) for mi in ms]
        if not ms:
            raise ValueError("outsource_determinant_mixed needs >= 1 matrix")
        for mi in ms:
            if mi.ndim != 2 or mi.shape[0] != mi.shape[1]:
                raise ValueError(
                    f"expected square matrices, got shape {mi.shape}"
                )
        sizes = [int(mi.shape[0]) for mi in ms]
        parts = self._partitions(num_servers)
        if pad_to is None:
            pad_to = common_padded_size(sizes, parts)
        if pad_to % parts != 0 or pad_to // parts <= 1:
            raise ValueError(
                f"pad_to={pad_to} not servable by {parts} partitions "
                f"(N={num_servers}"
                + (f" × overdecompose={parts // num_servers}"
                   if parts != num_servers else "")
                + "; need pad_to % parts == 0 and pad_to / parts > 1)"
            )
        if max(sizes) > pad_to:
            raise ValueError(
                f"matrix of size {max(sizes)} exceeds pad_to={pad_to}"
            )
        seeds, metas, xs, paddings, log2_scales = [], [], [], [], []
        for mi in ms:
            n = int(mi.shape[0])
            seed = seedgen(self.lambda1, mi)
            key = keygen(self.lambda2, seed, n)
            k = rotate_degree(seed.psi)
            x = _cipher_host(mi, np.asarray(key.v, dtype=np_dtype), k,
                             self.mode, growth_safe=self.growth_safe)
            if self.equilibrate:
                x, ls = _equilibrate_host(x)
            else:
                ls = 0
            aug_rng = np.random.default_rng(
                int.from_bytes(seed.digest[8:16], "big") % (2**31)
            )
            xs.append(_augment_host(x, pad_to - n, aug_rng))
            seeds.append(seed)
            metas.append(CipherMeta(mode=self.mode, rotate_k=k, n=n,
                                    flipped=self.growth_safe and k % 2 == 1))
            paddings.append(pad_to - n)
            log2_scales.append(ls)
        return Session(
            client=self, kind="mixed", num_servers=num_servers,
            x_aug=jnp.asarray(np.stack(xs)), seeds=seeds, metas=metas,
            log2_scale=np.asarray(log2_scales), n=pad_to, padding=0,
            digest=_batch_digest(seeds), plan=plan, tamper=tamper,
            paddings=paddings, pad_to=pad_to,
            num_strips=parts if parts != num_servers else None,
            _m_host=None, _m_hosts=ms,
        )


@dataclass
class Session:
    """One protocol run: the client's secrets + the dispatchable state.

    Everything here except `tasks()`'s output is client-private. The
    life cycle is tasks → (transport) → collect, or just `run(transport)`
    which does both and prefers the fused sweep on fused transports.
    """

    client: SPDCClient
    kind: str  # "single" | "batch" | "mixed"
    num_servers: int
    x_aug: jnp.ndarray  # (…, n', n') augmented CIPHERTEXT (client-held)
    seeds: list[Seed]
    metas: list[CipherMeta]
    log2_scale: Any
    n: int  # raw size (single/batch) or the common n' (mixed)
    padding: int
    digest: bytes
    plan: tuple = ()
    tamper: Any = None
    paddings: list[int] | None = None
    pad_to: int | None = None
    #: rateless over-decomposition: F > N strips (None = classic, one
    #: strip per server). The PARTITION geometry (authenticate blocks,
    #: strip minting, recovery) keys off `partitions`; `num_servers`
    #: stays the physical fleet size.
    num_strips: int | None = None
    fleet_report: Any = None
    #: retain the verified (possibly healed) factors after collect() so
    #: linalg.LinalgSession can grow its op plan — solve/inv rounds reuse
    #: the SAME verified LU instead of outsourcing a second factorization
    keep_factors: bool = False
    _factors: tuple | None = None
    _m_host: np.ndarray | None = None
    _m_hosts: list[np.ndarray] = field(default_factory=list)
    # phase timings feeding SPDCReport.timings (client.open_session stamps
    # _pmop_s; run/start stamp _dispatch_s; collect adds its own)
    _pmop_s: float = 0.0
    _dispatch_s: float = 0.0

    def __post_init__(self):
        from repro.distrib.recovery import dispatch_subseed

        # opaque routing tag: one-way derived from the secret digest so it
        # can be logged/echoed without leaking probe or channel material
        self.session_id = dispatch_subseed(self.digest, -1, -1)[:8].hex()

    # -- geometry ------------------------------------------------------------

    @property
    def n_aug(self) -> int:
        return int(self.x_aug.shape[-1])

    @property
    def block(self) -> int:
        return self.n_aug // self.num_servers

    @property
    def partitions(self) -> int:
        """Block rows the protocol partitions n' into: F when rateless,
        N classically. Verification, recovery, and task minting all key
        off this count — authenticate works for ANY divisor of n'."""
        return self.num_strips or self.num_servers

    @property
    def strip_block(self) -> int:
        return self.n_aug // self.partitions

    @property
    def batch(self) -> int | None:
        return int(self.x_aug.shape[0]) if self.x_aug.ndim == 3 else None

    # -- dispatch ------------------------------------------------------------

    def tasks(self, *, check_boundary: bool | None = None) -> list[ShardTask]:
        """The initial ShardTasks — one encrypted block row + dispatch
        sub-seed per partition (N classically, F when rateless).
        u_upstream is left to the transport's relay.

        check_boundary: None (default) runs the structural boundary
        checks always and the full entry-level plaintext screening up to
        ~1M payload elements; True forces the full screening at any size;
        False runs structural checks only.
        """
        from repro.distrib.recovery import dispatch_subseed

        b = self.strip_block
        out = []
        for i in range(self.partitions):
            out.append(
                ShardTask(
                    server=i,
                    num_servers=self.partitions,
                    x_row=np.asarray(
                        self.x_aug[..., i * b : (i + 1) * b, :]
                    ),
                    subseed=dispatch_subseed(self.digest, i, 0),
                    style="nserver",
                    session_id=self.session_id,
                )
            )
        self._assert_boundary(out, check_boundary)
        return out

    def _repair_task(self, server: int, attempt: int, u) -> ShardTask:
        """A verification-driven re-issue for one blamed block row: fresh
        dispatch sub-seed, verified upstream U rows attached (the
        replacement is stateless and the culprit's relay is untrusted)."""
        from repro.distrib.recovery import dispatch_subseed

        b, s0 = self.strip_block, server * self.strip_block
        return ShardTask(
            server=server,
            num_servers=self.partitions,
            x_row=np.asarray(self.x_aug[..., s0 : s0 + b, :]),
            subseed=dispatch_subseed(self.digest, server, attempt),
            style=self._style,
            attempt=attempt,
            u_upstream=np.asarray(u[..., :s0, :]),
            session_id=self.session_id,
        )

    def _assert_boundary(self, tasks, check_boundary) -> None:
        """No plaintext, no key material, no unexpected fields — checked
        at the moment messages are minted, not left to code review."""
        plaintexts = (
            self._m_hosts if self._m_hosts
            else ([self._m_host] if self._m_host is not None else [])
        )
        total = sum(t.x_row.size for t in tasks)
        full = check_boundary or (
            check_boundary is None and total <= _FULL_CHECK_ELEMS
        )
        secrets = np.asarray([s.psi for s in self.seeds])

        def informative(a):
            # exact 0/±1 entries are structural constants (zero border,
            # identity block) that carry no client information — screening
            # them would false-alarm on sparse client matrices
            a = np.asarray(a).ravel()
            return a[(a != 0.0) & (np.abs(a) != 1.0)]

        # the plaintext side of the screen is loop-invariant: filter and
        # sort it once, not once per task
        plain_sorted = [np.sort(informative(m)) for m in plaintexts] \
            if full else []

        def leaks(payload, reference_sorted):
            if not reference_sorted.size or not payload.size:
                return False
            idx = np.clip(np.searchsorted(reference_sorted, payload),
                          0, reference_sorted.size - 1)
            return bool(np.any(reference_sorted[idx] == payload))

        for t in tasks:
            extra = set(vars(t)) - _TASK_FIELDS
            if extra:
                raise BoundaryViolation(
                    f"ShardTask grew unreviewed fields {sorted(extra)}"
                )
            if not (isinstance(t.subseed, bytes) and len(t.subseed) == 32):
                raise BoundaryViolation("subseed must be a 32-byte digest")
            for m in plaintexts:
                if np.shares_memory(t.x_row, m):
                    raise BoundaryViolation(
                        "ShardTask payload aliases the plaintext buffer"
                    )
            if full:
                payload = informative(t.x_row)
                for ref in plain_sorted:
                    if leaks(payload, ref):
                        raise BoundaryViolation(
                            "ShardTask payload contains verbatim plaintext "
                            "entries — cipher did not run?"
                        )
                if leaks(payload, np.sort(secrets)):
                    raise BoundaryViolation(
                        "ShardTask payload contains client key material"
                    )

    # -- execution -----------------------------------------------------------

    _style: str = "nserver"

    def _resolve_transport(self, transport):
        """None falls back to the client's configured transport (which
        itself defaults to inline)."""
        if transport is None:
            transport = self.client.transport
        return resolve_transport(transport)

    def run(self, transport=None):
        """Dispatch + collect through a transport (default: the client's
        configured one, else inline).

        Rateless sessions always take the streaming scheduler — the
        fused sweep has no per-strip dispatch for health tracking to
        steer (distrib.rateless; DESIGN.md §8).
        """
        transport = self._resolve_transport(transport)
        self._style = transport.style
        t0 = time.perf_counter()
        if self.num_strips is not None:
            from repro.distrib.rateless import run_rateless

            self._style = "nserver"  # the scheduler's strip primitive
            l_host, u_host, rpt = run_rateless(
                self, transport, self.client.rateless, self.client.fleet,
                faults=self.plan,
            )
            self.fleet_report = rpt
            dt = self.x_aug.dtype
            l, u = jnp.asarray(l_host, dtype=dt), jnp.asarray(u_host, dtype=dt)
        elif transport.fused:
            l, u = transport.sweep(self.x_aug, self.num_servers,
                                   faults=self.plan)
        else:
            results = transport.factor(self.tasks(), faults=self.plan)
            l, u = self._assemble(results)
        self._dispatch_s = time.perf_counter() - t0
        return self.collect((l, u), transport=transport)

    def start(self, transport=None) -> "PendingResult":
        """Nonblocking dispatch: ship this session's Parallelize stage
        and return a PendingResult whose `.result()` runs the RRVP tail.

        On message transports the sweep rides the transport's driver
        threads (`Transport.driver_submit`), so the caller's NEXT
        `open_session` — the client PMOP for batch k+1 — overlaps this
        session's wire time; `SPDCClient.run_pipelined` is the loop
        built on exactly this. Fused transports complete the future
        synchronously — jax's own async dispatch already provides the
        overlap there.
        """
        transport = self._resolve_transport(transport)
        self._style = transport.style
        t0 = time.perf_counter()
        if self.num_strips is not None:
            from concurrent.futures import Future as _Future  # noqa: F401
            from repro.distrib.rateless import run_rateless

            self._style = "nserver"

            def drive_rateless():
                l_host, u_host, rpt = run_rateless(
                    self, transport, self.client.rateless,
                    self.client.fleet, faults=self.plan,
                )
                self.fleet_report = rpt
                dt = self.x_aug.dtype
                out = (jnp.asarray(l_host, dtype=dt),
                       jnp.asarray(u_host, dtype=dt))
                self._dispatch_s = time.perf_counter() - t0
                return out

            future = transport.driver_submit(drive_rateless)
        elif transport.fused:
            from concurrent.futures import Future as _Future

            future = _Future()
            try:
                future.set_result(
                    transport.sweep(self.x_aug, self.num_servers,
                                    faults=self.plan)
                )
                self._dispatch_s = time.perf_counter() - t0
            except Exception as e:  # noqa: BLE001 — future carries it
                future.set_exception(e)
        else:
            tasks = self.tasks()  # boundary-checked on THIS thread

            def drive_factor():
                out = transport.factor(tasks, self.plan)
                self._dispatch_s = time.perf_counter() - t0
                return out

            future = transport.driver_submit(drive_factor)
        return PendingResult(session=self, transport=transport,
                             future=future)

    def _assemble(self, results) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Stack per-partition strips into full (…, n', n') factors."""
        byid = {r.server: r for r in results}
        if sorted(byid) != list(range(self.partitions)):
            raise ValueError(
                f"need one ShardResult per partition, got {sorted(byid)}"
            )
        l = np.concatenate(
            [np.asarray(byid[i].l_row) for i in range(self.partitions)],
            axis=-2,
        )
        u = np.concatenate(
            [np.asarray(byid[i].u_row) for i in range(self.partitions)],
            axis=-2,
        )
        dt = self.x_aug.dtype
        return jnp.asarray(l, dtype=dt), jnp.asarray(u, dtype=dt)

    # -- RRVP: verify, heal, decipher ---------------------------------------

    def collect(self, results, *, transport=None):
        """Authenticate → (recovery) → Decipher.

        results: an (L, U) pair of full factors, or a list of
        ShardResults to assemble. Returns core.protocol.SPDCResult /
        SPDCBatchResult exactly as the facades always have.
        """
        from repro.core.protocol import (
            SPDCBatchResult, SPDCReport, SPDCResult, SessionTimings,
            _probe_rng,
        )
        from repro.distrib.recovery import recover_lu

        t_collect = time.perf_counter()
        transport = self._resolve_transport(transport)
        self._style = transport.style
        if (isinstance(results, tuple) and len(results) == 2
                and not isinstance(results[0], ShardResult)):
            l, u = results
        else:
            l, u = self._assemble(results)
        if self.tamper is not None:
            l, u = self.tamper(l, u)
        verdict = authenticate(
            l, u, self.x_aug, num_servers=self.partitions,
            method=self.client.method, rng=_probe_rng(self.digest),
        )
        report = None
        if self.client.recover and not bool(np.all(verdict.ok)):
            fleet = self.client.fleet

            def dispatch(x, u_now, server, attempt, replacement):
                # recovery IS re-streaming one strip: rateless sessions
                # route the re-issue to the healthiest live worker (or
                # compute it inline when the fleet is gone) instead of
                # the pool's positional replacement
                task = self._repair_task(server, attempt, u_now)
                if fleet is not None:
                    ids = tuple(range(self.num_servers))
                    live = (fleet.assignable(ids, set(), time.monotonic())
                            or fleet.live(ids))
                    if live:
                        res = transport.repair(task, replacement=live[0])
                    else:
                        from .server import EdgeServer

                        res = EdgeServer(None).run(task)
                else:
                    res = transport.repair(task, replacement=replacement)
                dt = self.x_aug.dtype
                return (jnp.asarray(res.l_row, dtype=dt),
                        jnp.asarray(res.u_row, dtype=dt))

            l, u, verdict, report = recover_lu(
                l, u, self.x_aug, num_servers=self.partitions,
                method=self.client.method, standby=self.client.standby,
                digest=self.digest, style=self._style, verdict=verdict,
                dispatch=dispatch,
            )
        if self.keep_factors:
            # post-recovery: these are the factors Authenticate accepted,
            # so every later trisolve round goes through healed material
            self._factors = (np.asarray(l), np.asarray(u))
        comm = (
            None if transport.style == "pipeline"
            else nserver_comm_model(self.n_aug, self.partitions)
        )

        def build_report() -> SPDCReport:
            collect_s = time.perf_counter() - t_collect
            return SPDCReport(
                verdict=verdict,
                recovery=report,
                fleet=self.fleet_report,
                timings=SessionTimings(
                    pmop_s=self._pmop_s,
                    dispatch_s=self._dispatch_s,
                    collect_s=collect_s,
                    total_s=self._pmop_s + self._dispatch_s + collect_s,
                ),
            )

        if self.kind == "single":
            det = decipher(self.seeds[0], self.metas[0], l, u,
                           faithful=self.client.faithful_sign,
                           log2_scale=self.log2_scale)
            return SPDCResult(
                det=det,
                verified=bool(np.all(verdict.ok)),
                residual=verdict.residual,
                seed=self.seeds[0],
                meta=self.metas[0],
                comm=comm,
                padding=self.padding,
                num_servers=self.num_servers,
                report=build_report(),
            )
        dets = decipher_batch(self.seeds, self.metas, l, u,
                              faithful=self.client.faithful_sign,
                              log2_scale=np.asarray(self.log2_scale))
        return SPDCBatchResult(
            dets=dets,
            verified=np.atleast_1d(np.asarray(verdict.ok)),
            residual=np.atleast_1d(np.asarray(verdict.residual)),
            seeds=self.seeds,
            metas=self.metas,
            comm=comm,
            padding=self.padding,
            num_servers=self.num_servers,
            report=build_report(),
            paddings=self.paddings,
            pad_to=self.pad_to,
        )


@dataclass
class PendingResult:
    """A `Session.start`ed protocol run awaiting its RRVP tail.

    `result(timeout=)` blocks on the in-flight Parallelize stage (the
    timeout is a client-side wait — expiry raises TransportTimeout and
    the dispatch keeps running; call `result` again to re-wait), then
    runs `Session.collect` on the CALLING thread: authenticate, recovery,
    and decipher touch session secrets and stay on the client thread by
    construction — only the wire wait is asynchronous.
    """

    session: Session
    transport: Any
    future: Any

    def done(self) -> bool:
        """True once the dispatch resolved (collect still pending)."""
        return self.future.done()

    def result(self, timeout: float | None = None):
        out = self.transport.result(self.future, timeout)
        return self.session.collect(out, transport=self.transport)
