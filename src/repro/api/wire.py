"""SPDC wire format — the serializable face of the role-split API.

Every message that crosses the client ↔ edge-server trust boundary
(ShardTask, ShardResult) or is archived/relayed by infrastructure
(Verdict, Determinant) encodes to a self-describing byte frame:

    ┌──────┬─────┬──────────────┬─────────────────┬───────────────────┐
    │ SPDC │ ver │ header nbytes│ header (JSON)    │ array buffers …   │
    │ 4 B  │ 1 B │ u32 big-end. │ utf-8            │ 16-byte aligned   │
    └──────┴─────┴──────────────┴─────────────────┴───────────────────┘

The JSON header carries the message kind, every scalar field (ints,
floats, bools, strings, None), `bytes` fields hex-encoded, and an array
table — one entry per ndarray payload with dtype/shape/offset — whose raw
little-endian buffers follow the header, each padded to a 16-byte offset
so zero-copy `np.frombuffer` views stay aligned.

Design constraints (why not pickle):

  * messages cross a TRUST boundary — the client must be able to decode a
    ShardResult from a malicious server without executing anything, and a
    server must decode ShardTasks without trusting the client. JSON +
    fixed dtype/shape tables are data, never code.
  * the format is language-agnostic and versioned (`VERSION` byte), so a
    non-Python edge worker can speak it.
  * floats in array payloads round-trip bit-exactly (raw IEEE buffers);
    scalar floats ride through JSON `repr` (shortest round-trip in
    Python ≥ 3.1) — also exact.

`encode(kind, scalars, arrays)` / `decode(data)` are the primitive pair;
message classes register themselves in `MESSAGE_KINDS` so
`decode_message(data)` can dispatch a frame of any known kind (the
transports' receive loop).
"""
from __future__ import annotations

import json
import struct

import numpy as np

MAGIC = b"SPDC"
VERSION = 1
_ALIGN = 16

#: kind (str) -> class with a `_from_wire(scalars, arrays)` classmethod;
#: populated by each message module at import time (see register()).
MESSAGE_KINDS: dict[str, type] = {}


class WireError(ValueError):
    """Malformed, truncated, or unknown-kind frame."""


def register(kind: str):
    """Class decorator: make `decode_message` able to dispatch `kind`."""

    def deco(cls):
        MESSAGE_KINDS[kind] = cls
        cls.wire_kind = kind
        return cls

    return deco


def _pad(offset: int) -> int:
    return (offset + _ALIGN - 1) // _ALIGN * _ALIGN


def encode(kind: str, scalars: dict, arrays: dict) -> bytes:
    """Encode one frame. `scalars` values must be JSON-able or bytes;
    `arrays` values are ndarrays (or None, recorded as absent-but-named so
    decode restores the None)."""
    header: dict = {"kind": kind, "scalars": {}, "bytes": {}, "arrays": []}
    for name, val in scalars.items():
        if isinstance(val, bytes):
            header["bytes"][name] = val.hex()
        elif isinstance(val, float):
            # repr round-trips IEEE-754 doubles exactly; JSON numbers may
            # be re-formatted by other emitters, so pin the string form
            header["scalars"][name] = {"__float__": repr(val)}
        else:
            header["scalars"][name] = val
    buffers: list[tuple[int, bytes]] = []
    offset = 0
    for name, arr in arrays.items():
        if arr is None:
            header["arrays"].append({"name": name, "none": True})
            continue
        arr = np.ascontiguousarray(arr)
        if arr.dtype.byteorder == ">":  # normalize to little-endian wire
            arr = arr.astype(arr.dtype.newbyteorder("<"))
        offset = _pad(offset)
        raw = arr.tobytes()
        header["arrays"].append(
            {
                "name": name,
                "dtype": arr.dtype.str,
                "shape": list(arr.shape),
                "offset": offset,
                "nbytes": len(raw),
            }
        )
        buffers.append((offset, raw))
        offset += len(raw)
    hjson = json.dumps(header, separators=(",", ":")).encode()
    head = MAGIC + struct.pack(">BI", VERSION, len(hjson)) + hjson
    body_start = _pad(len(head))
    out = bytearray(body_start + offset)
    out[: len(head)] = head
    for off, raw in buffers:
        out[body_start + off : body_start + off + len(raw)] = raw
    return bytes(out)


def decode(data: bytes) -> tuple[str, dict, dict]:
    """Decode one frame → (kind, scalars, arrays). bytes fields come back
    as bytes; None arrays come back as None; float scalars bit-exact."""
    if len(data) < len(MAGIC) + 5 or data[: len(MAGIC)] != MAGIC:
        raise WireError("not an SPDC wire frame (bad magic)")
    version, hlen = struct.unpack_from(">BI", data, len(MAGIC))
    if version != VERSION:
        raise WireError(f"unsupported wire version {version}")
    hstart = len(MAGIC) + 5
    if len(data) < hstart + hlen:
        raise WireError("truncated frame (header)")
    try:
        header = json.loads(data[hstart : hstart + hlen].decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireError(f"bad frame header: {e}") from e
    scalars = {}
    for name, val in header.get("scalars", {}).items():
        if isinstance(val, dict) and "__float__" in val:
            val = float(val["__float__"])
        scalars[name] = val
    for name, hexval in header.get("bytes", {}).items():
        scalars[name] = bytes.fromhex(hexval)
    body_start = _pad(hstart + hlen)
    arrays = {}
    for spec in header.get("arrays", []):
        name = spec.get("name")
        if spec.get("none"):
            arrays[name] = None
            continue
        # every header-supplied field is attacker-controlled: a frame from
        # a malicious server must either decode to exactly what a wellformed
        # encoder produced or raise WireError — never reinterpret header
        # bytes (negative offsets), object dtypes, or impossible shapes
        try:
            offset, nbytes = int(spec["offset"]), int(spec["nbytes"])
            shape = tuple(int(s) for s in spec["shape"])
            dtype = np.dtype(spec["dtype"])
        except (KeyError, TypeError, ValueError) as e:
            raise WireError(f"bad array spec for {name!r}: {e}") from e
        if dtype.hasobject:
            raise WireError(f"non-plain dtype {dtype} in array {name!r}")
        if offset < 0 or nbytes < 0 or any(s < 0 for s in shape):
            raise WireError(f"negative offset/size in array {name!r}")
        start = body_start + offset
        end = start + nbytes
        if end > len(data):
            raise WireError(f"truncated frame (array {name!r})")
        try:
            arr = np.frombuffer(data[start:end], dtype=dtype).reshape(shape)
        except ValueError as e:
            raise WireError(f"array {name!r} does not decode: {e}") from e
        arrays[name] = arr
    return header["kind"], scalars, arrays


def decode_message(data: bytes):
    """Decode a frame of any registered kind into its message object."""
    kind, scalars, arrays = decode(data)
    cls = MESSAGE_KINDS.get(kind)
    if cls is None:
        raise WireError(
            f"unknown message kind {kind!r}; known: {sorted(MESSAGE_KINDS)}"
        )
    return cls._from_wire(scalars, arrays)
