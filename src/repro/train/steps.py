"""Train-step factory: value_and_grad over the model loss with microbatch
gradient accumulation (lax.scan), remat policy from the config, optional
Freivalds SDC verification (the paper's Q2 idea at training scale), and the
AdamW update. One jit-compiled function per (config, opt, flags) triple.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.sdc import freivalds_residual
from repro.models.lm import forward_hidden, lm_loss
from .optimizer import AdamWConfig, adamw_update

F32 = jnp.float32


def build_train_step(cfg, opt_cfg: AdamWConfig, *, sdc_check: bool = False,
                     ce_chunk: int = 512):
    """Returns train_step(params, opt_state, batch, key) ->
    (params, opt_state, metrics)."""

    def loss_fn(params, mb):
        return lm_loss(params, mb, cfg, remat_policy=cfg.remat,
                       ce_chunk=ce_chunk)

    accum_dtype = jnp.float32 if cfg.optimizer_dtype == "float32" else jnp.bfloat16

    def compute_grads(params, batch):
        a = cfg.grad_accum
        if a == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        mbs = jax.tree.map(
            lambda x: x.reshape((a, x.shape[0] // a) + x.shape[1:]), batch
        )

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree.map(lambda ga, gi: ga + gi.astype(ga.dtype), g_acc, g)
            return (loss_acc + loss.astype(F32), g_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
        (loss_sum, grads), _ = lax.scan(body, (jnp.zeros((), F32), g0), mbs)
        return loss_sum / a, jax.tree.map(lambda g: (g / a), grads)

    def train_step(params, opt_state, batch, key):
        loss, grads = compute_grads(params, batch)
        metrics = {"loss": loss}
        if sdc_check:
            # verify the head matmul on a probe slice (paper's Q2 / Freivalds
            # as silent-data-corruption detection, DESIGN.md §2)
            hidden, _ = forward_hidden(
                params,
                jax.tree.map(lambda x: x[:1, :128], batch),
                cfg,
                remat_policy="none",
            )
            probe = hidden[0].astype(F32)
            head = params["lm_head"].astype(F32)
            claim = probe @ head
            metrics["sdc_residual"] = freivalds_residual(probe, head, claim, key)
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg
        )
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def build_eval_step(cfg, *, ce_chunk: int = 512):
    def eval_step(params, batch):
        return lm_loss(params, batch, cfg, remat_policy="none",
                       ce_chunk=ce_chunk)

    return eval_step
