"""AdamW from scratch (no optax dependency) with global-norm clipping,
linear-warmup cosine schedule, and configurable state dtype.

State dtype matters at fleet scale (DESIGN.md §5): f32 moments for a 340B
model are 2.7 TB; bf16 moments halve optimizer HBM and are the difference
between fitting and not fitting 256×16 GB for the two ≥340B archs. The
moment update is computed in f32 and stored in the state dtype.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: Any = jnp.float32


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_opt_state(params, cfg: AdamWConfig) -> dict:
    def zeros(p):
        return jnp.zeros(p.shape, cfg.state_dtype)

    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(F32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))
    lr = schedule(cfg, step.astype(F32))
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(F32)
    bc2 = 1.0 - b2 ** step.astype(F32)

    def upd(p, g, mu, nu):
        g = g.astype(F32) * scale
        mu_f = b1 * mu.astype(F32) + (1 - b1) * g
        nu_f = b2 * nu.astype(F32) + (1 - b2) * jnp.square(g)
        mhat = mu_f / bc1
        vhat = nu_f / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(F32)
        new_p = (p.astype(F32) - lr * delta).astype(p.dtype)
        return new_p, mu_f.astype(mu.dtype), nu_f.astype(nu.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    # Chain per-leaf updates through optimization_barrier so XLA schedules
    # them sequentially: the f32 intermediates of ONE leaf are live at a
    # time, not all leaves at once (340B models: ~25 GB -> ~2 GB peak).
    out = []
    token = None
    for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu, strict=True):
        if token is not None:
            p, g, m, n, _ = jax.lax.optimization_barrier((p, g, m, n, token))
        res = upd(p, g, m, n)
        out.append(res)
        token = res[1]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree.unflatten(tdef, [o[1] for o in out]),
        "nu": jax.tree.unflatten(tdef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
