"""Checkpointing: sharded-friendly, atomic, async, elastic.

Layout (one directory per step):

    ckpt_dir/step_000042.tmp-<pid>/   — written here first
        manifest.json                 — tree structure, shapes, dtypes, hashes
        leaf_000000.npy …             — one file per leaf (params + opt state)
    ckpt_dir/step_000042/             — atomic os.rename on completion

Properties the fleet story needs:
  * atomicity      — a crash mid-write never corrupts the latest checkpoint
                     (tmp dir + rename; restore only reads complete dirs)
  * integrity      — per-leaf SHA-256 in the manifest, verified on restore
                     (a silently corrupted disk block fails loudly)
  * async          — save runs on a writer thread off the training loop;
                     `wait()` joins before the next save or process exit
  * elastic        — restore() returns host arrays + the manifest;
                     `restore_sharded` device_puts onto ANY mesh/sharding,
                     so a 512-chip checkpoint restarts on 256 chips (or the
                     CPU tests' 4 fake devices) without conversion
  * gc             — keep_last_k pruning, never removing the newest
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten_with_paths(tree):
    from repro.compat import tree_flatten_with_path

    flat, treedef = tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def _sha(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str | Path, keep_last: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()
        if blocking:
            self._write(step, host)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True
            )
            self._thread.start()

    def _write(self, step: int, host_tree) -> None:
        final = self.dir / f"step_{step:09d}"
        tmp = self.dir / f"step_{step:09d}.tmp-{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        paths, leaves, _ = _flatten_with_paths(host_tree)
        manifest = {"step": step, "leaves": []}
        for i, (p, leaf) in enumerate(zip(paths, leaves, strict=True)):
            fname = f"leaf_{i:06d}.npy"
            np.save(tmp / fname, leaf)
            manifest["leaves"].append(
                {"path": p, "file": fname, "shape": list(leaf.shape),
                 "dtype": str(leaf.dtype), "sha": _sha(leaf)}
            )
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in self.dir.iterdir():
            if (d.is_dir() and d.name.startswith("step_")
                    and "tmp" not in d.name
                    and (d / "manifest.json").exists()):
                out.append(int(d.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None, *, verify: bool = True):
        """Host-array tree matching `template`'s structure."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        paths, _, treedef = _flatten_with_paths(template)
        by_path = {m["path"]: m for m in manifest["leaves"]}
        leaves = []
        for p in paths:
            m = by_path[p]
            arr = np.load(d / m["file"])
            if verify and _sha(arr) != m["sha"]:
                raise IOError(f"checkpoint corruption detected in {p}")
            leaves.append(arr)
        return jax.tree.unflatten(treedef, leaves), step

    def restore_sharded(self, template, shardings, step: int | None = None):
        """Elastic restore: place onto any mesh via per-leaf device_put."""
        host, step = self.restore(template, step)
        placed = jax.tree.map(
            lambda arr, t, s: jax.device_put(arr.astype(t.dtype), s)
            if s is not None else jax.device_put(arr.astype(t.dtype)),
            host, template, shardings,
        )
        return placed, step
