"""Training substrate: optimizer, steps, checkpointing, data, loop."""
