"""Deterministic synthetic data pipeline.

Every batch is a pure function of (seed, step, shard) — the property the
fault-tolerance story rests on: any worker, restarted anywhere, regenerates
exactly the batch any failed worker would have produced (no data-loader
state to checkpoint, no straggler re-shuffle protocol).

Tokens follow a order-1 Markov chain built from the seed (not uniform
noise), so models actually have structure to learn in the end-to-end
examples; frontends get unit-Gaussian embeddings (stub modality input).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _markov_logits(vocab: int, seed: int, branch: int = 32) -> np.ndarray:
    """Sparse-ish row-stochastic transition matrix (vocab, branch)."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, size=(vocab, branch))


class SyntheticLM:
    """tokens[t+1] = transition[tokens[t], choice] — learnable structure."""

    def __init__(self, cfg, seed: int = 0, branch: int = 32):
        self.cfg = cfg
        self.vocab = cfg.vocab_size
        self.branch = branch
        self.nexts = jnp.asarray(_markov_logits(self.vocab, seed, branch))
        self.seed = seed

    def batch(self, step: int, batch_size: int, seq_len: int) -> dict:
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        k0, k1 = jax.random.split(key)
        first = jax.random.randint(k0, (batch_size,), 0, self.vocab)
        choices = jax.random.randint(
            k1, (batch_size, seq_len - 1), 0, self.branch
        )

        def gen(tok, choice):
            nxt = self.nexts[tok, choice]
            return nxt, nxt

        _, rest = jax.lax.scan(
            lambda t, c: gen(t, c), first, jnp.moveaxis(choices, 1, 0)
        )
        tokens = jnp.concatenate([first[None], rest], axis=0).T  # (B, S)
        if self.cfg.frontend is not None:
            kf = jax.random.fold_in(key, 7)
            embeds = jax.random.normal(
                kf, (batch_size, seq_len, self.cfg.d_model), jnp.float32
            )
            return {"embeds": embeds, "labels": tokens.astype(jnp.int32)}
        return {"tokens": tokens.astype(jnp.int32),
                "labels": tokens.astype(jnp.int32)}

    def shard_batch(self, step: int, global_batch: int, seq_len: int,
                    shard: int, num_shards: int) -> dict:
        """The shard-local slice, regenerated identically by any worker."""
        full = self.batch(step, global_batch, seq_len)
        per = global_batch // num_shards
        return jax.tree.map(lambda x: x[shard * per : (shard + 1) * per], full)
