"""Fault-tolerant training loop.

Failure model (what a 1000-node fleet actually sees) and the countermeasure
implemented here:

  * process/node crash      → auto-resume from the latest complete atomic
                              checkpoint; deterministic data (train/data.py)
                              means the replayed steps are bit-identical
  * silent data corruption  → per-step Freivalds residual (paper's Q2); a
                              step whose residual exceeds the bound is
                              discarded (params/opt rolled forward from the
                              pre-step values) and counted
  * stragglers              → per-step wall-time tracked against a running
                              median; a step slower than `straggler_factor`×
                              median raises a StragglerEvent to the caller's
                              hook (in a real fleet: re-shard or evict; here:
                              observable + tested via injection)
  * checkpoint corruption   → SHA-verified restore falls back to the
                              previous checkpoint automatically
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import numpy as np

from .checkpoint import CheckpointManager


@dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    log_every: int = 10
    keep_last: int = 3
    straggler_factor: float = 3.0
    sdc_threshold: float = 1e-3
    max_restarts: int = 5


@dataclass
class LoopReport:
    steps_run: int = 0
    restarts: int = 0
    sdc_rejects: int = 0
    straggler_events: list = field(default_factory=list)
    losses: list = field(default_factory=list)


def run_training(
    train_step: Callable,
    params,
    opt_state,
    data_fn: Callable[[int], dict],
    ckpt: CheckpointManager,
    loop_cfg: LoopConfig,
    *,
    key=None,
    fault_injector: Callable[[int], None] | None = None,
    on_straggler: Callable | None = None,
) -> tuple[object, object, LoopReport]:
    """Run (and if needed re-run) steps until total_steps, surviving
    injected faults. data_fn(step) -> batch (deterministic)."""
    report = LoopReport()
    key = key if key is not None else jax.random.key(0)

    # resume if a checkpoint exists
    start = 0
    state_tpl = {"params": params, "opt": opt_state}
    if ckpt.latest_step() is not None:
        try:
            restored, at = ckpt.restore(state_tpl)
            params, opt_state = restored["params"], restored["opt"]
            start = at
        except IOError:
            steps = ckpt.all_steps()
            if len(steps) > 1:
                restored, at = ckpt.restore(state_tpl, steps[-2])
                params, opt_state = restored["params"], restored["opt"]
                start = at

    step = start
    times: list[float] = []
    restarts = 0
    while step < loop_cfg.total_steps:
        try:
            if fault_injector is not None:
                fault_injector(step)  # may raise to simulate a node failure
            t0 = time.perf_counter()
            batch = data_fn(step)
            new_params, new_opt, metrics = train_step(
                params, opt_state, batch, jax.random.fold_in(key, step)
            )
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0

            # SDC gate: reject the update, keep the step counter moving
            resid = float(metrics.get("sdc_residual", 0.0))
            if resid > loop_cfg.sdc_threshold:
                report.sdc_rejects += 1
            else:
                params, opt_state = new_params, new_opt

            times.append(dt)
            if len(times) >= 5:
                med = statistics.median(times[-50:])
                if dt > loop_cfg.straggler_factor * med:
                    report.straggler_events.append((step, dt, med))
                    if on_straggler is not None:
                        on_straggler(step, dt, med)
            report.losses.append(loss)
            report.steps_run += 1
            step += 1
            if step % loop_cfg.checkpoint_every == 0:
                ckpt.save(step, {"params": params, "opt": opt_state})
        except (RuntimeError, jax.errors.JaxRuntimeError):
            restarts += 1
            report.restarts = restarts
            if restarts > loop_cfg.max_restarts:
                raise
            # restart path: reload the latest complete checkpoint
            if ckpt.latest_step() is not None:
                restored, at = ckpt.restore(state_tpl)
                params, opt_state = restored["params"], restored["opt"]
                step = at
            else:
                step = 0
    ckpt.save(step, {"params": params, "opt": opt_state}, blocking=True)
    return params, opt_state, report
