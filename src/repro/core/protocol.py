"""SPDC end-to-end protocol — the paper's six-algorithm tuple
(SeedGen, KeyGen, Cipher, Parallelize, Authenticate, Decipher), §III–§IV.

As of the role-split redesign (DESIGN.md §7) this module is the stable
one-call FACADE over the role objects in `repro.api`:

    outsource_determinant(m, N)            # == SPDCClient(...).open_session(m, N).run(InlineTransport)

`repro.api.SPDCClient` owns the client-side PMOP (seed/key/cipher/
equilibrate/border) and the RRVP tail (verify/localize/recover/decipher);
`repro.api.EdgeServer` is the untrusted worker; a `Transport` carries the
`ShardTask`/`ShardResult` messages between them. The facades here keep
the historical signatures and result dataclasses unchanged, defaulting to
the fused inline transport — bit-identical to the pre-split protocol and
still the gateway's throughput path.

Batch-first (DESIGN.md §3): `outsource_determinant` accepts one matrix
(n, n) or a stack (B, n, n). The batched path runs every per-matrix stage
as one jitted device program over the stack — independent seeds, blinding
vectors, rotations, probes, and accept/reject decisions per matrix, but
ONE cipher launch, ONE sweep of the N-server schedule, ONE verify — which
is what makes high request throughput possible (see
benchmarks/run.py:throughput).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .cipher import CipherMeta, Mode
from .decipher import Determinant
from .lu import CommLog
from .seed import Seed
from .verify import Verdict


def resolve_dtype(dtype) -> jnp.dtype:
    """Canonical compute dtype for the protocol.

    Accepts a jnp/np dtype object or a string ("float32"/"float64").
    Canonicalization honors the x64 switch: with jax.enable_x64 OFF a
    float64 request resolves to float32 (the only float the backend will
    actually compute in) instead of warning per-array downstream.
    """
    if isinstance(dtype, str):
        dtype = jnp.dtype(dtype)
    return jax.dtypes.canonicalize_dtype(dtype)


def _low_precision(dtype) -> bool:
    """True for compute dtypes that need the growth-control stages."""
    return jnp.dtype(dtype).itemsize < 8


def _resolve_growth_controls(
    dtype, growth_safe, equilibrate, faithful_sign
) -> tuple[bool, bool]:
    """Default growth_safe/equilibrate ON for sub-f64 compute (where the
    no-pivot growth eats the mantissa — DESIGN.md §6), OFF for float64
    (bit-compatible with the pre-f32 protocol). Explicit booleans win."""
    auto = _low_precision(dtype)
    growth_safe = auto if growth_safe is None else bool(growth_safe)
    equilibrate = auto if equilibrate is None else bool(equilibrate)
    if growth_safe and faithful_sign:
        raise ValueError(
            "faithful_sign reproduces the paper's literal (-1)^k Decipher "
            "factor, which has no growth-safe-relayout analog; pass "
            "growth_safe=False (and expect float32 accuracy loss) or drop "
            "faithful_sign"
        )
    return growth_safe, equilibrate


@dataclass
class SessionTimings:
    """Wall-clock phase breakdown of one protocol run (seconds).

    pmop_s is the client-side prepare (seed/key/cipher/equilibrate/
    border); dispatch_s is the Parallelize stage as the client saw it —
    for message transports, dominated by wire time; collect_s is the
    RRVP tail (authenticate → recovery → decipher). With the
    async-overlap API (`Session.start` / `SPDCClient.run_pipelined`,
    DESIGN.md §9) batch k+1's pmop_s runs INSIDE batch k's dispatch_s —
    the sum of phases across a pipelined run exceeds its wall clock,
    which is the point.
    """

    pmop_s: float = 0.0
    dispatch_s: float = 0.0
    collect_s: float = 0.0
    total_s: float = 0.0


@dataclass(frozen=True)
class OpRecord:
    """One operation of a multi-op linalg session (DESIGN.md §12).

    The shared-LU op plan runs several client-facing ops (slogdet, solve,
    adjoint solve, inverse) through ONE outsourced factorization; each op
    appends one of these so SPDCReport covers the whole plan, not just
    the factor sweep. `round_trips` counts triangular-solve rounds the op
    added through the transport (0 for slogdet — it reads the already
    verified factors); `healed` counts chunks recovery re-dispatched.
    """

    op: str  # "factor" | "slogdet" | "solve" | "solve_t" | "inv"
    verified: bool = True
    residual: float = 0.0
    wall_s: float = 0.0
    round_trips: int = 0
    healed: int = 0


@dataclass
class SPDCReport:
    """The ONE typed diagnostics surface on a protocol result.

    Consolidates what used to be three ad-hoc optional result fields:

    verdict: structured Authenticate outcome (method, ε(N), per-server
        blame) — core.verify.Verdict.
    recovery: verification-driven re-dispatch log (None unless
        recover=True fired) — distrib.recovery.RecoveryReport.
    fleet: rateless dispatch report (strip counts, per-worker health;
        None on classic sessions) — distrib.rateless.RatelessReport.
    timings: wall-clock phase breakdown (None on paths that don't time
        themselves, e.g. a hand-driven tasks→collect flow).
    ops: per-operation timing/verdict records for multi-op linalg
        sessions (empty on plain determinant runs) — OpRecord.
    """

    verdict: Verdict | None = None
    recovery: object | None = None
    fleet: object | None = None
    timings: SessionTimings | None = None
    ops: tuple = ()


def _deprecated_report_field(name: str):
    """One-cycle shim: `result.verdict` etc. still answer, loudly."""

    @property
    def shim(self):
        warnings.warn(
            f"result.{name} is deprecated; read result.report.{name} "
            "(the consolidated SPDCReport surface)",
            DeprecationWarning,
            stacklevel=2,
        )
        return getattr(self.report, name)

    return shim


@dataclass
class SPDCResult:
    det: Determinant
    verified: bool
    residual: float
    seed: Seed
    meta: CipherMeta
    comm: CommLog | None
    padding: int
    num_servers: int
    #: consolidated diagnostics (verdict / recovery / fleet / timings)
    report: SPDCReport = field(default_factory=SPDCReport)

    # one-cycle deprecated aliases for the pre-consolidation fields
    verdict = _deprecated_report_field("verdict")
    recovery = _deprecated_report_field("recovery")
    fleet = _deprecated_report_field("fleet")


@dataclass
class SPDCBatchResult:
    """Per-matrix protocol outcomes for a (B, n, n) stack.

    `verified`/`residual` are (B,) arrays — one accept/reject decision per
    matrix (a single tampered matrix in the batch is flagged individually).

    `padding` is always a border *amount* (rows added), matching
    SPDCResult. On the uniform (B, n, n) path it is the per-matrix amount
    and `paddings`/`pad_to` are None. On the mixed-size path
    (`outsource_determinant_mixed`, the gateway's coalescing primitive)
    the amount differs per matrix: `paddings` lists them, `pad_to` is the
    common padded size n' the stack ran at, and `padding` is 0 — there is
    no single amount, so consumers of `n + padding` must use `pad_to`.
    """

    dets: list[Determinant]
    verified: np.ndarray
    residual: np.ndarray
    seeds: list[Seed]
    metas: list[CipherMeta]
    comm: CommLog | None
    padding: int
    num_servers: int
    #: consolidated diagnostics (verdict / recovery / fleet / timings)
    report: SPDCReport = field(default_factory=SPDCReport)
    #: mixed-size path only: per-matrix border amounts
    paddings: list[int] | None = None
    #: mixed-size path only: the common padded size n' of the sweep
    pad_to: int | None = None

    verdict = _deprecated_report_field("verdict")
    recovery = _deprecated_report_field("recovery")
    fleet = _deprecated_report_field("fleet")

    @property
    def batch(self) -> int:
        return len(self.dets)


def _probe_rng(digest: bytes) -> np.random.Generator:
    """Verification-probe generator keyed to client-secret material."""
    return np.random.default_rng(int.from_bytes(digest[:8], "big"))


def _batch_digest(seeds: list[Seed]) -> bytes:
    """One dispatch-channel digest for a whole stack: H(Ψ₀-digest ‖ … ‖
    Ψ_{B-1}-digest), so recovery sub-seeds are keyed to the batch's full
    secret material rather than matrix 0's alone."""
    import hashlib

    h = hashlib.sha256()
    for s in seeds:
        h.update(s.digest)
    return h.digest()


def _cipher_host(m: np.ndarray, v: np.ndarray, k: int, mode: Mode,
                 *, growth_safe: bool = False) -> np.ndarray:
    """Host-side Cipher for the mixed-size path: EWO row scaling + k
    clockwise quarter-turns, pure numpy.

    The gateway serves arbitrary client sizes; routing each raw (n, n)
    shape through the jnp cipher would compile a throwaway XLA program per
    distinct size. The O(n²) elementwise/relayout work is a host
    responsibility here (exactly the paper's client-side PMOP placement);
    the device only ever sees the uniform stacked bucket shape. numpy f64
    elementwise ops round identically to XLA-CPU f64, so results agree
    with core.cipher.cipher to the last ulp. growth_safe composes odd
    rotations with the exchange flip (core.cipher semantics).
    """
    if mode == "ewd":
        x = m / v.reshape(-1, 1)
    elif mode == "ewm":
        x = m * v.reshape(-1, 1)
    else:
        raise ValueError(f"unknown EWO mode: {mode!r}")
    x = np.rot90(x, k=-(k % 4))  # cw k turns == ccw -k (core.prt.rot90_cw)
    if growth_safe and k % 2 == 1:
        x = x[:, ::-1] if k % 4 == 1 else x[::-1, :]
    return np.ascontiguousarray(x)


def _equilibrate_host(x: np.ndarray) -> tuple[np.ndarray, int]:
    """numpy twin of core.cipher.equilibrate for the mixed-size path:
    power-of-two row then column scaling; returns (x_eq, log2_scale)."""
    def pow2_exp(maxabs):
        safe = np.where(maxabs > 0, maxabs, 1.0)
        return np.round(np.log2(safe)).astype(np.int64)

    e_r = pow2_exp(np.max(np.abs(x), axis=-1))
    x = x * np.exp2(-e_r.astype(x.dtype))[:, None]
    e_c = pow2_exp(np.max(np.abs(x), axis=-2))
    x = x * np.exp2(-e_c.astype(x.dtype))[None, :]
    return x, -int(e_r.sum() + e_c.sum())


def _augment_host(x: np.ndarray, p: int, rng: np.random.Generator) -> np.ndarray:
    """Host-side det-preserving border for the mixed-size path:
    [[X, 0], [R, I_p]] with R drawn from client-secret-keyed `rng`
    (core.augment semantics, numpy execution — same per-shape-compile
    rationale as _cipher_host)."""
    if p == 0:
        return x
    n = x.shape[-1]
    out = np.zeros((n + p, n + p), dtype=x.dtype)
    out[:n, :n] = x
    out[n:, :n] = rng.uniform(-1.0, 1.0, (p, n))
    out[n:, n:] = np.eye(p, dtype=x.dtype)
    return out


def common_padded_size(sizes, num_servers: int) -> int:
    """Smallest n' ≥ max(sizes) that the N-server schedule accepts
    (n' % N == 0 and n'/N > 1) — the shared shape a mixed-size stack is
    padded to before one coalesced sweep."""
    from .augment import padding_for_servers

    n = max(int(s) for s in sizes)
    return n + padding_for_servers(n, num_servers)


def _make_client(
    *, lambda1, lambda2, mode, method, use_kernel, faithful_sign,
    recover, standby, straggler_deadline, dtype, growth_safe, equilibrate,
    rateless=False,
):
    from repro.api import SPDCClient

    return SPDCClient(
        lambda1=lambda1, lambda2=lambda2, mode=mode, method=method,
        use_kernel=use_kernel, faithful_sign=faithful_sign,
        recover=recover, standby=standby,
        straggler_deadline=straggler_deadline, dtype=dtype,
        growth_safe=growth_safe, equilibrate=equilibrate,
        rateless=rateless,
    )


def outsource_determinant_mixed(
    ms,
    num_servers: int,
    *,
    pad_to: int | None = None,
    lambda1: int = 128,
    lambda2: int = 128,
    mode: Mode = "ewd",
    method: str = "q3",
    distributed: bool = False,
    faithful_sign: bool = False,
    tamper=None,
    faults=None,
    recover: bool = False,
    standby: int = 0,
    straggler_deadline: int | None = None,
    dtype="float64",
    growth_safe: bool | None = None,
    equilibrate: bool | None = None,
    transport=None,
    rateless=False,
) -> SPDCBatchResult:
    """Run the SPDC protocol for a *mixed-size* list of matrices in ONE
    coalesced N-server sweep — the gateway's batching primitive.

    Each matrix is ciphered at its own size (per-matrix Ψ, blinding vector,
    rotation — the host-side PMOP stages are O(n²) and cheap), then its
    ciphertext is padded post-cipher to the common size `pad_to` with the
    determinant-preserving [[X, 0], [R, I]] border (core.augment) so the
    whole stack shares one (B, n', n') shape and ONE jitted LU sweep, ONE
    batched verification, and one relay-hop schedule amortize over all B
    requests.

    Padding MUST happen after Cipher: the PRT stage rotates the matrix by
    a secret quarter-turn count, and any pre-cipher identity/zero border
    lands in a rotated position where the no-pivot LU hits structurally
    singular leading minors (see DESIGN.md §5.1). The post-cipher border
    never rotates; its Schur complement is exactly I, so it adds no
    element growth for any padding amount.

    pad_to: common padded size (defaults to the smallest valid size for
    the largest matrix, `common_padded_size`). Must satisfy
    pad_to % num_servers == 0 and pad_to / num_servers > 1.
    Remaining keywords match `outsource_determinant` (which routes list /
    tuple inputs here); `faults=`/`recover=`/`standby=` give the whole
    stack the fault-tolerance semantics of DESIGN.md §4, and `transport=`
    selects the execution boundary (DESIGN.md §7).

    Returns an SPDCBatchResult whose `pad_to` is the common n' and whose
    `paddings` list the per-matrix border amounts.
    """
    from repro.api import resolve_transport

    client = _make_client(
        lambda1=lambda1, lambda2=lambda2, mode=mode, method=method,
        use_kernel=False, faithful_sign=faithful_sign, recover=recover,
        standby=standby, straggler_deadline=straggler_deadline,
        dtype=dtype, growth_safe=growth_safe, equilibrate=equilibrate,
        rateless=rateless,
    )
    session = client.open_session(
        list(ms), num_servers, faults=faults, tamper=tamper, pad_to=pad_to
    )
    return session.run(resolve_transport(transport, distributed=distributed))


def outsource_determinant(
    m: np.ndarray | jnp.ndarray,
    num_servers: int,
    *,
    lambda1: int = 128,
    lambda2: int = 128,
    mode: Mode = "ewd",
    method: str = "q3",
    use_kernel: bool = False,
    distributed: bool = False,
    faithful_sign: bool = False,
    tamper=None,
    faults=None,
    recover: bool = False,
    standby: int = 0,
    straggler_deadline: int | None = None,
    dtype="float64",
    growth_safe: bool | None = None,
    equilibrate: bool | None = None,
    transport=None,
    rateless=False,
) -> SPDCResult | SPDCBatchResult:
    """Run the full SPDC protocol — the package's main entry point.

    Accepts one matrix (n, n), a same-size stack (B, n, n), or a Python
    list/tuple of mixed-size square matrices (routed through
    `outsource_determinant_mixed`: one coalesced sweep at a shared padded
    size — the gateway path, see repro.serve.spdc_gateway).

    Keyword reference (every public kwarg):

    num_servers: N, the edge-server count of the Parallelize stage. The
        ciphertext is padded so N divides its size (paper §IV.D.1).
    lambda1 / lambda2: security parameters of SeedGen / KeyGen — bits of
        entropy behind the seed Ψ and the blinding vector v (paper §IV.A).
    mode: element-wise obfuscation flavor, "ewd" (row-divide by v, the
        paper's default) or "ewm" (row-multiply).
    method: Authenticate residual — "q1" (Gao & Yu vector probe), "q2"
        (paper's scalar probe), "q3" (deterministic diagonal check,
        default), or "q3_literal" (paper's weaker literal form; see
        DESIGN.md §1.1.4).
    use_kernel: route Cipher through the fused Pallas CED kernel instead
        of the jnp oracle (TPU target; interpret-mode on CPU).
    distributed: route Parallelize through the shard_map pipeline — every
        mesh device plays one edge server (requires >= num_servers JAX
        devices); equivalent to transport="shardmap". See DESIGN.md §2.
    faithful_sign: reproduce the paper's literal (−1)^k rotation sign in
        Decipher instead of the Panth Rotation Theorem's case split —
        wrong for n ≡ 0,1 (mod 4); kept for faithfulness studies
        (DESIGN.md §1.1.3).
    tamper: optional fn (L, U) -> (L, U) applied to the servers' results
        before authentication — models a malicious edge server (tests use
        it to show Q2/Q3 reject tampered results, including a single bad
        matrix inside a batch).
    faults: a core.faults FaultPlan (or one ServerFault) — the structured
        untrusted-server model: per-server tamper/dropout/delay,
        batch-aware, applied inside the Parallelize stage (in-band faults
        poison the relay in the single-process simulation; the distributed
        pipeline injects at the device output; message transports play
        the faults on the matching WORKER, so every tamper is naturally
        in-band — the relay forwards what the worker reported).
    recover: on a rejected verdict, localize the faulty server (blocked-Q1
        attribution) and re-dispatch ONLY its shard — the Session emits a
        fresh ShardTask per blamed server through the same transport
        (distrib.recovery runs the loop) — result.report.recovery holds
        the RecoveryReport.
    standby: provision N+r spare servers for those re-dispatches
        (distrib.recovery.ServerPool).
    straggler_deadline: rounds after which a delayed server is treated as
        dropped and its shard re-dispatched (None = wait forever).
    dtype: compute dtype — "float64" (default; what the rtol 1e-10
        acceptance tests are calibrated for) or "float32" (the edge /
        accelerator profile — TPUs have no f64 and GPU f64 runs at 1/32
        rate). Strings or dtype objects accepted; with jax.enable_x64
        OFF, float64 resolves to float32. The ε(N) thresholds read the
        compute dtype's unit roundoff, so verification is calibrated for
        either (DESIGN.md §6).
    growth_safe: compose odd PRT rotations with a det-tracked exchange
        flip so a diagonally dominant input stays diagonally dominant
        under the no-pivot LU (None = auto: on for sub-f64 compute, off
        for float64). See DESIGN.md §6.1 for the precision/obfuscation
        trade.
    equilibrate: two-sided power-of-two scaling of the ciphertext, folded
        into Decipher exactly (None = same auto rule). Lossless in any
        binary float format; keeps ‖X‖-driven rounding flat (DESIGN.md
        §6.2).
    transport: execution boundary for the Parallelize stage (DESIGN.md
        §7/§9) — None (inline fused fast path, bit-identical to the
        pre-split protocol), a name ("threadpool"; "multiprocess" —
        spawned workers, ShardTask/ShardResult bytes on a real OS pipe;
        "socket" — warm worker daemons over TCP/UDS; "shardmap"), a
        repro.api.TransportConfig (declarative: name + addresses +
        timeout), or a live repro.api.Transport instance. All three
        spellings funnel through repro.api.resolve_transport.
    rateless: straggler-adaptive streaming dispatch (DESIGN.md §8) —
        True (default knobs) or a configs.spdc.RatelessConfig. The
        session over-decomposes into F = overdecompose·N strips and
        streams them to whichever workers are free; completion is
        "every strip verified", so there is no straggler_deadline to
        tune (the kwarg is ignored), slow workers just complete fewer
        strips, tampering workers get quarantined mid-session, and the
        client finishes strips inline if the fleet collapses.
        result.report.fleet carries the RatelessReport.

    Returns SPDCResult for a single matrix, SPDCBatchResult (per-matrix
    dets and verdicts) for a stack or list; both carry a consolidated
    `report` (SPDCReport: verdict, recovery, fleet, timings).
    """
    if isinstance(m, (list, tuple)):
        if use_kernel:
            raise ValueError(
                "use_kernel is not supported for mixed-size lists: the "
                "mixed path ciphers each matrix on the host (DESIGN.md "
                "§5.1); stack same-size matrices into a (B, n, n) array "
                "for the Pallas CED kernel"
            )
        return outsource_determinant_mixed(
            m, num_servers,
            lambda1=lambda1, lambda2=lambda2, mode=mode, method=method,
            distributed=distributed, faithful_sign=faithful_sign,
            tamper=tamper, faults=faults, recover=recover, standby=standby,
            straggler_deadline=straggler_deadline, dtype=dtype,
            growth_safe=growth_safe, equilibrate=equilibrate,
            transport=transport, rateless=rateless,
        )
    from repro.api import resolve_transport

    client = _make_client(
        lambda1=lambda1, lambda2=lambda2, mode=mode, method=method,
        use_kernel=use_kernel, faithful_sign=faithful_sign,
        recover=recover, standby=standby,
        straggler_deadline=straggler_deadline, dtype=dtype,
        growth_safe=growth_safe, equilibrate=equilibrate,
        rateless=rateless,
    )
    session = client.open_session(m, num_servers, faults=faults,
                                  tamper=tamper)
    return session.run(resolve_transport(transport, distributed=distributed))
