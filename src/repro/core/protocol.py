"""SPDC end-to-end protocol — the paper's six-algorithm tuple
(SeedGen, KeyGen, Cipher, Parallelize, Authenticate, Decipher), §III–§IV.

This is the client-side orchestration: everything the client does locally
(seed/key/cipher/augment/verify/decipher) plus the dispatch of the ciphered
blocks to the "edge servers" — either the faithful single-process simulation
(core.lu.lu_nserver) or the real distributed shard_map pipeline
(distrib.spdc_pipeline) where each mesh device plays one server.

Batch-first (DESIGN.md §3): `outsource_determinant` accepts one matrix
(n, n) or a stack (B, n, n). The batched path runs every per-matrix stage
as one jitted device program over the stack — independent seeds, blinding
vectors, rotations, probes, and accept/reject decisions per matrix, but
ONE cipher launch, ONE sweep of the N-server schedule, ONE verify — which
is what makes high request throughput possible (see
benchmarks/run.py:throughput).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .augment import augment_for_servers, padding_for_servers
from .cipher import CipherMeta, Mode, cipher, cipher_batch
from .decipher import Determinant, decipher, decipher_batch
from .faults import normalize_plan, resolve_delays
from .keygen import keygen, keygen_batch
from .lu import CommLog, lu_nserver, nserver_comm_model
from .seed import Seed, seedgen, seedgen_batch
from .verify import Verdict, authenticate


@dataclass
class SPDCResult:
    det: Determinant
    verified: bool
    residual: float
    seed: Seed
    meta: CipherMeta
    comm: CommLog | None
    padding: int
    num_servers: int
    #: structured Authenticate outcome (method, ε(N), per-server blame)
    verdict: Verdict | None = None
    #: verification-driven re-dispatch log (None unless recover=True fired)
    recovery: object | None = None


@dataclass
class SPDCBatchResult:
    """Per-matrix protocol outcomes for a (B, n, n) stack.

    `verified`/`residual` are (B,) arrays — one accept/reject decision per
    matrix (a single tampered matrix in the batch is flagged individually).
    """

    dets: list[Determinant]
    verified: np.ndarray
    residual: np.ndarray
    seeds: list[Seed]
    metas: list[CipherMeta]
    comm: CommLog | None
    padding: int
    num_servers: int
    verdict: Verdict | None = None
    recovery: object | None = None

    @property
    def batch(self) -> int:
        return len(self.dets)


@partial(jax.jit, static_argnames=("num_servers", "padding", "faults"))
def _augment_lu_batch(x, aug_key, *, num_servers, padding, faults=()):
    """Jitted server-side stage for the batched path: augment + one
    N-server schedule sweep over the whole stack. The fault plan is a
    static (hashable) argument — each distinct plan compiles once."""
    from .augment import augment

    x_aug = augment(x, padding, key=aug_key)
    l, u, _ = lu_nserver(x_aug, num_servers, faults=faults)
    return x_aug, l, u


def _probe_rng(digest: bytes) -> np.random.Generator:
    """Verification-probe generator keyed to client-secret material."""
    return np.random.default_rng(int.from_bytes(digest[:8], "big"))


def _batch_digest(seeds: list[Seed]) -> bytes:
    """One dispatch-channel digest for a whole stack: H(Ψ₀-digest ‖ … ‖
    Ψ_{B-1}-digest), so recovery sub-seeds are keyed to the batch's full
    secret material rather than matrix 0's alone."""
    import hashlib

    h = hashlib.sha256()
    for s in seeds:
        h.update(s.digest)
    return h.digest()


def _recover_if_needed(l, u, x_aug, verdict, *, num_servers, method, recover,
                       standby, digest, style):
    """Shared RRVP tail: on a rejected verdict, run the verification-driven
    re-dispatch loop (distrib.recovery) and re-authenticate."""
    if not recover or bool(np.all(verdict.ok)):
        return l, u, verdict, None
    from repro.distrib.recovery import recover_lu

    return recover_lu(
        l, u, x_aug, num_servers=num_servers, method=method,
        standby=standby, digest=digest, style=style, verdict=verdict,
    )


def _outsource_determinant_batch(
    m: jnp.ndarray,
    num_servers: int,
    *,
    lambda1: int,
    lambda2: int,
    mode: Mode,
    method: str,
    use_kernel: bool,
    distributed: bool,
    faithful_sign: bool,
    tamper,
    faults,
    recover: bool,
    standby: int,
    straggler_deadline: int | None,
    dtype,
) -> SPDCBatchResult:
    B, n = int(m.shape[0]), int(m.shape[-1])

    # --- client: PMOP, batched (host does B cheap hashes; the device does
    # one cipher launch over the stack) ---
    seeds = seedgen_batch(lambda1, np.asarray(m))
    v = keygen_batch(lambda2, seeds, n)
    x, metas = cipher_batch(m, v, seeds, mode=mode, use_kernel=use_kernel)

    aug_key = jax.random.key(
        int.from_bytes(seeds[0].digest[8:16], "big") % (2**31)
    )
    padding = padding_for_servers(n, num_servers)

    # --- servers: SPCP — one wavefront sweep factors the whole stack,
    # with the fault plan (untrusted-server models) applied in-line ---
    plan = resolve_delays(normalize_plan(faults), straggler_deadline)
    if distributed:
        from .augment import augment
        from repro.distrib.spdc_pipeline import lu_nserver_shardmap

        x_aug = augment(x, padding, key=aug_key)
        l, u = lu_nserver_shardmap(x_aug, num_servers, faults=plan)
        comm = None
    else:
        x_aug, l, u = _augment_lu_batch(
            x, aug_key, num_servers=num_servers, padding=padding, faults=plan
        )
        comm = nserver_comm_model(n + padding, num_servers)

    if tamper is not None:
        l, u = tamper(l, u)

    # --- client: RRVP — per-matrix accept/reject + per-matrix determinant,
    # healing localized faults by re-dispatching single shards ---
    verdict = authenticate(
        l, u, x_aug, num_servers=num_servers, method=method,
        rng=_probe_rng(_batch_digest(seeds)),
    )
    l, u, verdict, report = _recover_if_needed(
        l, u, x_aug, verdict, num_servers=num_servers, method=method,
        recover=recover, standby=standby,
        digest=_batch_digest(seeds),
        style="pipeline" if distributed else "nserver",
    )
    dets = decipher_batch(seeds, metas, l, u, faithful=faithful_sign)
    return SPDCBatchResult(
        dets=dets,
        verified=np.asarray(verdict.ok),
        residual=np.asarray(verdict.residual),
        seeds=seeds,
        metas=metas,
        comm=comm,
        padding=padding,
        num_servers=num_servers,
        verdict=verdict,
        recovery=report,
    )


def outsource_determinant(
    m: np.ndarray | jnp.ndarray,
    num_servers: int,
    *,
    lambda1: int = 128,
    lambda2: int = 128,
    mode: Mode = "ewd",
    method: str = "q3",
    use_kernel: bool = False,
    distributed: bool = False,
    faithful_sign: bool = False,
    tamper=None,
    faults=None,
    recover: bool = False,
    standby: int = 0,
    straggler_deadline: int | None = None,
    dtype=jnp.float64,
) -> SPDCResult | SPDCBatchResult:
    """Run the full SPDC protocol for one matrix or a (B, n, n) stack.

    tamper: optional fn (L, U) -> (L, U) applied to the servers' results
    before authentication — models a malicious edge server (tests use it to
    show Q2/Q3 reject tampered results, including a single bad matrix
    inside a batch).
    faults: a core.faults FaultPlan (or one ServerFault) — the structured
    untrusted-server model: per-server tamper/dropout/delay, batch-aware,
    applied inside the Parallelize stage (in-band faults poison the relay
    in the single-process simulation; the distributed pipeline injects at
    the device output).
    recover: on a rejected verdict, localize the faulty server (blocked-Q1
    attribution) and re-dispatch ONLY its shard via distrib.recovery —
    result.recovery holds the RecoveryReport. standby: provision N+r
    spare servers for those re-dispatches. straggler_deadline: rounds after
    which a delayed server is treated as dropped (None = wait forever).
    distributed: route Parallelize through the shard_map pipeline (requires
    the active process to have >= num_servers JAX devices); otherwise the
    faithful single-process simulation of Algorithm 3 is used.

    Returns SPDCResult for a single matrix, SPDCBatchResult (per-matrix
    dets and verdicts) for a stack; both carry the structured Verdict.
    """
    m = jnp.asarray(m, dtype=dtype)
    if m.ndim == 3:
        return _outsource_determinant_batch(
            m, num_servers,
            lambda1=lambda1, lambda2=lambda2, mode=mode, method=method,
            use_kernel=use_kernel, distributed=distributed,
            faithful_sign=faithful_sign, tamper=tamper, faults=faults,
            recover=recover, standby=standby,
            straggler_deadline=straggler_deadline, dtype=dtype,
        )
    n = int(m.shape[0])

    # --- client: PMOP (privacy-preserving matrix obfuscation protocol) ---
    seed = seedgen(lambda1, np.asarray(m))
    key = keygen(lambda2, seed, n)
    x, meta = cipher(m, key, seed, mode=mode, use_kernel=use_kernel)

    # augmentation (only when needed — paper Table IV) with random R block
    aug_key = jax.random.key(
        int.from_bytes(seed.digest[8:16], "big") % (2**31)
    )
    x_aug, padding = augment_for_servers(x, num_servers, key=aug_key)

    # --- servers: SPCP (secure parallel computation protocol) ---
    plan = resolve_delays(normalize_plan(faults), straggler_deadline)
    if distributed:
        from repro.distrib.spdc_pipeline import lu_nserver_shardmap

        l, u = lu_nserver_shardmap(x_aug, num_servers, faults=plan)
        comm = None
    else:
        l, u, comm = lu_nserver(x_aug, num_servers, faults=plan)

    if tamper is not None:
        l, u = tamper(l, u)

    # --- client: RRVP (result recovery & verification protocol) ---
    # probes are drawn from a generator keyed to the SECRET Ψ digest: a
    # predictable probe could be evaded by a codebase-aware server
    verdict = authenticate(
        l, u, x_aug, num_servers=num_servers, method=method,
        rng=_probe_rng(seed.digest),
    )
    l, u, verdict, report = _recover_if_needed(
        l, u, x_aug, verdict, num_servers=num_servers, method=method,
        recover=recover, standby=standby, digest=seed.digest,
        style="pipeline" if distributed else "nserver",
    )
    det = decipher(seed, meta, l, u, faithful=faithful_sign)
    return SPDCResult(
        det=det,
        verified=bool(np.all(verdict.ok)),
        residual=verdict.residual,
        seed=seed,
        meta=meta,
        comm=comm,
        padding=padding,
        num_servers=num_servers,
        verdict=verdict,
        recovery=report,
    )
