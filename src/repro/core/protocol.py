"""SPDC end-to-end protocol — the paper's six-algorithm tuple
(SeedGen, KeyGen, Cipher, Parallelize, Authenticate, Decipher), §III–§IV.

This is the client-side orchestration: everything the client does locally
(seed/key/cipher/augment/verify/decipher) plus the dispatch of the ciphered
blocks to the "edge servers" — either the faithful single-process simulation
(core.lu.lu_nserver) or the real distributed shard_map pipeline
(distrib.spdc_pipeline) where each mesh device plays one server.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .augment import augment_for_servers
from .cipher import CipherMeta, Mode, cipher
from .decipher import Determinant, decipher
from .keygen import keygen
from .lu import CommLog, lu_nserver
from .seed import Seed, seedgen
from .verify import authenticate


@dataclass
class SPDCResult:
    det: Determinant
    verified: bool
    residual: float
    seed: Seed
    meta: CipherMeta
    comm: CommLog | None
    padding: int
    num_servers: int


def outsource_determinant(
    m: np.ndarray | jnp.ndarray,
    num_servers: int,
    *,
    lambda1: int = 128,
    lambda2: int = 128,
    mode: Mode = "ewd",
    method: str = "q3",
    use_kernel: bool = False,
    distributed: bool = False,
    faithful_sign: bool = False,
    tamper=None,
    dtype=jnp.float64,
) -> SPDCResult:
    """Run the full SPDC protocol for one matrix.

    tamper: optional fn (L, U) -> (L, U) applied to the servers' results
    before authentication — models a malicious edge server (tests use it to
    show Q2/Q3 reject tampered results).
    distributed: route Parallelize through the shard_map pipeline (requires
    the active process to have >= num_servers JAX devices); otherwise the
    faithful single-process simulation of Algorithm 3 is used.
    """
    m = jnp.asarray(m, dtype=dtype)
    n = int(m.shape[0])

    # --- client: PMOP (privacy-preserving matrix obfuscation protocol) ---
    seed = seedgen(lambda1, np.asarray(m))
    key = keygen(lambda2, seed, n)
    x, meta = cipher(m, key, seed, mode=mode, use_kernel=use_kernel)

    # augmentation (only when needed — paper Table IV) with random R block
    aug_key = jax.random.key(
        int.from_bytes(seed.digest[8:16], "big") % (2**31)
    )
    x_aug, padding = augment_for_servers(x, num_servers, key=aug_key)

    # --- servers: SPCP (secure parallel computation protocol) ---
    if distributed:
        from repro.distrib.spdc_pipeline import lu_nserver_shardmap

        l, u = lu_nserver_shardmap(x_aug, num_servers)
        comm = None
    else:
        l, u, comm = lu_nserver(x_aug, num_servers)

    if tamper is not None:
        l, u = tamper(l, u)

    # --- client: RRVP (result recovery & verification protocol) ---
    verified, residual = authenticate(
        l, u, x_aug, num_servers=num_servers, method=method
    )
    det = decipher(seed, meta, l, u, faithful=faithful_sign)
    return SPDCResult(
        det=det,
        verified=verified,
        residual=residual,
        seed=seed,
        meta=meta,
        comm=comm,
        padding=padding,
        num_servers=num_servers,
    )
