"""KeyGen — paper §IV.B.

Constructs the secret blinding vector v = [v₁ … v_n] with

    ∏ v_i = Ψ,   v_i ≠ 1 ∀i,

drawn from a CSPRNG keyed by (λ₂, Ψ-digest). We sample log-space offsets so
every v_i has geometric mean Ψ^{1/n} — entries stay in a tight positive band
and the product telescopes to Ψ exactly (up to one float64 rounding in the
last entry, which we absorb by construction: v_n := Ψ / ∏_{i<n} v_i).
"""
from __future__ import annotations

import hashlib
import struct
from dataclasses import dataclass

import numpy as np

from .seed import Seed


@dataclass(frozen=True)
class Key:
    """Secret key K = {v}. Held by the client only."""

    v: np.ndarray  # float64 (n,)

    @property
    def n(self) -> int:
        return int(self.v.shape[0])


def _csprng(digest: bytes, lambda2: int, count: int) -> np.ndarray:
    """Deterministic CSPRNG stream: SHA-256 in counter mode → floats in [0,1).

    hashlib is the only cryptographic primitive available offline; counter-
    mode SHA-256 is a standard PRF construction for this purpose.
    """
    out = np.empty(count, dtype=np.float64)
    block = b""
    need = count * 8
    chunks = []
    ctr = 0
    while need > 0:
        h = hashlib.sha256()
        h.update(digest)
        h.update(struct.pack(">qq", int(lambda2), ctr))
        block = h.digest()
        chunks.append(block)
        need -= len(block)
        ctr += 1
    raw = b"".join(chunks)[: count * 8]
    ints = np.frombuffer(raw, dtype=">u8").astype(np.float64)
    out[:] = ints / 2.0**64
    return out


def keygen(lambda2: int, seed: Seed, n: int, *, spread: float = 0.5) -> Key:
    """KeyGen(λ₂, Ψ, μ, M_max) → K.

    spread controls the log-uniform band around the geometric mean; entries
    land in [g·2^-spread, g·2^spread] with g = Ψ^{1/n}, and the v_i ≠ 1
    constraint is enforced by nudging any entry that rounds to exactly 1.
    """
    if n < 2:
        raise ValueError("blinding vector needs n >= 2")
    u = _csprng(seed.digest, lambda2, n - 1)
    g = float(seed.psi) ** (1.0 / n)
    logs = (u * 2.0 - 1.0) * spread + np.log2(g)
    v = np.empty(n, dtype=np.float64)
    v[: n - 1] = np.exp2(logs)
    # exact product constraint
    v[n - 1] = float(seed.psi) / float(np.prod(v[: n - 1]))
    # v_i != 1 (paper constraint); measure-zero event, nudge deterministically
    ones = v == 1.0
    if ones.any():
        v[ones] = np.nextafter(1.0, 2.0)
        v[n - 1] = float(seed.psi) / float(np.prod(v[: n - 1]))
    return Key(v=v)


def keygen_batch(lambda2: int, seeds: list[Seed], n: int, *,
                 spread: float = 0.5) -> np.ndarray:
    """KeyGen over a batch of seeds → stacked blinding vectors (B, n).

    Each row satisfies the per-matrix product constraint ∏ v_i = Ψ_b; the
    stack feeds the batched cipher in one device call (DESIGN.md §3).
    """
    return np.stack([keygen(lambda2, s, n, spread=spread).v for s in seeds])
