"""Secure outsourced matrix INVERSION — the paper's §VII.B "future
enhancement", built on the same CED + N-server-LU machinery (beyond-paper
deliverable).

Math. With EWD ciphering, X = R^k(V^{-1} M) where V = diag(v) and R is one
clockwise quarter-turn, R(A) = Aᵀ·J (transpose then reverse columns,
J = exchange matrix). Then M = V·R^{-k}(X) and

    inv(M) = inv(R^{-k}(X)) · V^{-1} = R^{k}(inv(X)) · V^{-1}

(the identity inv(R^{-k}(X)) = R^{k}(inv(X)) is derived case-by-case in
the recovery code below). The servers do all O(n³) work (LU of X, then
column-block triangular
solves for inv(X) — embarrassingly parallel across column blocks, no
inter-server traffic beyond the LU pipeline itself). The client's recovery
is O(n²): k counter-quarter-turns of inv(X) (pure data movement) and one
column scaling by v⁻¹. Verification is the paper's Q2 idea applied to the
inverse claim: the Freivalds projection ‖X(inv(X)·r) − r‖ at O(n²).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .augment import augment_for_servers
from .cipher import CipherMeta, Mode, cipher
from .keygen import keygen
from .lu import lu_nserver
from .prt import rot90_cw
from .seed import Seed, seedgen


@dataclass
class SPDCInverseResult:
    inverse: jnp.ndarray
    verified: bool
    residual: float
    seed: Seed
    meta: CipherMeta
    padding: int


def _inv_from_lu(l: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Server-side: inv(X) columns by triangular solves against I.

    In deployment each server solves its own column block (n/N columns,
    O(n³/N) flops, zero extra communication); simulated here in one call.
    """
    n = l.shape[0]
    eye = jnp.eye(n, dtype=l.dtype)
    y = jax.scipy.linalg.solve_triangular(l, eye, lower=True,
                                          unit_diagonal=True)
    return jax.scipy.linalg.solve_triangular(u, y, lower=False)


def outsource_inverse(
    m: np.ndarray | jnp.ndarray,
    num_servers: int,
    *,
    lambda1: int = 128,
    lambda2: int = 128,
    mode: Mode = "ewd",
    dtype=jnp.float64,
    eps: float = 1e-6,
    tamper=None,
) -> SPDCInverseResult:
    """Full secure-inversion protocol: cipher -> N-server LU -> per-server
    column solves -> client O(n²) recovery -> Freivalds verification."""
    m = jnp.asarray(m, dtype=dtype)
    n = int(m.shape[0])

    seed = seedgen(lambda1, np.asarray(m))
    key = keygen(lambda2, seed, n)
    x, meta = cipher(m, key, seed, mode=mode)
    aug_key = jax.random.key(int.from_bytes(seed.digest[16:24], "big") % (2**31))
    x_aug, padding = augment_for_servers(x, num_servers, key=aug_key)

    # --- servers ---
    l, u, _ = lu_nserver(x_aug, num_servers)
    inv_x_aug = _inv_from_lu(l, u)
    if tamper is not None:
        inv_x_aug = tamper(inv_x_aug)

    # client: verify the inverse claim with a Freivalds projection (Q2-style)
    rng = np.random.default_rng(int.from_bytes(seed.digest[24:28], "big"))
    r = jnp.asarray(rng.standard_normal(x_aug.shape[0]), dtype=dtype)
    resid = float(jnp.linalg.norm(x_aug @ (inv_x_aug @ r) - r)
                  / (jnp.linalg.norm(r)))
    verified = resid < eps

    # client: O(n²) recovery — drop padding, un-rotate, un-blind
    # inv(X_aug) upper-left block is NOT inv(X) in general, BUT our
    # augmentation B = [[X,0],[R,I]] gives inv(B) = [[inv(X),0],[-R·inv(X),I]]
    # — the upper-left block IS inv(X) exactly.
    inv_x = inv_x_aug[:n, :n]
    # With R(A) = AᵀJ (one cw quarter-turn): R^{-1}(B) = JBᵀ, and
    #   inv(R^{-1}(X)) = inv(JXᵀ) = X^{-T}J = R(inv(X))
    #   inv(R^{-2}(X)) = inv(JXJ) = J·inv(X)·J = R²(inv(X))
    #   inv(R^{-3}(X)) = J·X^{-T} = R³(inv(X))
    # i.e. undoing k cipher rotations on the INVERSE means applying the SAME
    # k clockwise quarter-turns to inv(X).
    inv_unrot = rot90_cw(inv_x, meta.rotate_k)
    v = jnp.asarray(key.v, dtype=dtype)
    if mode == "ewd":
        # M = V·R^{-k}(X)  =>  inv(M) = R^{-k}(inv(X)) · V^{-1} (col-scale)
        inverse = inv_unrot / v[None, :]
    else:
        # EWM: M = V^{-1}·R^{-k}(X)  =>  inv(M) = R^{-k}(inv(X)) · V
        inverse = inv_unrot * v[None, :]
    return SPDCInverseResult(
        inverse=inverse, verified=verified, residual=resid,
        seed=seed, meta=meta, padding=padding,
    )
