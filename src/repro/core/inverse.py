"""Secure outsourced matrix INVERSION — facade over the shared-LU op plan.

The paper's §VII.B "future enhancement", originally a standalone
monolith predating the Session/Transport API. It is now a thin facade
over `repro.linalg.LinalgSession.inv` (DESIGN.md §12): one verified
outsourced factorization, one wide public-permutation-RHS triangular-
solve round dispatched over any `repro.api` transport, and O(n²) client
recovery (counter-rotations + the secret column scaling by v).

Verification happens at two layers. The session verifies the factors
(Q2 + Q3) and every solve round (per-chunk, healed through
`distrib.recovery.recover_solve`); the facade then re-checks the FINAL
recovered inverse with a Freivalds projection against the plaintext M.
The projection vector is drawn from a secret domain-separated lane of
the session digest, fresh per attempt — the pre-facade implementation
seeded it from a fixed 4-byte digest slice, a probe a server that
learned the slice could precompute its tampering to be orthogonal to
(the adaptive attack regression-tested in tests/test_inverse.py).

`tamper=` survives as facade-level fault injection: it mutates the
REPORTED inverse after recovery, exercising exactly the verification
the client runs on what a lying fleet would hand back. Transport-level
misbehavior (heal-able, per-chunk) is the `faults=` path instead.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from .cipher import CipherMeta, Mode
from .protocol import SPDCReport
from .seed import Seed

__all__ = ["SPDCInverseResult", "outsource_inverse"]


def _deprecated_protocol_field(name: str, hint: str):
    """One-cycle shim: `result.seed` / `result.meta` still answer, loudly."""

    @property
    def shim(self):
        warnings.warn(
            f"SPDCInverseResult.{name} is deprecated; {hint}",
            DeprecationWarning,
            stacklevel=2,
        )
        return getattr(self, f"_{name}")

    return shim


@dataclass
class SPDCInverseResult:
    """Outcome of one secure inversion (or a (B, n, n) stack of them).

    `report` is the consolidated SPDCReport surface — its `ops` tuple
    records the factorization and the inverse round(s) with per-op
    verdicts, residuals, and heal counts. `verified` folds the session's
    layered checks AND the facade's final Freivalds projection.
    """

    inverse: jnp.ndarray
    verified: bool
    residual: float
    padding: int
    #: consolidated diagnostics (per-op verdicts / recovery / timings)
    report: SPDCReport = field(default_factory=SPDCReport)
    #: one-cycle deprecated protocol internals (pre-facade return shape)
    _seed: Seed | None = field(default=None, repr=False)
    _meta: CipherMeta | None = field(default=None, repr=False)

    seed = _deprecated_protocol_field(
        "seed", "the protocol seed is session-internal now; key "
        "client-side state off the matrix bytes instead")
    meta = _deprecated_protocol_field(
        "meta", "the cipher meta is session-internal now; read "
        "result.report.ops for per-op diagnostics")


def _final_probe_residual(m, inverse, digest: bytes, attempt: int) -> float:
    """Freivalds residual ‖M·(Y·r) − r‖/‖r‖ of the recovered inverse.

    The probe r comes from the secret `inverse-probe` lane of the session
    digest — domain-separated from every wire-crossing subseed and fresh
    per attempt, so no server can precompute tampering orthogonal to it
    (the fixed-seed probe this replaces is the adaptive-attack regression
    in tests/test_inverse.py).
    """
    from repro.linalg.session import _lane_rng

    y = np.asarray(inverse)
    rng = _lane_rng(digest, b"inverse-probe", attempt)
    r = rng.standard_normal(y.shape[-1]).astype(y.dtype)
    return float(
        np.linalg.norm(np.asarray(m, dtype=y.dtype) @ (y @ r) - r)
        / np.linalg.norm(r)
    )


def _invert_one(m, num_servers, *, lambda1, lambda2, mode, dtype, eps,
                tamper, transport, faults, recover, standby):
    from repro.linalg import LinalgSession

    s = LinalgSession(
        m, num_servers,
        transport=transport, faults=faults, recover=recover,
        standby=standby, mode=mode, lambda1=lambda1, lambda2=lambda2,
        dtype=dtype,
    )
    inverse = jnp.asarray(s.inv())
    if tamper is not None:
        inverse = tamper(inverse)
    resid = _final_probe_residual(m, inverse, s.digest, 0)
    rep = s.report
    session_ok = all(o.verified for o in rep.ops)
    return SPDCInverseResult(
        inverse=inverse,
        verified=bool(session_ok and resid < eps),
        residual=resid,
        padding=s.padding,
        report=rep,
        _seed=s._session.seeds[0],
        _meta=s._session.metas[0],
    )


def outsource_inverse(
    m: np.ndarray | jnp.ndarray,
    num_servers: int,
    *,
    lambda1: int = 128,
    lambda2: int = 128,
    mode: Mode = "ewd",
    dtype=None,
    eps: float = 1e-6,
    tamper=None,
    transport=None,
    faults=None,
    recover: bool = True,
    standby: int = 0,
) -> SPDCInverseResult:
    """Secure inversion through one verified shared-LU session.

    m: one (n, n) matrix, or a (B, n, n) stack — the stack runs one
        session per matrix and returns a single result with a (B, n, n)
        inverse, verified = all, residual = max (per-op records of every
        session concatenate into report.ops).
    transport: any `repro.api` transport (name, instance, or None for
        inline) — the facade predated PR 7 and bypassed the transport
        layer entirely; it no longer does.
    faults / recover / standby: the transport-level fault model — a
        tampered server's chunks localize and HEAL through the session's
        per-chunk verification (recover=True), unlike `tamper=`, which
        corrupts the final reported inverse and must be caught by the
        facade's Freivalds projection.
    eps: acceptance threshold for that final projection residual.
    """
    m = np.asarray(m)
    kwargs = dict(lambda1=lambda1, lambda2=lambda2, mode=mode, dtype=dtype,
                  eps=eps, tamper=tamper, transport=transport, faults=faults,
                  recover=recover, standby=standby)
    if m.ndim == 3:
        parts = [_invert_one(mi, num_servers, **kwargs) for mi in m]
        return SPDCInverseResult(
            inverse=jnp.stack([p.inverse for p in parts]),
            verified=all(p.verified for p in parts),
            residual=max(p.residual for p in parts),
            padding=parts[0].padding,
            report=SPDCReport(ops=tuple(
                o for p in parts for o in p.report.ops
            )),
            _seed=parts[0]._seed,
            _meta=parts[0]._meta,
        )
    return _invert_one(m, num_servers, **kwargs)
