"""Decipher — paper §IV.F: recover det(M) from the LU of the ciphertext.

    det(X) = Π_i L_ii U_ii                      (from the servers' LU)
    EWD:  det(M) = det(X) · sign · Ψ
    EWM:  det(M) = det(X) · sign / Ψ

The correct rotation sign is ((-1)^{⌊n/2⌋})^k (PRT); the paper's literal
formula uses (-1)^k, valid only for n ≡ 2,3 (mod 4) — both are provided
(faithful=True reproduces the paper, default applies the theorem's own
case split). When the cipher used the growth-safe relayout
(meta.flipped — DESIGN.md §6.1) the sign law is growth_safe_sign instead.

All arithmetic is done in (sign, log|·|) space to survive large n; the
log-sum over the factor diagonals is compensated
(core.lu.slogdet_pair_from_lu) and recombined in float64 HERE, on the
host — a single float32 cannot represent log|det| ≈ 1000 to the 1e-4
absolute accuracy float32 protocol runs target. See DESIGN.md §1.1, §6.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .cipher import CipherMeta
from .lu import slogdet_pair_from_lu
from .prt import growth_safe_sign, rotation_sign, rotation_sign_paper
from .seed import Seed

_LN2 = float(np.log(2.0))

#: largest log|det| whose exp still fits a float64 — beyond it .value
#: would silently return inf (the satellite bug this guards against)
_MAX_VALUE_LOGABS = float(np.log(np.finfo(np.float64).max))

#: dtype-aware default relative det tolerance for allclose(): the
#: float64 figure matches the protocol's historic rtol; the float32
#: figure is the acceptance bar of the f32 protocol path (DESIGN.md §6)
_DEFAULT_RTOL = {"float64": 1e-8, "float32": 1e-4, "float16": 1e-2,
                 "bfloat16": 1e-1}


@dataclass(frozen=True)
class Determinant:
    """Determinant in overflow-safe (sign, log|det|) form.

    `dtype` records the compute dtype of the factorization that produced
    this determinant — it selects allclose()'s default tolerance. `logabs`
    itself is always a host float64 (built from the compensated device
    pair), so the log-space value is meaningful beyond the compute
    dtype's own resolution.
    """

    sign: float
    logabs: float
    dtype: str = "float64"

    @property
    def value(self) -> float:
        """det as a plain float — raises OverflowError when it does not fit.

        log|det| > ~709.78 means the determinant exceeds the float64
        range; silently returning inf (the pre-fix behavior) corrupted
        every downstream comparison. Work in (sign, logabs) space instead:
        this property is for small matrices and display only.
        """
        if self.logabs > _MAX_VALUE_LOGABS:
            raise OverflowError(
                f"|det| = exp({self.logabs:.1f}) overflows float64; compare "
                "in (sign, logabs) space instead of .value"
            )
        return float(self.sign * np.exp(self.logabs))

    def is_zero(self, atol_logabs: float = -np.inf) -> bool:
        """True when this determinant is (numerically) zero: an exact zero
        sign, a -inf logabs, or logabs at/below `atol_logabs`."""
        return self.sign == 0 or self.logabs == float("-inf") \
            or self.logabs <= atol_logabs

    def allclose(
        self,
        other: "Determinant",
        rtol: float | None = None,
        atol: float = 0.0,
        zero_logabs: float = -np.inf,
    ) -> bool:
        """Relative-determinant comparison, done correctly in log space.

        Two determinants agree to relative error rtol iff
        |Δ logabs| ≤ log1p(rtol); `atol` adds extra log-space slack. The
        pre-fix implementation applied rtol to logabs ITSELF
        (np.isclose(logabs, …, rtol)), so the tolerated relative det
        error grew with |log det| — wildly loose at n = 1024 and
        needlessly tight near |det| ≈ 1.

        rtol=None selects the dtype-aware default (1e-8 for float64
        computes, 1e-4 for float32) from the coarser of the two operands.

        Zero handling: determinants that are zero (sign 0, logabs -inf,
        or logabs ≤ zero_logabs) compare equal to each other regardless
        of sign — ±0 must not be a sign mismatch; a zero never equals a
        nonzero. Otherwise differing signs are a mismatch.
        """
        if rtol is None:
            rtols = [_DEFAULT_RTOL.get(d, 1e-8) for d in (self.dtype,
                                                          other.dtype)]
            rtol = max(rtols)
        a_zero = self.is_zero(zero_logabs)
        b_zero = other.is_zero(zero_logabs)
        if a_zero or b_zero:
            return a_zero and b_zero
        if self.sign != other.sign:
            return False
        return bool(
            abs(self.logabs - other.logabs) <= float(np.log1p(rtol)) + atol
        )

    def to_bytes(self) -> bytes:
        """Serialize with the role-split wire codec (repro.api.wire) —
        (sign, logabs) round-trip bit-exactly, ±inf included."""
        from repro.api import wire

        return wire.encode(
            "Determinant",
            {"sign": float(self.sign), "logabs": float(self.logabs),
             "dtype": self.dtype},
            {},
        )

    @classmethod
    def _from_wire(cls, scalars, arrays):
        return cls(sign=scalars["sign"], logabs=scalars["logabs"],
                   dtype=scalars["dtype"])

    @classmethod
    def from_bytes(cls, data: bytes) -> "Determinant":
        from repro.api import wire

        kind, scalars, arrays = wire.decode(data)
        if kind != "Determinant":
            raise wire.WireError(f"expected Determinant frame, got {kind!r}")
        return cls._from_wire(scalars, arrays)


def _assemble(
    sign_x: float,
    logabs_x: float,
    seed: Seed,
    meta: CipherMeta,
    *,
    faithful: bool,
    log2_scale: float,
    dtype: str,
) -> Determinant:
    """Shared Decipher bookkeeping: relayout sign, equilibration
    correction, Ψ factor — all in host float64."""
    if faithful:
        s = rotation_sign_paper(meta.rotate_k)
    elif meta.flipped:
        s = growth_safe_sign(meta.n, meta.rotate_k)
    else:
        s = rotation_sign(meta.n, meta.rotate_k)
    log_psi = float(np.log(seed.psi))
    logabs = logabs_x - float(log2_scale) * _LN2
    if meta.mode == "ewd":
        return Determinant(sign=sign_x * s, logabs=logabs + log_psi,
                           dtype=dtype)
    if meta.mode == "ewm":
        return Determinant(sign=sign_x * s, logabs=logabs - log_psi,
                           dtype=dtype)
    raise ValueError(f"unknown mode {meta.mode!r}")


def decipher(
    seed: Seed,
    meta: CipherMeta,
    l: jnp.ndarray,
    u: jnp.ndarray,
    *,
    faithful: bool = False,
    log2_scale: float = 0.0,
) -> Determinant:
    """Decipher(Ψ, L, U) → det(M).

    log2_scale: the equilibration exponent sum returned by
    core.cipher.equilibrate (0 when the ciphertext was not equilibrated).
    """
    sign_x, hi, lo = slogdet_pair_from_lu(l, u)
    logabs_x = float(hi) + float(lo)  # recombine the pair in float64
    return _assemble(
        float(sign_x), logabs_x, seed, meta,
        faithful=faithful, log2_scale=log2_scale, dtype=str(l.dtype),
    )


_slogdet_pair_jit = jax.jit(slogdet_pair_from_lu)


def decipher_batch(
    seeds: list[Seed],
    metas: list[CipherMeta],
    l: jnp.ndarray,
    u: jnp.ndarray,
    *,
    faithful: bool = False,
    log2_scale: np.ndarray | None = None,
) -> list[Determinant]:
    """Batched Decipher: (B, n, n) LU factors → one Determinant per matrix.

    The O(B·n) diagonal reduction runs as a single jitted device program;
    only the O(B) per-matrix Ψ/rotation-sign bookkeeping stays on host.
    log2_scale: per-matrix equilibration exponents, shape (B,).
    """
    sign_x, hi, lo = _slogdet_pair_jit(l, u)
    sign_x = np.asarray(sign_x)
    logabs_x = np.asarray(hi, dtype=np.float64) + np.asarray(lo, np.float64)
    dtype = str(l.dtype)
    if log2_scale is None:
        log2_scale = np.zeros(len(seeds))
    log2_scale = np.asarray(log2_scale)
    return [
        _assemble(
            float(sign_x[i]), float(logabs_x[i]), seed, meta,
            faithful=faithful, log2_scale=float(log2_scale[i]), dtype=dtype,
        )
        for i, (seed, meta) in enumerate(zip(seeds, metas, strict=True))
    ]


def decipher_flops(n: int) -> int:
    """Paper Table I Decipher cost: 2n (n diagonal products + n-ish for the
    running product/log accumulation)."""
    return 2 * n
