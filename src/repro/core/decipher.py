"""Decipher — paper §IV.F: recover det(M) from the LU of the ciphertext.

    det(X) = Π_i L_ii U_ii                      (from the servers' LU)
    EWD:  det(M) = det(X) · sign · Ψ
    EWM:  det(M) = det(X) · sign / Ψ

The correct rotation sign is ((-1)^{⌊n/2⌋})^k (PRT); the paper's literal
formula uses (-1)^k, valid only for n ≡ 2,3 (mod 4) — both are provided
(faithful=True reproduces the paper, default applies the theorem's own
case split). All arithmetic is done in (sign, log|·|) space to survive
large n. See DESIGN.md §1.1.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .cipher import CipherMeta
from .lu import slogdet_from_lu
from .prt import rotation_sign, rotation_sign_paper
from .seed import Seed


@dataclass(frozen=True)
class Determinant:
    """Determinant in overflow-safe (sign, log|det|) form."""

    sign: float
    logabs: float

    @property
    def value(self) -> float:
        return float(self.sign * np.exp(self.logabs))

    def allclose(self, other: "Determinant", rtol: float = 1e-8) -> bool:
        if self.sign != other.sign:
            return False
        return bool(np.isclose(self.logabs, other.logabs, rtol=rtol, atol=1e-8))


def decipher(
    seed: Seed,
    meta: CipherMeta,
    l: jnp.ndarray,
    u: jnp.ndarray,
    *,
    faithful: bool = False,
) -> Determinant:
    """Decipher(Ψ, L, U) → det(M)."""
    sign_x, logabs_x = slogdet_from_lu(l, u)
    sign_x = float(sign_x)
    logabs_x = float(logabs_x)
    if faithful:
        s = rotation_sign_paper(meta.rotate_k)
    else:
        s = rotation_sign(meta.n, meta.rotate_k)
    log_psi = float(np.log(seed.psi))
    if meta.mode == "ewd":
        return Determinant(sign=sign_x * s, logabs=logabs_x + log_psi)
    if meta.mode == "ewm":
        return Determinant(sign=sign_x * s, logabs=logabs_x - log_psi)
    raise ValueError(f"unknown mode {meta.mode!r}")


_slogdet_jit = jax.jit(slogdet_from_lu)


def decipher_batch(
    seeds: list[Seed],
    metas: list[CipherMeta],
    l: jnp.ndarray,
    u: jnp.ndarray,
    *,
    faithful: bool = False,
) -> list[Determinant]:
    """Batched Decipher: (B, n, n) LU factors → one Determinant per matrix.

    The O(B·n) diagonal reduction runs as a single jitted device program;
    only the O(B) per-matrix Ψ/rotation-sign bookkeeping stays on host.
    """
    sign_x, logabs_x = _slogdet_jit(l, u)
    sign_x = np.asarray(sign_x)
    logabs_x = np.asarray(logabs_x)
    out = []
    for i, (seed, meta) in enumerate(zip(seeds, metas)):
        if faithful:
            s = rotation_sign_paper(meta.rotate_k)
        else:
            s = rotation_sign(meta.n, meta.rotate_k)
        log_psi = float(np.log(seed.psi))
        if meta.mode == "ewd":
            out.append(Determinant(sign=float(sign_x[i]) * s,
                                   logabs=float(logabs_x[i]) + log_psi))
        elif meta.mode == "ewm":
            out.append(Determinant(sign=float(sign_x[i]) * s,
                                   logabs=float(logabs_x[i]) - log_psi))
        else:
            raise ValueError(f"unknown mode {meta.mode!r}")
    return out


def decipher_flops(n: int) -> int:
    """Paper Table I Decipher cost: 2n (n diagonal products + n-ish for the
    running product/log accumulation)."""
    return 2 * n
