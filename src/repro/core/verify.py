"""Result authentication — paper §IV.E: Q1 (prior work), Q2, Q3, ε(N).

Q1 (Gao & Yu):  vector residual   L(U r) − X r
Q2 (paper):     scalar residual   (Lᵀr)ᵀ(U r) − (rᵀ X) r
Q3 (paper):     deterministic     Σ_i |Σ_{j≤i} L_ij U_ji − x_ii|

All avoid matrix–matrix products: Q1/Q2 are matrix–vector (O(n²)), Q3 reads
only the diagonal band terms it needs (O(n²) for the inner products over
j ≤ i, or O(n) if L/U rows are streamed during integration).

Every check is batch-aware (DESIGN.md §3): with (..., n, n) factors and
(..., n) probes the residuals come back per-matrix — a tampered matrix
inside a batch is flagged individually, never averaged away.

ε(N): multi-server block pipelining + no-pivot elimination accumulate
rounding; the paper validates |Q| ≤ ε(N) with ε growing in N. We model
ε(N) = c · (1 + N) · n · u · scale(X) with u the unit roundoff of the
compute dtype and scale(X) = ‖X‖_F / √n (RMS magnitude) — first-order error
analysis of an n-step elimination distributed over N pipeline stages.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def q1(l: jnp.ndarray, u: jnp.ndarray, x: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """Gao & Yu's vector check: L(Ur) − Xr. Zero vector iff LU consistent."""
    ur = jnp.einsum("...ij,...j->...i", u, r)
    return (
        jnp.einsum("...ij,...j->...i", l, ur)
        - jnp.einsum("...ij,...j->...i", x, r)
    )


def q2(l: jnp.ndarray, u: jnp.ndarray, x: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """Paper's scalar probabilistic check: (Lᵀr)ᵀ(Ur) − (rᵀX)r."""
    lt_r = jnp.einsum("...ij,...i->...j", l, r)
    u_r = jnp.einsum("...ij,...j->...i", u, r)
    rx = jnp.einsum("...i,...ij->...j", r, x)
    return jnp.sum(lt_r * u_r, axis=-1) - jnp.sum(rx * r, axis=-1)


def q3(l: jnp.ndarray, u: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Deterministic diagonal check, per-element abs (the form the paper's
    own correctness proof §V.C.2 uses): Σ_i |(L·U)_ii − x_ii|."""
    lu_diag = jnp.einsum("...ij,...ji->...i", jnp.tril(l), jnp.triu(u))
    return jnp.sum(
        jnp.abs(lu_diag - jnp.diagonal(x, axis1=-2, axis2=-1)), axis=-1
    )


def q3_paper_literal(l: jnp.ndarray, u: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Q3 exactly as §IV.E.2 writes it: |Σ_i (Σ_{j≤i} L_ij U_ji − x_ii)|.

    Weaker than q3: opposite-sign per-row errors cancel (see
    tests/test_core_protocol.py::test_q3_literal_cancellation_weakness).
    """
    lu_diag = jnp.einsum("...ij,...ji->...i", jnp.tril(l), jnp.triu(u))
    return jnp.abs(
        jnp.sum(lu_diag - jnp.diagonal(x, axis1=-2, axis2=-1), axis=-1)
    )


def epsilon(
    num_servers: int,
    n: int,
    x: jnp.ndarray | None = None,
    *,
    dtype=jnp.float64,
    c: float = 64.0,
):
    """Acceptance threshold ε(N) — grows with server count (paper §IV.E.3).

    Scalar for a single matrix; a (B,) array for a (B, n, n) stack (each
    matrix gets a threshold scaled to its own magnitude).
    """
    u = float(jnp.finfo(dtype).eps)
    if x is not None:
        scale = jnp.linalg.norm(x, axis=(-2, -1)) / np.sqrt(n)
    else:
        scale = jnp.asarray(1.0)
    out = c * (1.0 + num_servers) * n * u * jnp.maximum(scale, 1.0) ** 2
    if out.ndim == 0:
        return float(out)
    return np.asarray(out)


def authenticate(
    l: jnp.ndarray,
    u: jnp.ndarray,
    x: jnp.ndarray,
    *,
    num_servers: int,
    method: str = "q3",
    rng: np.random.Generator | None = None,
    eps: float | np.ndarray | None = None,
) -> tuple[bool, float] | tuple[np.ndarray, np.ndarray]:
    """Authenticate(L, U, X) → {1, 0} plus the residual magnitude.

    method ∈ {"q1", "q2", "q3", "q3_literal"}. For q1/q2 a random r is drawn
    client-side (the server never sees it) — an independent probe per matrix
    when X is a (B, n, n) stack. Batched inputs return per-matrix
    (verified, residual) numpy arrays; a single matrix returns plain
    (bool, float).
    """
    n = x.shape[-1]
    batched = x.ndim == 3
    if eps is None:
        eps = epsilon(num_servers, n, x, dtype=x.dtype)
    if method in ("q1", "q2"):
        rng = rng or np.random.default_rng(0)
        r_shape = (x.shape[0], n) if batched else (n,)
        r = jnp.asarray(rng.standard_normal(r_shape), dtype=x.dtype)
        if method == "q1":
            resid = jnp.max(jnp.abs(q1(l, u, x, r)), axis=-1)
        else:
            resid = jnp.abs(q2(l, u, x, r))
            # Q2 contracts twice with r: widen by the extra ‖r‖² factor.
            eps = eps * n
    elif method == "q3":
        resid = q3(l, u, x)
    elif method == "q3_literal":
        resid = q3_paper_literal(l, u, x)
    else:
        raise ValueError(f"unknown authentication method {method!r}")
    if batched:
        resid = np.asarray(resid)
        return np.asarray(resid <= eps), resid
    return bool(resid <= eps), float(resid)


def verification_flops(n: int, method: str) -> int:
    """Cost models backing benchmarks/ (paper Table I's Authenticate column)."""
    if method == "q1":
        return 3 * 2 * n * n  # three mat-vec products
    if method == "q2":
        return 3 * 2 * n * n + 2 * 2 * n  # three mat-vec + two dot products
    if method in ("q3", "q3_literal"):
        return 2 * n * (n + 1) // 2 + n  # Σ_i 2i muls/adds + n subtractions
    raise ValueError(method)
