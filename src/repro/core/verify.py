"""Result authentication — paper §IV.E: Q1 (prior work), Q2, Q3, ε(N) —
plus per-server tamper LOCALIZATION (DESIGN.md §4).

Q1 (Gao & Yu):  vector residual   L(U r) − X r
Q2 (paper):     scalar residual   (Lᵀr)ᵀ(U r) − (rᵀ X) r
Q3 (paper):     deterministic     Σ_i |Σ_{j≤i} L_ij U_ji − x_ii|

All avoid matrix–matrix products: Q1/Q2 are matrix–vector (O(n²)), Q3 reads
only the diagonal band terms it needs (O(n²) for the inner products over
j ≤ i, or O(n) if L/U rows are streamed during integration).

Every check is batch-aware (DESIGN.md §3): with (..., n, n) factors and
(..., n) probes the residuals come back per-matrix — a tampered matrix
inside a batch is flagged individually, never averaged away.

ε(N): multi-server block pipelining + no-pivot elimination accumulate
rounding; the paper validates |Q| ≤ ε(N) with ε growing in N. We model
ε(N) = c · (1 + N) · n · u · scale(X) with u the unit roundoff of the
compute dtype and scale(X) = ‖X‖_F / √n (RMS magnitude) — first-order error
analysis of an n-step elimination distributed over N pipeline stages.
`authenticate` additionally widens ε by the *observed element growth*
max|U| / max|X| (clamped ≥ 1): the no-pivot schedule's rounding is
proportional to the largest intermediate the elimination produced, which
the returned factors expose. The growth term is what makes the threshold
dtype-portable — an equilibrated float32 ciphertext whose factorization
grew by g carries residual ~g·n·u, and a scale-only model either
false-alarms on it (scale clamps to 1) or needs a dtype-tuned fudge
(DESIGN.md §6.3).

How much widening a server may claim depends on whether the residual can
SEE the factors the growth is measured from. For the secret-probed Q1/Q2
residuals inflation is self-defeating: huge planted entries in U blow up
U·r with probability 1 over the client-held probe, so a result that
passes the widened check has small backward error relative to its own
factors — an exact factorization of a nearby matrix, whose determinant
is the right answer anyway. The diagonal-only Q3 residual has no such
property: a pair of huge strictly-upper entries U[j,i], U[j',i] chosen so
L[i,j]·U[j,i] + L[i,j']·U[j',i] = 0 cancels out of every diagonal term,
inflating max|U| (and hence ε) by an arbitrary factor G while leaving the
residual untouched — the server could then bias diagonal entries by
~ε·G and still verify. Q3/Q3-literal therefore clamp the widening at
`q3_growth_cap(n)` = c·n: the acceptance tolerance stays a client-chosen
bound, and honest runs keep ≥ 25× margin under it in every supported
configuration (the only config that needs widening at all — equilibrated
scale ≈ 1 with the growth-safe relayout disabled — needs ~10× at
n ≤ 256; see tests/test_precision.py and DESIGN.md §6.3).

Localization: Algorithm 3 gives server i ownership of block row i of both
factors, so a verification failure is *attributable*. Blocking the Q1
residual vector by server — rows [i·b, (i+1)·b) — names the culprit: a
corruption anywhere in server k's strips perturbs residual rows of block k
(L strip: directly; U strip: through (Ur)_k, which L's lower-triangular
support propagates only to rows ≥ k·b). The FIRST block with residual
above ε(N) is therefore the faulty server, and blocks above it are clean —
exactly the invariant the recovery scheduler (distrib/recovery.py) needs
to recompute a single strip from verified upstream rows. Q3's diagonal
terms attribute to the *diagonal owner* instead (an off-diagonal U tamper
in row k surfaces at column c's diagonal, implicating server ⌊c/b⌋), so
localization always uses the Q1 form regardless of the accept/reject
method; `per_server_residuals(..., method="q3")` stays available for
diagnostics.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


def q1(l: jnp.ndarray, u: jnp.ndarray, x: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """Gao & Yu's vector check: L(Ur) − Xr. Zero vector iff LU consistent."""
    ur = jnp.einsum("...ij,...j->...i", u, r)
    return (
        jnp.einsum("...ij,...j->...i", l, ur)
        - jnp.einsum("...ij,...j->...i", x, r)
    )


def q2(l: jnp.ndarray, u: jnp.ndarray, x: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """Paper's scalar probabilistic check: (Lᵀr)ᵀ(Ur) − (rᵀX)r."""
    lt_r = jnp.einsum("...ij,...i->...j", l, r)
    u_r = jnp.einsum("...ij,...j->...i", u, r)
    rx = jnp.einsum("...i,...ij->...j", r, x)
    return jnp.sum(lt_r * u_r, axis=-1) - jnp.sum(rx * r, axis=-1)


def q3(l: jnp.ndarray, u: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Deterministic diagonal check, per-element abs (the form the paper's
    own correctness proof §V.C.2 uses): Σ_i |(L·U)_ii − x_ii|."""
    lu_diag = jnp.einsum("...ij,...ji->...i", jnp.tril(l), jnp.triu(u))
    return jnp.sum(
        jnp.abs(lu_diag - jnp.diagonal(x, axis1=-2, axis2=-1)), axis=-1
    )


def q3_paper_literal(l: jnp.ndarray, u: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Q3 exactly as §IV.E.2 writes it: |Σ_i (Σ_{j≤i} L_ij U_ji − x_ii)|.

    Weaker than q3: opposite-sign per-row errors cancel (see
    tests/test_core_protocol.py::test_q3_literal_cancellation_weakness).
    """
    lu_diag = jnp.einsum("...ij,...ji->...i", jnp.tril(l), jnp.triu(u))
    return jnp.abs(
        jnp.sum(lu_diag - jnp.diagonal(x, axis1=-2, axis2=-1), axis=-1)
    )


def epsilon(
    num_servers: int,
    n: int,
    x: jnp.ndarray | None = None,
    *,
    dtype=jnp.float64,
    c: float = 64.0,
):
    """Acceptance threshold ε(N) — grows with server count (paper §IV.E.3).

    Scalar for a single matrix; a (B,) array for a (B, n, n) stack (each
    matrix gets a threshold scaled to its own magnitude).
    """
    u = float(jnp.finfo(dtype).eps)
    if x is not None:
        scale = jnp.linalg.norm(x, axis=(-2, -1)) / np.sqrt(n)
    else:
        scale = jnp.asarray(1.0)
    out = c * (1.0 + num_servers) * n * u * jnp.maximum(scale, 1.0) ** 2
    if out.ndim == 0:
        return float(out)
    return np.asarray(out)


def growth_estimate(u_factor: jnp.ndarray, x: jnp.ndarray):
    """Observed element growth of the no-pivot elimination, clamped ≥ 1:
    max|U| / max|X| per matrix (scalar, or (B,) for a stack).

    This is the classical growth factor ρ of the factorization the client
    actually received — the multiplier on the u·n rounding model that the
    value-independent (pivot-free) schedule cannot bound a priori.
    """
    num = jnp.max(jnp.abs(u_factor), axis=(-2, -1))
    den = jnp.maximum(jnp.max(jnp.abs(x), axis=(-2, -1)),
                      jnp.finfo(x.dtype).tiny)
    out = jnp.maximum(num / den, 1.0)
    if out.ndim == 0:
        return float(out)
    return np.asarray(out)


def q3_growth_cap(n: int, *, c: float = 4.0) -> float:
    """Ceiling on the ε-widening a diagonal-only (Q3) residual may claim.

    The observed growth is computed from the server-supplied U, and Q3
    never probes the strictly-upper entries it is largest over — planted
    mutually-cancelling entries inflate it for free (module docstring).
    Clamping at c·n keeps the acceptance tolerance client-chosen: honest
    factorizations that genuinely need widening (equilibrated input, no
    growth-safe relayout) stay ≥ 25× under the cap, while a malicious
    server's tolerance inflation is bounded by c·n instead of unbounded.
    The secret-probed Q1/Q2 residuals use the raw growth — there the
    widening is self-defeating to inflate.
    """
    return c * n


def per_server_residuals(
    l: jnp.ndarray,
    u: jnp.ndarray,
    x: jnp.ndarray,
    *,
    num_servers: int,
    method: str = "q1",
    r: jnp.ndarray | None = None,
    rng: np.random.Generator | None = None,
) -> np.ndarray:
    """Blocked residuals attributing the check to Alg. 3's block rows.

    Returns (N,) for a single matrix, (B, N) for a stack. method="q1" (the
    default, and what `localize` uses) blocks the Q1 residual vector by
    owner row — attribution-correct for any strip corruption (see module
    docstring). method="q3" blocks the diagonal terms by diagonal owner —
    a diagnostic view, not a culprit-namer.
    """
    n = x.shape[-1]
    if n % num_servers != 0:
        raise ValueError(f"n={n} not partitioned by N={num_servers}")
    batched = x.ndim == 3
    if method == "q1":
        if r is None:
            rng = rng or np.random.default_rng(1)
            r_shape = (x.shape[0], n) if batched else (n,)
            r = jnp.asarray(rng.standard_normal(r_shape), dtype=x.dtype)
        terms = jnp.abs(q1(l, u, x, r))  # (..., n)
        reduce = jnp.max
    elif method == "q3":
        lu_diag = jnp.einsum("...ij,...ji->...i", jnp.tril(l), jnp.triu(u))
        terms = jnp.abs(lu_diag - jnp.diagonal(x, axis1=-2, axis2=-1))
        reduce = jnp.sum
    else:
        raise ValueError(f"unknown localization method {method!r}")
    blocked = terms.reshape(*terms.shape[:-1], num_servers, n // num_servers)
    return np.asarray(reduce(blocked, axis=-1))


#: Verdict fields that may be scalars (single matrix) or per-matrix
#: numpy arrays (a stack) — the wire codec branches on this
_VERDICT_POLY = ("ok", "residual", "eps", "culprit")


@dataclass
class Verdict:
    """Structured Authenticate outcome: global accept/reject PLUS the
    per-server attribution the recovery scheduler consumes.

    Scalars (bool/float) for a single matrix; per-matrix numpy arrays for a
    (B, n, n) stack. `culprit` is the FIRST server whose residual block
    exceeds ε(N) — the owner of the earliest corrupted strip, with every
    strip above it verified-clean (-1 when all blocks pass).

    (The legacy `(verified, residual)` tuple emulation was removed after
    its deprecation cycle — unpack `.ok` / `.residual` explicitly.)

    Serializes with the role-split wire codec (`to_bytes`/`from_bytes`,
    repro.api.wire) so gateways and archives can move verdicts across
    process boundaries without pickle.
    """

    ok: bool | np.ndarray
    residual: float | np.ndarray
    method: str
    eps: float | np.ndarray
    num_servers: int
    server_residual: np.ndarray | None = None  # (N,) or (B, N)
    server_ok: np.ndarray | None = None
    culprit: int | np.ndarray = -1

    @property
    def all_ok(self) -> bool:
        return bool(np.all(self.ok))

    def to_bytes(self) -> bytes:
        from repro.api import wire

        scalars = {"method": self.method, "num_servers": self.num_servers}
        arrays = {"server_residual": self.server_residual,
                  "server_ok": self.server_ok}
        for name in _VERDICT_POLY:
            val = getattr(self, name)
            if isinstance(val, np.ndarray):
                arrays[name] = val
            elif isinstance(val, (bool, np.bool_)):
                scalars[name] = bool(val)
            elif isinstance(val, (int, np.integer)):
                scalars[name] = int(val)
            else:
                scalars[name] = float(val)
        return wire.encode("Verdict", scalars, arrays)

    @classmethod
    def _from_wire(cls, scalars, arrays):
        fields = {
            "method": scalars["method"],
            "num_servers": int(scalars["num_servers"]),
            "server_residual": arrays["server_residual"],
            "server_ok": arrays["server_ok"],
        }
        for name in _VERDICT_POLY:
            fields[name] = (
                arrays[name] if name in arrays else scalars[name]
            )
        return cls(**fields)

    @classmethod
    def from_bytes(cls, data: bytes) -> "Verdict":
        from repro.api import wire

        kind, scalars, arrays = wire.decode(data)
        if kind != "Verdict":
            raise wire.WireError(f"expected Verdict frame, got {kind!r}")
        return cls._from_wire(scalars, arrays)


def _first_culprit(server_ok: np.ndarray) -> int | np.ndarray:
    """Index of the first failing block row; -1 if all pass. (B,) if batched."""
    bad = ~server_ok
    if server_ok.ndim == 1:
        return int(np.argmax(bad)) if bad.any() else -1
    first = np.argmax(bad, axis=-1)
    return np.where(bad.any(axis=-1), first, -1).astype(np.int64)


def localize(
    l: jnp.ndarray,
    u: jnp.ndarray,
    x: jnp.ndarray,
    *,
    num_servers: int,
    eps: float | np.ndarray | None = None,
    rng: np.random.Generator | None = None,
) -> tuple[np.ndarray, np.ndarray, int | np.ndarray]:
    """(server_residual, server_ok, culprit) via the blocked Q1 residual."""
    n = x.shape[-1]
    if eps is None:
        eps = epsilon(num_servers, n, x, dtype=x.dtype)
        eps = eps * growth_estimate(u, x)
    sres = per_server_residuals(l, u, x, num_servers=num_servers, rng=rng)
    eps_col = np.asarray(eps)[..., None] if np.ndim(eps) else eps
    sok = sres <= eps_col
    return sres, sok, _first_culprit(sok)


def authenticate(
    l: jnp.ndarray,
    u: jnp.ndarray,
    x: jnp.ndarray,
    *,
    num_servers: int,
    method: str = "q3",
    rng: np.random.Generator | None = None,
    eps: float | np.ndarray | None = None,
    attribute: bool | str = "auto",
) -> Verdict:
    """Authenticate(L, U, X) → Verdict (accept/reject + per-server blame).

    method ∈ {"q1", "q2", "q3", "q3_literal"} picks the accept/reject
    residual. For q1/q2 a random r is drawn client-side (the server never
    sees it) — an independent probe per matrix when X is a (B, n, n) stack.
    rng SHOULD be seeded from client-held secret material (the protocol
    seeds it from the Ψ digest): with the module-default generator an
    adversarial server who knows the codebase can pick a perturbation
    orthogonal to the predictable probe and evade the q1/q2 checks and the
    localization pass entirely.

    attribute="auto" (default) computes the blocked-Q1 per-server
    residuals and culprit index only when the global verdict rejects (its
    sole consumer is the recovery scheduler) and n divides evenly over
    num_servers; True forces the pass on accepting verdicts too, False
    always skips it.

    Returns a Verdict; its fields are scalars for a single matrix and
    per-matrix numpy arrays for a stack. Unpacking the Verdict as the old
    (verified, residual) tuple still works but warns.
    """
    n = x.shape[-1]
    batched = x.ndim == 3
    widened_eps = None
    if eps is None:
        # scale-model ε widened by the observed element growth of the
        # returned factors (module docstring — the dtype-portable term).
        # The raw widening is reserved for residuals that SEE the factors
        # it is measured from: the secret-probed q1/q2 here, and the
        # Q1-shaped localization pass below. The diagonal-only q3 forms
        # clamp it at q3_growth_cap(n) — otherwise planted cancelling
        # strictly-upper entries hand the server an arbitrarily wide ε.
        base_eps = epsilon(num_servers, n, x, dtype=x.dtype)
        growth = growth_estimate(u, x)
        widened_eps = base_eps * growth
        if method in ("q3", "q3_literal"):
            eps = base_eps * np.minimum(growth, q3_growth_cap(n))
        else:
            eps = widened_eps
    if method in ("q1", "q2"):
        rng = rng or np.random.default_rng(0)
        r_shape = (x.shape[0], n) if batched else (n,)
        r = jnp.asarray(rng.standard_normal(r_shape), dtype=x.dtype)
        if method == "q1":
            resid = jnp.max(jnp.abs(q1(l, u, x, r)), axis=-1)
        else:
            resid = jnp.abs(q2(l, u, x, r))
            # Q2 contracts twice with r: widen by the extra ‖r‖² factor.
            eps = eps * n
    elif method == "q3":
        resid = q3(l, u, x)
    elif method == "q3_literal":
        resid = q3_paper_literal(l, u, x)
    else:
        raise ValueError(f"unknown authentication method {method!r}")
    if batched:
        resid = np.asarray(resid)
        ok = np.asarray(resid <= eps)
        eps_out = np.asarray(eps) + np.zeros_like(resid)
    else:
        resid = float(resid)
        ok = bool(resid <= eps)
        eps_out = float(np.asarray(eps))
    verdict = Verdict(
        ok=ok,
        residual=resid,
        method=method,
        eps=eps_out,
        num_servers=num_servers,
    )
    wanted = attribute is True or (
        attribute == "auto" and not bool(np.all(verdict.ok))
    )
    if wanted and n % num_servers == 0:
        # localization eps: the blocked check is Q1-shaped, so use the raw
        # growth-widened ε(N) (no Q2 widening) — already computed above
        # unless the caller supplied an explicit eps
        if widened_eps is None:
            widened_eps = epsilon(num_servers, n, x, dtype=x.dtype) \
                * growth_estimate(u, x)
        loc_eps = widened_eps
        sres, sok, culprit = localize(
            l, u, x, num_servers=num_servers, eps=loc_eps, rng=rng
        )
        verdict.server_residual = sres
        verdict.server_ok = sok
        verdict.culprit = culprit
    return verdict


def verification_flops(n: int, method: str) -> int:
    """Cost models backing benchmarks/ (paper Table I's Authenticate column)."""
    if method == "q1":
        return 3 * 2 * n * n  # three mat-vec products
    if method == "q2":
        return 3 * 2 * n * n + 2 * 2 * n  # three mat-vec + two dot products
    if method in ("q3", "q3_literal"):
        return 2 * n * (n + 1) // 2 + n  # Σ_i 2i muls/adds + n subtractions
    raise ValueError(method)
