"""SeedGen — paper §IV.A.

Ψ = H(λ₁, μ, M_max): a cryptographic hash of the security parameter and the
matrix's statistical properties (mean and max), mapped to a positive float
in a numerically safe range.

The hash-to-float mapping matters for numerics: Ψ is the *product* of the n
blinding-vector entries (§IV.B), so each entry has geometric mean Ψ^{1/n}.
We map the 256-bit digest to Ψ ∈ [2^-4, 2^4] — wide enough for 8 bits of
entropy in the exponent alone (plus 52 mantissa bits), narrow enough that
blinding never overflows float64 for any n. Security rests on the digest,
not on Ψ's magnitude.
"""
from __future__ import annotations

import hashlib
import struct
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Seed:
    """The client-secret seed Ψ plus the matrix statistics that fed it."""

    psi: float
    mu: float
    m_max: float
    digest: bytes  # full H(λ₁, μ, M_max) — feeds KeyGen's CSPRNG

    def __float__(self) -> float:
        return self.psi


def _hash(lambda1: int, mu: float, m_max: float) -> bytes:
    h = hashlib.sha256()
    h.update(struct.pack(">q", int(lambda1)))
    h.update(struct.pack(">d", float(mu)))
    h.update(struct.pack(">d", float(m_max)))
    return h.digest()


def seedgen(lambda1: int, m: np.ndarray) -> Seed:
    """SeedGen(λ₁, M) → (Ψ, μ, M_max). Runs on the client, off-accelerator."""
    arr = np.asarray(m, dtype=np.float64)
    if arr.ndim != 2 or arr.shape[0] != arr.shape[1]:
        raise ValueError(f"M must be square, got shape {arr.shape}")
    mu = float(arr.mean())
    m_max = float(arr.max())
    digest = _hash(lambda1, mu, m_max)
    # Map first 8 digest bytes to u ∈ [0, 1), then Ψ = 2^(8u - 4) ∈ [2^-4, 2^4).
    u = struct.unpack(">Q", digest[:8])[0] / 2**64
    psi = float(2.0 ** (8.0 * u - 4.0))
    return Seed(psi=psi, mu=mu, m_max=m_max, digest=digest)


def seedgen_batch(lambda1: int, m: np.ndarray) -> list[Seed]:
    """SeedGen over a (B, n, n) stack — one independent seed per matrix.

    Hashing is host-side and O(1) per matrix; the heavy per-matrix numerics
    downstream (cipher/LU/verify) consume the stacked outputs in one
    batched device program (DESIGN.md §3).
    """
    arr = np.asarray(m, dtype=np.float64)
    if arr.ndim != 3 or arr.shape[-1] != arr.shape[-2]:
        raise ValueError(f"M must be a (B, n, n) stack, got shape {arr.shape}")
    return [seedgen(lambda1, arr[i]) for i in range(arr.shape[0])]
