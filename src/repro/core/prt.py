"""Panth Rotation Theorem (PRT) — paper §II.A.

The theorem: for an n×n matrix X and k clockwise quarter-turns,

    det(rot90_cw^k(X)) = ((-1)^{floor(n/2)})^k · det(X)

so the determinant sign is invariant for n ≡ 0,1 (mod 4) and flips per
quarter-turn for n ≡ 2,3 (mod 4). 180° (k=2) always preserves the sign.

This module provides the rotation itself (as a cheap, fusable JAX op), the
sign law, and the paper's literal (erroneous for n ≡ 0,1 mod 4, k odd)
recovery factor for faithful comparison — see DESIGN.md §1.1.
"""
from __future__ import annotations

import jax.numpy as jnp


def rot90_cw(x: jnp.ndarray, k: int = 1) -> jnp.ndarray:
    """Rotate a matrix by k clockwise quarter-turns.

    Matches the paper's R_90(X): transpose followed by column reversal.
    jnp.rot90 rotates counter-clockwise, so cw k turns == ccw (-k) turns.
    """
    k = k % 4
    return jnp.rot90(x, k=-k, axes=(0, 1))


def rotation_sign(n: int, k: int) -> int:
    """Correct determinant sign factor after k clockwise quarter-turns.

    det(rot90_cw^k(X)) = rotation_sign(n, k) * det(X).
    """
    return (-1) ** ((n // 2) * (k % 4))


def rotation_sign_paper(k: int) -> int:
    """The paper's literal Decipher factor (-1)^{Rotate(Ψ)} — ignores n.

    Correct only for n ≡ 2,3 (mod 4). Kept for the faithful-reproduction
    comparison in tests and EXPERIMENTS.md.
    """
    return (-1) ** (k % 4)


def flip_sign(n: int) -> int:
    """Determinant sign of the n×n exchange (anti-identity) matrix J:
    det(J) = (-1)^{floor(n/2)} — one column flip is floor(n/2) swaps."""
    return (-1) ** (n // 2)


def growth_safe_sign(n: int, k: int) -> int:
    """Determinant sign of the growth-safe relayout (DESIGN.md §6.1).

    The growth-safe cipher composes rot90_cw^k with an exchange flip for
    odd k (column flip for k=1, row flip for k=3), so the composite map is
    a plain transpose — the main diagonal stays on the main diagonal and a
    diagonally dominant input keeps the no-pivot LU's element growth ~1.
    det is transpose-invariant, so the odd-k sign factor is exactly +1;
    even k falls back to the rotation sign (180° preserves dominance and
    needs no flip):

        k odd:  rotation_sign(n, k) * flip_sign(n) = ((-1)^{n//2})^2 = +1
        k even: rotation_sign(n, k)
    """
    if k % 2 == 1:
        return 1
    return rotation_sign(n, k)


def sign_preserved(n: int, k: int) -> bool:
    """True iff a k-quarter-turn rotation preserves det sign for size n.

    Encodes the theorem's case split:
      n ≡ 0,1 (mod 4): preserved for all k.
      n ≡ 2,3 (mod 4): preserved iff k even.
    """
    return rotation_sign(n, k) == 1


def quantize_seed(psi: float, method: str = "floor") -> int:
    """Quantized seed Ψ' — paper §IV.C.2 offers floor/ceil/round/trunc."""
    import math

    if method == "floor":
        return int(math.floor(psi))
    if method == "ceil":
        return int(math.ceil(psi))
    if method == "round":
        return int(round(psi))
    if method == "trunc":
        return int(psi)
    raise ValueError(f"unknown quantization method: {method!r}")


def rotate_degree(psi: float, method: str = "floor") -> int:
    """Rotate(Ψ) ∈ {1,2,3} — the number of clockwise quarter-turns.

    Paper §IV.C.2: Ψ' = quantize(Ψ); degree = (Ψ' mod 3) + 1, mapping to
    {90°, 180°, 270°}.
    """
    return (quantize_seed(psi, method) % 3) + 1
