"""Untrusted-server fault models — first-class, testable misbehavior.

The paper's threat model (§IV.E) is that the N edge servers are untrusted:
Q2/Q3 exist so the client can *reject* bad results. This module makes the
misbehavior itself first-class so the verification and recovery layers can
be exercised deterministically:

  * ``tamper``  — the server corrupts the L/U strip it reports. Three modes
    matching the verification-power study (tests/test_faults.py):
    ``single`` (one element perturbed), ``sign_flip`` (one element negated),
    ``block`` (the whole strip scaled — a wholesale substitution).
  * ``dropout`` — the server's strip never arrives; the client sees zeros
    (an all-zero L diagonal is structurally invalid, so Q1/Q3 flag it).
  * ``delay``   — a straggler. TWO units exist, matching the two kinds of
    execution boundary, and they are NOT interchangeable:

    - ``delay_rounds`` is measured in *pipeline rounds* — the abstract
      schedule steps of the fused single-process simulation and the
      shard_map pipeline, where no wall clock exists. It is meaningful
      ONLY against ``straggler_deadline`` (also in rounds): a client with
      deadline d treats any server later than d rounds as dropped and
      re-dispatches proactively (``resolve_delays``). On message
      transports (threadpool/multiprocess) rounds are meaningless and
      ``delay_rounds`` is ignored.
    - ``delay_s`` is wall-clock *seconds* — a real sleep executed by the
      worker on message transports before it reports its strip
      (``sample_delay``; ``delay_dist`` draws it from a fixed /
      exponential / Pareto latency distribution, the synthetic straggler
      models the rateless benchmarks use). Fused transports ignore it
      (there is no wall clock inside one jitted sweep).

    Both units converge on ONE straggler policy — dropout semantics: a
    server past the rounds deadline is dropped by ``resolve_delays``
    before dispatch; a server past a transport's wall-clock request
    timeout raises ``TransportTimeout`` and the relay substitutes a
    zero (dropped) strip, so verification localizes it and recovery
    re-dispatches — exactly as if the fault had been a ``dropout``. The
    rateless scheduler (distrib/rateless.py) applies the same rule per
    strip, with no deadline to tune: a slow server is simply assigned
    less work.

Faults are *per-server* (Algorithm 3's block-row ownership makes a server's
contribution exactly one L strip + one U strip) and *batch-aware*
(``matrices`` restricts a fault to chosen matrices of a (B, n, n) stack —
a server may corrupt one request and serve the rest honestly).

``in_band=True`` marks a tamper that enters the one-way relay chain: the
corrupted U row is what downstream servers consume, so every block row at
or below the faulty server is poisoned. Only the single-process simulation
(``core.lu.lu_nserver``) models in-band corruption; the shard_map pipeline
injects at the device-output (report) level. Recovery handles both — the
in-band case cascades one verification-driven re-dispatch per poisoned row.

Every ``ServerFault`` is a frozen (hashable) dataclass so a ``FaultPlan``
tuple can be a static jit argument and a compile-cache key.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

TAMPER_MODES = ("single", "sign_flip", "block")
FAULT_KINDS = ("tamper", "dropout", "delay")
DELAY_DISTS = ("fixed", "exponential", "pareto")


@dataclass(frozen=True)
class ServerFault:
    """One misbehaving server. See the module docstring for semantics.

    On message transports faults bind to the PHYSICAL worker id (the
    process/thread slot), which for the classic N-server dispatch is the
    same as the block-row index; under rateless dispatch a worker runs
    many strips and misbehaves on all of them.
    """

    server: int
    kind: str = "tamper"  # "tamper" | "dropout" | "delay"
    mode: str = "single"  # tamper only: "single" | "sign_flip" | "block"
    target: str = "u"  # tamper only: corrupt "l", "u", or "lu"
    magnitude: float = 0.05
    delay_rounds: int = 0  # delay only: PIPELINE ROUNDS late (fused paths)
    delay_s: float = 0.0  # delay only: wall-clock SECONDS (message paths)
    delay_dist: str = "fixed"  # "fixed" | "exponential" | "pareto"
    delay_alpha: float = 1.5  # pareto shape (tail heaviness; mean-preserving)
    matrices: tuple[int, ...] | None = None  # batch indices hit; None = all
    in_band: bool = False  # corruption enters the relay chain
    seed: int = 0  # position PRNG for single/sign_flip

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of {FAULT_KINDS}"
            )
        if self.kind == "tamper" and self.mode not in TAMPER_MODES:
            raise ValueError(
                f"unknown tamper mode {self.mode!r}; expected one of {TAMPER_MODES}"
            )
        if self.target not in ("l", "u", "lu"):
            raise ValueError(f"target must be 'l', 'u', or 'lu', got {self.target!r}")
        if self.server < 0:
            raise ValueError("server must be >= 0")
        if self.delay_dist not in DELAY_DISTS:
            raise ValueError(
                f"unknown delay_dist {self.delay_dist!r}; expected one of "
                f"{DELAY_DISTS}"
            )
        if self.delay_s < 0.0:
            raise ValueError("delay_s must be >= 0 seconds")
        if self.delay_dist == "pareto" and self.delay_alpha <= 1.0:
            raise ValueError(
                "pareto delay_alpha must be > 1 (finite mean; delay_s is "
                "the mean of the sampled distribution)"
            )
        if self.in_band and self.kind != "tamper":
            raise ValueError(
                "in_band is only meaningful for tamper faults (a dropped or "
                "late server sends nothing downstream; the pipeline stalls "
                "and the client's deadline converts it to a dropout)"
            )


#: A fault plan is a (possibly empty) tuple of ServerFaults — frozen and
#: hashable so it can ride through jit as a static argument and serve as a
#: compile-cache key. Build one with `normalize_plan(...)`, which accepts
#: None, a bare ServerFault, or any iterable of them; protocol entry
#: points (`outsource_determinant(faults=...)`) normalize for you.
FaultPlan = tuple[ServerFault, ...]


def normalize_plan(faults) -> FaultPlan:
    """Accept None, a single ServerFault, or an iterable → canonical tuple."""
    if faults is None:
        return ()
    if isinstance(faults, ServerFault):
        return (faults,)
    plan = tuple(faults)
    for f in plan:
        if not isinstance(f, ServerFault):
            raise TypeError(f"fault plan entries must be ServerFault, got {f!r}")
    return plan


def resolve_delays(faults, deadline: int | None) -> FaultPlan:
    """Client-side straggler policy for ROUND-denominated delays.

    ``deadline`` is measured in *pipeline rounds* (see the module
    docstring's unit discussion) — it is the fused-path analog of a
    message transport's wall-clock request timeout, and both resolve to
    the same dropout semantics:

      * a delay later than ``deadline`` rounds becomes a ``dropout`` here,
        BEFORE dispatch (the fused sweep has no wall clock to wait on);
      * an on-time-enough round delay is harmless and removed;
      * ``deadline=None`` tolerates any round delay (the client waits).

    Wall-clock delays (``delay_s > 0``) are NOT resolved here — they ride
    through to the message-transport workers, which actually sleep, and
    the transport's per-request timeout converts an over-budget sleep
    into the very same dropout (``TransportTimeout`` → zero strip →
    localization → re-dispatch). One policy, two clocks.
    """
    out = []
    for f in normalize_plan(faults):
        if f.kind != "delay":
            out.append(f)
        elif deadline is not None and f.delay_rounds > deadline:
            out.append(
                ServerFault(server=f.server, kind="dropout", matrices=f.matrices)
            )
        elif f.delay_s > 0.0:
            # wall-clock straggler: keep it in the effective plan so the
            # worker-side sleep actually happens on message transports
            # (fused paths ignore it — corrupt_strip is identity on delay)
            out.append(f)
    return tuple(out)


def sample_delay(fault: ServerFault, token: bytes = b"") -> float:
    """Draw one wall-clock delay (seconds) for a delay fault.

    Deterministic given (fault, token): benchmarks and the chaos tests
    seed ``token`` from the dispatch sub-seed so a straggling worker's
    latency sequence reproduces exactly. ``delay_s`` is the MEAN of every
    distribution; ``pareto`` keeps the mean but adds the heavy tail
    (shape ``delay_alpha``) that makes deadline tuning hopeless — the
    motivating case for rateless dispatch.
    """
    if fault.kind != "delay" or fault.delay_s <= 0.0:
        return 0.0
    if fault.delay_dist == "fixed":
        return float(fault.delay_s)
    import hashlib

    h = hashlib.sha256(
        token + fault.seed.to_bytes(8, "big", signed=True)
        + fault.server.to_bytes(8, "big", signed=True)
    ).digest()
    rng = np.random.default_rng(int.from_bytes(h[:8], "big"))
    if fault.delay_dist == "exponential":
        return float(rng.exponential(fault.delay_s))
    # pareto: delay_s * (alpha-1) * Lomax(alpha) has mean delay_s for
    # alpha > 1 — same budget as the exponential, much heavier tail
    a = fault.delay_alpha
    return float(fault.delay_s * (a - 1.0) * rng.pareto(a))


def _tamper_position(
    fault: ServerFault, *, block: int, n: int, factor: str
) -> tuple[int, int]:
    """Deterministic (local_row, global_col) inside the faulty strip, kept
    within the named factor's structural support so the corruption is
    something a malicious server could actually report. ``factor`` is the
    strip being corrupted ("l" or "u") — for target="lu" faults each
    factor gets a position inside its own triangle."""
    row0 = fault.server * block
    h = (fault.seed * 1315423911 + fault.server * 2654435761) & 0x7FFFFFFF
    if factor == "l" and fault.server > 0:
        r = h % block
        g = row0 + r
        c = (h >> 8) % g  # strictly lower: 0 <= c < g
        return r, c
    if factor == "l":
        # server 0's L strip: strictly-lower entries need r >= 1
        r = 1 + h % max(1, block - 1)
        c = (h >> 8) % (row0 + r)
        return r, c
    r = h % block
    g = row0 + r
    c = g + (h >> 8) % (n - g)  # upper: g <= c < n
    return r, c


def corrupt_strip(
    strip: jnp.ndarray,
    fault: ServerFault,
    *,
    n: int,
    factor: str | None = None,
) -> jnp.ndarray:
    """Apply one tamper/dropout fault to a server's (..., b, n) strip.

    Pure jnp with static positions — usable on full-matrix slices
    (report-level), inside ``lu_nserver``'s wavefront (in-band), and inside
    the shard_map server program (device-local injection). ``factor``
    names which strip this is ("l"/"u") so single-element positions stay
    in its triangle; defaults to the fault's target when unambiguous.
    Batch targeting (``fault.matrices``) is handled by the callers, which
    know the batch layout; this function corrupts every leading index it
    is given.
    """
    b = strip.shape[-2]
    if fault.kind == "dropout":
        return jnp.zeros_like(strip)
    if fault.kind == "delay":
        return strip
    if fault.mode == "block":
        return strip * (1.0 + fault.magnitude)
    if factor is None:
        factor = "u" if fault.target == "lu" else fault.target
    r, c = _tamper_position(fault, block=b, n=n, factor=factor)
    if fault.mode == "sign_flip":
        return strip.at[..., r, c].multiply(-1.0)
    # single: multiplicative + additive so structurally-zero entries move too
    return strip.at[..., r, c].set(
        strip[..., r, c] * (1.0 + fault.magnitude) + fault.magnitude
    )


def _splice(full: jnp.ndarray, strip: jnp.ndarray, fault: ServerFault, b: int):
    """Write a corrupted strip back into the full factor, honoring the
    fault's batch targeting."""
    sl = slice(fault.server * b, (fault.server + 1) * b)
    if fault.matrices is not None and full.ndim == 3:
        idx = np.asarray(fault.matrices, dtype=np.int32)
        return full.at[idx, sl, :].set(strip[idx])
    return full.at[..., sl, :].set(strip)


def apply_faults(
    l: jnp.ndarray,
    u: jnp.ndarray,
    faults,
    *,
    num_servers: int,
    deadline: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Report-level fault application on full (..., n, n) factors.

    Models what the *client* receives: each fault corrupts (or zeroes) the
    responsible server's strip of L and/or U. ``deadline`` resolves delay
    faults first (see ``resolve_delays``). In-band faults are NOT applied
    here — they belong inside the factorization (``lu_nserver(faults=…)``).
    """
    n = l.shape[-1]
    b = n // num_servers
    for f in resolve_delays(faults, deadline):
        if f.in_band:
            continue
        if f.server >= num_servers:
            raise ValueError(f"fault targets server {f.server} of {num_servers}")
        targets = ("l", "u") if f.kind == "dropout" else tuple(f.target)
        sl = slice(f.server * b, (f.server + 1) * b)
        if "l" in targets:
            bad = corrupt_strip(l[..., sl, :], f, n=n, factor="l")
            l = _splice(l, bad, f, b)
        if "u" in targets:
            bad = corrupt_strip(u[..., sl, :], f, n=n, factor="u")
            u = _splice(u, bad, f, b)
    return l, u


def split_plan(faults) -> tuple[FaultPlan, FaultPlan]:
    """(in_band, report_level) partition of a plan."""
    plan = normalize_plan(faults)
    in_band = tuple(f for f in plan if f.in_band)
    report = tuple(f for f in plan if not f.in_band)
    return in_band, report
