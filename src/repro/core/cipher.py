"""Cipher — Composite Element Distortion (CED), paper §IV.C.

CED = EWO ∘ PRT:

  * EWO (element-wise obfuscation): row i is divided (EWD) or multiplied
    (EWM) by blinding entry v_i.
  * PRT obfuscation: the scaled matrix is rotated by k ∈ {1,2,3} clockwise
    quarter-turns, k = Rotate(Ψ) = (⌊Ψ⌋ mod 3) + 1.

Both are applied in a single pass ("run simultaneously", §IV.C): the fused
Pallas kernel (kernels/ced.py) reads each input tile once, scales it in
VMEM, and writes it to the rotated destination via the BlockSpec index map —
the rotation costs nothing beyond addressing. This module is the public API;
it dispatches to the fused kernel or a pure-jnp path.

Determinant bookkeeping (used by Decipher):

    EWD:  det(X) = det(M) / Ψ · s      EWM:  det(X) = det(M) · Ψ · s

with s = rotation_sign(n, k).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Literal

import jax.numpy as jnp
import numpy as np

from .keygen import Key
from .prt import rot90_cw, rotate_degree
from .seed import Seed

Mode = Literal["ewd", "ewm"]


@dataclass(frozen=True)
class CipherMeta:
    """Public-side record of how M was ciphered (client keeps this)."""

    mode: Mode
    rotate_k: int  # quarter-turns applied
    n: int


def ewo(m: jnp.ndarray, v: jnp.ndarray, mode: Mode) -> jnp.ndarray:
    """Element-wise obfuscation: row-scale by the blinding vector."""
    v = v.reshape(-1, 1).astype(m.dtype)
    if mode == "ewd":
        return m / v
    if mode == "ewm":
        return m * v
    raise ValueError(f"unknown EWO mode: {mode!r}")


def cipher(
    m: jnp.ndarray,
    key: Key,
    seed: Seed,
    *,
    mode: Mode = "ewd",
    use_kernel: bool = False,
    interpret: bool = True,
) -> tuple[jnp.ndarray, CipherMeta]:
    """Cipher(K, M) → X. Returns the ciphertext and the (client-held) meta.

    use_kernel selects the fused Pallas CED kernel (TPU target; interpret
    mode executes it on CPU). The jnp path is the oracle.
    """
    n = int(m.shape[0])
    if key.v.shape[0] != n:
        raise ValueError(f"blinding vector length {key.v.shape[0]} != n {n}")
    k = rotate_degree(seed.psi)
    if use_kernel:
        from repro.kernels import ops as kops

        x = kops.ced(m, jnp.asarray(key.v), k, mode=mode, interpret=interpret)
    else:
        x = rot90_cw(ewo(m, jnp.asarray(key.v), mode), k)
    return x, CipherMeta(mode=mode, rotate_k=k, n=n)


def cipher_flops(n: int) -> int:
    """Cipher cost model — paper Table I claims n² flops for our protocol.

    One multiply (or divide) per element; the rotation is pure data
    movement (0 flops).
    """
    return n * n
