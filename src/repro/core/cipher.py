"""Cipher — Composite Element Distortion (CED), paper §IV.C.

CED = EWO ∘ PRT:

  * EWO (element-wise obfuscation): row i is divided (EWD) or multiplied
    (EWM) by blinding entry v_i.
  * PRT obfuscation: the scaled matrix is rotated by k ∈ {1,2,3} clockwise
    quarter-turns, k = Rotate(Ψ) = (⌊Ψ⌋ mod 3) + 1.

Both are applied in a single pass ("run simultaneously", §IV.C): the fused
Pallas kernel (kernels/ced.py) reads each input tile once, scales it in
VMEM, and writes it to the rotated destination via the BlockSpec index map —
the rotation costs nothing beyond addressing. This module is the public API;
it dispatches to the fused kernel or a pure-jnp path.

Determinant bookkeeping (used by Decipher):

    EWD:  det(X) = det(M) / Ψ · s      EWM:  det(X) = det(M) · Ψ · s

with s = rotation_sign(n, k) (growth_safe_sign(n, k) when the growth-safe
relayout is on).

Growth control (DESIGN.md §6) — two composable, det-tracked devices that
keep the no-pivot LU's element growth fp32-survivable:

  * growth_safe relayout: odd rotations (k ∈ {1, 3}) map the main diagonal
    onto the anti-diagonal, turning a diagonally dominant input into an
    anti-diagonally dominant ciphertext whose leading principal minors are
    structurally tiny — the no-pivot schedule then grows elements by ~n
    regardless of any scaling. Composing the odd rotation with an exchange
    flip (rot¹(A)·J = J·rot³(A) = Aᵀ) keeps the dominance structure on the
    diagonal; the flip's det sign is folded into Decipher exactly.
  * equilibrate(): two-sided power-of-two row/col scaling of the
    ciphertext. Scales are exact in any binary float format, so the
    transform is lossless; the log-det correction Σ log r_i + Σ log c_j is
    replayable bookkeeping the client folds into Decipher, like the
    padding draw.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .keygen import Key
from .prt import rot90_cw, rotate_degree
from .seed import Seed

Mode = Literal["ewd", "ewm"]


@dataclass(frozen=True)
class CipherMeta:
    """Public-side record of how M was ciphered (client keeps this)."""

    mode: Mode
    rotate_k: int  # quarter-turns applied
    n: int
    #: growth-safe relayout: odd rotations composed with an exchange flip
    #: (the ciphertext is the transposed, not rotated, scaled matrix);
    #: Decipher must use growth_safe_sign instead of rotation_sign
    flipped: bool = False


def ewo(m: jnp.ndarray, v: jnp.ndarray, mode: Mode) -> jnp.ndarray:
    """Element-wise obfuscation: row-scale by the blinding vector."""
    v = v.reshape(-1, 1).astype(m.dtype)
    if mode == "ewd":
        return m / v
    if mode == "ewm":
        return m * v
    raise ValueError(f"unknown EWO mode: {mode!r}")


def _flip_rotated(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Exchange-flip that undoes an odd rotation's diagonal→anti-diagonal
    map: column flip after k=1, row flip before-equivalent after k=3. Both
    compositions equal the transpose of the unrotated input; implemented
    as the flip so kernel-produced rotations compose identically."""
    if k % 2 == 0:
        return x
    if k % 4 == 1:
        return x[..., :, ::-1]
    return x[..., ::-1, :]


def cipher(
    m: jnp.ndarray,
    key: Key,
    seed: Seed,
    *,
    mode: Mode = "ewd",
    growth_safe: bool = False,
    use_kernel: bool = False,
    interpret: bool = True,
) -> tuple[jnp.ndarray, CipherMeta]:
    """Cipher(K, M) → X. Returns the ciphertext and the (client-held) meta.

    use_kernel selects the fused Pallas CED kernel (TPU target; interpret
    mode executes it on CPU). The jnp path is the oracle.

    growth_safe composes odd rotations with a det-tracked exchange flip
    (module docstring / DESIGN.md §6.1) so the no-pivot LU's element
    growth stays fp32-survivable; meta.flipped records it for Decipher.
    """
    n = int(m.shape[0])
    if key.v.shape[0] != n:
        raise ValueError(f"blinding vector length {key.v.shape[0]} != n {n}")
    k = rotate_degree(seed.psi)
    if use_kernel:
        from repro.kernels import ops as kops

        x = kops.ced(m, jnp.asarray(key.v), k, mode=mode,
                     growth_safe=growth_safe, interpret=interpret)
    else:
        x = rot90_cw(ewo(m, jnp.asarray(key.v), mode), k)
        if growth_safe:
            x = _flip_rotated(x, k)
    return x, CipherMeta(mode=mode, rotate_k=k, n=n,
                         flipped=growth_safe and k % 2 == 1)


@partial(jax.jit, static_argnames=("mode", "growth_safe"))
def _cipher_batch_jnp(m: jnp.ndarray, v: jnp.ndarray, ks: jnp.ndarray,
                      *, mode: Mode, growth_safe: bool = False) -> jnp.ndarray:
    """Batched CED, pure jnp: per-matrix blinding vector AND rotation degree.

    The per-example quarter-turn count is data (each matrix has its own
    seed), so the rotation is a vmapped lax.switch over the four turn
    counts — XLA lowers it to selects over cheap relayouts; still zero
    flops beyond the blinding scale. growth_safe swaps the odd-rotation
    branches for their flip compositions (= transpose; see cipher()).
    """

    if growth_safe:
        branches = [
            lambda a: a,
            lambda a: a.T,  # rot¹ then column flip
            lambda a: jnp.rot90(a, k=-2, axes=(0, 1)),
            lambda a: a.T,  # rot³ then row flip
        ]
    else:
        branches = [
            lambda a: a,
            lambda a: jnp.rot90(a, k=-1, axes=(0, 1)),
            lambda a: jnp.rot90(a, k=-2, axes=(0, 1)),
            lambda a: jnp.rot90(a, k=-3, axes=(0, 1)),
        ]

    def one(mi, vi, ki):
        return lax.switch(ki % 4, branches, ewo(mi, vi, mode))

    return jax.vmap(one)(m, v, ks)


def cipher_batch(
    m: jnp.ndarray,
    key_vs: np.ndarray | jnp.ndarray,
    seeds: list[Seed],
    *,
    mode: Mode = "ewd",
    growth_safe: bool = False,
    use_kernel: bool = False,
    interpret: bool = True,
) -> tuple[jnp.ndarray, list[CipherMeta]]:
    """Batched Cipher: (B, n, n) stack + (B, n) stacked blinding vectors.

    Pure-jnp path is one jitted vmapped program. The Pallas path groups the
    batch by rotation degree (the kernel's output index map is static in k)
    and launches one batched-grid kernel per group — at most 3 launches for
    any B.
    """
    B, n = int(m.shape[0]), int(m.shape[-1])
    if len(seeds) != B:
        raise ValueError(f"{len(seeds)} seeds for batch of {B}")
    v = jnp.asarray(key_vs, dtype=m.dtype)
    if v.shape != (B, n):
        raise ValueError(f"blinding stack shape {v.shape} != {(B, n)}")
    ks = np.array([rotate_degree(s.psi) for s in seeds], dtype=np.int32)
    metas = [
        CipherMeta(mode=mode, rotate_k=int(k), n=n,
                   flipped=growth_safe and int(k) % 2 == 1)
        for k in ks
    ]
    if use_kernel:
        from repro.kernels import ops as kops

        x = jnp.zeros_like(m)
        for k in sorted(set(ks.tolist())):
            idx = np.nonzero(ks == k)[0]
            xk = kops.ced(m[idx], v[idx], int(k), mode=mode,
                          growth_safe=growth_safe, interpret=interpret)
            x = x.at[idx].set(xk)
    else:
        x = _cipher_batch_jnp(m, v, jnp.asarray(ks), mode=mode,
                              growth_safe=growth_safe)
    return x, metas


def equilibrate(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Two-sided power-of-two equilibration of a ciphertext (DESIGN.md §6.2).

    Scales row i by r_i = 2^{-round(log2 max_j |x_ij|)} and then column j
    by c_j = 2^{-round(log2 max_i |(r x)_ij|)}, driving every row/col max
    magnitude into [2^{-1/2}, 2^{1/2}]. Powers of two make the scaling
    EXACT in any binary float format — the transform is lossless and fully
    replayable from the ciphertext itself (no extra secret state).

    Returns (x_eq, log2_scale) with log2_scale the INTEGER
    Σ log2 r_i + Σ log2 c_j (int32 — exact for any n, where a float32 sum
    of n log terms would round), so

        log|det x| = log|det x_eq| − log2_scale · ln 2

    — the correction Decipher folds in (`decipher(..., log2_scale=…)`,
    with the ln 2 multiply done in float64 on the host). Batch-aware:
    (..., n, n) input gives (...,)-shaped log2_scale. All-zero rows /
    columns scale by 1 (their max is clamped), leaving det = 0 alone.
    """
    def pow2_exp(maxabs):
        # integer exponent of the power of two nearest the magnitude;
        # clamp 0 → exponent 0 (scale 1)
        safe = jnp.where(maxabs > 0, maxabs, 1.0)
        return jnp.round(jnp.log2(safe)).astype(jnp.int32)

    e_r = pow2_exp(jnp.max(jnp.abs(x), axis=-1))
    x = x * jnp.exp2(-e_r.astype(x.dtype))[..., :, None]
    e_c = pow2_exp(jnp.max(jnp.abs(x), axis=-2))
    x = x * jnp.exp2(-e_c.astype(x.dtype))[..., None, :]
    log2_scale = -(jnp.sum(e_r, axis=-1) + jnp.sum(e_c, axis=-1))
    return x, log2_scale


def cipher_flops(n: int) -> int:
    """Cipher cost model — paper Table I claims n² flops for our protocol.

    One multiply (or divide) per element; the rotation is pure data
    movement (0 flops).
    """
    return n * n
