"""Cipher — Composite Element Distortion (CED), paper §IV.C.

CED = EWO ∘ PRT:

  * EWO (element-wise obfuscation): row i is divided (EWD) or multiplied
    (EWM) by blinding entry v_i.
  * PRT obfuscation: the scaled matrix is rotated by k ∈ {1,2,3} clockwise
    quarter-turns, k = Rotate(Ψ) = (⌊Ψ⌋ mod 3) + 1.

Both are applied in a single pass ("run simultaneously", §IV.C): the fused
Pallas kernel (kernels/ced.py) reads each input tile once, scales it in
VMEM, and writes it to the rotated destination via the BlockSpec index map —
the rotation costs nothing beyond addressing. This module is the public API;
it dispatches to the fused kernel or a pure-jnp path.

Determinant bookkeeping (used by Decipher):

    EWD:  det(X) = det(M) / Ψ · s      EWM:  det(X) = det(M) · Ψ · s

with s = rotation_sign(n, k).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .keygen import Key
from .prt import rot90_cw, rotate_degree
from .seed import Seed

Mode = Literal["ewd", "ewm"]


@dataclass(frozen=True)
class CipherMeta:
    """Public-side record of how M was ciphered (client keeps this)."""

    mode: Mode
    rotate_k: int  # quarter-turns applied
    n: int


def ewo(m: jnp.ndarray, v: jnp.ndarray, mode: Mode) -> jnp.ndarray:
    """Element-wise obfuscation: row-scale by the blinding vector."""
    v = v.reshape(-1, 1).astype(m.dtype)
    if mode == "ewd":
        return m / v
    if mode == "ewm":
        return m * v
    raise ValueError(f"unknown EWO mode: {mode!r}")


def cipher(
    m: jnp.ndarray,
    key: Key,
    seed: Seed,
    *,
    mode: Mode = "ewd",
    use_kernel: bool = False,
    interpret: bool = True,
) -> tuple[jnp.ndarray, CipherMeta]:
    """Cipher(K, M) → X. Returns the ciphertext and the (client-held) meta.

    use_kernel selects the fused Pallas CED kernel (TPU target; interpret
    mode executes it on CPU). The jnp path is the oracle.
    """
    n = int(m.shape[0])
    if key.v.shape[0] != n:
        raise ValueError(f"blinding vector length {key.v.shape[0]} != n {n}")
    k = rotate_degree(seed.psi)
    if use_kernel:
        from repro.kernels import ops as kops

        x = kops.ced(m, jnp.asarray(key.v), k, mode=mode, interpret=interpret)
    else:
        x = rot90_cw(ewo(m, jnp.asarray(key.v), mode), k)
    return x, CipherMeta(mode=mode, rotate_k=k, n=n)


@partial(jax.jit, static_argnames=("mode",))
def _cipher_batch_jnp(m: jnp.ndarray, v: jnp.ndarray, ks: jnp.ndarray,
                      *, mode: Mode) -> jnp.ndarray:
    """Batched CED, pure jnp: per-matrix blinding vector AND rotation degree.

    The per-example quarter-turn count is data (each matrix has its own
    seed), so the rotation is a vmapped lax.switch over the four turn
    counts — XLA lowers it to selects over cheap relayouts; still zero
    flops beyond the blinding scale.
    """

    def one(mi, vi, ki):
        scaled = ewo(mi, vi, mode)
        return lax.switch(
            ki % 4,
            [
                lambda a: a,
                lambda a: jnp.rot90(a, k=-1, axes=(0, 1)),
                lambda a: jnp.rot90(a, k=-2, axes=(0, 1)),
                lambda a: jnp.rot90(a, k=-3, axes=(0, 1)),
            ],
            scaled,
        )

    return jax.vmap(one)(m, v, ks)


def cipher_batch(
    m: jnp.ndarray,
    key_vs: np.ndarray | jnp.ndarray,
    seeds: list[Seed],
    *,
    mode: Mode = "ewd",
    use_kernel: bool = False,
    interpret: bool = True,
) -> tuple[jnp.ndarray, list[CipherMeta]]:
    """Batched Cipher: (B, n, n) stack + (B, n) stacked blinding vectors.

    Pure-jnp path is one jitted vmapped program. The Pallas path groups the
    batch by rotation degree (the kernel's output index map is static in k)
    and launches one batched-grid kernel per group — at most 3 launches for
    any B.
    """
    B, n = int(m.shape[0]), int(m.shape[-1])
    if len(seeds) != B:
        raise ValueError(f"{len(seeds)} seeds for batch of {B}")
    v = jnp.asarray(key_vs, dtype=m.dtype)
    if v.shape != (B, n):
        raise ValueError(f"blinding stack shape {v.shape} != {(B, n)}")
    ks = np.array([rotate_degree(s.psi) for s in seeds], dtype=np.int32)
    metas = [CipherMeta(mode=mode, rotate_k=int(k), n=n) for k in ks]
    if use_kernel:
        from repro.kernels import ops as kops

        x = jnp.zeros_like(m)
        for k in sorted(set(ks.tolist())):
            idx = np.nonzero(ks == k)[0]
            xk = kops.ced(m[idx], v[idx], int(k), mode=mode,
                          interpret=interpret)
            x = x.at[idx].set(xk)
    else:
        x = _cipher_batch_jnp(m, v, jnp.asarray(ks), mode=mode)
    return x, metas


def cipher_flops(n: int) -> int:
    """Cipher cost model — paper Table I claims n² flops for our protocol.

    One multiply (or divide) per element; the rotation is pure data
    movement (0 flops).
    """
    return n * n
