"""Silent-data-corruption (SDC) detection via Freivalds projection checks.

Beyond-paper extension (DESIGN.md §2): the paper's Q2 is a scalar Freivalds
check specialized to LU. The same O(n²) projection verifies any outsourced
matmul C = A·B — exactly the integrity problem a 1000+-chip training fleet
has with silently corrupting cores. We expose:

  * freivalds_residual(a, b, c, key)  — scalar |rᵀ(A(Br) − Cr)| residual
  * checked_matmul(a, b, key)         — matmul + residual, jit-safe
  * check_step_outputs(...)           — verify a pytree of (A,B,C) triples

These run at O(n²) against the O(n³) they protect, i.e. ~b⁻¹ relative
overhead for block size b — negligible at LM shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def freivalds_residual(
    a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray, key: jax.Array
) -> jnp.ndarray:
    """Relative scalar residual of the claim C = A @ B (last-2-dims matmul)."""
    r = jax.random.rademacher(key, (b.shape[-1],), dtype=c.dtype)
    lhs = a @ (b @ r)
    rhs = c @ r
    num = jnp.linalg.norm(lhs - rhs)
    den = jnp.linalg.norm(rhs) + jnp.asarray(1e-30, c.dtype)
    return num / den


def sdc_flag(residual: jnp.ndarray, *, dtype=None, c: float = 1e3) -> jnp.ndarray:
    """True iff the residual exceeds the roundoff-scaled acceptance bound."""
    eps = jnp.finfo(dtype or residual.dtype).eps
    return residual > c * eps


def checked_matmul(
    a: jnp.ndarray, b: jnp.ndarray, key: jax.Array
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """C = A@B plus its Freivalds residual (jit/pjit-safe, collective-free)."""
    c = a @ b
    return c, freivalds_residual(a, b, c, key)


def check_step_outputs(triples, key: jax.Array) -> jnp.ndarray:
    """Max residual over an iterable of (A, B, C) claims (e.g. one per layer)."""
    if not triples:
        return jnp.zeros(())
    keys = jax.random.split(key, len(triples))
    resids = [
        freivalds_residual(a, b, c, k)
        for (a, b, c), k in zip(triples, keys, strict=True)
    ]
    return jnp.max(jnp.stack(resids))
