"""SPDC core — the paper's contribution as composable JAX modules."""
from .augment import (
    augment,
    augment_block_row,
    augment_for_servers,
    padding_for_servers,
    padding_to_even,
)
from .cipher import (
    CipherMeta,
    cipher,
    cipher_batch,
    cipher_flops,
    equilibrate,
    ewo,
)
from .decipher import Determinant, decipher, decipher_batch, decipher_flops
from .faults import (
    FaultPlan,
    ServerFault,
    apply_faults,
    corrupt_strip,
    normalize_plan,
    resolve_delays,
)
from .inverse import SPDCInverseResult, outsource_inverse
from .keygen import Key, keygen, keygen_batch
from .lu import (
    CommLog,
    det_from_lu,
    lu_block_row,
    lu_blocked,
    lu_diag_factor,
    lu_nserver,
    lu_panel_blocked,
    lu_unblocked,
    nserver_comm_model,
    slogdet_from_lu,
    slogdet_pair_from_lu,
)
from .protocol import (
    SPDCBatchResult,
    SPDCResult,
    common_padded_size,
    outsource_determinant,
    outsource_determinant_mixed,
    resolve_dtype,
)
from .prt import (
    flip_sign,
    growth_safe_sign,
    quantize_seed,
    rot90_cw,
    rotate_degree,
    rotation_sign,
    rotation_sign_paper,
    sign_preserved,
)
from .sdc import checked_matmul, freivalds_residual, sdc_flag
from .seed import Seed, seedgen, seedgen_batch
from .verify import (
    Verdict,
    authenticate,
    epsilon,
    growth_estimate,
    localize,
    per_server_residuals,
    q1,
    q2,
    q3,
    q3_paper_literal,
)

__all__ = [
    "augment", "augment_block_row", "augment_for_servers",
    "padding_for_servers", "padding_to_even",
    "CipherMeta", "cipher", "cipher_batch", "cipher_flops", "equilibrate",
    "ewo",
    "Determinant", "decipher", "decipher_batch", "decipher_flops",
    "FaultPlan", "ServerFault", "apply_faults", "corrupt_strip",
    "normalize_plan", "resolve_delays",
    "Key", "keygen", "keygen_batch",
    "SPDCInverseResult", "outsource_inverse",
    "CommLog", "det_from_lu", "lu_block_row", "lu_blocked", "lu_diag_factor",
    "lu_nserver", "lu_panel_blocked", "lu_unblocked", "nserver_comm_model",
    "slogdet_from_lu", "slogdet_pair_from_lu",
    "SPDCBatchResult", "SPDCResult", "common_padded_size",
    "outsource_determinant", "outsource_determinant_mixed", "resolve_dtype",
    "flip_sign", "growth_safe_sign",
    "quantize_seed", "rot90_cw", "rotate_degree", "rotation_sign",
    "rotation_sign_paper", "sign_preserved",
    "checked_matmul", "freivalds_residual", "sdc_flag",
    "Seed", "seedgen", "seedgen_batch",
    "Verdict", "authenticate", "epsilon", "growth_estimate", "localize",
    "per_server_residuals",
    "q1", "q2", "q3", "q3_paper_literal",
]
