"""LU factorization — unblocked, blocked, and the paper's N-server schedule.

The paper (§IV.D, Algorithms 1–3) computes LU *without pivoting* on the
ciphered matrix: the schedule must be value-independent (pivot choices leak
magnitudes), and the client's ε(N)-thresholded Q2/Q3 check (§IV.E) is the
paper's own guard against the resulting numerical drift.

Implementations, used as successive oracles for one another:

  * lu_unblocked     — textbook Doolittle elimination, pure jnp (oracle).
  * lu_panel_blocked — blocked factorization of one diagonal tile: the
                       panel→TRSM→Schur structure of lu_blocked applied
                       *inside* the b×b tile, shrinking the sequential
                       critical path from b dependent rank-1 updates to
                       b/inner panel steps + matmuls (DESIGN.md §1.1).
  * lu_blocked       — right-looking block LU (panel → TRSM → Schur GEMM),
                       the per-server local computation. Optionally uses the
                       Pallas kernels (kernels/ops.py) for panel/TRSM/GEMM.
  * lu_nserver       — the paper's Algorithm 3: server i owns block row i;
                       computes L_{i,1..i-1}, factors X_ii, computes
                       U_{i,i+1..N}; one-way message log recorded exactly as
                       the paper's communication pattern prescribes.

All pure-jnp paths accept leading batch dimensions — (..., n, n) — so a
stack of matrices factors in one call (DESIGN.md §3); jax.vmap composes
with them as well.

Paper errata handled here (see DESIGN.md §1.1): Alg. 3 line 7 writes
U_kk^{-1}(X_ik − …) — the inverse must right-multiply (cf. Alg. 1 line 3,
L21 = X21·U11^{-1}); line 8 writes Σ L_ik U_ik — the correct Schur term is
Σ L_ik U_ki (cf. Alg. 1 line 5). We implement the corrected algebra.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


# ---------------------------------------------------------------------------
# unblocked (oracle)
# ---------------------------------------------------------------------------
def _doolittle_compact(a: jnp.ndarray) -> jnp.ndarray:
    """Doolittle elimination on (..., n, n) without pivoting.

    Returns the compact form: strict-lower multipliers + U in one array.
    """
    n = a.shape[-1]
    idx = jnp.arange(n)

    def body(k, a):
        below = idx > k
        pivot = a[..., k, k]
        lcol = jnp.where(below, a[..., :, k] / pivot[..., None], 0.0)
        urow = jnp.where(below, a[..., k, :], 0.0)
        a = a - lcol[..., :, None] * urow[..., None, :]
        return a.at[..., :, k].set(jnp.where(below, lcol, a[..., :, k]))

    return lax.fori_loop(0, n, body, a)


def _split_compact(a: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(L unit-lower, U upper) from the compact form; batch-aware."""
    n = a.shape[-1]
    l = jnp.tril(a, -1) + jnp.eye(n, dtype=a.dtype)
    u = jnp.triu(a)
    return l, u


def lu_unblocked(a: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Doolittle LU without pivoting on (..., n, n).

    Returns (L unit-lower, U upper) with matching leading batch dims.
    """
    return _split_compact(_doolittle_compact(a))


def _trsm_right_upper(u: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Solve Z U = B  →  Z = B U^{-1} via (Uᵀ)^{-1} Bᵀ; batch-aware."""
    ut = jnp.swapaxes(u, -1, -2)
    bt = jnp.swapaxes(b, -1, -2)
    z = jax.scipy.linalg.solve_triangular(ut, bt, lower=True)
    return jnp.swapaxes(z, -1, -2)


# ---------------------------------------------------------------------------
# blocked panel — the pipeline's per-round diagonal factorization
# ---------------------------------------------------------------------------
def lu_panel_blocked(
    a: jnp.ndarray, inner: int = 32
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Blocked factorization of a (..., b, b) diagonal tile.

    Reuses lu_blocked's panel→TRSM→Schur structure *inside* the tile: only
    the inner×inner sub-panels run the dependent Doolittle elimination; the
    off-diagonal strips are triangular solves and the trailing update is one
    GEMM per step. The sequential critical path drops from b dependent
    rank-1 updates to ceil(b/inner) panel factorizations — this is the
    factorization used on the N-server pipeline's critical path (§IV.D,
    DESIGN.md §1.1). Handles ragged tails (b not a multiple of inner) with
    a short final panel. Batch-aware over leading dims.
    """
    b = a.shape[-1]
    if b <= inner:
        return _split_compact(_doolittle_compact(a))
    for s0 in range(0, b, inner):
        s1 = min(s0 + inner, b)
        diag = _doolittle_compact(a[..., s0:s1, s0:s1])
        a = a.at[..., s0:s1, s0:s1].set(diag)
        if s1 < b:
            lkk = jnp.tril(diag, -1) + jnp.eye(s1 - s0, dtype=a.dtype)
            ukk = jnp.triu(diag)
            u_right = jax.scipy.linalg.solve_triangular(
                lkk, a[..., s0:s1, s1:], lower=True, unit_diagonal=True
            )
            l_below = _trsm_right_upper(ukk, a[..., s1:, s0:s1])
            a = a.at[..., s0:s1, s1:].set(u_right)
            a = a.at[..., s1:, s0:s1].set(l_below)
            a = a.at[..., s1:, s1:].add(-(l_below @ u_right))
    return _split_compact(a)


#: tile sizes >= this threshold take the blocked-panel path on the pipeline
#: critical path (below it the matmuls are too small to beat plain Doolittle)
PANEL_BLOCK_THRESHOLD = 64


def lu_diag_factor(a: jnp.ndarray, inner: int = 32) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Factor a diagonal tile, choosing blocked vs plain by tile size.

    This is THE entry point for every per-round diagonal factorization in
    lu_nserver and the shard_map pipeline: for b >= PANEL_BLOCK_THRESHOLD
    the blocked panel runs (no full-tile Doolittle on the critical path).
    """
    if a.shape[-1] >= PANEL_BLOCK_THRESHOLD:
        return lu_panel_blocked(a, inner=inner)
    return lu_unblocked(a)


# ---------------------------------------------------------------------------
# blocked right-looking (per-server local compute)
# ---------------------------------------------------------------------------
def lu_blocked(
    a: jnp.ndarray,
    block: int,
    *,
    use_kernels: bool = False,
    interpret: bool = True,
    acc_dtype=None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Right-looking block LU on (..., n, n). n must be divisible by block.

    Per step k over the block diagonal:
      panel:  X_kk = L_kk U_kk              (blocked-panel factorization)
      trsm:   U_kj = L_kk^{-1} X_kj (j>k);  L_ik = X_ik U_kk^{-1} (i>k)
      schur:  X_ij -= L_ik U_kj             (i,j > k — the GEMM hot spot)

    acc_dtype: optional wider accumulation dtype — the "mixed" variant
    (DESIGN.md §6.4): float32 inputs/outputs with float64 accumulation of
    the panel/TRSM/Schur arithmetic. On the jnp path the working matrix is
    upcast once and the factors are cast back; the kernel path threads
    acc_dtype through each Pallas kernel (each tile computes wide in VMEM,
    stores narrow). float64 accumulation requires a backend with f64
    support (CPU, GPU) — TPU callers stay at the storage dtype.
    """
    n = a.shape[-1]
    if n % block != 0:
        raise ValueError(f"n={n} not divisible by block={block}")
    nb = n // block
    out_dtype = a.dtype
    if acc_dtype is not None and not use_kernels:
        a = a.astype(acc_dtype)

    if use_kernels:
        from repro.kernels import ops as kops

        def panel(x):
            return kops.lu_panel(x, interpret=interpret, acc_dtype=acc_dtype)

        def trsm_l(l, b):
            return kops.trsm_lower(l, b, interpret=interpret,
                                   acc_dtype=acc_dtype)

        def trsm_u(u, b):
            return kops.trsm_upper_right(u, b, interpret=interpret,
                                         acc_dtype=acc_dtype)

        def schur(c, l, u_):
            return kops.schur_update(c, l, u_, interpret=interpret,
                                     acc_dtype=acc_dtype)
    else:
        panel = lu_diag_factor

        def trsm_l(l, b):
            return jax.scipy.linalg.solve_triangular(
                l, b, lower=True, unit_diagonal=True
            )

        trsm_u = _trsm_right_upper

        def schur(c, l, u_):
            return c - l @ u_

    # Work on an nb×nb grid of views. Python loop: nb is static & small.
    blocks = [
        [
            a[..., i * block : (i + 1) * block, j * block : (j + 1) * block]
            for j in range(nb)
        ]
        for i in range(nb)
    ]
    lout = [[None] * nb for _ in range(nb)]
    uout = [[None] * nb for _ in range(nb)]
    zero = jnp.zeros((*a.shape[:-2], block, block), dtype=a.dtype)

    for k in range(nb):
        lkk, ukk = panel(blocks[k][k])
        lout[k][k], uout[k][k] = lkk, ukk
        for j in range(k + 1, nb):
            uout[k][j] = trsm_l(lkk, blocks[k][j])
        for i in range(k + 1, nb):
            lout[i][k] = trsm_u(ukk, blocks[i][k])
        for i in range(k + 1, nb):
            for j in range(k + 1, nb):
                blocks[i][j] = schur(blocks[i][j], lout[i][k], uout[k][j])

    for i in range(nb):
        for j in range(nb):
            if lout[i][j] is None:
                lout[i][j] = zero
            if uout[i][j] is None:
                uout[i][j] = zero
    l = jnp.block(lout)
    u = jnp.block(uout)
    if l.dtype != out_dtype:
        l, u = l.astype(out_dtype), u.astype(out_dtype)
    return l, u


# ---------------------------------------------------------------------------
# the paper's N-server algorithm (Algorithm 3) with message accounting
# ---------------------------------------------------------------------------
@dataclass
class CommLog:
    """One-way communication record: (src_server, dst_server, n_elements)."""

    messages: list[tuple[int, int, int]] = field(default_factory=list)

    def send(self, src: int, dst: int, elems: int) -> None:
        self.messages.append((src, dst, elems))

    @property
    def total_elements(self) -> int:
        return sum(e for _, _, e in self.messages)

    @property
    def hops(self) -> int:
        return len(self.messages)


def nserver_comm_model(n: int, num_servers: int) -> CommLog:
    """The one-way chain's message log — a pure function of (n, N).

    This IS lu_nserver's log (it builds its CommLog here); also used by the
    batched protocol path (whose LU runs inside jit, where a host-side log
    can't be threaded out) and by comm benchmarks.
    """
    b = n // num_servers
    log = CommLog()
    for i in range(num_servers - 1):
        elems = sum((num_servers - k) * b * b for k in range(i + 1))
        log.send(i, i + 1, elems)
    return log


def _corrupt_row_blocks(blocks, row_faults, *, n, b, batched, factor):
    """In-band injection for lu_nserver: corrupt one server's strip of row
    blocks IN PLACE in the wavefront, so downstream servers consume the
    corrupted relay (the cascading-poison threat model)."""
    from .faults import corrupt_strip

    defined = [j for j in range(len(blocks)) if blocks[j] is not None]
    strip = jnp.concatenate([blocks[j] for j in defined], axis=-1)
    # pad to the full (…, b, n) strip so global column positions line up
    lead = strip.shape[:-2]
    full = jnp.zeros((*lead, b, n), dtype=strip.dtype)
    off = {j: k for k, j in enumerate(defined)}
    for j in defined:
        full = full.at[..., :, j * b : (j + 1) * b].set(
            strip[..., :, off[j] * b : (off[j] + 1) * b]
        )
    for f in row_faults:
        bad = corrupt_strip(full, f, n=n, factor=factor)
        if f.matrices is not None and batched:
            idx = np.asarray(f.matrices, dtype=np.int32)
            full = full.at[idx].set(bad[idx])
        else:
            full = bad
    for j in defined:
        blocks[j] = full[..., :, j * b : (j + 1) * b]


def lu_nserver(
    x: jnp.ndarray, num_servers: int, faults=()
) -> tuple[jnp.ndarray, jnp.ndarray, CommLog]:
    """Paper Algorithm 3 — N-server one-way pipelined block LU.

    Single-process faithful simulation: performs exactly the block operations
    of Alg. 3 in the paper's order and records every inter-server message of
    the one-way chain S_i → S_{i+1}. Server i computes only block row i.
    Accepts (..., n, n) — a batch factors in one sweep of the schedule.
    Returns (L, U, comm_log).

    faults: a FaultPlan (see core.faults). Faults marked ``in_band`` corrupt
    the faulty server's U strip *inside* the wavefront — downstream servers
    consume the poisoned relay, so every later block row is contaminated
    (recovery must cascade). Report-level faults are applied to the
    assembled factors on the way out, exactly as ``apply_faults`` would.
    """
    from .faults import apply_faults, split_plan

    in_band, report = split_plan(faults)
    n = x.shape[-1]
    N = num_servers
    if n % N != 0 or n // N <= 1:
        raise ValueError(
            f"n={n} must be divisible by N={N} with block > 1; augment first"
        )
    b = n // N
    X = [
        [x[..., i * b : (i + 1) * b, j * b : (j + 1) * b] for j in range(N)]
        for i in range(N)
    ]
    L = [[None] * N for _ in range(N)]
    U = [[None] * N for _ in range(N)]
    # one-way forward schedule: server i sends all U rows k <= i to i+1
    log = nserver_comm_model(n, N)

    # Knowledge forwarded along the one-way chain: U rows of upstream servers.
    # (Server i receives {U_kj : k < i, j >= k} from server i-1 and forwards
    # them, plus its own row, to i+1 — §IV.D.3.)
    for i in range(N):
        # L_{ik} for k < i (corrected right-multiply; see module docstring)
        for k in range(i):
            acc = X[i][k]
            for m in range(k):
                acc = acc - L[i][m] @ U[m][k]
            # L_ik U_kk = acc  =>  L_ik = acc @ U_kk^{-1}
            L[i][k] = _trsm_right_upper(U[k][k], acc)
        # Schur update of the diagonal block (corrected U_{ki}); the
        # factorization itself is the blocked panel for b >= 64 — no
        # full-tile Doolittle on the critical path (DESIGN.md §1.1).
        acc = X[i][i]
        for k in range(i):
            acc = acc - L[i][k] @ U[k][i]
        L[i][i], U[i][i] = lu_diag_factor(acc)
        # U_{ij} for j > i
        for j in range(i + 1, N):
            acc = X[i][j]
            for k in range(i):
                acc = acc - L[i][k] @ U[k][j]
            U[i][j] = jax.scipy.linalg.solve_triangular(
                L[i][i], acc, lower=True, unit_diagonal=True
            )
        # in-band faults: server i corrupts its strips BEFORE the relay hop,
        # so rows > i are computed against the poisoned U row
        row_faults = [f for f in in_band if f.server == i]
        if row_faults:
            batched = x.ndim == 3
            u_faults = [f for f in row_faults if "u" in f.target]
            l_faults = [f for f in row_faults if "l" in f.target]
            if u_faults:
                _corrupt_row_blocks(
                    U[i], u_faults, n=n, b=b, batched=batched, factor="u"
                )
            if l_faults:
                _corrupt_row_blocks(
                    L[i], l_faults, n=n, b=b, batched=batched, factor="l"
                )

    zero = jnp.zeros((*x.shape[:-2], b, b), dtype=x.dtype)
    for i in range(N):
        for j in range(N):
            if L[i][j] is None:
                L[i][j] = zero
            if U[i][j] is None:
                U[i][j] = zero
    l_out, u_out = jnp.block(L), jnp.block(U)
    if report:
        l_out, u_out = apply_faults(l_out, u_out, report, num_servers=N)
    return l_out, u_out, log


def lu_block_row(
    x: jnp.ndarray,
    u: jnp.ndarray,
    server: int,
    num_servers: int,
    *,
    style: str = "nserver",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Recompute one server's block row of the Alg.-3 factorization.

    This is the recovery primitive (distrib/recovery.py): given the
    ciphertext ``x`` and factors whose U rows *above* ``server`` are
    verified-correct, recompute exactly the (L strip, U strip) that server
    ``server`` should have reported. Rows of ``u`` at or below the faulty
    block row are masked out, so a corrupted or dropped strip never
    contaminates its own recomputation.

    style selects the *operation order*, which must match the execution
    path that produced the surviving rows — otherwise the recomputed strip
    differs from the honest one by enough rounding that the re-verification
    residual of the (honest!) downstream rows can graze ε(N):

      * "nserver"  — block-wise accumulation, bit-matching lu_nserver (the
        single-process simulation, the protocol's default Parallelize).
      * "pipeline" — full-row matmul accumulation, matching the shard_map
        server program (distrib/spdc_pipeline).

    Batch-aware over leading dims. Returns strips of shape (..., b, n).
    """
    n = x.shape[-1]
    N = num_servers
    if n % N != 0 or n // N <= 1:
        raise ValueError(f"n={n} not partitionable over N={N}")
    if not 0 <= server < N:
        raise ValueError(f"server {server} out of range for N={N}")
    if style not in ("nserver", "pipeline"):
        raise ValueError(f"unknown style {style!r}")
    b = n // N
    s0 = server * b
    x_row = x[..., s0 : s0 + b, :]
    rows = jnp.arange(n)
    u_above = jnp.where((rows < s0)[:, None], u, 0.0)
    l_row = jnp.zeros_like(x_row)

    if style == "pipeline":
        for k in range(server):
            kb = k * b
            u_col = u_above[..., :, kb : kb + b]
            acc = x_row[..., :, kb : kb + b] - l_row @ u_col
            ukk = u_above[..., kb : kb + b, kb : kb + b]
            lik = _trsm_right_upper(ukk, acc)
            l_row = l_row.at[..., :, kb : kb + b].set(lik)
        s = x_row - l_row @ u_above
        sii = s[..., :, s0 : s0 + b]
        lii, _ = lu_diag_factor(sii)
        l_row = l_row.at[..., :, s0 : s0 + b].set(lii)
        r = jax.scipy.linalg.solve_triangular(
            lii, s, lower=True, unit_diagonal=True
        )
        u_row = jnp.where((rows >= s0)[None, :], r, 0.0)
        return l_row, u_row

    # "nserver": mirror lu_nserver's per-block sequential accumulation
    def blk(a, i, j):
        return a[..., i * b : (i + 1) * b, j * b : (j + 1) * b]

    L = [None] * N
    for k in range(server):
        acc = blk(x, server, k)
        for m in range(k):
            acc = acc - L[m] @ blk(u_above, m, k)
        L[k] = _trsm_right_upper(blk(u_above, k, k), acc)
        l_row = l_row.at[..., :, k * b : (k + 1) * b].set(L[k])
    acc = blk(x, server, server)
    for k in range(server):
        acc = acc - L[k] @ blk(u_above, k, server)
    lii, uii = lu_diag_factor(acc)
    l_row = l_row.at[..., :, s0 : s0 + b].set(lii)
    u_row = jnp.zeros_like(x_row)
    u_row = u_row.at[..., :, s0 : s0 + b].set(uii)
    for j in range(server + 1, N):
        acc = blk(x, server, j)
        for k in range(server):
            acc = acc - L[k] @ blk(u_above, k, j)
        uij = jax.scipy.linalg.solve_triangular(
            lii, acc, lower=True, unit_diagonal=True
        )
        u_row = u_row.at[..., :, j * b : (j + 1) * b].set(uij)
    return l_row, u_row


# ---------------------------------------------------------------------------
# determinant from LU
# ---------------------------------------------------------------------------
def _neumaier_sum(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Compensated (Kahan–Babuška/Neumaier) sum over the LAST axis.

    Returns the (hi, lo) pair whose exact value hi + lo carries the sum to
    ~u² relative error — the lost low-order bits of every addition are
    accumulated in lo instead of discarded. In float32 a naive sum of n
    log terms loses ~n·u·|partial-sum| absolute accuracy, which at
    n = 1024 can exceed the 1e-4 log-space budget; the compensated pair,
    recombined in float64 on the host, does not. Batch-aware over leading
    dims; differentiably irrelevant (used only for reporting).
    """
    xt = jnp.moveaxis(x, -1, 0)
    zeros = jnp.zeros(xt.shape[1:], dtype=x.dtype)

    def step(carry, xi):
        s, c = carry
        t = s + xi
        # whichever operand is larger kept its bits; the smaller one's
        # truncated tail is recovered exactly
        c = c + jnp.where(jnp.abs(s) >= jnp.abs(xi),
                          (s - t) + xi, (xi - t) + s)
        return (t, c), None

    (s, c), _ = lax.scan(step, (zeros, zeros), xt)
    return s, c


def slogdet_pair_from_lu(
    l: jnp.ndarray, u: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(sign, logabs_hi, logabs_lo) from LU factors — the compensated form.

    log|det| = hi + lo exactly (recombine in float64 on the host: a single
    float32 cannot even REPRESENT log|det| ≈ 1000 to 1e-4 absolute — its
    ulp there is 2^-23·1024 ≈ 1.2e-4 — so the split is load-bearing for
    float32 compute, not an optimization). Decipher consumes this;
    `slogdet_from_lu` keeps the legacy single-float API.
    """
    d = jnp.diagonal(l, axis1=-2, axis2=-1) * jnp.diagonal(u, axis1=-2, axis2=-1)
    sign = jnp.prod(jnp.sign(d), axis=-1)
    hi, lo = _neumaier_sum(jnp.log(jnp.abs(d)))
    return sign, hi, lo


def slogdet_from_lu(l: jnp.ndarray, u: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(sign, log|det|) from LU factors — paper §IV.F.1 in overflow-safe form.

    det(X) = Π L_ii · Π U_ii; L is unit-diagonal in our construction but we
    include its diagonal anyway to match the paper's formula. Batch-aware:
    (..., n, n) factors give (...,)-shaped sign and logabs. The log sum is
    compensated (slogdet_pair_from_lu) so B×n=1024 float32 stacks don't
    lose digits; here the pair is recombined in the compute dtype — use
    the pair form when the caller can recombine in float64.
    """
    sign, hi, lo = slogdet_pair_from_lu(l, u)
    return sign, hi + lo


def det_from_lu(l: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    sign, logabs = slogdet_from_lu(l, u)
    return sign * jnp.exp(logabs)
