"""LU factorization — unblocked, blocked, and the paper's N-server schedule.

The paper (§IV.D, Algorithms 1–3) computes LU *without pivoting* on the
ciphered matrix: the schedule must be value-independent (pivot choices leak
magnitudes), and the client's ε(N)-thresholded Q2/Q3 check (§IV.E) is the
paper's own guard against the resulting numerical drift.

Three implementations, used as successive oracles for one another:

  * lu_unblocked     — textbook Doolittle elimination, pure jnp (oracle).
  * lu_blocked       — right-looking block LU (panel → TRSM → Schur GEMM),
                       the per-server local computation. Optionally uses the
                       Pallas kernels (kernels/ops.py) for panel/TRSM/GEMM.
  * lu_nserver       — the paper's Algorithm 3: server i owns block row i;
                       computes L_{i,1..i-1}, factors X_ii, computes
                       U_{i,i+1..N}; one-way message log recorded exactly as
                       the paper's communication pattern prescribes.

Paper errata handled here (see DESIGN.md §1.1): Alg. 3 line 7 writes
U_kk^{-1}(X_ik − …) — the inverse must right-multiply (cf. Alg. 1 line 3,
L21 = X21·U11^{-1}); line 8 writes Σ L_ik U_ik — the correct Schur term is
Σ L_ik U_ki (cf. Alg. 1 line 5). We implement the corrected algebra.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# unblocked (oracle)
# ---------------------------------------------------------------------------
def lu_unblocked(a: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Doolittle LU without pivoting. Returns (L unit-lower, U upper)."""
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(k, a):
        below = idx > k
        right = idx > k
        lcol = jnp.where(below, a[:, k] / a[k, k], 0.0)
        urow = jnp.where(right, a[k, :], 0.0)
        a = a - jnp.outer(lcol, urow)
        a = a.at[:, k].set(jnp.where(below, lcol, a[:, k]))
        return a

    a = lax.fori_loop(0, n, body, a)
    l = jnp.tril(a, -1) + jnp.eye(n, dtype=a.dtype)
    u = jnp.triu(a)
    return l, u


# ---------------------------------------------------------------------------
# blocked right-looking (per-server local compute)
# ---------------------------------------------------------------------------
def lu_blocked(
    a: jnp.ndarray,
    block: int,
    *,
    use_kernels: bool = False,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Right-looking block LU. n must be divisible by block.

    Per step k over the block diagonal:
      panel:  X_kk = L_kk U_kk              (in-VMEM unblocked factorization)
      trsm:   U_kj = L_kk^{-1} X_kj (j>k);  L_ik = X_ik U_kk^{-1} (i>k)
      schur:  X_ij -= L_ik U_kj             (i,j > k — the GEMM hot spot)
    """
    n = a.shape[0]
    if n % block != 0:
        raise ValueError(f"n={n} not divisible by block={block}")
    nb = n // block

    if use_kernels:
        from repro.kernels import ops as kops

        panel = lambda x: kops.lu_panel(x, interpret=interpret)
        trsm_l = lambda l, b: kops.trsm_lower(l, b, interpret=interpret)
        trsm_u = lambda u, b: kops.trsm_upper_right(u, b, interpret=interpret)
        schur = lambda c, l, u_: kops.schur_update(c, l, u_, interpret=interpret)
    else:
        panel = lu_unblocked
        trsm_l = lambda l, b: jax.scipy.linalg.solve_triangular(
            l, b, lower=True, unit_diagonal=True
        )
        # solve Z @ U = B  ->  Z = B @ U^{-1} via (U^T)^{-1} B^T
        trsm_u = lambda u, b: jax.scipy.linalg.solve_triangular(
            u.T, b.T, lower=True
        ).T
        schur = lambda c, l, u_: c - l @ u_

    # Work on an nb×nb grid of views. Python loop: nb is static & small.
    blocks = [
        [a[i * block : (i + 1) * block, j * block : (j + 1) * block] for j in range(nb)]
        for i in range(nb)
    ]
    lout = [[None] * nb for _ in range(nb)]
    uout = [[None] * nb for _ in range(nb)]
    zero = jnp.zeros((block, block), dtype=a.dtype)

    for k in range(nb):
        lkk, ukk = panel(blocks[k][k])
        lout[k][k], uout[k][k] = lkk, ukk
        for j in range(k + 1, nb):
            uout[k][j] = trsm_l(lkk, blocks[k][j])
        for i in range(k + 1, nb):
            lout[i][k] = trsm_u(ukk, blocks[i][k])
        for i in range(k + 1, nb):
            for j in range(k + 1, nb):
                blocks[i][j] = schur(blocks[i][j], lout[i][k], uout[k][j])

    for i in range(nb):
        for j in range(nb):
            if lout[i][j] is None:
                lout[i][j] = zero
            if uout[i][j] is None:
                uout[i][j] = zero
    l = jnp.block(lout)
    u = jnp.block(uout)
    return l, u


# ---------------------------------------------------------------------------
# the paper's N-server algorithm (Algorithm 3) with message accounting
# ---------------------------------------------------------------------------
@dataclass
class CommLog:
    """One-way communication record: (src_server, dst_server, n_elements)."""

    messages: list[tuple[int, int, int]] = field(default_factory=list)

    def send(self, src: int, dst: int, elems: int) -> None:
        self.messages.append((src, dst, elems))

    @property
    def total_elements(self) -> int:
        return sum(e for _, _, e in self.messages)

    @property
    def hops(self) -> int:
        return len(self.messages)


def lu_nserver(
    x: jnp.ndarray, num_servers: int
) -> tuple[jnp.ndarray, jnp.ndarray, CommLog]:
    """Paper Algorithm 3 — N-server one-way pipelined block LU.

    Single-process faithful simulation: performs exactly the block operations
    of Alg. 3 in the paper's order and records every inter-server message of
    the one-way chain S_i → S_{i+1}. Server i computes only block row i.
    Returns (L, U, comm_log).
    """
    n = x.shape[0]
    N = num_servers
    if n % N != 0 or n // N <= 1:
        raise ValueError(
            f"n={n} must be divisible by N={N} with block > 1; augment first"
        )
    b = n // N
    X = [
        [x[i * b : (i + 1) * b, j * b : (j + 1) * b] for j in range(N)]
        for i in range(N)
    ]
    L = [[None] * N for _ in range(N)]
    U = [[None] * N for _ in range(N)]
    log = CommLog()

    # Knowledge forwarded along the one-way chain: U rows of upstream servers.
    # (Server i receives {U_kj : k < i, j >= k} from server i-1 and forwards
    # them, plus its own row, to i+1 — §IV.D.3.)
    for i in range(N):
        # L_{ik} for k < i (corrected right-multiply; see module docstring)
        for k in range(i):
            acc = X[i][k]
            for m in range(k):
                acc = acc - L[i][m] @ U[m][k]
            # L_ik U_kk = acc  =>  L_ik = acc @ U_kk^{-1}
            L[i][k] = jax.scipy.linalg.solve_triangular(U[k][k].T, acc.T, lower=True).T
        # Schur update of the diagonal block (corrected U_{ki})
        acc = X[i][i]
        for k in range(i):
            acc = acc - L[i][k] @ U[k][i]
        L[i][i], U[i][i] = lu_unblocked(acc)
        # U_{ij} for j > i
        for j in range(i + 1, N):
            acc = X[i][j]
            for k in range(i):
                acc = acc - L[i][k] @ U[k][j]
            U[i][j] = jax.scipy.linalg.solve_triangular(
                L[i][i], acc, lower=True, unit_diagonal=True
            )
        # one-way forward: server i sends all U rows k <= i to server i+1
        if i + 1 < N:
            elems = sum((N - k) * b * b for k in range(i + 1))
            log.send(i, i + 1, elems)

    zero = jnp.zeros((b, b), dtype=x.dtype)
    for i in range(N):
        for j in range(N):
            if L[i][j] is None:
                L[i][j] = zero
            if U[i][j] is None:
                U[i][j] = zero
    return jnp.block(L), jnp.block(U), log


# ---------------------------------------------------------------------------
# determinant from LU
# ---------------------------------------------------------------------------
def slogdet_from_lu(l: jnp.ndarray, u: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(sign, log|det|) from LU factors — paper §IV.F.1 in overflow-safe form.

    det(X) = Π L_ii · Π U_ii; L is unit-diagonal in our construction but we
    include its diagonal anyway to match the paper's formula.
    """
    d = jnp.diagonal(l) * jnp.diagonal(u)
    sign = jnp.prod(jnp.sign(d))
    logabs = jnp.sum(jnp.log(jnp.abs(d)))
    return sign, logabs


def det_from_lu(l: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    sign, logabs = slogdet_from_lu(l, u)
    return sign * jnp.exp(logabs)
