"""Determinant-preserving matrix augmentation — paper §II.B and §IV.D.1.

Pads an n×n matrix A to (n+p)×(n+p) as the block matrix

    B = [[A, 0],
         [R, I_p]]

where R is arbitrary (we draw it from a PRNG so padding leaks no structure)
and the lower-right block is the p×p identity, so det(B) = det(A)·det(I) =
det(A). p is the smallest non-negative integer such that (n+p) is divisible
by the server count N and (n+p)/N > 1 (paper §IV.D.1), or such that (n+p)
is even for the "nearest-even" mode (paper §VI.C).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def padding_for_servers(n: int, num_servers: int) -> int:
    """Minimum p ≥ 0 with (n+p) % N == 0 and (n+p)/N > 1 (paper §IV.D.1)."""
    if num_servers < 1:
        raise ValueError("num_servers must be >= 1")
    p = 0
    while (n + p) % num_servers != 0 or (n + p) // num_servers <= 1:
        p += 1
    return p


def padding_to_even(n: int) -> int:
    """Nearest-even padding (paper §VI.C): p ∈ {0, 1}."""
    return n % 2


def augment(a: jnp.ndarray, p: int, *, key: jax.Array | None = None) -> jnp.ndarray:
    """Pad a to (n+p)×(n+p) preserving det. R-block random if key given.

    Batch-aware: (..., n, n) inputs get per-matrix independent R blocks
    from the same key (the draw covers the leading dims).
    """
    if p == 0:
        return a
    n = a.shape[-1]
    batch = a.shape[:-2]
    dtype = a.dtype
    if key is not None:
        r = jax.random.uniform(
            key, (*batch, p, n), dtype=dtype, minval=-1.0, maxval=1.0
        )
    else:
        r = jnp.zeros((*batch, p, n), dtype=dtype)
    eye = jnp.broadcast_to(jnp.eye(p, dtype=dtype), (*batch, p, p))
    top = jnp.concatenate([a, jnp.zeros((*batch, n, p), dtype=dtype)], axis=-1)
    bot = jnp.concatenate([r, eye], axis=-1)
    return jnp.concatenate([top, bot], axis=-2)


def augment_for_servers(
    a: jnp.ndarray, num_servers: int, *, key: jax.Array | None = None
) -> tuple[jnp.ndarray, int]:
    """Augment so the result partitions into N×N equal blocks. Returns (B, p)."""
    n = a.shape[-1]
    p = padding_for_servers(n, num_servers)
    return augment(a, p, key=key), p
