"""Determinant-preserving matrix augmentation — paper §II.B and §IV.D.1.

Pads an n×n matrix A to (n+p)×(n+p) as the block matrix

    B = [[A, 0],
         [R, I_p]]

where R is arbitrary (we draw it from a PRNG so padding leaks no structure)
and the lower-right block is the p×p identity, so det(B) = det(A)·det(I) =
det(A). p is the smallest non-negative integer such that (n+p) is divisible
by the server count N and (n+p)/N > 1 (paper §IV.D.1), or such that (n+p)
is even for the "nearest-even" mode (paper §VI.C).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def padding_for_servers(n: int, num_servers: int) -> int:
    """Minimum p ≥ 0 with (n+p) % N == 0 and (n+p)/N > 1 (paper §IV.D.1)."""
    if num_servers < 1:
        raise ValueError("num_servers must be >= 1")
    p = 0
    while (n + p) % num_servers != 0 or (n + p) // num_servers <= 1:
        p += 1
    return p


def padding_to_even(n: int) -> int:
    """Nearest-even padding (paper §VI.C): p ∈ {0, 1}."""
    return n % 2


def augment(a: jnp.ndarray, p: int, *, key: jax.Array | None = None) -> jnp.ndarray:
    """Pad a to (n+p)×(n+p) preserving det. R-block random if key given.

    Batch-aware: (..., n, n) inputs get per-matrix independent R blocks
    from the same key (the draw covers the leading dims).
    """
    if p == 0:
        return a
    n = a.shape[-1]
    batch = a.shape[:-2]
    dtype = a.dtype
    if key is not None:
        r = jax.random.uniform(
            key, (*batch, p, n), dtype=dtype, minval=-1.0, maxval=1.0
        )
    else:
        r = jnp.zeros((*batch, p, n), dtype=dtype)
    eye = jnp.broadcast_to(jnp.eye(p, dtype=dtype), (*batch, p, p))
    top = jnp.concatenate([a, jnp.zeros((*batch, n, p), dtype=dtype)], axis=-1)
    bot = jnp.concatenate([r, eye], axis=-1)
    return jnp.concatenate([top, bot], axis=-2)


def augment_for_servers(
    a: jnp.ndarray, num_servers: int, *, key: jax.Array | None = None
) -> tuple[jnp.ndarray, int]:
    """Augment so the result partitions into N×N equal blocks. Returns (B, p)."""
    n = a.shape[-1]
    p = padding_for_servers(n, num_servers)
    return augment(a, p, key=key), p


def augment_block_row(
    a: jnp.ndarray,
    p: int,
    row0: int,
    rows: int,
    *,
    key: jax.Array | None = None,
) -> jnp.ndarray:
    """Rows [row0, row0+rows) of `augment(a, p, key=key)` WITHOUT building
    the full augmented matrix.

    The recovery scheduler (distrib/recovery.py) re-derives exactly one
    server's shard — a (rows, n+p) strip — when re-dispatching after a
    localized fault: the client never has to cache the augmented ciphertext
    to recover, only replay the deterministic padding draw (O(p·n) for R)
    and slice. Bitwise-identical to slicing the full augmentation, because
    the R block is drawn with the same key and shapes.
    """
    n = a.shape[-1]
    batch = a.shape[:-2]
    dtype = a.dtype
    if not 0 <= row0 <= row0 + rows <= n + p:
        raise ValueError(f"rows [{row0}, {row0 + rows}) outside n+p={n + p}")
    if p == 0:
        return a[..., row0 : row0 + rows, :]
    # assemble only the requested rows — slice a (and the identity) BEFORE
    # concatenating; only the R block is drawn full-width so the PRNG
    # stream stays bitwise-identical to augment()'s
    parts = []
    top_rows = min(row0 + rows, n) - row0 if row0 < n else 0
    if top_rows > 0:
        parts.append(
            jnp.concatenate(
                [
                    a[..., row0 : row0 + top_rows, :],
                    jnp.zeros((*batch, top_rows, p), dtype=dtype),
                ],
                axis=-1,
            )
        )
    bot_rows = rows - max(top_rows, 0)
    if bot_rows > 0:
        b0 = max(row0, n) - n
        if key is not None:
            r = jax.random.uniform(
                key, (*batch, p, n), dtype=dtype, minval=-1.0, maxval=1.0
            )
        else:
            r = jnp.zeros((*batch, p, n), dtype=dtype)
        eye_rows = jnp.broadcast_to(
            jnp.eye(p, dtype=dtype)[b0 : b0 + bot_rows], (*batch, bot_rows, p)
        )
        parts.append(
            jnp.concatenate([r[..., b0 : b0 + bot_rows, :], eye_rows], axis=-1)
        )
    if len(parts) == 1:
        return parts[0]
    return jnp.concatenate(parts, axis=-2)
